//! Benchmark harness (criterion is unavailable offline; this is a
//! self-contained timing harness with warmup, repetitions, and mean/σ
//! reporting). Covers the performance-relevant paths of each layer:
//!
//! * P0  host matmul kernels (`Tensor::matmul` / `matmul_t` / `t_matmul`)
//! * P1  pivoted-QR basis extraction (L3 host linalg) vs matrix size
//! * P2  adapter merge (W + Q diag(λ) R)
//! * P3  backend kernel: base matmul vs fused adapter matmul
//! * P4  train-step latency per method (end-to-end backend step)
//! * P5  eval-forward latency + adapter hot-swap cost (serving path)
//!
//! Runs on whatever backend `QRLORA_BACKEND` selects (host by default, so
//! the bench is hermetic), and writes one snapshot of every entry to
//! `BENCH_<backend>.json`; the cross-commit trajectory lives in committed
//! snapshots / the CI artifact, not in the file itself (each run rewrites
//! it).

use std::collections::BTreeMap;
use std::time::Instant;

use qrlora::adapters::{factorize, Proj, Scope};
use qrlora::data::{task, Batcher, Lexicon, TaskData};
use qrlora::linalg::RankRule;
use qrlora::runtime::{create_backend, Backend, BackendChoice, Buffer, DType};
use qrlora::tensor::Tensor;
use qrlora::training::{Method, Methods, Session};
use qrlora::util::json::Json;
use qrlora::util::log::Stats;
use qrlora::util::rng::Rng;

/// Collects (name, stats) rows and writes the BENCH json at the end.
struct Recorder {
    entries: Vec<(String, Stats, usize)>,
}

impl Recorder {
    fn new() -> Recorder {
        Recorder { entries: Vec::new() }
    }

    fn bench<F: FnMut()>(&mut self, name: &str, warmup: usize, iters: usize, mut f: F) {
        for _ in 0..warmup {
            f();
        }
        let mut stats = Stats::new();
        for _ in 0..iters {
            let t = Instant::now();
            f();
            stats.push(t.elapsed().as_secs_f64() * 1e3);
        }
        println!(
            "{name:<48} {:>9.3} ms  ±{:>7.3}  (n={iters}, min {:.3}, max {:.3})",
            stats.mean(),
            stats.std(),
            stats.min,
            stats.max
        );
        self.entries.push((name.to_string(), stats, iters));
    }

    fn write(&self, backend: &str) -> anyhow::Result<()> {
        let rows: Vec<Json> = self
            .entries
            .iter()
            .map(|(name, s, n)| {
                Json::obj(vec![
                    ("name", Json::str(name.clone())),
                    ("mean_ms", Json::num(s.mean())),
                    ("std_ms", Json::num(s.std())),
                    ("min_ms", Json::num(s.min)),
                    ("max_ms", Json::num(s.max)),
                    ("iters", Json::num(*n as f64)),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("backend", Json::str(backend)),
            ("entries", Json::Arr(rows)),
        ]);
        let path = format!("BENCH_{backend}.json");
        std::fs::write(&path, doc.pretty())?;
        println!("\nwrote {path} ({} entries)", self.entries.len());
        Ok(())
    }
}

fn main() -> anyhow::Result<()> {
    println!("qrlora bench harness — all times per call\n");
    let mut rec = Recorder::new();

    // ---- P0: host matmul kernels --------------------------------------
    println!("# P0 host matmul (transposed-B blocked kernel)");
    let mut rng = Rng::new(0);
    for n in [64usize, 128, 256] {
        let a = Tensor::randn(&[n, n], &mut rng, 1.0);
        let b = Tensor::randn(&[n, n], &mut rng, 1.0);
        rec.bench(&format!("matmul {n}x{n}x{n}"), 2, 10, || {
            std::hint::black_box(a.matmul(&b).data[0]);
        });
    }
    {
        let a = Tensor::randn(&[256, 128], &mut rng, 1.0);
        let b = Tensor::randn(&[256, 128], &mut rng, 1.0);
        rec.bench("matmul_t 256x128 @ t(256x128)", 2, 10, || {
            std::hint::black_box(a.matmul_t(&b).data[0]);
        });
        let c = Tensor::randn(&[256, 512], &mut rng, 1.0);
        rec.bench("t_matmul t(256x128) @ 256x512", 2, 10, || {
            std::hint::black_box(a.t_matmul(&c).data[0]);
        });
    }

    // ---- P1: pivoted QR scaling --------------------------------------
    println!("\n# P1 pivoted-QR factorization (host)");
    let mut rng = Rng::new(1);
    for n in [64usize, 128, 256] {
        let w = Tensor::randn(&[n, n], &mut rng, 1.0);
        rec.bench(&format!("pivoted_qr {n}x{n}"), 1, 5, || {
            let f = qrlora::linalg::pivoted_qr(&w);
            std::hint::black_box(f.diag());
        });
    }

    // ---- P2: adapter merge --------------------------------------------
    println!("\n# P2 adapter merge W + Q diag(λ) R (host)");
    for n in [64usize, 128] {
        let w = Tensor::randn(&[n, n], &mut rng, 1.0);
        let f = factorize(&w, 0.5, RankRule::DiagRatio, n / 2);
        let lam = vec![0.1f32; n / 2];
        rec.bench(&format!("merge {n}x{n} r={}", f.used), 1, 10, || {
            let mut qs = f.q.clone();
            for i in 0..qs.rows() {
                for j in 0..qs.cols() {
                    qs.set(i, j, qs.at(i, j) * lam[j] * f.mask[j]);
                }
            }
            let mut out = w.clone();
            out.add_assign(&qs.matmul(&f.r));
            std::hint::black_box(out.data[0]);
        });
    }

    // ---- backend-side benches ------------------------------------------
    let dir = std::env::var("QRLORA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let rt = create_backend(BackendChoice::from_env()?, std::path::Path::new(&dir))?;
    let rt: &dyn Backend = rt.as_ref();
    // The host backend interprets every preset; PJRT benches default to the
    // artifact set's experiment preset.
    let default_preset = if rt.name() == "host" { "tiny" } else { "small" };
    let preset_name =
        std::env::var("QRLORA_BENCH_PRESET").unwrap_or_else(|_| default_preset.into());
    let preset = rt.manifest().preset(&preset_name)?.clone();
    println!("\nbackend: {} (preset {preset_name})", rt.name());

    // P3: kernel microbench through the backend.
    println!("\n# P3 kernel: base vs fused adapter matmul ({preset_name})");
    for key in ["kernel_base", "kernel_adapter"] {
        let exe = rt.load(&format!("{preset_name}/{key}"))?;
        let args: Vec<Buffer> = exe
            .spec
            .inputs
            .iter()
            .map(|t| match t.dtype {
                DType::F32 => rt.upload_f32(&vec![0.01f32; t.numel()], &t.shape).unwrap(),
                DType::I32 => rt.upload_i32(&vec![0; t.numel()], &t.shape).unwrap(),
            })
            .collect();
        let refs: Vec<&Buffer> = args.iter().collect();
        rec.bench(&format!("{key} (fwd)"), 3, 20, || {
            let outs = rt.execute(&exe, &refs).unwrap();
            std::hint::black_box(outs.len());
        });
    }

    // P4: train-step latency per method.
    println!("\n# P4 train step latency per method ({preset_name})");
    let lex = Lexicon::new(preset.vocab);
    let spec = task("sst2")?;
    let data = TaskData::generate(spec, &lex, 3);
    let batcher = Batcher::new(&preset, false);
    let refs: Vec<&qrlora::data::Example> = data.train[..preset.batch].iter().collect();
    let batch = batcher.assemble(&refs);

    // Synthetic backbone (random — latency doesn't depend on values).
    let mut backbone: BTreeMap<String, Tensor> = BTreeMap::new();
    {
        let mut brng = Rng::new(7);
        let exe = rt.load(&format!("{preset_name}/train_step_ft_cls"))?;
        for f in &exe.spec.layout()?.params {
            if !f.name.starts_with("head/") {
                backbone.insert(f.name.clone(), Tensor::randn(&f.shape, &mut brng, 0.05));
            }
        }
    }
    let methods: Vec<(&str, Method)> = vec![
        ("FT", Method::FullFt),
        ("LoRA", Methods::lora(&backbone, &preset, 2.0, 1)?),
        (
            "QR-LoRA",
            Methods::qr_lora(
                &backbone,
                &preset,
                Scope::all_layers(&[Proj::Q, Proj::K, Proj::V, Proj::O]),
                0.5,
                RankRule::DiagRatio,
            )?,
        ),
    ];
    for (name, method) in &methods {
        let mut session = Session::finetune(
            rt,
            &preset,
            method,
            qrlora::data::HeadKind::Cls,
            &backbone,
            None,
            9,
        )?;
        rec.bench(&format!("train_step {name}"), 3, 15, || {
            session.step(&batch, 2, 1e-3).unwrap();
        });
        rec.bench(&format!("metrics read {name}"), 2, 10, || {
            std::hint::black_box(session.last_loss().unwrap());
        });
    }

    // P5: eval forward + adapter swap.
    println!("\n# P5 serving path ({preset_name})");
    let method = &methods.iter().find(|(n, _)| *n == "QR-LoRA").unwrap().1;
    let mut session = Session::finetune(
        rt,
        &preset,
        method,
        qrlora::data::HeadKind::Cls,
        &backbone,
        None,
        10,
    )?;
    rec.bench("eval_fwd QR-LoRA", 3, 15, || {
        std::hint::black_box(session.forward(&batch, 2).unwrap());
    });
    let state = session.download_state()?;
    rec.bench("adapter hot-swap (upload state)", 2, 15, || {
        session.upload_state(&state).unwrap();
    });

    // Footprint summary for the serving claim.
    let qr_state_kib = (session.layout().total * 4) as f64 / 1024.0;
    let ft_params = qrlora::runtime::Preset::approx_backbone_params(&preset);
    println!(
        "\nadapter state {qr_state_kib:.1} KiB vs full-model copy {:.1} MiB ({}x smaller)",
        (ft_params * 4) as f64 / (1024.0 * 1024.0),
        (ft_params * 4) / (session.layout().total * 4).max(1)
    );

    rec.write(rt.name())?;
    Ok(())
}
