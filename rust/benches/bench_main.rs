//! Benchmark harness (criterion is unavailable offline; this is a
//! self-contained timing harness with warmup, repetitions, and mean/σ
//! reporting). Covers the performance-relevant paths of each layer:
//!
//! * P0  host matmul kernels (`Tensor::matmul` / `matmul_t` / `t_matmul`),
//!       each at the default thread count and forced serial (`[t=1]`), plus
//!       a sparse-rows `t_matmul` entry that exercises the zero-skip branch
//! * P1  pivoted-QR basis extraction (L3 host linalg) vs matrix size
//! * P2  adapter merge (W + Q diag(λ) R)
//! * P3  backend kernel: base matmul vs fused adapter matmul
//! * P4  train-step latency per method (end-to-end backend step), default
//!       threads and `[t=1]`
//! * P5  eval-forward latency + adapter hot-swap cost (serving path)
//! * P6  int8-quantized frozen backbone: fused `qmatmul` kernels vs their
//!       f32 twins, quantized eval/serve entries, and the resident-bytes
//!       reduction stat (host-only; see `qrlora::quant`)
//! * P7  adapter store: `serve_warm_start` (registry open + record
//!       load/verify + state restore) vs `serve_cold_start` (train the
//!       adapter) — the per-adapter startup win of `qrlora::store`
//! * P8  serving fleet: aggregate request throughput of `serve --fleet N`
//!       (real worker processes over one shared adapter store) for
//!       N = 1, 2, 4, parsed from the supervisor's `FLEET_AGGREGATE` line,
//!       plus a `serve_fleet_degraded` row that prices the supervision
//!       round trip (crash mid-publish → restart → re-publish) under an
//!       injected `QRLORA_FAULTS` crash
//! * P9  socket serving: `serve --listen` behind the soak load generator
//!       (real loopback TCP, line-delimited JSON) — client-observed
//!       p50/p99/p999 latency and end-to-end RPS
//!
//! Runs on whatever backend `QRLORA_BACKEND` selects (host by default, so
//! the bench is hermetic) with the pool sized by `QRLORA_THREADS` and the
//! host kernel backend by `QRLORA_SIMD`, and writes one snapshot of every
//! entry — including its thread count and kernel backend (`simd`) — to
//! `BENCH_<backend>.json`; the cross-commit trajectory lives in committed
//! snapshots / the CI artifact, not in the file itself (each run rewrites
//! it). Kernel-backend twins (`[t=1, scalar]` / `[t=1, relaxed]`) bracket
//! the default single-thread matmul and qmatmul rows so the SIMD win is
//! measured in the snapshot itself.
//!
//! Baseline comparison: `cargo bench --bench bench_main -- --compare
//! BENCH_host.json [--threshold 20] [--strict]` diffs this run's means
//! against a previously committed snapshot (matching entries by name +
//! thread count) and flags regressions above the threshold; `--strict`
//! exits non-zero when any are found. Inside GitHub Actions the flags are
//! also emitted as `::warning::` annotations.

use std::collections::BTreeMap;
use std::io::BufRead;
use std::time::Instant;

use qrlora::adapters::{factorize, Proj, Scope};
use qrlora::data::{task, Batcher, Lexicon, TaskData};
use qrlora::kernels::{self, Kernels};
use qrlora::linalg::RankRule;
use qrlora::quant::{self, QuantTensor};
use qrlora::runtime::{create_backend, Backend, BackendChoice, Buffer, DType, HostBackend};
use qrlora::store::{AdapterKey, AdapterRecord, Registry};
use qrlora::tensor::Tensor;
use qrlora::training::{Method, Methods, Session};
use qrlora::util::cli::Args;
use qrlora::util::json::Json;
use qrlora::util::log::Stats;
use qrlora::util::pool;
use qrlora::util::rng::Rng;

struct Entry {
    name: String,
    threads: usize,
    /// Kernel backend active when the entry ran (`kernels::Kernels::describe`).
    simd: &'static str,
    stats: Stats,
    iters: usize,
}

/// Collects (name, threads, stats) rows and writes the BENCH json at the end.
struct Recorder {
    entries: Vec<Entry>,
}

impl Recorder {
    fn new() -> Recorder {
        Recorder { entries: Vec::new() }
    }

    /// Time `f` with the pool's partition count forced to `threads`.
    fn bench<F: FnMut()>(
        &mut self,
        name: &str,
        threads: usize,
        warmup: usize,
        iters: usize,
        mut f: F,
    ) {
        // Captured before the timing loop: the thread-local kernel override
        // (`kernels::with_kernels`) set by the caller is what the benched
        // closure resolves at each call.
        let simd = kernels::active().describe();
        let stats = pool::with_threads(threads, || {
            for _ in 0..warmup {
                f();
            }
            let mut stats = Stats::new();
            for _ in 0..iters {
                let t = Instant::now();
                f();
                stats.push(t.elapsed().as_secs_f64() * 1e3);
            }
            stats
        });
        println!(
            "{name:<52} {:>9.3} ms  ±{:>7.3}  (t={threads}, n={iters}, min {:.3}, max {:.3})",
            stats.mean(),
            stats.std(),
            stats.min,
            stats.max
        );
        self.entries.push(Entry { name: name.to_string(), threads, simd, stats, iters });
    }

    fn write(&self, backend: &str, threads: usize) -> anyhow::Result<()> {
        let rows: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("name", Json::str(e.name.clone())),
                    ("threads", Json::num(e.threads as f64)),
                    ("simd", Json::str(e.simd)),
                    ("mean_ms", Json::num(e.stats.mean())),
                    ("std_ms", Json::num(e.stats.std())),
                    ("min_ms", Json::num(e.stats.min)),
                    ("max_ms", Json::num(e.stats.max)),
                    ("iters", Json::num(e.iters as f64)),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("backend", Json::str(backend)),
            ("threads", Json::num(threads as f64)),
            ("simd", Json::str(kernels::active().describe())),
            ("entries", Json::Arr(rows)),
        ]);
        let path = format!("BENCH_{backend}.json");
        std::fs::write(&path, doc.pretty())?;
        println!("\nwrote {path} ({} entries, default threads={threads})", self.entries.len());
        Ok(())
    }

    /// Diff this run against a committed baseline snapshot. Returns the
    /// number of regressions above `threshold` percent.
    fn compare(&self, path: &str, threshold: f64) -> anyhow::Result<usize> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read baseline {path}: {e}"))?;
        let doc = Json::parse(&text)?;
        let empty: Vec<Json> = Vec::new();
        let base_entries = doc.get("entries").and_then(|e| e.as_arr()).unwrap_or(&empty);
        if base_entries.is_empty() {
            // An empty baseline silently disarms the whole regression
            // gate — make that loud (a CI annotation, not just a log
            // line) instead of no-opping quietly.
            println!(
                "\ncompare: baseline {path} has ZERO entries — the regression gate is a no-op"
            );
            if std::env::var("GITHUB_ACTIONS").is_ok() {
                println!(
                    "::warning title=bench baseline empty::{path} has no entries, so \
                     `--compare --threshold` checked nothing. Regenerate it with `cargo bench \
                     --bench bench_main` (or copy the bench-host CI artifact) and commit it."
                );
            }
            return Ok(0);
        }
        let mut baseline: BTreeMap<(String, usize), f64> = BTreeMap::new();
        let mut by_name: BTreeMap<String, f64> = BTreeMap::new();
        for e in base_entries {
            let (Some(name), Some(mean)) = (
                e.get("name").and_then(|v| v.as_str()),
                e.get("mean_ms").and_then(|v| v.as_f64()),
            ) else {
                continue;
            };
            let threads = e.get("threads").and_then(|v| v.as_usize()).unwrap_or(0);
            baseline.insert((name.to_string(), threads), mean);
            by_name.insert(name.to_string(), mean);
        }
        println!("\n# compare vs {path} (flagging mean regressions > {threshold:.0}%)");
        let gha = std::env::var("GITHUB_ACTIONS").is_ok();
        let mut regressions = 0usize;
        let mut matched = 0usize;
        for e in &self.entries {
            // Exact (name, threads) match first, then name-only: entry
            // names are unique per thread configuration ([t=1] twins carry
            // distinct names), so name-only keeps default-thread entries
            // comparable when the baseline machine's core count differs.
            let old = baseline
                .get(&(e.name.clone(), e.threads))
                .or_else(|| by_name.get(&e.name));
            let Some(&old_mean) = old else { continue };
            matched += 1;
            if old_mean <= 0.0 {
                continue;
            }
            let pct = (e.stats.mean() - old_mean) / old_mean * 100.0;
            let tag = if pct > threshold {
                regressions += 1;
                "REGRESSION"
            } else if pct < -threshold {
                "improved"
            } else {
                "ok"
            };
            println!(
                "  {tag:<10} {:<52} {:>9.3} -> {:>9.3} ms ({pct:+.1}%)",
                e.name,
                old_mean,
                e.stats.mean()
            );
            if tag == "REGRESSION" && gha {
                println!(
                    "::warning title=bench regression::{} (t={}) mean {:.3} ms vs baseline {:.3} ms ({:+.1}%)",
                    e.name,
                    e.threads,
                    e.stats.mean(),
                    old_mean,
                    pct
                );
            }
        }
        println!(
            "compare: {matched} matched entries, {regressions} regression(s) > {threshold:.0}%"
        );
        Ok(regressions)
    }
}

fn main() -> anyhow::Result<()> {
    // `cargo bench` appends `--bench`; treat it as a switch so it cannot
    // swallow the next flag's value.
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &["strict", "bench"])?;

    let tmax = pool::threads();
    println!("qrlora bench harness — all times per call (default threads={tmax})");
    println!("simd kernels: {}\n", kernels::active().describe());
    let mut rec = Recorder::new();

    // ---- P0: host matmul kernels --------------------------------------
    println!("# P0 host matmul (transposed-B blocked kernel, row-parallel)");
    let mut rng = Rng::new(0);
    for n in [64usize, 128, 256] {
        let a = Tensor::randn(&[n, n], &mut rng, 1.0);
        let b = Tensor::randn(&[n, n], &mut rng, 1.0);
        rec.bench(&format!("matmul {n}x{n}x{n}"), tmax, 2, 10, || {
            std::hint::black_box(a.matmul(&b).data[0]);
        });
        rec.bench(&format!("matmul {n}x{n}x{n} [t=1]"), 1, 2, 10, || {
            std::hint::black_box(a.matmul(&b).data[0]);
        });
    }
    {
        let a = Tensor::randn(&[256, 128], &mut rng, 1.0);
        let b = Tensor::randn(&[256, 128], &mut rng, 1.0);
        rec.bench("matmul_t 256x128 @ t(256x128)", tmax, 2, 10, || {
            std::hint::black_box(a.matmul_t(&b).data[0]);
        });
        rec.bench("matmul_t 256x128 @ t(256x128) [t=1]", 1, 2, 10, || {
            std::hint::black_box(a.matmul_t(&b).data[0]);
        });
        let c = Tensor::randn(&[256, 512], &mut rng, 1.0);
        rec.bench("t_matmul t(256x128) @ 256x512", tmax, 2, 10, || {
            std::hint::black_box(a.t_matmul(&c).data[0]);
        });
        rec.bench("t_matmul t(256x128) @ 256x512 [t=1]", 1, 2, 10, || {
            std::hint::black_box(a.t_matmul(&c).data[0]);
        });
        // Zero-skip branch coverage: dense above vs 87.5% zero rows below
        // (the MLM dlogits contraction shape — masked-out rows are all
        // zero). The dense pair bounds the branch's overhead; this entry
        // shows its payoff.
        let mut sparse = Tensor::randn(&[256, 128], &mut rng, 1.0);
        for i in 0..256 {
            if i % 8 != 0 {
                for v in sparse.row_mut(i) {
                    *v = 0.0;
                }
            }
        }
        rec.bench("t_matmul zero-skip 87%-sparse rows [t=1]", 1, 2, 10, || {
            std::hint::black_box(sparse.t_matmul(&c).data[0]);
        });
        // Kernel-backend twins for the single-thread matmul_t row above:
        // forced-scalar (the pre-SIMD reference) and relaxed (wide-FMA
        // dots). default-vs-scalar is the strict SIMD win; relaxed prices
        // the f32-associativity opt-in on top.
        kernels::with_kernels(Kernels::scalar(), || {
            rec.bench("matmul_t 256x128 @ t(256x128) [t=1, scalar]", 1, 2, 10, || {
                std::hint::black_box(a.matmul_t(&b).data[0]);
            });
        });
        kernels::with_kernels(Kernels::detected(true), || {
            rec.bench("matmul_t 256x128 @ t(256x128) [t=1, relaxed]", 1, 2, 10, || {
                std::hint::black_box(a.matmul_t(&b).data[0]);
            });
        });
    }
    // Int8 fused kernels vs the f32 `matmul 256x256x256` pair above: the
    // forward product (`matmul_xw_q` — SIMD backends quantize each
    // activation row once and accumulate i8×i8 products in i32 lanes, one
    // scale multiply per group; the scalar backend dequantizes per dot)
    // and the backward product (`matmul_dyw_t_q`, scaled int8 row axpys).
    // The `[t=1, scalar]` twin is the integer path's f32-dequant baseline.
    {
        let n = 256usize;
        let a = Tensor::randn(&[n, n], &mut rng, 1.0);
        let w = Tensor::randn(&[n, n], &mut rng, 1.0);
        let wq = QuantTensor::quantize(&w.t(), quant::QUANT_GROUP_ROWS);
        rec.bench("qmatmul int8 256x256x256", tmax, 2, 10, || {
            std::hint::black_box(quant::matmul_xw_q(&a, &wq).data[0]);
        });
        rec.bench("qmatmul int8 256x256x256 [t=1]", 1, 2, 10, || {
            std::hint::black_box(quant::matmul_xw_q(&a, &wq).data[0]);
        });
        kernels::with_kernels(Kernels::scalar(), || {
            rec.bench("qmatmul int8 256x256x256 [t=1, scalar]", 1, 2, 10, || {
                std::hint::black_box(quant::matmul_xw_q(&a, &wq).data[0]);
            });
        });
        rec.bench("qmatmul_bwd int8 256x256x256 [t=1]", 1, 2, 10, || {
            std::hint::black_box(quant::matmul_dyw_t_q(&a, &wq).data[0]);
        });
    }

    // ---- P1: pivoted QR scaling --------------------------------------
    println!("\n# P1 pivoted-QR factorization (host)");
    let mut rng = Rng::new(1);
    for n in [64usize, 128, 256] {
        let w = Tensor::randn(&[n, n], &mut rng, 1.0);
        rec.bench(&format!("pivoted_qr {n}x{n}"), tmax, 1, 5, || {
            let f = qrlora::linalg::pivoted_qr(&w);
            std::hint::black_box(f.diag());
        });
    }

    // ---- P2: adapter merge --------------------------------------------
    println!("\n# P2 adapter merge W + Q diag(λ) R (host)");
    for n in [64usize, 128] {
        let w = Tensor::randn(&[n, n], &mut rng, 1.0);
        let f = factorize(&w, 0.5, RankRule::DiagRatio, n / 2);
        let lam = vec![0.1f32; n / 2];
        rec.bench(&format!("merge {n}x{n} r={}", f.used), tmax, 1, 10, || {
            let mut qs = f.q.clone();
            for i in 0..qs.rows() {
                for j in 0..qs.cols() {
                    qs.set(i, j, qs.at(i, j) * lam[j] * f.mask[j]);
                }
            }
            let mut out = w.clone();
            out.add_assign(&qs.matmul(&f.r));
            std::hint::black_box(out.data[0]);
        });
    }

    // ---- backend-side benches ------------------------------------------
    let dir = std::env::var("QRLORA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let rt = create_backend(BackendChoice::from_env()?, std::path::Path::new(&dir))?;
    let rt: &dyn Backend = rt.as_ref();
    // The host backend interprets every preset; PJRT benches default to the
    // artifact set's experiment preset.
    let default_preset = if rt.name() == "host" { "tiny" } else { "small" };
    let preset_name =
        std::env::var("QRLORA_BENCH_PRESET").unwrap_or_else(|_| default_preset.into());
    let preset = rt.manifest().preset(&preset_name)?.clone();
    println!("\nbackend: {} (preset {preset_name})", rt.name());

    // P3: kernel microbench through the backend.
    println!("\n# P3 kernel: base vs fused adapter matmul ({preset_name})");
    for key in ["kernel_base", "kernel_adapter"] {
        let exe = rt.load(&format!("{preset_name}/{key}"))?;
        let kargs: Vec<Buffer> = exe
            .spec
            .inputs
            .iter()
            .map(|t| match t.dtype {
                DType::F32 => rt.upload_f32(&vec![0.01f32; t.numel()], &t.shape).unwrap(),
                DType::I32 => rt.upload_i32(&vec![0; t.numel()], &t.shape).unwrap(),
            })
            .collect();
        let refs: Vec<&Buffer> = kargs.iter().collect();
        rec.bench(&format!("{key} (fwd)"), tmax, 3, 20, || {
            let outs = rt.execute(&exe, &refs).unwrap();
            std::hint::black_box(outs.len());
        });
    }

    // P4: train-step latency per method, default threads and serial.
    println!("\n# P4 train step latency per method ({preset_name})");
    let lex = Lexicon::new(preset.vocab);
    let spec = task("sst2")?;
    let data = TaskData::generate(spec, &lex, 3);
    let batcher = Batcher::new(&preset, false);
    let refs: Vec<&qrlora::data::Example> = data.train[..preset.batch].iter().collect();
    let batch = batcher.assemble(&refs);

    // Synthetic backbone (random — latency doesn't depend on values).
    let mut backbone: BTreeMap<String, Tensor> = BTreeMap::new();
    {
        let mut brng = Rng::new(7);
        let exe = rt.load(&format!("{preset_name}/train_step_ft_cls"))?;
        for f in &exe.spec.layout()?.params {
            if !f.name.starts_with("head/") {
                backbone.insert(f.name.clone(), Tensor::randn(&f.shape, &mut brng, 0.05));
            }
        }
    }
    let methods: Vec<(&str, Method)> = vec![
        ("FT", Method::FullFt),
        ("LoRA", Methods::lora(&backbone, &preset, 2.0, 1)?),
        (
            "QR-LoRA",
            Methods::qr_lora(
                &backbone,
                &preset,
                Scope::all_layers(&[Proj::Q, Proj::K, Proj::V, Proj::O]),
                0.5,
                RankRule::DiagRatio,
            )?,
        ),
    ];
    for (name, method) in &methods {
        let mut session = Session::finetune(
            rt,
            &preset,
            method,
            qrlora::data::HeadKind::Cls,
            &backbone,
            None,
            9,
        )?;
        rec.bench(&format!("train_step {name}"), tmax, 3, 15, || {
            session.step(&batch, 2, 1e-3).unwrap();
        });
        rec.bench(&format!("train_step {name} [t=1]"), 1, 3, 15, || {
            session.step(&batch, 2, 1e-3).unwrap();
        });
        rec.bench(&format!("metrics read {name}"), tmax, 2, 10, || {
            std::hint::black_box(session.last_loss().unwrap());
        });
    }

    // P5: eval forward + adapter swap.
    println!("\n# P5 serving path ({preset_name})");
    let method = &methods.iter().find(|(n, _)| *n == "QR-LoRA").unwrap().1;
    let mut session = Session::finetune(
        rt,
        &preset,
        method,
        qrlora::data::HeadKind::Cls,
        &backbone,
        None,
        10,
    )?;
    rec.bench("eval_fwd QR-LoRA", tmax, 3, 15, || {
        std::hint::black_box(session.forward(&batch, 2).unwrap());
    });
    rec.bench("eval_fwd QR-LoRA [t=1]", 1, 3, 15, || {
        std::hint::black_box(session.forward(&batch, 2).unwrap());
    });
    let state = session.download_state()?;
    rec.bench("adapter hot-swap (upload state)", tmax, 2, 15, || {
        session.upload_state(&state).unwrap();
    });

    // Multi-adapter serving: both entries process `preset.batch` requests
    // cycling through 3 resident adapters, so their means are directly
    // comparable per request. `serve_swap` runs one padded single-request
    // batch per request with a state swap on every task change (the legacy
    // router); `serve_mixed_batch` serves all rows in ONE mixed batch
    // through the resident bank — the acceptance gate for batched serving
    // is serve_mixed_batch ≥2x faster than serve_swap.
    let n_adapters = 3usize;
    let adapter_states: Vec<Vec<f32>> = {
        let layout = session.layout().clone();
        let base_state = session.download_state()?;
        (0..n_adapters)
            .map(|aidx| {
                let mut st = base_state.clone();
                let mut arng = Rng::new(100 + aidx as u64);
                for f in &layout.params {
                    for i in 0..f.numel() {
                        st[f.offset + i] += arng.normal() * 0.01;
                    }
                }
                st
            })
            .collect()
    };
    let serve_classes = 2usize;
    let singles: Vec<qrlora::data::Batch> = (0..preset.batch)
        .map(|i| batcher.assemble(&[&data.train[i]]))
        .collect();
    rec.bench("serve_swap", tmax, 1, 10, || {
        for (i, b) in singles.iter().enumerate() {
            session.upload_state(&adapter_states[i % n_adapters]).unwrap();
            std::hint::black_box(session.forward(b, serve_classes).unwrap());
        }
    });
    // Middle baseline: the pre-bank router's behavior — group same-task
    // requests into one full batch, swap state once per group. Separates
    // the win from batching per se (serve_swap → here) from the win of
    // mixed batches + residency (here → serve_mixed_batch).
    let grouped: Vec<qrlora::data::Batch> = (0..n_adapters)
        .map(|a| {
            let refs: Vec<&qrlora::data::Example> = (0..preset.batch)
                .filter(|i| i % n_adapters == a)
                .map(|i| &data.train[i])
                .collect();
            batcher.assemble(&refs)
        })
        .collect();
    rec.bench("serve_task_grouped", tmax, 1, 10, || {
        for (a, b) in grouped.iter().enumerate() {
            session.upload_state(&adapter_states[a]).unwrap();
            std::hint::black_box(session.forward(b, serve_classes).unwrap());
        }
    });
    let head_k = session.layout().param("head/wc")?.shape[1];
    let cmask = Batcher::class_mask(serve_classes, head_k);
    let state_bufs: Vec<Buffer> = adapter_states
        .iter()
        .map(|s| rt.upload_f32(s, &[s.len()]).unwrap())
        .collect();
    let mask_bufs: Vec<Buffer> = (0..n_adapters)
        .map(|_| rt.upload_f32(&cmask, &[head_k]).unwrap())
        .collect();
    let state_refs: Vec<&Buffer> = state_bufs.iter().collect();
    let mask_refs: Vec<&Buffer> = mask_bufs.iter().collect();
    let row_slots: Vec<usize> = (0..preset.batch).map(|i| i % n_adapters).collect();
    let mixed_refs: Vec<&qrlora::data::Example> = data.train[..preset.batch].iter().collect();
    let mixed = batcher.assemble(&mixed_refs);
    rec.bench("serve_mixed_batch", tmax, 1, 10, || {
        std::hint::black_box(
            session
                .forward_multi(&mixed, &state_refs, &mask_refs, &row_slots)
                .unwrap(),
        );
    });

    // Quantized-backbone twins (host backend regardless of the selected
    // one — quantization is host-only): same shapes as `eval_fwd QR-LoRA`
    // and `serve_mixed_batch`, with the frozen backbone held int8.
    println!("\n# P6 quantized frozen backbone ({preset_name}, int8)");
    let rtq = HostBackend::new_quantized();
    let qsession = Session::finetune(
        &rtq,
        &preset,
        method,
        qrlora::data::HeadKind::Cls,
        &backbone,
        None,
        10,
    )?;
    rec.bench("eval_fwd QR-LoRA [int8]", tmax, 3, 15, || {
        std::hint::black_box(qsession.forward(&batch, 2).unwrap());
    });
    rec.bench("eval_fwd QR-LoRA [int8] [t=1]", 1, 3, 15, || {
        std::hint::black_box(qsession.forward(&batch, 2).unwrap());
    });
    let qstate_bufs: Vec<Buffer> = adapter_states
        .iter()
        .map(|s| rtq.upload_f32(s, &[s.len()]).unwrap())
        .collect();
    let qmask_bufs: Vec<Buffer> = (0..n_adapters)
        .map(|_| rtq.upload_f32(&cmask, &[head_k]).unwrap())
        .collect();
    let qstate_refs: Vec<&Buffer> = qstate_bufs.iter().collect();
    let qmask_refs: Vec<&Buffer> = qmask_bufs.iter().collect();
    rec.bench("serve_mixed_batch [int8]", tmax, 1, 10, || {
        std::hint::black_box(
            qsession
                .forward_multi(&mixed, &qstate_refs, &qmask_refs, &row_slots)
                .unwrap(),
        );
    });
    if let Some(r) = rtq.frozen_residency() {
        println!(
            "\nfrozen backbone weights: {:.1} KiB f32 -> {:.1} KiB int8 resident ({:.2}x reduction)",
            r.backbone_f32_bytes as f64 / 1024.0,
            r.backbone_resident_bytes as f64 / 1024.0,
            r.reduction()
        );
    }

    // ---- P7: adapter store — warm vs cold serving prep ------------------
    // `serve_cold_start` is the tier-3 miss path (train the adapter);
    // `serve_warm_start` is the tier-2 hit path (open the registry, load +
    // checksum/fingerprint-verify the record, rebuild the state vector,
    // upload it). Same preset/method/task — the ratio is the startup win
    // the durable store buys per adapter.
    println!("\n# P7 adapter store ({preset_name}, warm vs cold start)");
    let store_dir = std::env::temp_dir().join("qrlora_bench_store");
    let _ = std::fs::remove_dir_all(&store_dir);
    let cold_steps = 30usize;
    rec.bench(&format!("serve_cold_start ({cold_steps} adapter steps)"), tmax, 1, 3, || {
        let mut s = Session::finetune(
            rt,
            &preset,
            method,
            qrlora::data::HeadKind::Cls,
            &backbone,
            None,
            11,
        )
        .unwrap();
        for _ in 0..cold_steps {
            s.step(&batch, 2, 1e-3).unwrap();
        }
        std::hint::black_box(s.steps_taken());
    });
    {
        let mut s = Session::finetune(
            rt,
            &preset,
            method,
            qrlora::data::HeadKind::Cls,
            &backbone,
            None,
            11,
        )?;
        for _ in 0..cold_steps {
            s.step(&batch, 2, 1e-3)?;
        }
        let backbone_fp = qrlora::store::fingerprint_params(&backbone);
        let manifest_fp = qrlora::store::fingerprint_layout(s.layout());
        let key = AdapterKey::new(&preset_name, "qrlora", "sst2", 11);
        let warm_record =
            AdapterRecord::from_session(&s, key.clone(), backbone_fp, 2, 0.0, 0.0, false)?;
        Registry::open(&store_dir)?.publish(&warm_record)?;
        rec.bench("serve_warm_start (store load)", tmax, 2, 10, || {
            let reg = Registry::open(&store_dir).unwrap();
            let loaded = reg.load(&key).unwrap();
            loaded.check_compat(manifest_fp, backbone_fp, rt.backbone_repr()).unwrap();
            let state = loaded.state_vector(session.layout()).unwrap();
            session.upload_state(&state).unwrap();
            std::hint::black_box(state.len());
        });
    }
    {
        let cold = rec.entries.iter().find(|e| e.name.starts_with("serve_cold_start"));
        let warm = rec.entries.iter().find(|e| e.name.starts_with("serve_warm_start"));
        if let (Some(cold), Some(warm)) = (cold, warm) {
            if warm.stats.mean() > 0.0 {
                println!(
                    "\nwarm-start speedup: {:.0}x ({:.1} ms cold vs {:.2} ms warm per adapter)",
                    cold.stats.mean() / warm.stats.mean(),
                    cold.stats.mean(),
                    warm.stats.mean()
                );
            }
        }
    }

    // ---- P8: serving fleet — aggregate RPS as workers scale -------------
    // Spawns the real binary (`serve --fleet N`) against one shared temp
    // store. The 1-worker run trains and publishes the three task
    // adapters; the 2- and 4-worker runs warm-start from them. Every row
    // records the aggregate serve wall (training is excluded from
    // `serve_wall_ms` by construction), so the rows are comparable:
    // scaling workers should shrink the wall / grow the aggregate RPS
    // until the box runs out of cores. Host backend only — the fleet
    // re-execs this machine's binary.
    if rt.name() == "host" {
        println!("\n# P8 serving fleet (multi-process, shared adapter store)");
        let exe = env!("CARGO_BIN_EXE_qrlora");
        let fleet_store = std::env::temp_dir().join("qrlora_bench_fleet");
        let _ = std::fs::remove_dir_all(&fleet_store);
        let fleet_requests = 24usize;
        for workers in [1usize, 2, 4] {
            let out = std::process::Command::new(exe)
                .args(["serve", "--fleet", &workers.to_string()])
                .args(["--requests", &fleet_requests.to_string()])
                .args(["--pretrain-steps", "60", "--warmup-steps", "40", "--steps", "40"])
                .args(["--adapter-store", &fleet_store.display().to_string()])
                .output()
                .map_err(|e| anyhow::anyhow!("cannot spawn the fleet bench: {e}"))?;
            anyhow::ensure!(
                out.status.success(),
                "serve --fleet {workers} failed:\n{}",
                String::from_utf8_lossy(&out.stderr)
            );
            let stdout = String::from_utf8_lossy(&out.stdout);
            let line = stdout
                .lines()
                .find_map(|l| l.strip_prefix("FLEET_AGGREGATE "))
                .ok_or_else(|| {
                    anyhow::anyhow!("serve --fleet {workers} emitted no FLEET_AGGREGATE line")
                })?;
            let agg = Json::parse(line)?;
            let wall_ms = agg.req("serve_wall_ms")?.as_f64().unwrap_or(0.0);
            let rps = agg.req("rps")?.as_f64().unwrap_or(0.0);
            let name = format!("serve_fleet {workers}w ({fleet_requests} req)");
            println!("{name:<52} {wall_ms:>9.3} ms  ({rps:.1} req/s aggregate)");
            let mut stats = Stats::new();
            stats.push(wall_ms);
            rec.entries.push(Entry {
                name,
                threads: tmax,
                simd: kernels::active().describe(),
                stats,
                iters: 1,
            });
        }

        // Degraded twin: the same 2-worker fleet with an injected crash
        // between a record's temp write and its rename (QRLORA_FAULTS).
        // The aggregate serve wall excludes training/prep by
        // construction, so this row is a throughput-parity check: after
        // a crash → restart → re-publish round trip, serving should
        // still land near the clean `serve_fleet 2w` row above. Fresh
        // store on purpose: a warm store would never publish, so nothing
        // would crash.
        {
            let workers = 2usize;
            let degraded_store = std::env::temp_dir().join("qrlora_bench_fleet_degraded");
            let _ = std::fs::remove_dir_all(&degraded_store);
            let out = std::process::Command::new(exe)
                .args(["serve", "--fleet", &workers.to_string(), "--heartbeat-secs", "1"])
                .args(["--requests", &fleet_requests.to_string()])
                .args(["--pretrain-steps", "60", "--warmup-steps", "40", "--steps", "40"])
                .args(["--adapter-store", &degraded_store.display().to_string()])
                .env("QRLORA_FAULTS", "publish=crash_after_temp")
                .output()
                .map_err(|e| anyhow::anyhow!("cannot spawn the degraded fleet bench: {e}"))?;
            anyhow::ensure!(
                out.status.success(),
                "degraded serve --fleet {workers} failed:\n{}",
                String::from_utf8_lossy(&out.stderr)
            );
            let stdout = String::from_utf8_lossy(&out.stdout);
            let line = stdout
                .lines()
                .find_map(|l| l.strip_prefix("FLEET_AGGREGATE "))
                .ok_or_else(|| {
                    anyhow::anyhow!("degraded fleet bench emitted no FLEET_AGGREGATE line")
                })?;
            let agg = Json::parse(line)?;
            let wall_ms = agg.req("serve_wall_ms")?.as_f64().unwrap_or(0.0);
            let rps = agg.req("rps")?.as_f64().unwrap_or(0.0);
            let name = format!("serve_fleet_degraded {workers}w ({fleet_requests} req)");
            println!("{name:<52} {wall_ms:>9.3} ms  ({rps:.1} req/s aggregate)");
            let mut stats = Stats::new();
            stats.push(wall_ms);
            rec.entries.push(Entry {
                name,
                threads: tmax,
                simd: kernels::active().describe(),
                stats,
                iters: 1,
            });
        }

        // ---- P9: socket serving — soak latency over real TCP -----------
        // Spawns `serve --listen` on an ephemeral loopback port and
        // drives it with the in-process soak generator: real sockets,
        // line-delimited JSON, shed-and-retry flow control. The rows are
        // the client-observed latency percentiles — what the network
        // front-end adds on top of the in-process `serve_fleet` rows.
        // Runs twice: observability registry on (the default) and with
        // QRLORA_OBS=0 in the server for the `[obs-off]` twin rows. The
        // pair holds the obs layer's <2% throughput-overhead contract —
        // advisory here (printed delta, no hard gate): bench numbers on
        // shared CI boxes are too noisy to assert on.
        {
            println!("\n# P9 socket serving (serve --listen + soak load generator)");
            let soak_requests = 48usize;
            let mut rps_by_mode: Vec<f64> = Vec::new();
            for (suffix, obs_on) in [("", true), (" [obs-off]", false)] {
                let soak_store = std::env::temp_dir()
                    .join(format!("qrlora_bench_soak{}", if obs_on { "" } else { "_off" }));
                let _ = std::fs::remove_dir_all(&soak_store);
                let mut cmd = std::process::Command::new(exe);
                cmd.args(["serve", "--listen", "127.0.0.1:0"])
                    .args(["--requests", &soak_requests.to_string()])
                    .args(["--pretrain-steps", "60", "--warmup-steps", "40", "--steps", "40"])
                    .args(["--adapter-store", &soak_store.display().to_string()])
                    .stdout(std::process::Stdio::piped());
                if !obs_on {
                    cmd.env("QRLORA_OBS", "0");
                }
                let mut child = cmd
                    .spawn()
                    .map_err(|e| anyhow::anyhow!("cannot spawn the soak bench server: {e}"))?;
                let stdout = child.stdout.take().expect("piped stdout");
                let mut lines = std::io::BufReader::new(stdout).lines();
                let addr = loop {
                    let Some(line) = lines.next() else {
                        let _ = child.kill();
                        anyhow::bail!("soak bench server exited before NET_LISTEN");
                    };
                    if let Some(rest) = line?.strip_prefix("NET_LISTEN ") {
                        break rest.split_whitespace().next().unwrap_or("").to_string();
                    }
                };
                // Keep draining the child's stdout so a full pipe can
                // never wedge the server mid-soak.
                let drain = std::thread::spawn(move || lines.for_each(|_| ()));
                let soak_cfg = qrlora::experiments::ExpConfig {
                    pretrain_steps: 60,
                    warmup_steps: 40,
                    steps: 40,
                    ..Default::default()
                };
                let report = qrlora::server::net::soak(&soak_cfg, &[addr], soak_requests, 4)?;
                let status = child.wait()?;
                let _ = drain.join();
                anyhow::ensure!(status.success(), "soak bench server failed after the load run");
                let num = |k: &str| -> anyhow::Result<f64> {
                    Ok(report.req(k)?.as_f64().unwrap_or(0.0))
                };
                anyhow::ensure!(
                    num("protocol_errors")? == 0.0,
                    "soak bench hit protocol errors: {}",
                    report.to_string()
                );
                let rps = num("rps")?;
                rps_by_mode.push(rps);
                for (key, label) in [
                    ("p50_ms", "serve_soak p50"),
                    ("p99_ms", "serve_soak p99"),
                    ("p999_ms", "serve_soak p999"),
                ] {
                    let ms = num(key)?;
                    let name = format!("{label} ({soak_requests} req, 4 lanes){suffix}");
                    println!("{name:<52} {ms:>9.3} ms  ({rps:.1} req/s end-to-end)");
                    let mut stats = Stats::new();
                    stats.push(ms);
                    rec.entries.push(Entry {
                        name,
                        threads: tmax,
                        simd: kernels::active().describe(),
                        stats,
                        iters: 1,
                    });
                }
            }
            if let [on, off] = rps_by_mode[..] {
                let overhead = (off - on) / off.max(1e-9) * 100.0;
                println!("serve_soak obs overhead: {overhead:+.2}% rps (contract <2%, advisory)");
            }
        }
    }

    // Footprint summary for the serving claim.
    let qr_state_kib = (session.layout().total * 4) as f64 / 1024.0;
    let ft_params = qrlora::runtime::Preset::approx_backbone_params(&preset);
    println!(
        "\nadapter state {qr_state_kib:.1} KiB vs full-model copy {:.1} MiB ({}x smaller)",
        (ft_params * 4) as f64 / (1024.0 * 1024.0),
        (ft_params * 4) / (session.layout().total * 4).max(1)
    );

    // Baseline diff happens before the write below overwrites the snapshot.
    let mut regressions = 0;
    if let Some(baseline) = args.get("compare") {
        let threshold = args.f64_or("threshold", 20.0)?;
        regressions = rec.compare(baseline, threshold)?;
    }
    rec.write(rt.name(), tmax)?;
    if regressions > 0 && args.has("strict") {
        eprintln!("bench: {regressions} regression(s) above threshold (--strict)");
        std::process::exit(1);
    }
    Ok(())
}
