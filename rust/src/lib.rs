//! QR-LoRA: QR-based low-rank adaptation for efficient fine-tuning.
//!
//! Three-layer architecture:
//! - Layer 3 (this crate): rust coordinator — config, data, linalg (pivoted QR),
//!   adapter state, training/eval loops, experiment harnesses, serving router.
//! - Layer 2: JAX transformer model (build-time python, `python/compile/model.py`),
//!   AOT-lowered to HLO text artifacts.
//! - Layer 1: Pallas kernels for the adapter-fused projections
//!   (`python/compile/kernels/`), lowered into the same HLO.
//!
//! Python never runs on the training/serving path: everything drives
//! through the pluggable [`runtime::Backend`] trait.
//!
//! # Execution backends
//!
//! | backend | availability | manifest | math |
//! |---------|--------------|----------|------|
//! | `host`  | always       | built-in (`runtime::spec`) | pure Rust (`model::host`) |
//! | `pjrt`  | cargo feature `pjrt` + `make artifacts` | `artifacts/manifest.json` | AOT HLO via PJRT |
//!
//! Select with `QRLORA_BACKEND` / `--backend` (`auto` prefers PJRT when
//! available, else host). The host backend makes the full pipeline — and
//! `cargo test -q` — run hermetically from a clean checkout; the PJRT path
//! additionally requires the real `xla` bindings in place of the vendored
//! API stub (`rust/vendor/xla-stub`).

pub mod adapters;
pub mod data;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod experiments;
pub mod kernels;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod store;
pub mod tensor;
pub mod training;
pub mod util;
