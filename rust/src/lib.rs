//! QR-LoRA: QR-based low-rank adaptation for efficient fine-tuning.
//!
//! Three-layer architecture:
//! - Layer 3 (this crate): rust coordinator — config, data, linalg (pivoted QR),
//!   adapter state, training/eval loops, experiment harnesses, serving router.
//! - Layer 2: JAX transformer model (build-time python, `python/compile/model.py`),
//!   AOT-lowered to HLO text artifacts.
//! - Layer 1: Pallas kernels for the adapter-fused projections
//!   (`python/compile/kernels/`), lowered into the same HLO.
//!
//! Python never runs on the training/serving path: the rust binary loads
//! `artifacts/*.hlo.txt` through PJRT (`runtime`) and drives everything.

pub mod adapters;
pub mod data;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod experiments;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod training;
pub mod util;
