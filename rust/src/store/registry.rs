//! The adapter registry: an atomic `index.json` over a directory of
//! adapter record files.
//!
//! The index is pure acceleration — every record file is self-describing
//! (`format::AdapterRecord`), so the registry can always rebuild the
//! index by scanning the directory. That is exactly what [`Registry::open`]
//! does when it finds damage:
//!
//! * leftover `*.tmp<pid>` files (a crashed [`super::atomic_write`]) are
//!   deleted once stale — a rename that never happened publishes
//!   nothing, and fresh temp files are left alone in case they belong to
//!   a live sibling process mid-publish;
//! * index entries whose record file vanished are dropped;
//! * record files the index doesn't know (an index write that crashed
//!   after the record rename, or a hand-copied record) are adopted by
//!   reading their metadata;
//! * an unreadable/corrupt `index.json` triggers a full rebuild from the
//!   record files.
//!
//! All writes — record publish and index update — go through
//! write-temp-then-rename, so a reader never observes a half-written file
//! under a published name.
//!
//! Index *rewrites* additionally serialize on the store's advisory lock
//! ([`super::lock::StoreLock`]) and re-read the on-disk index before
//! merging their change — never rewriting from the opener's possibly
//! stale in-memory snapshot — so N processes publishing into one store
//! all land ([`Registry::publish_merged`]; `remove` and `open()`'s
//! dirty-index recovery follow the same protocol). Every locked rewrite
//! bumps a monotonically increasing `generation` counter in the index
//! that fleet workers poll ([`Registry::read_generation`]) to hot-reload
//! adapters a sibling process published.

use std::path::{Path, PathBuf};

use super::format::{fp_hex, parse_fp, AdapterKey, AdapterRecord};
use super::lock::StoreLock;
use crate::util::json::Json;

/// Default store location (under the same `runs/` tree as the pipeline's
/// backbone/warm-up caches).
pub const DEFAULT_STORE_DIR: &str = "runs/adapters";

/// Record file extension.
pub const RECORD_EXT: &str = "qad";

/// Temp files younger than this are presumed to belong to a live sibling
/// process and are left alone by the [`Registry::open`] sweep.
pub const TMP_SWEEP_AGE_SECS: u64 = 60;

/// One index row: the key plus enough metadata to list/GC/pre-filter
/// without opening the record file.
#[derive(Clone, Debug)]
pub struct RegistryEntry {
    pub key: AdapterKey,
    /// Record file name, relative to the registry directory.
    pub file: String,
    pub manifest_fp: u64,
    pub backbone_fp: u64,
    pub n_classes: usize,
    pub eval_metric: f64,
    pub train_ms: f64,
    pub created_unix: u64,
    pub bytes: u64,
}

impl RegistryEntry {
    fn from_record(rec: &AdapterRecord, file: String, bytes: u64) -> RegistryEntry {
        RegistryEntry {
            key: rec.meta.key.clone(),
            file,
            manifest_fp: rec.meta.manifest_fp,
            backbone_fp: rec.meta.backbone_fp,
            n_classes: rec.meta.n_classes,
            eval_metric: rec.meta.eval_metric,
            train_ms: rec.meta.train_ms,
            created_unix: rec.meta.created_unix,
            bytes,
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("preset", Json::str(self.key.preset.clone())),
            ("method", Json::str(self.key.method.clone())),
            ("task", Json::str(self.key.task.clone())),
            ("seed", Json::str(self.key.seed.to_string())),
            ("file", Json::str(self.file.clone())),
            ("manifest_fp", Json::str(fp_hex(self.manifest_fp))),
            ("backbone_fp", Json::str(fp_hex(self.backbone_fp))),
            ("n_classes", Json::num(self.n_classes as f64)),
            ("eval_metric", Json::num(self.eval_metric)),
            ("train_ms", Json::num(self.train_ms)),
            ("created_unix", Json::num(self.created_unix as f64)),
            ("bytes", Json::num(self.bytes as f64)),
        ])
    }

    fn from_json(j: &Json) -> anyhow::Result<RegistryEntry> {
        let s = |k: &str| -> anyhow::Result<&str> {
            j.req(k)?.as_str().ok_or_else(|| anyhow::anyhow!("index entry: {k} not a string"))
        };
        let seed = s("seed")?
            .parse::<u64>()
            .map_err(|_| anyhow::anyhow!("index entry: bad seed"))?;
        // Strict: a wrong-typed field triggers the index rebuild path in
        // `open()` rather than silently defaulting (created_unix = 0
        // would age-GC a valid record on sight).
        let num = |k: &str| -> anyhow::Result<f64> {
            j.req(k)?.as_f64().ok_or_else(|| anyhow::anyhow!("index entry: bad {k}"))
        };
        let uint = |k: &str| -> anyhow::Result<usize> {
            j.req(k)?.as_usize().ok_or_else(|| anyhow::anyhow!("index entry: bad {k}"))
        };
        Ok(RegistryEntry {
            key: AdapterKey::new(s("preset")?, s("method")?, s("task")?, seed),
            file: s("file")?.to_string(),
            manifest_fp: parse_fp(s("manifest_fp")?)?,
            backbone_fp: parse_fp(s("backbone_fp")?)?,
            n_classes: uint("n_classes")?,
            eval_metric: num("eval_metric")?,
            train_ms: num("train_ms")?,
            created_unix: uint("created_unix")? as u64,
            bytes: uint("bytes")? as u64,
        })
    }
}

/// Verification outcome for one registry entry.
pub struct VerifyResult {
    pub key: AdapterKey,
    pub file: String,
    /// `Ok(())` when the record file decodes, every section checksum
    /// holds, and its metadata matches the index row.
    pub result: anyhow::Result<()>,
}

/// The versioned adapter registry over one directory.
pub struct Registry {
    dir: PathBuf,
    entries: Vec<RegistryEntry>,
    /// On-disk index generation this in-memory view corresponds to.
    /// Bumped by every locked index rewrite; fleet workers poll it via
    /// [`Registry::read_generation`] to notice sibling publishes.
    generation: u64,
}

impl Registry {
    /// Open (creating the directory if needed), recovering from any
    /// crashed-write debris. See the module docs for the recovery rules.
    pub fn open(dir: &Path) -> anyhow::Result<Registry> {
        crate::util::faults::io_fault("store.open")?;
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("cannot create adapter store {dir:?}: {e}"))?;

        // 1. Sweep crashed-write temp files (`*.tmp<pid>`, see
        //    `super::atomic_write`) — but only once they are demonstrably
        //    stale: a fresh temp file may be a *live* sibling process
        //    mid-publish, and deleting it would make that publish vanish.
        //    Fresh debris is harmless meanwhile (nothing ever reads temp
        //    names as records or index).
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            let is_tmp = path
                .extension()
                .and_then(|e| e.to_str())
                .map(|e| e.starts_with("tmp"))
                .unwrap_or(false);
            if !is_tmp || !path.is_file() {
                continue;
            }
            let stale = std::fs::metadata(&path)
                .and_then(|m| m.modified())
                .ok()
                .and_then(|t| t.elapsed().ok())
                .map(|age| age.as_secs() >= TMP_SWEEP_AGE_SECS)
                // Unreadable mtime: assume stale (better a rare lost
                // in-flight publish than debris that never clears).
                .unwrap_or(true);
            if stale {
                crate::warnln!("adapter store: removing stale crashed-write leftover {path:?}");
                let _ = std::fs::remove_file(&path);
            }
        }

        let scanned = scan(dir)?;
        let mut reg = Registry {
            dir: dir.to_path_buf(),
            entries: scanned.entries,
            generation: scanned.generation,
        };
        if scanned.dirty {
            // The recovery rewrite is itself a read-modify-write of the
            // index: take the lock and re-scan under it so recovery never
            // clobbers a sibling's concurrent publish.
            let _lock = StoreLock::acquire(dir)?;
            let fresh = scan(dir)?;
            reg.entries = fresh.entries;
            reg.generation = fresh.generation + 1;
            reg.write_index()?;
        }
        Ok(reg)
    }

    /// The directory this registry lives in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// All entries, publish order.
    pub fn entries(&self) -> &[RegistryEntry] {
        &self.entries
    }

    /// Find the entry for a key.
    pub fn lookup(&self, key: &AdapterKey) -> Option<&RegistryEntry> {
        self.entries.iter().find(|e| &e.key == key)
    }

    /// Absolute path of an entry's record file.
    pub fn record_path(&self, entry: &RegistryEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    /// The on-disk index generation this in-memory view corresponds to.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Read the index generation counter for `dir` without opening a
    /// registry — the cheap poll fleet workers run to notice sibling
    /// publishes. A missing index is generation 0; an unreadable one is
    /// an error (watchers treat that as "changed" and reopen, which runs
    /// recovery).
    pub fn read_generation(dir: &Path) -> anyhow::Result<u64> {
        let path = dir.join("index.json");
        if !path.exists() {
            return Ok(0);
        }
        let doc = Json::parse(&std::fs::read_to_string(&path)?)?;
        Ok(doc.get("generation").and_then(|j| j.as_usize()).unwrap_or(0) as u64)
    }

    /// Publish a record. Alias for [`Registry::publish_merged`] — every
    /// publish path merges under the store lock.
    pub fn publish(&mut self, record: &AdapterRecord) -> anyhow::Result<PathBuf> {
        self.publish_merged(record)
    }

    /// Publish a record: atomic record write, then — under the store
    /// lock — re-read the on-disk index, merge this entry into the
    /// *fresh* entries, and rewrite. Rewriting from the fresh on-disk
    /// view (not this opener's snapshot) is what lets N concurrent
    /// publishers all land instead of last-writer-wins dropping entries.
    /// An existing record for the same key is replaced. Returns the
    /// record's path.
    pub fn publish_merged(&mut self, record: &AdapterRecord) -> anyhow::Result<PathBuf> {
        let file = format!("{}.{RECORD_EXT}", record.meta.key.id());
        let path = self.dir.join(&file);
        // Size from the encoded buffer we write, not a re-stat: a
        // metadata failure used to silently record `bytes = 0`
        // (under-reporting gc's freed_bytes), and a sibling replacing the
        // same key could race the stat anyway.
        let buf = record.encode();
        super::atomic_write(&path, &buf)?;
        let bytes = buf.len() as u64;

        // The record write stays outside the lock on purpose: record
        // files are per-key named and individually atomic, so the index
        // is the only shared mutable state worth serializing.
        let _lock = StoreLock::acquire(&self.dir)?;
        let fresh = scan(&self.dir)?;
        self.entries = fresh.entries;
        self.entries.retain(|e| e.key != record.meta.key);
        self.entries.push(RegistryEntry::from_record(record, file, bytes));
        self.generation = fresh.generation + 1;
        self.write_index()?;
        Ok(path)
    }

    /// Load and checksum-verify the record for a key.
    pub fn load(&self, key: &AdapterKey) -> anyhow::Result<AdapterRecord> {
        let entry = self
            .lookup(key)
            .ok_or_else(|| anyhow::anyhow!("adapter store: no record for {key}"))?;
        let rec = AdapterRecord::load(&self.record_path(entry))?;
        anyhow::ensure!(
            rec.meta.key == entry.key,
            "adapter store: {} holds a record for {}, index says {}",
            entry.file,
            rec.meta.key,
            entry.key
        );
        // Same fingerprint-vs-index-row invariant `verify` enforces: a
        // record swapped on disk after indexing is rejected at load time,
        // not only by an explicit `adapters verify`.
        anyhow::ensure!(
            rec.meta.manifest_fp == entry.manifest_fp && rec.meta.backbone_fp == entry.backbone_fp,
            "adapter store: {} fingerprints drifted from the index row (swapped on disk?)",
            entry.file
        );
        Ok(rec)
    }

    /// Re-read and checksum-verify every record against its index row.
    pub fn verify(&self) -> Vec<VerifyResult> {
        self.entries
            .iter()
            .map(|entry| {
                let result = AdapterRecord::load(&self.record_path(entry)).and_then(|rec| {
                    anyhow::ensure!(
                        rec.meta.key == entry.key,
                        "record key {} != index key {}",
                        rec.meta.key,
                        entry.key
                    );
                    anyhow::ensure!(
                        rec.meta.manifest_fp == entry.manifest_fp
                            && rec.meta.backbone_fp == entry.backbone_fp,
                        "record fingerprints drifted from the index row"
                    );
                    Ok(())
                });
                VerifyResult { key: entry.key.clone(), file: entry.file.clone(), result }
            })
            .collect()
    }

    /// Remove entries (and their record files). Returns the freed bytes
    /// and the keys actually removed. An entry whose file cannot be
    /// deleted is **kept in the index** (and excluded from both) — the
    /// alternative would silently resurrect the record on the next
    /// `open()`, which re-adopts any on-disk record the index forgot.
    ///
    /// Takes the store lock and operates on the fresh on-disk index
    /// (same merge protocol as [`Registry::publish_merged`]), so gc in
    /// one process never clobbers a sibling's concurrent publish.
    pub fn remove(&mut self, keys: &[AdapterKey]) -> anyhow::Result<(u64, Vec<AdapterKey>)> {
        let _lock = StoreLock::acquire(&self.dir)?;
        let fresh = scan(&self.dir)?;
        self.entries = fresh.entries;
        self.generation = fresh.generation;
        let mut freed = 0u64;
        let mut removed = Vec::new();
        for key in keys {
            if let Some(i) = self.entries.iter().position(|e| &e.key == key) {
                let path = self.dir.join(&self.entries[i].file);
                match std::fs::remove_file(&path) {
                    Ok(()) => {}
                    // Already gone = removed as far as the caller cares.
                    Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                    Err(e) => {
                        crate::warnln!(
                            "adapter store: cannot delete {path:?} ({e}); keeping its \
                             index entry"
                        );
                        continue;
                    }
                }
                let entry = self.entries.remove(i);
                freed += entry.bytes;
                removed.push(entry.key);
            }
        }
        if !removed.is_empty() {
            self.generation += 1;
            self.write_index()?;
        }
        Ok((freed, removed))
    }

    fn write_index(&self) -> anyhow::Result<()> {
        let doc = Json::obj(vec![
            ("version", Json::num(super::format::FORMAT_VERSION as f64)),
            // Read tolerantly (`unwrap_or(0)`), written always: older
            // indexes without the counter stay readable, no format bump.
            ("generation", Json::num(self.generation as f64)),
            ("entries", Json::Arr(self.entries.iter().map(|e| e.to_json()).collect())),
        ]);
        super::atomic_write(&self.dir.join("index.json"), doc.pretty().as_bytes())
    }
}

/// What a fresh reconciliation of `dir` found.
struct Scan {
    entries: Vec<RegistryEntry>,
    /// Generation counter read from the on-disk index (0 when absent).
    generation: u64,
    /// True when the on-disk index disagreed with the record files (or
    /// was unreadable) and deserves a recovery rewrite.
    dirty: bool,
}

/// Reconcile the on-disk index with the record files: read the index
/// (rebuilding from records when unreadable), drop rows whose record
/// vanished, adopt orphaned records. Pure read — the caller decides
/// whether (and under which lock) to write the result back. This is the
/// fresh-read half of every locked index rewrite.
fn scan(dir: &Path) -> anyhow::Result<Scan> {
    let index_path = dir.join("index.json");
    let mut entries: Vec<RegistryEntry> = Vec::new();
    let mut generation = 0u64;
    let mut dirty = false;
    if index_path.exists() {
        match read_index(&index_path) {
            Ok((read, gen)) => {
                entries = read;
                generation = gen;
            }
            Err(e) => {
                crate::warnln!(
                    "adapter store: unreadable index {index_path:?} ({e:#}); \
                     rebuilding from record files"
                );
                dirty = true;
            }
        }
    }

    // Drop stale entries (record file gone).
    let before = entries.len();
    entries.retain(|e| {
        let ok = dir.join(&e.file).is_file();
        if !ok {
            crate::warnln!(
                "adapter store: dropping stale index entry {} ({} is missing)",
                e.key,
                e.file
            );
        }
        ok
    });
    dirty |= entries.len() != before;

    // Adopt orphaned record files the index doesn't know.
    for path in record_dir_files(dir, RECORD_EXT)? {
        let file = path.file_name().unwrap_or_default().to_string_lossy().to_string();
        if entries.iter().any(|e| e.file == file) {
            continue;
        }
        match AdapterRecord::load(&path) {
            Ok(rec) => {
                // A key already indexed under another file keeps its
                // indexed record (publish names files by key, so this
                // only happens with hand-copied files); adopting the
                // stray would flip-flop between opens.
                if entries.iter().any(|e| e.key == rec.meta.key) {
                    crate::warnln!(
                        "adapter store: ignoring duplicate-key record {file} ({})",
                        rec.meta.key
                    );
                    continue;
                }
                let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
                crate::debugln!("adapter store: adopting unindexed record {file}");
                entries.push(RegistryEntry::from_record(&rec, file, bytes));
                dirty = true;
            }
            Err(e) => {
                crate::warnln!("adapter store: ignoring unreadable record {file}: {e:#}");
            }
        }
    }
    Ok(Scan { entries, generation, dirty })
}

fn read_index(path: &Path) -> anyhow::Result<(Vec<RegistryEntry>, u64)> {
    // Retry *inside* the read: a transient IO blip here would otherwise
    // look like a corrupt index and trigger a full rebuild — which drops
    // any entry whose record momentarily fails to re-read.
    let text = super::retry::with_retry(Default::default(), "read store index", || {
        crate::util::faults::io_fault("store.read")?;
        Ok(std::fs::read_to_string(path)?)
    })?;
    let doc = Json::parse(&text)?;
    let version = doc.req("version")?.as_usize().unwrap_or(0);
    anyhow::ensure!(
        version as u32 == super::format::FORMAT_VERSION,
        "index version {version}, this build reads v{}",
        super::format::FORMAT_VERSION
    );
    let generation = doc.get("generation").and_then(|j| j.as_usize()).unwrap_or(0) as u64;
    let entries = doc
        .req("entries")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("index entries must be an array"))?
        .iter()
        .map(RegistryEntry::from_json)
        .collect::<anyhow::Result<Vec<_>>>()?;
    Ok((entries, generation))
}

/// Files in `dir` with the given extension (non-recursive, sorted for
/// deterministic adoption order).
fn record_dir_files(dir: &Path, ext: &str) -> anyhow::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_file() && path.extension().map(|e| e == ext).unwrap_or(false) {
            out.push(path);
        }
    }
    out.sort();
    Ok(out)
}
