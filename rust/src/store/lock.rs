//! Advisory file lock serializing index mutations across processes.
//!
//! `Registry::publish` used to be an unserialized read-modify-write of
//! `index.json`: two concurrent publishers each rewrote the full index
//! from their own stale in-memory snapshot, so the last writer silently
//! dropped the other's entry. [`StoreLock`] closes that race with a
//! dependency-free lock *file* (`index.lock`) next to the index:
//!
//! * **Acquisition** is an atomic `OpenOptions::create_new` — exactly one
//!   process can create the file. The holder writes its pid, acquisition
//!   time, and a per-acquisition token into it. Losers retry with a short
//!   exponential backoff until a timeout.
//! * **Stale takeover** mirrors the registry's crashed-write recovery
//!   rules ([`TMP_SWEEP_AGE_SECS`]): a lock file is presumed abandoned
//!   once its mtime age reaches [`LOCK_STALE_AGE_SECS`], or earlier when
//!   `/proc` shows the holder pid is gone. Takeover renames the lock
//!   aside to an `index-steal.tmp<pid>` name (a crashed takeover leaves
//!   only temp-named debris the open() sweep already clears), re-reads
//!   the renamed file to confirm it stole the lock it judged stale — a
//!   live writer may have replaced it in between — and restores it when
//!   the contents changed.
//! * **Release** happens on [`Drop`], and only when the on-disk token is
//!   still ours: after a (mis)takeover, the previous holder must not
//!   delete the new holder's lock.
//!
//! What the lock serializes: every index rewrite — `publish_merged`,
//! `remove` (and gc through it), and `open()`'s dirty-index recovery.
//! Record-file writes stay outside the lock: they are per-key named and
//! individually atomic, so the only shared mutable state is the index.
//!
//! Residual hazard, documented on purpose: between the staleness read
//! and the rename there is a window where a freshly re-acquired live
//! lock gets renamed aside; the content re-check shrinks that window to
//! the rename itself but cannot close it without OS lock primitives this
//! crate deliberately avoids. The stale ages involved (60 s against
//! millisecond-scale critical sections) make the window practically
//! unreachable.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use super::registry::TMP_SWEEP_AGE_SECS;
use crate::util::json::Json;

/// Lock file name, next to `index.json` in the store directory.
pub const LOCK_FILE: &str = "index.lock";

/// A lock file this old is presumed abandoned (holder crashed without
/// dropping it). Mirrors the registry's temp-file sweep age: both answer
/// "how long until crashed-write debris is demonstrably stale".
pub const LOCK_STALE_AGE_SECS: u64 = TMP_SWEEP_AGE_SECS;

/// Default time [`StoreLock::acquire`] waits for a busy lock before
/// giving up. Generous against millisecond-scale critical sections, but
/// finite so a wedged store surfaces as an error rather than a hang.
const ACQUIRE_TIMEOUT: Duration = Duration::from_secs(10);

/// Longest retry backoff while waiting on a busy lock.
const MAX_BACKOFF: Duration = Duration::from_millis(20);

/// Process-local sequence so two acquisitions by the same pid (e.g. two
/// threads, or acquire-release-acquire within one clock second) still
/// carry distinct tokens.
static TOKEN_SEQ: AtomicU64 = AtomicU64::new(0);

/// A held advisory lock on one store directory. Released on drop.
pub struct StoreLock {
    path: PathBuf,
    token: String,
}

impl StoreLock {
    /// Acquire the lock for `dir`, waiting up to the default timeout.
    pub fn acquire(dir: &Path) -> anyhow::Result<StoreLock> {
        Self::acquire_opts(dir, ACQUIRE_TIMEOUT, LOCK_STALE_AGE_SECS)
    }

    /// Acquire with explicit timeout and staleness age (tests use tiny
    /// values to exercise takeover without 60-second sleeps).
    pub fn acquire_opts(
        dir: &Path,
        timeout: Duration,
        stale_age_secs: u64,
    ) -> anyhow::Result<StoreLock> {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("cannot create adapter store {dir:?}: {e}"))?;
        // Injected "lock" faults render as transient (same marker the
        // real acquire timeout carries), so chaos specs exercise the
        // retry/degraded paths a genuinely contended lock would hit.
        crate::util::faults::io_fault("lock")?;
        let path = dir.join(LOCK_FILE);
        let token = format!(
            "{}:{}:{}",
            std::process::id(),
            TOKEN_SEQ.fetch_add(1, Ordering::Relaxed),
            super::unix_now_or_zero()
        );
        let body = Json::obj(vec![
            ("pid", Json::num(std::process::id() as f64)),
            ("acquired_unix", Json::num(super::unix_now_or_zero() as f64)),
            ("token", Json::str(token.clone())),
        ])
        .pretty();

        let start = Instant::now();
        let mut backoff = Duration::from_millis(1);
        loop {
            match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    // We own the file from create_new on; losing the race
                    // between create and write only leaves the body empty
                    // for a moment, which waiters tolerate (see
                    // `takeover_if_stale`: unparseable body falls back to
                    // age-based staleness only).
                    f.write_all(body.as_bytes())
                        .map_err(|e| anyhow::anyhow!("cannot write lock {path:?}: {e}"))?;
                    return Ok(StoreLock { path, token });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    takeover_if_stale(&path, stale_age_secs);
                }
                Err(e) => {
                    return Err(anyhow::anyhow!("cannot create lock {path:?}: {e}"));
                }
            }
            anyhow::ensure!(
                start.elapsed() < timeout,
                "timed out after {timeout:?} waiting for store lock {path:?} \
                 (holder: {})",
                describe_holder(&path)
            );
            std::thread::sleep(backoff);
            backoff = (backoff * 2).min(MAX_BACKOFF);
        }
    }

    /// This acquisition's unique token (what `Drop` matches on-disk).
    pub fn token(&self) -> &str {
        &self.token
    }
}

impl Drop for StoreLock {
    fn drop(&mut self) {
        // Fault injection: a `lock=hold_past_stale` clause simulates a
        // holder dying without release — the file stays and the next
        // acquirer must go through dead-pid/age takeover.
        if crate::util::faults::leaks("lock") {
            crate::warnln!("store lock: injected leak; leaving {:?} held", self.path);
            return;
        }
        match std::fs::read_to_string(&self.path) {
            Ok(text) if lock_token(&text).as_deref() == Some(self.token.as_str()) => {
                if let Err(e) = std::fs::remove_file(&self.path) {
                    crate::warnln!("store lock: cannot release {:?}: {e}", self.path);
                }
            }
            Ok(_) => {
                // Someone judged us stale and took over; the lock on disk
                // is theirs now and deleting it would unlock their
                // critical section.
                crate::warnln!(
                    "store lock: {:?} is no longer ours (stale takeover while held?); \
                     leaving it in place",
                    self.path
                );
            }
            // Already gone: a takeover happened *and* the new holder
            // released. Nothing left to do.
            Err(_) => {}
        }
    }
}

/// Parse the token out of a lock file body. `None` for unparseable
/// content (including the empty-body window between create and write).
fn lock_token(text: &str) -> Option<String> {
    let doc = Json::parse(text).ok()?;
    doc.get("token")?.as_str().map(|s| s.to_string())
}

/// Best-effort holder description for timeout errors.
fn describe_holder(path: &Path) -> String {
    match std::fs::read_to_string(path) {
        Ok(text) => match Json::parse(&text) {
            Ok(doc) => {
                let pid = doc.get("pid").and_then(|j| j.as_usize()).unwrap_or(0);
                let since = doc.get("acquired_unix").and_then(|j| j.as_usize()).unwrap_or(0);
                format!("pid {pid}, acquired at unix {since}")
            }
            Err(_) => "unparseable lock body".to_string(),
        },
        Err(_) => "lock vanished (retry may succeed)".to_string(),
    }
}

/// If the lock at `path` is demonstrably stale — mtime age at least
/// `stale_age_secs`, or the holder pid provably dead per `/proc` — steal
/// it so the caller's next `create_new` attempt can win. Failure modes
/// all degrade to "didn't steal"; the caller just keeps waiting.
fn takeover_if_stale(path: &Path, stale_age_secs: u64) {
    // Snapshot the contents first: the post-rename re-read must prove we
    // stole the same lock we judged stale, not a fresh one.
    let content = match std::fs::read(path) {
        Ok(c) => c,
        // Vanished: the holder released; retry create_new.
        Err(_) => return,
    };
    let aged = std::fs::metadata(path)
        .and_then(|m| m.modified())
        .ok()
        .and_then(|t| t.elapsed().ok())
        .map(|age| age.as_secs() >= stale_age_secs)
        // Unreadable mtime here means the file vanished under us — not
        // stale, just retry. (Opposite polarity to the temp-file sweep:
        // wrongly stealing a live lock loses index entries, wrongly
        // waiting only costs a timeout.)
        .unwrap_or(false);
    if !aged && !holder_dead(&content) {
        return;
    }
    // Rename-steal: move the stale lock to a temp-suffixed name so a
    // crash mid-takeover leaves only debris the open() sweep clears.
    let steal = path.with_file_name(format!("index-steal.tmp{}", std::process::id()));
    if std::fs::rename(path, &steal).is_err() {
        // Raced another waiter's takeover (or a release); retry.
        return;
    }
    match std::fs::read(&steal) {
        Ok(stolen) if stolen == content => {
            crate::warnln!(
                "store lock: took over stale lock {path:?} ({})",
                String::from_utf8_lossy(&content).replace('\n', " ")
            );
            let _ = std::fs::remove_file(&steal);
        }
        _ => {
            // We renamed a *different* lock than the one we judged stale:
            // the holder released and a live writer re-acquired between
            // our read and the rename. Put it back, best effort — if the
            // restore fails the live writer's Drop will warn and its
            // waiters will time out loudly rather than corrupt the index.
            if std::fs::rename(&steal, path).is_err() {
                crate::warnln!(
                    "store lock: could not restore live lock {path:?} after a \
                     misjudged takeover; a waiter may time out"
                );
            }
        }
    }
}

/// True only when `/proc` is available and the holder pid in `content`
/// parses and demonstrably has no process. Unparseable content is *not*
/// dead — age-based staleness is the only judge then.
fn holder_dead(content: &[u8]) -> bool {
    if !Path::new("/proc/self").exists() {
        return false;
    }
    let Ok(text) = std::str::from_utf8(content) else {
        return false;
    };
    let Ok(doc) = Json::parse(text) else {
        return false;
    };
    let Some(pid) = doc.get("pid").and_then(|j| j.as_usize()) else {
        return false;
    };
    if pid == 0 {
        return false;
    }
    !Path::new(&format!("/proc/{pid}")).exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("qrlora_lock_tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn exclusive_while_held_then_reacquirable() {
        let dir = tmp_dir("exclusive");
        let first = StoreLock::acquire(&dir).unwrap();
        // A fresh, live lock: a second acquire must time out.
        let busy = StoreLock::acquire_opts(&dir, Duration::from_millis(50), u64::MAX);
        assert!(busy.is_err(), "second acquire must fail while the lock is held");
        drop(first);
        assert!(!dir.join(LOCK_FILE).exists(), "drop must release the lock file");
        let _second = StoreLock::acquire(&dir).unwrap();
    }

    #[test]
    fn aged_lock_is_taken_over() {
        let dir = tmp_dir("aged");
        // A lock held by a *live* pid (ours), so only the age rule can
        // trigger takeover — which stale_age 0 makes immediate.
        let crashed = StoreLock::acquire(&dir).unwrap();
        std::mem::forget(crashed); // simulate a crash: no Drop, file stays
        let lock = StoreLock::acquire_opts(&dir, Duration::from_secs(5), 0).unwrap();
        drop(lock);
        assert!(!dir.join(LOCK_FILE).exists());
    }

    #[test]
    fn dead_pid_lock_is_taken_over_before_aging() {
        if !Path::new("/proc/self").exists() {
            return; // pid liveness is /proc-gated; nothing to test here
        }
        let dir = tmp_dir("dead_pid");
        // Forge a lock held by a pid that cannot exist (> PID_MAX).
        let body = Json::obj(vec![
            ("pid", Json::num(999_999_999.0)),
            ("acquired_unix", Json::num(0.0)),
            ("token", Json::str("forged")),
        ])
        .pretty();
        std::fs::write(dir.join(LOCK_FILE), body).unwrap();
        // Huge stale age: only the dead-pid rule can let this through.
        let lock = StoreLock::acquire_opts(&dir, Duration::from_secs(5), u64::MAX).unwrap();
        drop(lock);
        assert!(!dir.join(LOCK_FILE).exists());
    }

    #[test]
    fn drop_leaves_a_lock_that_is_no_longer_ours() {
        let dir = tmp_dir("not_ours");
        let lock = StoreLock::acquire(&dir).unwrap();
        // Simulate a takeover while held: replace the body with someone
        // else's token.
        let body = Json::obj(vec![
            ("pid", Json::num(1.0)),
            ("acquired_unix", Json::num(0.0)),
            ("token", Json::str("someone-else")),
        ])
        .pretty();
        std::fs::write(dir.join(LOCK_FILE), &body).unwrap();
        drop(lock);
        let on_disk = std::fs::read_to_string(dir.join(LOCK_FILE)).unwrap();
        assert_eq!(lock_token(&on_disk).as_deref(), Some("someone-else"));
    }

    #[test]
    fn unparseable_lock_body_waits_for_age() {
        let dir = tmp_dir("unparseable");
        std::fs::write(dir.join(LOCK_FILE), b"").unwrap();
        // Empty body + huge stale age: neither rule fires, so acquire
        // must time out rather than steal.
        let busy = StoreLock::acquire_opts(&dir, Duration::from_millis(50), u64::MAX);
        assert!(busy.is_err());
        // The same empty body past the age threshold is fair game.
        let _lock = StoreLock::acquire_opts(&dir, Duration::from_secs(5), 0).unwrap();
    }
}
