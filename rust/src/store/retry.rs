//! Bounded retry with deterministic jittered backoff for store IO.
//!
//! The store's IO seams (index reads, record loads, lock acquisition,
//! publishes) can fail transiently — NFS hiccups, a lock held a beat too
//! long, an injected fault from [`crate::util::faults`]. This module
//! gives every seam the same policy: a handful of attempts, exponential
//! backoff with deterministic jitter (FNV over `(what, attempt, pid)` —
//! no `rand`, reproducible per process), and a per-op deadline so a
//! flapping store cannot stall serving indefinitely.
//!
//! Classification is by message because the vendored `anyhow` carries no
//! downcast: an error is **transient** when its rendered chain contains
//! one of [`TRANSIENT_MARKERS`] (injected faults are stamped
//! "(transient)", real lock contention renders as "timed out …").
//! Everything else — corrupt records, fingerprint mismatches, missing
//! files — is permanent and fails on the first attempt; retrying those
//! would only mask bugs and triple the latency of a real error.

use std::time::{Duration, Instant};

use crate::util::hash::{fnv1a, FNV_OFFSET};

/// Lowercase substrings whose presence in a rendered error chain marks
/// it as transient (worth retrying). Kept deliberately short: when in
/// doubt an error is permanent.
pub const TRANSIENT_MARKERS: &[&str] =
    &["(transient)", "timed out", "interrupted", "temporarily unavailable"];

/// Whether `err`'s rendered chain looks transient (see module docs).
pub fn is_transient(err: &anyhow::Error) -> bool {
    let rendered = format!("{err:#}").to_lowercase();
    TRANSIENT_MARKERS.iter().any(|m| rendered.contains(m))
}

/// Retry policy: bounded attempts, exponential backoff with
/// deterministic jitter, and a hard per-op deadline.
#[derive(Debug, Clone, Copy)]
pub struct Retry {
    /// Total attempts (first try included). 1 = no retries.
    pub attempts: u32,
    /// Backoff before attempt 2; doubles each further attempt.
    pub base_backoff: Duration,
    /// Hard wall-clock budget across all attempts; once exceeded, the
    /// last error is returned even if attempts remain.
    pub deadline: Duration,
}

impl Default for Retry {
    fn default() -> Self {
        Retry {
            attempts: 3,
            base_backoff: Duration::from_millis(10),
            deadline: Duration::from_secs(2),
        }
    }
}

impl Retry {
    /// Backoff before attempt `attempt` (2-based), jittered ×[0.5, 1.5)
    /// by an FNV hash of `(what, attempt, pid)` — deterministic within a
    /// process, decorrelated across a fleet of workers.
    fn backoff(&self, what: &str, attempt: u32) -> Duration {
        let exp = self.base_backoff.saturating_mul(1 << (attempt - 2).min(16));
        let mut h = FNV_OFFSET;
        fnv1a(&mut h, what.as_bytes());
        fnv1a(&mut h, &attempt.to_le_bytes());
        fnv1a(&mut h, &std::process::id().to_le_bytes());
        // h%1000 ∈ [0,1000) → scale ∈ [0.5, 1.5)
        let scale = 0.5 + (h % 1000) as f64 / 1000.0;
        exp.mul_f64(scale)
    }
}

/// Run `f` under `policy`, retrying transient failures. Permanent errors
/// return immediately; exhausting attempts or the deadline returns the
/// last error with a "gave up" context naming `what`. Each retry warns,
/// so a store limping through transient errors is loud in the logs even
/// when every op ultimately succeeds.
pub fn with_retry<T>(
    policy: Retry,
    what: &str,
    mut f: impl FnMut() -> anyhow::Result<T>,
) -> anyhow::Result<T> {
    let start = Instant::now();
    let mut attempt = 1;
    loop {
        match f() {
            Ok(v) => return Ok(v),
            Err(e) if !is_transient(&e) => return Err(e),
            Err(e) => {
                if attempt >= policy.attempts.max(1) || start.elapsed() >= policy.deadline {
                    return Err(e.context(format!(
                        "{what}: gave up after {attempt} attempt(s) in {:?}",
                        start.elapsed()
                    )));
                }
                attempt += 1;
                crate::obs::counter("store.retries").inc();
                let pause = policy.backoff(what, attempt);
                crate::warnln!(
                    "{what}: transient failure ({e:#}); retry {attempt}/{} in {pause:?}",
                    policy.attempts
                );
                std::thread::sleep(pause);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast() -> Retry {
        Retry {
            attempts: 3,
            base_backoff: Duration::from_millis(1),
            deadline: Duration::from_secs(5),
        }
    }

    #[test]
    fn classification_is_marker_based() {
        assert!(is_transient(&anyhow::anyhow!("injected store.read fault (transient)")));
        assert!(is_transient(&anyhow::anyhow!("lock acquire timed out after 10s")));
        assert!(is_transient(
            &anyhow::anyhow!("io").context("resource temporarily unavailable")
        ));
        assert!(!is_transient(&anyhow::anyhow!("checksum mismatch in section 2")));
        assert!(!is_transient(&anyhow::anyhow!("cannot read record: no such file")));
    }

    #[test]
    fn first_success_needs_no_retry() {
        let mut calls = 0;
        let v = with_retry(fast(), "op", || {
            calls += 1;
            Ok::<_, anyhow::Error>(42)
        })
        .unwrap();
        assert_eq!(v, 42);
        assert_eq!(calls, 1);
    }

    #[test]
    fn transient_errors_retry_until_success() {
        let mut calls = 0;
        let v = with_retry(fast(), "op", || {
            calls += 1;
            if calls < 3 {
                anyhow::bail!("flaky (transient)");
            }
            Ok(7)
        })
        .unwrap();
        assert_eq!(v, 7);
        assert_eq!(calls, 3);
    }

    #[test]
    fn permanent_errors_fail_fast() {
        let mut calls = 0;
        let err = with_retry(fast(), "op", || -> anyhow::Result<()> {
            calls += 1;
            anyhow::bail!("corrupt record")
        })
        .unwrap_err();
        assert_eq!(calls, 1, "permanent error must not be retried");
        assert!(format!("{err:#}").contains("corrupt record"));
    }

    #[test]
    fn exhausted_attempts_report_the_give_up() {
        let mut calls = 0;
        let err = with_retry(fast(), "read index", || -> anyhow::Result<()> {
            calls += 1;
            anyhow::bail!("still down (transient)")
        })
        .unwrap_err();
        assert_eq!(calls, 3);
        let msg = format!("{err:#}");
        assert!(msg.contains("read index: gave up after 3 attempt(s)"), "got {msg}");
        assert!(msg.contains("still down"), "original cause preserved: {msg}");
    }

    #[test]
    fn deadline_caps_retries_even_with_attempts_left() {
        let policy = Retry {
            attempts: 1000,
            base_backoff: Duration::from_millis(5),
            deadline: Duration::from_millis(30),
        };
        let start = Instant::now();
        let err = with_retry(policy, "op", || -> anyhow::Result<()> {
            anyhow::bail!("down (transient)")
        })
        .unwrap_err();
        assert!(start.elapsed() < Duration::from_secs(2), "deadline must bound the loop");
        assert!(format!("{err:#}").contains("gave up"));
    }

    #[test]
    fn backoff_is_deterministic_and_grows() {
        let p = fast();
        assert_eq!(p.backoff("x", 2), p.backoff("x", 2));
        // Jitter spans ×[0.5,1.5), so attempt 4 (4× base) always exceeds
        // attempt 2 (1× base): 4×0.5 > 1×1.5.
        assert!(p.backoff("x", 4) > p.backoff("x", 2));
    }
}
