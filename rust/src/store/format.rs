//! The durable adapter record: a versioned, checksummed single-file
//! format for one trained adapter.
//!
//! A record is everything needed to warm-start serving a (preset, method,
//! task, seed) adapter without retraining: the trainable parameter tensors
//! (λ coefficients + task head for QR-LoRA; A/B + head for LoRA),
//! optionally the Adam moments for training resumption, and a metadata
//! section carrying the key, the achieved eval metric, the measured
//! training cost, and two fingerprints that pin the record to what it was
//! trained against:
//!
//! * **manifest fingerprint** — FNV-64 over the state layout (names,
//!   shapes, offsets, totals), so a record can never be unpacked against a
//!   drifted layout;
//! * **backbone fingerprint** — FNV-64 over the frozen backbone
//!   tensors, extended ([`fingerprint_extend`]) with the method-derived
//!   frozen inputs (QR factors/masks, LoRA A/B/scales): hyperparameters
//!   like τ/scope/α change those without touching the backbone or the
//!   layout, and the hash must cover *every* frozen input the adapter
//!   trained against.
//!
//! On-disk layout (all integers little-endian):
//!
//! ```text
//! magic "QRADPT01" | version u32 | section count u32
//! per section: name_len u16 | name | payload_len u64 | crc32 u32 | payload
//! ```
//!
//! Sections: `meta` (JSON), `tensors` (named-tensor block), optional
//! `adam`. Every section carries its own CRC-32, so a flipped byte is a
//! checksum error at load time — never silently-garbage weights.
//!
//! The named-tensor block ([`encode_tensors`]/[`decode_tensors`] — a
//! `u64`-length-prefixed JSON header followed by packed f32 data) is the
//! same codec `model::checkpoint` uses for backbone checkpoints; it fails
//! loudly on truncated or trailing bytes.

use std::collections::BTreeMap;
use std::path::Path;

use crate::runtime::StateLayout;
use crate::tensor::Tensor;
use crate::training::Session;
use crate::util::hash::{fnv1a, FNV_OFFSET};
use crate::util::json::Json;

/// Record file magic.
pub const RECORD_MAGIC: &[u8; 8] = b"QRADPT01";
/// Current record format version (bumped on any layout change).
pub const FORMAT_VERSION: u32 = 1;

// ---------------------------------------------------------------------------
// Checksums and fingerprints.
// ---------------------------------------------------------------------------

/// CRC-32 (IEEE 802.3, the zlib polynomial), bitwise variant — record
/// sections are at most a few hundred KiB, so a lookup table isn't worth
/// its cache footprint here.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// FNV-64 over a named tensor map (names, shapes, and data bytes).
/// Deterministic across runs — used to pin a record to the exact frozen
/// backbone it was trained against.
pub fn fingerprint_params(params: &BTreeMap<String, Tensor>) -> u64 {
    let mut h = FNV_OFFSET;
    for (name, t) in params {
        fnv1a(&mut h, name.as_bytes());
        for &d in &t.shape {
            fnv1a(&mut h, &(d as u64).to_le_bytes());
        }
        for &v in &t.data {
            fnv1a(&mut h, &v.to_le_bytes());
        }
    }
    h
}

/// Extend a fingerprint with named flat vectors — the method-derived
/// frozen inputs (QR factors/masks, LoRA A/B/scales,
/// [`crate::training::Method::frozen_inputs`]) that exist beside the
/// backbone map. Hyperparameters like τ/scope/α change these without
/// touching the backbone *or* the state layout, so a backbone fingerprint
/// alone would accept a record trained against different frozen inputs.
pub fn fingerprint_extend(mut h: u64, inputs: &[(String, Vec<f32>)]) -> u64 {
    for (name, data) in inputs {
        fnv1a(&mut h, name.as_bytes());
        for &v in data {
            fnv1a(&mut h, &v.to_le_bytes());
        }
    }
    h
}

/// FNV-64 over a state layout (field names, shapes, offsets, totals) —
/// the "manifest fingerprint" pinning a record to its artifact contract.
pub fn fingerprint_layout(layout: &StateLayout) -> u64 {
    let mut h = FNV_OFFSET;
    fnv1a(&mut h, &(layout.total as u64).to_le_bytes());
    fnv1a(&mut h, &(layout.n_params as u64).to_le_bytes());
    for f in &layout.params {
        fnv1a(&mut h, f.name.as_bytes());
        for &d in &f.shape {
            fnv1a(&mut h, &(d as u64).to_le_bytes());
        }
        fnv1a(&mut h, &(f.offset as u64).to_le_bytes());
    }
    h
}

/// `{:016x}` render of a fingerprint (JSON can't hold u64 exactly).
pub fn fp_hex(fp: u64) -> String {
    format!("{fp:016x}")
}

/// Parse a [`fp_hex`] string back to a fingerprint.
pub fn parse_fp(s: &str) -> anyhow::Result<u64> {
    u64::from_str_radix(s, 16).map_err(|_| anyhow::anyhow!("bad fingerprint hex {s:?}"))
}

// ---------------------------------------------------------------------------
// The shared named-tensor codec (also used by model::checkpoint).
// ---------------------------------------------------------------------------

/// Encode a named tensor map: `u64` header length, JSON header
/// (`[{name, shape, offset}…]` in map order), packed little-endian f32
/// payload tiling the offsets exactly.
pub fn encode_tensors(params: &BTreeMap<String, Tensor>) -> Vec<u8> {
    let mut offset = 0usize;
    let entries: Vec<Json> = params
        .iter()
        .map(|(n, t)| {
            let e = Json::obj(vec![
                ("name", Json::str(n.clone())),
                ("shape", Json::arr_usize(t.shape.iter())),
                ("offset", Json::num(offset as f64)),
            ]);
            offset += t.numel();
            e
        })
        .collect();
    let hjson = Json::Arr(entries).to_string();
    let mut out = Vec::with_capacity(8 + hjson.len() + offset * 4);
    out.extend_from_slice(&(hjson.len() as u64).to_le_bytes());
    out.extend_from_slice(hjson.as_bytes());
    for t in params.values() {
        for v in &t.data {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out
}

/// Decode a named tensor block. Strict: a malformed header, an
/// out-of-bounds tensor, a duplicate or empty name, or a payload whose
/// length disagrees with the header (truncation or trailing garbage) is an
/// error naming `what` — never a panic, never silently-misread weights.
pub fn decode_tensors(what: &str, bytes: &[u8]) -> anyhow::Result<BTreeMap<String, Tensor>> {
    anyhow::ensure!(bytes.len() >= 8, "{what}: truncated (no tensor-block header)");
    let hlen = u64::from_le_bytes(bytes[0..8].try_into().unwrap()) as usize;
    anyhow::ensure!(
        hlen <= bytes.len() - 8,
        "{what}: truncated tensor-block header ({hlen}-byte header, {} bytes left)",
        bytes.len() - 8
    );
    let htext = std::str::from_utf8(&bytes[8..8 + hlen])
        .map_err(|_| anyhow::anyhow!("{what}: tensor-block header is not UTF-8"))?;
    let header =
        Json::parse(htext).map_err(|e| anyhow::anyhow!("{what}: bad tensor header: {e}"))?;
    let entries = header
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("{what}: tensor header must be a JSON array"))?;
    let payload = &bytes[8 + hlen..];

    let mut out = BTreeMap::new();
    let mut described = 0usize;
    for entry in entries {
        let name = entry
            .req("name")?
            .as_str()
            .filter(|n| !n.is_empty())
            .ok_or_else(|| anyhow::anyhow!("{what}: tensor entry with empty name"))?
            .to_string();
        let shape: Vec<usize> = entry
            .req("shape")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("{what}: {name}: shape must be an array"))?
            .iter()
            .map(|d| {
                d.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("{what}: {name}: bad shape dim {d:?}"))
            })
            .collect::<anyhow::Result<_>>()?;
        let offset = entry
            .req("offset")?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("{what}: {name}: bad offset"))?;
        let numel = shape
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .and_then(|n| n.checked_mul(4))
            .ok_or_else(|| anyhow::anyhow!("{what}: {name}: shape overflow"))?
            / 4;
        let start = offset
            .checked_mul(4)
            .ok_or_else(|| anyhow::anyhow!("{what}: {name}: offset overflow"))?;
        let end = start
            .checked_add(numel * 4)
            .ok_or_else(|| anyhow::anyhow!("{what}: {name}: extent overflow"))?;
        anyhow::ensure!(
            end <= payload.len(),
            "{what}: truncated tensor {name} (needs bytes {start}..{end}, payload has {})",
            payload.len()
        );
        let data: Vec<f32> = payload[start..end]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        anyhow::ensure!(
            out.insert(name.clone(), Tensor::from_vec(&shape, data)).is_none(),
            "{what}: duplicate tensor {name}"
        );
        described += numel * 4;
    }
    anyhow::ensure!(
        described == payload.len(),
        "{what}: payload is {} bytes but the header describes {described} \
         (truncated file or trailing garbage)",
        payload.len()
    );
    Ok(out)
}

// ---------------------------------------------------------------------------
// Record metadata.
// ---------------------------------------------------------------------------

/// The registry key of one adapter: which preset/method/task/seed it was
/// trained for.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct AdapterKey {
    pub preset: String,
    pub method: String,
    pub task: String,
    pub seed: u64,
}

impl AdapterKey {
    pub fn new(preset: &str, method: &str, task: &str, seed: u64) -> AdapterKey {
        AdapterKey {
            preset: preset.to_string(),
            method: method.to_string(),
            task: task.to_string(),
            seed,
        }
    }

    /// Filesystem-safe identifier, also the record's file stem. The FNV
    /// suffix over the raw (unsanitized) fields keeps distinct keys
    /// distinct even when sanitization collides (`qr-lora` vs `qr/lora`
    /// both clean to `qr-lora`) — without it, publishing one key could
    /// overwrite the other's record file.
    pub fn id(&self) -> String {
        let clean = |s: &str| -> String {
            s.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '-' }).collect()
        };
        let mut h = FNV_OFFSET;
        for part in [&self.preset, &self.method, &self.task] {
            fnv1a(&mut h, part.as_bytes());
            fnv1a(&mut h, &[0]);
        }
        format!(
            "{}_{}_{}_s{}-{:06x}",
            clean(&self.preset),
            clean(&self.method),
            clean(&self.task),
            self.seed,
            h & 0xFF_FFFF
        )
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("preset", Json::str(self.preset.clone())),
            ("method", Json::str(self.method.clone())),
            ("task", Json::str(self.task.clone())),
            // Decimal string: JSON numbers are f64 and can't hold u64.
            ("seed", Json::str(self.seed.to_string())),
        ])
    }

    fn from_json(j: &Json) -> anyhow::Result<AdapterKey> {
        let s = |k: &str| -> anyhow::Result<String> {
            Ok(j.req(k)?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("adapter key: {k} must be a string"))?
                .to_string())
        };
        let seed_s = s("seed")?;
        let seed = seed_s
            .parse::<u64>()
            .map_err(|_| anyhow::anyhow!("adapter key: bad seed {seed_s:?}"))?;
        Ok(AdapterKey { preset: s("preset")?, method: s("method")?, task: s("task")?, seed })
    }
}

impl std::fmt::Display for AdapterKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{} on {} (seed {})", self.preset, self.method, self.task, self.seed)
    }
}

/// Record metadata (the `meta` section).
#[derive(Clone, Debug)]
pub struct RecordMeta {
    pub key: AdapterKey,
    /// [`fingerprint_layout`] of the state layout the tensors belong to.
    pub manifest_fp: u64,
    /// [`fingerprint_params`] of the frozen backbone trained against.
    pub backbone_fp: u64,
    /// How the training backend represented the frozen backbone
    /// ([`crate::runtime::Backend::backbone_repr`]: `"f32"` or `"int8"`).
    /// The same f32 backbone behaves differently once quantized, so a
    /// record must only warm-start a backend using the representation it
    /// trained against — otherwise served logits would not be
    /// bit-identical to the train-on-miss path.
    pub backbone_repr: String,
    /// Classes the task head was trained with (class-mask width).
    pub n_classes: usize,
    /// Achieved dev metric at save time (task headline convention).
    pub eval_metric: f64,
    /// Optimizer steps taken.
    pub steps: usize,
    /// Measured wall-clock training cost, milliseconds — what a warm
    /// start saves (the demo reports load-vs-train speedup from this).
    pub train_ms: f64,
    /// Unix seconds at save time (age-based GC).
    pub created_unix: u64,
}

impl RecordMeta {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::num(FORMAT_VERSION as f64)),
            ("key", self.key.to_json()),
            ("manifest_fp", Json::str(fp_hex(self.manifest_fp))),
            ("backbone_fp", Json::str(fp_hex(self.backbone_fp))),
            ("backbone_repr", Json::str(self.backbone_repr.clone())),
            ("n_classes", Json::num(self.n_classes as f64)),
            ("eval_metric", Json::num(self.eval_metric)),
            ("steps", Json::num(self.steps as f64)),
            ("train_ms", Json::num(self.train_ms)),
            ("created_unix", Json::num(self.created_unix as f64)),
        ])
    }

    fn from_json(j: &Json) -> anyhow::Result<RecordMeta> {
        // Strict like the rest of the record decoder: a wrong-typed field
        // is an error, never a silent default (a defaulted created_unix
        // of 0 would make age-based GC treat the record as ancient).
        let fp = |k: &str| -> anyhow::Result<u64> {
            parse_fp(j.req(k)?.as_str().unwrap_or_default())
        };
        let num = |k: &str| -> anyhow::Result<f64> {
            j.req(k)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("record meta: bad {k}"))
        };
        let uint = |k: &str| -> anyhow::Result<usize> {
            j.req(k)?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("record meta: bad {k}"))
        };
        Ok(RecordMeta {
            key: AdapterKey::from_json(j.req("key")?)?,
            manifest_fp: fp("manifest_fp")?,
            backbone_fp: fp("backbone_fp")?,
            backbone_repr: j
                .req("backbone_repr")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("record meta: bad backbone_repr"))?
                .to_string(),
            n_classes: uint("n_classes")?,
            eval_metric: num("eval_metric")?,
            steps: uint("steps")?,
            train_ms: num("train_ms")?,
            created_unix: uint("created_unix")? as u64,
        })
    }
}

/// Adam optimizer state riding along in a record (optional section) —
/// lets a later session resume fine-tuning instead of only serving.
#[derive(Clone, Debug)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: usize,
}

// ---------------------------------------------------------------------------
// The record itself.
// ---------------------------------------------------------------------------

/// One durable adapter: metadata + trainable tensors (+ optional Adam
/// state). See the module docs for the file layout.
pub struct AdapterRecord {
    pub meta: RecordMeta,
    /// The trainable parameter tensors, named per the state layout
    /// (λ + head for QR-LoRA, A/B + head for LoRA, everything for FT).
    pub params: BTreeMap<String, Tensor>,
    pub adam: Option<AdamState>,
}

impl AdapterRecord {
    /// Capture a record from a live session. The manifest fingerprint is
    /// computed from the session's own layout; `backbone_fp` must be the
    /// [`fingerprint_params`] of the frozen backbone the session was built
    /// against.
    #[allow(clippy::too_many_arguments)]
    pub fn from_session(
        session: &Session,
        key: AdapterKey,
        backbone_fp: u64,
        n_classes: usize,
        eval_metric: f64,
        train_ms: f64,
        with_adam: bool,
    ) -> anyhow::Result<AdapterRecord> {
        let params = session.download_params()?;
        let adam = if with_adam {
            let (m, v) = session.download_moments()?;
            Some(AdamState { m, v, t: session.steps_taken() })
        } else {
            None
        };
        Ok(AdapterRecord {
            meta: RecordMeta {
                key,
                manifest_fp: fingerprint_layout(session.layout()),
                backbone_fp,
                backbone_repr: session.backend().backbone_repr().to_string(),
                n_classes,
                eval_metric,
                steps: session.steps_taken(),
                train_ms,
                // A pre-epoch clock warns (in `unix_now_or_zero`) and
                // stamps 0; gc exempts 0 from age pruning so the record
                // is kept, not treated as ancient.
                created_unix: super::unix_now_or_zero(),
            },
            params,
            adam,
        })
    }

    /// Check the record against the live layout/backbone fingerprints and
    /// the live backend's backbone representation; a mismatch means the
    /// record was trained against something else and must not be served.
    pub fn check_compat(
        &self,
        manifest_fp: u64,
        backbone_fp: u64,
        backbone_repr: &str,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.meta.backbone_repr == backbone_repr,
            "adapter record {}: trained against a {} backbone, the live backend holds {} \
             (--quantize-backbone mismatch)",
            self.meta.key.id(),
            self.meta.backbone_repr,
            backbone_repr
        );
        anyhow::ensure!(
            self.meta.manifest_fp == manifest_fp,
            "adapter record {}: layout fingerprint {} != live manifest {} \
             (preset or method drift)",
            self.meta.key.id(),
            fp_hex(self.meta.manifest_fp),
            fp_hex(manifest_fp)
        );
        anyhow::ensure!(
            self.meta.backbone_fp == backbone_fp,
            "adapter record {}: backbone fingerprint {} != live backbone {} \
             (trained against a different frozen backbone)",
            self.meta.key.id(),
            fp_hex(self.meta.backbone_fp),
            fp_hex(backbone_fp)
        );
        Ok(())
    }

    /// Rebuild a flat state vector for `layout` from the record: params
    /// copied bit-exactly into place, Adam moments restored when present,
    /// metrics head zeroed. The forward path reads only the params region,
    /// so serving logits from this state are bit-identical to the session
    /// the record was captured from.
    pub fn state_vector(&self, layout: &StateLayout) -> anyhow::Result<Vec<f32>> {
        let id = self.meta.key.id();
        let mut state = vec![0f32; layout.total];
        for f in &layout.params {
            let t = self
                .params
                .get(&f.name)
                .ok_or_else(|| anyhow::anyhow!("record {id}: missing param {:?}", f.name))?;
            anyhow::ensure!(
                t.shape == f.shape,
                "record {id}: param {:?} has shape {:?}, layout wants {:?}",
                f.name,
                t.shape,
                f.shape
            );
            state[f.offset..f.offset + f.numel()].copy_from_slice(&t.data);
        }
        for name in self.params.keys() {
            anyhow::ensure!(
                layout.param(name).is_ok(),
                "record {id}: tensor {name:?} is not in the live layout"
            );
        }
        if let Some(adam) = &self.adam {
            let n = layout.n_params;
            anyhow::ensure!(
                adam.m.len() == n && adam.v.len() == n,
                "record {id}: adam moments have {}/{} elements, layout wants {n}",
                adam.m.len(),
                adam.v.len()
            );
            let base = layout.total - 3 * n;
            state[base + n..base + 2 * n].copy_from_slice(&adam.m);
            state[base + 2 * n..base + 3 * n].copy_from_slice(&adam.v);
        }
        Ok(state)
    }

    /// Serialize to the sectioned record format.
    pub fn encode(&self) -> Vec<u8> {
        let mut sections: Vec<(&str, Vec<u8>)> = vec![
            ("meta", self.meta.to_json().to_string().into_bytes()),
            ("tensors", encode_tensors(&self.params)),
        ];
        if let Some(adam) = &self.adam {
            let mut map = BTreeMap::new();
            map.insert("adam/m".to_string(), Tensor::from_vec(&[adam.m.len()], adam.m.clone()));
            map.insert("adam/v".to_string(), Tensor::from_vec(&[adam.v.len()], adam.v.clone()));
            map.insert("adam/t".to_string(), Tensor::from_vec(&[1], vec![adam.t as f32]));
            sections.push(("adam", encode_tensors(&map)));
        }
        let mut out = Vec::new();
        out.extend_from_slice(RECORD_MAGIC);
        out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        out.extend_from_slice(&(sections.len() as u32).to_le_bytes());
        for (name, payload) in &sections {
            out.extend_from_slice(&(name.len() as u16).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
            out.extend_from_slice(&crc32(payload).to_le_bytes());
            out.extend_from_slice(payload);
        }
        out
    }

    /// Parse and checksum-verify a record. `what` names the source (a
    /// path) in errors.
    pub fn decode(what: &str, bytes: &[u8]) -> anyhow::Result<AdapterRecord> {
        let mut pos = 0usize;
        let magic = take(what, bytes, &mut pos, 8)?;
        anyhow::ensure!(magic == RECORD_MAGIC, "{what}: not an adapter record (bad magic)");
        let version = u32::from_le_bytes(take(what, bytes, &mut pos, 4)?.try_into().unwrap());
        anyhow::ensure!(
            version == FORMAT_VERSION,
            "{what}: record format v{version}, this build reads v{FORMAT_VERSION}"
        );
        let n_sections =
            u32::from_le_bytes(take(what, bytes, &mut pos, 4)?.try_into().unwrap()) as usize;
        anyhow::ensure!(n_sections <= 16, "{what}: implausible section count {n_sections}");

        let mut sections: BTreeMap<String, &[u8]> = BTreeMap::new();
        for _ in 0..n_sections {
            let nlen =
                u16::from_le_bytes(take(what, bytes, &mut pos, 2)?.try_into().unwrap()) as usize;
            let name = std::str::from_utf8(take(what, bytes, &mut pos, nlen)?)
                .map_err(|_| anyhow::anyhow!("{what}: non-UTF-8 section name"))?
                .to_string();
            let plen =
                u64::from_le_bytes(take(what, bytes, &mut pos, 8)?.try_into().unwrap()) as usize;
            let want_crc = u32::from_le_bytes(take(what, bytes, &mut pos, 4)?.try_into().unwrap());
            let payload = take(what, bytes, &mut pos, plen)?;
            anyhow::ensure!(
                crc32(payload) == want_crc,
                "{what}: checksum mismatch in section {name:?} (corrupt record)"
            );
            sections.insert(name, payload);
        }
        anyhow::ensure!(pos == bytes.len(), "{what}: trailing bytes after last section");

        let meta_bytes = sections
            .get("meta")
            .ok_or_else(|| anyhow::anyhow!("{what}: record has no meta section"))?;
        let meta_text = std::str::from_utf8(meta_bytes)
            .map_err(|_| anyhow::anyhow!("{what}: meta section is not UTF-8"))?;
        let meta = RecordMeta::from_json(&Json::parse(meta_text)?)?;
        let tensors = sections
            .get("tensors")
            .ok_or_else(|| anyhow::anyhow!("{what}: record has no tensors section"))?;
        let params = decode_tensors(what, tensors)?;
        let adam = match sections.get("adam") {
            None => None,
            Some(bytes) => {
                let map = decode_tensors(what, bytes)?;
                let get = |k: &str| -> anyhow::Result<Vec<f32>> {
                    Ok(map
                        .get(k)
                        .ok_or_else(|| anyhow::anyhow!("{what}: adam section missing {k}"))?
                        .data
                        .clone())
                };
                Some(AdamState {
                    m: get("adam/m")?,
                    v: get("adam/v")?,
                    t: get("adam/t")?.first().copied().unwrap_or(0.0) as usize,
                })
            }
        };
        Ok(AdapterRecord { meta, params, adam })
    }

    /// Write atomically (temp file + rename) so a crash mid-write can
    /// never leave a half-record under the published name.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        super::atomic_write_site(path, &self.encode(), "publish")
    }

    /// Read + verify a record file. The read itself retries transient IO
    /// errors ([`super::retry`]) so a store blip degrades to a warning
    /// instead of a dropped/retrained adapter; decode failures (corrupt
    /// record) are permanent and surface immediately.
    pub fn load(path: &Path) -> anyhow::Result<AdapterRecord> {
        let bytes = super::retry::with_retry(Default::default(), "read adapter record", || {
            crate::util::faults::io_fault("store.read")?;
            std::fs::read(path)
                .map_err(|e| anyhow::anyhow!("cannot read adapter record {path:?}: {e}"))
        })?;
        AdapterRecord::decode(&path.display().to_string(), &bytes)
    }
}

/// Bounds-checked cursor advance over a record byte buffer.
fn take<'a>(what: &str, bytes: &'a [u8], pos: &mut usize, n: usize) -> anyhow::Result<&'a [u8]> {
    let end = pos.checked_add(n).filter(|&e| e <= bytes.len()).ok_or_else(|| {
        anyhow::anyhow!(
            "{what}: truncated record (wanted {n} bytes at {}, file has {})",
            *pos,
            bytes.len()
        )
    })?;
    let s = &bytes[*pos..end];
    *pos = end;
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample_params() -> BTreeMap<String, Tensor> {
        let mut rng = Rng::new(5);
        let mut p = BTreeMap::new();
        p.insert("qr/layer0/wq/lam".to_string(), Tensor::randn(&[6], &mut rng, 0.3));
        p.insert("head/wc".to_string(), Tensor::randn(&[4, 3], &mut rng, 0.1));
        p.insert("head/bc".to_string(), Tensor::zeros(&[3]));
        p
    }

    fn sample_record(adam: bool) -> AdapterRecord {
        let params = sample_params();
        AdapterRecord {
            meta: RecordMeta {
                key: AdapterKey::new("tiny", "qrlora", "sst2", 17),
                manifest_fp: 0xDEAD_BEEF_0123_4567,
                backbone_fp: 0x0123_4567_89AB_CDEF,
                backbone_repr: "f32".to_string(),
                n_classes: 2,
                eval_metric: 0.875,
                steps: 150,
                train_ms: 1234.5,
                created_unix: 1_750_000_000,
            },
            params,
            adam: adam.then(|| AdamState { m: vec![0.1; 6], v: vec![0.2; 6], t: 150 }),
        }
    }

    #[test]
    fn crc32_known_vector() {
        // The canonical IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn tensor_codec_roundtrip() {
        let params = sample_params();
        let bytes = encode_tensors(&params);
        let back = decode_tensors("test", &bytes).unwrap();
        assert_eq!(back, params);
    }

    #[test]
    fn tensor_codec_rejects_truncation_and_trailing() {
        let bytes = encode_tensors(&sample_params());
        // Truncated payload: every prefix must fail loudly, never panic.
        for cut in [0usize, 4, 8, bytes.len() / 2, bytes.len() - 1] {
            let err = decode_tensors("t", &bytes[..cut]).unwrap_err().to_string();
            assert!(
                err.contains("truncated") || err.contains("header") || err.contains("payload"),
                "cut={cut}: {err}"
            );
        }
        // Trailing garbage is not silently ignored.
        let mut long = bytes.clone();
        long.extend_from_slice(&[0u8; 7]);
        let err = decode_tensors("t", &long).unwrap_err().to_string();
        assert!(err.contains("trailing") || err.contains("describes"), "{err}");
    }

    #[test]
    fn tensor_codec_rejects_huge_header_length() {
        // A corrupt 8-byte length prefix must not drive a giant allocation
        // or a panic.
        let mut bytes = vec![0u8; 16];
        bytes[..8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_tensors("t", &bytes).is_err());
    }

    #[test]
    fn record_roundtrip_with_and_without_adam() {
        for adam in [false, true] {
            let rec = sample_record(adam);
            let bytes = rec.encode();
            let back = AdapterRecord::decode("test", &bytes).unwrap();
            assert_eq!(back.meta.key, rec.meta.key);
            assert_eq!(back.meta.manifest_fp, rec.meta.manifest_fp);
            assert_eq!(back.meta.backbone_fp, rec.meta.backbone_fp);
            assert_eq!(back.meta.n_classes, 2);
            assert_eq!(back.meta.steps, 150);
            assert_eq!(back.params, rec.params);
            assert_eq!(back.adam.is_some(), adam);
            if let (Some(a), Some(b)) = (&back.adam, &rec.adam) {
                assert_eq!(a.m, b.m);
                assert_eq!(a.v, b.v);
                assert_eq!(a.t, b.t);
            }
        }
    }

    #[test]
    fn record_flipped_byte_is_a_checksum_error() {
        let bytes = sample_record(true).encode();
        // Flip one byte in every section's payload region; each must be
        // caught by that section's CRC (or the structural checks), never
        // decoded into silently-wrong values.
        for pos in (20..bytes.len()).step_by(13) {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x40;
            match AdapterRecord::decode("test", &bad) {
                Err(_) => {}
                Ok(rec) => {
                    // The flip landed in a length/name field in a way that
                    // still parsed? Then the data must still be intact.
                    let orig = sample_record(true);
                    assert_eq!(rec.params, orig.params, "undetected corruption at {pos}");
                }
            }
        }
    }

    #[test]
    fn record_rejects_wrong_magic_and_version() {
        let mut bytes = sample_record(false).encode();
        let err = AdapterRecord::decode("t", b"NOTMAGIC").unwrap_err().to_string();
        assert!(err.contains("truncated") || err.contains("magic"), "{err}");
        bytes[8] = 99; // version byte
        let err = AdapterRecord::decode("t", &bytes).unwrap_err().to_string();
        assert!(err.contains("format"), "{err}");
    }

    #[test]
    fn fingerprints_are_stable_and_sensitive() {
        let params = sample_params();
        let a = fingerprint_params(&params);
        assert_eq!(a, fingerprint_params(&params.clone()));
        let mut changed = params.clone();
        changed.get_mut("head/bc").unwrap().data[0] = 1.0;
        assert_ne!(a, fingerprint_params(&changed));
        assert_eq!(parse_fp(&fp_hex(a)).unwrap(), a);
    }

    #[test]
    fn key_id_is_filesystem_safe_and_injective() {
        let key = AdapterKey::new("tiny", "qr/lora", "sst 2", 3);
        let id = key.id();
        assert!(id.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_'), "{id}");
        // Sanitization maps both methods to "qr-lora"; the ids must still
        // differ so one key's record can never clobber the other's file.
        let a = AdapterKey::new("tiny", "qr-lora", "sst2", 3).id();
        let b = AdapterKey::new("tiny", "qr/lora", "sst2", 3).id();
        assert_ne!(a, b);
    }
}
