//! Tiered adapter resolution: RAM → disk (registry) → train-on-miss.
//!
//! [`TieredAdapters`] extends the serving stack's RAM tier (the router's
//! library + the backend-resident `AdapterBank`) downward with the durable
//! registry. Resolution order for a task:
//!
//! 1. **RAM** — already resolved this process: free.
//! 2. **Disk** — registry hit. The record's checksums are verified at
//!    read time and its manifest/backbone fingerprints are checked against
//!    the *live* session before the state is trusted; any failure is a
//!    logged rejection that falls through to tier 3 (a corrupt record can
//!    degrade startup cost, never correctness).
//! 3. **Train-on-miss** — the caller-supplied trainer runs, and the fresh
//!    record is published back to the registry so the next process warm
//!    starts.
//!
//! Disk loads are dispatched onto the worker pool:
//! [`TieredAdapters::prefetch`] reads and decodes all registry hits in
//! parallel, one pool task per record, so router admission never blocks
//! on a cold file read — by the time requests are admitted the states
//! are RAM-resident.
//!
//! **Degraded mode**: when the store is unavailable (open failed past
//! the retry budget), the resolver keeps serving from the RAM tier and
//! train-on-miss ([`TieredAdapters::mark_degraded`]). Records trained
//! meanwhile — and publishes that fail transiently — queue in a pending
//! list; every [`TieredAdapters::refresh`] retries the reopen and
//! flushes the queue once the store is back, so an outage costs
//! duplicate training at worst, never a failed request or a lost
//! adapter.

use std::collections::{BTreeMap, BTreeSet};

use super::format::{AdapterKey, AdapterRecord};
use super::registry::Registry;
use crate::obs::{self, flight};
use crate::runtime::StateLayout;
use crate::util::pool;

/// Record one background (trace 0) flight span of `dur_ms` ending now.
fn span_ms(stage: usize, dur_ms: f64) {
    let dur_us = (dur_ms * 1e3).max(0.0) as u64;
    flight::record(0, 0, stage, obs::uptime_us().saturating_sub(dur_us), dur_us);
}

/// Where a resolved adapter came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Source {
    /// Resolved earlier in this process.
    Ram,
    /// Loaded from a verified registry record.
    Disk,
    /// Trained this process (registry miss or rejected record).
    Trained,
}

/// A serving-ready adapter: the flat state vector plus what the router
/// needs to register it.
#[derive(Clone)]
pub struct ResolvedAdapter {
    pub state: Vec<f32>,
    pub n_classes: usize,
    pub eval_metric: f64,
    /// Measured training cost recorded with the adapter (what a warm
    /// start saves).
    pub train_ms: f64,
    pub source: Source,
}

/// Resolution counters for the serving report.
#[derive(Debug, Default)]
pub struct TierStats {
    pub ram_hits: usize,
    pub disk_hits: usize,
    pub trained: usize,
    /// Registry records rejected (corrupt or fingerprint-mismatched) —
    /// each fell through to training.
    pub rejected: usize,
    /// Wall-clock spent loading + verifying records, milliseconds.
    pub load_ms: f64,
    /// Wall-clock spent training misses, milliseconds.
    pub train_ms: f64,
}

/// The tiered resolver. Generic over "how to train" (a closure per
/// [`TieredAdapters::resolve`] call), so the server owns the training
/// loop and the tiers own durability.
pub struct TieredAdapters {
    registry: Option<Registry>,
    manifest_fp: u64,
    backbone_fp: u64,
    backbone_repr: String,
    preset: String,
    method: String,
    seed: u64,
    ram: BTreeMap<String, ResolvedAdapter>,
    /// Tasks whose registry record was already rejected this process —
    /// consulted by [`TieredAdapters::resolve`] so a record that failed
    /// validation in `prefetch` is not re-read, re-warned about, and
    /// re-counted before falling through to training.
    rejected: BTreeSet<String>,
    /// Set when the store went unavailable: the directory to keep trying
    /// to reopen on [`TieredAdapters::refresh`].
    degraded_dir: Option<std::path::PathBuf>,
    /// Records awaiting publish-back: trained while degraded, or whose
    /// publish failed transiently. Flushed on refresh once the store is
    /// reachable again.
    pending: Vec<AdapterRecord>,
    pub stats: TierStats,
}

impl TieredAdapters {
    /// Build over an optional registry (None = store disabled: every
    /// resolve trains, nothing persists). The fingerprints pin which
    /// records are acceptable: `manifest_fp` from the live session layout
    /// ([`super::format::fingerprint_layout`]), `backbone_fp` from the
    /// frozen backbone ([`super::format::fingerprint_params`]),
    /// `backbone_repr` from the live backend
    /// ([`crate::runtime::Backend::backbone_repr`] — an f32-trained
    /// record must not warm-start an int8 backend or vice versa).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        registry: Option<Registry>,
        manifest_fp: u64,
        backbone_fp: u64,
        backbone_repr: &str,
        preset: &str,
        method: &str,
        seed: u64,
    ) -> TieredAdapters {
        if let Some(reg) = &registry {
            obs::gauge("store.generation").set(reg.generation() as i64);
        }
        TieredAdapters {
            registry,
            manifest_fp,
            backbone_fp,
            backbone_repr: backbone_repr.to_string(),
            preset: preset.to_string(),
            method: method.to_string(),
            seed,
            ram: BTreeMap::new(),
            rejected: BTreeSet::new(),
            degraded_dir: None,
            pending: Vec::new(),
            stats: TierStats::default(),
        }
    }

    /// The registry key for a task under this resolver's preset/method/seed.
    pub fn key(&self, task: &str) -> AdapterKey {
        AdapterKey::new(&self.preset, &self.method, task, self.seed)
    }

    pub fn registry(&self) -> Option<&Registry> {
        self.registry.as_ref()
    }

    /// True while the store is unavailable and serving falls back to
    /// RAM-tier → train-on-miss.
    pub fn degraded(&self) -> bool {
        self.degraded_dir.is_some()
    }

    /// Enter degraded mode: serve without the store, keep `dir` to retry
    /// reopening on every [`TieredAdapters::refresh`], and queue trained
    /// records for publish-back instead of dropping them.
    pub fn mark_degraded(&mut self, dir: &std::path::Path) {
        self.registry = None;
        self.degraded_dir = Some(dir.to_path_buf());
        obs::gauge("store.degraded").set(1);
    }

    /// Records still waiting for publish-back.
    pub fn pending_publishes(&self) -> usize {
        self.pending.len()
    }

    /// Try to publish every queued record. Records that still fail stay
    /// queued. Returns how many landed.
    pub fn flush_pending(&mut self) -> usize {
        if self.pending.is_empty() {
            return 0;
        }
        let queued = std::mem::take(&mut self.pending);
        let mut still = Vec::new();
        let mut flushed = 0;
        if let Some(reg) = self.registry.as_mut() {
            for record in queued {
                match reg.publish(&record) {
                    Ok(path) => {
                        flushed += 1;
                        crate::debugln!("adapter store: flushed queued publish {path:?}");
                    }
                    Err(e) => {
                        crate::warnln!(
                            "adapter store: queued publish for {} still failing ({e:#})",
                            record.meta.key
                        );
                        still.push(record);
                    }
                }
            }
        } else {
            still = queued;
        }
        self.pending = still;
        obs::gauge("store.pending_publishes").set(self.pending.len() as i64);
        flushed
    }

    /// True when `task` is already RAM-resident.
    pub fn resident(&self, task: &str) -> bool {
        self.ram.contains_key(task)
    }

    /// Re-sync with the on-disk registry: when a sibling process bumped
    /// the index generation since this resolver's registry was opened,
    /// reopen it (and forget earlier rejections — a sibling may have
    /// republished a good record). Returns whether anything was reloaded.
    /// This is the store-watch half of fleet hot-reloading; pair it with
    /// [`TieredAdapters::resolve_disk_only`].
    pub fn refresh(&mut self) -> anyhow::Result<bool> {
        // Degraded: every refresh is a reopen attempt; failure just
        // stays degraded (never an error — that's the point).
        if let Some(dir) = self.degraded_dir.clone() {
            match Registry::open(&dir) {
                Ok(reg) => {
                    obs::gauge("store.generation").set(reg.generation() as i64);
                    self.registry = Some(reg);
                    self.degraded_dir = None;
                    self.rejected.clear();
                    obs::gauge("store.degraded").set(0);
                    let flushed = self.flush_pending();
                    crate::warnln!(
                        "adapter store: {dir:?} reachable again; leaving degraded mode \
                         ({flushed} queued publish(es) flushed)"
                    );
                    return Ok(true);
                }
                Err(e) => {
                    crate::debugln!("adapter store: still unavailable ({e:#}); serving degraded");
                    return Ok(false);
                }
            }
        }
        let Some(reg) = &self.registry else { return Ok(false) };
        let dir = reg.dir().to_path_buf();
        // An unreadable generation reads as "changed": reopening runs
        // the registry's recovery path.
        let on_disk = Registry::read_generation(&dir).unwrap_or(u64::MAX);
        if on_disk == reg.generation() {
            return Ok(false);
        }
        let reg = Registry::open(&dir)?;
        obs::gauge("store.generation").set(reg.generation() as i64);
        self.registry = Some(reg);
        self.rejected.clear();
        self.flush_pending();
        Ok(true)
    }

    /// Resolve through the RAM and disk tiers only — never trains.
    /// `None` means the registry has no acceptable record for `task`
    /// (yet). Fleet workers use this for tasks a sibling worker owns:
    /// the owner trains and publishes, everyone else only hot-loads.
    pub fn resolve_disk_only(
        &mut self,
        layout: &StateLayout,
        task: &str,
    ) -> Option<&ResolvedAdapter> {
        if self.ram.contains_key(task) {
            self.stats.ram_hits += 1;
            obs::counter("store.ram_hits").inc();
            return Some(&self.ram[task]);
        }
        let key = self.key(task);
        let reg = self.registry.as_ref()?;
        reg.lookup(&key)?;
        let t0 = std::time::Instant::now();
        let loaded = reg.load(&key);
        match self.validate(layout, loaded) {
            Ok(resolved) => {
                let load_ms = t0.elapsed().as_secs_f64() * 1e3;
                self.stats.load_ms += load_ms;
                self.stats.disk_hits += 1;
                obs::counter("store.disk_hits").inc();
                span_ms(flight::STAGE_STORE_LOAD, load_ms);
                self.ram.insert(task.to_string(), resolved);
                Some(&self.ram[task])
            }
            Err(e) => {
                self.stats.rejected += 1;
                obs::counter("store.rejected").inc();
                self.rejected.insert(task.to_string());
                crate::warnln!("adapter store: record for {task:?} rejected ({e:#})");
                None
            }
        }
    }

    /// Read + decode every registry hit among `tasks` in parallel on the
    /// worker pool, then verify and promote them to the RAM tier in task
    /// order. Rejected records are logged and left for train-on-miss.
    pub fn prefetch(&mut self, layout: &StateLayout, tasks: &[&str]) {
        let Some(reg) = &self.registry else { return };
        let pending: Vec<(String, std::path::PathBuf)> = tasks
            .iter()
            .filter(|t| !self.ram.contains_key(**t))
            .filter_map(|t| {
                reg.lookup(&self.key(t)).map(|e| (t.to_string(), reg.record_path(e)))
            })
            .collect();
        if pending.is_empty() {
            return;
        }
        let t0 = std::time::Instant::now();
        // One pool task per record file; each writes only its own slot.
        let mut results: Vec<Option<anyhow::Result<AdapterRecord>>> =
            (0..pending.len()).map(|_| None).collect();
        let slots = pool::split_sizes(&mut results, &vec![1; pending.len()]);
        let mut jobs = Vec::with_capacity(pending.len());
        for (slot, (_, path)) in slots.into_iter().zip(&pending) {
            jobs.push(move || slot[0] = Some(AdapterRecord::load(path)));
        }
        pool::join_all(jobs);
        for ((task, _), result) in pending.iter().zip(results) {
            // An unfilled slot (pool job died) degrades that task to
            // train-on-miss rather than panicking the server.
            let Some(loaded) = result else {
                self.stats.rejected += 1;
                obs::counter("store.rejected").inc();
                self.rejected.insert(task.clone());
                crate::warnln!("adapter store: prefetch of {task:?} never completed; will retrain");
                continue;
            };
            match self.validate(layout, loaded) {
                Ok(resolved) => {
                    self.stats.disk_hits += 1;
                    obs::counter("store.disk_hits").inc();
                    self.ram.insert(task.clone(), resolved);
                }
                Err(e) => {
                    self.stats.rejected += 1;
                    obs::counter("store.rejected").inc();
                    self.rejected.insert(task.clone());
                    crate::warnln!(
                        "adapter store: record for {task:?} rejected ({e:#}); \
                         will retrain on miss"
                    );
                }
            }
        }
        let load_ms = t0.elapsed().as_secs_f64() * 1e3;
        self.stats.load_ms += load_ms;
        span_ms(flight::STAGE_STORE_LOAD, load_ms);
    }

    /// Fingerprint-check a loaded record and unpack its state vector.
    fn validate(
        &self,
        layout: &StateLayout,
        loaded: anyhow::Result<AdapterRecord>,
    ) -> anyhow::Result<ResolvedAdapter> {
        let rec = loaded?;
        rec.check_compat(self.manifest_fp, self.backbone_fp, &self.backbone_repr)?;
        Ok(ResolvedAdapter {
            state: rec.state_vector(layout)?,
            n_classes: rec.meta.n_classes,
            eval_metric: rec.meta.eval_metric,
            train_ms: rec.meta.train_ms,
            source: Source::Disk,
        })
    }

    /// Resolve one task through the tiers. `train` runs only on a full
    /// miss (or rejected record) and must return the fresh record, which
    /// is then published back to the registry (best-effort: a publish
    /// failure degrades durability, not serving) and promoted to RAM.
    pub fn resolve(
        &mut self,
        layout: &StateLayout,
        task: &str,
        train: impl FnOnce(&AdapterKey) -> anyhow::Result<AdapterRecord>,
    ) -> anyhow::Result<&ResolvedAdapter> {
        // Tier 1: RAM. (Entries land here tagged with their original
        // source; only a repeat resolve counts as a RAM hit.)
        if self.ram.contains_key(task) {
            self.stats.ram_hits += 1;
            obs::counter("store.ram_hits").inc();
            return Ok(&self.ram[task]);
        }

        let key = self.key(task);

        // Tier 2: disk (skipped when prefetch already rejected this
        // task's record — straight to training, no duplicate read/warn).
        if !self.rejected.contains(task) {
            if let Some(reg) = &self.registry {
                if reg.lookup(&key).is_some() {
                    let t0 = std::time::Instant::now();
                    let loaded = reg.load(&key);
                    match self.validate(layout, loaded) {
                        Ok(resolved) => {
                            let load_ms = t0.elapsed().as_secs_f64() * 1e3;
                            self.stats.load_ms += load_ms;
                            self.stats.disk_hits += 1;
                            obs::counter("store.disk_hits").inc();
                            span_ms(flight::STAGE_STORE_LOAD, load_ms);
                            self.ram.insert(task.to_string(), resolved);
                            return Ok(&self.ram[task]);
                        }
                        Err(e) => {
                            self.stats.rejected += 1;
                            obs::counter("store.rejected").inc();
                            self.rejected.insert(task.to_string());
                            crate::warnln!(
                                "adapter store: record for {task:?} rejected ({e:#}); \
                                 retraining"
                            );
                        }
                    }
                }
            }
        }

        // Tier 3: train, then publish back.
        let t0 = std::time::Instant::now();
        let record = train(&key)?;
        let train_ms = t0.elapsed().as_secs_f64() * 1e3;
        self.stats.train_ms += train_ms;
        self.stats.trained += 1;
        obs::counter("store.trained").inc();
        span_ms(flight::STAGE_STORE_TRAIN, train_ms);
        anyhow::ensure!(
            record.meta.key == key,
            "trainer returned a record for {}, expected {key}",
            record.meta.key
        );
        anyhow::ensure!(
            record.meta.backbone_repr == self.backbone_repr,
            "trainer returned a {} record, resolver serves a {} backbone",
            record.meta.backbone_repr,
            self.backbone_repr
        );
        // Symmetric compat checks: a trainer whose session layout or
        // frozen inputs differ from the serving session would otherwise
        // publish records that every later boot quietly rejects — the
        // store would degrade to retrain-on-every-start with nothing but
        // warnings.
        anyhow::ensure!(
            record.meta.manifest_fp == self.manifest_fp,
            "trainer session layout (fingerprint {}) differs from the serving session ({})",
            super::format::fp_hex(record.meta.manifest_fp),
            super::format::fp_hex(self.manifest_fp)
        );
        anyhow::ensure!(
            record.meta.backbone_fp == self.backbone_fp,
            "trainer backbone (fingerprint {}) differs from the serving backbone ({})",
            super::format::fp_hex(record.meta.backbone_fp),
            super::format::fp_hex(self.backbone_fp)
        );
        let resolved = ResolvedAdapter {
            state: record.state_vector(layout)?,
            n_classes: record.meta.n_classes,
            eval_metric: record.meta.eval_metric,
            train_ms: record.meta.train_ms,
            source: Source::Trained,
        };
        // Publish-back is best-effort for serving but never silently
        // lossy: a transient failure (or degraded mode) queues the
        // record so refresh() can land it once the store recovers.
        let mut queue_record = self.degraded_dir.is_some();
        if let Some(reg) = &mut self.registry {
            match reg.publish(&record) {
                Ok(path) => crate::debugln!("adapter store: published {path:?}"),
                Err(e) if super::retry::is_transient(&e) => {
                    crate::warnln!(
                        "adapter store: publish for {task:?} failed transiently ({e:#}); \
                         queued for retry"
                    );
                    queue_record = true;
                }
                Err(e) => {
                    crate::warnln!("adapter store: cannot publish record for {task:?}: {e:#}")
                }
            }
        }
        if queue_record {
            self.pending.push(record);
            obs::gauge("store.pending_publishes").set(self.pending.len() as i64);
        }
        self.ram.insert(task.to_string(), resolved);
        Ok(&self.ram[task])
    }
}
