//! Durable adapter store: trained adapters as first-class artifacts.
//!
//! QR-LoRA's premise is that a task adaptation is tiny — a λ coefficient
//! vector plus a head over a shared frozen backbone — which makes a
//! trained adapter worth *keeping*: serialize it once, verify it, ship
//! it, and hot-load it into any server holding the same backbone. This
//! subsystem provides exactly that:
//!
//! * [`format`] — the versioned, checksummed single-file record
//!   (`*.qad`): per-section CRC-32, manifest + backbone fingerprints,
//!   trainable tensors, optional Adam state, achieved eval metric. Its
//!   named-tensor codec is shared with `model::checkpoint`.
//! * [`registry`] — the atomic `index.json` over a record directory:
//!   write-temp-then-rename everywhere, stale-entry recovery and index
//!   rebuild on open, list/lookup/verify. Index rewrites re-read the
//!   on-disk index under the store lock and merge into *fresh* entries,
//!   so concurrent publishers from N processes all land.
//! * [`lock`] — the dependency-free advisory lock file (`index.lock`)
//!   serializing those index rewrites across processes, with stale-holder
//!   takeover mirroring the crashed-write sweep rules.
//! * [`tier`] — three-tier resolution for serving: RAM-resident → disk
//!   (fingerprint-checked against the live backbone/manifest, loads
//!   dispatched on the worker pool) → train-on-miss, which publishes the
//!   fresh record back.
//! * [`gc`] — prune records by key, age, or count.
//!
//! The `serve` demo warm starts from the store (`--adapter-store`,
//! `--no-warm-start`), and the `adapters` CLI command exposes
//! list/verify/gc. See ARCHITECTURE.md §"Adapter store".

pub mod format;
pub mod gc;
pub mod lock;
pub mod registry;
pub mod retry;
pub mod tier;

pub use format::{
    fingerprint_extend, fingerprint_layout, fingerprint_params, AdamState, AdapterKey,
    AdapterRecord, RecordMeta,
};
pub use gc::{GcPolicy, GcReport};
pub use lock::{StoreLock, LOCK_FILE, LOCK_STALE_AGE_SECS};
pub use registry::{Registry, RegistryEntry, VerifyResult, DEFAULT_STORE_DIR};
pub use tier::{ResolvedAdapter, Source, TierStats, TieredAdapters};

use std::path::Path;

/// Unix seconds now. Errors when the system clock sits before the epoch
/// instead of clamping to 0 — a silent 0 would stamp records as ancient
/// and make them instantly eligible for `--max-age-days` gc.
pub fn unix_now() -> anyhow::Result<u64> {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .map_err(|e| {
            anyhow::anyhow!("system clock is {:?} before the unix epoch", e.duration())
        })
}

/// [`unix_now`] for display-only call sites: warns on a pre-epoch clock
/// and returns 0. Never feed this into age-based gc decisions — `gc`
/// exempts `created_unix == 0` records from the age criterion precisely
/// because 0 means "clock was broken", not "1970".
pub fn unix_now_or_zero() -> u64 {
    unix_now().unwrap_or_else(|e| {
        crate::warnln!("adapter store: {e:#}; timestamps will read as 0");
        0
    })
}

/// Write a file atomically: write a `.tmp<pid>` sibling, then rename
/// into place. A crash mid-write leaves only the temp file — a
/// half-written file can never sit under a published name — and
/// [`Registry::open`] sweeps temp files once they are demonstrably stale.
/// The pid suffix keeps two processes publishing the same path from
/// interleaving writes into one temp file.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> anyhow::Result<()> {
    atomic_write_site(path, bytes, "store.write")
}

/// [`atomic_write`] with an explicit fault-injection site (`"publish"`
/// for adapter records, `"store.write"` for index rewrites): the
/// injection hooks sit before the temp write (transient IO error) and
/// between temp write and rename (`crash_after_temp` — dying exactly
/// inside the torn-write window the recovery sweeps exist for).
pub fn atomic_write_site(path: &Path, bytes: &[u8], site: &str) -> anyhow::Result<()> {
    crate::util::faults::io_fault(site)?;
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension(format!("tmp{}", std::process::id()));
    std::fs::write(&tmp, bytes).map_err(|e| anyhow::anyhow!("cannot write {tmp:?}: {e}"))?;
    crate::util::faults::crash_point(site);
    std::fs::rename(&tmp, path)
        .map_err(|e| anyhow::anyhow!("cannot move {tmp:?} into place at {path:?}: {e}"))?;
    Ok(())
}
