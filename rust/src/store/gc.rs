//! Registry garbage collection: prune adapter records by key, age, or
//! count.
//!
//! Policy semantics (CLI `adapters gc`):
//!
//! * `task` — restrict the candidate set to one task's records; with no
//!   other criterion, prune *all* of them (prune-by-key).
//! * `max_age_secs` — drop candidates older than this.
//! * `max_count` — after age pruning, keep only the newest N candidates.
//!
//! At least one criterion is required — a bare `gc` refusing to delete
//! everything is a feature. `dry_run` reports what would go without
//! touching the index or the files.

use super::format::AdapterKey;
use super::registry::Registry;

/// What to prune. See the module docs for semantics.
#[derive(Clone, Debug, Default)]
pub struct GcPolicy {
    pub task: Option<String>,
    pub max_age_secs: Option<u64>,
    pub max_count: Option<usize>,
}

impl GcPolicy {
    /// True when no criterion is set (gc must refuse).
    pub fn is_empty(&self) -> bool {
        self.task.is_none() && self.max_age_secs.is_none() && self.max_count.is_none()
    }
}

/// What a GC pass removed (or would remove, under `dry_run`).
#[derive(Debug, Default)]
pub struct GcReport {
    pub removed: Vec<AdapterKey>,
    pub kept: usize,
    pub freed_bytes: u64,
}

/// Apply a policy. `now_unix` is passed in (not sampled) so age pruning
/// is testable.
pub fn gc(
    reg: &mut Registry,
    policy: &GcPolicy,
    now_unix: u64,
    dry_run: bool,
) -> anyhow::Result<GcReport> {
    anyhow::ensure!(
        !policy.is_empty(),
        "refusing to gc with no criteria: pass --task, --max-age-days, or --max-count"
    );
    // Candidates within scope, newest first.
    let mut candidates: Vec<(AdapterKey, u64, u64)> = reg
        .entries()
        .iter()
        .filter(|e| policy.task.as_deref().map(|t| e.key.task == t).unwrap_or(true))
        .map(|e| (e.key.clone(), e.created_unix, e.bytes))
        .collect();
    candidates.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));

    let mut doomed: Vec<AdapterKey> = Vec::new();
    let mut survivors: Vec<&(AdapterKey, u64, u64)> = Vec::new();
    for c in &candidates {
        let too_old = match policy.max_age_secs {
            // created_unix == 0 means "clock was pre-epoch at publish",
            // not "1970": its age is unknowable, so exempt it from the
            // age criterion (count/task pruning still applies) instead
            // of treating it as instantly ancient.
            Some(_) if c.1 == 0 => {
                crate::warnln!(
                    "gc: {} has no creation timestamp (published under a skewed clock); \
                     skipping the age check for it",
                    c.0
                );
                false
            }
            Some(max) => now_unix.saturating_sub(c.1) > max,
            None => false,
        };
        if too_old {
            doomed.push(c.0.clone());
        } else {
            survivors.push(c);
        }
    }
    if let Some(max) = policy.max_count {
        for c in survivors.iter().skip(max) {
            doomed.push(c.0.clone());
        }
    } else if policy.max_age_secs.is_none() {
        // Pure key prune: --task with no age/count criterion drops all.
        doomed.extend(survivors.iter().map(|c| c.0.clone()));
    }

    if dry_run {
        let freed_planned: u64 = candidates
            .iter()
            .filter(|c| doomed.contains(&c.0))
            .map(|c| c.2)
            .sum();
        let kept = reg.len() - doomed.len();
        return Ok(GcReport { removed: doomed, kept, freed_bytes: freed_planned });
    }
    // `removed` reflects what actually left the store: an undeletable
    // record file keeps its index entry and is not reported as removed.
    let (freed_bytes, removed) = reg.remove(&doomed)?;
    Ok(GcReport { removed, kept: reg.len(), freed_bytes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::format::{AdapterRecord, RecordMeta};
    use crate::tensor::Tensor;
    use std::collections::BTreeMap;

    fn record(task: &str, seed: u64, created_unix: u64) -> AdapterRecord {
        let mut params = BTreeMap::new();
        params.insert("head/wc".to_string(), Tensor::zeros(&[2, 2]));
        AdapterRecord {
            meta: RecordMeta {
                key: AdapterKey::new("tiny", "qrlora", task, seed),
                manifest_fp: 1,
                backbone_fp: 2,
                backbone_repr: "f32".to_string(),
                n_classes: 2,
                eval_metric: 0.5,
                steps: 10,
                train_ms: 1.0,
                created_unix,
            },
            params,
            adam: None,
        }
    }

    fn tmp_registry(name: &str) -> Registry {
        let dir = std::env::temp_dir().join("qrlora_gc_tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        Registry::open(&dir).unwrap()
    }

    #[test]
    fn gc_refuses_empty_policy() {
        let mut reg = tmp_registry("empty_policy");
        assert!(gc(&mut reg, &GcPolicy::default(), 100, false).is_err());
    }

    #[test]
    fn gc_by_age_count_and_task() {
        let mut reg = tmp_registry("age_count");
        reg.publish(&record("sst2", 1, 100)).unwrap();
        reg.publish(&record("sst2", 2, 200)).unwrap();
        reg.publish(&record("mrpc", 1, 50)).unwrap();
        reg.publish(&record("qnli", 1, 300)).unwrap();

        // Dry run never mutates.
        let policy = GcPolicy { max_age_secs: Some(150), ..Default::default() };
        let dry = gc(&mut reg, &policy, 300, true).unwrap();
        assert_eq!(dry.removed.len(), 2, "{:?}", dry.removed); // ages 250, 200 > 150
        assert_eq!(reg.len(), 4);

        // Age prune for real: created 100 (age 200) and 50 (age 250) go.
        let report = gc(&mut reg, &policy, 300, false).unwrap();
        assert_eq!(report.removed.len(), 2);
        assert_eq!(reg.len(), 2);
        assert!(reg.lookup(&AdapterKey::new("tiny", "qrlora", "mrpc", 1)).is_none());

        // Count prune: keep only the newest 1.
        let policy = GcPolicy { max_count: Some(1), ..Default::default() };
        let report = gc(&mut reg, &policy, 300, false).unwrap();
        assert_eq!(report.kept, 1);
        assert!(reg.lookup(&AdapterKey::new("tiny", "qrlora", "qnli", 1)).is_some());

        // Task prune with no other criterion drops that task entirely.
        reg.publish(&record("sst2", 9, 400)).unwrap();
        let policy = GcPolicy { task: Some("sst2".to_string()), ..Default::default() };
        let report = gc(&mut reg, &policy, 500, false).unwrap();
        assert_eq!(report.removed, vec![AdapterKey::new("tiny", "qrlora", "sst2", 9)]);
        assert_eq!(reg.len(), 1, "qnli record must survive a task-scoped prune");
    }

    #[test]
    fn gc_age_exempts_records_without_a_timestamp() {
        let mut reg = tmp_registry("zero_created");
        reg.publish(&record("sst2", 1, 0)).unwrap(); // skewed-clock publish
        reg.publish(&record("sst2", 2, 100)).unwrap();

        // Age prune: the dated record (age 900 > 50) goes; the
        // timestampless one is exempt, not instantly ancient.
        let policy = GcPolicy { max_age_secs: Some(50), ..Default::default() };
        let report = gc(&mut reg, &policy, 1_000, false).unwrap();
        assert_eq!(report.removed, vec![AdapterKey::new("tiny", "qrlora", "sst2", 2)]);
        assert!(reg.lookup(&AdapterKey::new("tiny", "qrlora", "sst2", 1)).is_some());

        // Count/task pruning still reaches it.
        let policy = GcPolicy { max_count: Some(0), ..Default::default() };
        gc(&mut reg, &policy, 1_000, false).unwrap();
        assert!(reg.is_empty());
    }
}
