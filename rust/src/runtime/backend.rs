//! Pluggable execution backends.
//!
//! Every training/eval/serving path drives artifacts through the
//! [`Backend`] trait: upload host tensors, execute a step program, download
//! metrics. Two implementations exist:
//!
//! * [`super::HostBackend`] — pure-Rust interpreter of the built-in
//!   manifest (`runtime::spec`), always available. State "buffers" are
//!   plain host vectors; the step math lives in `model::host`.
//! * `PjrtBackend` (cargo feature `pjrt`) — the original PJRT path: loads
//!   `artifacts/*.hlo.txt`, compiles through the XLA CPU client, keeps the
//!   state buffer device-resident across steps.
//!
//! Selection: `create_backend` honors an explicit [`BackendChoice`]
//! (CLI `--backend` / `QRLORA_BACKEND`); `Auto` picks PJRT when the feature
//! is compiled **and** an artifacts manifest exists, else falls back to the
//! host backend, so a clean checkout runs hermetically.

use std::path::Path;
use std::rc::Rc;

use super::manifest::{ArtifactSpec, Manifest};

/// Host-side tensor value (upload source / download target).
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => anyhow::bail!("expected f32 tensor"),
        }
    }
}

/// A backend-owned buffer: host data for [`super::HostBackend`], a device
/// handle for the PJRT backend.
pub enum Buffer {
    Host { value: HostTensor, shape: Vec<usize> },
    #[cfg(feature = "pjrt")]
    Pjrt(xla::PjRtBuffer),
}

impl Buffer {
    pub fn host_f32(data: Vec<f32>, shape: &[usize]) -> Buffer {
        Buffer::Host { value: HostTensor::F32(data), shape: shape.to_vec() }
    }

    pub fn host_i32(data: Vec<i32>, shape: &[usize]) -> Buffer {
        Buffer::Host { value: HostTensor::I32(data), shape: shape.to_vec() }
    }

    /// Borrow as f32 host data (errors on dtype mismatch / device buffers).
    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match self {
            Buffer::Host { value: HostTensor::F32(v), .. } => Ok(v),
            Buffer::Host { .. } => anyhow::bail!("buffer is i32, expected f32"),
            #[cfg(feature = "pjrt")]
            Buffer::Pjrt(_) => anyhow::bail!("cannot borrow device buffer as host f32"),
        }
    }

    /// Borrow as i32 host data (errors on dtype mismatch / device buffers).
    pub fn as_i32(&self) -> anyhow::Result<&[i32]> {
        match self {
            Buffer::Host { value: HostTensor::I32(v), .. } => Ok(v),
            Buffer::Host { .. } => anyhow::bail!("buffer is f32, expected i32"),
            #[cfg(feature = "pjrt")]
            Buffer::Pjrt(_) => anyhow::bail!("cannot borrow device buffer as host i32"),
        }
    }
}

/// A loaded executable: manifest spec + backend-specific implementation.
pub struct Executable {
    pub spec: ArtifactSpec,
    pub(crate) imp: ExecutableImpl,
}

pub(crate) enum ExecutableImpl {
    Host(super::host::HostProgram),
    #[cfg(feature = "pjrt")]
    Pjrt(xla::PjRtLoadedExecutable),
}

/// The execution-backend contract: load/upload/execute/download over the
/// shared `Manifest`/`ArtifactSpec` protocol.
pub trait Backend {
    /// Stable identifier ("host" / "pjrt") for logs and BENCH files.
    fn name(&self) -> &'static str;

    /// The manifest this backend executes against.
    fn manifest(&self) -> &Manifest;

    /// Load (and cache) an executable by manifest key.
    fn load(&self, key: &str) -> anyhow::Result<Rc<Executable>>;

    /// Run an executable on backend buffers; returns one buffer per
    /// manifest output, in order.
    fn execute(&self, exe: &Executable, args: &[&Buffer]) -> anyhow::Result<Vec<Buffer>>;

    fn upload_f32(&self, data: &[f32], shape: &[usize]) -> anyhow::Result<Buffer>;

    fn upload_i32(&self, data: &[i32], shape: &[usize]) -> anyhow::Result<Buffer>;

    fn download_f32(&self, buf: &Buffer) -> anyhow::Result<Vec<f32>>;

    fn upload_scalar(&self, v: f32) -> anyhow::Result<Buffer> {
        self.upload_f32(&[v], &[])
    }

    /// Read the metrics head of a state buffer by running the paired
    /// `metrics_*` slice program and downloading only the small head.
    fn read_metrics(&self, metrics_exe: &Executable, state: &Buffer) -> anyhow::Result<Vec<f32>> {
        let outs = self.execute(metrics_exe, &[state])?;
        self.download_f32(&outs[0])
    }
}

/// Which backend the user asked for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// PJRT when compiled and artifacts exist, else host.
    Auto,
    Host,
    Pjrt,
}

impl BackendChoice {
    pub fn parse(s: &str) -> anyhow::Result<BackendChoice> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "auto" => BackendChoice::Auto,
            "host" => BackendChoice::Host,
            "pjrt" => BackendChoice::Pjrt,
            other => anyhow::bail!("unknown backend {other:?} (auto|host|pjrt)"),
        })
    }

    /// Read `QRLORA_BACKEND` (default `auto`).
    pub fn from_env() -> anyhow::Result<BackendChoice> {
        match std::env::var("QRLORA_BACKEND") {
            Ok(v) if !v.is_empty() => BackendChoice::parse(&v),
            _ => Ok(BackendChoice::Auto),
        }
    }
}

/// Instantiate a backend. `artifacts_dir` is only consulted by the PJRT
/// path (and by `Auto` to decide whether PJRT is viable).
pub fn create_backend(
    choice: BackendChoice,
    artifacts_dir: &Path,
) -> anyhow::Result<Box<dyn Backend>> {
    match choice {
        BackendChoice::Host => Ok(Box::new(super::HostBackend::new())),
        BackendChoice::Pjrt => {
            #[cfg(feature = "pjrt")]
            {
                Ok(Box::new(super::PjrtBackend::new(artifacts_dir)?))
            }
            #[cfg(not(feature = "pjrt"))]
            {
                let _ = artifacts_dir;
                anyhow::bail!(
                    "backend \"pjrt\" requested but this binary was built without the \
                     `pjrt` cargo feature; rebuild with `--features pjrt` or use \
                     QRLORA_BACKEND=host"
                )
            }
        }
        BackendChoice::Auto => {
            #[cfg(feature = "pjrt")]
            if artifacts_dir.join("manifest.json").exists() {
                match super::PjrtBackend::new(artifacts_dir) {
                    Ok(bk) => return Ok(Box::new(bk)),
                    Err(e) => {
                        crate::warnln!(
                            "pjrt backend unavailable ({e:#}); falling back to host backend"
                        );
                    }
                }
            }
            let _ = artifacts_dir;
            Ok(Box::new(super::HostBackend::new()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choice_parse() {
        assert_eq!(BackendChoice::parse("host").unwrap(), BackendChoice::Host);
        assert_eq!(BackendChoice::parse("PJRT").unwrap(), BackendChoice::Pjrt);
        assert_eq!(BackendChoice::parse("auto").unwrap(), BackendChoice::Auto);
        assert!(BackendChoice::parse("tpu").is_err());
    }

    #[test]
    fn auto_without_artifacts_is_host() {
        let bk = create_backend(BackendChoice::Auto, Path::new("/nonexistent/artifacts")).unwrap();
        assert_eq!(bk.name(), "host");
        assert!(bk.manifest().preset("tiny").is_ok());
    }

    #[test]
    fn host_buffer_accessors() {
        let b = Buffer::host_f32(vec![1.0, 2.0], &[2]);
        assert_eq!(b.as_f32().unwrap(), &[1.0, 2.0]);
        assert!(b.as_i32().is_err());
        let i = Buffer::host_i32(vec![3, 4], &[2]);
        assert_eq!(i.as_i32().unwrap(), &[3, 4]);
        assert!(i.as_f32().is_err());
    }
}
