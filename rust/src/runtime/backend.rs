//! Pluggable execution backends.
//!
//! Every training/eval/serving path drives artifacts through the
//! [`Backend`] trait: upload host tensors, execute a step program, download
//! metrics. Two implementations exist:
//!
//! * [`super::HostBackend`] — pure-Rust interpreter of the built-in
//!   manifest (`runtime::spec`), always available. State "buffers" are
//!   plain host vectors; the step math lives in `model::host`.
//! * `PjrtBackend` (cargo feature `pjrt`) — the original PJRT path: loads
//!   `artifacts/*.hlo.txt`, compiles through the XLA CPU client, keeps the
//!   state buffer device-resident across steps.
//!
//! Selection: [`create_backend`] honors an explicit [`BackendChoice`]
//! (CLI `--backend` / `QRLORA_BACKEND`); `Auto` picks PJRT when the feature
//! is compiled **and** an artifacts manifest exists, else falls back to the
//! host backend, so a clean checkout runs hermetically.
//!
//! Beyond single-adapter steps, the trait carries
//! [`Backend::execute_batched`]: mixed-adapter batched inference over one
//! eval-forward program, the primitive behind the serving router's
//! [`crate::server::AdapterBank`]. Backends without a native fast path get
//! the grouped fallback ([`execute_batched_grouped`]) for free.

use std::path::Path;
use std::rc::Rc;

use super::manifest::{ArtifactSpec, Manifest, Role};

/// Host-side tensor value (upload source / download target).
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    /// Element count, regardless of dtype.
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        match self {
            HostTensor::F32(v) => v.is_empty(),
            HostTensor::I32(v) => v.is_empty(),
        }
    }

    /// Borrow as f32 data (errors on an i32 tensor).
    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => anyhow::bail!("expected f32 tensor"),
        }
    }
}

/// A backend-owned buffer: host data for [`super::HostBackend`], a device
/// handle for the PJRT backend.
pub enum Buffer {
    Host { value: HostTensor, shape: Vec<usize> },
    #[cfg(feature = "pjrt")]
    Pjrt(xla::PjRtBuffer),
}

impl Buffer {
    /// Wrap host f32 data as a buffer (host backend).
    pub fn host_f32(data: Vec<f32>, shape: &[usize]) -> Buffer {
        Buffer::Host { value: HostTensor::F32(data), shape: shape.to_vec() }
    }

    /// Wrap host i32 data as a buffer (host backend).
    pub fn host_i32(data: Vec<i32>, shape: &[usize]) -> Buffer {
        Buffer::Host { value: HostTensor::I32(data), shape: shape.to_vec() }
    }

    /// Borrow as f32 host data (errors on dtype mismatch / device buffers).
    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match self {
            Buffer::Host { value: HostTensor::F32(v), .. } => Ok(v),
            Buffer::Host { .. } => anyhow::bail!("buffer is i32, expected f32"),
            #[cfg(feature = "pjrt")]
            Buffer::Pjrt(_) => anyhow::bail!("cannot borrow device buffer as host f32"),
        }
    }

    /// Borrow as i32 host data (errors on dtype mismatch / device buffers).
    pub fn as_i32(&self) -> anyhow::Result<&[i32]> {
        match self {
            Buffer::Host { value: HostTensor::I32(v), .. } => Ok(v),
            Buffer::Host { .. } => anyhow::bail!("buffer is f32, expected i32"),
            #[cfg(feature = "pjrt")]
            Buffer::Pjrt(_) => anyhow::bail!("cannot borrow device buffer as host i32"),
        }
    }
}

/// A loaded executable: manifest spec + backend-specific implementation.
pub struct Executable {
    pub spec: ArtifactSpec,
    pub(crate) imp: ExecutableImpl,
}

pub(crate) enum ExecutableImpl {
    Host(super::host::HostProgram),
    #[cfg(feature = "pjrt")]
    Pjrt(xla::PjRtLoadedExecutable),
}

/// Per-row adapter selection for one mixed-task batch (the argument block
/// of [`Backend::execute_batched`]).
///
/// `states[t]` / `class_masks[t]` are adapter `t`'s backend-resident flat
/// state vector and padded class-mask vector; `row_slots[b]` names the
/// adapter serving batch row `b`. The vectors stay resident across calls
/// (the serving router's `AdapterBank` owns them), so steady-state batched
/// inference uploads nothing per request.
pub struct BatchedAdapters<'a> {
    /// Resident per-adapter state vectors, one per bank slot. Each must
    /// match the executable's `state` input shape.
    pub states: &'a [&'a Buffer],
    /// Per-adapter `batch/class_mask` vectors, index-aligned with `states`.
    pub class_masks: &'a [&'a Buffer],
    /// For each batch row, the index into `states` of the adapter that
    /// serves it. Length must equal the program's batch dimension.
    pub row_slots: &'a [usize],
}

impl BatchedAdapters<'_> {
    /// Structural checks shared by every implementation: non-empty bank,
    /// aligned mask table, in-range row slots.
    pub fn validate(&self, spec: &ArtifactSpec) -> anyhow::Result<()> {
        anyhow::ensure!(!self.states.is_empty(), "{}: adapter bank is empty", spec.key);
        anyhow::ensure!(
            self.class_masks.len() == self.states.len(),
            "{}: {} class masks for {} adapter states",
            spec.key,
            self.class_masks.len(),
            self.states.len()
        );
        for &s in self.row_slots {
            anyhow::ensure!(
                s < self.states.len(),
                "{}: row slot {s} out of range ({} resident adapters)",
                spec.key,
                self.states.len()
            );
        }
        Ok(())
    }
}

/// Grouped fallback for [`Backend::execute_batched`]: one full `execute`
/// per *distinct* adapter in the batch, substituting that adapter's state
/// and class mask, then gathering only its rows from the logits output.
///
/// Correct on any backend (per-row outputs depend only on the row's own
/// inputs and the substituted adapter), but pays one backbone pass per
/// distinct task in the batch — this is what the PJRT backend runs today,
/// while [`super::HostBackend`] overrides the trait method with a true
/// single-pass path.
pub fn execute_batched_grouped<B: Backend + ?Sized>(
    bk: &B,
    exe: &Executable,
    args: &[&Buffer],
    adapters: &BatchedAdapters<'_>,
) -> anyhow::Result<Vec<Buffer>> {
    let spec = &exe.spec;
    adapters.validate(spec)?;
    anyhow::ensure!(
        spec.kind.starts_with("eval_fwd"),
        "{}: execute_batched supports eval_fwd programs only",
        spec.key
    );
    anyhow::ensure!(
        spec.outputs.len() == 1,
        "{}: batched execution expects a single logits output",
        spec.key
    );
    let state_idx = spec
        .inputs_with_role(Role::State)
        .map(|(i, _)| i)
        .next()
        .ok_or_else(|| anyhow::anyhow!("{}: no state input", spec.key))?;
    let mask_idx = spec.input_index("batch/class_mask");

    let out_spec = &spec.outputs[0];
    let rows = adapters.row_slots.len();
    anyhow::ensure!(
        out_spec.shape.first() == Some(&rows),
        "{}: {} row slots for a {:?} output",
        spec.key,
        rows,
        out_spec.shape
    );
    let k = out_spec.numel() / rows.max(1);

    // Same deterministic first-appearance adapter order as the host fast
    // path.
    let present = crate::model::host::distinct_slots(adapters.row_slots);

    let mut merged = vec![0f32; out_spec.numel()];
    for &slot in &present {
        let mut patched: Vec<&Buffer> = args.to_vec();
        patched[state_idx] = adapters.states[slot];
        if let Some(mi) = mask_idx {
            patched[mi] = adapters.class_masks[slot];
        }
        let outs = bk.execute(exe, &patched)?;
        let logits = bk.download_f32(&outs[0])?;
        for (row, &rs) in adapters.row_slots.iter().enumerate() {
            if rs == slot {
                merged[row * k..(row + 1) * k].copy_from_slice(&logits[row * k..(row + 1) * k]);
            }
        }
    }
    Ok(vec![bk.upload_f32(&merged, &out_spec.shape)?])
}

/// Resident footprint of a backend's converted frozen inputs, split into
/// the quantizable backbone weights (embeddings + attention/FFN
/// projections — see `quant::plan`) and everything else (QR factors,
/// masks, LayerNorm, biases), which always stays f32.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FrozenResidency {
    /// What the backbone weights would cost in f32.
    pub backbone_f32_bytes: usize,
    /// What they actually cost as resident (int8 values + scales when
    /// quantized, f32 otherwise).
    pub backbone_resident_bytes: usize,
    /// Non-quantizable frozen bytes (always f32).
    pub other_bytes: usize,
}

impl FrozenResidency {
    /// Backbone-weight memory reduction vs f32 (1.0 when unquantized).
    pub fn reduction(&self) -> f64 {
        if self.backbone_resident_bytes == 0 {
            return 1.0;
        }
        self.backbone_f32_bytes as f64 / self.backbone_resident_bytes as f64
    }
}

/// The execution-backend contract: load/upload/execute/download over the
/// shared `Manifest`/`ArtifactSpec` protocol.
pub trait Backend {
    /// Stable identifier ("host" / "pjrt") for logs and BENCH files.
    fn name(&self) -> &'static str;

    /// The manifest this backend executes against.
    fn manifest(&self) -> &Manifest;

    /// Load (and cache) an executable by manifest key.
    fn load(&self, key: &str) -> anyhow::Result<Rc<Executable>>;

    /// Run an executable on backend buffers; returns one buffer per
    /// manifest output, in order.
    fn execute(&self, exe: &Executable, args: &[&Buffer]) -> anyhow::Result<Vec<Buffer>>;

    /// Upload host f32 data as a backend buffer of the given shape.
    fn upload_f32(&self, data: &[f32], shape: &[usize]) -> anyhow::Result<Buffer>;

    /// Upload host i32 data as a backend buffer of the given shape.
    fn upload_i32(&self, data: &[i32], shape: &[usize]) -> anyhow::Result<Buffer>;

    /// Copy a backend buffer back to host f32 data.
    fn download_f32(&self, buf: &Buffer) -> anyhow::Result<Vec<f32>>;

    /// Upload one f32 scalar (rank-0 buffer).
    fn upload_scalar(&self, v: f32) -> anyhow::Result<Buffer> {
        self.upload_f32(&[v], &[])
    }

    /// Read the metrics head of a state buffer by running the paired
    /// `metrics_*` slice program and downloading only the small head.
    fn read_metrics(&self, metrics_exe: &Executable, state: &Buffer) -> anyhow::Result<Vec<f32>> {
        let outs = self.execute(metrics_exe, &[state])?;
        self.download_f32(&outs[0])
    }

    /// Execute one eval-forward program over a mixed-adapter batch.
    ///
    /// `args` is the full argument list in manifest order, with *some*
    /// adapter's buffers in the `state` / `batch/class_mask` slots as
    /// placeholders; `adapters` carries the resident per-adapter buffers
    /// and the per-row slot assignment. Returns the same outputs as
    /// [`Backend::execute`], each batch row produced by its own adapter —
    /// bit-identical, per row, to executing with that adapter's state
    /// swapped in (every op on the forward path is row-local).
    ///
    /// The default implementation is [`execute_batched_grouped`]: one
    /// `execute` per distinct adapter in the batch. [`super::HostBackend`]
    /// overrides it with a single-pass fast path that evaluates the shared
    /// frozen backbone once and selects adapter deltas and task heads per
    /// row.
    fn execute_batched(
        &self,
        exe: &Executable,
        args: &[&Buffer],
        adapters: &BatchedAdapters<'_>,
    ) -> anyhow::Result<Vec<Buffer>> {
        execute_batched_grouped(self, exe, args, adapters)
    }

    /// Resident footprint of the backend's converted frozen inputs, when
    /// the backend tracks one (the host backend's frozen cache does; see
    /// [`FrozenResidency`]). `None` for backends without such a cache.
    fn frozen_residency(&self) -> Option<FrozenResidency> {
        None
    }

    /// How this backend represents the frozen backbone in memory:
    /// `"int8"` on a host backend built with `--quantize-backbone`, else
    /// `"f32"`. Recorded in durable adapter records
    /// (`store::format::RecordMeta`) so an adapter trained against one
    /// representation is never warm-started onto the other — that would
    /// break the store's bit-identity-with-train-on-miss contract.
    fn backbone_repr(&self) -> &'static str {
        "f32"
    }
}

/// Which backend the user asked for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// PJRT when compiled and artifacts exist, else host.
    Auto,
    Host,
    Pjrt,
}

impl BackendChoice {
    pub fn parse(s: &str) -> anyhow::Result<BackendChoice> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "auto" => BackendChoice::Auto,
            "host" => BackendChoice::Host,
            "pjrt" => BackendChoice::Pjrt,
            other => anyhow::bail!("unknown backend {other:?} (auto|host|pjrt)"),
        })
    }

    /// Read `QRLORA_BACKEND` (default `auto`).
    pub fn from_env() -> anyhow::Result<BackendChoice> {
        match std::env::var("QRLORA_BACKEND") {
            Ok(v) if !v.is_empty() => BackendChoice::parse(&v),
            _ => Ok(BackendChoice::Auto),
        }
    }
}

/// Instantiate a backend. `artifacts_dir` is only consulted by the PJRT
/// path (and by `Auto` to decide whether PJRT is viable). The
/// `QRLORA_QUANT` env knob (CLI `--quantize-backbone`) turns on the
/// int8-quantized frozen backbone on the host backend; the PJRT path
/// executes fixed AOT graphs, so the knob is warned about and ignored
/// there.
pub fn create_backend(
    choice: BackendChoice,
    artifacts_dir: &Path,
) -> anyhow::Result<Box<dyn Backend>> {
    let quant = crate::quant::quant_backbone_from_env();
    let host = || Box::new(super::HostBackend::with_quant(quant)) as Box<dyn Backend>;
    let warn_quant_pjrt = || {
        if quant {
            crate::warnln!("--quantize-backbone is host-only; the pjrt backend ignores it");
        }
    };
    match choice {
        BackendChoice::Host => Ok(host()),
        BackendChoice::Pjrt => {
            #[cfg(feature = "pjrt")]
            {
                warn_quant_pjrt();
                Ok(Box::new(super::PjrtBackend::new(artifacts_dir)?))
            }
            #[cfg(not(feature = "pjrt"))]
            {
                let _ = (artifacts_dir, warn_quant_pjrt);
                anyhow::bail!(
                    "backend \"pjrt\" requested but this binary was built without the \
                     `pjrt` cargo feature; rebuild with `--features pjrt` or use \
                     QRLORA_BACKEND=host"
                )
            }
        }
        BackendChoice::Auto => {
            #[cfg(feature = "pjrt")]
            if artifacts_dir.join("manifest.json").exists() {
                match super::PjrtBackend::new(artifacts_dir) {
                    Ok(bk) => {
                        warn_quant_pjrt();
                        return Ok(Box::new(bk));
                    }
                    Err(e) => {
                        crate::warnln!(
                            "pjrt backend unavailable ({e:#}); falling back to host backend"
                        );
                    }
                }
            }
            let _ = (artifacts_dir, warn_quant_pjrt);
            Ok(host())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choice_parse() {
        assert_eq!(BackendChoice::parse("host").unwrap(), BackendChoice::Host);
        assert_eq!(BackendChoice::parse("PJRT").unwrap(), BackendChoice::Pjrt);
        assert_eq!(BackendChoice::parse("auto").unwrap(), BackendChoice::Auto);
        assert!(BackendChoice::parse("tpu").is_err());
    }

    #[test]
    fn auto_without_artifacts_is_host() {
        let bk = create_backend(BackendChoice::Auto, Path::new("/nonexistent/artifacts")).unwrap();
        assert_eq!(bk.name(), "host");
        assert!(bk.manifest().preset("tiny").is_ok());
    }

    #[test]
    fn batched_adapters_validate() {
        let b0 = Buffer::host_f32(vec![0.0], &[1]);
        let m0 = Buffer::host_f32(vec![1.0], &[1]);
        let m = Manifest::builtin();
        let spec = m.artifact("tiny/eval_fwd_qrlora_cls").unwrap();
        let states = [&b0];
        let masks = [&m0];
        let ok = BatchedAdapters { states: &states, class_masks: &masks, row_slots: &[0, 0] };
        assert!(ok.validate(spec).is_ok());
        let bad_slot = BatchedAdapters { states: &states, class_masks: &masks, row_slots: &[1] };
        assert!(bad_slot.validate(spec).is_err());
        let empty: [&Buffer; 0] = [];
        let none = BatchedAdapters { states: &empty, class_masks: &empty, row_slots: &[] };
        assert!(none.validate(spec).is_err());
        let misaligned = BatchedAdapters { states: &states, class_masks: &empty, row_slots: &[0] };
        assert!(misaligned.validate(spec).is_err());
    }

    #[test]
    fn host_buffer_accessors() {
        let b = Buffer::host_f32(vec![1.0, 2.0], &[2]);
        assert_eq!(b.as_f32().unwrap(), &[1.0, 2.0]);
        assert!(b.as_i32().is_err());
        let i = Buffer::host_i32(vec![3, 4], &[2]);
        assert_eq!(i.as_i32().unwrap(), &[3, 4]);
        assert!(i.as_f32().is_err());
    }
}
