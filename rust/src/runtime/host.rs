//! `HostBackend`: executes the built-in manifest's programs in pure Rust.
//!
//! Each artifact key resolves to a [`HostProgram`] — a small interpreter
//! over the same input/output contract the AOT graphs expose. The heavy
//! math (forward/backward/Adam) lives in `model::host`; this module only
//! unpacks buffers by manifest name, dispatches on artifact kind, and packs
//! the results back into [`Buffer`]s.
//!
//! Two backend-level caches keep the steady state allocation-free:
//!
//! * the **frozen-tensor cache** ([`FrozenCache`]) memoizes the
//!   buffer→`Tensor` conversion of every frozen input (backbone + QR
//!   factors), shared by all of a session's executables — and, on a
//!   backend created with `--quantize-backbone`, holds the backbone
//!   weights int8-quantized (see `crate::quant`), so quantization also
//!   happens once per distinct buffer;
//! * the **resident-adapter cache** ([`AdapterCache`]) memoizes the flat
//!   state→named-trainables unpack of every adapter the serving bank keeps
//!   resident, so mixed-batch inference re-slices nothing per call.
//!
//! Both invalidate by buffer identity + content fingerprint.

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;

use crate::data::HeadKind;
use crate::model::host as hostmodel;
use crate::model::host::{FrozenValue, MethodKind};
use crate::quant::{self, QuantPlan, QuantTensor};
use crate::tensor::Tensor;

use super::backend::{
    execute_batched_grouped, Backend, BatchedAdapters, Buffer, Executable, ExecutableImpl,
    FrozenResidency,
};
use super::manifest::{ArtifactSpec, DType, Manifest, Preset, Role};

/// What a host-interpreted artifact computes.
#[derive(Clone, Debug)]
enum ProgKind {
    PretrainStep,
    /// State → metrics head (pretrain_metrics and metrics_{m}_{h} alike).
    Metrics,
    TrainStep { method: MethodKind, head: HeadKind },
    EvalFwd { method: MethodKind, head: HeadKind },
    KernelBase,
    KernelAdapter,
}

/// A compiled-for-host artifact: parsed kind + preset constants.
pub struct HostProgram {
    kind: ProgKind,
    preset: Preset,
}

/// Frozen-input conversion cache, held by the backend (one per
/// [`HostBackend`]) so every executable of a session — train step, eval
/// forward, metrics — shares a single converted copy of each frozen
/// buffer instead of one per program. Keyed by input name, so the entry
/// count stays bounded by the number of distinct frozen inputs.
///
/// When the backend was created with `quantize = true`, backbone weights
/// (per `quant::plan`) are converted to int8 [`QuantTensor`]s here —
/// **once per distinct buffer** — and the quantized form is what every
/// train/eval/serve step reads. Invalidation keys are unchanged (input
/// name + buffer pointer + length + content fingerprint of the *f32
/// source*); the quantization mode is fixed per backend, so it never
/// participates in the key.
pub(crate) type FrozenCache = RefCell<HashMap<String, FrozenEntry>>;

pub(crate) struct FrozenEntry {
    ptr: usize,
    len: usize,
    fp: u64,
    value: FrozenValue,
}

/// Resident-adapter unpack cache: flat state vector → named trainable
/// tensors (`model::host::unpack_train`), keyed by the state buffer's data
/// pointer. The serving `AdapterBank` keeps its state buffers resident, so
/// pointers are stable and mixed batches hit this cache for every adapter
/// after the first call. Invalidation: pointer + length + a **full**
/// content hash ([`fingerprint_full`] — eviction re-allocates equal-length
/// vectors, so sampled hashing is not safe here), plus the artifact key so
/// a buffer can never be unpacked against the wrong state layout.
pub(crate) type AdapterCache = RefCell<HashMap<usize, AdapterEntry>>;

/// Bound on resident unpack entries; serving banks hold far fewer, so this
/// only guards against unbounded growth from pathological callers. On
/// overflow the cache is cleared wholesale (entries rebuild on next use).
const ADAPTER_CACHE_CAP: usize = 128;

pub(crate) struct AdapterEntry {
    key: String,
    len: usize,
    fp: u64,
    train: hostmodel::AdapterSlot,
}

/// Identity fingerprint for cache invalidation. Buffers at or below
/// `FULL_HASH_LEN` elements (the adapter factors and masks that actually
/// get hot-swapped) are hashed in full, so any single-element change
/// invalidates even if an allocator reuses the freed buffer's address.
/// Larger buffers (the backbone matrices, which are only ever replaced
/// wholesale) are FNV-1a'd over 256 strided samples plus the last element;
/// a same-address same-length reallocation colliding on every sampled
/// value is the remaining — astronomically unlikely for whole-matrix
/// re-uploads — false-hit case.
fn fingerprint(data: &[f32]) -> u64 {
    const FULL_HASH_LEN: usize = 1 << 16;
    let mut h = 0xcbf29ce484222325u64;
    let mut mix = |h: &mut u64, x: u64| {
        *h ^= x;
        *h = h.wrapping_mul(0x100000001b3);
    };
    mix(&mut h, data.len() as u64);
    if data.len() <= FULL_HASH_LEN {
        for v in data {
            mix(&mut h, v.to_bits() as u64);
        }
        return h;
    }
    let step = (data.len() / 256).max(1);
    let mut i = 0;
    while i < data.len() {
        mix(&mut h, data[i].to_bits() as u64);
        i += step;
    }
    if let Some(last) = data.last() {
        mix(&mut h, last.to_bits() as u64);
    }
    h
}

/// Full-content FNV-1a over every element, no sampling. The adapter cache
/// uses this instead of [`fingerprint`]: bank eviction frees and
/// re-allocates equal-length state vectors constantly, so same-pointer
/// same-length reuse is the *common* case there, not the rare one the
/// strided sampler was designed for — a sampled collision would silently
/// serve one task's trainables for another's rows.
fn fingerprint_full(data: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    let mut mix = |h: &mut u64, x: u64| {
        *h ^= x;
        *h = h.wrapping_mul(0x100000001b3);
    };
    mix(&mut h, data.len() as u64);
    for v in data {
        mix(&mut h, v.to_bits() as u64);
    }
    h
}

fn parse_head(s: &str) -> anyhow::Result<HeadKind> {
    Ok(match s {
        "cls" => HeadKind::Cls,
        "reg" => HeadKind::Reg,
        _ => anyhow::bail!("unknown head {s:?}"),
    })
}

fn parse_method_head(rest: &str) -> anyhow::Result<(MethodKind, HeadKind)> {
    let (m, h) = rest
        .rsplit_once('_')
        .ok_or_else(|| anyhow::anyhow!("bad method/head suffix {rest:?}"))?;
    Ok((MethodKind::parse(m)?, parse_head(h)?))
}

/// Name-indexed view of an execute call's arguments.
type ArgMap<'a> = BTreeMap<&'a str, &'a Buffer>;

fn get_buf<'a>(by_name: &ArgMap<'a>, spec_key: &str, name: &str) -> anyhow::Result<&'a Buffer> {
    by_name
        .get(name)
        .copied()
        .ok_or_else(|| anyhow::anyhow!("{spec_key}: missing input {name:?}"))
}

fn get_f32<'a>(by_name: &ArgMap<'a>, spec_key: &str, name: &str) -> anyhow::Result<&'a [f32]> {
    get_buf(by_name, spec_key, name)?.as_f32()
}

fn get_i32<'a>(by_name: &ArgMap<'a>, spec_key: &str, name: &str) -> anyhow::Result<&'a [i32]> {
    get_buf(by_name, spec_key, name)?.as_i32()
}

fn get_tensor(spec: &ArtifactSpec, by_name: &ArgMap, name: &str) -> anyhow::Result<Tensor> {
    let t = spec
        .inputs
        .iter()
        .find(|t| t.name == name)
        .ok_or_else(|| anyhow::anyhow!("{}: no spec entry {name:?}", spec.key))?;
    Ok(Tensor::from_vec(&t.shape, get_f32(by_name, &spec.key, name)?.to_vec()))
}

/// Validate an execute call's arguments against the spec (arity, element
/// count, shape, dtype, host residency) and index them by input name.
fn index_args<'a>(spec: &'a ArtifactSpec, args: &[&'a Buffer]) -> anyhow::Result<ArgMap<'a>> {
    anyhow::ensure!(
        args.len() == spec.inputs.len(),
        "{}: got {} args, expected {}",
        spec.key,
        args.len(),
        spec.inputs.len()
    );
    let mut by_name: BTreeMap<&str, &Buffer> = BTreeMap::new();
    for (t, buf) in spec.inputs.iter().zip(args) {
        if let Buffer::Host { value, shape } = buf {
            anyhow::ensure!(
                value.len() == t.numel(),
                "{}: input {:?} has {} elements, spec wants {}",
                spec.key,
                t.name,
                value.len(),
                t.numel()
            );
            anyhow::ensure!(
                shape == &t.shape,
                "{}: input {:?} has shape {:?}, spec wants {:?}",
                spec.key,
                t.name,
                shape,
                t.shape
            );
            match (t.dtype, value) {
                (DType::F32, super::backend::HostTensor::F32(_)) => {}
                (DType::I32, super::backend::HostTensor::I32(_)) => {}
                _ => anyhow::bail!("{}: input {:?} dtype mismatch", spec.key, t.name),
            }
        } else {
            anyhow::bail!("{}: host backend received a non-host buffer", spec.key);
        }
        by_name.insert(t.name.as_str(), *buf);
    }
    Ok(by_name)
}

/// Materialize the frozen inputs as (cached) tensors — int8-quantized for
/// backbone weights when `quantize` is set. Frozen inputs are converted
/// (and quantized) at most once per distinct buffer: the backend-level
/// cache re-serves the conversion until the buffer's identity/fingerprint
/// changes, so steady-state steps stop copying (and re-quantizing) the
/// backbone.
fn materialize_frozen(
    spec: &ArtifactSpec,
    by_name: &ArgMap,
    frozen_cache: &FrozenCache,
    quantize: bool,
) -> anyhow::Result<hostmodel::FrozenMap> {
    let mut frozen: hostmodel::FrozenMap = BTreeMap::new();
    let mut cache = frozen_cache.borrow_mut();
    for (_, t) in spec.inputs_with_role(Role::Frozen) {
        let data = get_f32(by_name, &spec.key, &t.name)?;
        let ptr = data.as_ptr() as usize;
        let fp = fingerprint(data);
        let hit = matches!(
            cache.get(&t.name),
            Some(e) if e.ptr == ptr && e.len == data.len() && e.fp == fp
        );
        let value = if hit {
            cache.get(&t.name).unwrap().value.clone()
        } else {
            let tensor = Tensor::from_vec(&t.shape, data.to_vec());
            let plan = if quantize { quant::plan(&t.name, &t.shape) } else { QuantPlan::Keep };
            let v = match plan {
                QuantPlan::Keep => FrozenValue::Dense(Rc::new(tensor)),
                QuantPlan::Rows => FrozenValue::QuantRows(Rc::new(QuantTensor::quantize(
                    &tensor,
                    quant::QUANT_GROUP_ROWS,
                ))),
                QuantPlan::Transposed => FrozenValue::QuantProj(Rc::new(QuantTensor::quantize(
                    &tensor.t(),
                    quant::QUANT_GROUP_ROWS,
                ))),
            };
            let entry = FrozenEntry { ptr, len: data.len(), fp, value: v.clone() };
            cache.insert(t.name.clone(), entry);
            v
        };
        frozen.insert(t.name.clone(), value);
    }
    Ok(frozen)
}

/// Unpack the adapter states a batch actually uses (the distinct values of
/// `row_slots`) through the backend's adapter cache; slots the batch does
/// not touch stay `None`, so per-batch hashing/unpacking is proportional
/// to the tasks in the batch, not to the bank's residency.
fn unpack_adapters(
    spec: &ArtifactSpec,
    states: &[&Buffer],
    row_slots: &[usize],
    cache: &AdapterCache,
) -> anyhow::Result<Vec<Option<hostmodel::AdapterSlot>>> {
    let layout = spec.layout()?;
    let mut cache = cache.borrow_mut();
    let mut out: Vec<Option<hostmodel::AdapterSlot>> = vec![None; states.len()];
    for slot in hostmodel::distinct_slots(row_slots) {
        let data = states[slot].as_f32()?;
        anyhow::ensure!(
            data.len() == layout.total,
            "{}: adapter state has {} elements, layout wants {}",
            spec.key,
            data.len(),
            layout.total
        );
        let ptr = data.as_ptr() as usize;
        let fp = fingerprint_full(data);
        let hit = matches!(
            cache.get(&ptr),
            Some(e) if e.key == spec.key && e.len == data.len() && e.fp == fp
        );
        let train = if hit {
            cache.get(&ptr).unwrap().train.clone()
        } else {
            if cache.len() >= ADAPTER_CACHE_CAP {
                cache.clear();
            }
            let tn = Rc::new(hostmodel::unpack_train(data, layout));
            cache.insert(
                ptr,
                AdapterEntry { key: spec.key.clone(), len: data.len(), fp, train: tn.clone() },
            );
            tn
        };
        out[slot] = Some(train);
    }
    Ok(out)
}

impl HostProgram {
    /// Interpret an artifact spec (the host analogue of PJRT compilation).
    pub fn compile(spec: &ArtifactSpec, manifest: &Manifest) -> anyhow::Result<HostProgram> {
        let preset = manifest.preset(&spec.preset)?.clone();
        let kind = match spec.kind.as_str() {
            "pretrain_step" => ProgKind::PretrainStep,
            "pretrain_metrics" => ProgKind::Metrics,
            "kernel_base" => ProgKind::KernelBase,
            "kernel_adapter" => ProgKind::KernelAdapter,
            k if k.starts_with("metrics_") => ProgKind::Metrics,
            k if k.starts_with("train_step_") => {
                let (m, h) = parse_method_head(&k["train_step_".len()..])?;
                ProgKind::TrainStep { method: m, head: h }
            }
            k if k.starts_with("eval_fwd_") => {
                let (m, h) = parse_method_head(&k["eval_fwd_".len()..])?;
                ProgKind::EvalFwd { method: m, head: h }
            }
            other => anyhow::bail!("{}: no host implementation for kind {other:?}", spec.key),
        };
        Ok(HostProgram { kind, preset })
    }

    /// Execute against host buffers; returns outputs in manifest order.
    /// `frozen_cache` is the owning backend's shared frozen-input cache;
    /// `quantize` its backbone-quantization mode (fixed per backend).
    pub fn execute(
        &self,
        spec: &ArtifactSpec,
        args: &[&Buffer],
        frozen_cache: &FrozenCache,
        quantize: bool,
    ) -> anyhow::Result<Vec<Buffer>> {
        let by_name = index_args(spec, args)?;
        let f32s = |name: &str| get_f32(&by_name, &spec.key, name);
        let i32s = |name: &str| get_i32(&by_name, &spec.key, name);
        let tensor_of = |name: &str| get_tensor(spec, &by_name, name);

        match &self.kind {
            ProgKind::Metrics => {
                let state = f32s("state")?;
                let mlen = spec.outputs[0].numel();
                Ok(vec![Buffer::host_f32(state[..mlen].to_vec(), &spec.outputs[0].shape)])
            }
            ProgKind::KernelBase => {
                let x = tensor_of("x")?;
                let w0 = tensor_of("w0")?;
                let y = x.matmul(&w0);
                Ok(vec![Buffer::host_f32(y.data, &spec.outputs[0].shape)])
            }
            ProgKind::KernelAdapter => {
                // y = x·w0 + ((x·Q) ⊙ λ)·R — mirrors kernels/ref.py.
                let x = tensor_of("x")?;
                let w0 = tensor_of("w0")?;
                let q = tensor_of("Q")?;
                let r = tensor_of("R")?;
                let lam = f32s("lam")?;
                let mut y = x.matmul(&w0);
                let mut xq = x.matmul(&q);
                let (rows, cols) = (xq.rows(), xq.cols());
                for i in 0..rows {
                    for j in 0..cols {
                        xq.data[i * cols + j] *= lam[j];
                    }
                }
                y.add_assign(&xq.matmul(&r));
                Ok(vec![Buffer::host_f32(y.data, &spec.outputs[0].shape)])
            }
            ProgKind::PretrainStep => {
                let layout = spec.layout()?;
                let state = f32s("state")?;
                let batch = hostmodel::MlmBatchRef {
                    input_ids: i32s("batch/input_ids")?,
                    type_ids: i32s("batch/type_ids")?,
                    attn_mask: f32s("batch/attn_mask")?,
                    mlm_labels: i32s("batch/mlm_labels")?,
                };
                let lr = f32s("lr")?[0];
                let t = f32s("t")?[0];
                let next = hostmodel::pretrain_step(&self.preset, layout, state, &batch, lr, t);
                Ok(vec![Buffer::host_f32(next, &[layout.total])])
            }
            ProgKind::TrainStep { method, head } | ProgKind::EvalFwd { method, head } => {
                let layout = spec.layout()?;
                let state = f32s("state")?;
                let frozen = materialize_frozen(spec, &by_name, frozen_cache, quantize)?;
                let (labels_i32, labels_f32): (&[i32], &[f32]) = match head {
                    HeadKind::Cls => (i32s("batch/labels")?, &[]),
                    HeadKind::Reg => (&[], f32s("batch/labels")?),
                };
                let batch = hostmodel::TaskBatchRef {
                    input_ids: i32s("batch/input_ids")?,
                    type_ids: i32s("batch/type_ids")?,
                    attn_mask: f32s("batch/attn_mask")?,
                    labels_i32,
                    labels_f32,
                    class_mask: f32s("batch/class_mask")?,
                    example_w: f32s("batch/example_w")?,
                };
                if matches!(self.kind, ProgKind::TrainStep { .. }) {
                    let lr = f32s("lr")?[0];
                    let t = f32s("t")?[0];
                    let next = hostmodel::train_step(
                        &self.preset,
                        *method,
                        *head,
                        layout,
                        state,
                        &frozen,
                        &batch,
                        lr,
                        t,
                    );
                    Ok(vec![Buffer::host_f32(next, &[layout.total])])
                } else {
                    let logits = hostmodel::eval_forward(
                        &self.preset,
                        *method,
                        *head,
                        layout,
                        state,
                        &frozen,
                        &batch,
                    );
                    Ok(vec![Buffer::host_f32(logits, &spec.outputs[0].shape)])
                }
            }
        }
    }

    /// Single-pass mixed-adapter execution of an eval-forward program (the
    /// host fast path behind [`Backend::execute_batched`]): the shared
    /// frozen backbone is evaluated once and each batch row's adapter
    /// delta, task head, and class mask are selected by `row_slots`.
    pub(crate) fn execute_multi(
        &self,
        spec: &ArtifactSpec,
        args: &[&Buffer],
        adapters: &BatchedAdapters<'_>,
        frozen_cache: &FrozenCache,
        adapter_cache: &AdapterCache,
        quantize: bool,
    ) -> anyhow::Result<Vec<Buffer>> {
        let ProgKind::EvalFwd { method, head } = &self.kind else {
            anyhow::bail!("{}: batched execution only supports eval_fwd programs", spec.key);
        };
        let (method, head) = (*method, *head);
        anyhow::ensure!(
            method != MethodKind::Ft,
            "{}: full fine-tuning shares no frozen backbone to batch over",
            spec.key
        );
        anyhow::ensure!(
            adapters.row_slots.len() == self.preset.batch,
            "{}: got {} row slots for batch size {}",
            spec.key,
            adapters.row_slots.len(),
            self.preset.batch
        );
        let by_name = index_args(spec, args)?;
        let frozen = materialize_frozen(spec, &by_name, frozen_cache, quantize)?;
        let slots = unpack_adapters(spec, adapters.states, adapters.row_slots, adapter_cache)?;

        let mask_len = spec
            .inputs
            .iter()
            .find(|t| t.name == "batch/class_mask")
            .map(|t| t.numel())
            .ok_or_else(|| anyhow::anyhow!("{}: no batch/class_mask input", spec.key))?;
        let mut masks: Vec<&[f32]> = Vec::with_capacity(adapters.class_masks.len());
        for buf in adapters.class_masks {
            let m = buf.as_f32()?;
            anyhow::ensure!(
                m.len() == mask_len,
                "{}: adapter class mask has {} elements, spec wants {mask_len}",
                spec.key,
                m.len()
            );
            masks.push(m);
        }

        let f32s = |name: &str| get_f32(&by_name, &spec.key, name);
        let i32s = |name: &str| get_i32(&by_name, &spec.key, name);
        let (labels_i32, labels_f32): (&[i32], &[f32]) = match head {
            HeadKind::Cls => (i32s("batch/labels")?, &[]),
            HeadKind::Reg => (&[], f32s("batch/labels")?),
        };
        let batch = hostmodel::TaskBatchRef {
            input_ids: i32s("batch/input_ids")?,
            type_ids: i32s("batch/type_ids")?,
            attn_mask: f32s("batch/attn_mask")?,
            labels_i32,
            labels_f32,
            // Placeholder from the arg list; the multi path masks per row
            // from `masks` instead.
            class_mask: f32s("batch/class_mask")?,
            example_w: f32s("batch/example_w")?,
        };
        let logits = hostmodel::eval_forward_multi(
            &self.preset,
            method,
            head,
            &slots,
            &masks,
            adapters.row_slots,
            &frozen,
            &batch,
        );
        Ok(vec![Buffer::host_f32(logits, &spec.outputs[0].shape)])
    }
}

/// Pure-Rust execution backend over the built-in manifest.
pub struct HostBackend {
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
    /// Shared frozen-input tensor cache (see [`FrozenCache`]): one copy of
    /// the backbone per backend, not per loaded executable.
    frozen_cache: FrozenCache,
    /// Resident-adapter unpack cache (see [`AdapterCache`]) for the
    /// batched serving path.
    adapter_cache: AdapterCache,
    /// Whether the frozen cache holds backbone weights as int8
    /// [`QuantTensor`]s (`--quantize-backbone` / `QRLORA_QUANT`). Fixed
    /// for the backend's lifetime, so it is not part of any cache key.
    quant: bool,
}

impl HostBackend {
    /// Create a backend over the built-in manifest with empty caches.
    pub fn new() -> HostBackend {
        HostBackend::with_quant(false)
    }

    /// Like [`HostBackend::new`] but with the frozen backbone held int8.
    pub fn new_quantized() -> HostBackend {
        HostBackend::with_quant(true)
    }

    /// Create a backend with an explicit backbone-quantization mode.
    pub fn with_quant(quant: bool) -> HostBackend {
        HostBackend {
            manifest: Manifest::builtin(),
            cache: RefCell::new(HashMap::new()),
            frozen_cache: RefCell::new(HashMap::new()),
            adapter_cache: RefCell::new(HashMap::new()),
            quant,
        }
    }

    /// True when the frozen backbone is held int8.
    pub fn quantized(&self) -> bool {
        self.quant
    }
}

impl Default for HostBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for HostBackend {
    fn name(&self) -> &'static str {
        "host"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load(&self, key: &str) -> anyhow::Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(key) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(key)?.clone();
        let prog = HostProgram::compile(&spec, &self.manifest)?;
        let e = Rc::new(Executable { spec, imp: ExecutableImpl::Host(prog) });
        self.cache.borrow_mut().insert(key.to_string(), e.clone());
        Ok(e)
    }

    fn execute(&self, exe: &Executable, args: &[&Buffer]) -> anyhow::Result<Vec<Buffer>> {
        match &exe.imp {
            ExecutableImpl::Host(prog) => {
                prog.execute(&exe.spec, args, &self.frozen_cache, self.quant)
            }
            #[cfg(feature = "pjrt")]
            ExecutableImpl::Pjrt(_) => {
                anyhow::bail!("{}: PJRT executable handed to host backend", exe.spec.key)
            }
        }
    }

    fn execute_batched(
        &self,
        exe: &Executable,
        args: &[&Buffer],
        adapters: &BatchedAdapters<'_>,
    ) -> anyhow::Result<Vec<Buffer>> {
        let prog = match &exe.imp {
            ExecutableImpl::Host(p) => p,
            #[cfg(feature = "pjrt")]
            ExecutableImpl::Pjrt(_) => {
                anyhow::bail!("{}: PJRT executable handed to host backend", exe.spec.key)
            }
        };
        adapters.validate(&exe.spec)?;
        match &prog.kind {
            // Single-pass fast path: one shared backbone evaluation,
            // per-row adapter deltas/heads. Full fine-tuning shares no
            // backbone, so it degrades to the grouped fallback below.
            ProgKind::EvalFwd { method, .. } if *method != MethodKind::Ft => prog.execute_multi(
                &exe.spec,
                args,
                adapters,
                &self.frozen_cache,
                &self.adapter_cache,
                self.quant,
            ),
            _ => execute_batched_grouped(self, exe, args, adapters),
        }
    }

    /// Footprint of the converted frozen inputs currently cached, split
    /// into backbone weights (quantizable per `quant::plan`) and the f32
    /// remainder. With quantization on, the backbone portion reports the
    /// int8-values-plus-scales residency against its f32 equivalent.
    fn frozen_residency(&self) -> Option<FrozenResidency> {
        let cache = self.frozen_cache.borrow();
        let mut r = FrozenResidency::default();
        for (name, e) in cache.iter() {
            match &e.value {
                FrozenValue::Dense(t) => {
                    let bytes = t.numel() * 4;
                    if quant::plan(name, &t.shape) == QuantPlan::Keep {
                        r.other_bytes += bytes;
                    } else {
                        r.backbone_f32_bytes += bytes;
                        r.backbone_resident_bytes += bytes;
                    }
                }
                FrozenValue::QuantProj(q) | FrozenValue::QuantRows(q) => {
                    r.backbone_f32_bytes += q.f32_bytes();
                    r.backbone_resident_bytes += q.resident_bytes();
                }
            }
        }
        Some(r)
    }

    fn backbone_repr(&self) -> &'static str {
        if self.quant {
            "int8"
        } else {
            "f32"
        }
    }

    fn upload_f32(&self, data: &[f32], shape: &[usize]) -> anyhow::Result<Buffer> {
        Ok(Buffer::host_f32(data.to_vec(), shape))
    }

    fn upload_i32(&self, data: &[i32], shape: &[usize]) -> anyhow::Result<Buffer> {
        Ok(Buffer::host_i32(data.to_vec(), shape))
    }

    fn download_f32(&self, buf: &Buffer) -> anyhow::Result<Vec<f32>> {
        Ok(buf.as_f32()?.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn kernel_base_matches_tensor_matmul() {
        let bk = HostBackend::new();
        let exe = bk.load("tiny/kernel_base").unwrap();
        let (m, k) = (exe.spec.inputs[0].shape[0], exe.spec.inputs[0].shape[1]);
        let n = exe.spec.inputs[1].shape[1];
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[m, k], &mut rng, 1.0);
        let w = Tensor::randn(&[k, n], &mut rng, 0.5);
        let xb = bk.upload_f32(&x.data, &[m, k]).unwrap();
        let wb = bk.upload_f32(&w.data, &[k, n]).unwrap();
        let outs = bk.execute(&exe, &[&xb, &wb]).unwrap();
        let got = Tensor::from_vec(&[m, n], bk.download_f32(&outs[0]).unwrap());
        assert!(got.max_abs_diff(&x.matmul(&w)) < 1e-4);
    }

    #[test]
    fn arity_and_dtype_checked() {
        let bk = HostBackend::new();
        let exe = bk.load("tiny/kernel_base").unwrap();
        let x = bk.upload_f32(&[0.0], &[1]).unwrap();
        assert!(bk.execute(&exe, &[&x]).is_err()); // wrong arity
        let spec = &exe.spec;
        let bad = bk
            .upload_i32(&vec![0; spec.inputs[0].numel()], &spec.inputs[0].shape)
            .unwrap();
        let w = bk
            .upload_f32(&vec![0.0; spec.inputs[1].numel()], &spec.inputs[1].shape)
            .unwrap();
        assert!(bk.execute(&exe, &[&bad, &w]).is_err()); // dtype mismatch
    }

    #[test]
    fn unknown_key_errors() {
        let bk = HostBackend::new();
        assert!(bk.load("tiny/nope").is_err());
    }
}
