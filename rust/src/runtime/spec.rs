//! Built-in artifact specs: the pure-Rust mirror of
//! `python/compile/presets.py` + the spec builders in
//! `python/compile/model.py`.
//!
//! The PJRT path learns shapes from `artifacts/manifest.json`, written by
//! `aot.py` from these same builders. The host backend has no artifacts
//! directory, so [`builtin_manifest`] regenerates the identical contract —
//! preset constants, input/output tensor lists, and the flat state-vector
//! layout `[ metrics | params | adam_m | adam_v ]` — entirely in Rust. The
//! two sides can only drift if this file and `model.py` disagree, which the
//! feature-gated parity tests in `rust/tests/runtime_smoke.rs` guard.

use std::collections::BTreeMap;

use super::manifest::{
    ArtifactSpec, DType, Manifest, Preset, Role, StateField, StateLayout, TensorSpec,
};

/// Methods and heads every preset lowers step programs for.
pub const METHODS: [&str; 3] = ["ft", "lora", "qrlora"];
pub const HEADS: [&str; 2] = ["cls", "reg"];

/// Preset constants (mirrors `presets.py::PRESETS`).
pub fn builtin_presets() -> BTreeMap<String, Preset> {
    let mk = |name: &str,
              d_model,
              n_layers,
              n_heads,
              d_ff,
              vocab,
              max_seq,
              batch,
              r_max,
              r_lora,
              n_classes| Preset {
        name: name.to_string(),
        d_model,
        n_layers,
        n_heads,
        d_ff,
        vocab,
        max_seq,
        batch,
        r_max,
        r_lora,
        n_classes,
    };
    let mut m = BTreeMap::new();
    m.insert("tiny".to_string(), mk("tiny", 64, 2, 2, 256, 512, 32, 8, 32, 2, 3));
    m.insert("small".to_string(), mk("small", 128, 4, 4, 512, 4096, 64, 32, 64, 2, 3));
    m.insert("mid".to_string(), mk("mid", 256, 6, 8, 1024, 8192, 64, 16, 128, 2, 3));
    m
}

/// (name, shape) pair — the unit of the spec lists.
type NamedShape = (String, Vec<usize>);

/// Ordered backbone parameter list (mirrors `model.py::backbone_specs`).
pub fn backbone_specs(p: &Preset) -> Vec<NamedShape> {
    let (d, f, v, s) = (p.d_model, p.d_ff, p.vocab, p.max_seq);
    let mut specs: Vec<NamedShape> = vec![
        ("emb/tok".into(), vec![v, d]),
        ("emb/pos".into(), vec![s, d]),
        ("emb/type".into(), vec![2, d]),
        ("emb/ln_g".into(), vec![d]),
        ("emb/ln_b".into(), vec![d]),
    ];
    for i in 0..p.n_layers {
        for proj in ["wq", "wk", "wv", "wo"] {
            specs.push((format!("layer{i}/attn/{proj}"), vec![d, d]));
        }
        for bias in ["bq", "bk", "bv", "bo"] {
            specs.push((format!("layer{i}/attn/{bias}"), vec![d]));
        }
        specs.push((format!("layer{i}/ln1_g"), vec![d]));
        specs.push((format!("layer{i}/ln1_b"), vec![d]));
        specs.push((format!("layer{i}/ffn/w1"), vec![d, f]));
        specs.push((format!("layer{i}/ffn/b1"), vec![f]));
        specs.push((format!("layer{i}/ffn/w2"), vec![f, d]));
        specs.push((format!("layer{i}/ffn/b2"), vec![d]));
        specs.push((format!("layer{i}/ln2_g"), vec![d]));
        specs.push((format!("layer{i}/ln2_b"), vec![d]));
    }
    specs.push(("mlm/bias".into(), vec![v]));
    specs
}

/// Task-head parameters (mirrors `model.py::head_specs`).
pub fn head_specs(p: &Preset, head: &str) -> Vec<NamedShape> {
    let d = p.d_model;
    let k = if head == "cls" { p.n_classes } else { 1 };
    vec![
        ("head/wp".into(), vec![d, d]),
        ("head/bp".into(), vec![d]),
        ("head/wc".into(), vec![d, k]),
        ("head/bc".into(), vec![k]),
    ]
}

/// (trainable λ, frozen Q/R/mask) specs for QR-LoRA.
pub fn qr_adapter_specs(p: &Preset) -> (Vec<NamedShape>, Vec<NamedShape>) {
    let (d, r) = (p.d_model, p.r_max);
    let mut train = Vec::new();
    let mut frozen = Vec::new();
    for i in 0..p.n_layers {
        for proj in ["wq", "wk", "wv", "wo"] {
            let base = format!("qr/layer{i}/{proj}");
            train.push((format!("{base}/lam"), vec![r]));
            frozen.push((format!("{base}/Q"), vec![d, r]));
            frozen.push((format!("{base}/R"), vec![r, d]));
            frozen.push((format!("{base}/mask"), vec![r]));
        }
    }
    (train, frozen)
}

/// (trainable A/B, frozen scale) specs for LoRA / SVD-LoRA.
pub fn lora_adapter_specs(p: &Preset) -> (Vec<NamedShape>, Vec<NamedShape>) {
    let (d, r) = (p.d_model, p.r_lora);
    let mut train = Vec::new();
    let mut frozen = Vec::new();
    for i in 0..p.n_layers {
        for proj in ["wq", "wv"] {
            let base = format!("lora/layer{i}/{proj}");
            train.push((format!("{base}/A"), vec![d, r]));
            train.push((format!("{base}/B"), vec![r, d]));
            frozen.push((format!("{base}/scale"), vec![r]));
        }
    }
    (train, frozen)
}

/// (trainable, frozen) parameter split for a finetune graph.
pub fn split_specs(p: &Preset, method: &str, head: &str) -> (Vec<NamedShape>, Vec<NamedShape>) {
    let bb = backbone_specs(p);
    let hd = head_specs(p, head);
    match method {
        "ft" => {
            let mut t = bb;
            t.extend(hd);
            (t, Vec::new())
        }
        "lora" => {
            let (mut at, af) = lora_adapter_specs(p);
            at.extend(hd);
            let mut f = bb;
            f.extend(af);
            (at, f)
        }
        "qrlora" => {
            let (mut at, af) = qr_adapter_specs(p);
            at.extend(hd);
            let mut f = bb;
            f.extend(af);
            (at, f)
        }
        other => panic!("unknown method {other:?}"),
    }
}

/// Per-step batch tensors for task training/eval.
pub fn batch_specs(p: &Preset, head: &str) -> Vec<(String, Vec<usize>, DType)> {
    let (b, s) = (p.batch, p.max_seq);
    let k = if head == "cls" { p.n_classes } else { 1 };
    let label_dtype = if head == "cls" { DType::I32 } else { DType::F32 };
    vec![
        ("batch/input_ids".into(), vec![b, s], DType::I32),
        ("batch/type_ids".into(), vec![b, s], DType::I32),
        ("batch/attn_mask".into(), vec![b, s], DType::F32),
        ("batch/labels".into(), vec![b], label_dtype),
        ("batch/class_mask".into(), vec![k], DType::F32),
        ("batch/example_w".into(), vec![b], DType::F32),
    ]
}

/// Per-step batch tensors for MLM pretraining.
pub fn mlm_batch_specs(p: &Preset) -> Vec<(String, Vec<usize>, DType)> {
    let (b, s) = (p.batch, p.max_seq);
    vec![
        ("batch/input_ids".into(), vec![b, s], DType::I32),
        ("batch/type_ids".into(), vec![b, s], DType::I32),
        ("batch/attn_mask".into(), vec![b, s], DType::F32),
        ("batch/mlm_labels".into(), vec![b, s], DType::I32),
    ]
}

fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

/// Flat state-vector layout (mirrors `model.py::state_layout`):
/// `[ metrics | params (P) | adam_m (P) | adam_v (P) ]`.
pub fn state_layout(t_specs: &[NamedShape], metric_specs: &[NamedShape]) -> StateLayout {
    let mut metrics = Vec::new();
    let mut off = 0usize;
    for (n, s) in metric_specs {
        metrics.push(StateField { name: n.clone(), shape: s.clone(), offset: off });
        off += numel(s);
    }
    let metrics_len = off;
    let mut params = Vec::new();
    for (n, s) in t_specs {
        params.push(StateField { name: n.clone(), shape: s.clone(), offset: off });
        off += numel(s);
    }
    let n_params = off - metrics_len;
    StateLayout {
        n_params,
        metrics_len,
        total: metrics_len + 3 * n_params,
        params,
        metrics,
    }
}

fn tensor(name: &str, shape: &[usize], dtype: DType, role: Role) -> TensorSpec {
    TensorSpec {
        name: name.to_string(),
        shape: shape.to_vec(),
        dtype,
        role,
    }
}

fn scalar(name: &str) -> TensorSpec {
    tensor(name, &[], DType::F32, Role::Scalar)
}

/// Inputs for a train/eval step: state, frozen, batch (+ scalars for train).
fn step_inputs(
    layout: &StateLayout,
    f_specs: &[NamedShape],
    b_specs: &[(String, Vec<usize>, DType)],
    with_scalars: bool,
) -> Vec<TensorSpec> {
    let mut inputs = vec![tensor("state", &[layout.total], DType::F32, Role::State)];
    for (n, s) in f_specs {
        inputs.push(tensor(n, s, DType::F32, Role::Frozen));
    }
    for (n, s, d) in b_specs {
        inputs.push(tensor(n, s, *d, Role::Batch));
    }
    if with_scalars {
        inputs.push(scalar("lr"));
        inputs.push(scalar("t"));
    }
    inputs
}

fn artifact(
    preset: &str,
    kind: &str,
    inputs: Vec<TensorSpec>,
    outputs: Vec<TensorSpec>,
    layout: Option<StateLayout>,
) -> (String, ArtifactSpec) {
    let key = format!("{preset}/{kind}");
    (
        key.clone(),
        ArtifactSpec {
            key,
            // Host programs are synthesized, not loaded from disk.
            file: String::new(),
            preset: preset.to_string(),
            kind: kind.to_string(),
            inputs,
            outputs,
            state_layout: layout,
        },
    )
}

/// The full built-in manifest: every artifact `aot.py` would lower, for
/// every built-in preset, with identical keys, shapes, roles, and layouts.
pub fn builtin_manifest() -> Manifest {
    let presets = builtin_presets();
    let mut artifacts = BTreeMap::new();

    for p in presets.values() {
        let name = p.name.as_str();
        let metrics_out = |layout: &StateLayout| {
            vec![tensor("metrics", &[layout.metrics_len], DType::F32, Role::Metric)]
        };
        let state_in = |layout: &StateLayout| {
            vec![tensor("state", &[layout.total], DType::F32, Role::State)]
        };
        let state_out = |layout: &StateLayout| {
            vec![tensor("state", &[layout.total], DType::F32, Role::State)]
        };

        // --- pretrain ---------------------------------------------------
        let bb = backbone_specs(p);
        let pre_layout = state_layout(&bb, &[("loss".into(), vec![])]);
        let (k, a) = artifact(
            name,
            "pretrain_step",
            step_inputs(&pre_layout, &[], &mlm_batch_specs(p), true),
            state_out(&pre_layout),
            Some(pre_layout.clone()),
        );
        artifacts.insert(k, a);
        let (k, a) = artifact(
            name,
            "pretrain_metrics",
            state_in(&pre_layout),
            metrics_out(&pre_layout),
            Some(pre_layout.clone()),
        );
        artifacts.insert(k, a);

        // --- finetune steps ----------------------------------------------
        for method in METHODS {
            for head in HEADS {
                let (t_specs, f_specs) = split_specs(p, method, head);
                let kk = if head == "cls" { p.n_classes } else { 1 };
                let metric_specs: Vec<NamedShape> =
                    vec![("loss".into(), vec![]), ("logits".into(), vec![p.batch, kk])];
                let layout = state_layout(&t_specs, &metric_specs);
                let b_specs = batch_specs(p, head);

                let (key, a) = artifact(
                    name,
                    &format!("train_step_{method}_{head}"),
                    step_inputs(&layout, &f_specs, &b_specs, true),
                    state_out(&layout),
                    Some(layout.clone()),
                );
                artifacts.insert(key, a);

                let (key, a) = artifact(
                    name,
                    &format!("metrics_{method}_{head}"),
                    state_in(&layout),
                    metrics_out(&layout),
                    Some(layout.clone()),
                );
                artifacts.insert(key, a);

                let (key, a) = artifact(
                    name,
                    &format!("eval_fwd_{method}_{head}"),
                    step_inputs(&layout, &f_specs, &b_specs, false),
                    vec![tensor("logits", &[p.batch, kk], DType::F32, Role::Metric)],
                    Some(layout),
                );
                artifacts.insert(key, a);
            }
        }

        // --- kernel micro-artifacts --------------------------------------
        let mm = p.batch * p.max_seq;
        let (d, r) = (p.d_model, p.r_max);
        let (key, a) = artifact(
            name,
            "kernel_base",
            vec![
                tensor("x", &[mm, d], DType::F32, Role::Batch),
                tensor("w0", &[d, d], DType::F32, Role::Frozen),
            ],
            vec![tensor("y", &[mm, d], DType::F32, Role::Metric)],
            None,
        );
        artifacts.insert(key, a);
        let (key, a) = artifact(
            name,
            "kernel_adapter",
            vec![
                tensor("x", &[mm, d], DType::F32, Role::Batch),
                tensor("w0", &[d, d], DType::F32, Role::Frozen),
                tensor("Q", &[d, r], DType::F32, Role::Frozen),
                tensor("R", &[r, d], DType::F32, Role::Frozen),
                tensor("lam", &[r], DType::F32, Role::Train),
            ],
            vec![tensor("y", &[mm, d], DType::F32, Role::Metric)],
            None,
        );
        artifacts.insert(key, a);
    }

    Manifest { presets, artifacts }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_manifest_has_expected_keys() {
        let m = builtin_manifest();
        for key in [
            "tiny/pretrain_step",
            "tiny/pretrain_metrics",
            "tiny/train_step_ft_cls",
            "tiny/train_step_lora_cls",
            "tiny/train_step_qrlora_cls",
            "tiny/train_step_qrlora_reg",
            "tiny/metrics_qrlora_cls",
            "tiny/eval_fwd_qrlora_cls",
            "tiny/kernel_base",
            "tiny/kernel_adapter",
            "small/train_step_qrlora_cls",
            "mid/pretrain_step",
        ] {
            assert!(m.artifacts.contains_key(key), "missing {key}");
        }
        assert_eq!(m.presets["tiny"].d_model, 64);
        assert_eq!(m.presets["small"].n_layers, 4);
    }

    #[test]
    fn layout_invariants() {
        let m = builtin_manifest();
        for (key, a) in &m.artifacts {
            if let Some(l) = &a.state_layout {
                assert_eq!(l.total, l.metrics_len + 3 * l.n_params, "{key}");
                // param offsets are contiguous from metrics_len
                let mut off = l.metrics_len;
                for f in &l.params {
                    assert_eq!(f.offset, off, "{key}: {}", f.name);
                    off += f.numel();
                }
                assert_eq!(off - l.metrics_len, l.n_params, "{key}");
            }
        }
    }

    #[test]
    fn train_and_eval_share_layout() {
        let m = builtin_manifest();
        for method in METHODS {
            let tr = m.artifacts[&format!("tiny/train_step_{method}_cls")]
                .state_layout
                .as_ref()
                .unwrap();
            let ev = m.artifacts[&format!("tiny/eval_fwd_{method}_cls")]
                .state_layout
                .as_ref()
                .unwrap();
            assert_eq!(tr.total, ev.total, "{method}");
        }
    }

    #[test]
    fn qrlora_trainables_are_lambdas_and_head() {
        let m = builtin_manifest();
        let l = m.artifacts["tiny/train_step_qrlora_cls"].state_layout.as_ref().unwrap();
        // 2 layers × 4 projections λ(r_max=32) + head (64·64 + 64 + 64·3 + 3)
        assert_eq!(l.n_params, 2 * 4 * 32 + 64 * 64 + 64 + 64 * 3 + 3);
        assert!(l.params.iter().all(|f| f.name.contains("/lam") || f.name.starts_with("head/")));
    }

    #[test]
    fn frozen_inputs_cover_backbone_and_factors() {
        let m = builtin_manifest();
        let a = &m.artifacts["tiny/train_step_qrlora_cls"];
        let frozen: Vec<&str> = a
            .inputs_with_role(Role::Frozen)
            .map(|(_, t)| t.name.as_str())
            .collect();
        assert!(frozen.contains(&"emb/tok"));
        assert!(frozen.contains(&"layer1/attn/wo"));
        assert!(frozen.contains(&"qr/layer0/wq/Q"));
        assert!(frozen.contains(&"qr/layer1/wo/mask"));
        // batch + scalars present, in aot order (state first, scalars last)
        assert_eq!(a.inputs[0].role, Role::State);
        assert_eq!(a.inputs[a.inputs.len() - 2].name, "lr");
        assert_eq!(a.inputs[a.inputs.len() - 1].name, "t");
    }
}
