//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. Parsed from `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::Json;

/// Element dtype of a device tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> anyhow::Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            _ => anyhow::bail!("unknown dtype {s:?}"),
        }
    }
}

/// Role of an input/output in the step protocol. Determines buffer
/// lifecycle: `Train`/`OptM`/`OptV` outputs alias back onto the same-named
/// inputs of the next step; `Frozen` is uploaded once; `Batch`/`Scalar`
/// re-upload per step; `Metric` outputs are copied to host.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// The flat state vector (arg0/out0 of every step program).
    State,
    Train,
    Frozen,
    Batch,
    Scalar,
    Metric,
}

impl Role {
    fn parse(s: &str) -> anyhow::Result<Role> {
        Ok(match s {
            "state" => Role::State,
            "train" => Role::Train,
            "frozen" => Role::Frozen,
            "batch" => Role::Batch,
            "scalar" => Role::Scalar,
            "metric" => Role::Metric,
            _ => anyhow::bail!("unknown role {s:?}"),
        })
    }
}

/// One named region of the flat state vector.
#[derive(Clone, Debug)]
pub struct StateField {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

impl StateField {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> anyhow::Result<StateField> {
        Ok(StateField {
            name: j.req("name")?.as_str().unwrap_or_default().to_string(),
            shape: j
                .req("shape")?
                .as_arr()
                .unwrap_or_default()
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect(),
            offset: j
                .req("offset")?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("bad offset"))?,
        })
    }
}

/// Layout of the flat state vector:
/// `[ metrics | params (P) | adam_m (P) | adam_v (P) ]`.
/// Metrics sit at offset 0 so they can be read with a ranged host copy
/// (the buffer API's bounds check makes nonzero offsets unusable).
#[derive(Clone, Debug)]
pub struct StateLayout {
    pub n_params: usize,
    pub metrics_len: usize,
    pub total: usize,
    pub params: Vec<StateField>,
    pub metrics: Vec<StateField>,
}

impl StateLayout {
    pub fn param(&self, name: &str) -> anyhow::Result<&StateField> {
        self.params
            .iter()
            .find(|f| f.name == name)
            .ok_or_else(|| anyhow::anyhow!("state param {name:?} not in layout"))
    }

    pub fn metric(&self, name: &str) -> anyhow::Result<&StateField> {
        self.metrics
            .iter()
            .find(|f| f.name == name)
            .ok_or_else(|| anyhow::anyhow!("state metric {name:?} not in layout"))
    }

    /// Offset of the params region (= metrics_len).
    pub fn params_offset(&self) -> usize {
        self.metrics_len
    }

    fn parse(j: &Json) -> anyhow::Result<StateLayout> {
        let fields = |key: &str| -> anyhow::Result<Vec<StateField>> {
            j.req(key)?
                .as_arr()
                .unwrap_or_default()
                .iter()
                .map(StateField::parse)
                .collect()
        };
        Ok(StateLayout {
            n_params: j
                .req("n_params")?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("bad n_params"))?,
            metrics_len: j
                .req("metrics_len")?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("bad metrics_len"))?,
            total: j
                .req("total")?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("bad total"))?,
            params: fields("params")?,
            metrics: fields("metrics")?,
        })
    }
}

/// One named tensor in an artifact's input or output list.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub role: Role,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> anyhow::Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.req("name")?.as_str().unwrap_or_default().to_string(),
            shape: j
                .req("shape")?
                .as_arr()
                .unwrap_or_default()
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect(),
            dtype: DType::parse(j.req("dtype")?.as_str().unwrap_or(""))?,
            role: Role::parse(j.req("role")?.as_str().unwrap_or(""))?,
        })
    }
}

/// One AOT-lowered executable.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub key: String,
    pub file: String,
    pub preset: String,
    pub kind: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Present on step programs (train/pretrain/eval).
    pub state_layout: Option<StateLayout>,
}

impl ArtifactSpec {
    pub fn layout(&self) -> anyhow::Result<&StateLayout> {
        self.state_layout
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("{}: no state layout", self.key))
    }
}

impl ArtifactSpec {
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|t| t.name == name)
    }

    pub fn inputs_with_role(&self, role: Role) -> impl Iterator<Item = (usize, &TensorSpec)> {
        self.inputs
            .iter()
            .enumerate()
            .filter(move |(_, t)| t.role == role)
    }
}

/// Model architecture constants for a preset (mirrors python presets.py).
#[derive(Clone, Debug)]
pub struct Preset {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub batch: usize,
    pub r_max: usize,
    pub r_lora: usize,
    pub n_classes: usize,
}

impl Preset {
    /// Approximate backbone parameter count (embeddings + encoder + mlm
    /// bias) — mirrors python presets.n_backbone_params.
    pub fn approx_backbone_params(p: &Preset) -> usize {
        let (d, f, v, s, nl) = (p.d_model, p.d_ff, p.vocab, p.max_seq, p.n_layers);
        let emb = v * d + s * d + 2 * d + 2 * d;
        let per_layer = 4 * (d * d + d) + 2 * d + (d * f + f) + (f * d + d) + 2 * d;
        emb + nl * per_layer + v
    }

    fn parse(name: &str, j: &Json) -> anyhow::Result<Preset> {
        let get = |k: &str| -> anyhow::Result<usize> {
            j.req(k)?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("preset {name}: bad {k}"))
        };
        Ok(Preset {
            name: name.to_string(),
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            d_ff: get("d_ff")?,
            vocab: get("vocab")?,
            max_seq: get("max_seq")?,
            batch: get("batch")?,
            r_max: get("r_max")?,
            r_lora: get("r_lora")?,
            n_classes: get("n_classes")?,
        })
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub presets: BTreeMap<String, Preset>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// The built-in manifest (host backend): identical presets, artifact
    /// keys, shapes, and state layouts to what `aot.py` writes, synthesized
    /// in pure Rust by `runtime::spec`.
    pub fn builtin() -> Manifest {
        super::spec::builtin_manifest()
    }

    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {path:?}: {e}. Run `make artifacts`."))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> anyhow::Result<Manifest> {
        let j = Json::parse(text)?;
        let mut presets = BTreeMap::new();
        for (name, pj) in j.req("presets")?.as_obj().unwrap_or(&[]) {
            presets.insert(name.clone(), Preset::parse(name, pj)?);
        }
        let mut artifacts = BTreeMap::new();
        for (key, aj) in j.req("artifacts")?.as_obj().unwrap_or(&[]) {
            let inputs = aj
                .req("inputs")?
                .as_arr()
                .unwrap_or_default()
                .iter()
                .map(TensorSpec::parse)
                .collect::<anyhow::Result<Vec<_>>>()?;
            let outputs = aj
                .req("outputs")?
                .as_arr()
                .unwrap_or_default()
                .iter()
                .map(TensorSpec::parse)
                .collect::<anyhow::Result<Vec<_>>>()?;
            let state_layout = match aj.get("state_layout") {
                Some(lj) => Some(StateLayout::parse(lj)?),
                None => None,
            };
            artifacts.insert(
                key.clone(),
                ArtifactSpec {
                    key: key.clone(),
                    file: aj.req("file")?.as_str().unwrap_or("").to_string(),
                    preset: aj.req("preset")?.as_str().unwrap_or("").to_string(),
                    kind: aj.req("kind")?.as_str().unwrap_or("").to_string(),
                    inputs,
                    outputs,
                    state_layout,
                },
            );
        }
        Ok(Manifest { presets, artifacts })
    }

    pub fn artifact(&self, key: &str) -> anyhow::Result<&ArtifactSpec> {
        self.artifacts.get(key).ok_or_else(|| {
            anyhow::anyhow!("artifact {key:?} not in manifest (run `make artifacts`)")
        })
    }

    pub fn preset(&self, name: &str) -> anyhow::Result<&Preset> {
        self.presets
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("preset {name:?} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "presets": {"tiny": {"d_model": 64, "n_layers": 2, "n_heads": 2,
        "d_ff": 256, "vocab": 512, "max_seq": 32, "batch": 8,
        "r_max": 32, "r_lora": 2, "n_classes": 3}},
      "adam": {"b1": 0.9, "b2": 0.999, "eps": 1e-8},
      "artifacts": {
        "tiny/eval": {"file": "tiny_eval.hlo.txt", "preset": "tiny",
          "kind": "eval",
          "inputs": [
            {"name": "w", "shape": [64, 64], "dtype": "f32", "role": "train"},
            {"name": "ids", "shape": [8, 32], "dtype": "i32", "role": "batch"},
            {"name": "lr", "shape": [], "dtype": "f32", "role": "scalar"}],
          "outputs": [
            {"name": "logits", "shape": [8, 3], "dtype": "f32", "role": "metric"}]
        }}}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let p = m.preset("tiny").unwrap();
        assert_eq!(p.d_model, 64);
        assert_eq!(p.batch, 8);
        let a = m.artifact("tiny/eval").unwrap();
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[1].dtype, DType::I32);
        assert_eq!(a.inputs[2].shape.len(), 0);
        assert_eq!(a.inputs[2].numel(), 1);
        assert_eq!(a.outputs[0].role, Role::Metric);
        assert_eq!(a.input_index("ids"), Some(1));
    }

    #[test]
    fn missing_artifact_errors() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.artifact("nope").is_err());
        assert!(m.preset("nope").is_err());
    }

    #[test]
    fn role_filtering() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.artifact("tiny/eval").unwrap();
        let batch: Vec<_> = a.inputs_with_role(Role::Batch).collect();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].1.name, "ids");
    }
}
