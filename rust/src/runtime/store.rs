//! Named buffer store.
//!
//! A `BufferStore` holds the backend-resident state of one training/eval
//! session keyed by manifest tensor names. The training loop binds an
//! artifact's input list against the store, runs the step, then writes the
//! `state`/`train`/`frozen` outputs back under the same names — params
//! never leave the backend between steps.

use std::collections::HashMap;

use super::backend::{Backend, Buffer, HostTensor};
use super::manifest::{ArtifactSpec, DType, Role, TensorSpec};

/// Named backend buffers.
#[derive(Default)]
pub struct BufferStore {
    bufs: HashMap<String, Buffer>,
}

impl BufferStore {
    pub fn new() -> BufferStore {
        BufferStore { bufs: HashMap::new() }
    }

    pub fn contains(&self, name: &str) -> bool {
        self.bufs.contains_key(name)
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&Buffer> {
        self.bufs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("buffer {name:?} not in store"))
    }

    pub fn insert(&mut self, name: impl Into<String>, buf: Buffer) {
        self.bufs.insert(name.into(), buf);
    }

    pub fn remove(&mut self, name: &str) -> Option<Buffer> {
        self.bufs.remove(name)
    }

    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.bufs.keys()
    }

    /// Upload a host tensor under `name`, checking shape/dtype against spec.
    pub fn upload(
        &mut self,
        bk: &dyn Backend,
        spec: &TensorSpec,
        value: &HostTensor,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            value.len() == spec.numel(),
            "{}: host tensor has {} elements, spec {:?} wants {}",
            spec.name,
            value.len(),
            spec.shape,
            spec.numel()
        );
        let buf = match (value, spec.dtype) {
            (HostTensor::F32(v), DType::F32) => bk.upload_f32(v, &spec.shape)?,
            (HostTensor::I32(v), DType::I32) => bk.upload_i32(v, &spec.shape)?,
            _ => anyhow::bail!("{}: dtype mismatch", spec.name),
        };
        self.bufs.insert(spec.name.clone(), buf);
        Ok(())
    }

    /// Assemble the ordered argument list for an artifact from the store.
    /// Every input name must be present.
    pub fn bind<'a>(&'a self, spec: &ArtifactSpec) -> anyhow::Result<Vec<&'a Buffer>> {
        spec.inputs
            .iter()
            .map(|t| {
                self.bufs.get(&t.name).ok_or_else(|| {
                    anyhow::anyhow!("{}: missing input buffer {:?}", spec.key, t.name)
                })
            })
            .collect()
    }

    /// Write step outputs back into the store: `state`/`train`/`frozen`
    /// roles are stored under their names (the state output becomes the
    /// next step's state input); metric outputs are returned for download.
    pub fn absorb_outputs(
        &mut self,
        spec: &ArtifactSpec,
        outs: Vec<Buffer>,
    ) -> Vec<(TensorSpec, Buffer)> {
        let mut metrics = Vec::new();
        for (t, buf) in spec.outputs.iter().zip(outs) {
            match t.role {
                Role::State | Role::Train | Role::Frozen => {
                    self.bufs.insert(t.name.clone(), buf);
                }
                _ => metrics.push((t.clone(), buf)),
            }
        }
        metrics
    }
}
