//! Named device-buffer store + host tensor carrier.
//!
//! A `BufferStore` holds the device-resident state of one training/eval
//! session keyed by manifest tensor names. The training loop binds an
//! artifact's input list against the store, runs the step, then writes the
//! `train`/`opt_m`/`opt_v` outputs back under the same names — params never
//! leave the device between steps.

use std::collections::HashMap;

use super::{ArtifactSpec, DType, Role, Runtime, TensorSpec};

/// Host-side tensor value (upload source / download target).
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => anyhow::bail!("expected f32 tensor"),
        }
    }
}

/// Named device buffers.
pub struct BufferStore {
    bufs: HashMap<String, xla::PjRtBuffer>,
}

impl Default for BufferStore {
    fn default() -> Self {
        Self::new()
    }
}

impl BufferStore {
    pub fn new() -> BufferStore {
        BufferStore {
            bufs: HashMap::new(),
        }
    }

    pub fn contains(&self, name: &str) -> bool {
        self.bufs.contains_key(name)
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&xla::PjRtBuffer> {
        self.bufs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("buffer {name:?} not in store"))
    }

    pub fn insert(&mut self, name: impl Into<String>, buf: xla::PjRtBuffer) {
        self.bufs.insert(name.into(), buf);
    }

    pub fn remove(&mut self, name: &str) -> Option<xla::PjRtBuffer> {
        self.bufs.remove(name)
    }

    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.bufs.keys()
    }

    /// Upload a host tensor under `name`, checking shape/dtype against spec.
    pub fn upload(
        &mut self,
        rt: &Runtime,
        spec: &TensorSpec,
        value: &HostTensor,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            value.len() == spec.numel(),
            "{}: host tensor has {} elements, spec {:?} wants {}",
            spec.name,
            value.len(),
            spec.shape,
            spec.numel()
        );
        let buf = match (value, spec.dtype) {
            (HostTensor::F32(v), DType::F32) => rt.upload_f32(v, &spec.shape)?,
            (HostTensor::I32(v), DType::I32) => rt.upload_i32(v, &spec.shape)?,
            _ => anyhow::bail!("{}: dtype mismatch", spec.name),
        };
        self.bufs.insert(spec.name.clone(), buf);
        Ok(())
    }

    /// Assemble the ordered argument list for an artifact from the store.
    /// Every input name must be present.
    pub fn bind<'a>(&'a self, spec: &ArtifactSpec) -> anyhow::Result<Vec<&'a xla::PjRtBuffer>> {
        spec.inputs
            .iter()
            .map(|t| {
                self.bufs.get(&t.name).ok_or_else(|| {
                    anyhow::anyhow!("{}: missing input buffer {:?}", spec.key, t.name)
                })
            })
            .collect()
    }

    /// Write step outputs back into the store: `state`/`frozen` roles are
    /// stored under their names (the state output becomes the next step's
    /// state input); metric outputs are returned for host download.
    pub fn absorb_outputs(
        &mut self,
        spec: &ArtifactSpec,
        outs: Vec<xla::PjRtBuffer>,
    ) -> Vec<(TensorSpec, xla::PjRtBuffer)> {
        let mut metrics = Vec::new();
        for (t, buf) in spec.outputs.iter().zip(outs) {
            match t.role {
                Role::State | Role::Train | Role::Frozen => {
                    self.bufs.insert(t.name.clone(), buf);
                }
                _ => metrics.push((t.clone(), buf)),
            }
        }
        metrics
    }
}
