//! `PjrtBackend` (cargo feature `pjrt`): loads AOT artifacts
//! (`artifacts/*.hlo.txt`) and executes them on the CPU PJRT client.
//!
//! Buffer lifecycle (see `manifest::Role`): training state (params + Adam
//! moments) lives on the device across steps; only batches and scalars are
//! uploaded per step and only metrics are copied back. The workspace ships
//! an API stub for the `xla` crate (`rust/vendor/xla-stub`) so this file
//! type-checks offline; swap the path dependency for the real bindings to
//! execute actual artifacts.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use super::backend::{Backend, Buffer, Executable, ExecutableImpl};
use super::manifest::Manifest;

/// PJRT execution backend: client + manifest + compiled-executable cache.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl PjrtBackend {
    /// Create a CPU PJRT backend rooted at the artifacts directory.
    pub fn new(artifacts_dir: &Path) -> anyhow::Result<PjrtBackend> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtBackend {
            client,
            manifest,
            dir: artifacts_dir.to_path_buf(),
            cache: RefCell::new(HashMap::new()),
        })
    }

    fn device_buf<'a>(buf: &'a Buffer, what: &str) -> anyhow::Result<&'a xla::PjRtBuffer> {
        match buf {
            Buffer::Pjrt(b) => Ok(b),
            Buffer::Host { .. } => {
                anyhow::bail!("{what}: host buffer handed to the pjrt backend")
            }
        }
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load(&self, key: &str) -> anyhow::Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(key) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(key)?.clone();
        let path = self.dir.join(&spec.file);
        let timer = crate::util::log::Timer::quiet(format!("compile {key}"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path {path:?}"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        crate::debugln!("compiled {} in {:.0} ms", key, timer.elapsed_ms());
        let e = Rc::new(Executable { spec, imp: ExecutableImpl::Pjrt(exe) });
        self.cache.borrow_mut().insert(key.to_string(), e.clone());
        Ok(e)
    }

    fn execute(&self, exe: &Executable, args: &[&Buffer]) -> anyhow::Result<Vec<Buffer>> {
        let pjrt_exe = match &exe.imp {
            ExecutableImpl::Pjrt(e) => e,
            ExecutableImpl::Host(_) => {
                anyhow::bail!("{}: host executable handed to pjrt backend", exe.spec.key)
            }
        };
        anyhow::ensure!(
            args.len() == exe.spec.inputs.len(),
            "{}: got {} args, expected {}",
            exe.spec.key,
            args.len(),
            exe.spec.inputs.len()
        );
        let device_args: Vec<&xla::PjRtBuffer> = args
            .iter()
            .map(|&b| Self::device_buf(b, &exe.spec.key))
            .collect::<anyhow::Result<_>>()?;
        let mut out = pjrt_exe.execute_b(&device_args)?;
        anyhow::ensure!(!out.is_empty(), "{}: empty replica output", exe.spec.key);
        let bufs = out.swap_remove(0);
        // Depending on the plugin, a tuple result arrives either already
        // flattened (one buffer per leaf) or as a single tuple buffer.
        let want = exe.spec.outputs.len();
        anyhow::ensure!(
            bufs.len() == want,
            "{}: PJRT returned {} buffers for {} manifest outputs (tuple not flattened?)",
            exe.spec.key,
            bufs.len(),
            want
        );
        Ok(bufs.into_iter().map(Buffer::Pjrt).collect())
    }

    fn upload_f32(&self, data: &[f32], shape: &[usize]) -> anyhow::Result<Buffer> {
        Ok(Buffer::Pjrt(self.client.buffer_from_host_buffer(data, shape, None)?))
    }

    fn upload_i32(&self, data: &[i32], shape: &[usize]) -> anyhow::Result<Buffer> {
        Ok(Buffer::Pjrt(self.client.buffer_from_host_buffer(data, shape, None)?))
    }

    fn download_f32(&self, buf: &Buffer) -> anyhow::Result<Vec<f32>> {
        let lit = Self::device_buf(buf, "download_f32")?.to_literal_sync()?;
        Ok(lit.to_vec::<f32>()?)
    }
}
