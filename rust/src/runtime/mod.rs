//! PJRT runtime: loads AOT artifacts (`artifacts/*.hlo.txt`) and executes
//! them on the CPU PJRT client. Python never runs here — this is the whole
//! request/training path.
//!
//! Buffer lifecycle (see `manifest::Role`): training state (params + Adam
//! moments) lives on the device across steps via `execute_b`; only batches
//! and scalars are uploaded per step and only metrics are copied back.

mod manifest;
mod store;

pub use manifest::{
    ArtifactSpec, DType, Manifest, Preset, Role, StateField, StateLayout, TensorSpec,
};
pub use store::{BufferStore, HostTensor};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// A loaded + compiled artifact.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute on device-resident buffers. Returns one buffer per manifest
    /// output (the lowering uses `return_tuple=True`; PJRT untuples).
    pub fn run(&self, args: &[&xla::PjRtBuffer]) -> anyhow::Result<Vec<xla::PjRtBuffer>> {
        anyhow::ensure!(
            args.len() == self.spec.inputs.len(),
            "{}: got {} args, expected {}",
            self.spec.key,
            args.len(),
            self.spec.inputs.len()
        );
        let mut out = self.exe.execute_b(args)?;
        anyhow::ensure!(!out.is_empty(), "{}: empty replica output", self.spec.key);
        let bufs = out.swap_remove(0);
        self.check_arity(bufs)
    }

    /// Execute host literals (slow path — tests and one-shot calls).
    pub fn run_literals(&self, args: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        let mut out = self.exe.execute::<xla::Literal>(args)?;
        anyhow::ensure!(!out.is_empty(), "{}: empty replica output", self.spec.key);
        let bufs = out.swap_remove(0);
        let bufs = self.check_arity(bufs)?;
        bufs.iter().map(|b| Ok(b.to_literal_sync()?)).collect()
    }

    /// Normalize PJRT output to one buffer per manifest output. Depending on
    /// the plugin, a tuple result arrives either already flattened (one
    /// buffer per leaf) or as a single tuple buffer.
    fn check_arity(&self, bufs: Vec<xla::PjRtBuffer>) -> anyhow::Result<Vec<xla::PjRtBuffer>> {
        let want = self.spec.outputs.len();
        if bufs.len() == want {
            return Ok(bufs);
        }
        anyhow::bail!(
            "{}: PJRT returned {} buffers for {} manifest outputs (tuple not flattened?)",
            self.spec.key,
            bufs.len(),
            want
        )
    }
}

/// Runtime: PJRT client + manifest + compiled-executable cache.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Runtime {
    /// Create a CPU runtime rooted at the artifacts directory.
    pub fn new(artifacts_dir: &Path) -> anyhow::Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            manifest,
            dir: artifacts_dir.to_path_buf(),
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, key: &str) -> anyhow::Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(key) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(key)?.clone();
        let path = self.dir.join(&spec.file);
        let timer = crate::util::log::Timer::quiet(format!("compile {key}"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path {path:?}"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        crate::debugln!("compiled {} in {:.0} ms", key, timer.elapsed_ms());
        let e = Rc::new(Executable { spec, exe });
        self.cache.borrow_mut().insert(key.to_string(), e.clone());
        Ok(e)
    }

    /// Upload an f32 host tensor.
    pub fn upload_f32(&self, data: &[f32], shape: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, shape, None)?)
    }

    /// Upload an i32 host tensor.
    pub fn upload_i32(&self, data: &[i32], shape: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, shape, None)?)
    }

    /// Upload an f32 scalar.
    pub fn upload_scalar(&self, v: f32) -> anyhow::Result<xla::PjRtBuffer> {
        self.upload_f32(&[v], &[])
    }

    /// Download a buffer to host as f32 (errors on dtype mismatch).
    pub fn download_f32(&self, buf: &xla::PjRtBuffer) -> anyhow::Result<Vec<f32>> {
        let lit = buf.to_literal_sync()?;
        Ok(lit.to_vec::<f32>()?)
    }

    /// Read the metrics head of a state buffer by running the paired
    /// `metrics_*` slice program (the CPU PJRT plugin implements no ranged
    /// host copy, so slicing happens on-device and only the small head is
    /// downloaded).
    pub fn read_metrics(
        &self,
        metrics_exe: &Executable,
        state: &xla::PjRtBuffer,
    ) -> anyhow::Result<Vec<f32>> {
        let outs = metrics_exe.run(&[state])?;
        self.download_f32(&outs[0])
    }
}
