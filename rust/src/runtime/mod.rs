//! Execution runtime: the manifest contract plus pluggable backends.
//!
//! The step protocol is backend-agnostic: every program takes and returns
//! ONE flat f32 state vector `[ metrics | params | adam_m | adam_v ]`
//! (see `python/compile/model.py`), so the output buffer of a step is the
//! next step's input and training state never leaves the backend between
//! steps. Two backends implement it:
//!
//! * [`HostBackend`] — pure Rust, always available, runs the built-in
//!   manifest (`spec::builtin_manifest`) with the reference model in
//!   `model::host`. This is what `cargo test` exercises hermetically.
//! * `PjrtBackend` — the AOT/PJRT path (cargo feature `pjrt`), loading
//!   `artifacts/*.hlo.txt` produced by `make artifacts`.
//!
//! Select with `--backend`/`QRLORA_BACKEND` (`auto` prefers PJRT when
//! compiled and artifacts exist, else host) via [`create_backend`].

mod backend;
mod host;
mod manifest;
#[cfg(feature = "pjrt")]
mod pjrt;
pub mod spec;
mod store;

pub use backend::{
    create_backend, execute_batched_grouped, Backend, BackendChoice, BatchedAdapters, Buffer,
    Executable, FrozenResidency, HostTensor,
};
pub use host::HostBackend;
pub use manifest::{
    ArtifactSpec, DType, Manifest, Preset, Role, StateField, StateLayout, TensorSpec,
};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use store::BufferStore;
