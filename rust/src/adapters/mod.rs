//! Adapter construction: QR-LoRA basis extraction, LoRA / SVD-LoRA
//! initialization, scope configuration, and parameter accounting.
//!
//! This is the paper's §3 on the coordinator side. For each adapted weight
//! matrix `W` (d×d) the coordinator computes a pivoted QR factorization
//! `W P = Q R`, selects the retained rank `r` from the diagonal of R via the
//! τ rule, and ships `(Q_r, R̃_r, mask)` to the device as frozen inputs —
//! zero-padded to the artifact's fixed `r_max` so one artifact serves every
//! (τ, scope, projection) configuration. Only the λ coefficients train.

use std::collections::BTreeMap;

use crate::linalg::{pivoted_qr, select_rank, RankRule};
use crate::runtime::Preset;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Which attention projections to adapt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Proj {
    Q,
    K,
    V,
    O,
}

impl Proj {
    pub fn key(&self) -> &'static str {
        match self {
            Proj::Q => "wq",
            Proj::K => "wk",
            Proj::V => "wv",
            Proj::O => "wo",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Proj> {
        Ok(match s {
            "wq" | "q" => Proj::Q,
            "wk" | "k" => Proj::K,
            "wv" | "v" => Proj::V,
            "wo" | "o" => Proj::O,
            _ => anyhow::bail!("unknown projection {s:?} (q|k|v|o)"),
        })
    }
}

/// All projections the QR-LoRA artifacts carry adapter slots for.
pub const QR_SLOTS: [Proj; 4] = [Proj::Q, Proj::K, Proj::V, Proj::O];
/// Projections the LoRA artifacts adapt (the baseline's fixed choice).
pub const LORA_SLOTS: [Proj; 2] = [Proj::Q, Proj::V];

/// Adapter scope: which layers and projections are active.
#[derive(Clone, Debug)]
pub struct Scope {
    /// `None` = all layers; `Some(k)` = last k layers only.
    pub last_k: Option<usize>,
    pub projs: Vec<Proj>,
}

impl Scope {
    pub fn all_layers(projs: &[Proj]) -> Scope {
        Scope { last_k: None, projs: projs.to_vec() }
    }

    pub fn last_layers(k: usize, projs: &[Proj]) -> Scope {
        Scope { last_k: Some(k), projs: projs.to_vec() }
    }

    pub fn active(&self, layer: usize, n_layers: usize, proj: Proj) -> bool {
        let layer_ok = match self.last_k {
            None => true,
            Some(k) => layer + k >= n_layers,
        };
        layer_ok && self.projs.contains(&proj)
    }

    /// Human-readable label for experiment tables.
    pub fn label(&self, n_layers: usize) -> String {
        let layers = match self.last_k {
            None => format!("all {n_layers} layers"),
            Some(k) => format!("last {k} layers"),
        };
        let projs: Vec<&str> = self.projs.iter().map(|p| match p {
            Proj::Q => "Wq",
            Proj::K => "Wk",
            Proj::V => "Wv",
            Proj::O => "Wo",
        }).collect();
        format!("{layers}, {}", projs.join(","))
    }
}

/// One adapted matrix's QR factors, padded to r_max.
#[derive(Clone, Debug)]
pub struct QrFactors {
    /// (d, r_max), columns ≥ r zeroed.
    pub q: Tensor,
    /// (r_max, d), rows ≥ r zeroed; columns un-permuted so Q·R̃ ≈ W.
    pub r: Tensor,
    /// (r_max,) 1/0 mask of retained directions.
    pub mask: Vec<f32>,
    /// Retained rank after the τ rule (pre-clamp).
    pub selected: usize,
    /// Rank actually used (= min(selected, r_max)).
    pub used: usize,
}

/// QR-LoRA adapter set for a whole model.
#[derive(Clone, Debug)]
pub struct QrAdapterSet {
    pub factors: BTreeMap<String, QrFactors>,
    pub scope: Scope,
    pub tau: f64,
    pub rule: RankRule,
    n_layers: usize,
    d_model: usize,
    r_max: usize,
}

impl QrAdapterSet {
    /// Factorize every in-scope projection of the (frozen) backbone.
    pub fn build(
        backbone: &BTreeMap<String, Tensor>,
        preset: &Preset,
        scope: Scope,
        tau: f64,
        rule: RankRule,
    ) -> anyhow::Result<QrAdapterSet> {
        let mut factors = BTreeMap::new();
        for layer in 0..preset.n_layers {
            for proj in QR_SLOTS {
                if !scope.active(layer, preset.n_layers, proj) {
                    continue;
                }
                let wname = format!("layer{layer}/attn/{}", proj.key());
                let w = backbone
                    .get(&wname)
                    .ok_or_else(|| anyhow::anyhow!("backbone missing {wname}"))?;
                let f = factorize(w, tau, rule, preset.r_max);
                factors.insert(format!("layer{layer}/{}", proj.key()), f);
            }
        }
        Ok(QrAdapterSet {
            factors,
            scope,
            tau,
            rule,
            n_layers: preset.n_layers,
            d_model: preset.d_model,
            r_max: preset.r_max,
        })
    }

    /// Number of trainable adapter parameters (Σ used ranks) — the paper's
    /// headline count (task head excluded, as in the paper's tables).
    pub fn trainable_params(&self) -> usize {
        self.factors.values().map(|f| f.used).sum()
    }

    /// Frozen inputs for the device graph: (name, flat data) for every
    /// Q/R/mask slot of every layer × projection, zeros when out of scope.
    pub fn frozen_inputs(&self) -> Vec<(String, Vec<f32>)> {
        let (d, rm) = (self.d_model, self.r_max);
        let mut out = Vec::new();
        for layer in 0..self.n_layers {
            for proj in QR_SLOTS {
                let key = format!("layer{layer}/{}", proj.key());
                let base = format!("qr/layer{layer}/{}", proj.key());
                match self.factors.get(&key) {
                    Some(f) => {
                        out.push((format!("{base}/Q"), f.q.data.clone()));
                        out.push((format!("{base}/R"), f.r.data.clone()));
                        out.push((format!("{base}/mask"), f.mask.clone()));
                    }
                    None => {
                        out.push((format!("{base}/Q"), vec![0.0; d * rm]));
                        out.push((format!("{base}/R"), vec![0.0; rm * d]));
                        out.push((format!("{base}/mask"), vec![0.0; rm]));
                    }
                }
            }
        }
        out
    }

    /// Merge a trained λ set into dense weights: W ← W + Q_r diag(λ) R̃_r.
    /// `lams` maps "layer{i}/{proj}" → λ vector (length r_max).
    pub fn merge_into(
        &self,
        backbone: &mut BTreeMap<String, Tensor>,
        lams: &BTreeMap<String, Vec<f32>>,
    ) -> anyhow::Result<()> {
        for (key, f) in &self.factors {
            let lam = lams
                .get(key)
                .ok_or_else(|| anyhow::anyhow!("missing λ for {key}"))?;
            let (layer_proj, proj) = key
                .rsplit_once('/')
                .ok_or_else(|| anyhow::anyhow!("bad adapter key {key}"))?;
            let wname = format!("{layer_proj}/attn/{proj}");
            let w = backbone
                .get_mut(&wname)
                .ok_or_else(|| anyhow::anyhow!("backbone missing {wname}"))?;
            // ΔW = Q diag(λ·mask) R
            let mut qs = f.q.clone(); // (d, r_max)
            for i in 0..qs.rows() {
                for j in 0..qs.cols() {
                    qs.set(i, j, qs.at(i, j) * lam[j] * f.mask[j]);
                }
            }
            let delta = qs.matmul(&f.r);
            w.add_assign(&delta);
        }
        Ok(())
    }
}

/// Pivoted-QR factorization of one weight matrix with τ-rank selection,
/// zero-padded to `r_max`.
pub fn factorize(w: &Tensor, tau: f64, rule: RankRule, r_max: usize) -> QrFactors {
    let f = pivoted_qr(w);
    let diag = f.diag();
    let selected = select_rank(&diag, tau, rule);
    let used = selected.min(r_max);
    let (q_r, r_r) = f.truncate(used);

    let d_rows = w.rows();
    let d_cols = w.cols();
    let mut q = Tensor::zeros(&[d_rows, r_max]);
    for i in 0..d_rows {
        for j in 0..used {
            q.set(i, j, q_r.at(i, j));
        }
    }
    let mut r = Tensor::zeros(&[r_max, d_cols]);
    for i in 0..used {
        for j in 0..d_cols {
            r.set(i, j, r_r.at(i, j));
        }
    }
    let mut mask = vec![0.0; r_max];
    for m in mask.iter_mut().take(used) {
        *m = 1.0;
    }
    QrFactors { q, r, mask, selected, used }
}

// ---------------------------------------------------------------------------
// LoRA / SVD-LoRA
// ---------------------------------------------------------------------------

/// LoRA initialization flavour.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LoraInit {
    /// A ~ N(0, 0.02), B = 0 (the LoRA paper's default).
    Standard,
    /// SVD-LoRA: seed the first k slots from the top-k singular triplets of
    /// W (B = U_k √Σ, A = √Σ V_kᵀ), remaining slots standard.
    Svd { k: usize },
}

/// LoRA adapter values: per (layer, proj in LORA_SLOTS) initial A/B plus the
/// frozen scale vector (α/r, 0 where inactive).
#[derive(Clone, Debug)]
pub struct LoraAdapterSet {
    /// "layer{i}/{proj}" → (A: d×r, B: r×d)
    pub init: BTreeMap<String, (Tensor, Tensor)>,
    pub scale: f32,
    n_layers: usize,
    d_model: usize,
    r_lora: usize,
}

impl LoraAdapterSet {
    pub fn build(
        backbone: &BTreeMap<String, Tensor>,
        preset: &Preset,
        init: LoraInit,
        alpha: f32,
        seed: u64,
    ) -> anyhow::Result<LoraAdapterSet> {
        let r = preset.r_lora;
        let mut rng = Rng::new(seed);
        let mut map = BTreeMap::new();
        for layer in 0..preset.n_layers {
            for proj in LORA_SLOTS {
                let wname = format!("layer{layer}/attn/{}", proj.key());
                let w = backbone
                    .get(&wname)
                    .ok_or_else(|| anyhow::anyhow!("backbone missing {wname}"))?;
                let mut a = Tensor::randn(&[preset.d_model, r], &mut rng, 0.02);
                let mut b = Tensor::zeros(&[r, preset.d_model]);
                if let LoraInit::Svd { k } = init {
                    let svd = crate::linalg::jacobi_svd(w);
                    let (bu, av) = svd.split_factors(k.min(r));
                    // bu: d×k → A's first k columns; av: k×d → B's first k rows
                    for i in 0..preset.d_model {
                        for j in 0..k.min(r) {
                            a.set(i, j, bu.at(i, j));
                        }
                    }
                    for i in 0..k.min(r) {
                        for j in 0..preset.d_model {
                            b.set(i, j, av.at(i, j));
                        }
                    }
                }
                map.insert(format!("layer{layer}/{}", proj.key()), (a, b));
            }
        }
        Ok(LoraAdapterSet {
            init: map,
            scale: alpha / r as f32,
            n_layers: preset.n_layers,
            d_model: preset.d_model,
            r_lora: preset.r_lora,
        })
    }

    /// Trainable parameter count: 2·d·r per adapted matrix.
    pub fn trainable_params(&self) -> usize {
        self.init.len() * 2 * self.d_model * self.r_lora
    }

    /// Frozen scale inputs for the graph.
    pub fn frozen_inputs(&self) -> Vec<(String, Vec<f32>)> {
        let mut out = Vec::new();
        for layer in 0..self.n_layers {
            for proj in LORA_SLOTS {
                let base = format!("lora/layer{layer}/{}", proj.key());
                out.push((format!("{base}/scale"), vec![self.scale; self.r_lora]));
            }
        }
        out
    }

    /// Initial values to write into the state vector's A/B leaves.
    pub fn state_writes(&self) -> Vec<(String, Tensor)> {
        let mut out = Vec::new();
        for (key, (a, b)) in &self.init {
            let (layer, proj) = key.rsplit_once('/').unwrap();
            out.push((format!("lora/{layer}/{proj}/A"), a.clone()));
            out.push((format!("lora/{layer}/{proj}/B"), b.clone()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::orthonormality_defect;

    fn preset() -> Preset {
        Preset {
            name: "test".into(),
            d_model: 16,
            n_layers: 3,
            n_heads: 2,
            d_ff: 32,
            vocab: 128,
            max_seq: 16,
            batch: 4,
            r_max: 8,
            r_lora: 2,
            n_classes: 3,
        }
    }

    fn backbone(p: &Preset, seed: u64) -> BTreeMap<String, Tensor> {
        let mut rng = Rng::new(seed);
        let mut map = BTreeMap::new();
        for layer in 0..p.n_layers {
            for proj in QR_SLOTS {
                map.insert(
                    format!("layer{layer}/attn/{}", proj.key()),
                    Tensor::randn(&[p.d_model, p.d_model], &mut rng, 0.3),
                );
            }
        }
        map
    }

    #[test]
    fn scope_semantics() {
        let s = Scope::last_layers(2, &[Proj::Q, Proj::V]);
        assert!(!s.active(0, 4, Proj::Q));
        assert!(s.active(2, 4, Proj::Q));
        assert!(s.active(3, 4, Proj::V));
        assert!(!s.active(3, 4, Proj::O));
        let all = Scope::all_layers(&[Proj::O]);
        assert!(all.active(0, 4, Proj::O));
        assert!(!all.active(0, 4, Proj::Q));
    }

    #[test]
    fn scope_layer_boundaries() {
        // last_k = 0: `layer + 0 >= n_layers` never holds → nothing active.
        let none = Scope::last_layers(0, &[Proj::Q, Proj::K, Proj::V, Proj::O]);
        for layer in 0..4 {
            for proj in QR_SLOTS {
                assert!(!none.active(layer, 4, proj), "layer {layer} unexpectedly active");
            }
        }
        // k > n_layers: every layer is within the "last k".
        let all = Scope::last_layers(99, &[Proj::Q]);
        for layer in 0..4 {
            assert!(all.active(layer, 4, Proj::Q));
        }
        // k == n_layers is equivalent to all layers.
        let exact = Scope::last_layers(4, &[Proj::V]);
        for layer in 0..4 {
            assert!(exact.active(layer, 4, Proj::V));
        }
        // boundary layer: with k=1 only the final layer is active.
        let last1 = Scope::last_layers(1, &[Proj::O]);
        assert!(!last1.active(2, 4, Proj::O));
        assert!(last1.active(3, 4, Proj::O));
    }

    #[test]
    fn scope_empty_set_yields_empty_adapter() {
        let p = preset();
        let bb = backbone(&p, 40);
        let set = QrAdapterSet::build(
            &bb,
            &p,
            Scope::last_layers(0, &[Proj::Q]),
            0.5,
            RankRule::DiagRatio,
        )
        .unwrap();
        assert_eq!(set.factors.len(), 0);
        assert_eq!(set.trainable_params(), 0);
        // frozen inputs still cover every slot (all zeros)
        let inputs = set.frozen_inputs();
        assert_eq!(inputs.len(), p.n_layers * 4 * 3);
        assert!(inputs.iter().all(|(_, v)| v.iter().all(|&x| x == 0.0)));
    }

    #[test]
    fn proj_parse_accepts_aliases_and_rejects_junk() {
        assert_eq!(Proj::parse("q").unwrap(), Proj::Q);
        assert_eq!(Proj::parse("wq").unwrap(), Proj::Q);
        assert_eq!(Proj::parse("k").unwrap(), Proj::K);
        assert_eq!(Proj::parse("wv").unwrap(), Proj::V);
        assert_eq!(Proj::parse("o").unwrap(), Proj::O);
        for bad in ["", "w", "wx", "Q ", "query", "wqv"] {
            let err = Proj::parse(bad);
            assert!(err.is_err(), "{bad:?} unexpectedly parsed");
            let msg = format!("{}", err.err().unwrap());
            assert!(msg.contains("unknown projection"), "{msg}");
        }
    }

    #[test]
    fn factorize_reconstructs_with_full_mask() {
        let mut rng = Rng::new(5);
        let w = Tensor::randn(&[12, 12], &mut rng, 1.0);
        let f = factorize(&w, 0.0, RankRule::DiagRatio, 12);
        // τ=0 keeps every direction with |R_ii| > 0 → full rank
        assert_eq!(f.used, 12);
        let approx = f.q.matmul(&f.r);
        assert!(approx.max_abs_diff(&w) < 5e-4, "{}", approx.max_abs_diff(&w));
    }

    #[test]
    fn factorize_clamps_to_rmax() {
        let mut rng = Rng::new(6);
        let w = Tensor::randn(&[16, 16], &mut rng, 1.0);
        let f = factorize(&w, 0.0, RankRule::DiagRatio, 4);
        assert_eq!(f.used, 4);
        assert!(f.selected >= f.used);
        assert_eq!(f.mask.iter().filter(|&&m| m == 1.0).count(), 4);
        // padded tail is zero
        for i in 0..16 {
            for j in 4..f.q.cols() {
                assert_eq!(f.q.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn higher_tau_keeps_fewer_directions() {
        let mut rng = Rng::new(7);
        let w = Tensor::randn(&[16, 16], &mut rng, 1.0);
        let lo = factorize(&w, 0.3, RankRule::DiagRatio, 16);
        let hi = factorize(&w, 0.8, RankRule::DiagRatio, 16);
        assert!(hi.used <= lo.used, "{} > {}", hi.used, lo.used);
    }

    #[test]
    fn padded_q_columns_orthonormal() {
        let mut rng = Rng::new(8);
        let w = Tensor::randn(&[16, 16], &mut rng, 1.0);
        let f = factorize(&w, 0.3, RankRule::DiagRatio, 16);
        let q_used = f.q.slice_cols(0, f.used);
        assert!(orthonormality_defect(&q_used) < 1e-4);
    }

    #[test]
    fn adapter_set_counts_and_inputs() {
        let p = preset();
        let bb = backbone(&p, 9);
        let set = QrAdapterSet::build(
            &bb,
            &p,
            Scope::last_layers(1, &[Proj::Q]),
            0.5,
            RankRule::DiagRatio,
        )
        .unwrap();
        assert_eq!(set.factors.len(), 1);
        assert!(set.trainable_params() > 0);
        assert!(set.trainable_params() <= p.r_max);
        // 3 layers × 4 slots × 3 tensors
        let inputs = set.frozen_inputs();
        assert_eq!(inputs.len(), 3 * 4 * 3);
        // out-of-scope slots are all zeros
        let q0: &Vec<f32> = &inputs.iter().find(|(n, _)| n == "qr/layer0/wq/Q").unwrap().1;
        assert!(q0.iter().all(|&v| v == 0.0));
        let q2: &Vec<f32> = &inputs.iter().find(|(n, _)| n == "qr/layer2/wq/Q").unwrap().1;
        assert!(q2.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn param_count_scales_with_scope() {
        let p = preset();
        let bb = backbone(&p, 10);
        let narrow = QrAdapterSet::build(
            &bb,
            &p,
            Scope::last_layers(1, &[Proj::Q]),
            0.5,
            RankRule::DiagRatio,
        )
        .unwrap();
        let wide = QrAdapterSet::build(
            &bb,
            &p,
            Scope::all_layers(&[Proj::Q, Proj::V, Proj::O]),
            0.5,
            RankRule::DiagRatio,
        )
        .unwrap();
        assert!(wide.trainable_params() > narrow.trainable_params());
    }

    #[test]
    fn merge_matches_factors() {
        let p = preset();
        let bb = backbone(&p, 11);
        let set = QrAdapterSet::build(
            &bb,
            &p,
            Scope::last_layers(1, &[Proj::V]),
            0.4,
            RankRule::DiagRatio,
        )
        .unwrap();
        let key = "layer2/wv".to_string();
        let f = &set.factors[&key];
        let mut lam = vec![0.0f32; p.r_max];
        lam[0] = 2.0;
        let mut lams = BTreeMap::new();
        lams.insert(key.clone(), lam);
        let mut merged = bb.clone();
        set.merge_into(&mut merged, &lams).unwrap();
        // ΔW = 2 · q₀ r₀ᵀ
        let w0 = &bb["layer2/attn/wv"];
        let w1 = &merged["layer2/attn/wv"];
        let mut want = w0.clone();
        for i in 0..p.d_model {
            for j in 0..p.d_model {
                let delta = 2.0 * f.q.at(i, 0) * f.r.at(0, j);
                want.set(i, j, want.at(i, j) + delta);
            }
        }
        assert!(w1.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn lora_standard_init_starts_at_zero_delta() {
        let p = preset();
        let bb = backbone(&p, 12);
        let set = LoraAdapterSet::build(&bb, &p, LoraInit::Standard, 2.0, 13).unwrap();
        assert_eq!(set.trainable_params(), 6 * 2 * 16 * 2); // 6 matrices × 2·d·r
        for (a, b) in set.init.values() {
            assert!(a.data.iter().any(|&v| v != 0.0));
            assert!(b.data.iter().all(|&v| v == 0.0));
        }
        assert_eq!(set.scale, 1.0);
    }

    #[test]
    fn svd_init_first_slot_reconstructs_top_direction() {
        let p = preset();
        let bb = backbone(&p, 14);
        let set = LoraAdapterSet::build(&bb, &p, LoraInit::Svd { k: 1 }, 2.0, 15).unwrap();
        let (a, b) = &set.init["layer0/wq"];
        let w = &bb["layer0/attn/wq"];
        // BA (using only slot 0) should equal σ₁ u₁ v₁ᵀ — the best rank-1
        // approximation; its Frobenius norm is σ₁.
        let a0 = a.slice_cols(0, 1);
        let b0 = b.slice_rows(0, 1);
        let approx = a0.matmul(&b0);
        let svd = crate::linalg::jacobi_svd(w);
        assert!((approx.fro_norm() - svd.s[0] as f64).abs() < 1e-2);
    }

    #[test]
    fn lora_frozen_scales() {
        let p = preset();
        let bb = backbone(&p, 16);
        let set = LoraAdapterSet::build(&bb, &p, LoraInit::Standard, 4.0, 17).unwrap();
        let inputs = set.frozen_inputs();
        assert_eq!(inputs.len(), 3 * 2);
        assert!(inputs.iter().all(|(_, v)| v.iter().all(|&s| s == 2.0)));
    }
}
