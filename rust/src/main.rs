//! qrlora — QR-LoRA coordinator CLI.
//!
//! The leader binary: drives pretraining / warm-up / adapter fine-tuning,
//! regenerates the paper's tables and figure, inspects rank selection, and
//! runs the multi-adapter serving demo. Python never runs here — only
//! `make artifacts` (build time) uses it.
//!
//! Execution backend: `--backend host|pjrt|auto` (or `QRLORA_BACKEND`).
//! The default `auto` uses PJRT artifacts when the binary was built with
//! `--features pjrt` and `$QRLORA_ARTIFACTS/manifest.json` exists, and the
//! hermetic pure-Rust host backend otherwise.
//!
//! Host-backend parallelism: `--threads N` (or `QRLORA_THREADS`) sizes the
//! worker pool; default is the machine's available parallelism. Results
//! are bit-identical for every thread count.
//!
//! Host-backend kernels: `--simd auto|avx2|neon|scalar` (or `QRLORA_SIMD`)
//! selects the SIMD microkernel backend; `auto` (default) uses runtime
//! feature detection, and every mode keeps results bit-identical. The
//! `--simd-relaxed` switch (or `QRLORA_SIMD_RELAXED=1`) additionally opts
//! into re-associated FMA dot products (faster, ≤1e-5 relative error; see
//! [`qrlora::kernels`]).
//!
//! Memory: `--quantize-backbone` (or `QRLORA_QUANT=1`) holds the frozen
//! backbone weights int8 on the host backend (embeddings + attention/FFN
//! projections, per-row-group absmax scales); QR factors, λ, LoRA A/B,
//! task heads, and all gradients stay f32. See the README's perf-knobs
//! section for the accuracy contract.
//!
//! Durable adapters: `serve` warm-starts from the adapter store
//! (`--adapter-store DIR`, default `runs/adapters`; `--no-warm-start`
//! disables it) and publishes freshly trained adapters back;
//! `adapters list|verify|gc` manages the records.
//!
//! Fault injection: `QRLORA_FAULTS` (see [`qrlora::util::faults`])
//! deterministically injects crashes, hangs, and transient IO errors at
//! the store/lock/checkpoint seams so the chaos tests and CI smoke jobs
//! can exercise supervision, retry, and degraded serving against the
//! real binary. Unset (the default), every hook is a no-op.
//!
//! Observability: the [`qrlora::obs`] registry instruments serving
//! end-to-end — `GET /metrics` (Prometheus text), `GET /metrics.json`,
//! and `serve --metrics-json PATH` export it; `QRLORA_OBS=0` disables
//! metric mutation. `QRLORA_LOG=error|warn|info|debug` is the env twin
//! of `--log` (the flag wins when both are given).

use qrlora::adapters::{Proj, Scope};
use qrlora::data::ALL_TASKS;
use qrlora::experiments::{self, ExpConfig, Pipeline};
use qrlora::linalg::{select_rank, RankRule};
use qrlora::runtime::Backend;
use qrlora::training::{self, FinetuneJob, Method, Methods};
use qrlora::util::cli::{render_help, Args, Command};
use qrlora::{errorln, info};

const COMMANDS: &[Command] = &[
    Command { name: "info", about: "summarize manifest, presets, artifacts" },
    Command { name: "pretrain", about: "MLM-pretrain a backbone and cache it under runs/" },
    Command { name: "train", about: "fine-tune one task with one method (full pipeline)" },
    Command { name: "ranks", about: "pivoted-QR rank-selection report for a backbone" },
    Command { name: "exp", about: "regenerate a paper table/figure: table1..table4, figure1, all" },
    Command { name: "serve", about: "batched serving demo (--fleet N spawns a worker fleet)" },
    Command {
        name: "soak",
        about: "socket load generator: drive RPS at serve --listen workers, report latency",
    },
    Command {
        name: "adapters",
        about: "adapter store: list | verify | gc | stress-publish (--adapter-store DIR)",
    },
];

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() || raw[0] == "--help" || raw[0] == "help" {
        print!("{}", render_help("qrlora", "QR-LoRA reproduction coordinator", COMMANDS));
        return;
    }
    let cmd = raw[0].clone();
    let switches =
        ["verbose", "force", "quantize-backbone", "no-warm-start", "dry-run", "simd-relaxed"];
    let args = match Args::parse(&raw[1..], &switches) {
        Ok(a) => a,
        Err(e) => {
            errorln!("{e}");
            std::process::exit(2);
        }
    };
    if let Some(level) = args.get("log") {
        let _ = qrlora::util::log::set_level_str(level);
    } else if let Ok(level) = std::env::var("QRLORA_LOG") {
        // Env twin of --log, for contexts where the flag can't be
        // threaded (fleet workers, CI harnesses). CLI > env > default.
        let _ = qrlora::util::log::set_level_str(&level);
    } else if args.has("verbose") {
        qrlora::util::log::set_level(qrlora::util::log::Level::Debug);
    }
    if let Some(backend) = args.get("backend") {
        // Validate eagerly, then hand selection to the (thread-local)
        // backend factory via the environment.
        if let Err(e) = qrlora::runtime::BackendChoice::parse(backend) {
            errorln!("{e:#}");
            std::process::exit(2);
        }
        std::env::set_var("QRLORA_BACKEND", backend);
    }
    if let Some(simd) = args.get("simd") {
        // Validate eagerly (a typo must not silently serve on the wrong
        // kernels), then hand selection to the cached kernel resolver via
        // the environment, like --backend.
        if let Err(e) = qrlora::kernels::SimdRequest::parse(simd) {
            errorln!("{e:#}");
            std::process::exit(2);
        }
        std::env::set_var("QRLORA_SIMD", simd);
    }
    if args.has("simd-relaxed") {
        std::env::set_var("QRLORA_SIMD_RELAXED", "1");
    }
    if let Some(threads) = args.get("threads") {
        // Size the host-backend worker pool before first use (overrides
        // QRLORA_THREADS; default is available_parallelism).
        match threads.parse::<usize>() {
            Ok(n) if n >= 1 => qrlora::util::pool::set_threads(n),
            _ => {
                errorln!("--threads expects a positive integer, got {threads:?}");
                std::process::exit(2);
            }
        }
    }
    if args.has("quantize-backbone") {
        // Hold the frozen backbone int8 on the host backend (~4x smaller
        // resident weights; QR factors, λ, heads, and gradients stay f32).
        // Handed to the backend factory via the env, like --backend.
        //
        // The flag is a valueless switch, so `--quantize-backbone off`
        // would silently leave `off` as a stray positional while turning
        // quantization ON — catch that spelling and demand the `=` form.
        let stray = args.positional().iter().find(|p| {
            matches!(
                p.to_ascii_lowercase().as_str(),
                "on" | "off" | "0" | "1" | "true" | "false" | "yes" | "no"
            )
        });
        if let Some(v) = stray {
            errorln!(
                "--quantize-backbone takes no value; use --quantize-backbone or \
                 --quantize-backbone=off, not `--quantize-backbone {v}`"
            );
            std::process::exit(2);
        }
        std::env::set_var("QRLORA_QUANT", "1");
    } else if let Some(v) = args.get("quantize-backbone") {
        // `--quantize-backbone=1` / `=off`: forward the value so the env
        // parser's truthiness applies instead of silently ignoring it.
        std::env::set_var("QRLORA_QUANT", v);
    }

    let result = match cmd.as_str() {
        "info" => cmd_info(&args),
        "pretrain" => cmd_pretrain(&args),
        "train" => cmd_train(&args),
        "ranks" => cmd_ranks(&args),
        "exp" => cmd_exp(&args),
        "serve" => cmd_serve(&args),
        "soak" => cmd_soak(&args),
        "adapters" => cmd_adapters(&args),
        other => {
            errorln!("unknown command {other:?}");
            print!("{}", render_help("qrlora", "QR-LoRA reproduction coordinator", COMMANDS));
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        errorln!("{e:#}");
        std::process::exit(1);
    }
}

fn exp_config(args: &Args) -> anyhow::Result<ExpConfig> {
    let mut cfg = ExpConfig {
        preset: args.str_or("preset", "tiny").to_string(),
        ..ExpConfig::default()
    };
    cfg.pretrain_steps = args.usize_or("pretrain-steps", cfg.pretrain_steps)?;
    cfg.warmup_steps = args.usize_or("warmup-steps", cfg.warmup_steps)?;
    cfg.steps = args.usize_or("steps", cfg.steps)?;
    cfg.train_examples = args.usize_or("train-examples", cfg.train_examples)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.lr_ft = args.f64_or("lr-ft", cfg.lr_ft)?;
    cfg.lr_adapter = args.f64_or("lr", cfg.lr_adapter)?;
    Ok(cfg)
}

fn cmd_info(_args: &Args) -> anyhow::Result<()> {
    let dir = std::env::var("QRLORA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let choice = qrlora::runtime::BackendChoice::from_env()?;
    let rt = qrlora::runtime::create_backend(choice, std::path::Path::new(&dir))?;
    println!("backend: {}", rt.name());
    println!("host threads: {}", qrlora::util::pool::threads());
    println!("simd kernels: {}", qrlora::kernels::active().describe());
    println!(
        "quantized backbone: {}",
        if qrlora::quant::quant_backbone_from_env() { "on (int8)" } else { "off (f32)" }
    );
    println!("presets:");
    for (name, p) in &rt.manifest().presets {
        println!(
            "  {name}: d={} layers={} heads={} ffn={} vocab={} seq={} batch={} r_max={}",
            p.d_model, p.n_layers, p.n_heads, p.d_ff, p.vocab, p.max_seq, p.batch, p.r_max
        );
    }
    println!("artifacts ({}):", rt.manifest().artifacts.len());
    for (key, a) in &rt.manifest().artifacts {
        println!(
            "  {key}: {} inputs, {} outputs{}",
            a.inputs.len(),
            a.outputs.len(),
            a.state_layout
                .as_ref()
                .map(|l| format!(", state {} f32 ({} trainable)", l.total, l.n_params))
                .unwrap_or_default()
        );
    }
    println!("tasks: {}", ALL_TASKS.iter().map(|t| t.name).collect::<Vec<_>>().join(", "));
    Ok(())
}

fn cmd_pretrain(args: &Args) -> anyhow::Result<()> {
    let cfg = exp_config(args)?;
    let mut pipe = Pipeline::new(&cfg)?;
    let bb = pipe.backbone()?;
    println!("backbone ready: {} parameter tensors (cached under runs/)", bb.len());
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let cfg = exp_config(args)?;
    let task_name = args.str_or("task", "sst2").to_string();
    let method_name = args.str_or("method", "qrlora").to_string();
    let tau = args.f64_or("tau", 0.5)?;
    let projs: Vec<Proj> = args
        .list_str("projs", &["q", "v"])
        .iter()
        .map(|s| Proj::parse(s))
        .collect::<anyhow::Result<_>>()?;
    let scope = match args.get("last-k") {
        Some(k) => Scope::last_layers(k.parse()?, &projs),
        None => Scope::all_layers(&projs),
    };

    let mut pipe = Pipeline::new(&cfg)?;
    let preset = pipe.preset.clone();
    let (warm_bb, warm_head) = pipe.warmed(&task_name)?;
    let method = match method_name.as_str() {
        "ft" => Method::FullFt,
        "lora" => Methods::lora(&warm_bb, &preset, 2.0, cfg.seed)?,
        "svdlora" | "svd-lora" => Methods::svd_lora(&warm_bb, &preset, 1, 2.0, cfg.seed)?,
        "qrlora" | "qr-lora" => {
            Methods::qr_lora(&warm_bb, &preset, scope, tau, RankRule::DiagRatio)?
        }
        other => anyhow::bail!("unknown method {other:?} (ft|lora|svdlora|qrlora)"),
    };

    let data = pipe.data(&task_name)?;
    let is_ft = matches!(method, Method::FullFt);
    let tc = qrlora::training::TrainConfig {
        steps: cfg.steps,
        lr: if is_ft { cfg.lr_ft } else { cfg.lr_adapter },
        warmup_steps: (cfg.steps / 20).max(5),
        train_examples: cfg.train_examples,
        log_every: (cfg.steps / 10).max(1),
    };
    let job = FinetuneJob {
        rt: pipe.rt,
        preset: &cfg.preset,
        task: &data,
        lexicon: &pipe.lexicon,
        backbone: &warm_bb,
        head: Some(&warm_head),
        config: tc,
        seed: cfg.seed,
    };
    let r = training::run_finetune(&job, &method)?;
    println!("task:        {}", r.task);
    println!("method:      {}", r.method_label);
    println!("trainable:   {}", r.trainable_params);
    println!("steps:       {}", r.steps);
    println!("final loss:  {:.4}", r.final_loss);
    println!("accuracy:    {:.2}%", 100.0 * r.dev.accuracy);
    println!("f1:          {:.2}%", 100.0 * r.dev.f1);
    println!("matthews:    {:.3}", r.dev.matthews);
    println!("pearson:     {:.3}", r.dev.pearson);
    if let Some(mm) = &r.dev_mm {
        println!("mismatched:  {:.2}%", 100.0 * mm.accuracy);
    }
    println!("loss curve:  {:?}", r.losses);
    Ok(())
}

fn cmd_ranks(args: &Args) -> anyhow::Result<()> {
    let cfg = exp_config(args)?;
    let mut pipe = Pipeline::new(&cfg)?;
    let bb = pipe.backbone()?;
    let taus = args.list_f64("taus", &[0.3, 0.5, 0.7, 0.8, 0.9])?;
    println!("pivoted-QR rank selection (preset {}, DiagRatio rule):\n", cfg.preset);
    let header: Vec<String> = taus.iter().map(|t| format!("τ={t}")).collect();
    println!("| matrix | {} |", header.join(" | "));
    println!("|---|{}", "---:|".repeat(taus.len()));
    for (name, w) in bb.iter().filter(|(n, _)| n.contains("/attn/w")) {
        let f = qrlora::linalg::pivoted_qr(w);
        let diag = f.diag();
        let ranks: Vec<String> = taus
            .iter()
            .map(|&t| select_rank(&diag, t, RankRule::DiagRatio).to_string())
            .collect();
        println!("| {name} | {} |", ranks.join(" | "));
    }
    Ok(())
}

fn cmd_exp(args: &Args) -> anyhow::Result<()> {
    let cfg = exp_config(args)?;
    let which = args.positional().first().cloned().unwrap_or_else(|| "all".to_string());
    let t0 = std::time::Instant::now();
    match which.as_str() {
        "table1" => experiments::table1(&cfg)?,
        "table2" => experiments::table2(&cfg)?,
        "table3" => {
            let tasks = args.list_str(
                "tasks",
                &["mnli", "sst2", "mrpc", "cola", "qnli", "qqp", "rte", "stsb"],
            );
            let refs: Vec<&str> = tasks.iter().map(|s| s.as_str()).collect();
            experiments::table3(&cfg, &refs)?
        }
        "table4" => {
            let sizes: Vec<usize> = args
                .list_f64("sizes", &[2000.0, 10000.0, 50000.0])?
                .into_iter()
                .map(|f| f as usize)
                .collect();
            experiments::table4(&cfg, &sizes)?
        }
        "figure1" => experiments::figure1(&cfg)?,
        "all" => {
            experiments::table1(&cfg)?;
            experiments::table2(&cfg)?;
            let refs: Vec<&str> = ALL_TASKS.iter().map(|t| t.name).collect();
            experiments::table3(&cfg, &refs)?;
            experiments::table4(&cfg, &[2000, 10000, 50000])?;
            experiments::figure1(&cfg)?;
        }
        other => anyhow::bail!("unknown experiment {other:?} (table1..table4, figure1, all)"),
    }
    info!("experiment {which} finished in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let cfg = exp_config(args)?;
    let sc = qrlora::server::ServeConfig::from_args(args)?;
    let result = run_serve(&cfg, &sc, args);
    // Final registry snapshot, written even when serving errored —
    // post-mortem metrics matter most for failed runs. In fleet
    // supervisor mode this is the supervisor's own (mostly idle)
    // registry; workers ship theirs in the FLEET_WORKER reports.
    if let Some(path) = &sc.metrics_json {
        match std::fs::write(path, qrlora::obs::snapshot().to_json().pretty()) {
            Ok(()) => println!("[serve] metrics snapshot written to {}", path.display()),
            Err(e) => errorln!("cannot write --metrics-json {}: {e}", path.display()),
        }
    }
    result
}

fn run_serve(cfg: &ExpConfig, sc: &qrlora::server::ServeConfig, args: &Args) -> anyhow::Result<()> {
    // Fleet worker mode (spawned by the supervisor, not typed by hand):
    // `--worker-id I --fleet-tasks a,b` trains the owned tasks, store-
    // watches for the rest, then serves the full mixed stream.
    if let Some(v) = args.get("worker-id") {
        let id: usize = v
            .parse()
            .map_err(|_| anyhow::anyhow!("--worker-id expects an integer, got {v:?}"))?;
        let owned: Vec<String> = args
            .str_or("fleet-tasks", "")
            .split(',')
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect();
        return qrlora::server::fleet::run_worker(cfg, sc, id, &owned);
    }
    // Fleet supervisor mode: partition tasks over N worker processes
    // sharing one adapter store, then aggregate their reports.
    if let Some(v) = args.get("fleet") {
        let n: usize = v
            .parse()
            .map_err(|_| anyhow::anyhow!("--fleet expects a worker count, got {v:?}"))?;
        anyhow::ensure!(n >= 1, "--fleet needs at least one worker");
        return qrlora::server::fleet::run_fleet(cfg, sc, n);
    }
    // Socket front-end: bind `--listen`, serve the request budget over
    // TCP (line-delimited JSON + a minimal HTTP shim), then report.
    if let Some(listen) = sc.listen.clone() {
        let mut core =
            qrlora::server::ServeCore::with_method(cfg, sc.adapter_store.as_deref(), &sc.method)?;
        core.prepare(qrlora::server::SERVE_TASKS)?;
        let stats = qrlora::server::net::serve_listen(&mut core, sc, &listen)?;
        core.flush_publishes();
        println!(
            "[serve] socket serving done: {} request(s), {} shed, {} rejected, {:.1} req/s",
            stats.requests,
            stats.shed,
            stats.rejected,
            stats.throughput()
        );
        return Ok(());
    }
    qrlora::server::demo(cfg, sc)
}

/// `soak` — socket load generator for `serve --listen` endpoints.
///
/// Opens `--concurrency` persistent connections spread over the
/// `--connect` address list, drives `--requests` line-protocol requests
/// sampled from the dev split (seeded, reproducible), retries explicit
/// 503 sheds, and reports p50/p99/p999 latency plus shed and protocol-
/// error counts. `--soak-json PATH` additionally writes the full report
/// (including the latency histogram) as pretty JSON.
fn cmd_soak(args: &Args) -> anyhow::Result<()> {
    let cfg = exp_config(args)?;
    let addrs = args.list_str("connect", &[]);
    let addrs: Vec<String> = addrs.into_iter().filter(|a| !a.is_empty()).collect();
    anyhow::ensure!(!addrs.is_empty(), "soak: --connect host:port[,host:port...] is required");
    let requests = args.usize_or("requests", 64)?;
    let concurrency = args.usize_or("concurrency", 4)?;
    let report = qrlora::server::net::soak(&cfg, &addrs, requests, concurrency)?;
    let line = report.to_string();
    println!("SOAK {line}");
    if let Some(path) = args.get("soak-json") {
        std::fs::write(path, report.pretty())
            .map_err(|e| anyhow::anyhow!("soak: writing {path}: {e}"))?;
        println!("[soak] report written to {path}");
    }
    let errors = report.req("protocol_errors")?.as_usize().unwrap_or(usize::MAX);
    anyhow::ensure!(errors == 0, "soak: {errors} protocol error(s) — see SOAK report above");
    Ok(())
}

fn cmd_adapters(args: &Args) -> anyhow::Result<()> {
    use qrlora::store::{gc, GcPolicy, Registry, DEFAULT_STORE_DIR};
    let dir = std::path::PathBuf::from(args.str_or("adapter-store", DEFAULT_STORE_DIR));
    let sub = args.positional().first().map(|s| s.as_str()).unwrap_or("list");
    let mut reg = Registry::open(&dir)?;
    match sub {
        "list" => {
            println!("adapter store {} — {} record(s)", dir.display(), reg.len());
            if reg.is_empty() {
                return Ok(());
            }
            println!("| preset | method | task | seed | metric | size | trained | age | file |");
            println!("|---|---|---|---:|---:|---:|---:|---:|---|");
            // Display-only: a pre-epoch clock degrades the age column to
            // "huge", it must not abort `list`.
            let now = qrlora::store::unix_now_or_zero();
            for e in reg.entries() {
                println!(
                    "| {} | {} | {} | {} | {:.1} | {:.1} KiB | {:.0} ms | {:.1} h | {} |",
                    e.key.preset,
                    e.key.method,
                    e.key.task,
                    e.key.seed,
                    e.eval_metric,
                    e.bytes as f64 / 1024.0,
                    e.train_ms,
                    now.saturating_sub(e.created_unix) as f64 / 3600.0,
                    e.file
                );
            }
            Ok(())
        }
        "verify" => {
            let results = reg.verify();
            let mut failed = 0usize;
            for r in &results {
                match &r.result {
                    Ok(()) => println!("OK    {}  ({})", r.key, r.file),
                    Err(e) => {
                        failed += 1;
                        println!("FAIL  {}  ({}): {e:#}", r.key, r.file);
                    }
                }
            }
            println!("verified {} record(s), {failed} failure(s)", results.len());
            anyhow::ensure!(failed == 0, "{failed} adapter record(s) failed verification");
            Ok(())
        }
        "gc" => {
            let max_age_secs = match args.get("max-age-days") {
                None => None,
                Some(v) => {
                    let days: f64 = v.parse().map_err(|_| {
                        anyhow::anyhow!("--max-age-days expects a number, got {v:?}")
                    })?;
                    anyhow::ensure!(days >= 0.0, "--max-age-days must be non-negative");
                    Some((days * 86_400.0) as u64)
                }
            };
            let max_count = match args.get("max-count") {
                None => None,
                Some(v) => Some(v.parse::<usize>().map_err(|_| {
                    anyhow::anyhow!("--max-count expects an integer, got {v:?}")
                })?),
            };
            let policy = GcPolicy {
                task: args.get("task").map(str::to_string),
                max_age_secs,
                max_count,
            };
            let dry = args.has("dry-run");
            // Age pruning against a pre-epoch clock must abort, not run
            // with now=0 (which would age-exempt nothing and prune wrong).
            let report = gc::gc(&mut reg, &policy, qrlora::store::unix_now()?, dry)?;
            let verb = if dry { "would remove" } else { "removed" };
            for key in &report.removed {
                println!("{verb} {key}");
            }
            println!(
                "{} {}, {} kept, {:.1} KiB freed{}",
                verb,
                report.removed.len(),
                report.kept,
                report.freed_bytes as f64 / 1024.0,
                if dry { " (dry run)" } else { "" }
            );
            Ok(())
        }
        "stress-publish" => {
            // Hammer `publish_merged` with M synthetic records from this
            // process (`--writer-id K` keeps keys distinct across
            // writers). The multi-process stress test spawns several of
            // these concurrently and asserts no index entry is lost.
            use qrlora::store::{AdapterKey, AdapterRecord, RecordMeta};
            use qrlora::tensor::Tensor;
            let records = args.usize_or("records", 8)?;
            let writer = args.u64_or("writer-id", 0)?;
            for j in 0..records {
                let mut params = std::collections::BTreeMap::new();
                params.insert("head/wc".to_string(), Tensor::zeros(&[2, 2]));
                let record = AdapterRecord {
                    meta: RecordMeta {
                        key: AdapterKey::new("tiny", "stress", &format!("t{j}"), writer),
                        manifest_fp: 1,
                        backbone_fp: 2,
                        backbone_repr: "f32".to_string(),
                        n_classes: 2,
                        eval_metric: 0.0,
                        steps: 0,
                        train_ms: 0.0,
                        created_unix: qrlora::store::unix_now_or_zero(),
                    },
                    params,
                    adam: None,
                };
                reg.publish_merged(&record)?;
            }
            println!(
                "stress-publish: writer {writer} published {records} record(s); \
                 index now holds {}",
                reg.len()
            );
            Ok(())
        }
        other => {
            anyhow::bail!("unknown adapters subcommand {other:?} (list|verify|gc|stress-publish)")
        }
    }
}
