//! Semantic partition of the synthetic vocabulary.
//!
//! Content ids are split into fields (negation, sentiment, relations,
//! question/answer types, agreement determiners/nouns) plus per-genre
//! entity and filler pools. All ranges scale with the vocabulary so the
//! same generators work for every preset.

use super::vocab::Vocab;
use crate::util::rng::Rng;

pub const N_GENRES: usize = 5;

/// An index range into the content-word space.
#[derive(Clone, Copy, Debug)]
pub struct Field {
    pub start: usize,
    pub len: usize,
}

impl Field {
    /// Sample a content index from this field.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        self.start + rng.below(self.len)
    }

    pub fn contains(&self, idx: usize) -> bool {
        idx >= self.start && idx < self.start + self.len
    }

    pub fn nth(&self, i: usize) -> usize {
        self.start + (i % self.len)
    }
}

/// The full semantic partition.
#[derive(Clone, Debug)]
pub struct Lexicon {
    pub vocab: Vocab,
    pub negation: Field,
    pub sent_pos: Field,
    pub sent_neg: Field,
    /// Relations come in synonym pairs: rel 2k and 2k+1 are synonyms.
    pub relations: Field,
    pub qtypes: Field,
    pub atypes: Field,
    pub det_sg: Field,
    pub det_pl: Field,
    pub noun_sg: Field,
    pub noun_pl: Field,
    pub entities: [Field; N_GENRES],
    pub fillers: [Field; N_GENRES],
}

impl Lexicon {
    pub fn new(vocab_size: usize) -> Lexicon {
        let vocab = Vocab::synthetic(vocab_size);
        let n = vocab_size - super::vocab::N_RESERVED as usize;
        // Fixed-fraction partition (sums to < 1.0; remainder unused slack).
        let mut cursor = 0usize;
        let mut take = |frac: f64, min: usize| {
            let len = ((n as f64 * frac) as usize).max(min);
            let f = Field { start: cursor, len };
            cursor += len;
            f
        };
        let negation = take(0.01, 4);
        let sent_pos = take(0.05, 8);
        let sent_neg = take(0.05, 8);
        let relations = take(0.04, 8); // even count → synonym pairs
        let qtypes = take(0.015, 6);
        let atypes = take(0.015, 6);
        let det_sg = take(0.008, 3);
        let det_pl = take(0.008, 3);
        let noun_sg = take(0.04, 8);
        let noun_pl = take(0.04, 8);
        let per_genre_ent = ((n as f64 * 0.07) as usize).max(10);
        let per_genre_fill = ((n as f64 * 0.06) as usize).max(10);
        let entities = std::array::from_fn(|_| {
            let f = Field { start: cursor, len: per_genre_ent };
            cursor += per_genre_ent;
            f
        });
        let fillers = std::array::from_fn(|_| {
            let f = Field { start: cursor, len: per_genre_fill };
            cursor += per_genre_fill;
            f
        });
        assert!(
            cursor <= n,
            "lexicon partition overflows vocab: {cursor} > {n} (vocab_size {vocab_size})"
        );
        Lexicon {
            vocab,
            negation,
            sent_pos,
            sent_neg,
            relations,
            qtypes,
            atypes,
            det_sg,
            det_pl,
            noun_sg,
            noun_pl,
            entities,
            fillers,
        }
    }

    /// Token id for a content index.
    pub fn id(&self, content_idx: usize) -> u32 {
        self.vocab.content_id(content_idx)
    }

    /// The synonym partner of a relation index.
    pub fn rel_synonym(&self, rel_idx: usize) -> usize {
        let local = rel_idx - self.relations.start;
        self.relations.start + (local ^ 1).min(self.relations.len - 1)
    }

    /// The answer-type paired with a question-type (same local index).
    pub fn atype_for(&self, qtype_idx: usize) -> usize {
        self.atypes.start + (qtype_idx - self.qtypes.start) % self.atypes.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_tiny_vocab() {
        let lex = Lexicon::new(512);
        assert!(lex.fillers[N_GENRES - 1].start + lex.fillers[N_GENRES - 1].len <= 512 - 5);
    }

    #[test]
    fn fits_small_vocab() {
        let _ = Lexicon::new(4096);
    }

    #[test]
    fn fields_disjoint() {
        let lex = Lexicon::new(1024);
        let mut fields = vec![
            lex.negation, lex.sent_pos, lex.sent_neg, lex.relations,
            lex.qtypes, lex.atypes, lex.det_sg, lex.det_pl,
            lex.noun_sg, lex.noun_pl,
        ];
        fields.extend_from_slice(&lex.entities);
        fields.extend_from_slice(&lex.fillers);
        for (i, a) in fields.iter().enumerate() {
            for b in &fields[i + 1..] {
                let overlap = a.start < b.start + b.len && b.start < a.start + a.len;
                assert!(!overlap, "{a:?} overlaps {b:?}");
            }
        }
    }

    #[test]
    fn synonym_is_involution() {
        let lex = Lexicon::new(1024);
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let r = lex.relations.sample(&mut rng);
            let s = lex.rel_synonym(r);
            assert!(lex.relations.contains(s));
            if lex.relations.len % 2 == 0 {
                assert_eq!(lex.rel_synonym(s), r);
            }
        }
    }

    #[test]
    fn sampling_stays_in_field() {
        let lex = Lexicon::new(512);
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            assert!(lex.sent_pos.contains(lex.sent_pos.sample(&mut rng)));
            let g = rng.below(N_GENRES);
            assert!(lex.entities[g].contains(lex.entities[g].sample(&mut rng)));
        }
    }

    #[test]
    fn atype_pairing_consistent() {
        let lex = Lexicon::new(1024);
        let q0 = lex.qtypes.start;
        let q1 = lex.qtypes.start + 1;
        assert_ne!(lex.atype_for(q0), lex.atype_for(q1));
        assert!(lex.atypes.contains(lex.atype_for(q0)));
    }
}
