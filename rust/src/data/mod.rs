//! Synthetic GLUE suite — the data substitution described in DESIGN.md §3.
//!
//! Each of the paper's eight GLUE tasks is mirrored by a generator that
//! plants a *latent rule* a transformer must learn (entity/relation
//! matching, negation, agreement, compositional entailment, lexical
//! overlap), with the task's class structure, metric, data sizes, and —
//! for MNLI — genre-based matched/mismatched evaluation all preserved.

mod batch;
mod lexicon;
mod tasks;
pub mod vocab;

pub use batch::{Batch, Batcher};
pub use lexicon::{Lexicon, N_GENRES};
pub use tasks::{gen_example, Example, Label, Split, TaskData, TaskSpec, ALL_TASKS};

use crate::metrics::MetricKind;

/// Head type a task trains on the device graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HeadKind {
    Cls,
    Reg,
}

impl HeadKind {
    pub fn artifact_suffix(&self) -> &'static str {
        match self {
            HeadKind::Cls => "cls",
            HeadKind::Reg => "reg",
        }
    }
}

/// Look up a task spec by name.
pub fn task(name: &str) -> anyhow::Result<&'static TaskSpec> {
    ALL_TASKS
        .iter()
        .find(|t| t.name == name)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "unknown task {name:?} (have: {})",
                ALL_TASKS.iter().map(|t| t.name).collect::<Vec<_>>().join(", ")
            )
        })
}

/// The headline metric for a task (GLUE conventions).
pub fn metric_kind(name: &str) -> MetricKind {
    match name {
        "mrpc" | "qqp" => MetricKind::AccuracyAndF1,
        "cola" => MetricKind::Matthews,
        "stsb" => MetricKind::PearsonSpearman,
        _ => MetricKind::Accuracy,
    }
}
