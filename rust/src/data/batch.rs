//! Batching: examples → fixed-shape device tensors matching the manifest's
//! batch specs ([CLS] a [SEP] b [SEP], padding, type ids, masks).

use super::tasks::{Example, Label};
use super::vocab::{CLS, PAD, SEP};
use crate::runtime::Preset;
use crate::util::rng::Rng;

/// A fully assembled batch, host side.
#[derive(Clone, Debug)]
pub struct Batch {
    pub input_ids: Vec<i32>,
    pub type_ids: Vec<i32>,
    pub attn_mask: Vec<f32>,
    /// i32 class labels (classification) — parallel to examples.
    pub labels_i32: Vec<i32>,
    /// f32 targets (regression).
    pub labels_f32: Vec<f32>,
    /// 1.0 for real examples, 0.0 for tail padding.
    pub example_w: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
    pub n_real: usize,
}

/// Assembles batches for one preset.
#[derive(Clone, Debug)]
pub struct Batcher {
    pub batch: usize,
    pub seq: usize,
    regression: bool,
}

impl Batcher {
    pub fn new(preset: &Preset, regression: bool) -> Batcher {
        Batcher {
            batch: preset.batch,
            seq: preset.max_seq,
            regression,
        }
    }

    /// Encode one example into (ids, types) of length `seq`.
    fn encode(&self, ex: &Example) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
        let mut ids = vec![CLS as i32];
        let mut types = vec![0i32];
        for &t in &ex.a {
            ids.push(t as i32);
            types.push(0);
        }
        ids.push(SEP as i32);
        types.push(0);
        if !ex.b.is_empty() {
            for &t in &ex.b {
                ids.push(t as i32);
                types.push(1);
            }
            ids.push(SEP as i32);
            types.push(1);
        }
        ids.truncate(self.seq);
        types.truncate(self.seq);
        let used = ids.len();
        let mut mask = vec![1.0f32; used];
        ids.resize(self.seq, PAD as i32);
        types.resize(self.seq, 0);
        mask.resize(self.seq, 0.0);
        (ids, types, mask)
    }

    /// Build a batch from up to `batch` examples; short batches are padded
    /// with zero-weight copies of the first example.
    pub fn assemble(&self, examples: &[&Example]) -> Batch {
        assert!(!examples.is_empty() && examples.len() <= self.batch);
        let n_real = examples.len();
        let mut b = Batch {
            input_ids: Vec::with_capacity(self.batch * self.seq),
            type_ids: Vec::with_capacity(self.batch * self.seq),
            attn_mask: Vec::with_capacity(self.batch * self.seq),
            labels_i32: Vec::with_capacity(self.batch),
            labels_f32: Vec::with_capacity(self.batch),
            example_w: Vec::with_capacity(self.batch),
            batch: self.batch,
            seq: self.seq,
            n_real,
        };
        for i in 0..self.batch {
            let (ex, w) = if i < n_real {
                (examples[i], 1.0)
            } else {
                (examples[0], 0.0)
            };
            let (ids, types, mask) = self.encode(ex);
            b.input_ids.extend(ids);
            b.type_ids.extend(types);
            b.attn_mask.extend(mask);
            match ex.label {
                Label::Class(c) => {
                    b.labels_i32.push(c as i32);
                    b.labels_f32.push(c as f32);
                }
                Label::Score(s) => {
                    b.labels_i32.push(0);
                    b.labels_f32.push(s);
                }
            }
            b.example_w.push(w);
        }
        b
    }

    /// Iterate a dataset in shuffled minibatches (one epoch).
    pub fn epoch<'a>(&'a self, data: &'a [Example], rng: &mut Rng) -> Vec<Vec<&'a Example>> {
        let mut order: Vec<usize> = (0..data.len()).collect();
        rng.shuffle(&mut order);
        order
            .chunks(self.batch)
            .map(|chunk| chunk.iter().map(|&i| &data[i]).collect())
            .collect()
    }

    /// Class-mask vector for a task with `n_classes` (padded head width `k`).
    pub fn class_mask(n_classes: usize, k: usize) -> Vec<f32> {
        (0..k).map(|i| if i < n_classes { 1.0 } else { 0.0 }).collect()
    }

    pub fn is_regression(&self) -> bool {
        self.regression
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{task, Lexicon, TaskData};

    fn preset() -> Preset {
        Preset {
            name: "tiny".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            d_ff: 256,
            vocab: 512,
            max_seq: 32,
            batch: 8,
            r_max: 32,
            r_lora: 2,
            n_classes: 3,
        }
    }

    fn data(name: &str) -> TaskData {
        let lex = Lexicon::new(512);
        TaskData::generate(task(name).unwrap(), &lex, 21)
    }

    #[test]
    fn batch_shapes() {
        let d = data("mnli");
        let b = Batcher::new(&preset(), false);
        let refs: Vec<&Example> = d.train[..8].iter().collect();
        let batch = b.assemble(&refs);
        assert_eq!(batch.input_ids.len(), 8 * 32);
        assert_eq!(batch.attn_mask.len(), 8 * 32);
        assert_eq!(batch.labels_i32.len(), 8);
        assert_eq!(batch.example_w, vec![1.0; 8]);
    }

    #[test]
    fn cls_and_sep_structure() {
        let d = data("mrpc");
        let b = Batcher::new(&preset(), false);
        let refs: Vec<&Example> = d.train[..1].iter().collect();
        let batch = b.assemble(&refs);
        assert_eq!(batch.input_ids[0], CLS as i32);
        let sep_count = batch.input_ids[..32]
            .iter()
            .filter(|&&t| t == SEP as i32)
            .count();
        assert_eq!(sep_count, 2, "pair tasks carry two separators");
        // type ids flip to 1 in the second segment
        assert!(batch.type_ids[..32].iter().any(|&t| t == 1));
    }

    #[test]
    fn short_batch_padded_with_zero_weight() {
        let d = data("sst2");
        let b = Batcher::new(&preset(), false);
        let refs: Vec<&Example> = d.train[..3].iter().collect();
        let batch = b.assemble(&refs);
        assert_eq!(batch.n_real, 3);
        assert_eq!(&batch.example_w[..3], &[1.0, 1.0, 1.0]);
        assert_eq!(&batch.example_w[3..], &[0.0; 5]);
    }

    #[test]
    fn mask_zero_past_content() {
        let d = data("sst2");
        let b = Batcher::new(&preset(), false);
        let refs: Vec<&Example> = d.train[..1].iter().collect();
        let batch = b.assemble(&refs);
        let used = 1 + d.train[0].a.len().min(30) + 1;
        for s in 0..32 {
            let want = if s < used.min(32) { 1.0 } else { 0.0 };
            assert_eq!(batch.attn_mask[s], want, "pos {s}");
        }
        for s in used..32 {
            assert_eq!(batch.input_ids[s], PAD as i32);
        }
    }

    #[test]
    fn epoch_covers_all_examples() {
        let d = data("rte");
        let b = Batcher::new(&preset(), false);
        let mut rng = Rng::new(5);
        let batches = b.epoch(&d.train[..100], &mut rng);
        let total: usize = batches.iter().map(|x| x.len()).sum();
        assert_eq!(total, 100);
        assert_eq!(batches.len(), 13); // ceil(100/8)
    }

    #[test]
    fn class_mask_padding() {
        assert_eq!(Batcher::class_mask(2, 3), vec![1.0, 1.0, 0.0]);
        assert_eq!(Batcher::class_mask(3, 3), vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn regression_labels_flow() {
        let d = data("stsb");
        let b = Batcher::new(&preset(), true);
        let refs: Vec<&Example> = d.train[..4].iter().collect();
        let batch = b.assemble(&refs);
        assert!(batch.labels_f32.iter().take(4).all(|&s| (0.0..=5.0).contains(&s)));
    }
}
