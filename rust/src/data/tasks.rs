//! The eight GLUE-analogue task generators.
//!
//! Latent rules (what the model must learn):
//! * `mnli`  — 3-way NLI over (entity, relation, entity) facts: entailment =
//!   relation-synonym paraphrase, neutral = different relation/object,
//!   contradiction = negated paraphrase. Genres partition the lexicon;
//!   matched eval draws from the training genres, mismatched from held-out.
//! * `rte`   — *compositional* 2-way entailment: the premise states two
//!   chained facts (a r1 b, b r2 c) and the hypothesis claims (a r3 c);
//!   entailed iff r3 equals the composition table's entry for (r1, r2).
//!   Only 2.5k train examples — the paper's low-resource anomaly task.
//! * `mrpc`/`qqp` — paraphrase detection: positives share content with
//!   synonym substitution + filler shuffling, negatives perturb one
//!   content token (hard negatives).
//! * `sst2`  — sentiment: sum of sentiment-token polarities, negation
//!   markers flip the token that follows.
//! * `cola`  — acceptability: determiner–noun number agreement plus a
//!   no-relation-initial word-order constraint.
//! * `qnli`  — question answerability: the passage answers the question iff
//!   it contains the answer-type paired with the question-type AND the
//!   question's entity.
//! * `stsb`  — similarity regression: score ∝ content-token overlap.

use super::lexicon::{Lexicon, N_GENRES};
use super::HeadKind;
use crate::util::rng::Rng;

/// Task label.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Label {
    Class(usize),
    Score(f32),
}

/// One generated example (token ids, pre-[CLS]/[SEP] assembly).
#[derive(Clone, Debug)]
pub struct Example {
    pub a: Vec<u32>,
    pub b: Vec<u32>,
    pub label: Label,
    pub genre: usize,
}

/// Static description of a task.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub name: &'static str,
    pub n_classes: usize,
    pub head: HeadKind,
    pub train_size: usize,
    pub dev_size: usize,
    pub train_genres: &'static [usize],
    /// Mismatched-eval genres (MNLI only).
    pub mm_genres: Option<&'static [usize]>,
    /// Fraction of examples whose label is resampled uniformly — injects a
    /// Bayes-error floor so methods have headroom to differ (the synthetic
    /// rules are otherwise perfectly separable, unlike real GLUE text).
    pub label_noise: f64,
}

#[rustfmt::skip] // one row per task reads as a table; keep it that way
pub static ALL_TASKS: &[TaskSpec] = &[
    TaskSpec { name: "mnli", n_classes: 3, head: HeadKind::Cls, train_size: 50_000, dev_size: 2_000, train_genres: &[0, 1, 2], mm_genres: Some(&[3, 4]), label_noise: 0.22 },
    TaskSpec { name: "sst2", n_classes: 2, head: HeadKind::Cls, train_size: 10_000, dev_size: 2_000, train_genres: &[0, 1, 2], mm_genres: None, label_noise: 0.08 },
    TaskSpec { name: "mrpc", n_classes: 2, head: HeadKind::Cls, train_size: 3_700, dev_size: 1_700, train_genres: &[1, 2], mm_genres: None, label_noise: 0.10 },
    TaskSpec { name: "cola", n_classes: 2, head: HeadKind::Cls, train_size: 8_500, dev_size: 1_000, train_genres: &[0, 2, 3], mm_genres: None, label_noise: 0.12 },
    TaskSpec { name: "qnli", n_classes: 2, head: HeadKind::Cls, train_size: 10_000, dev_size: 2_000, train_genres: &[0, 1, 3], mm_genres: None, label_noise: 0.08 },
    TaskSpec { name: "qqp", n_classes: 2, head: HeadKind::Cls, train_size: 10_000, dev_size: 2_000, train_genres: &[1, 3], mm_genres: None, label_noise: 0.10 },
    TaskSpec { name: "rte", n_classes: 2, head: HeadKind::Cls, train_size: 2_500, dev_size: 1_000, train_genres: &[0, 1, 2, 3, 4], mm_genres: None, label_noise: 0.05 },
    TaskSpec { name: "stsb", n_classes: 1, head: HeadKind::Reg, train_size: 5_700, dev_size: 1_500, train_genres: &[0, 1, 2], mm_genres: None, label_noise: 0.0 },
];

fn fillers(lex: &Lexicon, rng: &mut Rng, genre: usize, n: usize) -> Vec<u32> {
    (0..n).map(|_| lex.id(lex.fillers[genre].sample(rng))).collect()
}

/// Relation-composition table for RTE: comp(r1, r2) is a fixed pseudo-random
/// relation index (deterministic in the pair).
fn compose(lex: &Lexicon, r1: usize, r2: usize) -> usize {
    // Bucketed composition: only the relation *classes* (mod 4) matter, so
    // the table has 16 entries — hard (second-order) but learnable from the
    // 2.5k examples RTE provides.
    let l1 = (r1 - lex.relations.start) % 4;
    let l2 = (r2 - lex.relations.start) % 4;
    lex.relations.start + (l1 * 7 + l2 * 3 + 1) % lex.relations.len.min(16)
}

fn gen_mnli(lex: &Lexicon, rng: &mut Rng, genre: usize, label: usize) -> Example {
    let ea = lex.id(lex.entities[genre].sample(rng));
    let rel = lex.relations.sample(rng);
    let eb = lex.id(lex.entities[genre].sample(rng));
    let mut a = vec![ea, lex.id(rel), eb];
    let nf = rng.range(2, 6);
    a.extend(fillers(lex, rng, genre, nf));
    let syn = lex.id(lex.rel_synonym(rel));
    let b = match label {
        0 => vec![ea, syn, eb], // entailment: synonym paraphrase
        1 => {
            // neutral: same subject, different relation and object
            let mut rel2 = lex.relations.sample(rng);
            while rel2 == rel || rel2 == lex.rel_synonym(rel) {
                rel2 = lex.relations.sample(rng);
            }
            let mut ec = lex.id(lex.entities[genre].sample(rng));
            while ec == eb {
                ec = lex.id(lex.entities[genre].sample(rng));
            }
            vec![ea, lex.id(rel2), ec]
        }
        _ => {
            // contradiction: negated paraphrase
            let neg = lex.id(lex.negation.sample(rng));
            vec![neg, ea, syn, eb]
        }
    };
    Example { a, b, label: Label::Class(label), genre }
}

fn gen_rte(lex: &Lexicon, rng: &mut Rng, genre: usize, label: usize) -> Example {
    let ea = lex.id(lex.entities[genre].sample(rng));
    let eb = lex.id(lex.entities[genre].sample(rng));
    let ec = lex.id(lex.entities[genre].sample(rng));
    let r1 = lex.relations.sample(rng);
    let r2 = lex.relations.sample(rng);
    let comp = compose(lex, r1, r2);
    let mut a = vec![ea, lex.id(r1), eb, lex.id(r2), ec];
    let nf = rng.range(1, 4);
    a.extend(fillers(lex, rng, genre, nf));
    let r3 = if label == 0 {
        comp // entailed: the composed relation
    } else {
        let mut r = lex.relations.sample(rng);
        while r == comp {
            r = lex.relations.sample(rng);
        }
        r
    };
    let b = vec![ea, lex.id(r3), ec];
    Example { a, b, label: Label::Class(label), genre }
}

fn gen_paraphrase(
    lex: &Lexicon,
    rng: &mut Rng,
    genre: usize,
    label: usize,
    question_style: bool,
) -> Example {
    let ea = lex.id(lex.entities[genre].sample(rng));
    let rel = lex.relations.sample(rng);
    let eb = lex.id(lex.entities[genre].sample(rng));
    let mut a = Vec::new();
    if question_style {
        a.push(lex.id(lex.qtypes.sample(rng)));
    }
    a.extend([ea, lex.id(rel), eb]);
    let nf = rng.range(1, 4);
    a.extend(fillers(lex, rng, genre, nf));

    let mut b = Vec::new();
    if question_style {
        b.push(a[0]);
    }
    if label == 1 {
        // paraphrase: echo the full content (synonym relation), so the
        // lexical-overlap signal is strong — mirrors the overlap cue real
        // paraphrase pairs carry.
        b.extend([ea, lex.id(lex.rel_synonym(rel)), eb, ea, eb]);
        let nf = rng.range(1, 3);
        b.extend(fillers(lex, rng, genre, nf));
    } else {
        // negative: non-synonym relation AND a different object (two-token
        // divergence, mirroring the signal MNLI's neutral class carries)
        let mut rel2 = lex.relations.sample(rng);
        while rel2 == rel || rel2 == lex.rel_synonym(rel) {
            rel2 = lex.relations.sample(rng);
        }
        let mut eb2 = lex.id(lex.entities[genre].sample(rng));
        while eb2 == eb {
            eb2 = lex.id(lex.entities[genre].sample(rng));
        }
        let mut ea2 = lex.id(lex.entities[genre].sample(rng));
        while ea2 == ea {
            ea2 = lex.id(lex.entities[genre].sample(rng));
        }
        b.extend([ea2, lex.id(rel2), eb2]);
        let nf = rng.range(1, 3);
        b.extend(fillers(lex, rng, genre, nf));
        let nf = rng.range(1, 4);
        b.extend(fillers(lex, rng, genre, nf));
    }
    Example { a, b, label: Label::Class(label), genre }
}

fn gen_sst2(lex: &Lexicon, rng: &mut Rng, genre: usize, label: usize) -> Example {
    // Build a sentence whose net polarity matches `label` (1 = positive).
    let want: i32 = if label == 1 { 1 } else { -1 };
    let nf = rng.range(2, 5);
    let mut a = fillers(lex, rng, genre, nf);
    let mut score = 0i32;
    let n_sent = rng.range(2, 5);
    for _ in 0..n_sent {
        let pos = rng.chance(0.5);
        let tok = if pos {
            lex.id(lex.sent_pos.sample(rng))
        } else {
            lex.id(lex.sent_neg.sample(rng))
        };
        let negated = rng.chance(0.25);
        if negated {
            a.push(lex.id(lex.negation.sample(rng)));
        }
        a.push(tok);
        score += if pos != negated { 1 } else { -1 };
    }
    // Force the net score to the wanted sign by appending unambiguous
    // sentiment tokens.
    while score * want <= 0 {
        let tok = if want > 0 {
            lex.id(lex.sent_pos.sample(rng))
        } else {
            lex.id(lex.sent_neg.sample(rng))
        };
        a.push(tok);
        score += want;
    }
    let nf = rng.range(0, 3);
    a.extend(fillers(lex, rng, genre, nf));
    Example { a, b: Vec::new(), label: Label::Class(label), genre }
}

fn gen_cola(lex: &Lexicon, rng: &mut Rng, genre: usize, label: usize) -> Example {
    // Acceptable: all det–noun pairs agree in number AND no relation token
    // sentence-initial. Unacceptable: violate one of the two rules.
    let n_pairs = rng.range(1, 3);
    let mut a = Vec::new();
    a.extend(fillers(lex, rng, genre, 1)); // safe non-initial start
    let mut pairs = Vec::new();
    for _ in 0..n_pairs {
        let sg = rng.chance(0.5);
        let (det, noun) = if sg {
            (lex.det_sg.sample(rng), lex.noun_sg.sample(rng))
        } else {
            (lex.det_pl.sample(rng), lex.noun_pl.sample(rng))
        };
        pairs.push((det, noun, sg));
    }
    if label == 0 {
        // corrupt: either break one agreement or move a relation to front
        if rng.chance(0.7) {
            let k = rng.below(pairs.len());
            let (_, _, sg) = pairs[k];
            // mismatched noun number
            let noun = if sg {
                lex.noun_pl.sample(rng)
            } else {
                lex.noun_sg.sample(rng)
            };
            pairs[k].1 = noun;
        } else {
            a.insert(0, lex.id(lex.relations.sample(rng)));
        }
    }
    for (det, noun, _) in &pairs {
        a.push(lex.id(*det));
        a.push(lex.id(*noun));
        if rng.chance(0.4) {
            a.extend(fillers(lex, rng, genre, 1));
        }
    }
    a.push(lex.id(lex.relations.sample(rng))); // non-initial relation is fine
    let nf = rng.range(0, 3);
    a.extend(fillers(lex, rng, genre, nf));
    Example { a, b: Vec::new(), label: Label::Class(label), genre }
}

fn gen_qnli(lex: &Lexicon, rng: &mut Rng, genre: usize, label: usize) -> Example {
    // Answerable iff the passage contains the SAME question-type token as the
    // question AND mentions the question's entity (identity matching — the
    // mechanism a small encoder learns reliably).
    let qt = lex.id(lex.qtypes.sample(rng));
    let ea = lex.id(lex.entities[genre].sample(rng));
    let a = vec![qt, ea];

    let rel = lex.relations.sample(rng);
    let eb = lex.id(lex.entities[genre].sample(rng));
    let mut b = vec![ea, lex.id(rel), eb];
    if label == 0 {
        b.push(qt); // answerable: echoes the question type
    } else if rng.chance(0.5) {
        // wrong question type echoed
        let mut qt2 = lex.id(lex.qtypes.sample(rng));
        while qt2 == qt {
            qt2 = lex.id(lex.qtypes.sample(rng));
        }
        b.push(qt2);
    } else {
        // right type but wrong entity
        let mut ea2 = lex.id(lex.entities[genre].sample(rng));
        while ea2 == ea {
            ea2 = lex.id(lex.entities[genre].sample(rng));
        }
        b[0] = ea2;
        b.push(qt);
    }
    let nf = rng.range(1, 3);
    b.extend(fillers(lex, rng, genre, nf));
    Example { a, b, label: Label::Class(label), genre }
}

fn gen_stsb(lex: &Lexicon, rng: &mut Rng, genre: usize) -> Example {
    // Similarity = fraction of sentence-a content echoed in sentence b.
    // b carries `keep` of a's entity tokens (same order) and fillers for the
    // rest, so the graded signal is carried by *which and how many* content
    // tokens recur — learnable by a small encoder, graded like STS-B.
    let n = 4;
    let a: Vec<u32> = (0..n)
        .map(|_| lex.id(lex.entities[genre].sample(rng)))
        .collect();
    let keep = rng.below(n + 1); // 0..=n echoed tokens
    let mut b: Vec<u32> = a[..keep].to_vec();
    let nf = n - keep + 1;
    b.extend(fillers(lex, rng, genre, nf));
    // Paper-scale score in [0, 5]; correlation metrics are scale-invariant.
    let score = 5.0 * keep as f32 / n as f32;
    Example { a, b, label: Label::Score(score), genre }
}

/// Generate one example for `task` in `genre` with a chosen label bucket
/// (round-robin over classes keeps datasets balanced; stsb ignores it).
pub fn gen_example(
    spec: &TaskSpec,
    lex: &Lexicon,
    rng: &mut Rng,
    genre: usize,
    bucket: usize,
) -> Example {
    assert!(genre < N_GENRES);
    match spec.name {
        "mnli" => gen_mnli(lex, rng, genre, bucket % 3),
        "rte" => gen_rte(lex, rng, genre, bucket % 2),
        "mrpc" => gen_paraphrase(lex, rng, genre, if bucket % 3 == 0 { 0 } else { 1 }, false),
        "qqp" => gen_paraphrase(lex, rng, genre, if bucket % 8 < 3 { 1 } else { 0 }, true),
        "sst2" => gen_sst2(lex, rng, genre, bucket % 2),
        "cola" => gen_cola(lex, rng, genre, if bucket % 10 < 7 { 1 } else { 0 }),
        "qnli" => gen_qnli(lex, rng, genre, bucket % 2),
        "stsb" => gen_stsb(lex, rng, genre),
        other => panic!("unknown task {other}"),
    }
}

/// Which split of a task's data.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Dev,
    /// MNLI only: dev drawn from held-out genres.
    DevMismatched,
}

/// Materialized datasets for one task.
#[derive(Clone, Debug)]
pub struct TaskData {
    pub spec: &'static TaskSpec,
    pub train: Vec<Example>,
    pub dev: Vec<Example>,
    pub dev_mm: Vec<Example>,
}

impl TaskData {
    /// Deterministically generate all splits.
    pub fn generate(spec: &'static TaskSpec, lex: &Lexicon, seed: u64) -> TaskData {
        let gen_split = |tag: u64, n: usize, genres: &[usize]| -> Vec<Example> {
            let mut rng = Rng::new(seed ^ 0x9E37_79B9 ^ tag.wrapping_mul(0x1000_0001));
            (0..n)
                .map(|i| {
                    let genre = genres[i % genres.len()];
                    let mut ex = gen_example(spec, lex, &mut rng, genre, i);
                    if spec.label_noise > 0.0 && rng.chance(spec.label_noise) {
                        if let Label::Class(_) = ex.label {
                            ex.label = Label::Class(rng.below(spec.n_classes));
                        }
                    }
                    ex
                })
                .collect()
        };
        let train = gen_split(1, spec.train_size, spec.train_genres);
        let dev = gen_split(2, spec.dev_size, spec.train_genres);
        let dev_mm = match spec.mm_genres {
            Some(g) => gen_split(3, spec.dev_size, g),
            None => Vec::new(),
        };
        TaskData { spec, train, dev, dev_mm }
    }

    pub fn split(&self, s: Split) -> &[Example] {
        match s {
            Split::Train => &self.train,
            Split::Dev => &self.dev,
            Split::DevMismatched => &self.dev_mm,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::task;

    fn lex() -> Lexicon {
        Lexicon::new(512)
    }

    #[test]
    fn all_tasks_generate() {
        let lex = lex();
        let mut rng = Rng::new(3);
        for spec in ALL_TASKS {
            for i in 0..50 {
                let g = spec.train_genres[i % spec.train_genres.len()];
                let ex = gen_example(spec, &lex, &mut rng, g, i);
                assert!(!ex.a.is_empty(), "{}: empty sentence", spec.name);
                match ex.label {
                    Label::Class(c) => assert!(c < spec.n_classes.max(2), "{}", spec.name),
                    Label::Score(s) => assert!((0.0..=5.0).contains(&s), "{}", spec.name),
                }
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let lex = lex();
        let spec = task("mrpc").unwrap();
        let d1 = TaskData::generate(spec, &lex, 7);
        let d2 = TaskData::generate(spec, &lex, 7);
        assert_eq!(d1.train.len(), d2.train.len());
        for (a, b) in d1.train.iter().zip(&d2.train) {
            assert_eq!(a.a, b.a);
            assert_eq!(a.b, b.b);
            assert_eq!(a.label, b.label);
        }
    }

    #[test]
    fn seeds_change_data() {
        let lex = lex();
        let spec = task("sst2").unwrap();
        let d1 = TaskData::generate(spec, &lex, 1);
        let d2 = TaskData::generate(spec, &lex, 2);
        let same = d1.train.iter().zip(&d2.train).filter(|(a, b)| a.a == b.a).count();
        assert!(same < d1.train.len() / 10);
    }

    #[test]
    fn sizes_match_spec() {
        let lex = lex();
        for name in ["rte", "mrpc"] {
            let spec = task(name).unwrap();
            let d = TaskData::generate(spec, &lex, 5);
            assert_eq!(d.train.len(), spec.train_size);
            assert_eq!(d.dev.len(), spec.dev_size);
        }
    }

    #[test]
    fn rte_is_small() {
        assert_eq!(task("rte").unwrap().train_size, 2_500);
    }

    #[test]
    fn mnli_genre_split_is_disjoint() {
        let lex = lex();
        let spec = task("mnli").unwrap();
        let mut d = TaskData::generate(spec, &lex, 9);
        d.train.truncate(2000);
        let train_genres: std::collections::HashSet<_> =
            d.train.iter().map(|e| e.genre).collect();
        let mm_genres: std::collections::HashSet<_> =
            d.dev_mm.iter().map(|e| e.genre).collect();
        assert!(train_genres.is_disjoint(&mm_genres));
        assert!(!d.dev_mm.is_empty());
    }

    #[test]
    fn labels_balanced() {
        let lex = lex();
        for name in ["mnli", "sst2", "qnli", "rte"] {
            let spec = task(name).unwrap();
            let mut d = TaskData::generate(spec, &lex, 11);
            d.train.truncate(3000);
            let mut counts = [0usize; 3];
            for e in &d.train {
                if let Label::Class(c) = e.label {
                    counts[c] += 1;
                }
            }
            let total: usize = counts[..spec.n_classes].iter().sum();
            for c in 0..spec.n_classes {
                let frac = counts[c] as f64 / total as f64;
                assert!(
                    frac > 0.8 / spec.n_classes as f64,
                    "{name}: class {c} frac {frac}"
                );
            }
        }
    }

    #[test]
    fn mrpc_positive_skew() {
        // MRPC is ~2:1 positive in GLUE; generator mirrors that.
        let lex = lex();
        let spec = task("mrpc").unwrap();
        let d = TaskData::generate(spec, &lex, 13);
        let pos = d.train.iter().filter(|e| e.label == Label::Class(1)).count();
        let frac = pos as f64 / d.train.len() as f64;
        assert!((0.6..0.75).contains(&frac), "pos frac {frac}");
    }

    #[test]
    fn stsb_scores_cover_range() {
        let lex = lex();
        let spec = task("stsb").unwrap();
        let d = TaskData::generate(spec, &lex, 15);
        let scores: Vec<f32> = d
            .train
            .iter()
            .map(|e| match e.label {
                Label::Score(s) => s,
                _ => panic!(),
            })
            .collect();
        assert!(scores.iter().any(|&s| s < 1.0));
        assert!(scores.iter().any(|&s| s > 4.0));
    }

    #[test]
    fn sst2_label_matches_polarity_rule() {
        // Recompute the latent rule from the surface tokens and check it
        // agrees with the generated label.
        let lex = lex();
        let spec = task("sst2").unwrap();
        let mut rng = Rng::new(17);
        for i in 0..200 {
            let ex = gen_example(spec, &lex, &mut rng, 0, i);
            let mut score = 0i32;
            let mut negate = false;
            for &tok in &ex.a {
                // Reverse-map token id to content index.
                let idx = (tok - super::super::vocab::N_RESERVED) as usize;
                if lex.negation.contains(idx) {
                    negate = true;
                } else if lex.sent_pos.contains(idx) {
                    score += if negate { -1 } else { 1 };
                    negate = false;
                } else if lex.sent_neg.contains(idx) {
                    score += if negate { 1 } else { -1 };
                    negate = false;
                } else {
                    negate = false;
                }
            }
            let want = if score > 0 { 1 } else { 0 };
            assert_eq!(ex.label, Label::Class(want), "example {i}");
        }
    }
}
