//! Synthetic vocabulary + word-level tokenizer.
//!
//! The GLUE substitution (DESIGN.md §3) generates text over a synthetic
//! lexicon: pronounceable CV-syllable words partitioned into *genres* and
//! *semantic fields* (entities, relations, sentiment, fillers). The
//! tokenizer is word-level — the lexicon is closed by construction, so BPE
//! would be an identity transform; OOV still maps to `UNK` for robustness.

/// Reserved token ids (match `python/compile/model.py` conventions).
pub const PAD: u32 = 0;
pub const CLS: u32 = 1;
pub const SEP: u32 = 2;
pub const MASK: u32 = 3;
pub const UNK: u32 = 4;
pub const N_RESERVED: u32 = 5;

const CONSONANTS: &[&str] = &[
    "b", "d", "f", "g", "k", "l", "m", "n", "p", "r", "s", "t", "v", "z", "ch", "sh",
];
const VOWELS: &[&str] = &["a", "e", "i", "o", "u"];

/// Deterministically generate the `i`-th synthetic word (2–3 syllables).
pub fn word(i: usize) -> String {
    let nc = CONSONANTS.len();
    let nv = VOWELS.len();
    let s1 = format!("{}{}", CONSONANTS[i % nc], VOWELS[(i / nc) % nv]);
    let j = i / (nc * nv);
    let s2 = format!("{}{}", CONSONANTS[(j + 3) % nc], VOWELS[(j / nc + 1) % nv]);
    let k = j / (nc * nv);
    if k == 0 {
        format!("{s1}{s2}")
    } else {
        let s3 = format!("{}{}", CONSONANTS[(k + 7) % nc], VOWELS[(k + 2) % nv]);
        format!("{s1}{s2}{s3}")
    }
}

/// A closed word-level vocabulary.
#[derive(Clone, Debug)]
pub struct Vocab {
    words: Vec<String>,
    index: std::collections::HashMap<String, u32>,
}

impl Vocab {
    /// Build the synthetic lexicon with `size` total ids (incl. reserved).
    pub fn synthetic(size: usize) -> Vocab {
        assert!(size > N_RESERVED as usize + 16, "vocab too small: {size}");
        let n_words = size - N_RESERVED as usize;
        let mut words = Vec::with_capacity(n_words);
        let mut index = std::collections::HashMap::new();
        for i in 0..n_words {
            let w = word(i);
            index.entry(w.clone()).or_insert(N_RESERVED + words.len() as u32);
            // `word` is injective over the ranges we use, but guard anyway.
            if index[&w] == N_RESERVED + words.len() as u32 {
                words.push(w);
            }
        }
        Vocab { words, index }
    }

    pub fn len(&self) -> usize {
        self.words.len() + N_RESERVED as usize
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    /// Encode one word to its id (UNK if unknown).
    pub fn encode_word(&self, w: &str) -> u32 {
        *self.index.get(w).unwrap_or(&UNK)
    }

    /// Encode a whitespace-separated sentence.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.split_whitespace().map(|w| self.encode_word(w)).collect()
    }

    /// Decode an id back to its surface form.
    pub fn decode_id(&self, id: u32) -> &str {
        match id {
            PAD => "[PAD]",
            CLS => "[CLS]",
            SEP => "[SEP]",
            MASK => "[MASK]",
            UNK => "[UNK]",
            _ => self
                .words
                .get((id - N_RESERVED) as usize)
                .map(|s| s.as_str())
                .unwrap_or("[UNK]"),
        }
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter().map(|&i| self.decode_id(i)).collect::<Vec<_>>().join(" ")
    }

    /// Id of the `i`-th content word (for generators that address the
    /// lexicon by index rather than surface form).
    pub fn content_id(&self, i: usize) -> u32 {
        N_RESERVED + (i % self.words.len()) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn words_are_distinct_prefix() {
        let mut seen = std::collections::HashSet::new();
        for i in 0..4000 {
            assert!(seen.insert(word(i)), "duplicate word at {i}: {}", word(i));
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let v = Vocab::synthetic(512);
        for i in 0..(512 - N_RESERVED as usize) {
            let w = word(i);
            let id = v.encode_word(&w);
            assert_eq!(v.decode_id(id), w);
        }
    }

    #[test]
    fn sentence_roundtrip() {
        let v = Vocab::synthetic(256);
        let sent = format!("{} {} {}", word(3), word(17), word(40));
        let ids = v.encode(&sent);
        assert_eq!(v.decode(&ids), sent);
    }

    #[test]
    fn unknown_maps_to_unk() {
        let v = Vocab::synthetic(128);
        assert_eq!(v.encode_word("xyzzy"), UNK);
    }

    #[test]
    fn content_id_in_range() {
        let v = Vocab::synthetic(512);
        for i in 0..2000 {
            let id = v.content_id(i);
            assert!(id >= N_RESERVED && (id as usize) < v.len());
        }
    }

    #[test]
    fn ids_below_vocab_size() {
        let v = Vocab::synthetic(512);
        assert_eq!(v.len(), 512);
        let ids = v.encode(&(0..100).map(word).collect::<Vec<_>>().join(" "));
        assert!(ids.iter().all(|&i| (i as usize) < 512));
    }
}
