//! Checkpoint I/O: a single-file format holding named f32 tensors, plus
//! raw state-vector save/load. Interops with nothing — it's the
//! coordinator's own durable format — but tensors can also be exported
//! per-leaf as `.npy`.
//!
//! The file body is the shared named-tensor codec from
//! [`crate::store::format`] (`u64`-length-prefixed JSON header + packed
//! little-endian f32 payload) behind a checkpoint magic — the same codec
//! the adapter store's record sections use, so there is exactly one
//! header/payload parser in the tree. Decoding is strict: truncated,
//! malformed, or trailing bytes are loud errors, never a panic or
//! silently-misread weights.

use std::collections::BTreeMap;
use std::path::Path;

use crate::store::format::{decode_tensors, encode_tensors};
use crate::tensor::Tensor;
use crate::util::json::Json;

const MAGIC: &[u8; 8] = b"QRLORA01";

/// Save a named tensor map. Atomic: streams magic + body into a
/// pid-unique temp sibling, then renames into place (same protocol as
/// the adapter store's `atomic_write`), so a crash mid-write can never
/// leave a torn file under the published name — concurrent readers (a
/// fleet sibling warming the same cache) see the old checkpoint or the
/// new one, never a truncated hybrid.
pub fn save_params(path: &Path, params: &BTreeMap<String, Tensor>) -> anyhow::Result<()> {
    use std::io::Write;
    crate::util::faults::io_fault("checkpoint")?;
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let body = encode_tensors(params);
    let tmp = path.with_extension(format!("tmp{}", std::process::id()));
    {
        // Write magic + body separately: concatenating into one Vec would
        // transiently double the footprint of a full-FT backbone checkpoint.
        let mut f = std::fs::File::create(&tmp)
            .map_err(|e| anyhow::anyhow!("cannot write {tmp:?}: {e}"))?;
        f.write_all(MAGIC)?;
        f.write_all(&body)?;
    }
    crate::util::faults::crash_point("checkpoint");
    std::fs::rename(&tmp, path)
        .map_err(|e| anyhow::anyhow!("cannot move {tmp:?} into place at {path:?}: {e}"))?;
    Ok(())
}

/// Load a named tensor map. Fails loudly on anything short of a complete,
/// well-formed checkpoint (bad magic, truncated header or payload,
/// trailing bytes, malformed entries).
pub fn load_params(path: &Path) -> anyhow::Result<BTreeMap<String, Tensor>> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("cannot open checkpoint {path:?}: {e}"))?;
    anyhow::ensure!(
        bytes.len() >= MAGIC.len() && &bytes[..MAGIC.len()] == MAGIC,
        "{path:?}: not a qrlora checkpoint"
    );
    decode_tensors(&format!("checkpoint {}", path.display()), &bytes[MAGIC.len()..])
}

/// Save a raw state vector with a tiny JSON sidecar for provenance.
/// Atomic like [`save_params`]: both the `.npy` and the sidecar go
/// through temp-then-rename.
pub fn save_state(path: &Path, state: &[f32], meta: &Json) -> anyhow::Result<()> {
    crate::util::faults::io_fault("checkpoint")?;
    let t = Tensor::from_vec(&[state.len()], state.to_vec());
    let tmp = path.with_extension(format!("tmp{}", std::process::id()));
    t.save_npy(&tmp)?;
    crate::util::faults::crash_point("checkpoint");
    std::fs::rename(&tmp, path)
        .map_err(|e| anyhow::anyhow!("cannot move {tmp:?} into place at {path:?}: {e}"))?;
    crate::store::atomic_write(&path.with_extension("json"), meta.pretty().as_bytes())?;
    Ok(())
}

/// Load a raw state vector.
pub fn load_state(path: &Path) -> anyhow::Result<Vec<f32>> {
    Ok(Tensor::load_npy(path)?.data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("qrlora_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn params_roundtrip() {
        let mut rng = Rng::new(1);
        let mut params = BTreeMap::new();
        params.insert("a/w".to_string(), Tensor::randn(&[3, 4], &mut rng, 1.0));
        params.insert("b".to_string(), Tensor::randn(&[7], &mut rng, 2.0));
        params.insert("empty_name/x".to_string(), Tensor::zeros(&[1]));
        let p = tmp("params.qck");
        save_params(&p, &params).unwrap();
        let loaded = load_params(&p).unwrap();
        assert_eq!(loaded, params);
    }

    #[test]
    fn state_roundtrip() {
        let state: Vec<f32> = (0..100).map(|i| i as f32 / 7.0).collect();
        let p = tmp("state.npy");
        save_state(&p, &state, &Json::obj(vec![("step", Json::num(5.0))])).unwrap();
        assert_eq!(load_state(&p).unwrap(), state);
        let meta = std::fs::read_to_string(p.with_extension("json")).unwrap();
        assert!(meta.contains("step"));
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("garbage.qck");
        std::fs::write(&p, b"not a checkpoint at all").unwrap();
        assert!(load_params(&p).is_err());
    }

    #[test]
    fn missing_file_reports_path() {
        let err = load_params(Path::new("/nonexistent/x.qck"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("x.qck"));
    }

    #[test]
    fn truncated_checkpoint_fails_loudly() {
        // A checkpoint cut at ANY byte boundary must be a clean error —
        // no panic (e.g. a giant header-length alloc), no silently
        // short-read tensors.
        let mut rng = Rng::new(2);
        let mut params = BTreeMap::new();
        params.insert("w".to_string(), Tensor::randn(&[5, 5], &mut rng, 1.0));
        params.insert("b".to_string(), Tensor::randn(&[5], &mut rng, 1.0));
        let p = tmp("trunc.qck");
        save_params(&p, &params).unwrap();
        let full = std::fs::read(&p).unwrap();
        for cut in [5usize, 9, 14, full.len() / 2, full.len() - 1] {
            std::fs::write(&p, &full[..cut]).unwrap();
            assert!(load_params(&p).is_err(), "cut at {cut} must not load");
        }
        // Trailing garbage is detected too (not silently ignored).
        let mut long = full.clone();
        long.extend_from_slice(&[1, 2, 3]);
        std::fs::write(&p, &long).unwrap();
        assert!(load_params(&p).is_err(), "trailing bytes must not load");
    }
}
