//! Checkpoint I/O: a single-file format holding named f32 tensors
//! (JSON header + packed little-endian data), plus raw state-vector
//! save/load. Interops with nothing — it's the coordinator's own durable
//! format — but tensors can also be exported per-leaf as `.npy`.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::tensor::Tensor;
use crate::util::json::Json;

const MAGIC: &[u8; 8] = b"QRLORA01";

/// Save a named tensor map.
pub fn save_params(path: &Path, params: &BTreeMap<String, Tensor>) -> anyhow::Result<()> {
    let mut header = Vec::new();
    let mut offset = 0usize;
    for (name, t) in params {
        header.push((name.clone(), t.shape.clone(), offset));
        offset += t.numel();
    }
    let hjson = Json::Arr(
        header
            .iter()
            .map(|(n, s, o)| {
                Json::obj(vec![
                    ("name", Json::str(n.clone())),
                    ("shape", Json::arr_usize(s.iter())),
                    ("offset", Json::num(*o as f64)),
                ])
            })
            .collect(),
    )
    .to_string();

    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&(hjson.len() as u64).to_le_bytes())?;
    f.write_all(hjson.as_bytes())?;
    let mut buf = Vec::with_capacity(offset * 4);
    for t in params.values() {
        for v in &t.data {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    f.write_all(&buf)?;
    Ok(())
}

/// Load a named tensor map.
pub fn load_params(path: &Path) -> anyhow::Result<BTreeMap<String, Tensor>> {
    let mut f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("cannot open checkpoint {path:?}: {e}"))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "{path:?}: not a qrlora checkpoint");
    let mut lenb = [0u8; 8];
    f.read_exact(&mut lenb)?;
    let hlen = u64::from_le_bytes(lenb) as usize;
    let mut hbuf = vec![0u8; hlen];
    f.read_exact(&mut hbuf)?;
    let header = Json::parse(std::str::from_utf8(&hbuf)?)?;
    let mut body = Vec::new();
    f.read_to_end(&mut body)?;

    let mut out = BTreeMap::new();
    for entry in header.as_arr().unwrap_or_default() {
        let name = entry.req("name")?.as_str().unwrap_or("").to_string();
        let shape: Vec<usize> = entry
            .req("shape")?
            .as_arr()
            .unwrap_or_default()
            .iter()
            .filter_map(|d| d.as_usize())
            .collect();
        let offset = entry.req("offset")?.as_usize().unwrap_or(0);
        let numel: usize = shape.iter().product();
        let start = offset * 4;
        anyhow::ensure!(
            start + numel * 4 <= body.len(),
            "{path:?}: truncated tensor {name}"
        );
        let data: Vec<f32> = body[start..start + numel * 4]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.insert(name, Tensor::from_vec(&shape, data));
    }
    Ok(out)
}

/// Save a raw state vector with a tiny JSON sidecar for provenance.
pub fn save_state(path: &Path, state: &[f32], meta: &Json) -> anyhow::Result<()> {
    let t = Tensor::from_vec(&[state.len()], state.to_vec());
    t.save_npy(path)?;
    std::fs::write(path.with_extension("json"), meta.pretty())?;
    Ok(())
}

/// Load a raw state vector.
pub fn load_state(path: &Path) -> anyhow::Result<Vec<f32>> {
    Ok(Tensor::load_npy(path)?.data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("qrlora_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn params_roundtrip() {
        let mut rng = Rng::new(1);
        let mut params = BTreeMap::new();
        params.insert("a/w".to_string(), Tensor::randn(&[3, 4], &mut rng, 1.0));
        params.insert("b".to_string(), Tensor::randn(&[7], &mut rng, 2.0));
        params.insert("empty_name/x".to_string(), Tensor::zeros(&[1]));
        let p = tmp("params.qck");
        save_params(&p, &params).unwrap();
        let loaded = load_params(&p).unwrap();
        assert_eq!(loaded, params);
    }

    #[test]
    fn state_roundtrip() {
        let state: Vec<f32> = (0..100).map(|i| i as f32 / 7.0).collect();
        let p = tmp("state.npy");
        save_state(&p, &state, &Json::obj(vec![("step", Json::num(5.0))])).unwrap();
        assert_eq!(load_state(&p).unwrap(), state);
        let meta = std::fs::read_to_string(p.with_extension("json")).unwrap();
        assert!(meta.contains("step"));
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("garbage.qck");
        std::fs::write(&p, b"not a checkpoint at all").unwrap();
        assert!(load_params(&p).is_err());
    }

    #[test]
    fn missing_file_reports_path() {
        let err = load_params(Path::new("/nonexistent/x.qck"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("x.qck"));
    }
}
