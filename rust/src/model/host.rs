//! Pure-Rust reference model: forward / backward / Adam for the encoder the
//! AOT graphs implement, operating on `tensor::Tensor`.
//!
//! This is the numeric core of `runtime::HostBackend`. It mirrors
//! `python/compile/model.py` (and the fused-projection reference in
//! `python/compile/kernels/ref.py`) operation for operation:
//!
//! * embeddings (token + position + type) → LayerNorm
//! * pre-LN residual blocks: multi-head attention with the QR-fused adapter
//!   projection `x·W₀ + (x·Q_r)·diag(λ·mask)·R̃_r` (or the LoRA form
//!   `x·W₀ + (x·A)·diag(α/r)·B`), then a GELU FFN
//! * pooled-CLS classification/regression heads and the weight-tied MLM head
//! * in-graph Adam with global-norm gradient clipping over the flat
//!   state-vector protocol `[ metrics | params | adam_m | adam_v ]`
//!
//! The backward pass is hand-derived; its gradients were validated against
//! `jax.grad` of `model.py` for every method × head (and the packed Adam
//! state update) to ~1e-7 relative error before being ported here.

use std::collections::BTreeMap;
use std::rc::Rc;

use crate::data::HeadKind;
use crate::kernels;
use crate::quant::{self, QuantTensor};
use crate::runtime::{Preset, StateLayout};
use crate::tensor::Tensor;
use crate::util::pool;

/// One frozen (non-trainable) input: full-precision, or int8-quantized
/// backbone weight (see `quant`). `Rc` so the runtime backend can cache
/// the buffer→tensor conversion (and the quantization) across steps and
/// hand the same representation to every call without copying the
/// backbone.
#[derive(Clone)]
pub enum FrozenValue {
    /// Full-precision tensor (QR factors, masks, LayerNorm, biases — and
    /// everything when quantization is off).
    Dense(Rc<Tensor>),
    /// Int8 projection weight `W (k×n)`, stored **transposed** (n×k) with
    /// per-row-group scales; `x·W` and `dy·Wᵀ` run the fused
    /// `quant::matmul_xw_q` / `quant::matmul_dyw_t_q` kernels.
    QuantProj(Rc<QuantTensor>),
    /// Int8 row-gather table (embeddings), natural orientation.
    QuantRows(Rc<QuantTensor>),
}

impl FrozenValue {
    /// Wrap a full-precision tensor.
    pub fn dense(t: Tensor) -> FrozenValue {
        FrozenValue::Dense(Rc::new(t))
    }

    fn as_dense(&self, name: &str) -> &Tensor {
        match self {
            FrozenValue::Dense(t) => t.as_ref(),
            _ => panic!("host model: frozen {name:?} is quantized but used as dense f32"),
        }
    }

    /// View as a projection operand (`ctx` prefixes the panic message).
    fn as_weight(&self, ctx: &str, name: &str) -> WeightRef<'_> {
        match self {
            FrozenValue::Dense(t) => WeightRef::Dense(t),
            FrozenValue::QuantProj(q) => WeightRef::Quant(q),
            FrozenValue::QuantRows(_) => {
                panic!("{ctx}: row-quantized {name:?} used as projection")
            }
        }
    }

    /// View as a gather table (`ctx` prefixes the panic message).
    fn as_emb(&self, ctx: &str, name: &str) -> EmbRef<'_> {
        match self {
            FrozenValue::Dense(t) => EmbRef::Dense(t),
            FrozenValue::QuantRows(q) => EmbRef::Quant(q),
            FrozenValue::QuantProj(_) => {
                panic!("{ctx}: transposed-quantized {name:?} used as gather table")
            }
        }
    }
}

/// Frozen (non-trainable) inputs keyed by graph name.
pub type FrozenMap = BTreeMap<String, FrozenValue>;

/// One unpacked adapter: its named trainable tensors, shared via `Rc` by
/// the runtime's resident-adapter cache.
pub type AdapterSlot = Rc<BTreeMap<String, Tensor>>;

/// A weight operand that may be dense f32 or an int8 projection stored
/// transposed. The two products the model needs dispatch here, so every
/// forward/backward path is quantization-agnostic.
enum WeightRef<'a> {
    Dense(&'a Tensor),
    Quant(&'a QuantTensor),
}

impl WeightRef<'_> {
    /// Forward product `x · W`. Named by direction (not `matmul`) on
    /// purpose: the receiver is the *weight*, the opposite operand order
    /// of `Tensor::matmul`, and a lookalike name would invite transposed
    /// products at call sites.
    fn fwd(&self, x: &Tensor) -> Tensor {
        match self {
            WeightRef::Dense(w) => x.matmul(w),
            WeightRef::Quant(w) => quant::matmul_xw_q(x, w),
        }
    }

    /// Backward product `dy · Wᵀ`.
    fn bwd(&self, dy: &Tensor) -> Tensor {
        match self {
            WeightRef::Dense(w) => dy.matmul_t(w),
            WeightRef::Quant(w) => quant::matmul_dyw_t_q(dy, w),
        }
    }
}

/// A row-gather table (embeddings) that may be dense or int8 with
/// per-row-group scales.
enum EmbRef<'a> {
    Dense(&'a Tensor),
    Quant(&'a QuantTensor),
}

impl EmbRef<'_> {
    /// `out[e] = row(idx)[e]` — first table of the embedding sum. `kern`
    /// comes from the caller because gathers run on pool worker threads,
    /// which don't see the caller's `kernels::with_kernels` override.
    #[inline]
    fn write_row(&self, kern: kernels::Kernels, idx: usize, out: &mut [f32]) {
        match self {
            EmbRef::Dense(t) => out.copy_from_slice(t.row(idx)),
            EmbRef::Quant(q) => kern.scale_i8(q.scale_of_row(idx), q.row(idx), out),
        }
    }

    /// `out[e] += row(idx)[e]` — subsequent tables, in the serial order.
    #[inline]
    fn add_row(&self, kern: kernels::Kernels, idx: usize, out: &mut [f32]) {
        match self {
            EmbRef::Dense(t) => kern.vadd(t.row(idx), out),
            EmbRef::Quant(q) => kern.axpy_i8(q.scale_of_row(idx), q.row(idx), out),
        }
    }
}

pub const NEG_INF: f32 = -1e9;
const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// Which adapter structure the graph carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MethodKind {
    Ft,
    Lora,
    QrLora,
}

impl MethodKind {
    pub fn parse(s: &str) -> anyhow::Result<MethodKind> {
        Ok(match s {
            "ft" => MethodKind::Ft,
            "lora" => MethodKind::Lora,
            "qrlora" => MethodKind::QrLora,
            _ => anyhow::bail!("unknown method {s:?}"),
        })
    }
}

/// Borrowed task batch (flat row-major host tensors).
pub struct TaskBatchRef<'a> {
    pub input_ids: &'a [i32],
    pub type_ids: &'a [i32],
    pub attn_mask: &'a [f32],
    /// Classification labels (cls head).
    pub labels_i32: &'a [i32],
    /// Regression targets (reg head).
    pub labels_f32: &'a [f32],
    pub class_mask: &'a [f32],
    pub example_w: &'a [f32],
}

/// Borrowed MLM batch.
pub struct MlmBatchRef<'a> {
    pub input_ids: &'a [i32],
    pub type_ids: &'a [i32],
    pub attn_mask: &'a [f32],
    /// -100 = not predicted.
    pub mlm_labels: &'a [i32],
}

/// Trainable + frozen parameters looked up by graph name.
struct ParamView<'a> {
    train: &'a BTreeMap<String, Tensor>,
    frozen: &'a FrozenMap,
}

impl ParamView<'_> {
    fn get(&self, name: &str) -> &Tensor {
        if let Some(t) = self.train.get(name) {
            return t;
        }
        if let Some(v) = self.frozen.get(name) {
            return v.as_dense(name);
        }
        panic!("host model: missing parameter {name:?}")
    }

    fn vec(&self, name: &str) -> &[f32] {
        &self.get(name).data
    }

    /// A matmul operand that may be dense (trainable or f32 frozen) or an
    /// int8 projection.
    fn weight(&self, name: &str) -> WeightRef<'_> {
        if let Some(t) = self.train.get(name) {
            return WeightRef::Dense(t);
        }
        self.frozen
            .get(name)
            .unwrap_or_else(|| panic!("host model: missing parameter {name:?}"))
            .as_weight("host model", name)
    }

    /// A gather table that may be dense or row-quantized int8.
    fn emb(&self, name: &str) -> EmbRef<'_> {
        if let Some(t) = self.train.get(name) {
            return EmbRef::Dense(t);
        }
        self.frozen
            .get(name)
            .unwrap_or_else(|| panic!("host model: missing parameter {name:?}"))
            .as_emb("host model", name)
    }
}

/// Gradient accumulator keyed by parameter name.
#[derive(Default)]
struct Grads {
    map: BTreeMap<String, Tensor>,
}

impl Grads {
    fn add(&mut self, name: &str, t: Tensor) {
        match self.map.get_mut(name) {
            Some(g) => g.add_assign(&t),
            None => {
                self.map.insert(name.to_string(), t);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Primitive ops (with caches for the backward pass).
// ---------------------------------------------------------------------------

struct LnCache {
    xhat: Tensor,
    rstd: Vec<f32>,
}

fn ln_fwd(x: &Tensor, g: &[f32], b: &[f32]) -> (Tensor, LnCache) {
    let (rows, d) = (x.rows(), x.cols());
    let mut y = Tensor::zeros(&[rows, d]);
    let mut xhat = Tensor::zeros(&[rows, d]);
    let mut rstd = vec![0f32; rows];
    // Rows are independent; parallelize over batch rows (y/xhat/rstd spans
    // are split on the same row partition, so writes stay disjoint). The
    // μ/σ² reductions stay scalar inside the kernel; only the
    // normalize/affine writes vectorize (exact in every simd mode).
    let kern = kernels::active();
    pool::par_parts3(
        &mut y.data,
        d,
        &mut xhat.data,
        d,
        &mut rstd,
        1,
        rows,
        rows.saturating_mul(d) * 4,
        |r0, yc, xc, rc| {
            let x_rows = &x.data[r0 * d..r0 * d + yc.len()];
            kern.ln_fwd_rows(x_rows, d, g, b, yc, xc, rc);
        },
    );
    (y, LnCache { xhat, rstd })
}

fn ln_bwd(dy: &Tensor, g: &[f32], c: &LnCache) -> (Tensor, Vec<f32>, Vec<f32>) {
    let (rows, d) = (dy.rows(), dy.cols());
    let mut dx = Tensor::zeros(&[rows, d]);
    // dγ/dβ are row reductions: fixed-chunk partial sums (one packed
    // [dγ | dβ] accumulator per chunk, a single pass over dy/x̂) keep the
    // accumulation order a function of the row count alone, so results
    // are bit-identical for any thread count.
    let kern = kernels::active();
    let packed = pool::par_reduce_rows(rows, 2 * d, rows.saturating_mul(d) * 4, |r0, n, acc| {
        let (dg_acc, db_acc) = acc.split_at_mut(d);
        for i in r0..r0 + n {
            let dyr = dy.row(i);
            // Per-column accumulators are independent, so splitting the
            // packed pass into two vectorized column sweeps keeps every
            // column's row-order accumulation — exact in every simd mode.
            kern.vmuladd(dyr, c.xhat.row(i), dg_acc);
            kern.vadd(dyr, db_acc);
        }
    });
    let (dg, db) = (packed[..d].to_vec(), packed[d..].to_vec());
    // dx rows are independent — parallel (m1/m2 are per-row reductions,
    // kept scalar-sequential inside the kernel; the dx write vectorizes
    // exactly).
    pool::par_rows(&mut dx.data, rows, rows.saturating_mul(d) * 6, |r0, chunk| {
        let nrows = chunk.len() / d;
        let dy_rows = &dy.data[r0 * d..(r0 + nrows) * d];
        let xhat_rows = &c.xhat.data[r0 * d..(r0 + nrows) * d];
        kern.ln_bwd_dx_rows(dy_rows, xhat_rows, &c.rstd[r0..r0 + nrows], g, d, chunk);
    });
    (dx, dg, db)
}

/// tanh-approximate GELU (JAX's default). Returns (y, tanh cache).
/// Elementwise on live rows, so the pool split can't change any value.
///
/// `live`, when present, holds one mask value per row (the batch's
/// attention mask): padded rows skip the `tanh` entirely and their
/// `y`/cache stay exactly `0.0`. Padded activations never reach logits or
/// gradients (attention `p == 0.0` skips masked keys, the Cls head reads
/// position 0, masked-out MLM rows zero their dlogits), so live-row bits
/// are unchanged.
fn gelu_fwd(x: &Tensor, live: Option<&[f32]>) -> (Tensor, Tensor) {
    let (rows, cols) = (x.rows(), x.cols());
    let mut y = Tensor::zeros(&x.shape);
    let mut t = Tensor::zeros(&x.shape);
    if cols == 0 {
        return (y, t);
    }
    let n = x.data.len();
    let kern = kernels::active();
    pool::par_parts2(&mut y.data, cols, &mut t.data, cols, rows, n * 8, |r0, yc, tc| {
        let nrows = yc.len() / cols;
        let x_rows = &x.data[r0 * cols..(r0 + nrows) * cols];
        let live_rows = live.map(|m| &m[r0..r0 + nrows]);
        kern.gelu_fwd_rows(x_rows, cols, live_rows, yc, tc);
    });
    (y, t)
}

fn gelu_bwd(dy: &Tensor, x_pre: &Tensor, t: &Tensor) -> Tensor {
    let mut dx = Tensor::zeros(&dy.shape);
    let n = dy.data.len();
    let kern = kernels::active();
    pool::par_rows(&mut dx.data, n, n * 8, |lo, chunk| {
        let hi = lo + chunk.len();
        kern.gelu_bwd(&dy.data[lo..hi], &x_pre.data[lo..hi], &t.data[lo..hi], chunk);
    });
    dx
}

/// out[i, j] = t[i, j] * coeff[j]
fn scale_cols(t: &Tensor, coeff: &[f32]) -> Tensor {
    let (rows, cols) = (t.rows(), t.cols());
    let mut out = t.clone();
    if cols == 0 {
        return out;
    }
    let kern = kernels::active();
    pool::par_rows(&mut out.data, rows, rows.saturating_mul(cols), |_, chunk| {
        for r in chunk.chunks_mut(cols) {
            kern.vmul(coeff, r);
        }
    });
    out
}

/// Column sums (bias gradients) — a row reduction, parallelized with
/// fixed-chunk partial sums (`pool::par_reduce_rows`): the chunk
/// boundaries depend only on the row count, so the accumulation order —
/// and every output bit — is independent of the thread count.
fn col_sum(t: &Tensor) -> Vec<f32> {
    let (rows, cols) = (t.rows(), t.cols());
    let kern = kernels::active();
    pool::par_reduce_rows(rows, cols, rows.saturating_mul(cols), |row0, n, acc| {
        for i in row0..row0 + n {
            kern.vadd(t.row(i), acc);
        }
    })
}

fn add_bias_rows(t: &mut Tensor, bias: &[f32]) {
    let (rows, cols) = (t.rows(), t.cols());
    if cols == 0 {
        return;
    }
    let kern = kernels::active();
    pool::par_rows(&mut t.data, rows, rows.saturating_mul(cols), |_, chunk| {
        for r in chunk.chunks_mut(cols) {
            kern.vadd(bias, r);
        }
    });
}

// ---------------------------------------------------------------------------
// Adapted projection.
// ---------------------------------------------------------------------------

struct ProjCache {
    /// x·Q (QR-LoRA) or x·A (LoRA) when the slot is adapted.
    xq: Option<Tensor>,
}

fn adapted(method: MethodKind, pj: &str) -> bool {
    match method {
        MethodKind::Ft => false,
        MethodKind::QrLora => true, // all of wq/wk/wv/wo carry slots
        MethodKind::Lora => pj == "wq" || pj == "wv",
    }
}

/// Forward: y = x·W₀ (+ adapter delta) + bias.
fn proj_fwd(
    pv: &ParamView,
    method: MethodKind,
    layer: usize,
    pj: &str,
    x: &Tensor,
) -> (Tensor, ProjCache) {
    let w0 = pv.weight(&format!("layer{layer}/attn/{pj}"));
    let bias = pv.vec(&format!("layer{layer}/attn/b{}", &pj[1..2]));
    let mut y = w0.fwd(x);
    let mut cache = ProjCache { xq: None };
    if adapted(method, pj) {
        match method {
            MethodKind::QrLora => {
                let base = format!("qr/layer{layer}/{pj}");
                let q = pv.get(&format!("{base}/Q"));
                let r = pv.get(&format!("{base}/R"));
                let lam = pv.vec(&format!("{base}/lam"));
                let mask = pv.vec(&format!("{base}/mask"));
                let coeff: Vec<f32> = lam.iter().zip(mask).map(|(l, m)| l * m).collect();
                let xq = x.matmul(q);
                y.add_assign(&scale_cols(&xq, &coeff).matmul(r));
                cache.xq = Some(xq);
            }
            MethodKind::Lora => {
                let base = format!("lora/layer{layer}/{pj}");
                let a = pv.get(&format!("{base}/A"));
                let b = pv.get(&format!("{base}/B"));
                let scale = pv.vec(&format!("{base}/scale"));
                let xa = x.matmul(a);
                y.add_assign(&scale_cols(&xa, scale).matmul(b));
                cache.xq = Some(xa);
            }
            MethodKind::Ft => unreachable!(),
        }
    }
    add_bias_rows(&mut y, bias);
    (y, cache)
}

/// Backward: accumulates adapter (and, when `train_backbone`, W₀/bias)
/// gradients; returns dx.
#[allow(clippy::too_many_arguments)]
fn proj_bwd(
    pv: &ParamView,
    grads: &mut Grads,
    method: MethodKind,
    layer: usize,
    pj: &str,
    x: &Tensor,
    dy: &Tensor,
    cache: &ProjCache,
    train_backbone: bool,
) -> Tensor {
    let wname = format!("layer{layer}/attn/{pj}");
    let w0 = pv.weight(&wname);
    let mut dx = w0.bwd(dy); // dy · W₀ᵀ
    if train_backbone {
        grads.add(&wname, x.t_matmul(dy)); // xᵀ · dy
        let bname = format!("layer{layer}/attn/b{}", &pj[1..2]);
        let db = col_sum(dy);
        grads.add(&bname, Tensor::from_vec(&[db.len()], db));
    }
    if adapted(method, pj) {
        let xq = cache.xq.as_ref().expect("adapter cache");
        match method {
            MethodKind::QrLora => {
                let base = format!("qr/layer{layer}/{pj}");
                let q = pv.get(&format!("{base}/Q"));
                let r = pv.get(&format!("{base}/R"));
                let lam = pv.vec(&format!("{base}/lam"));
                let mask = pv.vec(&format!("{base}/mask"));
                let dyr = dy.matmul_t(r); // dy · R̃ᵀ → (rows, r_max)
                // dλ_i = mask_i · Σ_rows (x·Q)[·,i] (dy·R̃ᵀ)[·,i]
                let kern = kernels::active();
                let rmax = lam.len();
                let mut dlam = vec![0f32; rmax];
                for row in 0..xq.rows() {
                    kern.vmuladd(xq.row(row), dyr.row(row), &mut dlam);
                }
                kern.vmul(mask, &mut dlam);
                grads.add(&format!("{base}/lam"), Tensor::from_vec(&[rmax], dlam));
                let coeff: Vec<f32> = lam.iter().zip(mask).map(|(l, m)| l * m).collect();
                dx.add_assign(&scale_cols(&dyr, &coeff).matmul_t(q));
            }
            MethodKind::Lora => {
                let base = format!("lora/layer{layer}/{pj}");
                let a = pv.get(&format!("{base}/A"));
                let b = pv.get(&format!("{base}/B"));
                let scale = pv.vec(&format!("{base}/scale"));
                let dyb = dy.matmul_t(b); // dy · Bᵀ → (rows, r)
                let dyb_s = scale_cols(&dyb, scale);
                grads.add(&format!("{base}/A"), x.t_matmul(&dyb_s));
                grads.add(&format!("{base}/B"), scale_cols(xq, scale).t_matmul(dy));
                dx.add_assign(&dyb_s.matmul_t(a));
            }
            MethodKind::Ft => unreachable!(),
        }
    }
    dx
}

// ---------------------------------------------------------------------------
// Encoder.
// ---------------------------------------------------------------------------

struct LayerCache {
    ln1: LnCache,
    x_ln1: Tensor,
    pq: ProjCache,
    pk: ProjCache,
    pv_: ProjCache,
    po: ProjCache,
    q: Tensor,
    k: Tensor,
    v: Tensor,
    /// Softmax probabilities, rows = (b·nh + h)·S + i, cols = S.
    probs: Tensor,
    ctx: Tensor,
    ln2: LnCache,
    x_ln2: Tensor,
    f1_pre: Tensor,
    gelu_t: Tensor,
    f1: Tensor,
}

struct EncCache {
    emb_ln: LnCache,
    layers: Vec<LayerCache>,
}

/// Multi-head attention forward on flat (B·S, d) projections.
///
/// Parallel over batch elements: every (bb, h, i) writes only its own probs
/// row and ctx segment, and those regions are contiguous per `bb`, so the
/// pool splits the batch range and each lane works on a disjoint block.
fn attention_fwd(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    amask_add: &[f32], // (B·S,) additive mask per key position
    b: usize,
    s: usize,
    nh: usize,
) -> (Tensor, Tensor) {
    let d = q.cols();
    let dh = d / nh;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut probs = Tensor::zeros(&[b * nh * s, s]);
    let mut ctx = Tensor::zeros(&[b * s, d]);
    let kern = kernels::active();
    let work = b * nh * s * s * (dh + 4);
    pool::par_parts2(
        &mut probs.data,
        nh * s * s,
        &mut ctx.data,
        s * d,
        b,
        work,
        |bb0, pchunk, cchunk| {
            let nb = cchunk.len() / (s * d);
            for bl in 0..nb {
                let bb = bb0 + bl;
                for h in 0..nh {
                    for i in 0..s {
                        let prow = (bl * nh + h) * s + i;
                        let pr = &mut pchunk[prow * s..(prow + 1) * s];
                        let qrow =
                            &q.data[(bb * s + i) * d + h * dh..(bb * s + i) * d + (h + 1) * dh];
                        // scores + additive mask (sequential-order dot:
                        // the kernel keeps the scalar chain in strict mode)
                        let mut maxv = f32::NEG_INFINITY;
                        for (j, pv) in pr.iter_mut().enumerate() {
                            let krow = &k.data
                                [(bb * s + j) * d + h * dh..(bb * s + j) * d + (h + 1) * dh];
                            let sc = kern.dot_seq(qrow, krow);
                            let val = sc * scale + amask_add[bb * s + j];
                            *pv = val;
                            maxv = maxv.max(val);
                        }
                        // softmax row
                        let mut denom = 0f32;
                        for pv in pr.iter_mut() {
                            let e = (*pv - maxv).exp();
                            *pv = e;
                            denom += e;
                        }
                        for pv in pr.iter_mut() {
                            *pv /= denom;
                        }
                        // ctx
                        let crow =
                            &mut cchunk[(bl * s + i) * d + h * dh..(bl * s + i) * d + (h + 1) * dh];
                        for (j, &p) in pr.iter().enumerate() {
                            if p == 0.0 {
                                continue;
                            }
                            let vrow = &v.data
                                [(bb * s + j) * d + h * dh..(bb * s + j) * d + (h + 1) * dh];
                            kern.axpy(p, vrow, crow);
                        }
                    }
                }
            }
        },
    );
    (probs, ctx)
}

/// Backward of [`attention_fwd`] → (dq, dk, dv). Parallel over batch
/// elements: all three gradients only touch rows inside the lane's batch
/// block, so the pool splits them on the same partition.
fn attention_bwd(
    dctx: &Tensor,
    probs: &Tensor,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    b: usize,
    s: usize,
    nh: usize,
) -> (Tensor, Tensor, Tensor) {
    let d = q.cols();
    let dh = d / nh;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut dq = Tensor::zeros(&[b * s, d]);
    let mut dk = Tensor::zeros(&[b * s, d]);
    let mut dv = Tensor::zeros(&[b * s, d]);
    let kern = kernels::active();
    let work = b * nh * s * s * (3 * dh + 4);
    pool::par_parts3(
        &mut dq.data,
        s * d,
        &mut dk.data,
        s * d,
        &mut dv.data,
        s * d,
        b,
        work,
        |bb0, dqc, dkc, dvc| {
            let nb = dqc.len() / (s * d);
            let mut dprobs = vec![0f32; s];
            for bl in 0..nb {
                let bb = bb0 + bl;
                for h in 0..nh {
                    for i in 0..s {
                        let prow = (bb * nh + h) * s + i;
                        let dcrow = &dctx.data
                            [(bb * s + i) * d + h * dh..(bb * s + i) * d + (h + 1) * dh];
                        // dprobs_j = dctx · v_j ; dv_j += p_j dctx
                        for (j, dp) in dprobs.iter_mut().enumerate().take(s) {
                            let vrow = &v.data
                                [(bb * s + j) * d + h * dh..(bb * s + j) * d + (h + 1) * dh];
                            *dp = kern.dot_seq(dcrow, vrow);
                            let p = probs.data[prow * s + j];
                            if p != 0.0 {
                                let dvrow = &mut dvc
                                    [(bl * s + j) * d + h * dh..(bl * s + j) * d + (h + 1) * dh];
                                kern.axpy(p, dcrow, dvrow);
                            }
                        }
                        // softmax backward: ds = p ⊙ (dp − Σ dp·p), then ·scale
                        let inner = kern.dot_seq(&dprobs, &probs.data[prow * s..(prow + 1) * s]);
                        for j in 0..s {
                            let ds = probs.data[prow * s + j] * (dprobs[j] - inner) * scale;
                            if ds == 0.0 {
                                continue;
                            }
                            let krow = &k.data
                                [(bb * s + j) * d + h * dh..(bb * s + j) * d + (h + 1) * dh];
                            let qrow = &q.data
                                [(bb * s + i) * d + h * dh..(bb * s + i) * d + (h + 1) * dh];
                            let dqrow = &mut dqc
                                [(bl * s + i) * d + h * dh..(bl * s + i) * d + (h + 1) * dh];
                            kern.axpy(ds, krow, dqrow);
                            let dkrow = &mut dkc
                                [(bl * s + j) * d + h * dh..(bl * s + j) * d + (h + 1) * dh];
                            kern.axpy(ds, qrow, dkrow);
                        }
                    }
                }
            }
        },
    );
    (dq, dk, dv)
}

fn encode_fwd(
    pv: &ParamView,
    p: &Preset,
    method: MethodKind,
    ids: &[i32],
    type_ids: &[i32],
    attn_mask: &[f32],
) -> (Tensor, EncCache) {
    let (b, s, d, nh) = (p.batch, p.max_seq, p.d_model, p.n_heads);
    let tok = pv.emb("emb/tok");
    let pos = pv.emb("emb/pos");
    let typ = pv.emb("emb/type");
    let mut h = Tensor::zeros(&[b * s, d]);
    // Embedding gather: each output row depends only on its own ids (the
    // three adds keep the serial left-to-right order, so the split can't
    // change any value; quantized tables dequantize per gathered row).
    let kern = kernels::active();
    pool::par_rows(&mut h.data, b * s, b * s * d, |row0, chunk| {
        for (ri, out) in chunk.chunks_mut(d).enumerate() {
            let row = row0 + ri;
            let ss = row % s;
            let t = ids[row] as usize;
            let ty = type_ids[row] as usize;
            tok.write_row(kern, t, out);
            pos.add_row(kern, ss, out);
            typ.add_row(kern, ty, out);
        }
    });
    let (mut h, emb_ln) = {
        let (y, c) = ln_fwd(&h, pv.vec("emb/ln_g"), pv.vec("emb/ln_b"));
        (y, c)
    };

    let amask_add: Vec<f32> = attn_mask.iter().map(|&m| (1.0 - m) * NEG_INF).collect();

    let mut layers = Vec::with_capacity(p.n_layers);
    for l in 0..p.n_layers {
        let (x_ln1, ln1) = ln_fwd(
            &h,
            pv.vec(&format!("layer{l}/ln1_g")),
            pv.vec(&format!("layer{l}/ln1_b")),
        );
        let (q, pq) = proj_fwd(pv, method, l, "wq", &x_ln1);
        let (k, pk) = proj_fwd(pv, method, l, "wk", &x_ln1);
        let (v, pv_c) = proj_fwd(pv, method, l, "wv", &x_ln1);
        let (probs, ctx) = attention_fwd(&q, &k, &v, &amask_add, b, s, nh);
        let (o, po) = proj_fwd(pv, method, l, "wo", &ctx);
        h.add_assign(&o);

        let (x_ln2, ln2) = ln_fwd(
            &h,
            pv.vec(&format!("layer{l}/ln2_g")),
            pv.vec(&format!("layer{l}/ln2_b")),
        );
        let mut f1_pre = pv.weight(&format!("layer{l}/ffn/w1")).fwd(&x_ln2);
        add_bias_rows(&mut f1_pre, pv.vec(&format!("layer{l}/ffn/b1")));
        let (f1, gelu_t) = gelu_fwd(&f1_pre, Some(attn_mask));
        let mut f2 = pv.weight(&format!("layer{l}/ffn/w2")).fwd(&f1);
        add_bias_rows(&mut f2, pv.vec(&format!("layer{l}/ffn/b2")));
        h.add_assign(&f2);

        layers.push(LayerCache {
            ln1,
            x_ln1,
            pq,
            pk,
            pv_: pv_c,
            po,
            q,
            k,
            v,
            probs,
            ctx,
            ln2,
            x_ln2,
            f1_pre,
            gelu_t,
            f1,
        });
    }
    (h, EncCache { emb_ln, layers })
}

#[allow(clippy::too_many_arguments)]
fn encode_bwd(
    pv: &ParamView,
    grads: &mut Grads,
    p: &Preset,
    method: MethodKind,
    mut dh: Tensor,
    cache: &EncCache,
    ids: &[i32],
    type_ids: &[i32],
    train_backbone: bool,
) {
    let (b, s, d, nh) = (p.batch, p.max_seq, p.d_model, p.n_heads);
    for l in (0..p.n_layers).rev() {
        let c = &cache.layers[l];
        // FFN branch (residual: dh reaches both f2 and h_mid).
        let df2 = &dh;
        let w2 = pv.weight(&format!("layer{l}/ffn/w2"));
        let df1 = w2.bwd(df2);
        if train_backbone {
            grads.add(&format!("layer{l}/ffn/w2"), c.f1.t_matmul(df2));
            let db2 = col_sum(df2);
            grads.add(&format!("layer{l}/ffn/b2"), Tensor::from_vec(&[db2.len()], db2));
        }
        let df1_pre = gelu_bwd(&df1, &c.f1_pre, &c.gelu_t);
        let w1 = pv.weight(&format!("layer{l}/ffn/w1"));
        let dx2 = w1.bwd(&df1_pre);
        if train_backbone {
            grads.add(&format!("layer{l}/ffn/w1"), c.x_ln2.t_matmul(&df1_pre));
            let db1 = col_sum(&df1_pre);
            grads.add(&format!("layer{l}/ffn/b1"), Tensor::from_vec(&[db1.len()], db1));
        }
        let (dmid, dg2, db2) = ln_bwd(&dx2, pv.vec(&format!("layer{l}/ln2_g")), &c.ln2);
        if train_backbone {
            grads.add(&format!("layer{l}/ln2_g"), Tensor::from_vec(&[dg2.len()], dg2));
            grads.add(&format!("layer{l}/ln2_b"), Tensor::from_vec(&[db2.len()], db2));
        }
        dh.add_assign(&dmid);

        // Attention branch (residual at h_mid: dh reaches o and h_in).
        let dctx = proj_bwd(pv, grads, method, l, "wo", &c.ctx, &dh, &c.po, train_backbone);
        let (dq, dk, dv) = attention_bwd(&dctx, &c.probs, &c.q, &c.k, &c.v, b, s, nh);
        let mut dx1 = proj_bwd(pv, grads, method, l, "wq", &c.x_ln1, &dq, &c.pq, train_backbone);
        let dxk = proj_bwd(pv, grads, method, l, "wk", &c.x_ln1, &dk, &c.pk, train_backbone);
        dx1.add_assign(&dxk);
        let dxv = proj_bwd(pv, grads, method, l, "wv", &c.x_ln1, &dv, &c.pv_, train_backbone);
        dx1.add_assign(&dxv);
        let (dhin, dg1, db1) = ln_bwd(&dx1, pv.vec(&format!("layer{l}/ln1_g")), &c.ln1);
        if train_backbone {
            grads.add(&format!("layer{l}/ln1_g"), Tensor::from_vec(&[dg1.len()], dg1));
            grads.add(&format!("layer{l}/ln1_b"), Tensor::from_vec(&[db1.len()], db1));
        }
        dh.add_assign(&dhin);
    }

    let (demb, dg, db) = ln_bwd(&dh, pv.vec("emb/ln_g"), &cache.emb_ln);
    if train_backbone {
        grads.add("emb/ln_g", Tensor::from_vec(&[dg.len()], dg));
        grads.add("emb/ln_b", Tensor::from_vec(&[db.len()], db));
        let tok = pv.get("emb/tok");
        let pos = pv.get("emb/pos");
        let typ = pv.get("emb/type");
        let mut dtok = Tensor::zeros(&tok.shape);
        let mut dpos = Tensor::zeros(&pos.shape);
        let mut dtyp = Tensor::zeros(&typ.shape);
        for bb in 0..b {
            for ss in 0..s {
                let row = bb * s + ss;
                let src = &demb.data[row * d..(row + 1) * d];
                let t = ids[row] as usize;
                let ty = type_ids[row] as usize;
                for e in 0..d {
                    dtok.data[t * d + e] += src[e];
                    dpos.data[ss * d + e] += src[e];
                    dtyp.data[ty * d + e] += src[e];
                }
            }
        }
        grads.add("emb/tok", dtok);
        grads.add("emb/pos", dpos);
        grads.add("emb/type", dtyp);
    }
}

// ---------------------------------------------------------------------------
// Heads + losses.
// ---------------------------------------------------------------------------

/// Row-wise softmax in place (row-parallel; the MLM path runs this over a
/// (B·S, V) matrix, the single biggest elementwise op in pretraining).
fn softmax_rows(t: &mut Tensor) {
    let cols = t.cols();
    softmax_rows_masked(t, cols);
}

/// Row-wise softmax restricted to the first `valid` columns — columns the
/// caller pushed to `NEG_INF` (padded class slots) skip the `exp` and are
/// written exactly `0.0`, which is bit-identical to what the full-width
/// softmax produced on them (`exp` of ≈`-1e9` below the live max
/// underflows to `+0.0`; see [`kernels::Kernels::softmax_rows`]).
fn softmax_rows_masked(t: &mut Tensor, valid: usize) {
    let (rows, cols) = (t.rows(), t.cols());
    if cols == 0 {
        return;
    }
    let kern = kernels::active();
    pool::par_rows(&mut t.data, rows, rows.saturating_mul(cols) * 4, |_, chunk| {
        kern.softmax_rows(chunk, cols, valid);
    });
}

/// Task-head forward: (masked logits, pooled, cls, pre-tanh).
fn head_fwd(
    pv: &ParamView,
    head: HeadKind,
    h: &Tensor, // (B·S, d)
    b: usize,
    s: usize,
    class_mask: &[f32],
) -> (Tensor, Tensor, Tensor) {
    let d = h.cols();
    let mut cls = Tensor::zeros(&[b, d]);
    for bb in 0..b {
        cls.row_mut(bb).copy_from_slice(&h.data[bb * s * d..(bb * s + 1) * d]);
    }
    let mut pre = cls.matmul(pv.get("head/wp"));
    add_bias_rows(&mut pre, pv.vec("head/bp"));
    let mut pooled = pre.clone();
    for v in pooled.data.iter_mut() {
        *v = v.tanh();
    }
    let mut logits = pooled.matmul(pv.get("head/wc"));
    add_bias_rows(&mut logits, pv.vec("head/bc"));
    if head == HeadKind::Cls {
        let k = logits.cols();
        for bb in 0..b {
            for j in 0..k {
                logits.data[bb * k + j] += (1.0 - class_mask[j]) * NEG_INF;
            }
        }
    }
    (logits, pooled, cls)
}

/// Loss + dlogits for the task heads.
fn task_loss_bwd(
    head: HeadKind,
    logits: &Tensor,
    batch: &TaskBatchRef,
) -> (f32, Tensor) {
    let (b, k) = (logits.rows(), logits.cols());
    let w = batch.example_w;
    let wsum = w.iter().sum::<f32>().max(1e-6);
    match head {
        HeadKind::Cls => {
            let mut probs = logits.clone();
            // Class slots beyond the task's label count carry
            // `(1-mask)·NEG_INF` from `head_fwd` — skip their `exp`.
            let valid = batch.class_mask.iter().rposition(|&m| m != 0.0).map_or(k, |i| i + 1);
            softmax_rows_masked(&mut probs, valid);
            let mut loss = 0f32;
            let mut dlogits = probs.clone();
            for bb in 0..b {
                let label = batch.labels_i32[bb] as usize;
                let p = probs.data[bb * k + label].max(1e-30);
                loss += -(p.ln()) * w[bb];
                dlogits.data[bb * k + label] -= 1.0;
                let scale = w[bb] / wsum;
                for j in 0..k {
                    dlogits.data[bb * k + j] *= scale;
                }
            }
            (loss / wsum, dlogits)
        }
        HeadKind::Reg => {
            let mut loss = 0f32;
            let mut dlogits = Tensor::zeros(&[b, k]);
            for bb in 0..b {
                let diff = logits.data[bb * k] - batch.labels_f32[bb];
                loss += diff * diff * w[bb];
                dlogits.data[bb * k] = 2.0 * diff * w[bb] / wsum;
            }
            (loss / wsum, dlogits)
        }
    }
}

/// Head backward → dh (B·S, d); accumulates head grads.
#[allow(clippy::too_many_arguments)]
fn head_bwd(
    pv: &ParamView,
    grads: &mut Grads,
    dlogits: &Tensor,
    pooled: &Tensor,
    cls: &Tensor,
    b: usize,
    s: usize,
    d: usize,
) -> Tensor {
    grads.add("head/wc", pooled.t_matmul(dlogits));
    let dbc = col_sum(dlogits);
    grads.add("head/bc", Tensor::from_vec(&[dbc.len()], dbc));
    let wc = pv.get("head/wc");
    let dpooled = dlogits.matmul_t(wc);
    let mut dpre = dpooled.clone();
    for (i, v) in dpre.data.iter_mut().enumerate() {
        let t = pooled.data[i];
        *v *= 1.0 - t * t;
    }
    grads.add("head/wp", cls.t_matmul(&dpre));
    let dbp = col_sum(&dpre);
    grads.add("head/bp", Tensor::from_vec(&[dbp.len()], dbp));
    let wp = pv.get("head/wp");
    let dcls = dpre.matmul_t(wp);
    let mut dh = Tensor::zeros(&[b * s, d]);
    for bb in 0..b {
        dh.data[bb * s * d..(bb * s + 1) * d].copy_from_slice(dcls.row(bb));
    }
    dh
}

// ---------------------------------------------------------------------------
// Flat-state plumbing: unpack, clip, Adam, repack.
// ---------------------------------------------------------------------------

/// Read the trainable leaves of a flat state vector as named tensors.
///
/// Public because the runtime's resident-adapter cache memoizes exactly
/// this unpack per bank slot (see `runtime::host`), so batched serving
/// stops re-slicing adapter states on every mixed batch.
pub fn unpack_train(state: &[f32], layout: &StateLayout) -> BTreeMap<String, Tensor> {
    layout
        .params
        .iter()
        .map(|f| {
            (
                f.name.clone(),
                Tensor::from_vec(&f.shape, state[f.offset..f.offset + f.numel()].to_vec()),
            )
        })
        .collect()
}

/// Global-norm clip + Adam over the flat protocol; returns the new state
/// with the metrics head set to `metrics`.
fn clip_and_adam(
    layout: &StateLayout,
    state: &[f32],
    grads: &Grads,
    lr: f32,
    t: f32,
    metrics: &[(&str, Vec<f32>)],
) -> Vec<f32> {
    let n = layout.n_params;
    // The flat protocol tiles the state as [ metrics | params | m | v ]
    // (asserted layout-wide by the runtime smoke tests), so the update is
    // one dense elementwise pass. Flatten the named gradients into that
    // order once — the global-norm reduction and the Adam update both
    // stream the flat buffer.
    let base = layout.total - 3 * n;
    debug_assert_eq!(
        layout.params.iter().map(|f| f.numel()).sum::<usize>(),
        n,
        "param fields must tile the flat block"
    );
    let mut g_flat = vec![0f32; n];
    for f in &layout.params {
        if let Some(g) = grads.map.get(&f.name) {
            let lo = f.offset - base;
            g_flat[lo..lo + g.data.len()].copy_from_slice(&g.data);
        }
    }
    // Global grad-norm: an all-params reduction, run as fixed-chunk f64
    // partial sums (`pool::par_reduce_rows`) so the accumulation order is
    // a function of the element count alone — bit-identical for every
    // thread count. Params without a gradient contribute exact zeros.
    let sq = pool::par_reduce_rows::<f64, _>(n, 1, 2 * n, |lo, len, acc| {
        for &v in &g_flat[lo..lo + len] {
            acc[0] += (v as f64) * (v as f64);
        }
    })[0];
    let norm = (sq + 1e-12).sqrt();
    let scale = (1.0f64.min(1.0 / norm)) as f32;

    let b1t = 1.0 - ADAM_B1.powf(t);
    let b2t = 1.0 - ADAM_B2.powf(t);

    let mut new_state = vec![0f32; layout.total];
    for (name, vals) in metrics {
        if let Ok(f) = layout.metric(name) {
            new_state[f.offset..f.offset + vals.len().min(f.numel())]
                .copy_from_slice(&vals[..vals.len().min(f.numel())]);
        }
    }
    // Update params/moments row-parallel — per-element, so the split
    // can't change any value.
    let st_p = &state[base..base + n];
    let st_m = &state[base + n..base + 2 * n];
    let st_v = &state[base + 2 * n..base + 3 * n];
    let (head, rest) = new_state.split_at_mut(base + n);
    let p_seg = &mut head[base..];
    let (m_seg, v_seg) = rest.split_at_mut(n);
    pool::par_parts3(p_seg, 1, m_seg, 1, v_seg, 1, n, n * 10, |lo, pc, mc, vc| {
        for i in 0..pc.len() {
            let j = lo + i;
            let gi = g_flat[j] * scale;
            let m_new = ADAM_B1 * st_m[j] + (1.0 - ADAM_B1) * gi;
            let v_new = ADAM_B2 * st_v[j] + (1.0 - ADAM_B2) * gi * gi;
            let mhat = m_new / b1t;
            let vhat = v_new / b2t;
            pc[i] = st_p[j] - lr * mhat / (vhat.sqrt() + ADAM_EPS);
            mc[i] = m_new;
            vc[i] = v_new;
        }
    });
    new_state
}

// ---------------------------------------------------------------------------
// Public entry points (one per artifact kind).
// ---------------------------------------------------------------------------

/// One fine-tune training step over the flat state protocol. Returns the
/// next state vector (params + moments updated, metrics head refreshed).
#[allow(clippy::too_many_arguments)]
pub fn train_step(
    p: &Preset,
    method: MethodKind,
    head: HeadKind,
    layout: &StateLayout,
    state: &[f32],
    frozen: &FrozenMap,
    batch: &TaskBatchRef,
    lr: f32,
    t: f32,
) -> Vec<f32> {
    let train = unpack_train(state, layout);
    let pv = ParamView { train: &train, frozen };
    let train_backbone = method == MethodKind::Ft;

    let (h, cache) = encode_fwd(&pv, p, method, batch.input_ids, batch.type_ids, batch.attn_mask);
    let (logits, pooled, cls) = head_fwd(&pv, head, &h, p.batch, p.max_seq, batch.class_mask);
    let (loss, dlogits) = task_loss_bwd(head, &logits, batch);

    let mut grads = Grads::default();
    let dh = head_bwd(&pv, &mut grads, &dlogits, &pooled, &cls, p.batch, p.max_seq, p.d_model);
    encode_bwd(
        &pv,
        &mut grads,
        p,
        method,
        dh,
        &cache,
        batch.input_ids,
        batch.type_ids,
        train_backbone,
    );

    clip_and_adam(
        layout,
        state,
        &grads,
        lr,
        t,
        &[("loss", vec![loss]), ("logits", logits.data.clone())],
    )
}

/// Forward-only pass over the training state layout → logits (B·K).
pub fn eval_forward(
    p: &Preset,
    method: MethodKind,
    head: HeadKind,
    layout: &StateLayout,
    state: &[f32],
    frozen: &FrozenMap,
    batch: &TaskBatchRef,
) -> Vec<f32> {
    let train = unpack_train(state, layout);
    let pv = ParamView { train: &train, frozen };
    let (h, _) = encode_fwd(&pv, p, method, batch.input_ids, batch.type_ids, batch.attn_mask);
    let (logits, _, _) = head_fwd(&pv, head, &h, p.batch, p.max_seq, batch.class_mask);
    logits.data
}

// ---------------------------------------------------------------------------
// Batched multi-adapter forward (serving fast path).
// ---------------------------------------------------------------------------

/// Per-adapter trainables + the shared frozen backbone, for the batched
/// multi-adapter forward ([`eval_forward_multi`]).
///
/// Adapter methods (LoRA / QR-LoRA) freeze the whole backbone, so every
/// shared parameter lives in `frozen` and only the tiny per-task leaves
/// (λ, LoRA A/B, task head) come from the selected slot. `slots` is
/// indexed by bank slot id; only slots referenced by the batch's
/// `row_slots` need to be populated (`None` elsewhere).
struct MultiView<'a> {
    slots: &'a [Option<AdapterSlot>],
    frozen: &'a FrozenMap,
}

impl MultiView<'_> {
    /// Shared (frozen) f32 parameter — Q/R factors, masks, LayerNorm,
    /// biases.
    fn shared(&self, name: &str) -> &Tensor {
        self.frozen
            .get(name)
            .unwrap_or_else(|| panic!("host model (multi): missing frozen {name:?}"))
            .as_dense(name)
    }

    fn shared_vec(&self, name: &str) -> &[f32] {
        &self.shared(name).data
    }

    /// Shared projection weight, dense or int8 (see [`WeightRef`]).
    fn shared_weight(&self, name: &str) -> WeightRef<'_> {
        self.frozen
            .get(name)
            .unwrap_or_else(|| panic!("host model (multi): missing frozen {name:?}"))
            .as_weight("host model (multi)", name)
    }

    /// Shared gather table, dense or int8 (see [`EmbRef`]).
    fn shared_emb(&self, name: &str) -> EmbRef<'_> {
        self.frozen
            .get(name)
            .unwrap_or_else(|| panic!("host model (multi): missing frozen {name:?}"))
            .as_emb("host model (multi)", name)
    }

    /// Per-adapter trainable parameter of slot `t` (must be populated).
    fn slot(&self, t: usize, name: &str) -> &Tensor {
        self.slots[t]
            .as_ref()
            .unwrap_or_else(|| panic!("host model (multi): slot {t} not unpacked"))
            .get(name)
            .unwrap_or_else(|| panic!("host model (multi): slot {t} missing {name:?}"))
    }

    fn slot_vec(&self, t: usize, name: &str) -> &[f32] {
        &self.slot(t, name).data
    }
}

/// Distinct values of `row_slots` in first-appearance order. Shared with
/// the runtime's grouped `execute_batched` fallback so both paths iterate
/// adapters in the same deterministic order.
pub fn distinct_slots(row_slots: &[usize]) -> Vec<usize> {
    let mut out = Vec::new();
    for &s in row_slots {
        if !out.contains(&s) {
            out.push(s);
        }
    }
    out
}

/// Forward of one adapted projection with per-row adapter selection:
/// `y = x·W₀ + Δ_task(row) + bias`. Rows of `x` are grouped `s` per batch
/// element and `row_slots[b]` names the adapter for element `b`. The
/// backbone product `x·W₀` happens exactly once for the whole mixed batch.
fn proj_fwd_multi(
    mv: &MultiView,
    method: MethodKind,
    layer: usize,
    pj: &str,
    x: &Tensor,
    row_slots: &[usize],
    s: usize,
) -> Tensor {
    let w0 = mv.shared_weight(&format!("layer{layer}/attn/{pj}"));
    let bias = mv.shared_vec(&format!("layer{layer}/attn/b{}", &pj[1..2]));
    let mut y = w0.fwd(x);
    if adapted(method, pj) {
        match method {
            MethodKind::QrLora => {
                // x·Q and ·R̃ use the shared frozen factors once; only the
                // diag(λ·mask) scaling is per row. The coefficient vectors
                // are built exactly as `proj_fwd` builds its single one, so
                // each row's values match the swapped-in path bit for bit.
                let base = format!("qr/layer{layer}/{pj}");
                let q = mv.shared(&format!("{base}/Q"));
                let r = mv.shared(&format!("{base}/R"));
                let mask = mv.shared_vec(&format!("{base}/mask"));
                // Only slots actually present in this batch need a
                // coefficient vector (the bank may hold many more).
                let mut coeffs: Vec<Option<Vec<f32>>> = vec![None; mv.slots.len()];
                for t in distinct_slots(row_slots) {
                    coeffs[t] = Some(
                        mv.slot_vec(t, &format!("{base}/lam"))
                            .iter()
                            .zip(mask)
                            .map(|(l, m)| l * m)
                            .collect(),
                    );
                }
                let mut xq = x.matmul(q);
                let cols = xq.cols();
                for (i, row) in xq.data.chunks_mut(cols).enumerate() {
                    let coeff = coeffs[row_slots[i / s]].as_ref().expect("slot coeffs");
                    for (v, &c) in row.iter_mut().zip(coeff) {
                        *v *= c;
                    }
                }
                y.add_assign(&xq.matmul(r));
            }
            MethodKind::Lora => {
                // A/B are per-adapter matrices, so the low-rank delta runs
                // once per *distinct* slot (rank r_lora is tiny) and only
                // that slot's rows are kept.
                let base = format!("lora/layer{layer}/{pj}");
                let scale = mv.shared_vec(&format!("{base}/scale"));
                for t in distinct_slots(row_slots) {
                    let a = mv.slot(t, &format!("{base}/A"));
                    let b = mv.slot(t, &format!("{base}/B"));
                    let delta = scale_cols(&x.matmul(a), scale).matmul(b);
                    let cols = delta.cols();
                    for (i, row) in y.data.chunks_mut(cols).enumerate() {
                        if row_slots[i / s] == t {
                            for (v, &dv) in row.iter_mut().zip(delta.row(i)) {
                                *v += dv;
                            }
                        }
                    }
                }
            }
            MethodKind::Ft => unreachable!("multi-adapter serving requires a frozen backbone"),
        }
    }
    add_bias_rows(&mut y, bias);
    y
}

/// Encoder forward over a mixed-adapter batch (no backward caches). The
/// layer structure mirrors [`encode_fwd`] exactly; only the adapted
/// projections consult `row_slots`.
fn encode_fwd_multi(
    mv: &MultiView,
    p: &Preset,
    method: MethodKind,
    row_slots: &[usize],
    ids: &[i32],
    type_ids: &[i32],
    attn_mask: &[f32],
) -> Tensor {
    let (b, s, d, nh) = (p.batch, p.max_seq, p.d_model, p.n_heads);
    let tok = mv.shared_emb("emb/tok");
    let pos = mv.shared_emb("emb/pos");
    let typ = mv.shared_emb("emb/type");
    let mut h = Tensor::zeros(&[b * s, d]);
    let kern = kernels::active();
    pool::par_rows(&mut h.data, b * s, b * s * d, |row0, chunk| {
        for (ri, out) in chunk.chunks_mut(d).enumerate() {
            let row = row0 + ri;
            let ss = row % s;
            let t = ids[row] as usize;
            let ty = type_ids[row] as usize;
            tok.write_row(kern, t, out);
            pos.add_row(kern, ss, out);
            typ.add_row(kern, ty, out);
        }
    });
    let (mut h, _) = ln_fwd(&h, mv.shared_vec("emb/ln_g"), mv.shared_vec("emb/ln_b"));

    let amask_add: Vec<f32> = attn_mask.iter().map(|&m| (1.0 - m) * NEG_INF).collect();

    for l in 0..p.n_layers {
        let (x_ln1, _) = ln_fwd(
            &h,
            mv.shared_vec(&format!("layer{l}/ln1_g")),
            mv.shared_vec(&format!("layer{l}/ln1_b")),
        );
        let q = proj_fwd_multi(mv, method, l, "wq", &x_ln1, row_slots, s);
        let k = proj_fwd_multi(mv, method, l, "wk", &x_ln1, row_slots, s);
        let v = proj_fwd_multi(mv, method, l, "wv", &x_ln1, row_slots, s);
        let (_, ctx) = attention_fwd(&q, &k, &v, &amask_add, b, s, nh);
        let o = proj_fwd_multi(mv, method, l, "wo", &ctx, row_slots, s);
        h.add_assign(&o);

        let (x_ln2, _) = ln_fwd(
            &h,
            mv.shared_vec(&format!("layer{l}/ln2_g")),
            mv.shared_vec(&format!("layer{l}/ln2_b")),
        );
        let mut f1_pre = mv.shared_weight(&format!("layer{l}/ffn/w1")).fwd(&x_ln2);
        add_bias_rows(&mut f1_pre, mv.shared_vec(&format!("layer{l}/ffn/b1")));
        let (f1, _) = gelu_fwd(&f1_pre, Some(attn_mask));
        let mut f2 = mv.shared_weight(&format!("layer{l}/ffn/w2")).fwd(&f1);
        add_bias_rows(&mut f2, mv.shared_vec(&format!("layer{l}/ffn/b2")));
        h.add_assign(&f2);
    }
    h
}

/// Task heads over a mixed-adapter batch: each adapter's head runs over
/// the pooled CLS matrix once, and each batch row keeps the logits of its
/// own adapter, masked by that adapter's class mask.
fn head_fwd_multi(
    mv: &MultiView,
    head: HeadKind,
    h: &Tensor,
    b: usize,
    s: usize,
    class_masks: &[&[f32]],
    row_slots: &[usize],
) -> Tensor {
    let d = h.cols();
    let mut cls = Tensor::zeros(&[b, d]);
    for bb in 0..b {
        cls.row_mut(bb).copy_from_slice(&h.data[bb * s * d..(bb * s + 1) * d]);
    }
    // Head width is layout-wide; read it off any slot the batch uses.
    let k = mv.slot(row_slots[0], "head/wc").cols();
    let mut logits = Tensor::zeros(&[b, k]);
    for t in distinct_slots(row_slots) {
        let mut pre = cls.matmul(mv.slot(t, "head/wp"));
        add_bias_rows(&mut pre, mv.slot_vec(t, "head/bp"));
        let mut pooled = pre;
        for v in pooled.data.iter_mut() {
            *v = v.tanh();
        }
        let mut lg = pooled.matmul(mv.slot(t, "head/wc"));
        add_bias_rows(&mut lg, mv.slot_vec(t, "head/bc"));
        if head == HeadKind::Cls {
            let cm = class_masks[t];
            for bb in 0..b {
                for j in 0..k {
                    lg.data[bb * k + j] += (1.0 - cm[j]) * NEG_INF;
                }
            }
        }
        for bb in 0..b {
            if row_slots[bb] == t {
                logits.row_mut(bb).copy_from_slice(lg.row(bb));
            }
        }
    }
    logits
}

/// Batched multi-adapter forward: one shared frozen-backbone pass over a
/// mixed-task batch, with per-row adapter deltas and task heads.
///
/// `slots[t]` holds adapter `t`'s unpacked trainables (λ or LoRA A/B plus
/// the task head; only slots named by `row_slots` need to be `Some`),
/// `class_masks[t]` its padded class mask, and `row_slots[b]` selects the
/// adapter for batch element `b`. Per-request logits are
/// **bit-identical** to [`eval_forward`] with the same adapter's state
/// swapped in, because every op on the forward path is row-local —
/// enforced by `rust/tests/serve_batched.rs`.
#[allow(clippy::too_many_arguments)]
pub fn eval_forward_multi(
    p: &Preset,
    method: MethodKind,
    head: HeadKind,
    slots: &[Option<AdapterSlot>],
    class_masks: &[&[f32]],
    row_slots: &[usize],
    frozen: &FrozenMap,
    batch: &TaskBatchRef,
) -> Vec<f32> {
    let mv = MultiView { slots, frozen };
    let h = encode_fwd_multi(
        &mv,
        p,
        method,
        row_slots,
        batch.input_ids,
        batch.type_ids,
        batch.attn_mask,
    );
    head_fwd_multi(&mv, head, &h, p.batch, p.max_seq, class_masks, row_slots).data
}

/// One MLM pretraining step (whole backbone trains, weight-tied LM head).
pub fn pretrain_step(
    p: &Preset,
    layout: &StateLayout,
    state: &[f32],
    batch: &MlmBatchRef,
    lr: f32,
    t: f32,
) -> Vec<f32> {
    let train = unpack_train(state, layout);
    let empty = BTreeMap::new();
    let pv = ParamView { train: &train, frozen: &empty };
    let (b, s, v) = (p.batch, p.max_seq, p.vocab);

    let (h, cache) =
        encode_fwd(&pv, p, MethodKind::Ft, batch.input_ids, batch.type_ids, batch.attn_mask);
    let tok = pv.get("emb/tok");
    let mut logits = h.matmul_t(tok); // (B·S, V)
    add_bias_rows(&mut logits, pv.vec("mlm/bias"));

    let mut probs = logits;
    softmax_rows(&mut probs);
    let mut denom = 0f32;
    for row in 0..b * s {
        if batch.mlm_labels[row] >= 0 {
            denom += 1.0;
        }
    }
    let denom = denom.max(1.0);
    // Loss is a reduction over rows — read it serially (O(B·S)) before the
    // row-parallel pass below overwrites probs in place.
    let mut loss = 0f32;
    for row in 0..b * s {
        let label = batch.mlm_labels[row];
        if label >= 0 {
            let pr = probs.data[row * v + label as usize].max(1e-30);
            loss += -pr.ln();
        }
    }
    let loss = loss / denom;
    let mut dlogits = probs; // reuse allocation
    let labels = batch.mlm_labels;
    pool::par_rows(&mut dlogits.data, b * s, b * s * v, |row0, chunk| {
        for (ri, r) in chunk.chunks_mut(v).enumerate() {
            let label = labels[row0 + ri];
            let valid = label >= 0;
            let safe = label.max(0) as usize;
            let scale = if valid { 1.0 / denom } else { 0.0 };
            r[safe] -= 1.0;
            for x in r.iter_mut() {
                *x *= scale;
            }
        }
    });

    let mut grads = Grads::default();
    let dbias = col_sum(&dlogits);
    grads.add("mlm/bias", Tensor::from_vec(&[dbias.len()], dbias));
    grads.add("emb/tok", dlogits.t_matmul(&h)); // (V, d)
    let dh = dlogits.matmul(tok); // (B·S, d)
    encode_bwd(
        &pv,
        &mut grads,
        p,
        MethodKind::Ft,
        dh,
        &cache,
        batch.input_ids,
        batch.type_ids,
        true,
    );

    clip_and_adam(layout, state, &grads, lr, t, &[("loss", vec![loss])])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use crate::util::rng::Rng;

    fn layout_for(key: &str) -> (Preset, StateLayout) {
        let m = Manifest::builtin();
        let a = m.artifact(key).unwrap();
        (m.preset(&a.preset).unwrap().clone(), a.layout().unwrap().clone())
    }

    fn rand_state(layout: &StateLayout, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut state = vec![0f32; layout.total];
        for f in &layout.params {
            for i in 0..f.numel() {
                state[f.offset + i] = rng.normal() * 0.05;
            }
        }
        state
    }

    /// Finite-difference check of dλ through the full task loss — the one
    /// gradient path unique to QR-LoRA.
    #[test]
    fn lambda_grad_matches_finite_difference() {
        let (p, layout) = layout_for("tiny/train_step_qrlora_cls");
        let mut rng = Rng::new(5);
        let state = rand_state(&layout, 6);

        // frozen backbone + factors
        let m = Manifest::builtin();
        let a = m.artifact("tiny/train_step_qrlora_cls").unwrap();
        let mut frozen = BTreeMap::new();
        for (_, t) in a.inputs_with_role(crate::runtime::Role::Frozen) {
            let data: Vec<f32> = if t.name.ends_with("/mask") {
                vec![1.0; t.numel()]
            } else {
                (0..t.numel()).map(|_| rng.normal() * 0.1).collect()
            };
            frozen.insert(t.name.clone(), FrozenValue::dense(Tensor::from_vec(&t.shape, data)));
        }

        let bs = p.batch * p.max_seq;
        let ids: Vec<i32> = (0..bs).map(|i| ((i * 7) % p.vocab) as i32).collect();
        let type_ids = vec![0i32; bs];
        let attn_mask = vec![1.0f32; bs];
        let labels: Vec<i32> = (0..p.batch).map(|i| (i % 2) as i32).collect();
        let class_mask = vec![1.0f32; p.n_classes];
        let example_w = vec![1.0f32; p.batch];
        let batch = TaskBatchRef {
            input_ids: &ids,
            type_ids: &type_ids,
            attn_mask: &attn_mask,
            labels_i32: &labels,
            labels_f32: &[],
            class_mask: &class_mask,
            example_w: &example_w,
        };

        // analytic gradient via the internals
        let train = unpack_train(&state, &layout);
        let pv = ParamView { train: &train, frozen: &frozen };
        let (h, cache) = encode_fwd(&pv, &p, MethodKind::QrLora, &ids, &type_ids, &attn_mask);
        let (logits, pooled, cls) =
            head_fwd(&pv, HeadKind::Cls, &h, p.batch, p.max_seq, &class_mask);
        let (loss0, dlogits) = task_loss_bwd(HeadKind::Cls, &logits, &batch);
        let mut grads = Grads::default();
        let dh = head_bwd(&pv, &mut grads, &dlogits, &pooled, &cls, p.batch, p.max_seq, p.d_model);
        encode_bwd(&pv, &mut grads, &p, MethodKind::QrLora, dh, &cache, &ids, &type_ids, false);

        let lam_name = "qr/layer1/wo/lam";
        let lam_field = layout.param(lam_name).unwrap().clone();
        let analytic = grads.map.get(lam_name).unwrap().data.clone();

        // finite difference on two entries
        for idx in [0usize, 3] {
            let eps = 1e-2f32;
            let mut splus = state.clone();
            splus[lam_field.offset + idx] += eps;
            let mut sminus = state.clone();
            sminus[lam_field.offset + idx] -= eps;
            let loss_at = |st: &[f32]| -> f32 {
                let train = unpack_train(st, &layout);
                let pv = ParamView { train: &train, frozen: &frozen };
                let (h, _) = encode_fwd(&pv, &p, MethodKind::QrLora, &ids, &type_ids, &attn_mask);
                let (logits, _, _) =
                    head_fwd(&pv, HeadKind::Cls, &h, p.batch, p.max_seq, &class_mask);
                task_loss_bwd(HeadKind::Cls, &logits, &batch).0
            };
            let fd = (loss_at(&splus) - loss_at(&sminus)) / (2.0 * eps);
            let got = analytic[idx];
            assert!(
                (fd - got).abs() < 2e-2 * fd.abs().max(got.abs()).max(0.1),
                "dλ[{idx}]: fd {fd} vs analytic {got} (loss {loss0})"
            );
        }
    }

    #[test]
    fn train_step_reduces_loss_over_iterations() {
        let (p, layout) = layout_for("tiny/train_step_ft_cls");
        let mut state = rand_state(&layout, 11);
        let frozen = BTreeMap::new();
        let bs = p.batch * p.max_seq;
        let ids: Vec<i32> = (0..bs).map(|i| ((i * 13 + 5) % p.vocab) as i32).collect();
        let type_ids = vec![0i32; bs];
        let attn_mask = vec![1.0f32; bs];
        let labels: Vec<i32> = (0..p.batch).map(|i| ((i * 13) % 2) as i32).collect();
        let class_mask = vec![1.0, 1.0, 0.0];
        let example_w = vec![1.0f32; p.batch];
        let batch = TaskBatchRef {
            input_ids: &ids,
            type_ids: &type_ids,
            attn_mask: &attn_mask,
            labels_i32: &labels,
            labels_f32: &[],
            class_mask: &class_mask,
            example_w: &example_w,
        };
        let mut losses = Vec::new();
        for t in 1..=10 {
            let tf = t as f32;
            state = train_step(
                &p,
                MethodKind::Ft,
                HeadKind::Cls,
                &layout,
                &state,
                &frozen,
                &batch,
                5e-3,
                tf,
            );
            losses.push(state[0]);
        }
        assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
        assert!(losses[9] < losses[0], "loss did not fall: {losses:?}");
    }

    #[test]
    fn eval_matches_train_metrics_logits() {
        // eval_forward on the post-step state must equal the logits the step
        // recorded (same batch, same params).
        let (p, layout) = layout_for("tiny/train_step_qrlora_cls");
        let mut rng = Rng::new(21);
        let state = rand_state(&layout, 22);
        let m = Manifest::builtin();
        let a = m.artifact("tiny/train_step_qrlora_cls").unwrap();
        let mut frozen = BTreeMap::new();
        for (_, t) in a.inputs_with_role(crate::runtime::Role::Frozen) {
            let data: Vec<f32> = if t.name.ends_with("/mask") {
                vec![1.0; t.numel()]
            } else {
                (0..t.numel()).map(|_| rng.normal() * 0.1).collect()
            };
            frozen.insert(t.name.clone(), FrozenValue::dense(Tensor::from_vec(&t.shape, data)));
        }
        let bs = p.batch * p.max_seq;
        let ids: Vec<i32> = (0..bs).map(|i| ((i * 3 + 1) % p.vocab) as i32).collect();
        let type_ids = vec![0i32; bs];
        let attn_mask = vec![1.0f32; bs];
        let labels = vec![0i32; p.batch];
        let class_mask = vec![1.0f32; p.n_classes];
        let example_w = vec![1.0f32; p.batch];
        let batch = TaskBatchRef {
            input_ids: &ids,
            type_ids: &type_ids,
            attn_mask: &attn_mask,
            labels_i32: &labels,
            labels_f32: &[],
            class_mask: &class_mask,
            example_w: &example_w,
        };
        let next = train_step(
            &p,
            MethodKind::QrLora,
            HeadKind::Cls,
            &layout,
            &state,
            &frozen,
            &batch,
            1e-3,
            1.0,
        );
        let recorded = {
            let f = layout.metric("logits").unwrap();
            next[f.offset..f.offset + f.numel()].to_vec()
        };
        let evald =
            eval_forward(&p, MethodKind::QrLora, HeadKind::Cls, &layout, &next, &frozen, &batch);
        // recorded logits came from the *pre-update* params; re-running on the
        // post-update state must differ (params moved) but stay finite & close.
        assert_eq!(recorded.len(), evald.len());
        assert!(evald.iter().all(|v| v.is_finite()));
        // and evaluating the pre-step state reproduces the recorded metrics
        let evald0 =
            eval_forward(&p, MethodKind::QrLora, HeadKind::Cls, &layout, &state, &frozen, &batch);
        for (a, b) in evald0.iter().zip(&recorded) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn pretrain_step_runs_and_loss_finite() {
        let (p, layout) = layout_for("tiny/pretrain_step");
        let mut state = crate::model::init_state(&layout, 3);
        let bs = p.batch * p.max_seq;
        let ids: Vec<i32> = (0..bs).map(|i| ((i * 17 + 3) % p.vocab) as i32).collect();
        let type_ids = vec![0i32; bs];
        let attn_mask = vec![1.0f32; bs];
        let mut labels = vec![-100i32; bs];
        for i in (0..bs).step_by(7) {
            labels[i] = ((i * 31) % p.vocab) as i32;
        }
        let batch = MlmBatchRef {
            input_ids: &ids,
            type_ids: &type_ids,
            attn_mask: &attn_mask,
            mlm_labels: &labels,
        };
        let mut losses = Vec::new();
        for t in 1..=6 {
            state = pretrain_step(&p, &layout, &state, &batch, 2e-3, t as f32);
            losses.push(state[0]);
        }
        assert!(losses.iter().all(|l| l.is_finite() && *l > 0.0), "{losses:?}");
        assert!(losses[5] < losses[0], "mlm loss did not fall: {losses:?}");
    }
}
