//! Host-side model state management: initialization of the flat state
//! vector, named parameter access, and backbone checkpointing.
//!
//! On the PJRT backend the actual math lives in the AOT graphs; this module
//! only knows the *layout* (from the manifest) and the initialization
//! rules, which mirror `python/compile/model.py::init_backbone`. The
//! [`host`] submodule additionally implements the full reference
//! forward/backward/Adam step in pure Rust for `runtime::HostBackend`.

pub mod checkpoint;
pub mod host;

use std::collections::BTreeMap;

use crate::runtime::StateLayout;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Initialization rule for one named parameter.
fn init_leaf(name: &str, shape: &[usize], rng: &mut Rng, out: &mut [f32]) {
    let last = name.rsplit('/').next().unwrap_or(name);
    let is_gain = last.ends_with("_g") || last == "ln_g";
    let is_bias =
        last.starts_with('b') && shape.len() == 1 || last.ends_with("_b") || last == "bias";
    let is_emb = name.starts_with("emb/") && shape.len() == 2;
    let is_lam = last == "lam";
    let is_lora_b = name.starts_with("lora/") && last == "B";
    let is_lora_a = name.starts_with("lora/") && last == "A";

    if is_gain {
        out.fill(1.0);
    } else if is_lam || is_lora_b {
        // Adapters start at ΔW = 0: λ=0 (QR-LoRA), B=0 (LoRA).
        out.fill(0.0);
    } else if is_emb {
        for v in out.iter_mut() {
            *v = rng.normal() * 0.02;
        }
    } else if is_lora_a {
        for v in out.iter_mut() {
            *v = rng.normal() * 0.02;
        }
    } else if is_bias || shape.len() == 1 {
        out.fill(0.0);
    } else {
        // Xavier for matrices.
        let fan: usize = shape.iter().sum();
        let std = (2.0 / fan as f32).sqrt();
        for v in out.iter_mut() {
            *v = rng.normal() * std;
        }
    }
}

/// Build a freshly initialized flat state vector for a layout.
/// Moments and the metrics head start at zero.
pub fn init_state(layout: &StateLayout, seed: u64) -> Vec<f32> {
    let mut state = vec![0f32; layout.total];
    let rng = Rng::new(seed);
    for field in &layout.params {
        let mut leaf_rng = rng.split(hash_name(&field.name));
        init_leaf(
            &field.name,
            &field.shape,
            &mut leaf_rng,
            &mut state[field.offset..field.offset + field.numel()],
        );
    }
    state
}

fn hash_name(name: &str) -> u64 {
    crate::util::hash::fnv1a_str(name)
}

/// Read one named parameter out of a state vector.
pub fn read_param(state: &[f32], layout: &StateLayout, name: &str) -> anyhow::Result<Tensor> {
    let f = layout.param(name)?;
    Ok(Tensor::from_vec(
        &f.shape,
        state[f.offset..f.offset + f.numel()].to_vec(),
    ))
}

/// Write one named parameter into a state vector.
pub fn write_param(
    state: &mut [f32],
    layout: &StateLayout,
    name: &str,
    value: &Tensor,
) -> anyhow::Result<()> {
    let f = layout.param(name)?;
    anyhow::ensure!(
        f.shape == value.shape,
        "{name}: shape mismatch {:?} vs {:?}",
        f.shape,
        value.shape
    );
    state[f.offset..f.offset + f.numel()].copy_from_slice(&value.data);
    Ok(())
}

/// Extract every named parameter from a state vector (e.g. to hand a
/// pretrained backbone to an adapter run as frozen inputs).
pub fn extract_all(state: &[f32], layout: &StateLayout) -> BTreeMap<String, Tensor> {
    layout
        .params
        .iter()
        .map(|f| {
            (
                f.name.clone(),
                Tensor::from_vec(&f.shape, state[f.offset..f.offset + f.numel()].to_vec()),
            )
        })
        .collect()
}

/// Copy parameters that exist in both layouts from `src` into `dst`
/// (e.g. seed an FT fine-tune run with pretrained backbone weights, or
/// carry the warmed head into an adapter run). Returns the copied names.
pub fn transfer_params(
    src: &[f32],
    src_layout: &StateLayout,
    dst: &mut [f32],
    dst_layout: &StateLayout,
) -> Vec<String> {
    let mut copied = Vec::new();
    for f in &dst_layout.params {
        if let Ok(sf) = src_layout.param(&f.name) {
            if sf.shape == f.shape {
                dst[f.offset..f.offset + f.numel()]
                    .copy_from_slice(&src[sf.offset..sf.offset + sf.numel()]);
                copied.push(f.name.clone());
            }
        }
    }
    copied
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{StateField, StateLayout};

    fn layout() -> StateLayout {
        let fields = vec![
            StateField { name: "emb/tok".into(), shape: vec![8, 4], offset: 2 },
            StateField { name: "layer0/ln1_g".into(), shape: vec![4], offset: 34 },
            StateField { name: "layer0/attn/wq".into(), shape: vec![4, 4], offset: 38 },
            StateField { name: "qr/layer0/wq/lam".into(), shape: vec![6], offset: 54 },
            StateField { name: "head/bc".into(), shape: vec![3], offset: 60 },
        ];
        StateLayout {
            n_params: 61,
            metrics_len: 2,
            total: 2 + 3 * 61,
            params: fields,
            metrics: vec![StateField { name: "loss".into(), shape: vec![], offset: 0 }],
        }
    }

    #[test]
    fn init_rules() {
        let l = layout();
        let s = init_state(&l, 42);
        // metrics head zero
        assert_eq!(&s[..2], &[0.0, 0.0]);
        // ln gain ones
        assert_eq!(&s[34..38], &[1.0; 4]);
        // λ zero
        assert_eq!(&s[54..60], &[0.0; 6]);
        // bias zero
        assert_eq!(&s[60..63], &[0.0; 3]);
        // embeddings small but nonzero
        let emb = &s[2..34];
        assert!(emb.iter().any(|&v| v != 0.0));
        assert!(emb.iter().all(|&v| v.abs() < 0.2));
        // wq xavier-ish
        let wq = &s[38..54];
        assert!(wq.iter().any(|&v| v != 0.0));
        // moments region zero
        assert!(s[2 + 61..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn init_deterministic_and_order_free() {
        let l = layout();
        assert_eq!(init_state(&l, 1), init_state(&l, 1));
        assert_ne!(init_state(&l, 1), init_state(&l, 2));
    }

    #[test]
    fn read_write_roundtrip() {
        let l = layout();
        let mut s = init_state(&l, 3);
        let t = Tensor::filled(&[4, 4], 0.5);
        write_param(&mut s, &l, "layer0/attn/wq", &t).unwrap();
        let r = read_param(&s, &l, "layer0/attn/wq").unwrap();
        assert_eq!(r, t);
    }

    #[test]
    fn write_shape_mismatch_errors() {
        let l = layout();
        let mut s = init_state(&l, 3);
        let t = Tensor::filled(&[2, 2], 0.5);
        assert!(write_param(&mut s, &l, "layer0/attn/wq", &t).is_err());
    }

    #[test]
    fn transfer_copies_matching() {
        let l = layout();
        let src = init_state(&l, 9);
        let mut dst = init_state(&l, 10);
        let copied = transfer_params(&src, &l, &mut dst, &l);
        assert_eq!(copied.len(), l.params.len());
        assert_eq!(&dst[2..2 + 61], &src[2..2 + 61]);
    }

    #[test]
    fn extract_all_names() {
        let l = layout();
        let s = init_state(&l, 4);
        let map = extract_all(&s, &l);
        assert_eq!(map.len(), 5);
        assert!(map.contains_key("emb/tok"));
        assert_eq!(map["head/bc"].shape, vec![3]);
    }
}
