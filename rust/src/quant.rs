//! Int8 quantization of the frozen backbone (host serving path).
//!
//! QR-LoRA keeps the pretrained backbone strictly read-only — adaptation
//! lives in the tiny λ coefficient vector over the frozen QR basis — so
//! the backbone weights are pure read-only operands and can be held in
//! int8 with no effect on what trains. This module provides:
//!
//! * [`QuantTensor`] — symmetric absmax int8 quantization with one f32
//!   scale per **row group** ([`QUANT_GROUP_ROWS`] rows share a scale), so
//!   an outlier row can only perturb its own group;
//! * fused int8 matmuls ([`matmul_xw_q`], [`matmul_dyw_t_q`]) that mirror
//!   `Tensor::matmul_t` / the saxpy contraction, row-parallel over the
//!   worker pool and dispatched through [`crate::kernels::Kernels`]. On a
//!   SIMD backend the forward product runs a true integer inner loop
//!   (i8×i8 accumulated in i32 lanes, scales applied once per output);
//!   forced-scalar keeps the fused dequant-on-the-fly reference with the
//!   same bit-identical-for-any-thread-count guarantee (per-output-element
//!   evaluation order never depends on the partition);
//! * the [`plan`] that decides which frozen inputs quantize (embedding
//!   tables and attention/FFN projection matrices) and in which
//!   orientation. QR factors, λ, masks, LoRA A/B, task heads, LayerNorm
//!   parameters, biases, and every gradient stay f32.
//!
//! # Accuracy contract
//!
//! Per-group error is bounded by `absmax(group) / 254` per element
//! (symmetric absmax, round-to-nearest — enforced by
//! `rust/tests/quant.rs`). End to end, adapters *train against* the
//! quantized backbone, so the documented contract is on eval metrics: the
//! quantized path's eval metric must stay within
//! [`METRIC_DELTA_BOUND`] of the f32 path for both adapter methods
//! (enforced by `rust/tests/quant.rs::eval_metric_parity_quant_vs_f32`).
//!
//! Enable with `--quantize-backbone` (CLI) or `QRLORA_QUANT=1`; see the
//! README's perf-knobs section and `ARCHITECTURE.md` ("Quantized frozen
//! cache").

use crate::kernels;
use crate::tensor::Tensor;
use crate::util::pool;

/// Rows per shared scale (the "row group"). Four rows per f32 scale keeps
/// the resident footprint at ≥3.75x below f32 even for narrow matrices
/// while an outlier row can only perturb three neighbors.
pub const QUANT_GROUP_ROWS: usize = 4;

/// Documented eval-metric accuracy contract of the quantized backbone:
/// the absolute delta of any eval metric (accuracy / F1 / Pearson) vs the
/// f32 path, when the adapter was trained against its own backbone
/// representation. Enforced by `rust/tests/quant.rs`.
pub const METRIC_DELTA_BOUND: f64 = 0.1;

/// How a frozen input participates in quantization.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantPlan {
    /// Stays f32 (QR factors, masks, LoRA scales, LayerNorm, biases).
    Keep,
    /// Row-gather table (embeddings): quantized in natural orientation so
    /// a gather dequantizes one contiguous row.
    Rows,
    /// Projection matrix `W (k×n)`: quantized **transposed** (n×k) so the
    /// forward `x·W` dots contiguous rows (per-output-channel scales) and
    /// the backward `dy·Wᵀ` streams the same rows as axpys.
    Transposed,
}

/// Which frozen inputs quantize, and how. Only 2-D backbone weights
/// qualify; adapter factors and every 1-D parameter stay f32.
pub fn plan(name: &str, shape: &[usize]) -> QuantPlan {
    if shape.len() != 2 {
        return QuantPlan::Keep;
    }
    match name {
        "emb/tok" | "emb/pos" | "emb/type" => QuantPlan::Rows,
        _ if name.contains("/attn/w") || name.contains("/ffn/w") => QuantPlan::Transposed,
        _ => QuantPlan::Keep,
    }
}

/// `QRLORA_QUANT` env knob (set by the CLI's `--quantize-backbone`).
/// Case-insensitive: `0`/`false`/`off`/`no`/empty disable, anything else
/// enables.
pub fn quant_backbone_from_env() -> bool {
    match std::env::var("QRLORA_QUANT") {
        Ok(v) => !matches!(
            v.trim().to_ascii_lowercase().as_str(),
            "" | "0" | "false" | "off" | "no"
        ),
        Err(_) => false,
    }
}

/// Row-major int8 matrix with one f32 scale per group of
/// [`QUANT_GROUP_ROWS`] rows (symmetric absmax: `w ≈ scale · q`,
/// `q ∈ [-127, 127]`).
#[derive(Clone, Debug, PartialEq)]
pub struct QuantTensor {
    /// Dimension sizes of the stored matrix (rank 2).
    pub shape: Vec<usize>,
    /// Row-major int8 values.
    pub q: Vec<i8>,
    /// One scale per row group, `ceil(rows / group_rows)` of them.
    pub scales: Vec<f32>,
    /// Rows sharing one scale.
    pub group_rows: usize,
}

impl QuantTensor {
    /// Quantize a rank-2 tensor with per-row-group symmetric absmax
    /// scales. An all-zero group gets scale 1.0 (its values are exactly 0).
    pub fn quantize(src: &Tensor, group_rows: usize) -> QuantTensor {
        let (r, c) = (src.rows(), src.cols());
        let g = group_rows.max(1);
        let n_groups = r.div_ceil(g);
        let mut scales = vec![0f32; n_groups];
        let mut q = vec![0i8; r * c];
        for (gi, scale_out) in scales.iter_mut().enumerate() {
            let lo = gi * g * c;
            let hi = ((gi * g + g) * c).min(r * c);
            let mut absmax = 0f32;
            for v in &src.data[lo..hi] {
                absmax = absmax.max(v.abs());
            }
            let scale = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
            *scale_out = scale;
            let inv = 1.0 / scale;
            for (dst, &v) in q[lo..hi].iter_mut().zip(&src.data[lo..hi]) {
                *dst = (v * inv).round().clamp(-127.0, 127.0) as i8;
            }
        }
        QuantTensor { shape: src.shape.clone(), q, scales, group_rows: g }
    }

    /// Number of rows of the stored matrix.
    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    /// Number of columns of the stored matrix.
    pub fn cols(&self) -> usize {
        self.shape[1]
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.q.len()
    }

    /// Int8 row slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[i8] {
        let c = self.shape[1];
        &self.q[i * c..(i + 1) * c]
    }

    /// Scale of row `i` (its group's scale).
    #[inline]
    pub fn scale_of_row(&self, i: usize) -> f32 {
        self.scales[i / self.group_rows]
    }

    /// Full-precision reconstruction `scale · q` (tests, debugging).
    pub fn dequantize(&self) -> Tensor {
        let (r, c) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&self.shape);
        for i in 0..r {
            let s = self.scale_of_row(i);
            let qr = self.row(i);
            for (o, &qv) in out.data[i * c..(i + 1) * c].iter_mut().zip(qr) {
                *o = s * qv as f32;
            }
        }
        out
    }

    /// Resident footprint in bytes (int8 values + f32 scales).
    pub fn resident_bytes(&self) -> usize {
        self.q.len() + self.scales.len() * 4
    }

    /// What the same matrix costs in f32.
    pub fn f32_bytes(&self) -> usize {
        self.q.len() * 4
    }
}

/// Forward int8 product `x (m×k) @ W` with `w` holding the weight in
/// transposed int8 form (n×k): `out[i,j] ≈ Σ_e x[i,e]·scale(j)·q[j,e]`,
/// i.e. `x·W → (m×n)`.
///
/// Row-parallel over output rows; each pool span is one
/// [`kernels::Kernels::matmul_xw_q`] call, which keeps the reference's
/// column blocking. On the scalar backend (`QRLORA_SIMD=scalar`) this is
/// the fused dequant-on-the-fly reference, bit-identical for any thread
/// count and to the pre-kernels implementation. On a SIMD backend it is
/// the true integer inner loop — activations quantized once per row,
/// i8×i8 accumulated in i32 lanes, scales applied once per output — which
/// is exact integer arithmetic (identical across AVX2/NEON and bit-stable
/// for any thread count) but differs from the scalar reference within the
/// activation-quantization bound documented on the kernel method.
pub fn matmul_xw_q(x: &Tensor, w: &QuantTensor) -> Tensor {
    let (m, k) = (x.rows(), x.cols());
    let (n, k2) = (w.rows(), w.cols());
    assert_eq!(k, k2, "matmul_xw_q shape mismatch: {:?} @ t{:?}", x.shape, w.shape);
    let mut out = Tensor::zeros(&[m, n]);
    if m == 0 || n == 0 {
        return out;
    }
    // Resolve the kernel selection on this thread: pool workers do not see
    // the caller's `kernels::with_kernels` override.
    let kern = kernels::active();
    let work = m.saturating_mul(n).saturating_mul(k.max(1));
    pool::par_rows(&mut out.data, m, work, |row0, chunk| {
        let rows = chunk.len() / n;
        let x_rows = &x.data[row0 * k..(row0 + rows) * k];
        kern.matmul_xw_q(x_rows, k, &w.q, &w.scales, w.group_rows, n, chunk);
    });
    out
}

/// Backward int8 product `dy (m×n) @ Wᵀ → (m×k)` with `w` holding the
/// weight `W (k×n)` in transposed int8 form (n×k), computed as a sum of
/// scaled int8 row axpys: `out[i,:] += (dy[i,j]·scale(j)) · q[j,:]`.
///
/// Row-parallel over output rows; each pool span is one
/// [`kernels::Kernels::matmul_dyw_t_q`] call. Each row accumulates over
/// `j` in the serial order with an exact int8 axpy on every backend, so
/// results are bit-identical for any thread count *and* any backend
/// (gradients stay f32-faithful; only the forward product quantizes
/// activations). The `c == 0.0` skip mirrors `Tensor::t_matmul`'s
/// (gradient rows zeroed by masking skip the whole axpy).
pub fn matmul_dyw_t_q(dy: &Tensor, w: &QuantTensor) -> Tensor {
    let (m, n) = (dy.rows(), dy.cols());
    let (n2, k) = (w.rows(), w.cols());
    assert_eq!(n, n2, "matmul_dyw_t_q shape mismatch: {:?} @ {:?}", dy.shape, w.shape);
    let mut out = Tensor::zeros(&[m, k]);
    if m == 0 || k == 0 {
        return out;
    }
    let kern = kernels::active();
    let work = m.saturating_mul(n).saturating_mul(k.max(1));
    pool::par_rows(&mut out.data, m, work, |row0, chunk| {
        let rows = chunk.len() / k;
        let dy_rows = &dy.data[row0 * n..(row0 + rows) * n];
        kern.matmul_dyw_t_q(dy_rows, n, &w.q, &w.scales, w.group_rows, k, chunk);
    });
    out
}

/// Former name of [`matmul_xw_q`] (PR-4 era), kept for one PR.
#[deprecated(note = "renamed to `matmul_xw_q`; routes through kernels::Kernels")]
pub fn matmul_qt(x: &Tensor, w: &QuantTensor) -> Tensor {
    matmul_xw_q(x, w)
}

/// Former name of [`matmul_dyw_t_q`] (PR-4 era), kept for one PR.
#[deprecated(note = "renamed to `matmul_dyw_t_q`; routes through kernels::Kernels")]
pub fn matmul_q(x: &Tensor, w: &QuantTensor) -> Tensor {
    matmul_dyw_t_q(x, w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn quantize_shapes_and_group_count() {
        let mut rng = Rng::new(1);
        let t = Tensor::randn(&[10, 6], &mut rng, 1.0);
        let q = QuantTensor::quantize(&t, 4);
        assert_eq!(q.shape, vec![10, 6]);
        assert_eq!(q.q.len(), 60);
        assert_eq!(q.scales.len(), 3); // ceil(10/4)
        assert_eq!(q.resident_bytes(), 60 + 12);
        assert_eq!(q.f32_bytes(), 240);
    }

    #[test]
    fn zero_group_roundtrips_exactly() {
        let t = Tensor::zeros(&[4, 8]);
        let q = QuantTensor::quantize(&t, 2);
        assert!(q.dequantize().max_abs_diff(&t) == 0.0);
    }

    #[test]
    fn plan_selects_backbone_weights_only() {
        assert_eq!(plan("emb/tok", &[512, 64]), QuantPlan::Rows);
        assert_eq!(plan("emb/pos", &[32, 64]), QuantPlan::Rows);
        assert_eq!(plan("layer0/attn/wq", &[64, 64]), QuantPlan::Transposed);
        assert_eq!(plan("layer1/ffn/w2", &[256, 64]), QuantPlan::Transposed);
        // Adapter factors, masks, and 1-D parameters stay f32.
        assert_eq!(plan("qr/layer0/wq/Q", &[64, 32]), QuantPlan::Keep);
        assert_eq!(plan("qr/layer0/wq/R", &[32, 64]), QuantPlan::Keep);
        assert_eq!(plan("qr/layer0/wq/mask", &[32]), QuantPlan::Keep);
        assert_eq!(plan("lora/layer0/wq/scale", &[2]), QuantPlan::Keep);
        assert_eq!(plan("emb/ln_g", &[64]), QuantPlan::Keep);
        assert_eq!(plan("layer0/attn/bq", &[64]), QuantPlan::Keep);
    }

    #[test]
    fn dequant_error_within_absmax_over_254() {
        let mut rng = Rng::new(2);
        for g in [1usize, 4] {
            let t = Tensor::randn(&[12, 16], &mut rng, 2.0);
            let q = QuantTensor::quantize(&t, g);
            let back = q.dequantize();
            for i in 0..t.rows() {
                let bound = q.scale_of_row(i) * 0.5 + 1e-6;
                for j in 0..t.cols() {
                    let err = (t.at(i, j) - back.at(i, j)).abs();
                    assert!(err <= bound, "g={g} ({i},{j}): err {err} > {bound}");
                }
            }
        }
    }
}
