//! Batched multi-adapter serving.
//!
//! QR-LoRA's headline property — adaptation is a tiny per-task λ/head
//! state vector over a shared frozen backbone — makes multi-tenant serving
//! nearly free. This module exploits it end to end:
//!
//! * [`AdapterBank`] keeps N adapters' state vectors **resident** on the
//!   backend (capacity-bounded, LRU-evicted), uploaded once at admission;
//! * [`Router`] drains a FIFO admission queue into **mixed-task batches**
//!   and serves each with a single [`crate::runtime::Backend::execute_batched`]
//!   call — on the host backend that is one shared backbone pass with
//!   per-row adapter deltas and task heads, eliminating per-request state
//!   swaps entirely;
//! * [`serve_swap`] is the swap-per-request baseline — one request at a
//!   time, state re-uploaded on task change (`serve_swap` vs
//!   `serve_task_grouped` vs `serve_mixed_batch` in `BENCH_host.json`) —
//!   and the shape a backend without a batched fast path tends toward
//!   (PJRT runs the grouped fallback: one backbone pass per distinct task
//!   in the batch).
//!
//! Per-request results are bit-identical between the two paths — every op
//! on the forward path is row-local — enforced by
//! `rust/tests/serve_batched.rs`. See `ARCHITECTURE.md` for the request
//! lifecycle diagram.
//!
//! [`fleet`] scales this out to N worker *processes* sharing one durable
//! adapter store (`serve --fleet N`): a supervisor partitions tasks over
//! a consistent-hash ring, workers train-and-publish their partition and
//! hot-load sibling publishes by store-watching the index generation.
//! [`ServeCore`] is the per-process serving context both the
//! single-process [`demo`] and every fleet worker build the same way.

pub mod fleet;
pub mod net;
pub mod queue;

use std::collections::{BTreeMap, VecDeque};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

use crate::adapters::{Proj, Scope};
use crate::obs;
use crate::data::{metric_kind, task, Batcher, Example, HeadKind, Split};
use crate::experiments::{ExpConfig, Pipeline};
use crate::linalg::RankRule;
use crate::metrics::argmax;
use crate::runtime::{Backend, Buffer, Preset, StateLayout};
use crate::store::{self, AdapterRecord, Registry, Source, TieredAdapters};
use crate::tensor::Tensor;
use crate::training::{Method, Methods, Session, TrainConfig};
use crate::util::cli::Args;
use crate::util::rng::Rng;

/// The demo task set: one adapter per task over the shared backbone.
/// The fleet supervisor partitions exactly this set across workers, so
/// single-process and fleet runs populate the same store keys.
pub const SERVE_TASKS: &[&str] = &["sst2", "mrpc", "qnli"];

/// One inference request.
#[derive(Clone)]
pub struct Request {
    /// Caller-assigned id (stable across router paths, used to join
    /// results).
    pub id: usize,
    /// Task name; must have a registered adapter.
    pub task: String,
    /// The example to classify/score.
    pub example: Example,
}

/// Router statistics, batched vs swap paths broken out.
#[derive(Debug, Default)]
pub struct RouterStats {
    /// Requests served (both paths).
    pub requests: usize,
    /// Batches evaluated.
    pub batches: usize,
    /// Requests served through the batched bank path.
    pub batched_requests: usize,
    /// Requests served through the swap-per-request path.
    pub swap_requests: usize,
    /// Adapter-state uploads: bank admissions on the batched path, state
    /// swaps on the legacy path.
    pub swaps: usize,
    /// Bank slots recycled under capacity pressure (subset of `swaps`).
    pub evictions: usize,
    /// Total time spent uploading adapter state, milliseconds.
    pub swap_ms: f64,
    /// Total inference time, milliseconds.
    pub infer_ms: f64,
    /// Wall-clock serving time, seconds.
    pub wall_s: f64,
    /// Requests shed with an explicit 503-style reply (queue full,
    /// adapter unavailable, shutdown drain). Only the socket front-end
    /// ([`net::serve_listen`]) sheds; in-process paths leave this 0.
    pub shed: usize,
    /// Requests rejected with a 4xx-style protocol error (malformed
    /// JSON, unknown task, oversized line). Socket front-end only.
    pub rejected: usize,
}

impl RouterStats {
    /// Average state-upload cost; `None` when no swap ever happened.
    pub fn swap_avg_ms(&self) -> Option<f64> {
        if self.swaps > 0 {
            Some(self.swap_ms / self.swaps as f64)
        } else {
            None
        }
    }

    /// `"{count} ({avg} ms avg)"` — prints `n/a` rather than a misleading
    /// `0.00 ms avg` when no swaps occurred.
    pub fn swap_summary(&self) -> String {
        match self.swap_avg_ms() {
            Some(avg) => format!("{} ({avg:.2} ms avg)", self.swaps),
            None => format!("{} (n/a)", self.swaps),
        }
    }

    /// Requests per second over the recorded wall time.
    pub fn throughput(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.requests as f64 / self.wall_s
        } else {
            0.0
        }
    }
}

/// Registry handles for the router/bank hot path, resolved once so
/// per-batch updates cost one relaxed atomic op each.
struct RouterMetrics {
    batches: &'static obs::Counter,
    batched_requests: &'static obs::Counter,
    /// Sum of distinct tasks per batch — divide by `router.batches` for
    /// mean batch occupancy.
    occupancy_total: &'static obs::Counter,
    assemble_ms: &'static obs::HistMetric,
    execute_ms: &'static obs::HistMetric,
    bank_hits: &'static obs::Counter,
    bank_uploads: &'static obs::Counter,
    bank_evictions: &'static obs::Counter,
    bank_resident: &'static obs::Gauge,
    bank_pinned: &'static obs::Gauge,
}

fn router_metrics() -> &'static RouterMetrics {
    static M: OnceLock<RouterMetrics> = OnceLock::new();
    M.get_or_init(|| RouterMetrics {
        batches: obs::counter("router.batches"),
        batched_requests: obs::counter("router.batched_requests"),
        occupancy_total: obs::counter("router.occupancy_total"),
        assemble_ms: obs::histogram("router.assemble_ms"),
        execute_ms: obs::histogram("router.execute_ms"),
        bank_hits: obs::counter("bank.hits"),
        bank_uploads: obs::counter("bank.uploads"),
        bank_evictions: obs::counter("bank.evictions"),
        bank_resident: obs::gauge("bank.resident"),
        bank_pinned: obs::gauge("bank.pinned"),
    })
}

/// Backend-resident adapter states, keyed by task.
///
/// Each slot holds one task's flat state vector and padded class mask,
/// uploaded once at admission; `execute_batched` reads them in place, so
/// serving a resident task costs zero uploads. Capacity-bounded with LRU
/// eviction; eviction respects the `pinned` slots of the batch currently
/// being assembled so an in-flight batch can never lose an adapter.
pub struct AdapterBank {
    capacity: usize,
    slots: Vec<BankSlot>,
    clock: u64,
}

struct BankSlot {
    task: String,
    state: Buffer,
    class_mask: Buffer,
    last_used: u64,
}

/// Outcome of [`AdapterBank::admit`].
pub struct Admission {
    /// Slot index the task now occupies.
    pub slot: usize,
    /// True when the state was uploaded (first admission or refill after
    /// eviction); false on a resident hit.
    pub uploaded: bool,
    /// True when the upload recycled an occupied slot.
    pub evicted: bool,
}

impl AdapterBank {
    /// A bank holding at most `capacity` resident adapters (min 1).
    pub fn new(capacity: usize) -> AdapterBank {
        AdapterBank { capacity: capacity.max(1), slots: Vec::new(), clock: 0 }
    }

    /// Resident adapter count.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True when no adapter is resident.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Maximum resident adapters.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Slot index of a resident task.
    pub fn slot_of(&self, task: &str) -> Option<usize> {
        self.slots.iter().position(|s| s.task == task)
    }

    /// Ensure `task` is resident and return its slot. Uploads the state on
    /// a miss, evicting the least-recently-used slot not in `pinned` when
    /// at capacity. Errors when every slot is pinned (the caller must
    /// flush its batch first).
    pub fn admit(
        &mut self,
        bk: &dyn Backend,
        task: &str,
        state: &[f32],
        class_mask: &[f32],
        pinned: &[usize],
    ) -> anyhow::Result<Admission> {
        self.clock += 1;
        if let Some(i) = self.slot_of(task) {
            self.slots[i].last_used = self.clock;
            router_metrics().bank_hits.inc();
            return Ok(Admission { slot: i, uploaded: false, evicted: false });
        }
        // Pick the destination before uploading anything, so the
        // every-slot-pinned error path costs no backend traffic.
        let victim = if self.slots.len() < self.capacity {
            None
        } else {
            Some(
                self.slots
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| !pinned.contains(i))
                    .min_by_key(|(_, s)| s.last_used)
                    .map(|(i, _)| i)
                    .ok_or_else(|| anyhow::anyhow!("adapter bank: every slot is pinned"))?,
            )
        };
        let slot = BankSlot {
            task: task.to_string(),
            state: bk.upload_f32(state, &[state.len()])?,
            class_mask: bk.upload_f32(class_mask, &[class_mask.len()])?,
            last_used: self.clock,
        };
        let m = router_metrics();
        m.bank_uploads.inc();
        let adm = match victim {
            None => {
                self.slots.push(slot);
                Admission { slot: self.slots.len() - 1, uploaded: true, evicted: false }
            }
            Some(lru) => {
                m.bank_evictions.inc();
                self.slots[lru] = slot;
                Admission { slot: lru, uploaded: true, evicted: true }
            }
        };
        m.bank_resident.set(self.slots.len() as i64);
        Ok(adm)
    }

    /// Per-slot state buffers, index-aligned with slot ids (for
    /// `execute_batched`).
    pub fn states(&self) -> Vec<&Buffer> {
        self.slots.iter().map(|s| &s.state).collect()
    }

    /// Per-slot class-mask buffers, index-aligned with slot ids.
    pub fn class_masks(&self) -> Vec<&Buffer> {
        self.slots.iter().map(|s| &s.class_mask).collect()
    }
}

/// A registered adapter: the task's trained state and class mask, the
/// source of truth the bank admits from.
struct LibraryEntry {
    state: Vec<f32>,
    class_mask: Vec<f32>,
}

/// Batched serving router.
///
/// Request lifecycle: FIFO admission queue → batch assembly (up to
/// `max_batch` consecutive requests, admitting each task into the
/// [`AdapterBank`] as it appears) → one `execute_batched` call → per-row
/// logits scattered back to requests. A batch is flushed early only when
/// the next request's task would need to evict a slot the batch already
/// uses.
pub struct Router<'s, 'b> {
    session: &'s Session<'b>,
    batcher: Batcher,
    bank: AdapterBank,
    library: BTreeMap<String, LibraryEntry>,
    max_batch: usize,
    head_width: usize,
    /// Counters for the serving report (batched vs swap breakdown).
    pub stats: RouterStats,
}

impl<'s, 'b> Router<'s, 'b> {
    /// Build a router over a shared session (frozen backbone + eval
    /// executable). `max_batch` is clamped to the artifact's fixed batch
    /// size (0 = use it as-is); `resident_adapters` bounds the bank.
    pub fn new(
        session: &'s Session<'b>,
        batcher: Batcher,
        max_batch: usize,
        resident_adapters: usize,
    ) -> anyhow::Result<Router<'s, 'b>> {
        let head_width = session.layout().param("head/wc")?.shape[1];
        let max_batch = if max_batch == 0 {
            batcher.batch
        } else {
            max_batch.clamp(1, batcher.batch)
        };
        Ok(Router {
            session,
            batcher,
            bank: AdapterBank::new(resident_adapters),
            library: BTreeMap::new(),
            max_batch,
            head_width,
            stats: RouterStats::default(),
        })
    }

    /// Register a task's trained adapter state (layout must match the
    /// session's).
    pub fn register(
        &mut self,
        task: &str,
        state: Vec<f32>,
        n_classes: usize,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            state.len() == self.session.layout().total,
            "adapter for {task:?} has {} elements, session layout wants {}",
            state.len(),
            self.session.layout().total
        );
        let class_mask = Batcher::class_mask(n_classes, self.head_width);
        self.library.insert(task.to_string(), LibraryEntry { state, class_mask });
        Ok(())
    }

    /// Resident adapter count (bank occupancy).
    pub fn resident(&self) -> usize {
        self.bank.len()
    }

    /// Serve every queued request through the batched path; returns
    /// `(request, logits)` pairs in completion order (logits are
    /// `head_width` floats, padded classes masked to −∞).
    pub fn serve(
        &mut self,
        queue: &mut VecDeque<Request>,
    ) -> anyhow::Result<Vec<(Request, Vec<f32>)>> {
        // Reject unknown tasks up front, before any request is popped, so
        // a bad request can't strand already-dequeued work mid-batch.
        for r in queue.iter() {
            anyhow::ensure!(
                self.library.contains_key(&r.task),
                "no adapter registered for task {:?} (request {})",
                r.task,
                r.id
            );
        }
        let bk = self.session.backend();
        let k = self.head_width;
        let t_wall = Instant::now();
        let mut results = Vec::new();
        let m = router_metrics();
        while !queue.is_empty() {
            // --- batch assembly + bank admission --------------------------
            let t_asm = Instant::now();
            let mut reqs: Vec<Request> = Vec::new();
            let mut row_slots: Vec<usize> = Vec::new();
            while reqs.len() < self.max_batch {
                let Some(front) = queue.front() else { break };
                let tname = front.task.clone();
                // Present by the prescan at serve() entry; checked again
                // so a future library mutation degrades to an error on
                // this request, never a server panic.
                let Some(entry) = self.library.get(&tname) else {
                    anyhow::bail!(
                        "adapter for task {tname:?} vanished from the library mid-batch \
                         (request {})",
                        front.id
                    );
                };
                let mut pinned: Vec<usize> = row_slots.clone();
                pinned.sort_unstable();
                pinned.dedup();
                if self.bank.slot_of(&tname).is_none()
                    && self.bank.len() >= self.bank.capacity()
                    && pinned.len() >= self.bank.capacity()
                {
                    // Admitting would evict a slot this batch uses: flush.
                    break;
                }
                let t0 = Instant::now();
                let adm = self.bank.admit(bk, &tname, &entry.state, &entry.class_mask, &pinned)?;
                if adm.uploaded {
                    self.stats.swap_ms += t0.elapsed().as_secs_f64() * 1e3;
                    self.stats.swaps += 1;
                    if adm.evicted {
                        self.stats.evictions += 1;
                    }
                }
                row_slots.push(adm.slot);
                let Some(req) = queue.pop_front() else { break };
                reqs.push(req);
            }
            // A non-empty queue always admits at least one request, but
            // bail (don't index-panic) if that invariant ever breaks.
            let Some(&slot0) = row_slots.first() else {
                anyhow::bail!("batch assembly yielded no requests from a non-empty queue");
            };

            // --- one mixed pass -------------------------------------------
            let refs: Vec<&Example> = reqs.iter().map(|r| &r.example).collect();
            let batch = self.batcher.assemble(&refs);
            let mut slots_padded = row_slots.clone();
            slots_padded.resize(self.batcher.batch, slot0);
            let states = self.bank.states();
            let masks = self.bank.class_masks();
            m.assemble_ms.record_ms(t_asm.elapsed().as_secs_f64() * 1e3);
            let t0 = Instant::now();
            let logits = self.session.forward_multi(&batch, &states, &masks, &slots_padded)?;
            let infer_ms = t0.elapsed().as_secs_f64() * 1e3;
            self.stats.infer_ms += infer_ms;
            self.stats.batches += 1;
            self.stats.requests += reqs.len();
            self.stats.batched_requests += reqs.len();
            m.execute_ms.record_ms(infer_ms);
            m.batches.inc();
            m.batched_requests.add(reqs.len() as u64);
            let mut distinct = row_slots.clone();
            distinct.sort_unstable();
            distinct.dedup();
            m.occupancy_total.add(distinct.len() as u64);
            m.bank_pinned.set(distinct.len() as i64);
            for (i, r) in reqs.into_iter().enumerate() {
                results.push((r, logits[i * k..(i + 1) * k].to_vec()));
            }
        }
        self.stats.wall_s += t_wall.elapsed().as_secs_f64();
        Ok(results)
    }
}

/// Reference swap-per-request serving loop: one request at a time, the
/// whole state vector re-uploaded on every task change.
///
/// Note this is deliberately the *weakest* baseline (every request pays a
/// full fixed-shape batch evaluation): the router this PR replaced
/// already greedily grouped same-task requests, a middle point measured
/// separately as the `serve_task_grouped` bench entry. `serve_swap`
/// remains the bit-identity oracle for the batched path and the shape of
/// truly unbatched serving; compare all three entries in
/// `BENCH_host.json`.
pub fn serve_swap(
    session: &mut Session,
    batcher: &Batcher,
    library: &BTreeMap<String, Vec<f32>>,
    queue: &mut VecDeque<Request>,
    stats: &mut RouterStats,
) -> anyhow::Result<Vec<(Request, Vec<f32>)>> {
    let k = session.layout().param("head/wc")?.shape[1];
    let mut current: Option<String> = None;
    let t_wall = Instant::now();
    let mut results = Vec::new();
    while let Some(r) = queue.pop_front() {
        let spec = task(&r.task)?;
        if current.as_deref() != Some(r.task.as_str()) {
            let state = library
                .get(&r.task)
                .ok_or_else(|| anyhow::anyhow!("no adapter registered for task {:?}", r.task))?;
            let t0 = Instant::now();
            session.upload_state(state)?;
            stats.swap_ms += t0.elapsed().as_secs_f64() * 1e3;
            stats.swaps += 1;
            current = Some(r.task.clone());
        }
        let batch = batcher.assemble(&[&r.example]);
        let t0 = Instant::now();
        let logits = session.forward(&batch, spec.n_classes)?;
        stats.infer_ms += t0.elapsed().as_secs_f64() * 1e3;
        stats.batches += 1;
        stats.requests += 1;
        stats.swap_requests += 1;
        results.push((r, logits[..k].to_vec()));
    }
    stats.wall_s += t_wall.elapsed().as_secs_f64();
    Ok(results)
}

/// Serving-demo knobs (CLI `--requests` / `--max-batch` /
/// `--resident-adapters` / `--adapter-store` / `--no-warm-start`).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Mixed-stream length.
    pub requests: usize,
    /// Rows per mixed batch; 0 = the preset's full batch size (the
    /// artifact shape is fixed, so this is also the upper bound).
    pub max_batch: usize,
    /// [`AdapterBank`] capacity.
    pub resident_adapters: usize,
    /// Durable adapter-store directory for warm starts (trained adapters
    /// are published here and loaded back on restart); `None` disables
    /// the store entirely (`--no-warm-start`).
    pub adapter_store: Option<std::path::PathBuf>,
    /// Fleet supervision: restarts allowed per worker before its tasks
    /// fail over to survivors (`--max-restarts`).
    pub max_restarts: usize,
    /// Fleet supervision: worker heartbeat period in seconds; a worker
    /// silent for 3× this is declared hung and killed
    /// (`--heartbeat-secs`).
    pub heartbeat_secs: u64,
    /// Socket front-end: `host:port` to listen on (`--listen`); `None`
    /// serves the in-memory demo stream. Under `--fleet N`, worker `w`
    /// listens on `port + w`.
    pub listen: Option<String>,
    /// Admission-queue reordering bound (`--reorder-window`): how many
    /// times a queued request may be overtaken by later same-batch pulls
    /// before it becomes a barrier (0 = strict FIFO).
    pub reorder_window: usize,
    /// Admission-queue depth bound (`--max-queue-depth`): requests past
    /// it shed with an explicit `queue_full` 503 reply.
    pub max_queue_depth: usize,
    /// Adapter method to serve (`--method`): `qrlora` (default) or
    /// `lora` — both are tiny states over the same frozen backbone.
    pub method: String,
    /// Write a final [`crate::obs`] metrics snapshot (pretty JSON) here
    /// at exit (`--metrics-json`); `None` skips the write. The fleet
    /// supervisor keeps this to itself — workers would race on one path.
    pub metrics_json: Option<std::path::PathBuf>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            requests: 200,
            max_batch: 0,
            resident_adapters: 8,
            adapter_store: Some(std::path::PathBuf::from(crate::store::DEFAULT_STORE_DIR)),
            max_restarts: 2,
            heartbeat_secs: 5,
            listen: None,
            reorder_window: 8,
            max_queue_depth: 256,
            method: "qrlora".to_string(),
            metrics_json: None,
        }
    }
}

impl ServeConfig {
    /// Read the serve flags over the defaults — the single place the
    /// `util::cli::SERVE_FLAGS` list is interpreted (used by both the CLI
    /// `serve` command and the `adapter_server` example).
    pub fn from_args(args: &Args) -> anyhow::Result<ServeConfig> {
        let d = ServeConfig::default();
        let adapter_store = if args.has("no-warm-start") {
            None
        } else {
            Some(std::path::PathBuf::from(
                args.str_or("adapter-store", crate::store::DEFAULT_STORE_DIR),
            ))
        };
        Ok(ServeConfig {
            requests: args.usize_or("requests", d.requests)?,
            max_batch: args.usize_or("max-batch", d.max_batch)?,
            resident_adapters: args.usize_or("resident-adapters", d.resident_adapters)?,
            adapter_store,
            max_restarts: args.usize_or("max-restarts", d.max_restarts)?,
            heartbeat_secs: args.u64_or("heartbeat-secs", d.heartbeat_secs)?,
            listen: args.get("listen").map(str::to_string),
            reorder_window: args.usize_or("reorder-window", d.reorder_window)?,
            max_queue_depth: args.usize_or("max-queue-depth", d.max_queue_depth)?,
            method: args.str_or("method", &d.method).to_string(),
            metrics_json: args.get("metrics-json").map(std::path::PathBuf::from),
        })
    }
}

/// Per-process serving context: the pipeline (data + warm caches), the
/// QR method over the warmed backbone, the one serving session, and the
/// tiered adapter resolver pinned to that session's fingerprints.
///
/// Built identically by the single-process [`demo`] and every
/// [`fleet`] worker, so "what counts as the same adapter" — key fields,
/// manifest/backbone fingerprints — can never drift between the two
/// paths (a drift would make workers retrain what a sibling published).
pub struct ServeCore {
    pub cfg: ExpConfig,
    pub pipe: Pipeline,
    pub preset: Preset,
    warm_bb: BTreeMap<String, Tensor>,
    method: Method,
    pub session: Session<'static>,
    pub tiers: TieredAdapters,
    backbone_fp: u64,
    layout: StateLayout,
    /// Resolved per-task flat states, ready for [`Router::register`] /
    /// [`serve_swap`].
    pub states: BTreeMap<String, Vec<f32>>,
    n_classes: BTreeMap<String, usize>,
    from_store: usize,
    recorded_train_ms: f64,
    /// Warm-up training steps actually run this process (0 on a full
    /// warm start — what the fleet smoke test asserts after a restart).
    pub steps_this_run: usize,
}

impl ServeCore {
    /// Build the shared serving state with the default `qrlora` method.
    pub fn new(cfg: &ExpConfig, adapter_store: Option<&std::path::Path>) -> anyhow::Result<Self> {
        ServeCore::with_method(cfg, adapter_store, "qrlora")
    }

    /// Build the shared serving state: warmed backbone + adapter method
    /// (identical for every task — only the tiny trainable state and
    /// head differ), the serving session, and the tiered resolver over
    /// `adapter_store` (None disables durability: every resolve trains,
    /// nothing persists). `method_name` picks the adapter family —
    /// `qrlora` or `lora` — and flows into the store key, so records of
    /// the two methods never cross-resolve.
    pub fn with_method(
        cfg: &ExpConfig,
        adapter_store: Option<&std::path::Path>,
        method_name: &str,
    ) -> anyhow::Result<Self> {
        let mut pipe = Pipeline::new(cfg)?;
        let preset = pipe.preset.clone();
        let (warm_bb, _) = pipe.warmed("sst2")?;
        let method = match method_name {
            "qrlora" | "qr-lora" => Methods::qr_lora(
                &warm_bb,
                &preset,
                Scope::last_layers((preset.n_layers / 3).max(1), &[Proj::Q, Proj::V]),
                0.5,
                RankRule::DiagRatio,
            )?,
            "lora" => Methods::lora(&warm_bb, &preset, 2.0, cfg.seed)?,
            other => anyhow::bail!("serve: unknown --method {other:?} (want qrlora or lora)"),
        };
        let session =
            Session::finetune(pipe.rt, &preset, &method, HeadKind::Cls, &warm_bb, None, cfg.seed)?;
        // A store that won't open past the retry budget degrades serving
        // instead of failing it: RAM tier + train-on-miss keep every
        // request answerable, and publishes queue until the store is
        // back ([`TieredAdapters::mark_degraded`]).
        let mut degraded_dir = None;
        let registry = match adapter_store {
            Some(dir) => {
                let opened = store::retry::with_retry(Default::default(), "open adapter store", || {
                    Registry::open(dir)
                });
                match opened {
                    Ok(reg) => {
                        println!(
                            "[serve] adapter store: {} ({} record(s) on disk)",
                            reg.dir().display(),
                            reg.len()
                        );
                        Some(reg)
                    }
                    Err(e) => {
                        crate::warnln!(
                            "[serve] DEGRADED: adapter store {dir:?} unavailable ({e:#}); \
                             serving RAM tier + train-on-miss, publishes queued for retry"
                        );
                        degraded_dir = Some(dir.to_path_buf());
                        None
                    }
                }
            }
            None => {
                println!("[serve] adapter store: disabled (--no-warm-start)");
                None
            }
        };
        // The "backbone" fingerprint covers everything frozen: the warmed
        // backbone tensors AND the method-derived factors/masks, so a
        // record trained under a different τ/scope (same layout, same
        // backbone) is still rejected.
        let backbone_fp = store::fingerprint_extend(
            store::fingerprint_params(&warm_bb),
            &method.frozen_inputs(),
        );
        let mut tiers = TieredAdapters::new(
            registry,
            store::fingerprint_layout(session.layout()),
            backbone_fp,
            session.backend().backbone_repr(),
            &cfg.preset,
            method.artifact_name(),
            cfg.seed,
        );
        if let Some(dir) = &degraded_dir {
            tiers.mark_degraded(dir);
        }
        let layout = session.layout().clone();
        Ok(ServeCore {
            cfg: cfg.clone(),
            pipe,
            preset,
            warm_bb,
            method,
            session,
            tiers,
            backbone_fp,
            layout,
            states: BTreeMap::new(),
            n_classes: BTreeMap::new(),
            from_store: 0,
            recorded_train_ms: 0.0,
            steps_this_run: 0,
        })
    }

    /// Resolve adapters for `tasks` through the tiered store — registry
    /// hits are fingerprint-checked against this session's layout and
    /// backbone; misses train (short budget) and publish back — then
    /// print the warm-start report.
    pub fn prepare(&mut self, tasks: &[&str]) -> anyhow::Result<()> {
        println!("[serve] simd kernels: {}", crate::kernels::active().describe());
        println!("[serve] preparing {} task adapters…", tasks.len());
        let t_prep = Instant::now();
        self.tiers.prefetch(&self.layout, tasks);
        for name in tasks {
            self.resolve_owned(name)?;
        }
        let prep_ms = t_prep.elapsed().as_secs_f64() * 1e3;
        println!(
            "[serve] adapter prep: {}/{} from store, {} trained, \
             warm-up training steps: {}",
            self.from_store,
            tasks.len(),
            self.tiers.stats.trained,
            self.steps_this_run
        );
        if self.from_store == tasks.len() && self.recorded_train_ms > 0.0 {
            println!(
                "[serve]   warm start: {prep_ms:.1} ms (records list {:.0} ms \
                 of training) → {:.0}x faster startup",
                self.recorded_train_ms,
                self.recorded_train_ms / prep_ms.max(1e-3)
            );
        }
        Ok(())
    }

    /// Resolve one task this process is responsible for: RAM → disk →
    /// train-on-miss (wall-clock measured so the published record carries
    /// the cost a warm start saves).
    pub fn resolve_owned(&mut self, name: &str) -> anyhow::Result<()> {
        let (pipe, tiers) = (&mut self.pipe, &mut self.tiers);
        let (preset, method, warm_bb) = (&self.preset, &self.method, &self.warm_bb);
        let (cfg, backbone_fp) = (&self.cfg, self.backbone_fp);
        let steps_this_run = &mut self.steps_this_run;
        let resolved = tiers.resolve(&self.layout, name, |key| {
            let t0 = Instant::now();
            let (_, warm_head) = pipe.warmed(name)?;
            let data = pipe.data(name)?;
            let tc = TrainConfig {
                steps: cfg.steps.min(150),
                lr: cfg.lr_adapter,
                warmup_steps: 5,
                train_examples: 2000,
                log_every: 1000,
            };
            let mut s = Session::finetune(
                pipe.rt, preset, method, data.spec.head, warm_bb, Some(&warm_head), cfg.seed,
            )?;
            let batcher = Batcher::new(preset, false);
            let mut rng = Rng::new(cfg.seed ^ 0xD0);
            let mut step = 0;
            'outer: loop {
                for chunk in batcher
                    .epoch(&data.train[..tc.train_examples.min(data.train.len())], &mut rng)
                {
                    if step >= tc.steps {
                        break 'outer;
                    }
                    let b = batcher.assemble(&chunk);
                    s.step(&b, data.spec.n_classes, tc.lr_at(step))?;
                    step += 1;
                }
            }
            *steps_this_run += step;
            let metric = s
                .evaluate(&batcher, &data, Split::Dev)?
                .result
                .headline(metric_kind(name));
            let train_ms = t0.elapsed().as_secs_f64() * 1e3;
            println!(
                "[serve]   {name}: adapter trained ({} trainable params, \
                 dev metric {metric:.1}, {train_ms:.0} ms)",
                s.trainable_params()
            );
            AdapterRecord::from_session(
                &s,
                key.clone(),
                backbone_fp,
                data.spec.n_classes,
                metric,
                train_ms,
                false,
            )
        })?;
        if resolved.source == Source::Disk {
            self.from_store += 1;
            self.recorded_train_ms += resolved.train_ms;
            println!(
                "[serve]   {name}: adapter loaded from store (dev metric {:.1} on record)",
                resolved.eval_metric
            );
        }
        self.states.insert(name.to_string(), resolved.state.clone());
        self.n_classes.insert(name.to_string(), resolved.n_classes);
        Ok(())
    }

    /// Hot-load adapters a sibling process owns: poll the store's index
    /// generation ([`TieredAdapters::refresh`]) and resolve each task
    /// through the disk tier as its record appears — never training.
    /// Errors when `timeout` passes with tasks still missing.
    pub fn adopt_published(&mut self, tasks: &[&str], timeout: Duration) -> anyhow::Result<()> {
        let poll = Duration::from_millis(100);
        let deadline = Instant::now() + timeout;
        let mut missing: Vec<&str> =
            tasks.iter().copied().filter(|t| !self.states.contains_key(*t)).collect();
        loop {
            let mut still = Vec::new();
            for t in missing {
                match self.tiers.resolve_disk_only(&self.layout, t) {
                    Some(r) => {
                        let (state, n) = (r.state.clone(), r.n_classes);
                        println!(
                            "[serve]   {t}: adapter hot-loaded from sibling publish \
                             (dev metric {:.1} on record)",
                            r.eval_metric
                        );
                        self.states.insert(t.to_string(), state);
                        self.n_classes.insert(t.to_string(), n);
                    }
                    None => still.push(t),
                }
            }
            missing = still;
            if missing.is_empty() {
                return Ok(());
            }
            anyhow::ensure!(
                Instant::now() < deadline,
                "timed out after {timeout:?} waiting for sibling-published adapters: \
                 {missing:?}"
            );
            std::thread::sleep(poll);
            self.tiers.refresh()?;
        }
    }

    /// A deterministic mixed request stream over `tasks`.
    pub fn build_queue(
        &mut self,
        tasks: &[&str],
        requests: usize,
        seed: u64,
    ) -> anyhow::Result<VecDeque<Request>> {
        let mut rng = Rng::new(seed);
        let mut queue: VecDeque<Request> = VecDeque::new();
        for id in 0..requests {
            let tname = *rng.choice(tasks);
            let data = self.pipe.data(tname)?;
            let ex = data.split(Split::Dev)[rng.below(data.dev.len())].clone();
            queue.push_back(Request { id, task: tname.to_string(), example: ex });
        }
        Ok(queue)
    }

    /// Serve a queue through the batched [`Router`] with every resolved
    /// adapter registered. Returns the results and the router's stats.
    pub fn serve_batched(
        &self,
        sc: &ServeConfig,
        queue: &VecDeque<Request>,
    ) -> anyhow::Result<(Vec<(Request, Vec<f32>)>, RouterStats)> {
        let batcher = Batcher::new(&self.preset, false);
        let mut router = Router::new(&self.session, batcher, sc.max_batch, sc.resident_adapters)?;
        for (name, state) in &self.states {
            let n = *self.n_classes.get(name).ok_or_else(|| {
                anyhow::anyhow!("resolved state for {name:?} has no recorded class count")
            })?;
            router.register(name, state.clone(), n)?;
        }
        let mut q = queue.clone();
        let results = router.serve(&mut q)?;
        Ok((results, router.stats))
    }

    /// Last-chance publish-back before the process exits: reopen the
    /// store if degraded, retry every queued publish, and warn about
    /// anything still stuck (those adapters simply retrain next boot —
    /// degraded mode costs duplicate training, never lost serving).
    pub fn flush_publishes(&mut self) {
        if self.tiers.pending_publishes() == 0 {
            return;
        }
        // refresh() reopens + flushes when degraded; flush_pending()
        // covers the registry-was-live-but-publish-flaked case.
        let _ = self.tiers.refresh();
        self.tiers.flush_pending();
        let left = self.tiers.pending_publishes();
        if left > 0 {
            crate::warnln!(
                "[serve] {left} adapter publish(es) still queued at shutdown (store \
                 unavailable); those adapters will retrain on the next boot"
            );
        }
    }
}

/// The serving demo: resolves one QR adapter per task through the tiered
/// store (RAM → durable registry → train-on-miss, publishing back),
/// routes a mixed request stream through the batched [`Router`], then
/// replays the same stream through the legacy [`serve_swap`] loop and
/// reports the warm-start and batching speedups plus per-request
/// agreement.
pub fn demo(cfg: &ExpConfig, sc: &ServeConfig) -> anyhow::Result<()> {
    let tasks = SERVE_TASKS;

    // 1+2. Shared serving state + tiered adapter resolution (see
    //      `ServeCore`; the fleet workers build the identical context).
    let mut core = ServeCore::with_method(cfg, sc.adapter_store.as_deref(), &sc.method)?;
    core.prepare(tasks)?;

    // 3. Build a mixed request stream.
    let queue = core.build_queue(tasks, sc.requests, cfg.seed ^ 0x5EED)?;
    let preset = core.preset.clone();
    let batcher = Batcher::new(&preset, false);

    // 4. Batched path: resident bank, mixed batches, no per-request swaps.
    let (batched_results, batched_stats) = core.serve_batched(sc, &queue)?;

    // 5. Swap baseline on the identical stream.
    let mut swap_stats = RouterStats::default();
    let mut q = queue.clone();
    let swap_results =
        serve_swap(&mut core.session, &batcher, &core.states, &mut q, &mut swap_stats)?;
    let session = &core.session;

    // 6. Per-request agreement + accuracy.
    let k = session.layout().param("head/wc")?.shape[1];
    let mut by_id: BTreeMap<usize, &Vec<f32>> = BTreeMap::new();
    for (r, l) in &swap_results {
        by_id.insert(r.id, l);
    }
    let mut identical = true;
    let mut correct = 0usize;
    let mut total = 0usize;
    for (r, logits) in &batched_results {
        if let Some(want) = by_id.get(&r.id) {
            identical &= logits
                .iter()
                .zip(want.iter())
                .all(|(a, b)| a.to_bits() == b.to_bits());
        }
        if let crate::data::Label::Class(c) = r.example.label {
            total += 1;
            if argmax(&logits[..k]) == c {
                correct += 1;
            }
        }
    }

    let eff_batch = if sc.max_batch == 0 {
        preset.batch
    } else {
        sc.max_batch.clamp(1, preset.batch)
    };
    println!("\n[serve] batched router (bank capacity {})", sc.resident_adapters);
    println!(
        "  requests:        {} ({} batched)",
        batched_stats.requests, batched_stats.batched_requests
    );
    println!("  batches:         {} (≤{eff_batch} rows each)", batched_stats.batches);
    println!("  bank admissions: {}", batched_stats.swap_summary());
    println!("  evictions:       {}", batched_stats.evictions);
    println!(
        "  batch latency:   {:.1} ms avg",
        batched_stats.infer_ms / batched_stats.batches.max(1) as f64
    );
    println!("  throughput:      {:.1} req/s", batched_stats.throughput());
    println!("\n[serve] swap-per-request baseline");
    println!("  adapter swaps:   {}", swap_stats.swap_summary());
    println!("  throughput:      {:.1} req/s", swap_stats.throughput());
    let speedup = if swap_stats.throughput() > 0.0 {
        batched_stats.throughput() / swap_stats.throughput()
    } else {
        0.0
    };
    println!("\n[serve] batched vs swap: {speedup:.1}x throughput");
    println!("  bit-identical per request: {}", if identical { "yes" } else { "NO" });
    println!("  online accuracy: {:.1}%", 100.0 * correct as f64 / total.max(1) as f64);
    println!(
        "  adapter residency: {} tasks × {:.1} KiB state  vs  {:.1} MiB per full model copy",
        tasks.len(),
        (session.layout().total * 4) as f64 / 1024.0,
        (crate::runtime::Preset::approx_backbone_params(&preset) * 4) as f64 / (1024.0 * 1024.0),
    );
    // Backbone residency: with --quantize-backbone the shared frozen
    // weights are held int8 (per-row-group scales), so the one backbone
    // every resident adapter shares shrinks ~4x.
    if let Some(r) = session.backend().frozen_residency() {
        // Only meaningful when quantization actually shrank something; a
        // plain f32 run would print a misleading "1.00x reduction".
        if r.backbone_resident_bytes < r.backbone_f32_bytes {
            println!(
                "  frozen backbone weights: {:.2} MiB resident ({:.2} MiB f32, {:.2}x reduction)",
                r.backbone_resident_bytes as f64 / (1024.0 * 1024.0),
                r.backbone_f32_bytes as f64 / (1024.0 * 1024.0),
                r.reduction(),
            );
        }
    }
    core.flush_publishes();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostBackend;

    #[test]
    fn swap_summary_prints_na_without_swaps() {
        let stats = RouterStats::default();
        assert_eq!(stats.swap_avg_ms(), None);
        let s = stats.swap_summary();
        assert!(s.contains("n/a"), "{s}");
        assert!(!s.contains("0.00 ms avg"), "{s}");
    }

    #[test]
    fn swap_summary_prints_average_with_swaps() {
        let stats = RouterStats { swaps: 4, swap_ms: 10.0, ..RouterStats::default() };
        assert_eq!(stats.swap_avg_ms(), Some(2.5));
        let s = stats.swap_summary();
        assert!(s.contains("4 (2.50 ms avg)"), "{s}");
    }

    #[test]
    fn bank_admits_touches_and_evicts_lru() {
        let bk = HostBackend::new();
        let mut bank = AdapterBank::new(2);
        let mask = [1.0f32, 1.0];
        let a = bank.admit(&bk, "a", &[1.0], &mask, &[]).unwrap();
        assert!(a.uploaded && !a.evicted);
        let b = bank.admit(&bk, "b", &[2.0], &mask, &[]).unwrap();
        assert_eq!((a.slot, b.slot), (0, 1));
        assert_eq!(bank.len(), 2);
        // touch "a" so "b" becomes LRU
        let a2 = bank.admit(&bk, "a", &[1.0], &mask, &[]).unwrap();
        assert!(!a2.uploaded);
        let c = bank.admit(&bk, "c", &[3.0], &mask, &[]).unwrap();
        assert!(c.uploaded && c.evicted);
        assert_eq!(c.slot, 1, "LRU slot (b) recycled");
        assert_eq!(bank.slot_of("b"), None);
        assert_eq!(bank.slot_of("a"), Some(0));
        assert_eq!(bank.slot_of("c"), Some(1));
    }

    #[test]
    fn bank_eviction_respects_pins() {
        let bk = HostBackend::new();
        let mut bank = AdapterBank::new(2);
        let mask = [1.0f32];
        bank.admit(&bk, "a", &[1.0], &mask, &[]).unwrap();
        bank.admit(&bk, "b", &[2.0], &mask, &[]).unwrap();
        // slot 0 ("a") is LRU but pinned: "c" must evict slot 1 instead.
        let c = bank.admit(&bk, "c", &[3.0], &mask, &[0]).unwrap();
        assert_eq!(c.slot, 1);
        assert_eq!(bank.slot_of("a"), Some(0));
        // with every slot pinned, admission must refuse
        assert!(bank.admit(&bk, "d", &[4.0], &mask, &[0, 1]).is_err());
    }
}
