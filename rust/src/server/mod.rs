//! Multi-adapter serving router.
//!
//! QR-LoRA's headline property — hundreds of trainable parameters per task —
//! makes per-task adapters essentially free to keep resident and to swap:
//! the backbone is shared (frozen device buffers) and each task contributes
//! only its λ/head state vector. This module demonstrates that with a
//! batching router: requests tagged with a task are queued, grouped into
//! per-task batches, and served by hot-swapping the task's state vector
//! onto a single shared eval executable.

use std::collections::{BTreeMap, VecDeque};
use std::time::Instant;

use crate::adapters::{Proj, Scope};
use crate::data::{task, Batcher, Example, Split};
use crate::experiments::{ExpConfig, Pipeline};
use crate::linalg::RankRule;
use crate::metrics::argmax;
use crate::training::{FinetuneJob, Methods, Session, TrainConfig};
use crate::util::log::Stats;
use crate::util::rng::Rng;

/// One inference request.
pub struct Request {
    pub id: usize,
    pub task: String,
    pub example: Example,
}

/// Router statistics.
#[derive(Debug, Default)]
pub struct RouterStats {
    pub requests: usize,
    pub batches: usize,
    pub swaps: usize,
    pub swap_ms: f64,
    pub infer_ms: f64,
    pub wall_s: f64,
}

/// The serving demo: trains tiny QR adapters for several tasks, then routes
/// a mixed request stream through a single shared backbone.
pub fn demo(cfg: &ExpConfig, n_requests: usize) -> anyhow::Result<()> {
    let tasks = ["sst2", "mrpc", "qnli"];
    let mut pipe = Pipeline::new(cfg)?;
    let preset = pipe.preset.clone();

    // 1. Train one QR-LoRA adapter per task (short budget — demo).
    println!("[serve] preparing {} task adapters…", tasks.len());
    let mut states: BTreeMap<String, Vec<f32>> = BTreeMap::new();
    let mut session: Option<Session> = None;
    let (warm_bb, _) = pipe.warmed(tasks[0])?;
    for name in tasks {
        let (_, warm_head) = pipe.warmed(name)?;
        let method = Methods::qr_lora(
            &warm_bb,
            &preset,
            Scope::last_layers((preset.n_layers / 3).max(1), &[Proj::Q, Proj::V]),
            0.5,
            RankRule::DiagRatio,
        )?;
        let data = pipe.data(name)?;
        let tc = TrainConfig {
            steps: cfg.steps.min(150),
            lr: cfg.lr_adapter,
            warmup_steps: 5,
            train_examples: 2000,
            log_every: 1000,
        };
        let job = FinetuneJob {
            rt: pipe.rt,
            preset: &cfg.preset,
            task: &data,
            lexicon: &pipe.lexicon,
            backbone: &warm_bb,
            head: Some(&warm_head),
            config: tc.clone(),
            seed: cfg.seed,
        };
        // Train via a session we keep (last one becomes the serving session).
        let mut s = Session::finetune(
            pipe.rt, &preset, &method, data.spec.head, &warm_bb, Some(&warm_head), cfg.seed,
        )?;
        let batcher = Batcher::new(&preset, false);
        let mut rng = Rng::new(cfg.seed ^ 0xD0);
        let mut step = 0;
        'outer: loop {
            for chunk in batcher.epoch(&data.train[..tc.train_examples.min(data.train.len())], &mut rng) {
                if step >= tc.steps {
                    break 'outer;
                }
                let b = batcher.assemble(&chunk);
                s.step(&b, data.spec.n_classes, tc.lr_at(step))?;
                step += 1;
            }
        }
        let _ = &job;
        states.insert(name.to_string(), s.download_state()?);
        println!(
            "[serve]   {name}: adapter ready ({} trainable params, state {:.1} KiB)",
            s.trainable_params(),
            (s.layout().total * 4) as f64 / 1024.0
        );
        session = Some(s);
    }
    let mut session = session.unwrap();

    // 2. Build a mixed request stream.
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let mut queue: VecDeque<Request> = VecDeque::new();
    for id in 0..n_requests {
        let tname = *rng.choice(&tasks);
        let data = pipe.data(tname)?;
        let ex = data.split(Split::Dev)[rng.below(data.dev.len())].clone();
        queue.push_back(Request { id, task: tname.to_string(), example: ex });
    }

    // 3. Route: greedily batch consecutive same-task requests (the batcher
    //    policy a real deployment would tune), swap adapters only on task
    //    change.
    let batcher = Batcher::new(&preset, false);
    let mut stats = RouterStats::default();
    let mut lat = Stats::new();
    let mut current_task: Option<String> = None;
    let t_wall = Instant::now();
    let mut correct = 0usize;
    let mut total = 0usize;

    while !queue.is_empty() {
        // Pick the task of the oldest request; drain up to batch size of it.
        let tname = queue.front().unwrap().task.clone();
        let mut batch_reqs: Vec<Request> = Vec::new();
        let mut rest: VecDeque<Request> = VecDeque::new();
        while let Some(r) = queue.pop_front() {
            if r.task == tname && batch_reqs.len() < preset.batch {
                batch_reqs.push(r);
            } else {
                rest.push_back(r);
            }
        }
        queue = rest;

        if current_task.as_deref() != Some(tname.as_str()) {
            let t0 = Instant::now();
            session.upload_state(&states[&tname])?;
            stats.swap_ms += t0.elapsed().as_secs_f64() * 1e3;
            stats.swaps += 1;
            current_task = Some(tname.clone());
        }

        let spec = task(&tname)?;
        let refs: Vec<&Example> = batch_reqs.iter().map(|r| &r.example).collect();
        let b = batcher.assemble(&refs);
        let t0 = Instant::now();
        let logits = session.forward(&b, spec.n_classes)?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        stats.infer_ms += ms;
        lat.push(ms);
        stats.batches += 1;
        stats.requests += batch_reqs.len();

        let k = preset.n_classes;
        for (i, r) in batch_reqs.iter().enumerate() {
            if let crate::data::Label::Class(c) = r.example.label {
                total += 1;
                if argmax(&logits[i * k..(i + 1) * k]) == c {
                    correct += 1;
                }
            }
        }
    }
    stats.wall_s = t_wall.elapsed().as_secs_f64();

    println!("\n[serve] router results");
    println!("  requests:        {}", stats.requests);
    println!("  batches:         {}", stats.batches);
    println!("  adapter swaps:   {} ({:.2} ms avg)", stats.swaps, stats.swap_ms / stats.swaps.max(1) as f64);
    println!("  batch latency:   {:.1} ms avg (p_min {:.1} / p_max {:.1})", lat.mean(), lat.min, lat.max);
    println!("  throughput:      {:.1} req/s", stats.requests as f64 / stats.wall_s);
    println!("  online accuracy: {:.1}%", 100.0 * correct as f64 / total.max(1) as f64);
    println!(
        "  adapter residency: {} tasks × {:.1} KiB state  vs  {:.1} MiB per full model copy",
        tasks.len(),
        (session.layout().total * 4) as f64 / 1024.0,
        (crate::runtime::Preset::approx_backbone_params(&preset) * 4) as f64 / (1024.0 * 1024.0),
    );
    Ok(())
}
