//! Multi-process serving fleet over one shared adapter store, with
//! supervision: crashed/hung workers restart under a bounded budget, and
//! a worker that exhausts it has its tasks failed over to survivors.
//!
//! `serve --fleet N` is the single-box dress rehearsal for horizontal
//! scale: N worker *processes* (re-execs of the current binary) share one
//! `runs/adapters/` store, with the task set partitioned across workers
//! by a consistent-hash ring. Lifecycle:
//!
//! ```text
//!            supervisor (serve --fleet N)
//!   pre-warm runs/ caches → partition tasks on the HashRing
//!        │ spawn               │ spawn                │ spawn
//!        ▼                     ▼                      ▼
//!   worker 0              worker 1     …         worker N−1
//!   train+publish owned   train+publish owned    train+publish owned
//!        │   └──────── index.lock serializes ───────┘  │
//!        ▼                                             ▼
//!   store-watch: poll index generation, hot-load sibling publishes
//!        ▼                                             ▼
//!   serve a mixed stream over ALL tasks through the batched Router
//!        └── FLEET_WORKER / FLEET_HEARTBEAT lines ─────┘
//!                            ▼
//!     supervisor poll loop: try_wait + heartbeat liveness
//!       crash/hang → kill + restart (backoff, ≤ --max-restarts)
//!       budget exhausted → supervisor trains + publishes the
//!       orphaned tasks so blocked survivors' adoption completes
//!                            ▼
//!        supervisor aggregates → FLEET_AGGREGATE {json}
//! ```
//!
//! Every worker ends up serving every task — ownership only decides who
//! *trains* an adapter; the store's locked `publish_merged` guarantees
//! all concurrent publishes land, and the index `generation` counter
//! gives workers a cheap poll to notice them. That same generation-watch
//! path is the failover mechanism: when an owner dies for good, the
//! supervisor trains-and-publishes its tasks itself, and the survivors
//! blocked in [`ServeCore::adopt_published`] pick them up exactly as if
//! the dead worker had published them. A *restarted* worker reclaims its
//! tasks the cheap way — its first-incarnation publishes (and any
//! supervisor failover publishes) warm-start it from the store.
//!
//! **Liveness**: workers emit a `FLEET_HEARTBEAT` line every
//! `--heartbeat-secs` from a detached thread (training is legitimately
//! stdout-silent for long stretches). The supervisor's relay thread
//! timestamps every line; a worker silent past 3× the heartbeat period
//! is declared hung, killed, and goes through the same restart budget as
//! a crash. Socket workers get a second liveness channel: once one is
//! stdout-quiet past a heartbeat period the supervisor probes its HTTP
//! `GET /healthz`, and an answering worker counts as seen. Supervision
//! is crash-safe against torn state because every
//! write a worker can die inside — adapter records, the store index,
//! `runs/` checkpoints — is temp-then-rename atomic with stale-debris
//! sweeps on open.
//!
//! The supervisor still pre-warms the pipeline's backbone/warm-up caches
//! before spawning, but since `model::checkpoint` went atomic this is an
//! optimization (N workers would redundantly compute the same caches,
//! and on the host backend that is the dominant startup cost), not a
//! correctness requirement.
//!
//! The [`HashRing`] is deliberately a reusable stub for real horizontal
//! scale: adding a worker only moves the keys the new worker now owns
//! (`ring_rebalance_moves_keys_only_to_the_new_worker` pins that down).

use std::io::{BufRead, Read, Write};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::{ServeConfig, ServeCore, SERVE_TASKS};
use crate::experiments::{ExpConfig, Pipeline};
use crate::obs::{self, hist};
use crate::util::faults;
use crate::util::hash::fnv1a_str;
use crate::util::json::Json;
use crate::util::pool;

/// Virtual nodes per worker on the ring. Enough to spread a small task
/// set evenly; cheap enough that ring construction stays trivial.
pub const VNODES_PER_WORKER: usize = 64;

/// How long a worker store-watches for sibling-published adapters before
/// giving up (covers the siblings' worst-case training time *plus* a
/// sibling crash → restart/failover round trip).
const ADOPT_TIMEOUT: Duration = Duration::from_secs(300);

/// Supervisor poll period: how often `try_wait`/heartbeat liveness runs.
const SUPERVISE_POLL: Duration = Duration::from_millis(50);

/// Backoff before restart attempt 1; doubles per attempt, capped at
/// [`RESTART_BACKOFF_MAX`].
const RESTART_BACKOFF_BASE: Duration = Duration::from_millis(200);
const RESTART_BACKOFF_MAX: Duration = Duration::from_secs(2);

/// A consistent-hash ring over worker ids: each worker contributes
/// [`VNODES_PER_WORKER`] points (FNV-1a of `"w{worker}/v{vnode}"`), and a
/// task routes to the first point clockwise of its own hash. Existing
/// workers' points never move when a worker joins, so growing the fleet
/// only reassigns the keys the new worker takes over.
pub struct HashRing {
    /// Sorted `(point, worker)` pairs.
    ring: Vec<(u64, usize)>,
    workers: usize,
}

impl HashRing {
    pub fn new(workers: usize) -> HashRing {
        let workers = workers.max(1);
        let mut ring = Vec::with_capacity(workers * VNODES_PER_WORKER);
        for w in 0..workers {
            for v in 0..VNODES_PER_WORKER {
                ring.push((fnv1a_str(&format!("w{w}/v{v}")), w));
            }
        }
        // Ties (astronomically unlikely under FNV-1a over distinct
        // labels) resolve to the lower worker id via the pair ordering.
        ring.sort_unstable();
        HashRing { ring, workers }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The worker owning `task`: successor lookup with wraparound.
    pub fn route(&self, task: &str) -> usize {
        let h = fnv1a_str(task);
        let i = self.ring.partition_point(|(p, _)| *p < h);
        self.ring[i % self.ring.len()].1
    }

    /// Partition `tasks` into per-worker owned sets (a worker may own
    /// none — it then serves purely from sibling publishes).
    pub fn partition(&self, tasks: &[&str]) -> Vec<Vec<String>> {
        let mut owned = vec![Vec::new(); self.workers];
        for t in tasks {
            owned[self.route(t)].push(t.to_string());
        }
        owned
    }
}

/// Worker `w`'s listen address under `serve --fleet N --listen host:port`:
/// the supervisor hands out consecutive ports, `host:(port + w)`.
fn worker_listen_addr(base: &str, w: usize) -> anyhow::Result<String> {
    let (host, port) = base
        .rsplit_once(':')
        .ok_or_else(|| anyhow::anyhow!("--listen expects host:port, got {base:?}"))?;
    let port: u16 = port
        .parse()
        .map_err(|_| anyhow::anyhow!("--listen expects a numeric port, got {base:?}"))?;
    let port = port as usize + w;
    anyhow::ensure!(port <= u16::MAX as usize, "--listen {base:?} + worker {w} overflows the port");
    Ok(format!("{host}:{port}"))
}

/// One worker's parsed `FLEET_WORKER` report.
struct WorkerReport {
    worker: usize,
    requests: usize,
    serve_wall_ms: f64,
    rps: f64,
    warmup_steps: usize,
    /// 503-style sheds (socket front-end only; in-process workers report 0).
    shed: usize,
    /// 4xx-style protocol rejections (socket front-end only).
    rejected: usize,
    /// The worker's [`crate::obs`] registry snapshot (counters, gauges,
    /// hists), carried verbatim for fleet-wide merging. Absent from
    /// older binaries' reports; the aggregator treats that as "nothing
    /// to merge", never an error.
    metrics: Option<Json>,
}

impl WorkerReport {
    fn parse(worker: usize, json: &str) -> anyhow::Result<WorkerReport> {
        let doc = Json::parse(json)?;
        let num = |k: &str| -> anyhow::Result<f64> {
            doc.req(k)?.as_f64().ok_or_else(|| anyhow::anyhow!("FLEET_WORKER: bad {k}"))
        };
        // Tolerant on purpose: absence means zero, never a parse failure,
        // so a report from an older worker binary still aggregates.
        let count = |k: &str| doc.get(k).and_then(Json::as_usize).unwrap_or(0);
        Ok(WorkerReport {
            worker,
            requests: num("requests")? as usize,
            serve_wall_ms: num("serve_wall_ms")?,
            rps: num("rps")?,
            warmup_steps: num("warmup_steps")? as usize,
            shed: count("shed"),
            rejected: count("rejected"),
            metrics: doc.get("metrics").cloned(),
        })
    }
}

/// A spawned worker process plus its relay plumbing.
struct LiveWorker {
    child: Child,
    relay: JoinHandle<()>,
    /// Timestamp of the last line the worker wrote (any line — reports,
    /// log output, `FLEET_HEARTBEAT`). The supervisor's hang detector
    /// compares it against 3× the heartbeat period.
    last_seen: Arc<Mutex<Instant>>,
    /// Socket fleet only: the worker's listen address, probed over HTTP
    /// `/healthz` as a second liveness channel. `None` for in-process
    /// workers (stdout heartbeats are their only channel).
    addr: Option<String>,
    /// When the supervisor last probed `/healthz` (rate limit: at most
    /// once per heartbeat period, and only once the worker is quiet).
    last_probe: Instant,
}

/// Where one worker slot is in its lifecycle.
enum SlotState {
    Running(LiveWorker),
    /// Crashed/hung; respawn once `until` passes.
    Backoff { until: Instant, generation: u64 },
    /// Exited cleanly.
    Done,
    /// Restart budget exhausted; tasks failed over.
    Failed,
}

/// One worker id's supervision record.
struct WorkerSlot {
    id: usize,
    state: SlotState,
    restarts: usize,
}

/// Everything needed to (re)spawn worker `w` with identical flags.
struct WorkerSpawner<'a> {
    exe: std::path::PathBuf,
    cfg: &'a ExpConfig,
    sc: &'a ServeConfig,
    owned: &'a [Vec<String>],
    requests_for: Vec<usize>,
    threads_per: usize,
    tx: Sender<(usize, String)>,
}

impl WorkerSpawner<'_> {
    /// Spawn worker `w` (restart `generation`; 0 = first incarnation).
    /// The generation is exported so one-shot injected faults don't
    /// re-fire forever across restarts (see [`crate::util::faults`]).
    fn spawn(&self, w: usize, generation: u64) -> anyhow::Result<LiveWorker> {
        let cfg = self.cfg;
        let mut cmd = Command::new(&self.exe);
        cmd.arg("serve")
            .args(["--worker-id", &w.to_string()])
            .args(["--fleet-tasks", &self.owned[w].join(",")])
            .args(["--preset", &cfg.preset])
            .args(["--pretrain-steps", &cfg.pretrain_steps.to_string()])
            .args(["--warmup-steps", &cfg.warmup_steps.to_string()])
            .args(["--steps", &cfg.steps.to_string()])
            .args(["--train-examples", &cfg.train_examples.to_string()])
            .args(["--seed", &cfg.seed.to_string()])
            .args(["--lr-ft", &cfg.lr_ft.to_string()])
            .args(["--lr", &cfg.lr_adapter.to_string()])
            .args(["--requests", &self.requests_for[w].to_string()])
            .args(["--max-batch", &self.sc.max_batch.to_string()])
            .args(["--resident-adapters", &self.sc.resident_adapters.to_string()])
            .args(["--heartbeat-secs", &self.sc.heartbeat_secs.to_string()])
            .args(["--method", &self.sc.method])
            // Split the host pool across workers instead of oversubscribing
            // the box N-fold.
            .env("QRLORA_THREADS", self.threads_per.to_string())
            .env(faults::ENV_WORKER, w.to_string())
            .env(faults::ENV_RESTART, generation.to_string())
            .stdout(Stdio::piped());
        match &self.sc.adapter_store {
            Some(dir) => {
                cmd.args(["--adapter-store", &dir.display().to_string()]);
            }
            None => {
                cmd.arg("--no-warm-start");
            }
        }
        // Socket fleet: the supervisor hands out consecutive ports so a
        // load generator can enumerate them (`soak --connect`).
        let mut addr = None;
        if let Some(base) = &self.sc.listen {
            let worker_addr = worker_listen_addr(base, w)?;
            cmd.args(["--listen", &worker_addr])
                .args(["--reorder-window", &self.sc.reorder_window.to_string()])
                .args(["--max-queue-depth", &self.sc.max_queue_depth.to_string()]);
            addr = Some(worker_addr);
        }
        let mut child = cmd
            .spawn()
            .map_err(|e| anyhow::anyhow!("cannot spawn fleet worker {w}: {e}"))?;
        let stdout = child
            .stdout
            .take()
            .ok_or_else(|| anyhow::anyhow!("fleet worker {w}: stdout was not piped"))?;
        let last_seen = Arc::new(Mutex::new(Instant::now()));
        let seen = Arc::clone(&last_seen);
        let tx = self.tx.clone();
        let relay = std::thread::spawn(move || {
            for line in std::io::BufReader::new(stdout).lines() {
                let Ok(line) = line else { break };
                if let Ok(mut t) = seen.lock() {
                    *t = Instant::now();
                }
                if line == "FLEET_HEARTBEAT" {
                    continue; // liveness only; not worth echoing
                }
                if let Some(json) = line.strip_prefix("FLEET_WORKER ") {
                    let _ = tx.send((w, json.to_string()));
                }
                println!("[w{w}] {line}");
            }
        });
        Ok(LiveWorker { child, relay, last_seen, addr, last_probe: Instant::now() })
    }
}

/// Best-effort HTTP liveness probe of a worker's `GET /healthz`. Short
/// timeouts throughout — the supervisor's poll loop must never stall on
/// a wedged socket — and any failure just reads as "not alive via HTTP"
/// (the stdout heartbeat remains the primary channel).
fn probe_healthz(addr: &str) -> bool {
    let Ok(sock) = addr.parse::<std::net::SocketAddr>() else {
        return false;
    };
    let timeout = Duration::from_millis(200);
    let Ok(mut stream) = TcpStream::connect_timeout(&sock, timeout) else {
        return false;
    };
    let _ = stream.set_read_timeout(Some(timeout));
    let _ = stream.set_write_timeout(Some(timeout));
    if stream.write_all(b"GET /healthz HTTP/1.1\r\nHost: fleet\r\n\r\n").is_err() {
        return false;
    }
    let mut buf = [0u8; 512];
    match stream.read(&mut buf) {
        Ok(n) if n > 0 => String::from_utf8_lossy(&buf[..n]).contains("200 OK"),
        _ => false,
    }
}

/// Supervisor: pre-warm the shared `runs/` caches, partition
/// [`SERVE_TASKS`] over the ring, spawn `workers` re-execs of the current
/// binary, then run the supervision loop — relay worker output
/// `[w{i}]`-prefixed, restart crashed/hung workers (exponential backoff,
/// at most `--max-restarts` each), fail a worker's tasks over to the
/// survivors once its budget is gone — and aggregate the surviving
/// reports into a `FLEET_AGGREGATE` line (what the `serve_fleet` bench
/// and the CI fleet smoke parse).
pub fn run_fleet(cfg: &ExpConfig, sc: &ServeConfig, workers: usize) -> anyhow::Result<()> {
    let workers = workers.max(1);
    let tasks = SERVE_TASKS;

    // Materialize the shared backbone/warm-up caches once so workers only
    // ever read them. Startup-cost optimization (checkpoint writes are
    // atomic, so racing workers would be correct, just N× slower), and it
    // keeps the workers' first heartbeat from racing a cold cache build.
    println!(
        "[fleet] pre-warming shared caches (backbone + {} task warm-up(s))…",
        tasks.len()
    );
    {
        let mut pipe = Pipeline::new(cfg)?;
        for t in tasks {
            pipe.warmed(t)?;
        }
    }

    let ring = HashRing::new(workers);
    let owned = ring.partition(tasks);
    for (w, ts) in owned.iter().enumerate() {
        println!("[fleet] worker {w} owns {ts:?}");
    }

    let exe = std::env::current_exe()
        .map_err(|e| anyhow::anyhow!("cannot locate the current binary: {e}"))?;
    let threads_per = (pool::threads() / workers).max(1);
    let base = sc.requests / workers;
    let extra = sc.requests % workers;
    let requests_for: Vec<usize> =
        (0..workers).map(|w| base + usize::from(w < extra)).collect();

    let (tx, rx) = std::sync::mpsc::channel::<(usize, String)>();
    let spawner = WorkerSpawner { exe, cfg, sc, owned: &owned, requests_for, threads_per, tx };

    let mut slots: Vec<WorkerSlot> = Vec::with_capacity(workers);
    for w in 0..workers {
        let live = spawner.spawn(w, 0)?;
        slots.push(WorkerSlot { id: w, state: SlotState::Running(live), restarts: 0 });
    }

    let hang_deadline = Duration::from_secs(sc.heartbeat_secs.max(1)) * 3;
    supervise(&mut slots, &spawner, cfg, sc, &owned, hang_deadline)?;

    // All relay threads joined inside supervise(); dropping the spawner
    // drops the last sender so the report drain below terminates.
    let failed: Vec<usize> =
        slots.iter().filter(|s| matches!(s.state, SlotState::Failed)).map(|s| s.id).collect();
    drop(spawner);

    // Dedup by worker id, last report wins — a worker that got restarted
    // after somehow reporting must not be double-counted.
    let mut by_worker: std::collections::BTreeMap<usize, WorkerReport> =
        std::collections::BTreeMap::new();
    for (w, json) in rx.iter() {
        match WorkerReport::parse(w, &json) {
            Ok(r) => {
                by_worker.insert(w, r);
            }
            // A malformed report degrades that worker to "no report",
            // it doesn't abort the fleet.
            Err(e) => crate::warnln!("[fleet] ignoring malformed report from worker {w}: {e:#}"),
        }
    }
    let reports: Vec<WorkerReport> = by_worker.into_values().collect();
    anyhow::ensure!(
        !reports.is_empty(),
        "no fleet worker completed serving ({} of {workers} failed permanently)",
        failed.len()
    );
    if !failed.is_empty() {
        crate::warnln!(
            "[fleet] {} of {workers} worker(s) failed permanently ({failed:?}); \
             aggregating over the {} survivor(s)",
            failed.len(),
            reports.len()
        );
    }

    for r in &reports {
        println!(
            "[fleet] worker {}: {} requests, {:.1} req/s, {} shed, {} rejected, \
             warm-up training steps: {}",
            r.worker, r.requests, r.rps, r.shed, r.rejected, r.warmup_steps
        );
    }
    let agg = aggregate(&reports);
    let field = |k: &str| agg.req(k).ok().and_then(Json::as_f64).unwrap_or(0.0);
    println!(
        "[fleet] aggregate: {} worker(s), {} requests, {:.1} req/s, {} shed, {} rejected, \
         warm-up training steps: {}",
        reports.len(),
        field("requests") as usize,
        field("rps"),
        field("shed") as usize,
        field("rejected") as usize,
        field("warmup_steps") as usize,
    );
    println!("FLEET_AGGREGATE {}", agg.to_string());
    Ok(())
}

/// Fold surviving worker reports into the `FLEET_AGGREGATE` body.
///
/// Throughput is total requests over the *longest* serve phase — the
/// honest single-box number (workers serve concurrently; summing
/// per-worker RPS would overcount whenever phases don't fully overlap).
/// Shed and rejected counts are summed so the aggregate can never claim
/// every request succeeded while workers were load-shedding
/// (`aggregate_carries_shed_and_rejected_counts` pins the fields).
///
/// Worker metric snapshots roll up too: counters sum by name into a
/// fleet-wide `metrics` object, and the server-side `net.request_ms`
/// histograms merge bucket-wise (sound because every histogram shares
/// [`hist::BOUNDS_MS`]) into `hist`/`hist_bounds_ms` with derived
/// `p50_ms`/`p99_ms`. A report without a `metrics` field (older worker
/// binary, obs off) contributes nothing to the roll-up.
fn aggregate(reports: &[WorkerReport]) -> Json {
    let total_requests: usize = reports.iter().map(|r| r.requests).sum();
    let max_wall_ms = reports.iter().map(|r| r.serve_wall_ms).fold(0.0f64, f64::max);
    let agg_rps = total_requests as f64 / (max_wall_ms / 1e3).max(1e-9);
    let mut counters: std::collections::BTreeMap<String, u64> = std::collections::BTreeMap::new();
    let mut lat = hist::Hist::new();
    for r in reports {
        let Some(m) = &r.metrics else { continue };
        if let Some(cs) = m.get("counters").and_then(Json::as_obj) {
            for (name, v) in cs {
                if let Some(x) = v.as_f64() {
                    *counters.entry(name.clone()).or_insert(0) += x as u64;
                }
            }
        }
        if let Some(h) = m
            .get("hists")
            .and_then(|hs| hs.get("net.request_ms"))
            .and_then(hist::Hist::from_json)
        {
            lat.merge(&h);
        }
    }
    let merged: Vec<(String, Json)> =
        counters.into_iter().map(|(n, v)| (n, Json::num(v as f64))).collect();
    Json::obj(vec![
        ("workers", Json::num(reports.len() as f64)),
        ("requests", Json::num(total_requests as f64)),
        ("serve_wall_ms", Json::num(max_wall_ms)),
        ("rps", Json::num(agg_rps)),
        ("warmup_steps", Json::num(reports.iter().map(|r| r.warmup_steps).sum::<usize>() as f64)),
        ("shed", Json::num(reports.iter().map(|r| r.shed).sum::<usize>() as f64)),
        ("rejected", Json::num(reports.iter().map(|r| r.rejected).sum::<usize>() as f64)),
        ("metrics", Json::Obj(merged)),
        ("p50_ms", Json::num(lat.quantile_ms(0.50))),
        ("p99_ms", Json::num(lat.quantile_ms(0.99))),
        ("hist", Json::arr_num(lat.counts.iter().map(|&c| c as f64))),
        ("hist_bounds_ms", Json::arr_num(hist::BOUNDS_MS.iter().copied())),
    ])
}

/// What the per-slot poll decided to do with a slot this tick.
enum Transition {
    /// Clean exit: join the relay, mark done.
    Finished,
    /// Crashed or killed as hung: restart or fail over.
    Crashed,
    /// Backoff elapsed: respawn at this generation.
    Respawn(u64),
}

/// The supervision loop: poll every live worker with `try_wait` (never a
/// blocking `wait` — one dead worker must not stall the fleet), kill
/// workers silent past `hang_deadline`, restart under budget with
/// exponential backoff, and fail over the tasks of workers that exhaust
/// it. Failover happens *inside* the loop because survivors block in
/// adoption waiting for the dead worker's publishes — deferring it would
/// deadlock the fleet until the adopt timeout.
fn supervise(
    slots: &mut [WorkerSlot],
    spawner: &WorkerSpawner,
    cfg: &ExpConfig,
    sc: &ServeConfig,
    owned: &[Vec<String>],
    hang_deadline: Duration,
) -> anyhow::Result<()> {
    loop {
        let mut orphans: Vec<String> = Vec::new();
        let mut settled = true;
        for slot in slots.iter_mut() {
            let transition = match &mut slot.state {
                SlotState::Running(live) => {
                    settled = false;
                    match live.child.try_wait() {
                        Ok(Some(status)) if status.success() => Some(Transition::Finished),
                        Ok(Some(status)) => {
                            crate::warnln!("[fleet] worker {} exited with {status}", slot.id);
                            Some(Transition::Crashed)
                        }
                        Ok(None) => {
                            // Socket workers are legitimately stdout-quiet
                            // while serving (replies go to connections, not
                            // the relay), so once one is silent past a
                            // heartbeat period the supervisor also probes
                            // its HTTP `/healthz`; an answer counts as
                            // seen. Probe failures are ignored — the worker
                            // may simply not have bound its listener yet.
                            let heartbeat = hang_deadline / 3;
                            if let Some(addr) = &live.addr {
                                let quiet = live
                                    .last_seen
                                    .lock()
                                    .map(|t| t.elapsed())
                                    .unwrap_or(Duration::ZERO);
                                if quiet >= heartbeat
                                    && live.last_probe.elapsed() >= heartbeat
                                {
                                    live.last_probe = Instant::now();
                                    if probe_healthz(addr) {
                                        if let Ok(mut t) = live.last_seen.lock() {
                                            *t = Instant::now();
                                        }
                                    }
                                }
                            }
                            let silent = live
                                .last_seen
                                .lock()
                                .map(|t| t.elapsed())
                                .unwrap_or(Duration::ZERO);
                            if silent >= hang_deadline {
                                crate::warnln!(
                                    "[fleet] worker {} silent for {silent:?} \
                                     (deadline {hang_deadline:?}); killing as hung",
                                    slot.id
                                );
                                let _ = live.child.kill();
                                let _ = live.child.wait();
                                Some(Transition::Crashed)
                            } else {
                                None
                            }
                        }
                        Err(e) => {
                            // Can't poll it — treat like a crash rather
                            // than spinning on the error forever.
                            crate::warnln!("[fleet] cannot poll worker {}: {e}", slot.id);
                            let _ = live.child.kill();
                            let _ = live.child.wait();
                            Some(Transition::Crashed)
                        }
                    }
                }
                SlotState::Backoff { until, generation } => {
                    settled = false;
                    if Instant::now() >= *until {
                        Some(Transition::Respawn(*generation))
                    } else {
                        None
                    }
                }
                SlotState::Done | SlotState::Failed => None,
            };
            match transition {
                Some(Transition::Finished) => {
                    if let SlotState::Running(live) =
                        std::mem::replace(&mut slot.state, SlotState::Done)
                    {
                        let _ = live.relay.join();
                    }
                }
                Some(Transition::Crashed) => {
                    if let SlotState::Running(live) =
                        std::mem::replace(&mut slot.state, SlotState::Failed)
                    {
                        let _ = live.relay.join();
                    }
                    if slot.restarts < sc.max_restarts {
                        slot.restarts += 1;
                        let pause = RESTART_BACKOFF_BASE
                            .saturating_mul(1u32 << (slot.restarts - 1).min(4))
                            .min(RESTART_BACKOFF_MAX);
                        crate::warnln!(
                            "[fleet] restarting worker {} in {pause:?} (attempt {}/{})",
                            slot.id,
                            slot.restarts,
                            sc.max_restarts
                        );
                        slot.state = SlotState::Backoff {
                            until: Instant::now() + pause,
                            generation: slot.restarts as u64,
                        };
                    } else {
                        crate::warnln!(
                            "[fleet] worker {} exhausted its restart budget \
                             ({} restart(s)); failing its tasks over",
                            slot.id,
                            sc.max_restarts
                        );
                        orphans.extend(owned[slot.id].iter().cloned());
                    }
                }
                Some(Transition::Respawn(generation)) => match spawner.spawn(slot.id, generation) {
                    Ok(live) => slot.state = SlotState::Running(live),
                    Err(e) => {
                        crate::warnln!("[fleet] respawn of worker {} failed: {e:#}", slot.id);
                        orphans.extend(owned[slot.id].iter().cloned());
                        slot.state = SlotState::Failed;
                    }
                },
                None => {}
            }
        }
        if !orphans.is_empty() {
            fail_over(cfg, sc, &orphans)?;
        }
        if settled {
            return Ok(());
        }
        std::thread::sleep(SUPERVISE_POLL);
    }
}

/// Adopt a dead worker's ring-owned tasks: the supervisor builds the same
/// [`ServeCore`] a worker would and resolves each orphan — load-from-store
/// when the dead worker managed to publish, train-on-miss otherwise —
/// publishing the result. Survivors blocked in adoption then hot-load
/// them through the ordinary generation-watch path, exactly as if the
/// dead worker had published.
fn fail_over(cfg: &ExpConfig, sc: &ServeConfig, orphans: &[String]) -> anyhow::Result<()> {
    if orphans.is_empty() || sc.adapter_store.is_none() {
        return Ok(());
    }
    crate::warnln!("[fleet] failing over orphaned task(s) {orphans:?} in the supervisor");
    let refs: Vec<&str> = orphans.iter().map(|s| s.as_str()).collect();
    let mut core = ServeCore::with_method(cfg, sc.adapter_store.as_deref(), &sc.method)?;
    core.prepare(&refs)?;
    core.flush_publishes();
    Ok(())
}

/// One fleet worker (`serve --worker-id I --fleet-tasks a,b`): build the
/// same [`ServeCore`] the demo uses, train-and-publish the owned tasks,
/// store-watch until every sibling-owned adapter is hot-loaded, then
/// serve a mixed stream over the full task set and emit the
/// machine-readable `FLEET_WORKER` report the supervisor aggregates. A
/// detached thread emits `FLEET_HEARTBEAT` every `--heartbeat-secs` so
/// the supervisor can tell "training silently" from "hung".
pub fn run_worker(
    cfg: &ExpConfig,
    sc: &ServeConfig,
    worker_id: usize,
    owned: &[String],
) -> anyhow::Result<()> {
    // Before the heartbeat thread exists, so an injected hang presents to
    // the supervisor as a genuinely silent (hung) worker.
    faults::hang_point("serve");
    faults::crash_point("serve");
    let hb = Duration::from_secs(sc.heartbeat_secs.max(1));
    std::thread::spawn(move || loop {
        std::thread::sleep(hb);
        println!("FLEET_HEARTBEAT");
    });

    let tasks = SERVE_TASKS;
    let owned: Vec<&str> = owned.iter().map(|s| s.as_str()).collect();
    let siblings: Vec<&str> =
        tasks.iter().copied().filter(|t| !owned.contains(t)).collect();

    let mut core = ServeCore::with_method(cfg, sc.adapter_store.as_deref(), &sc.method)?;
    core.prepare(&owned)?;

    // Socket mode: serve over TCP. Sibling adapters are *not* awaited up
    // front — the engine's generation-watch hot-loads them live, and a
    // request for a not-yet-published task gets an explicit
    // `adapter_unavailable` shed instead of blocking the listener.
    if let Some(base) = &sc.listen {
        let addr = worker_listen_addr(base, worker_id)?;
        let stats = super::net::serve_listen(&mut core, sc, &addr)?;
        core.flush_publishes();
        println!(
            "[serve] worker {worker_id}: served {} request(s) at {:.1} req/s \
             ({} shed, {} rejected)",
            stats.requests,
            stats.throughput(),
            stats.shed,
            stats.rejected
        );
        let report = worker_report_json(worker_id, &stats, core.steps_this_run);
        println!("FLEET_WORKER {}", report.to_string());
        return Ok(());
    }

    if !siblings.is_empty() {
        println!(
            "[serve] store-watching for {} sibling adapter(s): {siblings:?}",
            siblings.len()
        );
        core.adopt_published(&siblings, ADOPT_TIMEOUT)?;
    }

    // Per-worker stream seed: same distribution shape as the demo, but
    // distinct request sequences per worker.
    let stream_seed = cfg.seed ^ 0x5EED ^ ((worker_id as u64 + 1) << 32);
    let queue = core.build_queue(tasks, sc.requests, stream_seed)?;
    let (_results, stats) = core.serve_batched(sc, &queue)?;
    core.flush_publishes();
    println!(
        "[serve] worker {worker_id}: served {} request(s) at {:.1} req/s",
        stats.requests,
        stats.throughput()
    );
    let report = worker_report_json(worker_id, &stats, core.steps_this_run);
    println!("FLEET_WORKER {}", report.to_string());
    Ok(())
}

/// The machine-readable `FLEET_WORKER` report body — one schema for the
/// in-process and socket paths, so the aggregator parses both.
fn worker_report_json(worker: usize, stats: &super::RouterStats, warmup_steps: usize) -> Json {
    Json::obj(vec![
        ("worker", Json::num(worker as f64)),
        ("requests", Json::num(stats.requests as f64)),
        ("serve_wall_ms", Json::num(stats.wall_s * 1e3)),
        ("rps", Json::num(stats.throughput())),
        ("warmup_steps", Json::num(warmup_steps as f64)),
        ("shed", Json::num(stats.shed as f64)),
        ("rejected", Json::num(stats.rejected as f64)),
        ("metrics", obs::snapshot().to_json()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_routes_deterministically_and_in_range() {
        let ring = HashRing::new(4);
        for t in ["sst2", "mrpc", "qnli", "task-x", "task-y"] {
            let w = ring.route(t);
            assert!(w < 4);
            assert_eq!(w, ring.route(t), "routing must be deterministic");
        }
    }

    #[test]
    fn ring_partition_covers_every_task_exactly_once() {
        let ring = HashRing::new(3);
        let tasks: Vec<String> = (0..60).map(|i| format!("task{i}")).collect();
        let refs: Vec<&str> = tasks.iter().map(|s| s.as_str()).collect();
        let owned = ring.partition(&refs);
        assert_eq!(owned.len(), 3);
        let total: usize = owned.iter().map(|o| o.len()).sum();
        assert_eq!(total, tasks.len());
        // 64 vnodes/worker spread 60 keys well enough that no worker
        // should sit at zero (deterministic: fixed hash, fixed labels).
        for (w, o) in owned.iter().enumerate() {
            assert!(!o.is_empty(), "worker {w} owns no tasks: {owned:?}");
        }
    }

    #[test]
    fn ring_rebalance_moves_keys_only_to_the_new_worker() {
        // The consistent-hashing property this stub exists for: growing
        // the fleet must never shuffle keys between existing workers.
        let before = HashRing::new(3);
        let after = HashRing::new(4);
        for i in 0..200 {
            let task = format!("task{i}");
            let (b, a) = (before.route(&task), after.route(&task));
            assert!(
                a == b || a == 3,
                "{task} moved {b} → {a}, not to the new worker"
            );
        }
    }

    #[test]
    fn single_worker_ring_owns_everything() {
        let ring = HashRing::new(1);
        assert_eq!(ring.workers(), 1);
        assert_eq!(ring.route("anything"), 0);
    }

    fn report(
        worker: usize,
        requests: usize,
        wall_ms: f64,
        shed: usize,
        rej: usize,
    ) -> WorkerReport {
        WorkerReport {
            worker,
            requests,
            serve_wall_ms: wall_ms,
            rps: 0.0,
            warmup_steps: worker + 1,
            shed,
            rejected: rej,
            metrics: None,
        }
    }

    /// FLEET_AGGREGATE must carry shed/rejected sums — without them the
    /// fleet could report every request served while workers were
    /// load-shedding, and nothing downstream could tell.
    #[test]
    fn aggregate_carries_shed_and_rejected_counts() {
        let agg = aggregate(&[report(0, 10, 2000.0, 2, 1), report(1, 6, 1000.0, 0, 4)]);
        let field = |k: &str| agg.req(k).unwrap().as_f64().unwrap();
        assert_eq!(field("workers") as usize, 2);
        assert_eq!(field("requests") as usize, 16);
        assert_eq!(field("shed") as usize, 2);
        assert_eq!(field("rejected") as usize, 5);
        assert_eq!(field("warmup_steps") as usize, 3);
        assert_eq!(field("serve_wall_ms"), 2000.0, "wall is the longest phase, not the sum");
        assert!((field("rps") - 8.0).abs() < 1e-9, "16 requests over the 2 s longest phase");
    }

    #[test]
    fn worker_report_parse_tolerates_missing_shed_fields() {
        let old = r#"{"requests": 4, "serve_wall_ms": 10.0, "rps": 400.0, "warmup_steps": 2}"#;
        let r = WorkerReport::parse(1, old).unwrap();
        assert_eq!((r.shed, r.rejected), (0, 0), "absent counts mean zero, not a parse error");
        assert!(r.metrics.is_none(), "absent metrics is tolerated, not a parse error");
        let new = r#"{"requests": 4, "serve_wall_ms": 10.0, "rps": 400.0, "warmup_steps": 2,
                      "shed": 3, "rejected": 1, "metrics": {"counters": {}}}"#;
        let r = WorkerReport::parse(2, new).unwrap();
        assert_eq!((r.shed, r.rejected), (3, 1));
        assert!(r.metrics.is_some());
    }

    /// Counters sum by name and `net.request_ms` merges bucket-wise; a
    /// report without metrics (older binary, obs off) contributes
    /// nothing instead of breaking the roll-up.
    #[test]
    fn aggregate_merges_worker_metric_snapshots() {
        let mk = |ok: usize, ms: f64| {
            let mut h = hist::Hist::new();
            h.record(ms);
            Json::obj(vec![
                (
                    "counters",
                    Json::obj(vec![("net.requests{code=\"ok\"}", Json::num(ok as f64))]),
                ),
                ("hists", Json::obj(vec![("net.request_ms", h.to_json())])),
            ])
        };
        let mut a = report(0, 10, 2000.0, 0, 0);
        a.metrics = Some(mk(10, 1.5));
        let mut b = report(1, 6, 1000.0, 0, 0);
        b.metrics = Some(mk(6, 100.0));
        let c = report(2, 0, 0.0, 0, 0);
        let agg = aggregate(&[a, b, c]);
        let ok = agg
            .req("metrics")
            .unwrap()
            .get("net.requests{code=\"ok\"}")
            .and_then(Json::as_usize);
        assert_eq!(ok, Some(16), "counters sum across workers");
        let total: f64 =
            agg.req("hist").unwrap().as_arr().unwrap().iter().filter_map(Json::as_f64).sum();
        assert_eq!(total as u64, 2, "one latency sample per reporting worker");
        assert_eq!(agg.req("p50_ms").unwrap().as_f64(), Some(2.0));
        assert_eq!(agg.req("p99_ms").unwrap().as_f64(), Some(128.0));
        assert_eq!(
            agg.req("hist_bounds_ms").unwrap().as_arr().map(|a| a.len()),
            Some(hist::BOUNDS_MS.len())
        );
    }

    #[test]
    fn fleet_listen_ports_are_consecutive_per_worker() {
        assert_eq!(worker_listen_addr("127.0.0.1:7311", 0).unwrap(), "127.0.0.1:7311");
        assert_eq!(worker_listen_addr("127.0.0.1:7311", 3).unwrap(), "127.0.0.1:7314");
        assert!(worker_listen_addr("noport", 0).is_err());
        assert!(worker_listen_addr("127.0.0.1:sixty", 0).is_err());
        assert!(worker_listen_addr("127.0.0.1:65535", 1).is_err());
    }
}
