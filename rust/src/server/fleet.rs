//! Multi-process serving fleet over one shared adapter store.
//!
//! `serve --fleet N` is the single-box dress rehearsal for horizontal
//! scale: N worker *processes* (re-execs of the current binary) share one
//! `runs/adapters/` store, with the task set partitioned across workers
//! by a consistent-hash ring. Lifecycle:
//!
//! ```text
//!            supervisor (serve --fleet N)
//!   pre-warm runs/ caches → partition tasks on the HashRing
//!        │ spawn               │ spawn                │ spawn
//!        ▼                     ▼                      ▼
//!   worker 0              worker 1     …         worker N−1
//!   train+publish owned   train+publish owned    train+publish owned
//!        │   └──────── index.lock serializes ───────┘  │
//!        ▼                                             ▼
//!   store-watch: poll index generation, hot-load sibling publishes
//!        ▼                                             ▼
//!   serve a mixed stream over ALL tasks through the batched Router
//!        └────────── FLEET_WORKER {json} lines ────────┘
//!                            ▼
//!        supervisor aggregates → FLEET_AGGREGATE {json}
//! ```
//!
//! Every worker ends up serving every task — ownership only decides who
//! *trains* an adapter; the store's locked `publish_merged` guarantees
//! all concurrent publishes land, and the index `generation` counter
//! gives workers a cheap poll to notice them. The supervisor pre-warms
//! the pipeline's backbone/warm-up caches before spawning because those
//! checkpoint writes are not atomic — N workers racing to create them
//! could corrupt a cache file all of them read.
//!
//! The [`HashRing`] is deliberately a reusable stub for real horizontal
//! scale: adding a worker only moves the keys the new worker now owns
//! (`ring_rebalance_moves_keys_only_to_the_new_worker` pins that down).

use std::io::BufRead;
use std::process::{Command, Stdio};
use std::time::Duration;

use super::{ServeConfig, ServeCore, SERVE_TASKS};
use crate::experiments::{ExpConfig, Pipeline};
use crate::util::hash::fnv1a_str;
use crate::util::json::Json;
use crate::util::pool;

/// Virtual nodes per worker on the ring. Enough to spread a small task
/// set evenly; cheap enough that ring construction stays trivial.
pub const VNODES_PER_WORKER: usize = 64;

/// How long a worker store-watches for sibling-published adapters before
/// giving up (covers the siblings' worst-case training time).
const ADOPT_TIMEOUT: Duration = Duration::from_secs(300);

/// A consistent-hash ring over worker ids: each worker contributes
/// [`VNODES_PER_WORKER`] points (FNV-1a of `"w{worker}/v{vnode}"`), and a
/// task routes to the first point clockwise of its own hash. Existing
/// workers' points never move when a worker joins, so growing the fleet
/// only reassigns the keys the new worker takes over.
pub struct HashRing {
    /// Sorted `(point, worker)` pairs.
    ring: Vec<(u64, usize)>,
    workers: usize,
}

impl HashRing {
    pub fn new(workers: usize) -> HashRing {
        let workers = workers.max(1);
        let mut ring = Vec::with_capacity(workers * VNODES_PER_WORKER);
        for w in 0..workers {
            for v in 0..VNODES_PER_WORKER {
                ring.push((fnv1a_str(&format!("w{w}/v{v}")), w));
            }
        }
        // Ties (astronomically unlikely under FNV-1a over distinct
        // labels) resolve to the lower worker id via the pair ordering.
        ring.sort_unstable();
        HashRing { ring, workers }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The worker owning `task`: successor lookup with wraparound.
    pub fn route(&self, task: &str) -> usize {
        let h = fnv1a_str(task);
        let i = self.ring.partition_point(|(p, _)| *p < h);
        self.ring[i % self.ring.len()].1
    }

    /// Partition `tasks` into per-worker owned sets (a worker may own
    /// none — it then serves purely from sibling publishes).
    pub fn partition(&self, tasks: &[&str]) -> Vec<Vec<String>> {
        let mut owned = vec![Vec::new(); self.workers];
        for t in tasks {
            owned[self.route(t)].push(t.to_string());
        }
        owned
    }
}

/// One worker's parsed `FLEET_WORKER` report.
struct WorkerReport {
    worker: usize,
    requests: usize,
    serve_wall_ms: f64,
    rps: f64,
    warmup_steps: usize,
}

impl WorkerReport {
    fn parse(worker: usize, json: &str) -> anyhow::Result<WorkerReport> {
        let doc = Json::parse(json)?;
        let num = |k: &str| -> anyhow::Result<f64> {
            doc.req(k)?.as_f64().ok_or_else(|| anyhow::anyhow!("FLEET_WORKER: bad {k}"))
        };
        Ok(WorkerReport {
            worker,
            requests: num("requests")? as usize,
            serve_wall_ms: num("serve_wall_ms")?,
            rps: num("rps")?,
            warmup_steps: num("warmup_steps")? as usize,
        })
    }
}

/// Supervisor: pre-warm the shared `runs/` caches, partition
/// [`SERVE_TASKS`] over the ring, spawn `workers` re-execs of the current
/// binary, relay their output `[w{i}]`-prefixed, and aggregate their
/// reports into a `FLEET_AGGREGATE` line (what the `serve_fleet` bench
/// and the CI fleet smoke parse).
pub fn run_fleet(cfg: &ExpConfig, sc: &ServeConfig, workers: usize) -> anyhow::Result<()> {
    let workers = workers.max(1);
    let tasks = SERVE_TASKS;

    // The backbone/warm-up checkpoint writes under runs/ are not atomic;
    // materialize them once here so workers only ever read them.
    println!(
        "[fleet] pre-warming shared caches (backbone + {} task warm-up(s))…",
        tasks.len()
    );
    {
        let mut pipe = Pipeline::new(cfg)?;
        for t in tasks {
            pipe.warmed(t)?;
        }
    }

    let ring = HashRing::new(workers);
    let owned = ring.partition(tasks);
    for (w, ts) in owned.iter().enumerate() {
        println!("[fleet] worker {w} owns {ts:?}");
    }

    let exe = std::env::current_exe()
        .map_err(|e| anyhow::anyhow!("cannot locate the current binary: {e}"))?;
    let threads_per = (pool::threads() / workers).max(1);
    let base = sc.requests / workers;
    let extra = sc.requests % workers;

    let (tx, rx) = std::sync::mpsc::channel::<(usize, String)>();
    let mut children = Vec::new();
    for (w, ts) in owned.iter().enumerate() {
        let mut cmd = Command::new(&exe);
        cmd.arg("serve")
            .args(["--worker-id", &w.to_string()])
            .args(["--fleet-tasks", &ts.join(",")])
            .args(["--preset", &cfg.preset])
            .args(["--pretrain-steps", &cfg.pretrain_steps.to_string()])
            .args(["--warmup-steps", &cfg.warmup_steps.to_string()])
            .args(["--steps", &cfg.steps.to_string()])
            .args(["--train-examples", &cfg.train_examples.to_string()])
            .args(["--seed", &cfg.seed.to_string()])
            .args(["--lr-ft", &cfg.lr_ft.to_string()])
            .args(["--lr", &cfg.lr_adapter.to_string()])
            .args(["--requests", &(base + usize::from(w < extra)).to_string()])
            .args(["--max-batch", &sc.max_batch.to_string()])
            .args(["--resident-adapters", &sc.resident_adapters.to_string()])
            // Split the host pool across workers instead of oversubscribing
            // the box N-fold.
            .env("QRLORA_THREADS", threads_per.to_string())
            .stdout(Stdio::piped());
        match &sc.adapter_store {
            Some(dir) => {
                cmd.args(["--adapter-store", &dir.display().to_string()]);
            }
            None => {
                cmd.arg("--no-warm-start");
            }
        }
        let mut child = cmd
            .spawn()
            .map_err(|e| anyhow::anyhow!("cannot spawn fleet worker {w}: {e}"))?;
        let stdout = child.stdout.take().expect("stdout was piped");
        let tx = tx.clone();
        let relay = std::thread::spawn(move || {
            for line in std::io::BufReader::new(stdout).lines() {
                let Ok(line) = line else { break };
                if let Some(json) = line.strip_prefix("FLEET_WORKER ") {
                    let _ = tx.send((w, json.to_string()));
                }
                println!("[w{w}] {line}");
            }
        });
        children.push((w, child, relay));
    }
    drop(tx);

    for (w, mut child, relay) in children {
        let status = child.wait()?;
        let _ = relay.join();
        anyhow::ensure!(status.success(), "fleet worker {w} exited with {status}");
    }
    let mut reports: Vec<WorkerReport> = rx
        .iter()
        .map(|(w, json)| WorkerReport::parse(w, &json))
        .collect::<anyhow::Result<_>>()?;
    reports.sort_by_key(|r| r.worker);
    anyhow::ensure!(
        reports.len() == workers,
        "expected {workers} FLEET_WORKER report(s), got {}",
        reports.len()
    );

    // Aggregate throughput over the longest serve phase: the honest
    // single-box number (workers serve concurrently; summing per-worker
    // RPS would overcount whenever phases don't fully overlap).
    let total_requests: usize = reports.iter().map(|r| r.requests).sum();
    let warmup_steps: usize = reports.iter().map(|r| r.warmup_steps).sum();
    let max_wall_ms = reports.iter().map(|r| r.serve_wall_ms).fold(0.0f64, f64::max);
    let agg_rps = total_requests as f64 / (max_wall_ms / 1e3).max(1e-9);
    for r in &reports {
        println!(
            "[fleet] worker {}: {} requests, {:.1} req/s, warm-up training steps: {}",
            r.worker, r.requests, r.rps, r.warmup_steps
        );
    }
    println!(
        "[fleet] aggregate: {workers} worker(s), {total_requests} requests, \
         {agg_rps:.1} req/s, warm-up training steps: {warmup_steps}"
    );
    let agg = Json::obj(vec![
        ("workers", Json::num(workers as f64)),
        ("requests", Json::num(total_requests as f64)),
        ("serve_wall_ms", Json::num(max_wall_ms)),
        ("rps", Json::num(agg_rps)),
        ("warmup_steps", Json::num(warmup_steps as f64)),
    ]);
    println!("FLEET_AGGREGATE {}", agg.to_string());
    Ok(())
}

/// One fleet worker (`serve --worker-id I --fleet-tasks a,b`): build the
/// same [`ServeCore`] the demo uses, train-and-publish the owned tasks,
/// store-watch until every sibling-owned adapter is hot-loaded, then
/// serve a mixed stream over the full task set and emit the
/// machine-readable `FLEET_WORKER` report the supervisor aggregates.
pub fn run_worker(
    cfg: &ExpConfig,
    sc: &ServeConfig,
    worker_id: usize,
    owned: &[String],
) -> anyhow::Result<()> {
    let tasks = SERVE_TASKS;
    let owned: Vec<&str> = owned.iter().map(|s| s.as_str()).collect();
    let siblings: Vec<&str> =
        tasks.iter().copied().filter(|t| !owned.contains(t)).collect();

    let mut core = ServeCore::new(cfg, sc.adapter_store.as_deref())?;
    core.prepare(&owned)?;
    if !siblings.is_empty() {
        println!(
            "[serve] store-watching for {} sibling adapter(s): {siblings:?}",
            siblings.len()
        );
        core.adopt_published(&siblings, ADOPT_TIMEOUT)?;
    }

    // Per-worker stream seed: same distribution shape as the demo, but
    // distinct request sequences per worker.
    let stream_seed = cfg.seed ^ 0x5EED ^ ((worker_id as u64 + 1) << 32);
    let queue = core.build_queue(tasks, sc.requests, stream_seed)?;
    let (_results, stats) = core.serve_batched(sc, &queue)?;
    println!(
        "[serve] worker {worker_id}: served {} request(s) at {:.1} req/s",
        stats.requests,
        stats.throughput()
    );
    let report = Json::obj(vec![
        ("worker", Json::num(worker_id as f64)),
        ("requests", Json::num(stats.requests as f64)),
        ("serve_wall_ms", Json::num(stats.wall_s * 1e3)),
        ("rps", Json::num(stats.throughput())),
        ("warmup_steps", Json::num(core.steps_this_run as f64)),
    ]);
    println!("FLEET_WORKER {}", report.to_string());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_routes_deterministically_and_in_range() {
        let ring = HashRing::new(4);
        for t in ["sst2", "mrpc", "qnli", "task-x", "task-y"] {
            let w = ring.route(t);
            assert!(w < 4);
            assert_eq!(w, ring.route(t), "routing must be deterministic");
        }
    }

    #[test]
    fn ring_partition_covers_every_task_exactly_once() {
        let ring = HashRing::new(3);
        let tasks: Vec<String> = (0..60).map(|i| format!("task{i}")).collect();
        let refs: Vec<&str> = tasks.iter().map(|s| s.as_str()).collect();
        let owned = ring.partition(&refs);
        assert_eq!(owned.len(), 3);
        let total: usize = owned.iter().map(|o| o.len()).sum();
        assert_eq!(total, tasks.len());
        // 64 vnodes/worker spread 60 keys well enough that no worker
        // should sit at zero (deterministic: fixed hash, fixed labels).
        for (w, o) in owned.iter().enumerate() {
            assert!(!o.is_empty(), "worker {w} owns no tasks: {owned:?}");
        }
    }

    #[test]
    fn ring_rebalance_moves_keys_only_to_the_new_worker() {
        // The consistent-hashing property this stub exists for: growing
        // the fleet must never shuffle keys between existing workers.
        let before = HashRing::new(3);
        let after = HashRing::new(4);
        for i in 0..200 {
            let task = format!("task{i}");
            let (b, a) = (before.route(&task), after.route(&task));
            assert!(
                a == b || a == 3,
                "{task} moved {b} → {a}, not to the new worker"
            );
        }
    }

    #[test]
    fn single_worker_ring_owns_everything() {
        let ring = HashRing::new(1);
        assert_eq!(ring.workers(), 1);
        assert_eq!(ring.route("anything"), 0);
    }
}
