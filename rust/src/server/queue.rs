//! Slot-aware admission queue with a bounded reordering window.
//!
//! The network front-end ([`super::net`]) decouples socket readers from
//! the single engine thread through this queue. It is the
//! continuous-batching policy in one pure, wall-clock-free data
//! structure:
//!
//! * **Bounded depth** — [`AdmissionQueue::push`] refuses entries past
//!   `max_depth` and hands the item back, so the caller can send an
//!   explicit 503-style rejection (load shedding, never a silent drop).
//! * **Slot-aware batch assembly** — [`AdmissionQueue::pop_batch`] takes
//!   the front entry unconditionally, then pulls *later* requests forward
//!   when they fit the batch: their task is already admitted, or a free
//!   adapter slot remains under `max_distinct` (the [`super::AdapterBank`]
//!   capacity). A stream that interleaves many tasks therefore still
//!   fills batches without ever forcing the bank to evict a pinned slot.
//! * **Bounded reordering** — every queued entry counts how many times a
//!   later entry overtook it; a selection that would push any skipped
//!   entry past `window` overtakes ends the batch instead, so no request
//!   starves. `window = 0` degrades to strict FIFO prefixes.
//! * **Per-connection FIFO** — skipping an entry blocks its connection
//!   for the rest of the scan, so two requests from one connection can
//!   never be reordered (replies stay in request order per client).
//!
//! Pure and deterministic: no clocks, no randomness, no threads. The
//! property suite (`rust/tests/queue_props.rs`) drives it with seeded
//! arrival orders from [`crate::util::rng`] and pins the three
//! invariants above.

use std::collections::VecDeque;

/// What the queue needs to know about an entry to schedule it.
pub trait Slotted {
    /// Connection the entry arrived on (per-connection order is kept).
    fn conn(&self) -> u64;
    /// Task name (batch assembly groups by task under the slot budget).
    fn task(&self) -> &str;
}

/// Queue policy knobs (`--reorder-window`, `--max-queue-depth`, and the
/// adapter-bank capacity).
#[derive(Clone, Copy, Debug)]
pub struct QueueConfig {
    /// Maximum times any entry may be overtaken by a later entry before
    /// it becomes a barrier (0 = strict FIFO).
    pub window: usize,
    /// Maximum queued entries before [`AdmissionQueue::push`] sheds.
    pub max_depth: usize,
    /// Maximum distinct tasks per popped batch — the adapter-bank
    /// capacity, so a batch can never pin-saturate the bank.
    pub max_distinct: usize,
}

struct Entry<T> {
    item: T,
    /// Times a later entry was popped before this one. Never exceeds
    /// `window` (the starvation bound the property suite pins).
    overtakes: usize,
}

/// The admission queue. See the module docs for the scheduling policy.
pub struct AdmissionQueue<T> {
    cfg: QueueConfig,
    entries: VecDeque<Entry<T>>,
    /// Cumulative entries pulled forward past a skipped entry, across
    /// every batch — a pure counter (no clocks), read by the engine for
    /// the `queue.reorder_pulls` metric.
    pulled: usize,
}

impl<T: Slotted> AdmissionQueue<T> {
    /// An empty queue under `cfg` (depth and slot budget are clamped to
    /// at least 1 so the queue can always make progress).
    pub fn new(cfg: QueueConfig) -> AdmissionQueue<T> {
        let cfg = QueueConfig {
            max_depth: cfg.max_depth.max(1),
            max_distinct: cfg.max_distinct.max(1),
            ..cfg
        };
        AdmissionQueue { cfg, entries: VecDeque::new(), pulled: 0 }
    }

    /// Current queue depth.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Cumulative count of reorder pulls: selections that jumped a
    /// skipped entry, summed over every [`AdmissionQueue::pop_batch`].
    pub fn reorder_pulls(&self) -> usize {
        self.pulled
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Admit an entry, or hand it back when the queue is at `max_depth` —
    /// the caller owes the client an explicit rejection reply.
    pub fn push(&mut self, item: T) -> Result<(), T> {
        if self.entries.len() >= self.cfg.max_depth {
            return Err(item);
        }
        self.entries.push_back(Entry { item, overtakes: 0 });
        Ok(())
    }

    /// Remove every queued entry in FIFO order (shutdown drain).
    pub fn drain(&mut self) -> Vec<T> {
        self.entries.drain(..).map(|e| e.item).collect()
    }

    /// Assemble the next batch of up to `max_batch` entries.
    ///
    /// The front entry is always taken (guaranteed progress). Later
    /// entries are pulled forward when their connection has nothing
    /// skipped ahead of them and their task fits the slot budget. Every
    /// selection past a skipped entry costs that entry one overtake;
    /// a selection that would push any skipped entry past `window` ends
    /// the batch instead.
    pub fn pop_batch(&mut self, max_batch: usize) -> Vec<T> {
        if self.entries.is_empty() {
            return Vec::new();
        }
        let max_batch = max_batch.max(1);
        let mut selected: Vec<usize> = Vec::new();
        let mut tasks: Vec<String> = Vec::new();
        let mut blocked: Vec<u64> = Vec::new();
        // Every selection overtakes *every* entry skipped so far, so the
        // binding constraint is one number: the largest projected
        // overtake count among skipped entries.
        let mut worst = 0usize;
        let mut skipped_any = false;
        for i in 0..self.entries.len() {
            if selected.len() == max_batch {
                break;
            }
            let e = &self.entries[i];
            let task_fits = tasks.iter().any(|t| t == e.item.task())
                || tasks.len() < self.cfg.max_distinct;
            if task_fits && !blocked.contains(&e.item.conn()) {
                if skipped_any && worst + 1 > self.cfg.window {
                    break; // would starve a skipped entry past the window
                }
                if !tasks.iter().any(|t| t == e.item.task()) {
                    tasks.push(e.item.task().to_string());
                }
                selected.push(i);
                if skipped_any {
                    worst += 1;
                    self.pulled += 1;
                }
            } else {
                skipped_any = true;
                worst = worst.max(e.overtakes);
                let c = e.item.conn();
                if !blocked.contains(&c) {
                    blocked.push(c);
                }
            }
        }
        // Charge one overtake to every entry a selection jumped over,
        // then extract the batch (`selected` is ascending — scan order).
        for (j, e) in self.entries.iter_mut().enumerate() {
            if selected.binary_search(&j).is_err() {
                e.overtakes += selected.iter().filter(|&&i| i > j).count();
            }
        }
        let mut batch = Vec::with_capacity(selected.len());
        for (removed, &i) in selected.iter().enumerate() {
            let e = self.entries.remove(i - removed).expect("selected index in range");
            batch.push(e.item);
        }
        debug_assert!(
            self.entries.iter().all(|e| e.overtakes <= self.cfg.window),
            "an entry was overtaken past the window bound"
        );
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq)]
    struct Item {
        conn: u64,
        seq: usize,
        task: &'static str,
    }

    impl Slotted for Item {
        fn conn(&self) -> u64 {
            self.conn
        }
        fn task(&self) -> &str {
            self.task
        }
    }

    fn item(conn: u64, seq: usize, task: &'static str) -> Item {
        Item { conn, seq, task }
    }

    fn q(window: usize, max_depth: usize, max_distinct: usize) -> AdmissionQueue<Item> {
        AdmissionQueue::new(QueueConfig { window, max_depth, max_distinct })
    }

    fn seqs(batch: &[Item]) -> Vec<usize> {
        batch.iter().map(|i| i.seq).collect()
    }

    #[test]
    fn fifo_when_everything_fits() {
        let mut q = q(4, 64, 8);
        for s in 0..4 {
            q.push(item(s as u64, s, "a")).unwrap();
        }
        assert_eq!(seqs(&q.pop_batch(8)), vec![0, 1, 2, 3]);
        assert!(q.is_empty());
        assert!(q.pop_batch(8).is_empty());
    }

    #[test]
    fn push_sheds_past_max_depth() {
        let mut q = q(4, 2, 8);
        q.push(item(0, 0, "a")).unwrap();
        q.push(item(0, 1, "a")).unwrap();
        let back = q.push(item(0, 2, "a")).unwrap_err();
        assert_eq!(back.seq, 2, "the refused item comes back to the caller");
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn pulls_same_task_forward_under_the_slot_budget() {
        // [a, b, c, a] with 2 slots: c does not fit, the later a does.
        let mut q = q(4, 64, 2);
        q.push(item(1, 0, "a")).unwrap();
        q.push(item(2, 1, "b")).unwrap();
        q.push(item(3, 2, "c")).unwrap();
        q.push(item(4, 3, "a")).unwrap();
        assert_eq!(seqs(&q.pop_batch(8)), vec![0, 1, 3]);
        assert_eq!(seqs(&q.pop_batch(8)), vec![2], "c is served next, once overtaken");
    }

    #[test]
    fn window_zero_never_reorders() {
        let mut q = q(0, 64, 2);
        q.push(item(1, 0, "a")).unwrap();
        q.push(item(2, 1, "b")).unwrap();
        q.push(item(3, 2, "c")).unwrap();
        q.push(item(4, 3, "a")).unwrap();
        assert_eq!(seqs(&q.pop_batch(8)), vec![0, 1], "stops at the first skip");
        assert_eq!(seqs(&q.pop_batch(8)), vec![2]);
        assert_eq!(seqs(&q.pop_batch(8)), vec![3]);
    }

    #[test]
    fn same_connection_is_never_reordered() {
        // conn 1 sends a, c, a with one slot: once c is skipped the
        // connection is blocked, so the second a cannot jump it.
        let mut q = q(8, 64, 1);
        q.push(item(1, 0, "a")).unwrap();
        q.push(item(1, 1, "c")).unwrap();
        q.push(item(1, 2, "a")).unwrap();
        assert_eq!(seqs(&q.pop_batch(8)), vec![0]);
        assert_eq!(seqs(&q.pop_batch(8)), vec![1]);
        assert_eq!(seqs(&q.pop_batch(8)), vec![2]);
    }

    #[test]
    fn window_bounds_overtakes_within_one_batch() {
        // [a, c, a, a, a] with one slot and window 1: the batch may pull
        // exactly one a past the skipped c, then c becomes a barrier.
        let mut q = q(1, 64, 1);
        q.push(item(1, 0, "a")).unwrap();
        q.push(item(2, 1, "c")).unwrap();
        for s in 2..5 {
            q.push(item(2 + s as u64, s, "a")).unwrap();
        }
        assert_eq!(seqs(&q.pop_batch(8)), vec![0, 2], "one overtake allowed, then barrier");
        assert_eq!(seqs(&q.pop_batch(8)), vec![1], "the overtaken entry is now front");
    }

    #[test]
    fn window_bound_carries_across_batches() {
        // c is overtaken once in batch 1; with window 1 spent, batch 2
        // must not let the remaining a past it again.
        let mut q = q(1, 64, 1);
        q.push(item(1, 0, "a")).unwrap();
        q.push(item(2, 1, "c")).unwrap();
        q.push(item(3, 2, "a")).unwrap();
        q.push(item(4, 3, "a")).unwrap();
        assert_eq!(seqs(&q.pop_batch(2)), vec![0, 2]);
        assert_eq!(seqs(&q.pop_batch(2)), vec![1], "spent window blocks further overtakes");
        assert_eq!(seqs(&q.pop_batch(2)), vec![3]);
    }

    #[test]
    fn reorder_pulls_accumulate_across_batches() {
        // [a, b, c, a] with 2 slots: the trailing a jumps the skipped c
        // — exactly one pull; the follow-up FIFO pop adds none.
        let mut q = q(4, 64, 2);
        q.push(item(1, 0, "a")).unwrap();
        q.push(item(2, 1, "b")).unwrap();
        q.push(item(3, 2, "c")).unwrap();
        q.push(item(4, 3, "a")).unwrap();
        assert_eq!(q.reorder_pulls(), 0);
        assert_eq!(seqs(&q.pop_batch(8)), vec![0, 1, 3]);
        assert_eq!(q.reorder_pulls(), 1);
        assert_eq!(seqs(&q.pop_batch(8)), vec![2]);
        assert_eq!(q.reorder_pulls(), 1, "a plain FIFO pop adds no pulls");
    }

    #[test]
    fn drain_returns_everything_in_fifo_order() {
        let mut q = q(4, 64, 1);
        q.push(item(1, 0, "a")).unwrap();
        q.push(item(2, 1, "b")).unwrap();
        q.push(item(3, 2, "c")).unwrap();
        assert_eq!(seqs(&q.drain()), vec![0, 1, 2]);
        assert!(q.is_empty());
    }
}
