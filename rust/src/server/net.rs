//! Socketed serving front-end: a hand-rolled, dependency-free TCP server
//! (std-only, in the style of `util/pool.rs` — no tokio) in front of
//! [`ServeCore`].
//!
//! ## Protocol
//!
//! The native protocol is line-delimited JSON, one request per line:
//!
//! ```text
//! {"id": 7, "task": "sst2", "a": [12, 904, 3], "b": [], "genre": 0}
//! ```
//!
//! `id` is echoed verbatim (any JSON value); `b` and `genre` are
//! optional. A success reply is `{"id", "task", "logits"}` with exactly
//! the task's `n_classes` logits — bit-identical to the in-process
//! [`super::serve_swap`] path, proven by `rust/tests/serve_net.rs`
//! (f32→f64→shortest-decimal→f64→f32 round-trips exactly; the −∞ padding
//! lanes are truncated away, since JSON has no infinities). An error
//! reply is `{"id", "error", "code"}` with an HTTP-flavored code:
//!
//! | error                 | code | meaning                                   |
//! |-----------------------|------|-------------------------------------------|
//! | `bad_request`         | 400  | unparseable JSON / bad fields / bad token |
//! | `unknown_task`        | 404  | task outside [`super::SERVE_TASKS`]       |
//! | `not_found`           | 404  | HTTP path other than the two routes       |
//! | `oversized`           | 413  | request line/body over [`MAX_LINE`]       |
//! | `queue_full`          | 503  | admission queue at `--max-queue-depth`    |
//! | `adapter_unavailable` | 503  | task known but no adapter resolved yet    |
//! | `shutting_down`       | 503  | queued behind the final budgeted reply    |
//! | `internal_error`      | 500  | batch execution failed                    |
//!
//! A connection whose first line starts with an HTTP method gets a
//! minimal HTTP/1.1 shim instead — one request per connection
//! (`Connection: close`): `POST /infer` (body = one request object),
//! `GET /healthz` (liveness plus a registry snapshot: queue depth, bank
//! occupancy, store generation, degraded flag), `GET /metrics`
//! (Prometheus text format), `GET /metrics.json` (the same snapshot as
//! JSON), and `GET /flight` (the flight-recorder ring as JSON). The
//! shim has exactly one response shape, so every route — `/metrics`
//! included — is served with an `application/json` content type;
//! Prometheus scrapes by path, not content type.
//!
//! ## Observability
//!
//! Every admitted request gets a trace id ([`crate::obs::next_trace_id`],
//! echoed as `"trace"` in success replies) and leaves
//! admit → queue → execute → write spans in the [`crate::obs::flight`]
//! recorder, so a chaos-killed worker dumps the in-flight requests'
//! timelines. The same stages feed the `net.*` registry histograms
//! ([`crate::obs`]): server-side p50/p99 are measured where shedding
//! happens, not just at the soak client, and every error reply counts
//! into `net.requests{code="…"}` by error name.
//!
//! ## Anatomy
//!
//! One detached reader thread per connection parses and validates
//! requests and admits them into the shared [`AdmissionQueue`]; one
//! writer thread per connection owns the write half and drains a reply
//! channel (so a reader wedged by a fault can never block replies); a
//! single engine thread — the caller of [`serve_listen`] — pops
//! slot-aware batches, runs them through the batched [`super::Router`],
//! and every [`RELOAD_POLL`] polls the store generation
//! ([`crate::store::TieredAdapters::refresh`]) to hot-load adapters a
//! sibling process publishes, without dropping a single connection.
//!
//! Load shedding is everywhere explicit: a full queue, an unresolved
//! adapter, or shutdown each produce a 503-style reply, counted into
//! [`RouterStats::shed`]/[`RouterStats::rejected`] so the fleet
//! aggregate can never claim 100% success while the front-end sheds.
//!
//! The serving budget is exact: the engine exits once `--requests`
//! *successful* replies have been sent. Sheds and rejects never consume
//! budget, and the [`soak`] client retries 503s, so a soak of N logical
//! requests against a server with budget N always terminates on both
//! sides.

use std::collections::{BTreeSet, VecDeque};
use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::queue::{AdmissionQueue, QueueConfig, Slotted};
use super::{Request, Router, RouterStats, ServeConfig, ServeCore, SERVE_TASKS};
use crate::data::{Batcher, Example, Label, Split};
use crate::experiments::ExpConfig;
use crate::obs::{self, flight, hist};
use crate::util::faults;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Longest accepted request line (native protocol) or body (HTTP shim),
/// bytes. Anything longer gets an `oversized` 413 reply — the line is
/// discarded without buffering it, so a hostile client can't balloon
/// memory.
pub const MAX_LINE: usize = 64 * 1024;

/// Reader/writer poll period: how often blocked socket IO re-checks the
/// shutdown flag.
const READ_POLL: Duration = Duration::from_millis(100);
/// Engine idle wait on the admission queue condvar.
const ENGINE_POLL: Duration = Duration::from_millis(20);
/// Store-generation poll period for adapter hot-reload.
const RELOAD_POLL: Duration = Duration::from_millis(200);
/// Socket write timeout — a client that stops reading is abandoned.
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// State shared between the acceptor, per-connection threads, and the
/// engine loop.
struct Shared {
    /// The slot-aware admission queue (see [`super::queue`]).
    queue: Mutex<AdmissionQueue<Pending>>,
    /// Signaled on every successful admission.
    work: Condvar,
    /// Set once the serving budget is met; every thread winds down.
    done: AtomicBool,
    /// Tasks with a resolved adapter — requests for other known tasks
    /// shed with `adapter_unavailable` until a hot reload registers them.
    registered: RwLock<BTreeSet<String>>,
    /// Connection id allocator (per-connection FIFO key in the queue).
    conn_ids: AtomicU64,
    /// Successful replies sent — the budget counter.
    served: AtomicUsize,
    /// 503 `queue_full` replies.
    shed_queue_full: AtomicUsize,
    /// 503 `adapter_unavailable` replies.
    shed_unavailable: AtomicUsize,
    /// 4xx protocol rejections (malformed, unknown task, oversized).
    rejected: AtomicUsize,
    /// `GET /healthz` hits.
    healthz: AtomicUsize,
    /// Vocabulary size; token ids are validated against it at admission.
    vocab: usize,
    /// Writer threads, joined at shutdown so buffered final replies are
    /// flushed before the process exits.
    writers: Mutex<Vec<JoinHandle<()>>>,
}

/// An admitted request waiting for the engine.
struct Pending {
    conn: u64,
    /// Flight-recorder trace id, assigned at admission.
    trace: u64,
    /// When admission started — the anchor for queue-wait and
    /// whole-request latency.
    admitted: Instant,
    /// The request's `id` field, echoed verbatim in the reply.
    wire_id: Json,
    task: String,
    example: Example,
    /// The owning connection's reply channel.
    reply: Sender<Reply>,
}

impl Slotted for Pending {
    fn conn(&self) -> u64 {
        self.conn
    }
    fn task(&self) -> &str {
        &self.task
    }
}

/// Reply-side bookkeeping for one in-flight batch row.
struct Replier {
    conn: u64,
    trace: u64,
    admitted: Instant,
    wire_id: Json,
    task: String,
    reply: Sender<Reply>,
}

/// One reply on its way to a connection's writer thread.
struct Reply {
    code: u16,
    body: String,
    /// Trace id for the write-stage span; 0 for untraced replies
    /// (errors, health/metrics responses).
    trace: u64,
    /// When the reply was enqueued — the write span's start.
    queued: Instant,
}

impl Reply {
    fn untraced(code: u16, body: String) -> Reply {
        Reply { code, body, trace: 0, queued: Instant::now() }
    }
}

/// Registry handles for the hot serving path, resolved once so every
/// per-request update is a single relaxed atomic op.
struct NetMetrics {
    ok: &'static obs::Counter,
    bad_request: &'static obs::Counter,
    unknown_task: &'static obs::Counter,
    not_found: &'static obs::Counter,
    oversized: &'static obs::Counter,
    queue_full: &'static obs::Counter,
    adapter_unavailable: &'static obs::Counter,
    shutting_down: &'static obs::Counter,
    internal_error: &'static obs::Counter,
    healthz: &'static obs::Counter,
    queue_depth: &'static obs::Gauge,
    reorder_pulls: &'static obs::Counter,
    queue_wait_ms: &'static obs::HistMetric,
    request_ms: &'static obs::HistMetric,
    write_ms: &'static obs::HistMetric,
}

impl NetMetrics {
    /// The `net.requests{code="…"}` counter for an error-reply name.
    fn errors(&self, error: &str) -> &'static obs::Counter {
        match error {
            "bad_request" => self.bad_request,
            "unknown_task" => self.unknown_task,
            "not_found" => self.not_found,
            "oversized" => self.oversized,
            "queue_full" => self.queue_full,
            "adapter_unavailable" => self.adapter_unavailable,
            "shutting_down" => self.shutting_down,
            _ => self.internal_error,
        }
    }
}

fn metrics() -> &'static NetMetrics {
    static M: OnceLock<NetMetrics> = OnceLock::new();
    M.get_or_init(|| NetMetrics {
        ok: obs::counter("net.requests{code=\"ok\"}"),
        bad_request: obs::counter("net.requests{code=\"bad_request\"}"),
        unknown_task: obs::counter("net.requests{code=\"unknown_task\"}"),
        not_found: obs::counter("net.requests{code=\"not_found\"}"),
        oversized: obs::counter("net.requests{code=\"oversized\"}"),
        queue_full: obs::counter("net.requests{code=\"queue_full\"}"),
        adapter_unavailable: obs::counter("net.requests{code=\"adapter_unavailable\"}"),
        shutting_down: obs::counter("net.requests{code=\"shutting_down\"}"),
        internal_error: obs::counter("net.requests{code=\"internal_error\"}"),
        healthz: obs::counter("net.healthz"),
        queue_depth: obs::gauge("queue.depth"),
        reorder_pulls: obs::counter("queue.reorder_pulls"),
        queue_wait_ms: obs::histogram("net.queue_wait_ms"),
        request_ms: obs::histogram("net.request_ms"),
        write_ms: obs::histogram("net.write_ms"),
    })
}

/// Every error reply in the front-end is built here, so this is also
/// where the per-error-code `net.requests` counters increment — one
/// site, no error path can forget its metric.
fn error_body(id: &Json, error: &str, code: u16) -> String {
    metrics().errors(error).inc();
    Json::obj(vec![
        ("id", id.clone()),
        ("error", Json::str(error)),
        ("code", Json::num(code)),
    ])
    .to_string()
}

/// Outcome of reading one line off a connection.
enum Line {
    /// A complete line (newline stripped, CR trimmed).
    Ok(String),
    /// The line exceeded [`MAX_LINE`]; its bytes were discarded.
    TooLong,
    /// Peer closed, IO error, or shutdown.
    Eof,
}

/// Read one `\n`-terminated line, capped at [`MAX_LINE`] bytes. The
/// stream has a read timeout of [`READ_POLL`], so a quiet connection
/// re-checks `done` instead of blocking shutdown forever.
fn read_line_capped(reader: &mut BufReader<TcpStream>, done: &AtomicBool) -> Line {
    let mut buf: Vec<u8> = Vec::new();
    let mut over = false;
    loop {
        let (consumed, newline) = {
            let chunk = match reader.fill_buf() {
                Ok(c) => c,
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                    if done.load(Ordering::SeqCst) {
                        return Line::Eof;
                    }
                    continue;
                }
                Err(_) => return Line::Eof,
            };
            if chunk.is_empty() {
                return Line::Eof; // peer closed
            }
            match chunk.iter().position(|&b| b == b'\n') {
                Some(i) => {
                    if !over {
                        buf.extend_from_slice(&chunk[..i]);
                    }
                    (i + 1, true)
                }
                None => {
                    if !over {
                        buf.extend_from_slice(chunk);
                    }
                    (chunk.len(), false)
                }
            }
        };
        reader.consume(consumed);
        if buf.len() > MAX_LINE {
            over = true;
            buf.clear();
        }
        if newline {
            if over {
                return Line::TooLong;
            }
            if buf.last() == Some(&b'\r') {
                buf.pop();
            }
            return Line::Ok(String::from_utf8_lossy(&buf).into_owned());
        }
    }
}

/// Parse token ids, validating each against the vocabulary (an
/// out-of-range id would index out of the embedding table).
fn tokens(v: &Json, vocab: usize) -> Option<Vec<u32>> {
    let arr = v.as_arr()?;
    let mut out = Vec::with_capacity(arr.len());
    for t in arr {
        let id = t.as_usize()?;
        if id >= vocab {
            return None;
        }
        out.push(id as u32);
    }
    Some(out)
}

fn parse_example(doc: &Json, vocab: usize) -> Option<Example> {
    let a = tokens(doc.get("a")?, vocab)?;
    let b = match doc.get("b") {
        Some(v) => tokens(v, vocab)?,
        None => Vec::new(),
    };
    let genre = match doc.get("genre") {
        Some(v) => v.as_usize()?,
        None => 0,
    };
    // The label never reaches the forward pass; a placeholder keeps the
    // wire protocol label-free.
    Some(Example { a, b, label: Label::Class(0), genre })
}

/// Validate + admit one request body. Returns an immediate error reply,
/// or `None` when the request was queued (the engine replies later).
fn admit(
    shared: &Arc<Shared>,
    conn: u64,
    text: &str,
    reply: &Sender<Reply>,
) -> Option<(u16, String)> {
    let t0 = Instant::now();
    let doc = match Json::parse(text) {
        Ok(d) => d,
        Err(_) => {
            shared.rejected.fetch_add(1, Ordering::SeqCst);
            return Some((400, error_body(&Json::Null, "bad_request", 400)));
        }
    };
    let wire_id = doc.get("id").cloned().unwrap_or(Json::Null);
    let Some(task) = doc.get("task").and_then(Json::as_str) else {
        shared.rejected.fetch_add(1, Ordering::SeqCst);
        return Some((400, error_body(&wire_id, "bad_request", 400)));
    };
    if !SERVE_TASKS.contains(&task) {
        shared.rejected.fetch_add(1, Ordering::SeqCst);
        return Some((404, error_body(&wire_id, "unknown_task", 404)));
    }
    if !shared.registered.read().expect("net: registered lock poisoned").contains(task) {
        shared.shed_unavailable.fetch_add(1, Ordering::SeqCst);
        return Some((503, error_body(&wire_id, "adapter_unavailable", 503)));
    }
    let Some(example) = parse_example(&doc, shared.vocab) else {
        shared.rejected.fetch_add(1, Ordering::SeqCst);
        return Some((400, error_body(&wire_id, "bad_request", 400)));
    };
    let trace = obs::next_trace_id();
    let pending = Pending {
        conn,
        trace,
        admitted: t0,
        wire_id: wire_id.clone(),
        task: task.to_string(),
        example,
        reply: reply.clone(),
    };
    let mut q = shared.queue.lock().expect("net: queue lock poisoned");
    // Checked under the queue lock so the shutdown drain can't miss a
    // racing admission (the drain also takes this lock).
    if shared.done.load(Ordering::SeqCst) {
        drop(q);
        shared.shed_queue_full.fetch_add(1, Ordering::SeqCst);
        return Some((503, error_body(&wire_id, "shutting_down", 503)));
    }
    // The admit span lands *before* the push: once the request is
    // visible in the queue, its timeline is already in the flight
    // recorder, so a fault dump can never show an untraced request.
    let admit_us = t0.elapsed().as_micros() as u64;
    flight::record(
        trace,
        conn,
        flight::STAGE_ADMIT,
        obs::uptime_us().saturating_sub(admit_us),
        admit_us,
    );
    match q.push(pending) {
        Ok(()) => {
            metrics().queue_depth.set(q.len() as i64);
            drop(q);
            shared.work.notify_one();
            None
        }
        Err(_) => {
            drop(q);
            shared.shed_queue_full.fetch_add(1, Ordering::SeqCst);
            Some((503, error_body(&wire_id, "queue_full", 503)))
        }
    }
}

fn http_response(code: u16, body: &str) -> String {
    let reason = match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        413 => "Payload Too Large",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    };
    format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
}

fn is_http_request_line(line: &str) -> bool {
    ["GET ", "POST ", "PUT ", "DELETE ", "HEAD "].iter().any(|m| line.starts_with(m))
}

/// Read an exact-length HTTP body, polling `done` across read timeouts.
fn read_body(reader: &mut BufReader<TcpStream>, len: usize, done: &AtomicBool) -> Option<String> {
    let mut buf = vec![0u8; len];
    let mut got = 0;
    while got < len {
        match reader.read(&mut buf[got..]) {
            Ok(0) => return None,
            Ok(n) => got += n,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if done.load(Ordering::SeqCst) {
                    return None;
                }
            }
            Err(_) => return None,
        }
    }
    Some(String::from_utf8_lossy(&buf).into_owned())
}

/// The HTTP/1.1 shim: one request per connection, `Connection: close`.
fn handle_http(
    shared: &Arc<Shared>,
    conn: u64,
    request_line: &str,
    reader: &mut BufReader<TcpStream>,
    tx: &Sender<Reply>,
) {
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let mut content_length = 0usize;
    let mut oversized_header = false;
    loop {
        match read_line_capped(reader, &shared.done) {
            Line::Eof => return,
            Line::TooLong => oversized_header = true,
            Line::Ok(h) => {
                if h.is_empty() {
                    break;
                }
                if let Some((k, v)) = h.split_once(':') {
                    if k.trim().eq_ignore_ascii_case("content-length") {
                        content_length = v.trim().parse().unwrap_or(0);
                    }
                }
            }
        }
    }
    let reply = match (method, path) {
        ("GET", "/healthz") => {
            shared.healthz.fetch_add(1, Ordering::SeqCst);
            metrics().healthz.inc();
            let depth = shared.queue.lock().expect("net: queue lock poisoned").len();
            let registered: Vec<Json> = shared
                .registered
                .read()
                .expect("net: registered lock poisoned")
                .iter()
                .map(|t| Json::str(t.as_str()))
                .collect();
            // The gauges read 0 when their subsystem hasn't registered
            // yet (or obs is off) — health stays answerable regardless.
            let body = Json::obj(vec![
                ("status", Json::str("ok")),
                ("queue_depth", Json::num(depth as f64)),
                ("served", Json::num(shared.served.load(Ordering::SeqCst) as f64)),
                ("registered", Json::Arr(registered)),
                ("bank_resident", Json::num(obs::gauge_value("bank.resident") as f64)),
                ("bank_pinned", Json::num(obs::gauge_value("bank.pinned") as f64)),
                ("store_generation", Json::num(obs::gauge_value("store.generation") as f64)),
                ("degraded", Json::num(obs::gauge_value("store.degraded") as f64)),
            ]);
            Some((200, body.to_string()))
        }
        ("GET", "/metrics") => Some((200, obs::snapshot().prometheus_text())),
        ("GET", "/metrics.json") => Some((200, obs::snapshot().to_json().to_string())),
        ("GET", "/flight") => Some((200, flight::dump_json("on-demand").to_string())),
        ("POST", "/infer") => {
            if oversized_header || content_length > MAX_LINE {
                shared.rejected.fetch_add(1, Ordering::SeqCst);
                Some((413, error_body(&Json::Null, "oversized", 413)))
            } else {
                match read_body(reader, content_length, &shared.done) {
                    Some(body) => admit(shared, conn, &body, tx),
                    None => return,
                }
            }
        }
        _ => {
            shared.rejected.fetch_add(1, Ordering::SeqCst);
            Some((404, error_body(&Json::Null, "not_found", 404)))
        }
    };
    if let Some((code, body)) = reply {
        let _ = tx.send(Reply::untraced(code, body));
    }
}

/// Writer thread: owns the connection's write half and drains the reply
/// channel, so replies flow even when the reader thread is wedged (the
/// `net.conn` hang fault) or mid-parse. Exits once the channel closes,
/// or once `done` is set and the channel is drained.
fn writer_loop(
    mut stream: TcpStream,
    conn: u64,
    rx: mpsc::Receiver<Reply>,
    http: bool,
    shared: Arc<Shared>,
) {
    let write = |stream: &mut TcpStream, code: u16, body: &str| -> bool {
        let payload = if http {
            http_response(code, body)
        } else {
            format!("{body}\n")
        };
        stream.write_all(payload.as_bytes()).is_ok() && stream.flush().is_ok()
    };
    // Write-stage span + histogram, recorded at dequeue (before the
    // bytes hit the socket) so the span is in the ring strictly before
    // the client can observe the reply.
    let note = |reply: &Reply| {
        if reply.trace != 0 {
            let wait_us = reply.queued.elapsed().as_micros() as u64;
            flight::record(
                reply.trace,
                conn,
                flight::STAGE_WRITE,
                obs::uptime_us().saturating_sub(wait_us),
                wait_us,
            );
            metrics().write_ms.record_ms(wait_us as f64 / 1e3);
        }
    };
    loop {
        match rx.recv_timeout(READ_POLL) {
            Ok(reply) => {
                note(&reply);
                if !write(&mut stream, reply.code, &reply.body) || http {
                    break; // dead peer, or HTTP's one-reply-per-connection
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if shared.done.load(Ordering::SeqCst) {
                    // Final drain: a reply sent between our timeout and
                    // this check must still reach the wire.
                    while let Ok(reply) = rx.try_recv() {
                        note(&reply);
                        if !write(&mut stream, reply.code, &reply.body) {
                            break;
                        }
                    }
                    break;
                }
            }
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    let _ = stream.shutdown(Shutdown::Both);
}

/// Per-connection reader: sniffs HTTP vs the native line protocol,
/// spawns the connection's writer, then parses + admits requests until
/// EOF or shutdown.
fn handle_conn(shared: Arc<Shared>, stream: TcpStream) {
    let conn = shared.conn_ids.fetch_add(1, Ordering::SeqCst);
    // Chaos seam. Gated to the first connection only: fault actions fire
    // on *every* call within an incarnation, and the isolation test
    // needs the later connections alive to prove one wedged reader
    // stalls nobody else.
    if conn == 0 {
        faults::hang_point("net.conn");
    }
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let Ok(write_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(stream);
    let first = read_line_capped(&mut reader, &shared.done);
    let (tx, rx) = mpsc::channel::<Reply>();
    let http = matches!(&first, Line::Ok(l) if is_http_request_line(l));
    {
        let shared2 = Arc::clone(&shared);
        let writer = std::thread::spawn(move || writer_loop(write_half, conn, rx, http, shared2));
        shared.writers.lock().expect("net: writers lock poisoned").push(writer);
    }
    if http {
        if let Line::Ok(l) = &first {
            handle_http(&shared, conn, l, &mut reader, &tx);
        }
        return; // dropping tx lets the writer exit after the last reply
    }
    let mut next = first;
    loop {
        if shared.done.load(Ordering::SeqCst) {
            return;
        }
        match next {
            Line::Eof => return,
            Line::TooLong => {
                shared.rejected.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(Reply::untraced(413, error_body(&Json::Null, "oversized", 413)));
            }
            Line::Ok(l) => {
                if !l.trim().is_empty() {
                    if let Some((code, body)) = admit(&shared, conn, &l, &tx) {
                        let _ = tx.send(Reply::untraced(code, body));
                    }
                }
            }
        }
        next = read_line_capped(&mut reader, &shared.done);
    }
}

/// Accept loop: non-blocking accepts, one detached reader thread per
/// connection. Detached on purpose — a connection wedged by the
/// `net.conn` hang fault must not block shutdown; the joined *writer*
/// threads are what guarantee final replies hit the wire.
fn acceptor(shared: Arc<Shared>, listener: TcpListener) {
    loop {
        if shared.done.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let shared2 = Arc::clone(&shared);
                std::thread::spawn(move || handle_conn(shared2, stream));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// Bind with a bounded retry on `AddrInUse`: a restarted fleet worker
/// rebinds its old port before the kernel finishes reclaiming it (std
/// exposes no `SO_REUSEADDR`).
fn bind_with_retry(addr: &str) -> anyhow::Result<TcpListener> {
    let mut last: Option<std::io::Error> = None;
    for attempt in 0..8u64 {
        match TcpListener::bind(addr) {
            Ok(l) => return Ok(l),
            Err(e) if e.kind() == ErrorKind::AddrInUse => {
                last = Some(e);
                std::thread::sleep(Duration::from_millis(250 * (attempt + 1)));
            }
            Err(e) => return Err(e.into()),
        }
    }
    Err(anyhow::anyhow!("bind {addr}: {}", last.expect("retry loop saw AddrInUse")))
}

/// Serve over a real socket until `sc.requests` successful replies have
/// been sent, then shut down gracefully (queued stragglers get explicit
/// `shutting_down` replies; writer threads are joined so every buffered
/// reply reaches the wire).
///
/// Prints `NET_LISTEN <addr> …` once the socket is bound (tests and the
/// fleet smoke parse the address — bind to port 0 for an ephemeral one)
/// and `NET_REPORT {json}` at shutdown.
pub fn serve_listen(
    core: &mut ServeCore,
    sc: &ServeConfig,
    addr: &str,
) -> anyhow::Result<RouterStats> {
    flight::install_panic_hook();
    let listener = bind_with_retry(addr)?;
    let local = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let eff_batch = if sc.max_batch == 0 {
        core.preset.batch
    } else {
        sc.max_batch.clamp(1, core.preset.batch)
    };
    println!(
        "NET_LISTEN {local} (budget {} request(s), batch ≤{eff_batch}, reorder window {}, \
         max queue depth {})",
        sc.requests, sc.reorder_window, sc.max_queue_depth
    );
    let shared = Arc::new(Shared {
        queue: Mutex::new(AdmissionQueue::new(QueueConfig {
            window: sc.reorder_window,
            max_depth: sc.max_queue_depth,
            max_distinct: sc.resident_adapters,
        })),
        work: Condvar::new(),
        done: AtomicBool::new(false),
        registered: RwLock::new(core.states.keys().cloned().collect()),
        conn_ids: AtomicU64::new(0),
        served: AtomicUsize::new(0),
        shed_queue_full: AtomicUsize::new(0),
        shed_unavailable: AtomicUsize::new(0),
        rejected: AtomicUsize::new(0),
        healthz: AtomicUsize::new(0),
        vocab: core.preset.vocab,
        writers: Mutex::new(Vec::new()),
    });

    // Split disjoint field borrows: the router holds `&core.session` for
    // the whole serve, while hot reload needs `&mut core.tiers/states`.
    let core = &mut *core;
    let session = &core.session;
    let tiers = &mut core.tiers;
    let states = &mut core.states;
    let n_classes = &mut core.n_classes;
    let layout = &core.layout;
    let batcher = Batcher::new(&core.preset, false);
    let mut router = Router::new(session, batcher, sc.max_batch, sc.resident_adapters)?;
    for (name, state) in states.iter() {
        let n = *n_classes.get(name).ok_or_else(|| {
            anyhow::anyhow!("resolved state for {name:?} has no recorded class count")
        })?;
        router.register(name, state.clone(), n)?;
    }

    let acceptor_handle = {
        let shared2 = Arc::clone(&shared);
        std::thread::spawn(move || acceptor(shared2, listener))
    };
    let faults_on = faults::active();

    let m = metrics();
    let t_start = Instant::now();
    let mut fill = vec![0usize; eff_batch + 1];
    let mut reloads = 0usize;
    let mut pulls_seen = 0usize;
    let mut last_reload = Instant::now();
    while shared.served.load(Ordering::SeqCst) < sc.requests {
        // Generation-poll adapter hot-reload: a sibling's store publish
        // swaps in mid-serve, without dropping a connection.
        if last_reload.elapsed() >= RELOAD_POLL {
            last_reload = Instant::now();
            if tiers.refresh().unwrap_or(false) {
                for t in SERVE_TASKS {
                    if states.contains_key(*t) {
                        continue;
                    }
                    let resolved =
                        tiers.resolve_disk_only(layout, t).map(|r| (r.state.clone(), r.n_classes));
                    if let Some((state, n)) = resolved {
                        router.register(t, state.clone(), n)?;
                        states.insert(t.to_string(), state);
                        n_classes.insert(t.to_string(), n);
                        shared
                            .registered
                            .write()
                            .expect("net: registered lock poisoned")
                            .insert(t.to_string());
                        reloads += 1;
                        println!("[serve]   {t}: adapter hot-loaded from store publish (live)");
                    }
                }
            }
        }
        // Chaos seams: a wedged/killed engine with live connections.
        // Fired from inside the loop, once work is actually queued, so
        // the flight-recorder dump the fault triggers holds the
        // in-flight requests' admit spans.
        if faults_on && !shared.queue.lock().map(|q| q.is_empty()).unwrap_or(true) {
            faults::hang_point("net.engine");
            faults::crash_point("net.engine");
        }
        let (batch, depth, pulls) = {
            let q = shared.queue.lock().expect("net: queue lock poisoned");
            let mut q = if q.is_empty() {
                shared.work.wait_timeout(q, ENGINE_POLL).expect("net: queue lock poisoned").0
            } else {
                q
            };
            let batch = q.pop_batch(eff_batch);
            (batch, q.len(), q.reorder_pulls())
        };
        if batch.is_empty() {
            continue;
        }
        m.queue_depth.set(depth as i64);
        m.reorder_pulls.add(pulls.saturating_sub(pulls_seen) as u64);
        pulls_seen = pulls;
        fill[batch.len()] += 1;
        let mut queue: VecDeque<Request> = VecDeque::new();
        let mut repliers: Vec<Replier> = Vec::with_capacity(batch.len());
        for (i, p) in batch.into_iter().enumerate() {
            let Pending { conn, trace, admitted, wire_id, task, example, reply } = p;
            let wait_us = admitted.elapsed().as_micros() as u64;
            flight::record(
                trace,
                conn,
                flight::STAGE_QUEUE,
                obs::uptime_us().saturating_sub(wait_us),
                wait_us,
            );
            m.queue_wait_ms.record_ms(wait_us as f64 / 1e3);
            queue.push_back(Request { id: i, task: task.clone(), example });
            repliers.push(Replier { conn, trace, admitted, wire_id, task, reply });
        }
        let t_exec = Instant::now();
        match router.serve(&mut queue) {
            Ok(results) => {
                let exec_us = t_exec.elapsed().as_micros() as u64;
                let exec_start = obs::uptime_us().saturating_sub(exec_us);
                for (req, logits) in results {
                    let r = &repliers[req.id];
                    flight::record(r.trace, r.conn, flight::STAGE_EXECUTE, exec_start, exec_us);
                    // Truncate to the task's classes: the padded lanes
                    // are −∞, which JSON cannot carry, and clients only
                    // ever see real logits.
                    let n = n_classes.get(&r.task).copied().unwrap_or(logits.len());
                    let body = Json::obj(vec![
                        ("id", r.wire_id.clone()),
                        ("task", Json::str(r.task.as_str())),
                        (
                            "logits",
                            Json::arr_num(logits[..n.min(logits.len())].iter().map(|&x| x as f64)),
                        ),
                        ("trace", Json::num(r.trace as f64)),
                    ])
                    .to_string();
                    // Count before sending: a client that has its reply
                    // in hand can scrape /metrics and see itself counted
                    // (the metrics-scrape test relies on this ordering).
                    m.ok.inc();
                    m.request_ms.record_ms(r.admitted.elapsed().as_secs_f64() * 1e3);
                    // A reply to a vanished client still consumes budget
                    // — the inference ran; anything else wedges the
                    // server on client death.
                    let _ = r.reply.send(Reply {
                        code: 200,
                        body,
                        trace: r.trace,
                        queued: Instant::now(),
                    });
                    shared.served.fetch_add(1, Ordering::SeqCst);
                }
            }
            Err(e) => {
                crate::warnln!("[serve] batch failed ({e:#}); replying internal_error");
                for r in &repliers {
                    let body = error_body(&r.wire_id, "internal_error", 500);
                    let _ = r.reply.send(Reply::untraced(500, body));
                }
            }
        }
    }

    // Budget met: stop admissions, shed stragglers explicitly, then join
    // the writers so every buffered reply is flushed.
    shared.done.store(true, Ordering::SeqCst);
    let leftovers = shared.queue.lock().expect("net: queue lock poisoned").drain();
    let drained = leftovers.len();
    for p in leftovers {
        let body = error_body(&p.wire_id, "shutting_down", 503);
        let _ = p.reply.send(Reply::untraced(503, body));
    }
    m.queue_depth.set(0);
    if acceptor_handle.join().is_err() {
        crate::warnln!("[serve] acceptor thread panicked");
    }
    let writers = std::mem::take(&mut *shared.writers.lock().expect("net: writers lock poisoned"));
    for w in writers {
        let _ = w.join();
    }

    let mut stats = std::mem::take(&mut router.stats);
    stats.shed = shared.shed_queue_full.load(Ordering::SeqCst)
        + shared.shed_unavailable.load(Ordering::SeqCst)
        + drained;
    stats.rejected = shared.rejected.load(Ordering::SeqCst);
    // Wall time of the whole socket serve, not just router CPU windows.
    stats.wall_s = t_start.elapsed().as_secs_f64();

    let batches: usize = fill.iter().skip(1).sum();
    let rows: usize = fill.iter().enumerate().map(|(n, c)| n * c).sum();
    let mean_fill = rows as f64 / batches.max(1) as f64;
    let report = Json::obj(vec![
        ("served", Json::num(shared.served.load(Ordering::SeqCst) as f64)),
        ("shed", Json::num(stats.shed as f64)),
        ("rejected", Json::num(stats.rejected as f64)),
        ("reloads", Json::num(reloads as f64)),
        ("healthz", Json::num(shared.healthz.load(Ordering::SeqCst) as f64)),
        ("batches", Json::num(batches as f64)),
        ("mean_fill", Json::num(mean_fill)),
        ("occupancy", Json::num(mean_fill / eff_batch.max(1) as f64)),
        ("batch_fill", Json::arr_usize(fill[1..].iter())),
    ]);
    let report = report.to_string();
    println!("NET_REPORT {report}");
    Ok(stats)
}

// ---------------------------------------------------------------------------
// Soak load generator (the `soak` CLI subcommand and `serve_soak` bench).
// ---------------------------------------------------------------------------

/// One pre-serialized request and where it goes.
struct Shot {
    addr: usize,
    id: usize,
    task: String,
    line: String,
}

struct LaneReport {
    ok: usize,
    sheds: usize,
    errors: usize,
    latencies_ms: Vec<f64>,
}

enum Verdict {
    Ok,
    Shed,
    Error,
}

fn classify(reply: &str, shot: &Shot) -> Verdict {
    let Ok(doc) = Json::parse(reply) else { return Verdict::Error };
    if let Some(err) = doc.get("error").and_then(Json::as_str) {
        return if err == "queue_full" || err == "adapter_unavailable" {
            Verdict::Shed
        } else {
            Verdict::Error
        };
    }
    let id_ok = doc.get("id").and_then(Json::as_usize) == Some(shot.id);
    let task_ok = doc.get("task").and_then(Json::as_str) == Some(shot.task.as_str());
    let logits_ok =
        doc.get("logits").and_then(Json::as_arr).map(|a| !a.is_empty()).unwrap_or(false);
    if id_ok && task_ok && logits_ok {
        Verdict::Ok
    } else {
        Verdict::Error
    }
}

struct LaneConn {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

fn connect_with_retry(addr: &str) -> Option<LaneConn> {
    // Generous deadline: the server trains adapters before it binds when
    // the store is cold.
    let deadline = Instant::now() + Duration::from_secs(180);
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => {
                let _ = s.set_read_timeout(Some(Duration::from_secs(60)));
                let reader = BufReader::new(s.try_clone().ok()?);
                return Some(LaneConn { stream: s, reader });
            }
            Err(_) if Instant::now() < deadline => {
                std::thread::sleep(Duration::from_millis(100));
            }
            Err(_) => return None,
        }
    }
}

fn exchange(conn: &mut LaneConn, line: &str) -> Option<String> {
    conn.stream.write_all(line.as_bytes()).ok()?;
    conn.stream.write_all(b"\n").ok()?;
    conn.stream.flush().ok()?;
    let mut reply = String::new();
    match conn.reader.read_line(&mut reply) {
        Ok(0) | Err(_) => None,
        Ok(_) => Some(reply.trim_end().to_string()),
    }
}

/// Drive one connection's shots in order, retrying sheds (503s) with a
/// short backoff — a shed is flow control, not failure; only protocol
/// violations count as errors.
fn run_lane(addr: &str, shots: Vec<Shot>) -> LaneReport {
    let mut report = LaneReport { ok: 0, sheds: 0, errors: 0, latencies_ms: Vec::new() };
    if shots.is_empty() {
        return report;
    }
    let Some(mut conn) = connect_with_retry(addr) else {
        report.errors += shots.len();
        return report;
    };
    for shot in &shots {
        let mut tries = 0usize;
        loop {
            let t0 = Instant::now();
            let reply = match exchange(&mut conn, &shot.line) {
                Some(r) => r,
                None => {
                    // One reconnect, then give up on this shot: the
                    // server may have been restarted under chaos.
                    let Some(fresh) = connect_with_retry(addr) else {
                        report.errors += 1;
                        break;
                    };
                    conn = fresh;
                    match exchange(&mut conn, &shot.line) {
                        Some(r) => r,
                        None => {
                            report.errors += 1;
                            break;
                        }
                    }
                }
            };
            match classify(&reply, shot) {
                Verdict::Ok => {
                    report.ok += 1;
                    report.latencies_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                    break;
                }
                Verdict::Shed => {
                    report.sheds += 1;
                    tries += 1;
                    if tries > 4000 {
                        report.errors += 1;
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(25));
                }
                Verdict::Error => {
                    report.errors += 1;
                    break;
                }
            }
        }
    }
    report
}

/// The soak load generator: sends exactly `requests` logical requests
/// round-robin across `addrs` over `concurrency` persistent connections,
/// retries sheds, and aggregates p50/p99/p999 latency, shed/error
/// counts, RPS, and a fixed-bucket latency histogram into one JSON
/// report.
///
/// Shot `i` goes to `addrs[i % addrs.len()]` — the exact split the fleet
/// supervisor uses to hand out per-worker budgets, so every worker's
/// budget is met and both sides terminate.
pub fn soak(
    cfg: &ExpConfig,
    addrs: &[String],
    requests: usize,
    concurrency: usize,
) -> anyhow::Result<Json> {
    anyhow::ensure!(!addrs.is_empty(), "soak: no --connect addresses");
    let mut pipe = crate::experiments::Pipeline::new(cfg)?;
    let mut rng = Rng::new(cfg.seed ^ 0x50AC);
    let mut shots: Vec<Shot> = Vec::with_capacity(requests);
    for id in 0..requests {
        let tname = *rng.choice(SERVE_TASKS);
        let data = pipe.data(tname)?;
        let ex = data.split(Split::Dev)[rng.below(data.dev.len())].clone();
        let line = Json::obj(vec![
            ("id", Json::num(id as f64)),
            ("task", Json::str(tname)),
            ("a", Json::arr_num(ex.a.iter().map(|&t| f64::from(t)))),
            ("b", Json::arr_num(ex.b.iter().map(|&t| f64::from(t)))),
            ("genre", Json::num(ex.genre as f64)),
        ])
        .to_string();
        shots.push(Shot { addr: id % addrs.len(), id, task: tname.to_string(), line });
    }
    // Lanes: `concurrency` persistent connections split evenly across
    // addresses; a shot stays on one lane so per-connection FIFO holds.
    let lanes = (concurrency / addrs.len()).max(1);
    let mut per_lane: Vec<Vec<Shot>> = (0..addrs.len() * lanes).map(|_| Vec::new()).collect();
    for (i, shot) in shots.into_iter().enumerate() {
        let lane = (i / addrs.len()) % lanes;
        per_lane[shot.addr * lanes + lane].push(shot);
    }
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for (li, lane_shots) in per_lane.into_iter().enumerate() {
        let addr = addrs[li / lanes].clone();
        handles.push(std::thread::spawn(move || run_lane(&addr, lane_shots)));
    }
    let (mut ok, mut sheds, mut errors) = (0usize, 0usize, 0usize);
    let mut lat: Vec<f64> = Vec::new();
    for h in handles {
        match h.join() {
            Ok(r) => {
                ok += r.ok;
                sheds += r.sheds;
                errors += r.errors;
                lat.extend(r.latencies_ms);
            }
            Err(_) => errors += 1,
        }
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    lat.sort_by(|a, b| a.total_cmp(b));
    // The shared fixed-bucket layout (obs::hist) — identical bounds on
    // the client and the server side of every measurement, so this
    // histogram merges losslessly with the `/metrics` ones.
    let mut h = hist::Hist::new();
    for &ms in &lat {
        h.record(ms);
    }
    let hist_total = h.total() as usize;
    anyhow::ensure!(
        hist_total == ok,
        "soak: latency histogram lost samples ({hist_total} of {ok})"
    );
    let rps = if wall_ms > 0.0 { ok as f64 / (wall_ms / 1e3) } else { 0.0 };
    Ok(Json::obj(vec![
        ("requests", Json::num(requests as f64)),
        ("ok", Json::num(ok as f64)),
        ("sheds", Json::num(sheds as f64)),
        ("protocol_errors", Json::num(errors as f64)),
        ("wall_ms", Json::num(wall_ms)),
        ("rps", Json::num(rps)),
        ("p50_ms", Json::num(hist::percentile(&lat, 0.50))),
        ("p99_ms", Json::num(hist::percentile(&lat, 0.99))),
        ("p999_ms", Json::num(hist::percentile(&lat, 0.999))),
        ("hist_bounds_ms", Json::arr_num(hist::BOUNDS_MS.iter().copied())),
        ("hist", Json::arr_num(h.counts.iter().map(|&c| c as f64))),
        ("addrs", Json::Arr(addrs.iter().map(|a| Json::str(a.as_str())).collect())),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn http_response_carries_length_and_reason() {
        let r = http_response(503, "{\"x\":1}");
        assert!(r.starts_with("HTTP/1.1 503 Service Unavailable\r\n"), "{r}");
        assert!(r.contains("Content-Length: 7\r\n"), "{r}");
        assert!(r.ends_with("\r\n\r\n{\"x\":1}"), "{r}");
    }

    #[test]
    fn http_sniff_matches_methods_only() {
        assert!(is_http_request_line("GET /healthz HTTP/1.1"));
        assert!(is_http_request_line("POST /infer HTTP/1.1"));
        assert!(!is_http_request_line("{\"id\": 1}"));
        assert!(!is_http_request_line("GETAWAY"));
    }

    #[test]
    fn error_body_echoes_wire_id() {
        let b = error_body(&Json::num(7.0), "queue_full", 503);
        let doc = Json::parse(&b).unwrap();
        assert_eq!(doc.get("id").unwrap().as_usize(), Some(7));
        assert_eq!(doc.get("error").unwrap().as_str(), Some("queue_full"));
        assert_eq!(doc.get("code").unwrap().as_usize(), Some(503));
    }

    #[test]
    fn parse_example_validates_tokens_against_vocab() {
        let ok = Json::parse(r#"{"task":"sst2","a":[1,2],"b":[3],"genre":1}"#).unwrap();
        let ex = parse_example(&ok, 10).unwrap();
        assert_eq!((ex.a, ex.b, ex.genre), (vec![1, 2], vec![3], 1));
        let oob = Json::parse(r#"{"task":"sst2","a":[99]}"#).unwrap();
        assert!(parse_example(&oob, 10).is_none(), "token ≥ vocab must be rejected");
        let missing = Json::parse(r#"{"task":"sst2"}"#).unwrap();
        assert!(parse_example(&missing, 10).is_none(), "missing 'a' must be rejected");
        let bad = Json::parse(r#"{"task":"sst2","a":[-1]}"#).unwrap();
        assert!(parse_example(&bad, 10).is_none(), "negative token must be rejected");
    }

    #[test]
    fn classify_discriminates_ok_shed_error() {
        let shot = Shot { addr: 0, id: 3, task: "sst2".into(), line: String::new() };
        let ok = r#"{"id":3,"task":"sst2","logits":[0.5,-0.5]}"#;
        assert!(matches!(classify(ok, &shot), Verdict::Ok));
        let shed = r#"{"id":3,"error":"queue_full","code":503}"#;
        assert!(matches!(classify(shed, &shot), Verdict::Shed));
        let stale = r#"{"id":4,"task":"sst2","logits":[0.5]}"#;
        assert!(matches!(classify(stale, &shot), Verdict::Error), "wrong id is a protocol error");
        assert!(matches!(classify("garbage", &shot), Verdict::Error));
    }
}
