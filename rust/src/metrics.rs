//! Evaluation metrics matching the GLUE per-task conventions:
//! accuracy (MNLI, SST-2, QNLI, RTE), accuracy + F1 (MRPC, QQP),
//! Matthews correlation (CoLA), Pearson/Spearman (STS-B).

/// Binary/multiclass accuracy.
pub fn accuracy(preds: &[usize], labels: &[usize]) -> f64 {
    assert_eq!(preds.len(), labels.len());
    if preds.is_empty() {
        return 0.0;
    }
    let hit = preds.iter().zip(labels).filter(|(p, l)| p == l).count();
    hit as f64 / preds.len() as f64
}

/// F1 of the positive class (label 1), GLUE's convention for MRPC/QQP.
pub fn f1_binary(preds: &[usize], labels: &[usize]) -> f64 {
    assert_eq!(preds.len(), labels.len());
    let mut tp = 0f64;
    let mut fp = 0f64;
    let mut fne = 0f64;
    for (&p, &l) in preds.iter().zip(labels) {
        match (p, l) {
            (1, 1) => tp += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fne += 1.0,
            _ => {}
        }
    }
    if tp == 0.0 {
        return 0.0;
    }
    let precision = tp / (tp + fp);
    let recall = tp / (tp + fne);
    2.0 * precision * recall / (precision + recall)
}

/// Matthews correlation coefficient (CoLA).
pub fn matthews_corr(preds: &[usize], labels: &[usize]) -> f64 {
    assert_eq!(preds.len(), labels.len());
    let (mut tp, mut tn, mut fp, mut fne) = (0f64, 0f64, 0f64, 0f64);
    for (&p, &l) in preds.iter().zip(labels) {
        match (p, l) {
            (1, 1) => tp += 1.0,
            (0, 0) => tn += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fne += 1.0,
            _ => {}
        }
    }
    let denom = ((tp + fp) * (tp + fne) * (tn + fp) * (tn + fne)).sqrt();
    if denom == 0.0 {
        0.0
    } else {
        (tp * tn - fp * fne) / denom
    }
}

/// Pearson correlation (STS-B).
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if x.is_empty() {
        return 0.0;
    }
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (&a, &b) in x.iter().zip(y) {
        sxy += (a - mx) * (b - my);
        sxx += (a - mx) * (a - mx);
        syy += (b - my) * (b - my);
    }
    if sxx == 0.0 || syy == 0.0 {
        0.0
    } else {
        sxy / (sxx * syy).sqrt()
    }
}

/// Ranks with average ties (helper for Spearman).
fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut out = vec![0.0; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            out[idx[k]] = avg;
        }
        i = j + 1;
    }
    out
}

/// Spearman rank correlation (STS-B).
pub fn spearman(x: &[f64], y: &[f64]) -> f64 {
    pearson(&ranks(x), &ranks(y))
}

/// Argmax over a logits row.
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

/// The per-task headline metric, as GLUE reports it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MetricKind {
    Accuracy,
    AccuracyAndF1,
    Matthews,
    PearsonSpearman,
}

/// Aggregated evaluation result.
#[derive(Clone, Debug, Default)]
pub struct EvalResult {
    pub accuracy: f64,
    pub f1: f64,
    pub matthews: f64,
    pub pearson: f64,
    pub spearman: f64,
    pub n: usize,
}

impl EvalResult {
    /// Classification eval from (logits rows, labels).
    pub fn classification(preds: &[usize], labels: &[usize]) -> EvalResult {
        EvalResult {
            accuracy: accuracy(preds, labels),
            f1: f1_binary(preds, labels),
            matthews: matthews_corr(preds, labels),
            n: preds.len(),
            ..Default::default()
        }
    }

    /// Regression eval from (predictions, targets).
    pub fn regression(preds: &[f64], targets: &[f64]) -> EvalResult {
        EvalResult {
            pearson: pearson(preds, targets),
            spearman: spearman(preds, targets),
            n: preds.len(),
            ..Default::default()
        }
    }

    /// The headline number for a metric kind, in percent.
    pub fn headline(&self, kind: MetricKind) -> f64 {
        100.0
            * match kind {
                MetricKind::Accuracy => self.accuracy,
                MetricKind::AccuracyAndF1 => self.accuracy,
                MetricKind::Matthews => self.matthews,
                MetricKind::PearsonSpearman => self.pearson,
            }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[0, 1, 2, 1], &[0, 1, 1, 1]), 0.75);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn f1_known_value() {
        // tp=2, fp=1, fn=1 → p=2/3, r=2/3, f1=2/3
        let preds = [1, 1, 1, 0, 0];
        let labels = [1, 1, 0, 1, 0];
        assert!((f1_binary(&preds, &labels) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn f1_degenerate() {
        assert_eq!(f1_binary(&[0, 0], &[1, 1]), 0.0);
        assert_eq!(f1_binary(&[1, 1], &[1, 1]), 1.0);
    }

    #[test]
    fn matthews_perfect_and_inverse() {
        let y = [0, 1, 0, 1, 1, 0];
        assert!((matthews_corr(&y, &y) - 1.0).abs() < 1e-12);
        let inv: Vec<usize> = y.iter().map(|&v| 1 - v).collect();
        assert!((matthews_corr(&inv, &y) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn matthews_uninformative_is_zero() {
        assert_eq!(matthews_corr(&[1, 1, 1, 1], &[0, 1, 0, 1]), 0.0);
    }

    #[test]
    fn pearson_linear() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let z = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &z) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y = [1.0, 8.0, 27.0, 64.0, 125.0]; // monotone → ρ=1
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_ties() {
        let x = [1.0, 2.0, 2.0, 3.0];
        let y = [1.0, 2.0, 2.0, 3.0];
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn argmax_rows() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[-1.0, -2.0]), 0);
    }

    #[test]
    fn eval_result_headline() {
        let r = EvalResult {
            accuracy: 0.9,
            f1: 0.8,
            matthews: 0.5,
            pearson: 0.7,
            spearman: 0.6,
            n: 10,
        };
        assert_eq!(r.headline(MetricKind::Accuracy), 90.0);
        assert_eq!(r.headline(MetricKind::Matthews), 50.0);
        assert_eq!(r.headline(MetricKind::PearsonSpearman), 70.0);
    }
}
