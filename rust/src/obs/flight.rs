//! The crash flight recorder: a lock-free, fixed-capacity,
//! overwrite-oldest ring of per-request span records.
//!
//! Every request admitted by the socket front-end gets a trace id
//! ([`crate::obs::next_trace_id`]) and leaves one span per stage it
//! crosses:
//!
//! ```text
//! admit ──▶ queue ──▶ execute ──▶ write
//! (reader    (engine    (router     (writer thread,
//!  thread)    pop)       batch)      before the bytes hit the wire)
//! ```
//!
//! Background work (store loads, train-on-miss) records spans with
//! trace 0. Writers claim a slot with one `fetch_add` on the ring
//! cursor, mark it in-progress, fill the fields with relaxed stores, and
//! publish with a release store of the final sequence number; readers
//! skip in-progress and empty slots, so a torn read is impossible and
//! recording never blocks a request.
//!
//! The ring holds the last [`CAPACITY`] spans — enough to reconstruct
//! what every in-flight request was doing when something died. It dumps
//! as `FLIGHT {json}` JSONL lines to stderr (between `FLIGHT_BEGIN` /
//! `FLIGHT_END` markers) on three triggers:
//!
//! * **panic** — [`install_panic_hook`] wraps the previous hook;
//! * **injected fault fire** — `util/faults.rs` dumps before a
//!   `crash`/`hang` action, so every chaos kill leaves a timeline;
//! * **on demand** — `GET /flight` on the HTTP shim returns the same
//!   spans as a JSON document.
//!
//! Recording is gated on [`crate::obs::enabled`]; dumping is not (an
//! obs-off process dumps an empty ring, loudly, rather than nothing).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Once, OnceLock};

use crate::util::json::Json;

/// Ring capacity in spans. At four spans per request this covers the
/// last ~1k requests — far past any in-flight set.
pub const CAPACITY: usize = 4096;

/// Span stage names, indexed by the `STAGE_*` constants.
pub const STAGES: &[&str] = &["admit", "queue", "execute", "write", "store.load", "store.train"];

pub const STAGE_ADMIT: usize = 0;
pub const STAGE_QUEUE: usize = 1;
pub const STAGE_EXECUTE: usize = 2;
pub const STAGE_WRITE: usize = 3;
pub const STAGE_STORE_LOAD: usize = 4;
pub const STAGE_STORE_TRAIN: usize = 5;

/// Slot sequence value marking "a writer is mid-publish".
const IN_PROGRESS: u64 = u64::MAX;

/// One ring slot. `seq` is the publication gate: 0 = never written,
/// [`IN_PROGRESS`] = being written, anything else = the (1-based) global
/// sequence number of a complete record.
struct Slot {
    seq: AtomicU64,
    trace: AtomicU64,
    conn: AtomicU64,
    stage: AtomicU64,
    start_us: AtomicU64,
    dur_us: AtomicU64,
}

struct Ring {
    cursor: AtomicU64,
    slots: Vec<Slot>,
}

fn ring() -> &'static Ring {
    static RING: OnceLock<Ring> = OnceLock::new();
    RING.get_or_init(|| Ring {
        cursor: AtomicU64::new(0),
        slots: (0..CAPACITY)
            .map(|_| Slot {
                seq: AtomicU64::new(0),
                trace: AtomicU64::new(0),
                conn: AtomicU64::new(0),
                stage: AtomicU64::new(0),
                start_us: AtomicU64::new(0),
                dur_us: AtomicU64::new(0),
            })
            .collect(),
    })
}

/// Record one span. Lock-free: claim a sequence number, mark the slot
/// in-progress, fill, publish with a release store. Overwrites the
/// oldest record once the ring is full. No-op when obs is disabled.
pub fn record(trace: u64, conn: u64, stage: usize, start_us: u64, dur_us: u64) {
    if !crate::obs::enabled() {
        return;
    }
    let r = ring();
    let seq = r.cursor.fetch_add(1, Ordering::Relaxed) + 1;
    let slot = &r.slots[(seq - 1) as usize % CAPACITY];
    slot.seq.store(IN_PROGRESS, Ordering::Release);
    slot.trace.store(trace, Ordering::Relaxed);
    slot.conn.store(conn, Ordering::Relaxed);
    slot.stage.store(stage as u64, Ordering::Relaxed);
    slot.start_us.store(start_us, Ordering::Relaxed);
    slot.dur_us.store(dur_us, Ordering::Relaxed);
    slot.seq.store(seq, Ordering::Release);
}

/// Snapshot every complete record, oldest first. Skips empty and
/// in-progress slots; a slot overwritten mid-read shows up as whichever
/// complete record won — never a torn mix (the fields are re-checked
/// against an unchanged `seq`).
pub fn spans() -> Vec<Json> {
    let r = ring();
    let mut out: Vec<(u64, Json)> = Vec::new();
    for slot in &r.slots {
        let seq = slot.seq.load(Ordering::Acquire);
        if seq == 0 || seq == IN_PROGRESS {
            continue;
        }
        let doc = Json::obj(vec![
            ("seq", Json::num(seq as f64)),
            ("trace", Json::num(slot.trace.load(Ordering::Relaxed) as f64)),
            ("conn", Json::num(slot.conn.load(Ordering::Relaxed) as f64)),
            (
                "stage",
                Json::str(
                    STAGES.get(slot.stage.load(Ordering::Relaxed) as usize).copied().unwrap_or("?"),
                ),
            ),
            ("start_us", Json::num(slot.start_us.load(Ordering::Relaxed) as f64)),
            ("dur_us", Json::num(slot.dur_us.load(Ordering::Relaxed) as f64)),
        ]);
        if slot.seq.load(Ordering::Acquire) == seq {
            out.push((seq, doc));
        }
    }
    out.sort_by_key(|(seq, _)| *seq);
    out.into_iter().map(|(_, doc)| doc).collect()
}

/// Dump the ring as JSONL to stderr: one `FLIGHT {json}` line per span
/// between `FLIGHT_BEGIN`/`FLIGHT_END` markers. Called on panic, on an
/// injected-fault fire, and never blocks recording.
pub fn dump_stderr(reason: &str) {
    let spans = spans();
    eprintln!("FLIGHT_BEGIN reason={reason} spans={}", spans.len());
    for s in &spans {
        eprintln!("FLIGHT {}", s.to_string());
    }
    eprintln!("FLIGHT_END reason={reason}");
}

/// The on-demand (`GET /flight`) form: the same spans as one JSON
/// document.
pub fn dump_json(reason: &str) -> Json {
    Json::obj(vec![("reason", Json::str(reason)), ("spans", Json::Arr(spans()))])
}

/// Install a panic hook that dumps the ring before delegating to the
/// previous hook. Idempotent (`Once`); called from the serve paths.
pub fn install_panic_hook() {
    static INSTALL: Once = Once::new();
    INSTALL.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            dump_stderr("panic");
            prev(info);
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Trace ids in a range no other test uses, so the shared global
    /// ring can be filtered per test.
    fn mine(spans: &[Json], base: u64, n: u64) -> Vec<Json> {
        spans
            .iter()
            .filter(|s| {
                s.get("trace")
                    .and_then(Json::as_f64)
                    .map(|t| (t as u64) >= base && (t as u64) < base + n)
                    .unwrap_or(false)
            })
            .cloned()
            .collect()
    }

    #[test]
    fn records_publish_in_sequence_order() {
        let base = 0xF100_0000u64;
        for i in 0..4 {
            record(base + i, 7, STAGE_QUEUE, 100 + i, 10);
        }
        let got = mine(&spans(), base, 4);
        assert_eq!(got.len(), 4);
        let seqs: Vec<u64> =
            got.iter().map(|s| s.get("seq").and_then(Json::as_f64).unwrap() as u64).collect();
        assert!(seqs.windows(2).all(|w| w[0] < w[1]), "spans must come back oldest-first");
        assert_eq!(got[0].get("stage").and_then(Json::as_str), Some("queue"));
        assert_eq!(got[0].get("conn").and_then(Json::as_usize), Some(7));
    }

    #[test]
    fn ring_overwrites_oldest_past_capacity() {
        let base = 0xF200_0000u64;
        let n = (CAPACITY + 16) as u64;
        for i in 0..n {
            record(base + i, 0, STAGE_EXECUTE, i, 1);
        }
        let got = mine(&spans(), base, n);
        // Other tests share the ring, so some of our spans may have been
        // overwritten too — but the *early* ones must be gone and the
        // *latest* must survive.
        assert!(got.len() <= CAPACITY, "ring must stay bounded");
        let traces: Vec<u64> =
            got.iter().map(|s| s.get("trace").and_then(Json::as_f64).unwrap() as u64).collect();
        assert!(!traces.contains(&base), "the oldest record must be overwritten");
        assert!(traces.contains(&(base + n - 1)), "the newest record must survive");
    }

    #[test]
    fn dump_json_carries_reason_and_spans() {
        let base = 0xF300_0000u64;
        record(base, 1, STAGE_WRITE, 5, 2);
        let doc = dump_json("test");
        assert_eq!(doc.get("reason").and_then(Json::as_str), Some("test"));
        let spans = doc.get("spans").and_then(Json::as_arr).unwrap();
        assert!(!mine(spans, base, 1).is_empty(), "the recorded span must be in the dump");
        // The JSONL stderr form shares the same span serialization.
        dump_stderr("test");
    }
}
