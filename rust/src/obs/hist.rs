//! The one latency-histogram layout for the whole tree: fixed bucket
//! bounds, the nearest-rank percentile, and a mergeable owned histogram.
//!
//! Everything that measures latency — the `soak` client report, the
//! server-side `net.*_ms` registry histograms, the `/metrics` export, and
//! the fleet aggregator — shares [`BOUNDS_MS`]. Fixed (not
//! data-dependent) bounds are what make histograms from different runs,
//! workers, and processes directly mergeable: merging is an elementwise
//! bucket-count sum ([`Hist::merge`]), with no re-binning and no loss.
//! This machinery started life private to `server/net.rs`; it moved here
//! so the bucket layout can never fork between the client and the server
//! side of a measurement.

use crate::util::json::Json;

/// Upper bounds (ms) of the fixed latency-histogram buckets; one final
/// unbounded bucket follows.
pub const BOUNDS_MS: &[f64] = &[
    0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0, 2048.0, 4096.0,
];

/// Total bucket count: every bound's `≤` bucket plus the unbounded tail.
pub const BUCKETS: usize = BOUNDS_MS.len() + 1;

/// The bucket index a sample in milliseconds falls into.
pub fn bucket(ms: f64) -> usize {
    BOUNDS_MS.iter().position(|&ub| ms <= ub).unwrap_or(BOUNDS_MS.len())
}

/// Nearest-rank percentile over an ascending-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 * q).ceil() as usize).saturating_sub(1);
    sorted[idx.min(sorted.len() - 1)]
}

/// An owned fixed-bucket histogram — the mergeable snapshot form of a
/// registry [`crate::obs::HistMetric`], and what the fleet aggregator
/// folds worker reports into.
#[derive(Debug, Clone, PartialEq)]
pub struct Hist {
    /// Per-bucket sample counts, `BUCKETS` long.
    pub counts: Vec<u64>,
    /// Sum of all recorded samples, ms (for mean-latency derivation).
    pub sum_ms: f64,
}

impl Default for Hist {
    fn default() -> Hist {
        Hist::new()
    }
}

impl Hist {
    pub fn new() -> Hist {
        Hist { counts: vec![0; BUCKETS], sum_ms: 0.0 }
    }

    pub fn record(&mut self, ms: f64) {
        self.counts[bucket(ms)] += 1;
        self.sum_ms += ms;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.total() == 0
    }

    /// Fold another histogram in: elementwise bucket-count sum. Sound
    /// because every histogram in the tree shares [`BOUNDS_MS`].
    pub fn merge(&mut self, other: &Hist) {
        for (mine, theirs) in self.counts.iter_mut().zip(&other.counts) {
            *mine += theirs;
        }
        self.sum_ms += other.sum_ms;
    }

    /// Nearest-rank quantile from bucket counts: the upper bound of the
    /// bucket holding the target rank. Approximate by construction (a
    /// bucket only knows its bound, not its samples); the unbounded tail
    /// reports twice the last bound. 0.0 on an empty histogram.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        let total = self.total();
        if total == 0 {
            return 0.0;
        }
        let target = ((total as f64 * q).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return BOUNDS_MS.get(i).copied().unwrap_or(BOUNDS_MS[BOUNDS_MS.len() - 1] * 2.0);
            }
        }
        BOUNDS_MS[BOUNDS_MS.len() - 1] * 2.0
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.total() as f64)),
            ("sum_ms", Json::num(self.sum_ms)),
            ("p50_ms", Json::num(self.quantile_ms(0.50))),
            ("p99_ms", Json::num(self.quantile_ms(0.99))),
            ("buckets", Json::arr_num(self.counts.iter().map(|&c| c as f64))),
        ])
    }

    /// Tolerant parse of [`Hist::to_json`] output: an absent or
    /// wrong-shape document is `None`, and a `buckets` array shorter than
    /// [`BUCKETS`] (an older binary with fewer bounds) zero-extends —
    /// never a hard error, so a fleet of mixed binaries still aggregates.
    pub fn from_json(doc: &Json) -> Option<Hist> {
        let buckets = doc.get("buckets")?.as_arr()?;
        if buckets.len() > BUCKETS {
            return None;
        }
        let mut h = Hist::new();
        for (i, b) in buckets.iter().enumerate() {
            h.counts[i] = b.as_f64()? as u64;
        }
        h.sum_ms = doc.get("sum_ms").and_then(Json::as_f64).unwrap_or(0.0);
        Some(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_is_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(f64::from).collect();
        assert_eq!(percentile(&xs, 0.50), 50.0);
        assert_eq!(percentile(&xs, 0.99), 99.0);
        assert_eq!(percentile(&xs, 0.999), 100.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn bucket_assignment_matches_bounds() {
        assert_eq!(bucket(0.0), 0);
        assert_eq!(bucket(0.25), 0, "bounds are inclusive upper bounds");
        assert_eq!(bucket(0.26), 1);
        assert_eq!(bucket(4096.0), BOUNDS_MS.len() - 1);
        assert_eq!(bucket(1e9), BOUNDS_MS.len(), "overflow lands in the unbounded tail");
    }

    #[test]
    fn merge_is_elementwise_and_lossless() {
        let mut a = Hist::new();
        let mut b = Hist::new();
        for ms in [0.1, 3.0, 50.0, 5000.0] {
            a.record(ms);
        }
        for ms in [0.2, 3.5, 9999.0] {
            b.record(ms);
        }
        let (ta, tb) = (a.total(), b.total());
        a.merge(&b);
        assert_eq!(a.total(), ta + tb, "merge must not lose samples");
        assert_eq!(a.counts[bucket(3.0)], 2, "both ≤4 ms samples share a bucket");
        assert_eq!(a.counts[BOUNDS_MS.len()], 2, "both overflow samples share the tail");
        assert!((a.sum_ms - (0.1 + 3.0 + 50.0 + 5000.0 + 0.2 + 3.5 + 9999.0)).abs() < 1e-9);
    }

    #[test]
    fn quantile_reports_bucket_upper_bounds() {
        let mut h = Hist::new();
        for _ in 0..99 {
            h.record(1.5); // bucket ≤2 ms
        }
        h.record(100.0); // bucket ≤128 ms
        assert_eq!(h.quantile_ms(0.50), 2.0);
        assert_eq!(h.quantile_ms(0.99), 2.0);
        assert_eq!(h.quantile_ms(1.0), 128.0);
        assert_eq!(Hist::new().quantile_ms(0.5), 0.0, "empty histogram quantile is 0");
    }

    #[test]
    fn json_round_trip_and_tolerant_parse() {
        let mut h = Hist::new();
        h.record(0.4);
        h.record(77.0);
        let back = Hist::from_json(&h.to_json()).unwrap();
        assert_eq!(back, h);
        // Older binaries: shorter bucket arrays zero-extend, absent
        // fields parse as zero, wrong shapes are None — never a panic.
        let short = Json::parse(r#"{"buckets": [1, 2]}"#).unwrap();
        let parsed = Hist::from_json(&short).unwrap();
        assert_eq!(parsed.counts[..2], [1, 2]);
        assert_eq!(parsed.total(), 3);
        assert_eq!(parsed.sum_ms, 0.0);
        assert!(Hist::from_json(&Json::Null).is_none());
        assert!(Hist::from_json(&Json::parse("{}").unwrap()).is_none());
    }
}
