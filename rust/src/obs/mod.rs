//! Dependency-free observability: a process-global metrics registry, a
//! request-trace flight recorder, and export surfaces — std-only (no
//! tokio, no prometheus crate), in the `util/pool.rs` house style.
//!
//! ## Three layers
//!
//! * **Metrics registry** (this module): named counters, gauges, and
//!   fixed-bucket latency histograms, registered once and then updated
//!   with relaxed atomic ops. Names follow `subsystem.metric{label}`
//!   (e.g. `net.requests{code="ok"}`, `store.disk_hits`); the label part
//!   is free-form and carried verbatim into both export formats. Hot
//!   paths hold `&'static` handles (leaked once at registration) so a
//!   metric update is one branch + one relaxed atomic — no lock, no hash.
//! * **Flight recorder** ([`flight`]): a lock-free overwrite-oldest ring
//!   of per-request span records (stage, start, duration), dumped as
//!   JSONL to stderr on panic, on an injected-fault fire, and on demand
//!   (`GET /flight`). See the module docs for the span lifecycle.
//! * **Histogram plumbing** ([`hist`]): the one fixed bucket layout every
//!   latency histogram in the tree shares, so client reports, server
//!   registries, and fleet aggregates merge losslessly.
//!
//! ## On/off switch
//!
//! `QRLORA_OBS=0` (or `off`/`false`) disables every mutation: updates
//! early-return before touching an atomic, span records are dropped, and
//! snapshots come back zeroed. The default is **on** — the registry is
//! cheap enough to leave enabled (the `serve_soak … [obs-off]` bench twin
//! holds the contract at <2% throughput overhead). Export never turns
//! off: `/metrics` and `--metrics-json` always answer, with zeros.
//!
//! ## Export
//!
//! [`snapshot`] freezes the registry into a [`Snapshot`]:
//! [`Snapshot::to_json`] is the `GET /metrics.json` body, the
//! `--metrics-json` file, and the `FLEET_WORKER` `metrics` field;
//! [`Snapshot::prometheus_text`] is the `GET /metrics` body (`qrlora_`
//! prefix, dots → underscores, histograms in cumulative
//! `_bucket{le=…}`/`_sum`/`_count` form).

pub mod flight;
pub mod hist;

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Whether metric mutation is enabled (`QRLORA_OBS`, default on).
/// Read once; flipping the env mid-process has no effect.
pub fn enabled() -> bool {
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| {
        !matches!(
            std::env::var("QRLORA_OBS").unwrap_or_default().to_ascii_lowercase().as_str(),
            "0" | "off" | "false"
        )
    })
}

fn base_instant() -> Instant {
    static BASE: OnceLock<Instant> = OnceLock::new();
    *BASE.get_or_init(Instant::now)
}

/// Microseconds since this process first touched the observability layer
/// — the shared monotonic clock for span timestamps and log lines.
pub fn uptime_us() -> u64 {
    base_instant().elapsed().as_micros() as u64
}

/// [`uptime_us`] in milliseconds (log-line resolution).
pub fn uptime_ms() -> u64 {
    base_instant().elapsed().as_millis() as u64
}

/// Allocate the next request trace id (process-unique, never 0 — 0 marks
/// "no trace": background work and error replies).
pub fn next_trace_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// A monotonically increasing count. Updates are relaxed: totals are
/// exact (atomic add), only cross-metric ordering is unspecified.
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        if enabled() {
            self.0.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A settable instantaneous value (queue depth, resident adapters,
/// degraded flag).
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        if enabled() {
            self.0.store(v, Ordering::Relaxed);
        }
    }

    pub fn add(&self, d: i64) {
        if enabled() {
            self.0.fetch_add(d, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A fixed-bucket latency histogram over [`hist::BOUNDS_MS`]. Recording
/// is two relaxed atomic adds; snapshots are mergeable [`hist::Hist`]s.
pub struct HistMetric {
    counts: [AtomicU64; hist::BUCKETS],
    sum_us: AtomicU64,
}

impl HistMetric {
    pub fn record_ms(&self, ms: f64) {
        if !enabled() {
            return;
        }
        self.counts[hist::bucket(ms)].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add((ms * 1e3).max(0.0) as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> hist::Hist {
        hist::Hist {
            counts: self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            sum_ms: self.sum_us.load(Ordering::Relaxed) as f64 / 1e3,
        }
    }
}

/// A registered metric: a copyable wrapper over the leaked `&'static`
/// handle, so lookups return it by value (never a reference into the
/// registry's reallocating `Vec`).
#[derive(Clone, Copy)]
enum Metric {
    Counter(&'static Counter),
    Gauge(&'static Gauge),
    Hist(&'static HistMetric),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Hist(_) => "histogram",
        }
    }
}

/// The process-global registry: name → metric, insertion under a mutex
/// (cold path), updates lock-free through the returned `&'static`.
fn registry() -> &'static Mutex<Vec<(String, Metric)>> {
    static REGISTRY: OnceLock<Mutex<Vec<(String, Metric)>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

fn register(name: &str, make: impl FnOnce() -> Metric) -> Metric {
    let mut reg = registry().lock().expect("obs: registry lock poisoned");
    if let Some((_, m)) = reg.iter().find(|(n, _)| n == name) {
        return *m;
    }
    let m = make();
    reg.push((name.to_string(), m));
    m
}

/// Register (or look up) a counter by name. Idempotent per name;
/// registering one name as two different kinds is a programmer error and
/// panics loudly.
pub fn counter(name: &str) -> &'static Counter {
    match register(name, || Metric::Counter(Box::leak(Box::new(Counter(AtomicU64::new(0)))))) {
        Metric::Counter(c) => c,
        other => panic!("obs: {name:?} already registered as a {}", other.kind()),
    }
}

/// Register (or look up) a gauge by name.
pub fn gauge(name: &str) -> &'static Gauge {
    match register(name, || Metric::Gauge(Box::leak(Box::new(Gauge(AtomicI64::new(0)))))) {
        Metric::Gauge(g) => g,
        other => panic!("obs: {name:?} already registered as a {}", other.kind()),
    }
}

/// Register (or look up) a histogram by name.
pub fn histogram(name: &str) -> &'static HistMetric {
    match register(name, || {
        Metric::Hist(Box::leak(Box::new(HistMetric {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
        })))
    }) {
        Metric::Hist(h) => h,
        other => panic!("obs: {name:?} already registered as a {}", other.kind()),
    }
}

/// A point-in-time copy of every registered metric, sorted by name.
pub struct Snapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, i64)>,
    pub hists: Vec<(String, hist::Hist)>,
}

/// Freeze the registry. Relaxed loads: each value is exact, cross-metric
/// consistency is best-effort (fine for monitoring, documented as such).
pub fn snapshot() -> Snapshot {
    let reg = registry().lock().expect("obs: registry lock poisoned");
    let mut snap = Snapshot { counters: Vec::new(), gauges: Vec::new(), hists: Vec::new() };
    for (name, metric) in reg.iter() {
        match metric {
            Metric::Counter(c) => snap.counters.push((name.clone(), c.get())),
            Metric::Gauge(g) => snap.gauges.push((name.clone(), g.get())),
            Metric::Hist(h) => snap.hists.push((name.clone(), h.snapshot())),
        }
    }
    snap.counters.sort_by(|a, b| a.0.cmp(&b.0));
    snap.gauges.sort_by(|a, b| a.0.cmp(&b.0));
    snap.hists.sort_by(|a, b| a.0.cmp(&b.0));
    snap
}

/// Convenience: a registered gauge's current value, 0 when the name was
/// never registered (e.g. obs queried before the store layer ran).
pub fn gauge_value(name: &str) -> i64 {
    let reg = registry().lock().expect("obs: registry lock poisoned");
    match reg.iter().find(|(n, _)| n == name) {
        Some((_, Metric::Gauge(g))) => g.get(),
        _ => 0,
    }
}

/// Split `subsystem.metric{label}` into the Prometheus base name
/// (`qrlora_subsystem_metric`) and the verbatim label part (`{label}` or
/// empty).
fn prom_name(name: &str) -> (String, String) {
    let (base, label) = match name.find('{') {
        Some(i) => (&name[..i], name[i..].to_string()),
        None => (name, String::new()),
    };
    (format!("qrlora_{}", base.replace('.', "_")), label)
}

/// Inject `le="…"` into a (possibly empty) label part.
fn with_le(label: &str, le: &str) -> String {
    if label.is_empty() {
        format!("{{le=\"{le}\"}}")
    } else {
        format!("{},le=\"{le}\"}}", &label[..label.len() - 1])
    }
}

impl Snapshot {
    /// The JSON export form (`GET /metrics.json`, `--metrics-json`, the
    /// `FLEET_WORKER` `metrics` field). Histograms carry derived
    /// p50/p99 alongside raw buckets so dashboards need no client math.
    pub fn to_json(&self) -> Json {
        let counters = self.counters.iter().map(|(n, v)| (n.clone(), Json::num(*v as f64)));
        let gauges = self.gauges.iter().map(|(n, v)| (n.clone(), Json::num(*v as f64)));
        let hists = self.hists.iter().map(|(n, h)| (n.clone(), h.to_json()));
        Json::obj(vec![
            ("counters", Json::Obj(counters.collect())),
            ("gauges", Json::Obj(gauges.collect())),
            ("hists", Json::Obj(hists.collect())),
            ("hist_bounds_ms", Json::arr_num(hist::BOUNDS_MS.iter().copied())),
            ("uptime_ms", Json::num(uptime_ms() as f64)),
        ])
    }

    /// Prometheus text exposition (`GET /metrics`): `qrlora_`-prefixed,
    /// dots → underscores, the `{label}` part carried verbatim,
    /// histograms as cumulative `_bucket{le=…}` + `_sum` + `_count`.
    /// `# TYPE` lines are emitted once per base name, so labeled
    /// variants of one metric share a single family declaration.
    pub fn prometheus_text(&self) -> String {
        let mut out = String::new();
        let mut last_typed = String::new();
        let mut typed = |out: &mut String, base: &str, kind: &str| {
            if last_typed != base {
                out.push_str(&format!("# TYPE {base} {kind}\n"));
                last_typed = base.to_string();
            }
        };
        for (name, v) in &self.counters {
            let (base, label) = prom_name(name);
            typed(&mut out, &base, "counter");
            out.push_str(&format!("{base}{label} {v}\n"));
        }
        for (name, v) in &self.gauges {
            let (base, label) = prom_name(name);
            typed(&mut out, &base, "gauge");
            out.push_str(&format!("{base}{label} {v}\n"));
        }
        for (name, h) in &self.hists {
            let (base, label) = prom_name(name);
            typed(&mut out, &base, "histogram");
            let mut cum = 0u64;
            for (i, &c) in h.counts.iter().enumerate() {
                cum += c;
                let le = match hist::BOUNDS_MS.get(i) {
                    Some(ub) => ub.to_string(),
                    None => "+Inf".to_string(),
                };
                out.push_str(&format!("{base}_bucket{} {cum}\n", with_le(&label, &le)));
            }
            out.push_str(&format!("{base}_sum{label} {}\n", h.sum_ms));
            out.push_str(&format!("{base}_count{label} {cum}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// N threads hammer one counter and one histogram; totals are exact
    /// — the registry's core contract (relaxed ordering loses ordering,
    /// never increments).
    #[test]
    fn concurrent_updates_keep_exact_totals() {
        let c = counter("test.obs.concurrent_total");
        let h = histogram("test.obs.concurrent_ms");
        const THREADS: usize = 8;
        const PER: usize = 10_000;
        std::thread::scope(|s| {
            for t in 0..THREADS {
                s.spawn(move || {
                    for i in 0..PER {
                        c.inc();
                        h.record_ms(((t * PER + i) % 300) as f64);
                    }
                });
            }
        });
        assert_eq!(c.get(), (THREADS * PER) as u64);
        assert_eq!(h.snapshot().total(), (THREADS * PER) as u64);
    }

    #[test]
    fn registration_is_idempotent_per_name() {
        let a = counter("test.obs.idempotent");
        a.add(3);
        let b = counter("test.obs.idempotent");
        assert!(std::ptr::eq(a, b), "same name must yield the same handle");
        assert_eq!(b.get(), 3);
    }

    #[test]
    fn gauges_set_and_read_back() {
        let g = gauge("test.obs.gauge");
        g.set(41);
        g.add(1);
        assert_eq!(g.get(), 42);
        assert_eq!(gauge_value("test.obs.gauge"), 42);
        assert_eq!(gauge_value("test.obs.never_registered"), 0);
    }

    #[test]
    fn snapshot_exports_both_formats() {
        counter("test.obs.export{code=\"ok\"}").add(7);
        gauge("test.obs.export_depth").set(3);
        histogram("test.obs.export_ms").record_ms(1.5);
        let snap = snapshot();
        let doc = snap.to_json();
        let ok = doc
            .req("counters")
            .unwrap()
            .get("test.obs.export{code=\"ok\"}")
            .and_then(Json::as_usize);
        assert_eq!(ok, Some(7));
        let round = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(round.req("counters").unwrap().as_obj().map(|o| o.is_empty()), Some(false));

        let text = snap.prometheus_text();
        assert!(text.contains("# TYPE qrlora_test_obs_export counter"), "{text}");
        assert!(text.contains("qrlora_test_obs_export{code=\"ok\"} 7"), "{text}");
        assert!(text.contains("qrlora_test_obs_export_depth 3"), "{text}");
        assert!(text.contains("qrlora_test_obs_export_ms_bucket{le=\"2\"} 1"), "{text}");
        assert!(text.contains("qrlora_test_obs_export_ms_bucket{le=\"+Inf\"} 1"), "{text}");
        assert!(text.contains("qrlora_test_obs_export_ms_count 1"), "{text}");
        assert_eq!(
            text.matches("# TYPE qrlora_test_obs_export counter").count(),
            1,
            "one family declaration per base name"
        );
    }

    #[test]
    fn trace_ids_are_unique_and_nonzero() {
        let a = next_trace_id();
        let b = next_trace_id();
        assert_ne!(a, 0);
        assert_ne!(a, b);
    }
}
