//! FNV-1a 64-bit hashing (std-only) — the one byte-wise FNV in the tree,
//! shared by parameter-init name seeding (`model::init_state`) and the
//! adapter store's fingerprints (`store::format`).
//!
//! (`runtime/host.rs` keeps a separate *word-wise* FNV variant for its
//! strided buffer fingerprint — different input domain, not a duplicate
//! of this one.)

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf29ce484222325;

/// Mix `bytes` into an FNV-1a accumulator.
pub fn fnv1a(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x100000001b3);
    }
}

/// One-shot FNV-1a of a string's bytes.
pub fn fnv1a_str(s: &str) -> u64 {
    let mut h = FNV_OFFSET;
    fnv1a(&mut h, s.as_bytes());
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Canonical FNV-1a 64 test vectors.
        assert_eq!(fnv1a_str(""), 0xcbf29ce484222325);
        assert_eq!(fnv1a_str("a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a_str("foobar"), 0x85944171f73967e8);
    }
}
