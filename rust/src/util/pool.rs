//! Hand-rolled worker pool for deterministic data parallelism (std-only;
//! the offline vendor set has no rayon/crossbeam).
//!
//! Design goals, in order:
//!
//! 1. **Determinism.** Every parallel helper partitions work into
//!    contiguous row spans and each task writes only its own disjoint
//!    output slice. The per-element computation order inside a span is
//!    exactly the serial order, so results are **bit-identical for every
//!    thread count** (including 1). Reductions whose accumulation tree
//!    could depend on the partition (column sums, LayerNorm dγ/dβ, the
//!    gradient norm) run as **fixed-chunk partial sums**
//!    ([`par_reduce_rows`]): the chunk boundaries are a function of the
//!    row count alone, never the thread count.
//! 2. **Zero per-call thread spawns.** A process-global pool of persistent
//!    workers is lazily created on first use; scoped tasks borrow the
//!    caller's stack (crossbeam-style `scope`/`spawn`) and the scope blocks
//!    until every task has finished, so non-`'static` borrows are sound.
//! 3. **Tiny shapes stay serial.** Helpers take an approximate `work`
//!    operation count and fall back to the inline serial path below
//!    [`PAR_CUTOFF`], so dispatch overhead never shows up on small-kernel
//!    latency.
//!
//! Sizing: `set_threads()` (the CLI's `--threads`), else the
//! `QRLORA_THREADS` env var, else `std::thread::available_parallelism()`.
//! [`with_threads`] caps (or raises) the partition count for the current
//! thread — the bench harness uses it to time threads=1 vs threads=N in one
//! process. Tasks spawned from inside a pool worker run serially (no nested
//! fan-out), which makes accidental nesting safe instead of a deadlock.

use std::cell::Cell;
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Below this many inner operations a parallel helper runs serially.
pub const PAR_CUTOFF: usize = 1 << 15;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<QueueState>,
    cv: Condvar,
}

/// Countdown latch: `scope` waits until every spawned task called `done`.
struct Latch {
    count: Mutex<usize>,
    cv: Condvar,
}

impl Latch {
    fn new() -> Latch {
        Latch { count: Mutex::new(0), cv: Condvar::new() }
    }

    fn add(&self, k: usize) {
        *self.count.lock().unwrap() += k;
    }

    fn done(&self) {
        let mut g = self.count.lock().unwrap();
        *g -= 1;
        if *g == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut g = self.count.lock().unwrap();
        while *g > 0 {
            g = self.cv.wait(g).unwrap();
        }
    }
}

/// Persistent worker pool. `lanes` counts the caller thread too: a pool of
/// `n` lanes spawns `n − 1` OS threads and the caller always executes one
/// span itself (see [`join_all`]).
pub struct Pool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    lanes: usize,
}

fn worker_loop(shared: Arc<Shared>) {
    IN_WORKER.with(|f| f.set(true));
    loop {
        let job = {
            let mut g = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = g.jobs.pop_front() {
                    break j;
                }
                if g.shutdown {
                    return;
                }
                g = shared.cv.wait(g).unwrap();
            }
        };
        job();
    }
}

impl Pool {
    /// Spawn a pool with `lanes − 1` worker threads (min 1 lane).
    pub fn new(lanes: usize) -> Pool {
        let lanes = lanes.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(lanes - 1);
        for i in 0..lanes - 1 {
            let sh = Arc::clone(&shared);
            let h = std::thread::Builder::new()
                .name(format!("qrlora-pool-{i}"))
                .spawn(move || worker_loop(sh))
                .expect("pool: failed to spawn worker thread");
            handles.push(h);
        }
        Pool { shared, handles, lanes }
    }

    /// Total lanes (worker threads + the caller).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    fn workers(&self) -> usize {
        self.handles.len()
    }

    fn inject(&self, job: Job) {
        self.shared.queue.lock().unwrap().jobs.push_back(job);
        self.shared.cv.notify_one();
    }

    /// Run `f` with a [`Scope`] on which non-`'static` tasks can be
    /// spawned. Blocks (via a drop guard, so also on unwind) until every
    /// spawned task completed; panics afterwards if any task panicked.
    pub fn scope<'env, R>(&'env self, f: impl FnOnce(&Scope<'env>) -> R) -> R {
        let scope = Scope {
            pool: self,
            latch: Arc::new(Latch::new()),
            panicked: Arc::new(AtomicBool::new(false)),
            _env: PhantomData,
        };
        struct Guard<'a, 'env>(&'a Scope<'env>);
        impl Drop for Guard<'_, '_> {
            fn drop(&mut self) {
                self.0.latch.wait();
            }
        }
        let out;
        {
            let guard = Guard(&scope);
            out = f(&scope);
            drop(guard);
        }
        if scope.panicked.load(Ordering::Relaxed) {
            panic!("pool: a scoped task panicked");
        }
        out
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shared.queue.lock().unwrap().shutdown = true;
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Spawn handle tied to one [`Pool::scope`] call. `'env` is invariant so
/// tasks can borrow anything that outlives the scope.
pub struct Scope<'env> {
    pool: &'env Pool,
    latch: Arc<Latch>,
    panicked: Arc<AtomicBool>,
    _env: PhantomData<Cell<&'env ()>>,
}

impl<'env> Scope<'env> {
    /// Queue a task on the pool; the owning [`Pool::scope`] call blocks
    /// until it (and every sibling) finished.
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'env,
    {
        if self.pool.workers() == 0 {
            // No worker threads: run on the caller so the scope still makes
            // progress (and panics propagate through the same path).
            if std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)).is_err() {
                self.panicked.store(true, Ordering::Relaxed);
            }
            return;
        }
        self.latch.add(1);
        let latch = Arc::clone(&self.latch);
        let panicked = Arc::clone(&self.panicked);
        let job: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
            if std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)).is_err() {
                panicked.store(true, Ordering::Relaxed);
            }
            latch.done();
        });
        // SAFETY: `Pool::scope` blocks until the latch reaches zero (the
        // wait lives in a drop guard, so it runs even when unwinding), so
        // this closure — and every `'env` borrow inside it — strictly
        // outlives its execution. The transmute only erases the lifetime;
        // the fat-pointer layout is identical.
        let job: Job = unsafe { std::mem::transmute(job) };
        self.pool.inject(job);
    }
}

// ---------------------------------------------------------------------------
// Global pool + sizing knobs.
// ---------------------------------------------------------------------------

thread_local! {
    static IN_WORKER: Cell<bool> = Cell::new(false);
    static LANE_CAP: Cell<usize> = Cell::new(0);
}

static CONFIG_THREADS: AtomicUsize = AtomicUsize::new(0);
static POOL: OnceLock<Pool> = OnceLock::new();

fn default_threads() -> usize {
    let cfg = CONFIG_THREADS.load(Ordering::Relaxed);
    if cfg > 0 {
        return cfg;
    }
    if let Ok(v) = std::env::var("QRLORA_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Set the pool size (the CLI's `--threads`). Takes effect only if called
/// before the first parallel operation creates the global pool.
pub fn set_threads(n: usize) {
    CONFIG_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// The process-global pool, created on first use.
pub fn global() -> &'static Pool {
    POOL.get_or_init(|| Pool::new(default_threads()))
}

/// Lanes the global pool was sized with.
pub fn threads() -> usize {
    global().lanes()
}

/// Run `f` with the partition count for this thread forced to `threads`.
/// More spans than worker threads is fine (workers drain a shared queue),
/// so this works for both capping (`1` = serial path) and oversubscribing
/// (deterministic 4-way splits on a 2-core box). Restored on unwind.
pub fn with_threads<R>(threads: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            LANE_CAP.with(|c| c.set(self.0));
        }
    }
    let prev = LANE_CAP.with(|c| {
        let p = c.get();
        c.set(threads.max(1));
        p
    });
    let _restore = Restore(prev);
    f()
}

/// How many spans a task with `work` inner operations should split into.
/// 1 (the serial path) when the task is small, when the caller is itself a
/// pool worker, or under `with_threads(1, …)`.
pub fn lanes_for(work: usize) -> usize {
    if IN_WORKER.with(|c| c.get()) {
        return 1;
    }
    if work < PAR_CUTOFF {
        return 1;
    }
    let cap = LANE_CAP.with(|c| c.get());
    if cap > 0 {
        cap
    } else {
        global().lanes()
    }
}

// ---------------------------------------------------------------------------
// Deterministic partition helpers.
// ---------------------------------------------------------------------------

/// Split `0..n` into at most `parts` contiguous `(start, len)` spans whose
/// lengths differ by at most one.
pub fn partition(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.min(n).max(1);
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        out.push((start, len));
        start += len;
    }
    out
}

/// Split `data` into consecutive chunks of the given element counts.
pub fn split_sizes<'a, T>(mut data: &'a mut [T], sizes: &[usize]) -> Vec<&'a mut [T]> {
    let mut out = Vec::with_capacity(sizes.len());
    for &sz in sizes {
        let rest = std::mem::take(&mut data);
        let (head, tail) = rest.split_at_mut(sz);
        out.push(head);
        data = tail;
    }
    out
}

/// Run every job concurrently on the pool; the caller executes the last one
/// inline so all lanes (workers + caller) do useful work.
pub fn join_all<F: FnOnce() + Send>(jobs: Vec<F>) {
    let n = jobs.len();
    if n == 0 {
        return;
    }
    if n == 1 {
        for job in jobs {
            job();
        }
        return;
    }
    global().scope(|sc| {
        for (i, job) in jobs.into_iter().enumerate() {
            if i + 1 == n {
                job();
            } else {
                sc.spawn(job);
            }
        }
    });
}

/// Row-parallel map over `data` viewed as `rows` rows of `data.len()/rows`
/// elements. `f(row0, chunk)` receives a block of whole rows starting at
/// global row `row0` and must fully determine those rows from shared input.
/// The split never changes per-element evaluation order, so output is
/// bit-identical for any thread count. `work` ≈ total inner operations
/// (used for the serial cutoff).
pub fn par_rows<T, F>(data: &mut [T], rows: usize, work: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let lanes = lanes_for(work);
    if lanes <= 1 || rows <= 1 {
        f(0, data);
        return;
    }
    debug_assert_eq!(data.len() % rows, 0, "par_rows: ragged row length");
    let row_len = data.len() / rows;
    let parts = partition(rows, lanes);
    let sizes: Vec<usize> = parts.iter().map(|&(_, len)| len * row_len).collect();
    let chunks = split_sizes(data, &sizes);
    let fr = &f;
    let mut jobs = Vec::with_capacity(parts.len());
    for (&(row0, _), chunk) in parts.iter().zip(chunks) {
        jobs.push(move || fr(row0, chunk));
    }
    join_all(jobs);
}

/// Like [`par_rows`] but over two output slices partitioned by the same row
/// spans; `ra`/`rb` are elements per logical row in each slice.
#[allow(clippy::too_many_arguments)]
pub fn par_parts2<A, B, F>(
    a: &mut [A],
    ra: usize,
    b: &mut [B],
    rb: usize,
    rows: usize,
    work: usize,
    f: F,
) where
    A: Send,
    B: Send,
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    let lanes = lanes_for(work);
    if lanes <= 1 || rows <= 1 {
        f(0, a, b);
        return;
    }
    let parts = partition(rows, lanes);
    let asizes: Vec<usize> = parts.iter().map(|&(_, len)| len * ra).collect();
    let bsizes: Vec<usize> = parts.iter().map(|&(_, len)| len * rb).collect();
    let achunks = split_sizes(a, &asizes);
    let bchunks = split_sizes(b, &bsizes);
    let fr = &f;
    let mut jobs = Vec::with_capacity(parts.len());
    for ((&(row0, _), ac), bc) in parts.iter().zip(achunks).zip(bchunks) {
        jobs.push(move || fr(row0, ac, bc));
    }
    join_all(jobs);
}

/// Three-output variant of [`par_parts2`] (attention backward, LayerNorm
/// forward).
#[allow(clippy::too_many_arguments)]
pub fn par_parts3<A, B, C, F>(
    a: &mut [A],
    ra: usize,
    b: &mut [B],
    rb: usize,
    c: &mut [C],
    rc: usize,
    rows: usize,
    work: usize,
    f: F,
) where
    A: Send,
    B: Send,
    C: Send,
    F: Fn(usize, &mut [A], &mut [B], &mut [C]) + Sync,
{
    let lanes = lanes_for(work);
    if lanes <= 1 || rows <= 1 {
        f(0, a, b, c);
        return;
    }
    let parts = partition(rows, lanes);
    let asizes: Vec<usize> = parts.iter().map(|&(_, len)| len * ra).collect();
    let bsizes: Vec<usize> = parts.iter().map(|&(_, len)| len * rb).collect();
    let csizes: Vec<usize> = parts.iter().map(|&(_, len)| len * rc).collect();
    let achunks = split_sizes(a, &asizes);
    let bchunks = split_sizes(b, &bsizes);
    let cchunks = split_sizes(c, &csizes);
    let fr = &f;
    let mut jobs = Vec::with_capacity(parts.len());
    for (((&(row0, _), ac), bc), cc) in parts.iter().zip(achunks).zip(bchunks).zip(cchunks) {
        jobs.push(move || fr(row0, ac, bc, cc));
    }
    join_all(jobs);
}

// ---------------------------------------------------------------------------
// Fixed-chunk parallel reductions.
// ---------------------------------------------------------------------------

/// Rows per partial sum in [`par_reduce_rows`]. A constant — never derived
/// from the thread count — so the partial-sum boundaries (and therefore the
/// float-accumulation tree) are a function of the row count alone.
pub const REDUCE_CHUNK: usize = 64;

/// Thread-count-independent parallel row reduction.
///
/// Reduces `rows` logical rows into one `width`-wide accumulator. Rows are
/// split into fixed chunks of [`REDUCE_CHUNK`]; `f(row0, n, partial)` must
/// accumulate rows `row0 .. row0 + n` into its zero-initialized
/// `width`-wide partial in ascending row order. Chunks evaluate on the pool
/// (each writes only its own partial) and the partials are folded serially
/// in chunk order, so the accumulation tree is fully determined by `rows`
/// — results are **bit-identical for every thread count**. With a single
/// chunk (`rows ≤ REDUCE_CHUNK`) the result equals the plain serial
/// reduction. `work` ≈ total inner operations (serial cutoff, as in
/// [`par_rows`]).
pub fn par_reduce_rows<T, F>(rows: usize, width: usize, work: usize, f: F) -> Vec<T>
where
    T: Send + Copy + Default + std::ops::AddAssign,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    let mut out = vec![T::default(); width];
    if rows == 0 || width == 0 {
        return out;
    }
    let n_chunks = rows.div_ceil(REDUCE_CHUNK);
    if n_chunks == 1 {
        f(0, rows, &mut out);
        return out;
    }
    let mut partials = vec![T::default(); n_chunks * width];
    par_rows(&mut partials, n_chunks, work, |c0, chunk| {
        for (ci, part) in chunk.chunks_mut(width).enumerate() {
            let row0 = (c0 + ci) * REDUCE_CHUNK;
            f(row0, REDUCE_CHUNK.min(rows - row0), part);
        }
    });
    for part in partials.chunks(width) {
        for (o, &p) in out.iter_mut().zip(part) {
            *o += p;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn partition_covers_contiguously() {
        for n in [0usize, 1, 2, 7, 64, 1000] {
            for p in [1usize, 2, 3, 8] {
                let parts = partition(n, p);
                let total: usize = parts.iter().map(|&(_, len)| len).sum();
                assert_eq!(total, n, "n={n} p={p}");
                let mut next = 0;
                for &(s, len) in &parts {
                    assert_eq!(s, next);
                    next += len;
                }
                assert!(parts.len() <= p.max(1));
            }
        }
    }

    #[test]
    fn split_sizes_tiles() {
        let mut v: Vec<u32> = (0..10).collect();
        let chunks = split_sizes(&mut v, &[3, 0, 5, 2]);
        assert_eq!(chunks.len(), 4);
        assert_eq!(chunks[0], &[0, 1, 2]);
        assert_eq!(chunks[1], &[] as &[u32]);
        assert_eq!(chunks[3], &[8, 9]);
    }

    #[test]
    fn scope_runs_all_tasks() {
        let counter = AtomicUsize::new(0);
        global().scope(|sc| {
            for _ in 0..32 {
                let c = &counter;
                sc.spawn(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 32);
    }

    #[test]
    #[should_panic(expected = "a scoped task panicked")]
    fn task_panic_propagates_to_scope() {
        global().scope(|sc| {
            sc.spawn(|| panic!("boom"));
        });
    }

    #[test]
    fn par_rows_writes_every_row_once() {
        let rows = 501;
        let cols = 16;
        let mut data = vec![0f32; rows * cols];
        // work forced above the cutoff so the parallel path runs.
        par_rows(&mut data, rows, 1 << 20, |row0, chunk| {
            for (ri, row) in chunk.chunks_mut(cols).enumerate() {
                for (j, v) in row.iter_mut().enumerate() {
                    *v = ((row0 + ri) * cols + j) as f32;
                }
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as f32);
        }
    }

    #[test]
    fn par_parts_split_consistently() {
        let rows = 97;
        let mut a = vec![0u32; rows * 3];
        let mut b = vec![0u32; rows];
        let mut c = vec![0u32; rows * 2];
        par_parts3(&mut a, 3, &mut b, 1, &mut c, 2, rows, 1 << 20, |r0, ac, bc, cc| {
            let n = bc.len();
            assert_eq!(ac.len(), 3 * n);
            assert_eq!(cc.len(), 2 * n);
            for i in 0..n {
                bc[i] = (r0 + i) as u32;
                ac[3 * i] = (r0 + i) as u32;
                cc[2 * i + 1] = (r0 + i) as u32;
            }
        });
        for (i, &v) in b.iter().enumerate() {
            assert_eq!(v, i as u32);
            assert_eq!(a[3 * i], i as u32);
            assert_eq!(c[2 * i + 1], i as u32);
        }
    }

    #[test]
    fn with_threads_is_deterministic_and_restores() {
        let run = |t: usize| {
            with_threads(t, || {
                let rows = 64;
                let cols = 64;
                let mut data = vec![0f64; rows * cols];
                par_rows(&mut data, rows, 1 << 20, |row0, chunk| {
                    for (ri, row) in chunk.chunks_mut(cols).enumerate() {
                        let mut acc = 0f64;
                        for (j, v) in row.iter_mut().enumerate() {
                            acc += ((row0 + ri) * 31 + j) as f64 * 0.125;
                            *v = acc;
                        }
                    }
                });
                data
            })
        };
        let serial = run(1);
        for t in [2usize, 3, 5, 8] {
            assert_eq!(serial, run(t), "threads={t}");
        }
        assert_eq!(LANE_CAP.with(|c| c.get()), 0, "cap must be restored");
    }

    #[test]
    fn par_reduce_rows_covers_every_row_once() {
        // Integer accumulators make coverage exact: the reduction of
        // row-index weights must equal the closed form regardless of how
        // rows and chunks line up.
        for rows in [0usize, 1, 63, 64, 65, 200, 517] {
            let got = with_threads(4, || {
                par_reduce_rows::<u64, _>(rows, 2, 1 << 20, |row0, n, acc| {
                    for i in row0..row0 + n {
                        acc[0] += i as u64;
                        acc[1] += 1;
                    }
                })
            });
            let want0: u64 = (0..rows as u64).sum();
            assert_eq!(got, vec![want0, rows as u64], "rows={rows}");
        }
    }

    #[test]
    fn par_reduce_rows_bit_identical_across_thread_counts() {
        // Float partial sums: the chunk boundaries are fixed, so the
        // accumulation tree — and every output bit — must not depend on
        // the lane count. Shapes straddle the chunk size and the cutoff.
        for rows in [1usize, 63, 64, 65, 130, 517] {
            let width = 7usize;
            let reduce = || {
                par_reduce_rows::<f32, _>(rows, width, 1 << 20, |row0, n, acc| {
                    for i in row0..row0 + n {
                        for (j, a) in acc.iter_mut().enumerate() {
                            *a += ((i * 31 + j) as f32).sin() * 0.37;
                        }
                    }
                })
            };
            let serial = with_threads(1, reduce);
            for t in [2usize, 3, 5, 8] {
                let par = with_threads(t, reduce);
                for (j, (a, b)) in serial.iter().zip(&par).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "rows={rows} t={t} col={j}");
                }
            }
        }
    }

    #[test]
    fn nested_parallelism_from_workers_is_serial() {
        // A task running on a pool worker must not fan out again.
        let seen = Mutex::new(Vec::new());
        global().scope(|sc| {
            let seen = &seen;
            sc.spawn(move || {
                seen.lock().unwrap().push(lanes_for(usize::MAX));
            });
        });
        let got = seen.into_inner().unwrap();
        // Inside a worker IN_WORKER forces 1; on a 1-lane pool the task ran
        // inline on a 1-lane global pool. Either way: no nested fan-out.
        assert_eq!(got, vec![1]);
    }
}
