//! Minimal JSON parser + writer (std-only).
//!
//! Used for the AOT artifact manifest produced by `python/compile/aot.py`,
//! metric logs (JSONL), and checkpoint indexes. Supports the full JSON value
//! model with f64 numbers; preserves object insertion order (the manifest's
//! input ordering is semantically meaningful).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects keep insertion order via a Vec of pairs plus a
/// lazily-consulted key index.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 {
                Some(f as usize)
            } else {
                None
            }
        })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
    /// `get` that errors with a path-ish message; convenient for manifests.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
    pub fn arr_num<I: IntoIterator<Item = f64>>(it: I) -> Json {
        Json::Arr(it.into_iter().map(Json::Num).collect())
    }
    pub fn arr_usize<'a, I: IntoIterator<Item = &'a usize>>(it: I) -> Json {
        Json::Arr(it.into_iter().map(|&u| Json::Num(u as f64)).collect())
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            anyhow::bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        if n.fract() == 0.0 && n.abs() < 1e15 {
            let _ = fmt::Write::write_fmt(out, format_args!("{}", n as i64));
        } else {
            let _ = fmt::Write::write_fmt(out, format_args!("{n}"));
        }
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            )
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            pairs.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(pairs));
                }
                other => anyhow::bail!(
                    "expected ',' or '}}' at byte {} (found {:?})",
                    self.i,
                    other.map(|b| b as char)
                ),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => anyhow::bail!(
                    "expected ',' or ']' at byte {} (found {:?})",
                    self.i,
                    other.map(|b| b as char)
                ),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.hex4()?;
                            // Handle surrogate pairs.
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i + 1) == Some(&b'\\')
                                    && self.b.get(self.i + 2) == Some(&b'u')
                                {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    s.push(char::from_u32(c).unwrap_or('\u{FFFD}'));
                                } else {
                                    s.push('\u{FFFD}');
                                }
                            } else {
                                s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            }
                            self.i += 1;
                            continue;
                        }
                        other => anyhow::bail!("bad escape {:?}", other.map(|b| b as char)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Advance one UTF-8 char.
                    let rest = &self.b[self.i..];
                    let txt = std::str::from_utf8(rest)
                        .map_err(|_| anyhow::anyhow!("invalid utf-8 in string"))?;
                    let c = txt.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    /// Parses the 4 hex digits after `\u`; leaves `i` on the final digit.
    fn hex4(&mut self) -> anyhow::Result<u32> {
        let start = self.i + 1;
        if start + 4 > self.b.len() {
            anyhow::bail!("truncated \\u escape");
        }
        let hex = std::str::from_utf8(&self.b[start..start + 4])?;
        let cp = u32::from_str_radix(hex, 16)?;
        self.i = start + 3;
        Ok(cp)
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

/// Convenience: read an object into a BTreeMap (order-insensitive views).
pub fn to_map(j: &Json) -> BTreeMap<String, Json> {
    match j {
        Json::Obj(o) => o.iter().cloned().collect(),
        _ => BTreeMap::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalar() {
        for src in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn preserves_object_order() {
        let v = Json::parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<&str> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn escapes_roundtrip() {
        let s = "line1\nline2\t\"quoted\" \\ slash \u{1}";
        let v = Json::Str(s.to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap().as_str().unwrap(), s);
    }

    #[test]
    fn rejects_garbage() {
        for src in ["{", "[1,", "tru", "{\"a\" 1}", "1 2", ""] {
            assert!(Json::parse(src).is_err(), "accepted {src:?}");
        }
    }

    #[test]
    fn numbers() {
        let v = Json::parse("[0, -0.5, 1e3, 2.5E-2, 123456789]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[2].as_f64().unwrap(), 1000.0);
        assert_eq!(a[4].as_usize().unwrap(), 123456789);
    }

    #[test]
    fn pretty_reparses() {
        let v = Json::parse(r#"{"a":[1,{"b":[true,null]}],"c":3}"#).unwrap();
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn property_roundtrip_random() {
        // Lightweight generative test: random JSON trees survive a
        // serialize → parse round-trip bit-exactly.
        use crate::util::rng::Rng;
        fn gen(r: &mut Rng, depth: usize) -> Json {
            match if depth > 3 { r.below(4) } else { r.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(r.chance(0.5)),
                2 => Json::Num((r.next_u64() % 100_000) as f64 / 8.0),
                3 => {
                    let n = r.below(8);
                    Json::Str((0..n).map(|_| *r.choice(&['a', 'é', '\n', '"', 'z'])).collect())
                }
                4 => Json::Arr((0..r.below(4)).map(|_| gen(r, depth + 1)).collect()),
                _ => Json::Obj(
                    (0..r.below(4))
                        .map(|i| (format!("k{i}"), gen(r, depth + 1)))
                        .collect(),
                ),
            }
        }
        let mut r = Rng::new(99);
        for _ in 0..200 {
            let v = gen(&mut r, 0);
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
            assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
        }
    }
}
