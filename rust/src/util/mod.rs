//! Shared substrates: JSON, TOML-lite config, CLI parsing, RNG, logging.
//! All std-only — the offline vendor set contains no serde/clap/rand.

pub mod cli;
pub mod faults;
pub mod hash;
pub mod json;
pub mod log;
pub mod pool;
pub mod rng;
pub mod toml;
