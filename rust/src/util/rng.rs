//! Splittable pseudo-random number generator (xoshiro256++ seeded by
//! SplitMix64). Deterministic across runs given the same seed; every
//! data-generation and initialization path in the framework draws from
//! this so experiments are exactly reproducible.

/// xoshiro256++ PRNG with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream; `tag` distinguishes siblings.
    /// Mixing through SplitMix64 keeps child streams decorrelated.
    pub fn split(&self, tag: u64) -> Rng {
        let mut sm = self.s[0] ^ self.s[2].rotate_left(17) ^ tag.wrapping_mul(0xA24BAED4963EE407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Rejection-free (modulo bias is negligible
    /// for n << 2^64 but we use Lemire's method anyway).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.below(hi - lo)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted: all-zero weights");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn split_streams_decorrelated() {
        let root = Rng::new(7);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let x = r.below(7);
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(17);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }
}
