//! Hand-rolled CLI argument parser (std-only; the vendored crate set has no
//! clap). Supports subcommands, `--flag value`, `--flag=value`, boolean
//! switches, repeated flags, and positional arguments, with generated help.

use std::collections::BTreeMap;

/// Parsed arguments for one (sub)command invocation.
#[derive(Debug, Default, Clone)]
pub struct Args {
    flags: BTreeMap<String, Vec<String>>,
    switches: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse raw arguments. `known_switches` lists flags that take no value.
    pub fn parse(raw: &[String], known_switches: &[&str]) -> anyhow::Result<Args> {
        let mut args = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if known_switches.contains(&body) {
                    args.switches.push(body.to_string());
                } else {
                    i += 1;
                    let v = raw
                        .get(i)
                        .ok_or_else(|| anyhow::anyhow!("flag --{body} expects a value"))?;
                    args.flags.entry(body.to_string()).or_default().push(v.clone());
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags
            .get(key)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Typed getters with defaults.
    pub fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    /// Comma-separated list flag, e.g. `--taus 0.5,0.7,0.8`.
    pub fn list_f64(&self, key: &str, default: &[f64]) -> anyhow::Result<Vec<f64>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--{key}: bad number {p:?}"))
                })
                .collect(),
        }
    }

    pub fn list_str(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|p| p.trim().to_string()).collect(),
        }
    }
}

/// Flags the `serve` demo accepts beyond the shared experiment flags.
///
/// The README's "Serving" section must document every one of these as
/// `--<flag>`; the `readme_documents_serve_flags` test (also run as a
/// dedicated CI step) keeps docs and CLI in lockstep. Extend this list
/// whenever `cmd_serve` in `main.rs` learns a new flag.
pub const SERVE_FLAGS: &[&str] = &[
    "requests",
    "max-batch",
    "resident-adapters",
    "adapter-store",
    "no-warm-start",
    "fleet",
    "worker-id",
    "fleet-tasks",
    "max-restarts",
    "heartbeat-secs",
    "listen",
    "reorder-window",
    "max-queue-depth",
    "method",
    "metrics-json",
];

/// Flags the `soak` load-generator command accepts beyond the shared
/// experiment flags.
///
/// Same lockstep rule as [`SERVE_FLAGS`]: the README's soak section must
/// document each as `--<flag>`, enforced by the
/// `readme_documents_soak_flags` test and the matching CI step.
pub const SOAK_FLAGS: &[&str] = &["connect", "concurrency", "soak-json"];

/// Flags the `adapters` store-management command accepts beyond
/// `--adapter-store` (which [`SERVE_FLAGS`] already carries).
///
/// Same lockstep rule: each must appear as `--<flag>` in the README
/// (enforced by `readme_documents_store_flags` and the matching CI step).
pub const STORE_FLAGS: &[&str] =
    &["task", "max-age-days", "max-count", "dry-run", "records", "writer-id"];

/// Global performance/memory knobs every subcommand accepts (parsed in
/// `main.rs`, handed to the backend factory via the environment).
///
/// Same lockstep rule as [`SERVE_FLAGS`]: the README's perf-knobs section
/// must document each as `--<flag>`, enforced by the
/// `readme_documents_perf_flags` test and the matching CI step. Extend
/// this list whenever `main.rs` learns a new global knob.
pub const PERF_FLAGS: &[&str] =
    &["backend", "threads", "quantize-backbone", "simd", "simd-relaxed"];

/// A subcommand descriptor for help output.
pub struct Command {
    /// Subcommand name as typed on the command line.
    pub name: &'static str,
    /// One-line description for the help screen.
    pub about: &'static str,
}

/// Render a help screen for a command set.
pub fn render_help(bin: &str, about: &str, commands: &[Command]) -> String {
    let mut s = format!("{bin} — {about}\n\nUSAGE:\n  {bin} <command> [flags]\n\nCOMMANDS:\n");
    let width = commands.iter().map(|c| c.name.len()).max().unwrap_or(0);
    for c in commands {
        s.push_str(&format!("  {:width$}  {}\n", c.name, c.about, width = width));
    }
    s.push_str(&format!("\nRun `{bin} <command> --help` for command flags.\n"));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn flags_and_positionals() {
        let a = Args::parse(&raw(&["train", "--task", "mnli", "--steps=100", "-v"]), &[]).unwrap();
        assert_eq!(a.positional(), &["train".to_string(), "-v".to_string()]);
        assert_eq!(a.get("task"), Some("mnli"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
    }

    #[test]
    fn switches() {
        let a = Args::parse(&raw(&["--verbose", "--task", "sst2"]), &["verbose"]).unwrap();
        assert!(a.has("verbose"));
        assert_eq!(a.get("task"), Some("sst2"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&raw(&["--task"]), &[]).is_err());
    }

    #[test]
    fn repeated_flags() {
        let a = Args::parse(&raw(&["--x", "1", "--x", "2"]), &[]).unwrap();
        assert_eq!(a.get_all("x"), vec!["1", "2"]);
        assert_eq!(a.get("x"), Some("2"));
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(&raw(&["--taus", "0.5,0.7,0.8"]), &[]).unwrap();
        assert_eq!(a.list_f64("taus", &[]).unwrap(), vec![0.5, 0.7, 0.8]);
        assert_eq!(a.list_f64("missing", &[1.0]).unwrap(), vec![1.0]);
    }

    #[test]
    fn typed_defaults() {
        let a = Args::parse(&raw(&[]), &[]).unwrap();
        assert_eq!(a.usize_or("steps", 7).unwrap(), 7);
        assert_eq!(a.f64_or("lr", 0.1).unwrap(), 0.1);
        assert_eq!(a.str_or("preset", "tiny"), "tiny");
    }

    #[test]
    fn bad_typed_value_errors() {
        let a = Args::parse(&raw(&["--steps", "abc"]), &[]).unwrap();
        assert!(a.usize_or("steps", 0).is_err());
    }

    /// Docs/CLI lockstep: every serve flag must appear as `--<flag>` in the
    /// README's Serving section (run as a dedicated CI step too).
    #[test]
    fn readme_documents_serve_flags() {
        let readme = include_str!("../../../README.md");
        for flag in SERVE_FLAGS {
            assert!(
                readme.contains(&format!("--{flag}")),
                "README.md must document serve flag --{flag}"
            );
        }
    }

    /// Same lockstep for the global perf/memory knobs (`--backend`,
    /// `--threads`, `--quantize-backbone`, `--simd`, `--simd-relaxed`).
    #[test]
    fn readme_documents_perf_flags() {
        let readme = include_str!("../../../README.md");
        for flag in PERF_FLAGS {
            assert!(
                readme.contains(&format!("--{flag}")),
                "README.md must document perf flag --{flag}"
            );
        }
    }

    /// Same lockstep for the soak load-generator flags
    /// (`soak --connect/--concurrency/--soak-json`).
    #[test]
    fn readme_documents_soak_flags() {
        let readme = include_str!("../../../README.md");
        for flag in SOAK_FLAGS {
            assert!(
                readme.contains(&format!("--{flag}")),
                "README.md must document soak flag --{flag}"
            );
        }
    }

    /// Same lockstep for the adapter-store management flags
    /// (`adapters gc --max-age-days/--max-count/--dry-run`).
    #[test]
    fn readme_documents_store_flags() {
        let readme = include_str!("../../../README.md");
        for flag in STORE_FLAGS {
            assert!(
                readme.contains(&format!("--{flag}")),
                "README.md must document store flag --{flag}"
            );
        }
    }
}
