//! Leveled logging + wall-clock timing utilities (std-only).
//!
//! The level is process-global and set once by the CLI (`--log debug`,
//! or its env twin `QRLORA_LOG` — see `main.rs` for the precedence).
//! Logs go to stderr so stdout stays clean for machine-readable output
//! (experiment tables, JSONL metrics). Every line carries a monotonic
//! `+{ms}ms` process-uptime offset (from [`crate::obs::uptime_ms`]) so
//! log lines correlate with flight-recorder span timestamps without a
//! wall clock.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn set_level_str(s: &str) -> anyhow::Result<()> {
    let level = match s {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "info" => Level::Info,
        "debug" => Level::Debug,
        _ => anyhow::bail!("unknown log level {s:?} (error|warn|info|debug)"),
    };
    set_level(level);
    Ok(())
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: std::fmt::Arguments) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag} +{}ms] {module}: {msg}", crate::obs::uptime_ms());
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnln {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debugln {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! errorln {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

/// Simple scope timer: `let _t = Timer::new("pretrain");` logs on drop,
/// or use `elapsed_ms()` for explicit measurement.
pub struct Timer {
    label: String,
    start: Instant,
    log_on_drop: bool,
}

impl Timer {
    pub fn new(label: impl Into<String>) -> Timer {
        Timer {
            label: label.into(),
            start: Instant::now(),
            log_on_drop: true,
        }
    }

    pub fn quiet(label: impl Into<String>) -> Timer {
        Timer {
            label: label.into(),
            start: Instant::now(),
            log_on_drop: false,
        }
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Drop for Timer {
    fn drop(&mut self) {
        if self.log_on_drop {
            log(
                Level::Debug,
                "timer",
                format_args!("{} took {:.1} ms", self.label, self.elapsed_ms()),
            );
        }
    }
}

/// Online mean/min/max/stddev accumulator for latency stats.
#[derive(Clone, Debug, Default)]
pub struct Stats {
    pub n: usize,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn new() -> Stats {
        Stats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn level_from_str() {
        assert!(set_level_str("debug").is_ok());
        assert!(set_level_str("nope").is_err());
        set_level(Level::Info);
    }

    #[test]
    fn stats_accumulate() {
        let mut s = Stats::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.n, 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.std() - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn timer_measures() {
        let t = Timer::quiet("x");
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }
}
