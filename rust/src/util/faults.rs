//! Deterministic fault injection for chaos testing (std-only, no `rand`).
//!
//! The `QRLORA_FAULTS` environment variable holds a spec of
//! `;`-separated clauses, each `site=action`:
//!
//! ```text
//! QRLORA_FAULTS="store.read=err#2;publish=crash_after_temp;lock=hold_past_stale"
//! ```
//!
//! Sites are fixed seams threaded through the store/lock/checkpoint/serve
//! paths (see [`SITES`]); an unknown site or action is a loud parse panic
//! rather than a chaos test that silently passes vacuously. Actions:
//!
//! | action             | effect at the seam                                   |
//! |--------------------|------------------------------------------------------|
//! | `err`              | every call fails with a transient-marked IO error    |
//! | `err#N`            | the first N calls fail, then succeed                 |
//! | `err@P`            | each call fails with probability P (0..=1)           |
//! | `crash` / `crash_after_temp` | abort the process (at write seams: after the temp write, before the rename) |
//! | `hang`             | block forever (exercises hung-worker detection)      |
//! | `leak` / `hold_past_stale` | skip the store-lock release on drop          |
//!
//! Firing is **deterministic**: `err@P` hashes `(seed, site, call#)` with
//! the shared FNV-1a ([`crate::util::hash`]) — no `rand` dependency, and
//! the same spec + seed (`QRLORA_FAULTS_SEED`, default 0) always fails
//! the same calls. Two suffixes refine a clause:
//!
//! * `!` (sticky): crash/hang/leak faults are **one-shot** by default —
//!   they fire only in a process's first incarnation, judged by the
//!   `QRLORA_FAULTS_RESTART` env the fleet supervisor sets on every
//!   respawn — so a restarted worker makes progress. `!` makes the fault
//!   fire in every incarnation (to drive a worker past its restart
//!   budget into failover).
//! * `@wN`: fire only in fleet worker N (`QRLORA_WORKER_ID`, set by the
//!   supervisor), e.g. `serve=hang@w0` hangs worker 0 and nobody else.
//!
//! With the spec empty or unset every hook is a no-op behind one
//! `OnceLock` load — production binaries pay nothing. The spec is parsed
//! once per process; chaos tests drive real binaries
//! (`CARGO_BIN_EXE_qrlora`) and vary the env per child process.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use super::hash::{fnv1a, FNV_OFFSET};

/// Env var holding the fault spec (empty/unset = all hooks no-op).
pub const ENV_SPEC: &str = "QRLORA_FAULTS";
/// Env var seeding `err@P` firing (default 0).
pub const ENV_SEED: &str = "QRLORA_FAULTS_SEED";
/// Restart generation (0/unset = first incarnation). The fleet
/// supervisor sets this on every respawn; non-sticky crash/hang/leak
/// faults fire only at generation 0.
pub const ENV_RESTART: &str = "QRLORA_FAULTS_RESTART";
/// Fleet worker id, set per worker by the supervisor; `@wN`-filtered
/// clauses fire only when it matches.
pub const ENV_WORKER: &str = "QRLORA_WORKER_ID";

/// Marker substring carried by every injected error. The store's retry
/// policy ([`crate::store::retry::is_transient`]) classifies on it, so
/// injected faults exercise exactly the transient-error path.
pub const TRANSIENT_MARKER: &str = "(transient)";

/// The seams a spec may name. Kept in sync with the `io_fault` /
/// `crash_point` / `hang_point` / `leaks` call sites:
///
/// * `store.open` — `Registry::open` entry (store-unavailable serving)
/// * `store.read` — record-file and index reads
/// * `store.write` — generic `atomic_write` (index rewrites)
/// * `publish` — adapter-record writes (`AdapterRecord::save`)
/// * `checkpoint` — pipeline checkpoint writes (`model::checkpoint`)
/// * `lock` — `StoreLock` acquisition (err = simulated lock timeout) and
///   release (leak = holder dies without releasing)
/// * `serve` — fleet worker entry (hang = silent worker, crash = death)
/// * `net.conn` — first accepted socket connection's reader thread
///   (hang = one wedged client connection; later connections must keep
///   flowing)
/// * `net.engine` — socket serving engine loop, firing once work is
///   queued (hang = accepting-but-dead server, crash = death with
///   requests in flight — the flight-recorder dump must show their
///   admit spans)
pub const SITES: &[&str] = &[
    "store.open",
    "store.read",
    "store.write",
    "publish",
    "checkpoint",
    "lock",
    "serve",
    "net.conn",
    "net.engine",
];

#[derive(Debug, Clone, Copy, PartialEq)]
enum Action {
    /// Fail the first `n` calls at the site (`err` = `u64::MAX`).
    ErrCount(u64),
    /// Fail each call with probability permille/1000, deterministically
    /// from (seed, site, call#).
    ErrProb(u32),
    /// Abort the process at the seam.
    Crash,
    /// Block forever at the seam.
    Hang,
    /// Skip the store-lock release on drop.
    Leak,
}

#[derive(Debug)]
struct Fault {
    site: String,
    action: Action,
    /// `!` suffix: fire in every incarnation, not only restart gen 0.
    sticky: bool,
    /// `@wN` suffix: fire only in fleet worker N.
    worker: Option<u64>,
    /// Calls seen at this clause (drives `err#N` / `err@P`).
    calls: AtomicU64,
}

struct Plan {
    faults: Vec<Fault>,
    seed: u64,
    /// True when `QRLORA_FAULTS_RESTART` says this is a respawn.
    restarted: bool,
    worker: Option<u64>,
}

fn plan() -> &'static Plan {
    static PLAN: OnceLock<Plan> = OnceLock::new();
    PLAN.get_or_init(|| {
        let spec = std::env::var(ENV_SPEC).unwrap_or_default();
        let faults = match parse_spec(&spec) {
            Ok(f) => f,
            // A typo'd chaos spec must not become a vacuously green test.
            Err(e) => panic!("{ENV_SPEC}: {e}"),
        };
        let seed = std::env::var(ENV_SEED).ok().and_then(|v| v.parse().ok()).unwrap_or(0);
        let restarted = std::env::var(ENV_RESTART)
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .map(|g| g > 0)
            .unwrap_or(false);
        let worker = std::env::var(ENV_WORKER).ok().and_then(|v| v.parse().ok());
        if !faults.is_empty() {
            crate::warnln!(
                "fault injection ACTIVE ({} clause(s) from {ENV_SPEC}={spec:?}, seed {seed})",
                faults.len()
            );
        }
        Plan { faults, seed, restarted, worker }
    })
}

/// Parse a spec into fault clauses. Pure (no env access) so unit tests
/// cover the grammar without process-global state.
fn parse_spec(spec: &str) -> Result<Vec<Fault>, String> {
    let mut out = Vec::new();
    for clause in spec.split(';').map(str::trim).filter(|c| !c.is_empty()) {
        let (site, rest) = clause
            .split_once('=')
            .ok_or_else(|| format!("bad clause {clause:?} (want site=action)"))?;
        let site = site.trim();
        if !SITES.contains(&site) {
            return Err(format!("unknown site {site:?} (known: {SITES:?})"));
        }
        // Suffix order: action[!][@wN]
        let (rest, worker) = match rest.rfind("@w") {
            Some(i) if !rest[i + 2..].is_empty()
                && rest[i + 2..].bytes().all(|b| b.is_ascii_digit()) =>
            {
                let w = rest[i + 2..]
                    .parse()
                    .map_err(|_| format!("bad worker filter in {clause:?}"))?;
                (&rest[..i], Some(w))
            }
            _ => (rest, None),
        };
        let (rest, sticky) = match rest.strip_suffix('!') {
            Some(r) => (r, true),
            None => (rest, false),
        };
        let action = if let Some(p) = rest.strip_prefix("err@") {
            let p: f64 =
                p.parse().map_err(|_| format!("bad probability in {clause:?}"))?;
            if !(0.0..=1.0).contains(&p) {
                return Err(format!("probability out of [0,1] in {clause:?}"));
            }
            Action::ErrProb((p * 1000.0).round() as u32)
        } else if let Some(n) = rest.strip_prefix("err#") {
            Action::ErrCount(n.parse().map_err(|_| format!("bad count in {clause:?}"))?)
        } else {
            match rest {
                "err" => Action::ErrCount(u64::MAX),
                "crash" | "crash_after_temp" => Action::Crash,
                "hang" => Action::Hang,
                "leak" | "hold_past_stale" => Action::Leak,
                other => return Err(format!("unknown action {other:?} in {clause:?}")),
            }
        };
        out.push(Fault {
            site: site.to_string(),
            action,
            sticky,
            worker,
            calls: AtomicU64::new(0),
        });
    }
    Ok(out)
}

/// Whether one clause fires for this call. `oneshot` marks actions that
/// must be suppressed after a supervisor restart unless sticky.
fn fires(f: &Fault, seed: u64, restarted: bool, worker: Option<u64>, oneshot: bool) -> bool {
    if let Some(w) = f.worker {
        if worker != Some(w) {
            return false;
        }
    }
    if oneshot && !f.sticky && restarted {
        return false;
    }
    let n = f.calls.fetch_add(1, Ordering::Relaxed);
    match f.action {
        Action::ErrCount(k) => n < k,
        Action::ErrProb(permille) => {
            let mut h = FNV_OFFSET;
            fnv1a(&mut h, &seed.to_le_bytes());
            fnv1a(&mut h, f.site.as_bytes());
            fnv1a(&mut h, &n.to_le_bytes());
            h % 1000 < permille as u64
        }
        Action::Crash | Action::Hang | Action::Leak => true,
    }
}

/// True when a fault spec is active in this process (diagnostics only —
/// the hooks below are already self-gating).
pub fn active() -> bool {
    !plan().faults.is_empty()
}

/// Error-injection hook for IO seams. Returns `Err` when an `err` clause
/// fires for `site`; the error message carries [`TRANSIENT_MARKER`] so
/// retry policies treat it as transient.
pub fn io_fault(site: &str) -> std::io::Result<()> {
    let p = plan();
    for f in p.faults.iter().filter(|f| f.site == site) {
        if matches!(f.action, Action::ErrCount(_) | Action::ErrProb(_))
            && fires(f, p.seed, p.restarted, p.worker, false)
        {
            return Err(std::io::Error::other(format!(
                "injected {site} fault {TRANSIENT_MARKER}"
            )));
        }
    }
    Ok(())
}

/// Crash hook for write seams: placed between the temp write and the
/// rename, so a firing `crash_after_temp` clause dies exactly inside the
/// torn-write window the recovery sweeps exist for. Aborts (no unwind,
/// no Drop — the closest in-process stand-in for SIGKILL).
pub fn crash_point(site: &str) {
    let p = plan();
    for f in p.faults.iter().filter(|f| f.site == site) {
        if f.action == Action::Crash && fires(f, p.seed, p.restarted, p.worker, true) {
            eprintln!("FAULT: injected crash at {site}");
            // Post-mortem before the abort: the flight recorder's spans
            // are this process's last words (abort skips Drop and hooks).
            crate::obs::flight::dump_stderr(site);
            std::process::abort();
        }
    }
}

/// Hang hook: blocks forever when a `hang` clause fires for `site`
/// (exercises the supervisor's silent-worker deadline).
pub fn hang_point(site: &str) {
    let p = plan();
    for f in p.faults.iter().filter(|f| f.site == site) {
        if f.action == Action::Hang && fires(f, p.seed, p.restarted, p.worker, true) {
            eprintln!("FAULT: injected hang at {site}");
            // A hung process will be SIGKILLed by its supervisor, so dump
            // the in-flight spans now while stderr still flows.
            crate::obs::flight::dump_stderr(site);
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
    }
}

/// True when a `leak` clause fires for `site` — the store lock's `Drop`
/// consults this to simulate a holder that dies without releasing.
pub fn leaks(site: &str) -> bool {
    let p = plan();
    p.faults
        .iter()
        .filter(|f| f.site == site)
        .any(|f| f.action == Action::Leak && fires(f, p.seed, p.restarted, p.worker, true))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_spec_parses_to_no_faults() {
        assert!(parse_spec("").unwrap().is_empty());
        assert!(parse_spec("  ;  ; ").unwrap().is_empty());
    }

    #[test]
    fn grammar_roundtrip() {
        let faults =
            parse_spec("store.read=err#2; publish=crash_after_temp; lock=hold_past_stale")
                .unwrap();
        assert_eq!(faults.len(), 3);
        assert_eq!(faults[0].action, Action::ErrCount(2));
        assert_eq!(faults[1].action, Action::Crash);
        assert_eq!(faults[2].action, Action::Leak);
        assert!(!faults[0].sticky && faults[0].worker.is_none());
    }

    #[test]
    fn suffixes_parse() {
        let faults = parse_spec("serve=hang@w0;store.read=err@0.5!;publish=crash!@w2").unwrap();
        assert_eq!(faults[0].worker, Some(0));
        assert!(!faults[0].sticky);
        assert_eq!(faults[1].action, Action::ErrProb(500));
        assert!(faults[1].sticky);
        assert!(faults[2].sticky);
        assert_eq!(faults[2].worker, Some(2));
    }

    #[test]
    fn bad_specs_error() {
        assert!(parse_spec("store.read").is_err(), "missing action");
        assert!(parse_spec("nope=err").is_err(), "unknown site");
        assert!(parse_spec("store.read=explode").is_err(), "unknown action");
        assert!(parse_spec("store.read=err@1.5").is_err(), "probability > 1");
        assert!(parse_spec("store.read=err#x").is_err(), "bad count");
    }

    #[test]
    fn err_count_fires_first_n_calls_only() {
        let f = &parse_spec("store.read=err#2").unwrap()[0];
        assert!(fires(f, 0, false, None, false));
        assert!(fires(f, 0, false, None, false));
        assert!(!fires(f, 0, false, None, false));
        assert!(!fires(f, 0, false, None, false));
    }

    #[test]
    fn err_prob_is_deterministic_and_roughly_calibrated() {
        let a = &parse_spec("store.read=err@0.5").unwrap()[0];
        let b = &parse_spec("store.read=err@0.5").unwrap()[0];
        let hits_a: Vec<bool> = (0..1000).map(|_| fires(a, 7, false, None, false)).collect();
        let hits_b: Vec<bool> = (0..1000).map(|_| fires(b, 7, false, None, false)).collect();
        assert_eq!(hits_a, hits_b, "same seed + spec must fire identically");
        let rate = hits_a.iter().filter(|h| **h).count();
        assert!((300..700).contains(&rate), "p=0.5 fired {rate}/1000");
    }

    #[test]
    fn oneshot_faults_skip_restarted_processes_unless_sticky() {
        let oneshot = &parse_spec("publish=crash").unwrap()[0];
        assert!(fires(oneshot, 0, false, None, true), "first incarnation fires");
        let oneshot = &parse_spec("publish=crash").unwrap()[0];
        assert!(!fires(oneshot, 0, true, None, true), "restart suppresses");
        let sticky = &parse_spec("publish=crash!").unwrap()[0];
        assert!(fires(sticky, 0, true, None, true), "sticky fires after restart");
    }

    #[test]
    fn worker_filter_gates_firing() {
        let f = &parse_spec("serve=hang@w1").unwrap()[0];
        assert!(!fires(f, 0, false, None, true), "no worker id → no fire");
        assert!(!fires(f, 0, false, Some(0), true), "wrong worker → no fire");
        assert!(fires(f, 0, false, Some(1), true), "matching worker fires");
    }

    #[test]
    fn hooks_are_noops_without_a_spec() {
        // The test binary runs without QRLORA_FAULTS (the suite would be
        // chaos otherwise), so the global hooks must all pass through.
        assert!(io_fault("store.read").is_ok());
        assert!(!leaks("lock"));
        crash_point("publish");
        hang_point("serve");
        assert!(!active());
    }
}
