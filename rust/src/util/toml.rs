//! TOML-subset parser for experiment / model configuration files.
//!
//! Supported: top-level key/value pairs, `[table]` and `[table.sub]` headers,
//! `[[array-of-tables]]`, strings, integers, floats, booleans, and homogeneous
//! inline arrays. Comments (`#`) and blank lines are skipped. This covers the
//! full config surface of the framework; unsupported TOML (dates, multiline
//! strings, inline tables) errors loudly rather than mis-parsing.

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum Toml {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Toml>),
    Table(BTreeMap<String, Toml>),
    /// `[[name]]` array-of-tables.
    TableArr(Vec<BTreeMap<String, Toml>>),
}

impl Toml {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Toml::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Toml::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Toml::Float(f) => Some(*f),
            Toml::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Toml::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Toml]> {
        match self {
            Toml::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_table(&self) -> Option<&BTreeMap<String, Toml>> {
        match self {
            Toml::Table(t) => Some(t),
            _ => None,
        }
    }
    /// Dotted-path lookup through nested tables: `get_path("model.d_model")`.
    pub fn get_path(&self, path: &str) -> Option<&Toml> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.as_table()?.get(part)?;
        }
        Some(cur)
    }
}

/// Parse a TOML document into a root table.
pub fn parse(text: &str) -> anyhow::Result<Toml> {
    let mut root: BTreeMap<String, Toml> = BTreeMap::new();
    // Path of the currently-open table header.
    let mut current: Vec<String> = Vec::new();
    let mut current_is_arr = false;

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| anyhow::anyhow!("toml line {}: {} ({:?})", lineno + 1, msg, raw);

        if let Some(inner) = line.strip_prefix("[[").and_then(|l| l.strip_suffix("]]")) {
            let path: Vec<String> = inner.split('.').map(|s| s.trim().to_string()).collect();
            if path.iter().any(|p| p.is_empty()) {
                return Err(err("empty table-array name"));
            }
            push_table_arr(&mut root, &path).map_err(|e| err(&e.to_string()))?;
            current = path;
            current_is_arr = true;
        } else if let Some(inner) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            let path: Vec<String> = inner.split('.').map(|s| s.trim().to_string()).collect();
            if path.iter().any(|p| p.is_empty()) {
                return Err(err("empty table name"));
            }
            ensure_table(&mut root, &path).map_err(|e| err(&e.to_string()))?;
            current = path;
            current_is_arr = false;
        } else if let Some(eq) = find_eq(line) {
            let key = line[..eq].trim();
            let val = line[eq + 1..].trim();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let v = parse_value(val).map_err(|e| err(&e.to_string()))?;
            let table = open_table(&mut root, &current, current_is_arr)
                .map_err(|e| err(&e.to_string()))?;
            if table.insert(key.to_string(), v).is_some() {
                return Err(err("duplicate key"));
            }
        } else {
            return Err(err("expected key = value or [table]"));
        }
    }
    Ok(Toml::Table(root))
}

/// Strip a trailing comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Find the first unquoted '='.
fn find_eq(line: &str) -> Option<usize> {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '=' if !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

fn ensure_table<'a>(
    root: &'a mut BTreeMap<String, Toml>,
    path: &[String],
) -> anyhow::Result<&'a mut BTreeMap<String, Toml>> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| Toml::Table(BTreeMap::new()));
        match entry {
            Toml::Table(t) => cur = t,
            Toml::TableArr(v) => {
                cur = v.last_mut().ok_or_else(|| anyhow::anyhow!("empty table array"))?
            }
            _ => anyhow::bail!("'{part}' is not a table"),
        }
    }
    Ok(cur)
}

fn push_table_arr(root: &mut BTreeMap<String, Toml>, path: &[String]) -> anyhow::Result<()> {
    let (last, prefix) = path.split_last().unwrap();
    let parent = ensure_table(root, prefix)?;
    match parent
        .entry(last.clone())
        .or_insert_with(|| Toml::TableArr(Vec::new()))
    {
        Toml::TableArr(v) => {
            v.push(BTreeMap::new());
            Ok(())
        }
        _ => anyhow::bail!("'{last}' is not an array of tables"),
    }
}

fn open_table<'a>(
    root: &'a mut BTreeMap<String, Toml>,
    path: &[String],
    is_arr: bool,
) -> anyhow::Result<&'a mut BTreeMap<String, Toml>> {
    if path.is_empty() {
        return Ok(root);
    }
    if is_arr {
        let (last, prefix) = path.split_last().unwrap();
        let parent = ensure_table(root, prefix)?;
        match parent.get_mut(last) {
            Some(Toml::TableArr(v)) => v
                .last_mut()
                .ok_or_else(|| anyhow::anyhow!("empty table array")),
            _ => anyhow::bail!("'{last}' is not an array of tables"),
        }
    } else {
        ensure_table(root, path)
    }
}

fn parse_value(s: &str) -> anyhow::Result<Toml> {
    let s = s.trim();
    if s.is_empty() {
        anyhow::bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
        // Basic escapes only.
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => anyhow::bail!("bad escape \\{other:?}"),
                }
            } else if c == '"' {
                anyhow::bail!("unescaped quote inside string");
            } else {
                out.push(c);
            }
        }
        return Ok(Toml::Str(out));
    }
    if s == "true" {
        return Ok(Toml::Bool(true));
    }
    if s == "false" {
        return Ok(Toml::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| anyhow::anyhow!("unterminated array"))?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(Toml::Arr(items));
    }
    // Number: int first, then float.
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Toml::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Toml::Float(f));
    }
    anyhow::bail!("cannot parse value {s:?}")
}

/// Split on top-level commas (no nesting beyond one array level needed, but
/// handle nested arrays anyway).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_keys() {
        let t = parse("a = 1\nb = \"x\"\nc = true\nd = 2.5\n").unwrap();
        assert_eq!(t.get_path("a").unwrap().as_i64(), Some(1));
        assert_eq!(t.get_path("b").unwrap().as_str(), Some("x"));
        assert_eq!(t.get_path("c").unwrap().as_bool(), Some(true));
        assert_eq!(t.get_path("d").unwrap().as_f64(), Some(2.5));
    }

    #[test]
    fn tables_and_nesting() {
        let src = "[model]\nd = 128\n[model.opt]\nlr = 1e-3\n[data]\nname = \"mnli\"\n";
        let t = parse(src).unwrap();
        assert_eq!(t.get_path("model.d").unwrap().as_i64(), Some(128));
        assert_eq!(t.get_path("model.opt.lr").unwrap().as_f64(), Some(1e-3));
        assert_eq!(t.get_path("data.name").unwrap().as_str(), Some("mnli"));
    }

    #[test]
    fn arrays() {
        let t = parse("taus = [0.5, 0.7, 0.8]\nnames = [\"a\", \"b\"]\nnested = [[1,2],[3]]\n")
            .unwrap();
        assert_eq!(t.get_path("taus").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            t.get_path("names").unwrap().as_arr().unwrap()[1].as_str(),
            Some("b")
        );
        assert_eq!(t.get_path("nested").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn array_of_tables() {
        let src = "[[run]]\nname = \"a\"\n[[run]]\nname = \"b\"\n";
        let t = parse(src).unwrap();
        match t.get_path("run").unwrap() {
            Toml::TableArr(v) => {
                assert_eq!(v.len(), 2);
                assert_eq!(v[1]["name"].as_str(), Some("b"));
            }
            _ => panic!("expected table array"),
        }
    }

    #[test]
    fn comments_and_blank_lines() {
        let t = parse("# header\n\na = 1 # trailing\nb = \"with # inside\"\n").unwrap();
        assert_eq!(t.get_path("a").unwrap().as_i64(), Some(1));
        assert_eq!(t.get_path("b").unwrap().as_str(), Some("with # inside"));
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse("a =").is_err());
        assert!(parse("= 1").is_err());
        assert!(parse("[unclosed\na=1").is_err());
        assert!(parse("a = 1\na = 2").is_err());
        assert!(parse("a = [1, 2").is_err());
    }

    #[test]
    fn underscores_in_numbers() {
        let t = parse("n = 92_160\n").unwrap();
        assert_eq!(t.get_path("n").unwrap().as_i64(), Some(92160));
    }
}
