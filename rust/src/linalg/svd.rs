//! One-sided Jacobi SVD. Used to initialize the SVD-LoRA baseline (the
//! paper's comparator that seeds LoRA's A/B from the top-k singular
//! vectors) and in tests as an independent check on the QR energy ranking.

use crate::tensor::Tensor;

/// Thin SVD of `A` (m×n, m ≥ n after internal transposition handling):
/// `A = U · diag(s) · Vᵀ`, U m×n, s length n (descending), V n×n.
#[derive(Clone, Debug)]
pub struct Svd {
    pub u: Tensor,
    pub s: Vec<f32>,
    pub v: Tensor,
}

impl Svd {
    pub fn reconstruct(&self) -> Tensor {
        let n = self.s.len();
        let mut us = self.u.clone();
        for i in 0..us.rows() {
            for j in 0..n {
                us.set(i, j, us.at(i, j) * self.s[j]);
            }
        }
        us.matmul(&self.v.t())
    }

    /// Rank-k truncation: (U_k scaled by √s, √s V_kᵀ) — the symmetric split
    /// SVD-LoRA uses for B/A initialization.
    pub fn split_factors(&self, k: usize) -> (Tensor, Tensor) {
        let k = k.min(self.s.len());
        let m = self.u.rows();
        let n = self.v.rows();
        let mut b = Tensor::zeros(&[m, k]);
        let mut a = Tensor::zeros(&[k, n]);
        for j in 0..k {
            let rs = self.s[j].max(0.0).sqrt();
            for i in 0..m {
                b.set(i, j, self.u.at(i, j) * rs);
            }
            for i in 0..n {
                a.set(j, i, self.v.at(i, j) * rs);
            }
        }
        (b, a)
    }
}

/// One-sided Jacobi SVD. Handles any m×n by transposing internally when
/// m < n. Converges quadratically; `max_sweeps` bounds worst-case work.
pub fn jacobi_svd(a: &Tensor) -> Svd {
    let (m, n) = (a.rows(), a.cols());
    if m < n {
        // A = U S Vᵀ  ⇔  Aᵀ = V S Uᵀ
        let f = jacobi_svd(&a.t());
        return Svd {
            u: f.v,
            s: f.s,
            v: f.u,
        };
    }

    let mut u = a.clone(); // columns get orthogonalized in place
    let mut v = Tensor::eye(n);
    let max_sweeps = 60;
    let eps = 1e-12f64;

    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Gram entries over columns p, q.
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let up = u.at(i, p) as f64;
                    let uq = u.at(i, q) as f64;
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation that zeroes the (p,q) Gram entry.
                let zeta = (aqq - app) / (2.0 * apq);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let up = u.at(i, p) as f64;
                    let uq = u.at(i, q) as f64;
                    u.set(i, p, (c * up - s * uq) as f32);
                    u.set(i, q, (s * up + c * uq) as f32);
                }
                for i in 0..n {
                    let vp = v.at(i, p) as f64;
                    let vq = v.at(i, q) as f64;
                    v.set(i, p, (c * vp - s * vq) as f32);
                    v.set(i, q, (s * vp + c * vq) as f32);
                }
            }
        }
        if off < 1e-10 {
            break;
        }
    }

    // Singular values = column norms; normalize U.
    let mut s: Vec<f32> = (0..n)
        .map(|j| {
            let nrm = (0..m).map(|i| (u.at(i, j) as f64).powi(2)).sum::<f64>().sqrt();
            nrm as f32
        })
        .collect();
    for j in 0..n {
        if s[j] > 0.0 {
            for i in 0..m {
                u.set(i, j, u.at(i, j) / s[j]);
            }
        }
    }

    // Sort descending by singular value.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| s[j].partial_cmp(&s[i]).unwrap());
    let u = u.permute_cols(&order);
    let v = v.permute_cols(&order);
    s = order.iter().map(|&i| s[i]).collect();

    Svd { u, s, v }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::orthonormality_defect;
    use crate::util::rng::Rng;

    #[test]
    fn reconstructs_random() {
        let mut rng = Rng::new(20);
        for (m, n) in [(6usize, 6usize), (10, 4), (4, 10), (1, 5), (12, 12)] {
            let a = Tensor::randn(&[m, n], &mut rng, 1.0);
            let f = jacobi_svd(&a);
            let err = f.reconstruct().max_abs_diff(&a);
            assert!(err < 5e-4, "{m}x{n}: err {err}");
        }
    }

    #[test]
    fn factors_orthonormal() {
        let mut rng = Rng::new(21);
        let a = Tensor::randn(&[9, 6], &mut rng, 1.0);
        let f = jacobi_svd(&a);
        assert!(orthonormality_defect(&f.u) < 1e-4);
        assert!(orthonormality_defect(&f.v) < 1e-4);
    }

    #[test]
    fn singular_values_sorted_nonnegative() {
        let mut rng = Rng::new(22);
        let a = Tensor::randn(&[8, 8], &mut rng, 2.0);
        let f = jacobi_svd(&a);
        for i in 0..f.s.len() {
            assert!(f.s[i] >= 0.0);
            if i > 0 {
                assert!(f.s[i] <= f.s[i - 1] + 1e-5);
            }
        }
    }

    #[test]
    fn diagonal_matrix_exact() {
        let mut a = Tensor::zeros(&[4, 4]);
        for (i, v) in [3.0f32, 7.0, 1.0, 5.0].iter().enumerate() {
            a.set(i, i, *v);
        }
        let f = jacobi_svd(&a);
        let want = [7.0, 5.0, 3.0, 1.0];
        for (got, want) in f.s.iter().zip(want.iter()) {
            assert!((got - want).abs() < 1e-4, "{got} vs {want}");
        }
    }

    #[test]
    fn rank_deficient_trailing_zeros() {
        let mut rng = Rng::new(23);
        let u = Tensor::randn(&[8, 2], &mut rng, 1.0);
        let v = Tensor::randn(&[2, 8], &mut rng, 1.0);
        let a = u.matmul(&v);
        let f = jacobi_svd(&a);
        assert!(f.s[1] > 1e-3);
        for &x in &f.s[2..] {
            assert!(x < 1e-3, "trailing σ {x}");
        }
    }

    #[test]
    fn split_factors_product_matches_truncation() {
        let mut rng = Rng::new(24);
        let a = Tensor::randn(&[6, 6], &mut rng, 1.0);
        let f = jacobi_svd(&a);
        let (b, aa) = f.split_factors(6);
        assert!(b.matmul(&aa).max_abs_diff(&a) < 5e-4);
        // k=1 gives the best rank-1 approximation; error bounded by σ₂.
        let (b1, a1) = f.split_factors(1);
        let approx = b1.matmul(&a1);
        let mut diff = a.clone();
        for (d, ap) in diff.data.iter_mut().zip(&approx.data) {
            *d -= ap;
        }
        // Spectral norm of the residual is σ₂; Frobenius ≤ √(n-1)·σ₂.
        let bound = ((f.s.len() - 1) as f64).sqrt() * f.s[1] as f64 + 1e-3;
        assert!(diff.fro_norm() <= bound);
    }

    #[test]
    fn svd_energy_agrees_with_qr_ordering() {
        // The pivoted-QR diagonal and the singular values both measure
        // column-space energy; their totals must match (|det| invariance
        // is too strong for f32, but Frobenius energy matches exactly:
        // Σ R_ij² = Σ σ_i² = ||A||_F²).
        let mut rng = Rng::new(25);
        let a = Tensor::randn(&[10, 10], &mut rng, 1.0);
        let sv = jacobi_svd(&a);
        let total_sv: f64 = sv.s.iter().map(|&x| (x as f64).powi(2)).sum();
        let fro2 = a.fro_norm().powi(2);
        assert!((total_sv - fro2).abs() / fro2 < 1e-4);
    }
}
