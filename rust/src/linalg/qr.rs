//! Householder QR with column pivoting (Businger–Golub; Golub & Van Loan
//! §5.4.2). Produces the ordered orthonormal basis QR-LoRA adapts over.

use crate::tensor::Tensor;

/// Result of a pivoted QR factorization of `A` (m×n):
/// `A[:, perm] = Q R` with Q (m×t) orthonormal, R (t×n) upper-triangular,
/// t = min(m, n), and `|R[0,0]| ≥ |R[1,1]| ≥ …` by construction.
#[derive(Clone, Debug)]
pub struct PivotedQr {
    pub q: Tensor,
    pub r: Tensor,
    /// Column permutation: original column `perm[j]` landed in position `j`.
    pub perm: Vec<usize>,
}

impl PivotedQr {
    /// |diagonal of R| — the energy ranking of basis directions.
    pub fn diag(&self) -> Vec<f32> {
        let t = self.r.rows().min(self.r.cols());
        (0..t).map(|i| self.r.at(i, i)).collect()
    }

    /// R with its columns mapped back to the original column order of A:
    /// `R̃[:, perm[j]] = R[:, j]`, so `A = Q · R̃` exactly.
    ///
    /// The paper's update ΔW = Σ λᵢ Qᵢ Rᵢᵀ implicitly works in the original
    /// column space; with pivoting this is only well-defined after
    /// un-permuting R (with λ ≡ 1 for all i, ΔW then reconstructs W itself).
    pub fn r_unpermuted(&self) -> Tensor {
        let (t, n) = (self.r.rows(), self.r.cols());
        let mut out = Tensor::zeros(&[t, n]);
        for i in 0..t {
            for j in 0..n {
                out.set(i, self.perm[j], self.r.at(i, j));
            }
        }
        out
    }

    /// Q·R (still in permuted column order).
    pub fn reconstruct_permuted(&self) -> Tensor {
        self.q.matmul(&self.r)
    }

    /// Q·R̃ — reconstruction in the original column order (equals A up to
    /// floating-point error).
    pub fn reconstruct(&self) -> Tensor {
        self.q.matmul(&self.r_unpermuted())
    }

    /// The rank-r truncated factors in original column order:
    /// (Q_r: m×r, R̃_r: r×n). ΔW = Q_r · diag(λ) · R̃_r.
    pub fn truncate(&self, r: usize) -> (Tensor, Tensor) {
        let r = r.min(self.q.cols());
        let q_r = self.q.slice_cols(0, r);
        let r_full = self.r_unpermuted();
        let r_r = r_full.slice_rows(0, r);
        (q_r, r_r)
    }
}

/// Pivoted Householder QR. `a` is m×n; returns thin factors with
/// t = min(m, n) columns of Q / rows of R.
pub fn pivoted_qr(a: &Tensor) -> PivotedQr {
    qr_impl(a, true)
}

/// Unpivoted Householder QR (perm = identity). Used where a plain
/// orthonormal basis is enough (e.g. OLoRA-style ablations).
pub fn householder_qr(a: &Tensor) -> PivotedQr {
    qr_impl(a, false)
}

fn qr_impl(a: &Tensor, pivot: bool) -> PivotedQr {
    let (m, n) = (a.rows(), a.cols());
    let t = m.min(n);
    // Working copy; Householder vectors are stored below the diagonal,
    // R on and above it.
    let mut w = a.clone();
    let mut perm: Vec<usize> = (0..n).collect();
    // Squared column norms for pivot selection, downdated each step and
    // recomputed when cancellation makes them unreliable.
    let mut norms: Vec<f64> = (0..n)
        .map(|j| (0..m).map(|i| (w.at(i, j) as f64).powi(2)).sum())
        .collect();
    let norms_orig = norms.clone();
    // Householder scalars β_k (H = I - β v vᵀ).
    let mut betas = vec![0.0f64; t];

    for k in 0..t {
        if pivot {
            // Select the remaining column with the largest updated norm.
            let (p, _) = norms
                .iter()
                .enumerate()
                .skip(k)
                .fold((k, f64::NEG_INFINITY), |acc, (j, &v)| {
                    if v > acc.1 {
                        (j, v)
                    } else {
                        acc
                    }
                });
            if p != k {
                for i in 0..m {
                    let tmp = w.at(i, k);
                    w.set(i, k, w.at(i, p));
                    w.set(i, p, tmp);
                }
                norms.swap(k, p);
                perm.swap(k, p);
            }
        }

        // Householder vector for column k, rows k..m.
        let mut sig = 0.0f64;
        for i in k..m {
            sig += (w.at(i, k) as f64).powi(2);
        }
        let norm = sig.sqrt();
        if norm == 0.0 {
            betas[k] = 0.0;
            continue;
        }
        let x0 = w.at(k, k) as f64;
        let alpha = if x0 >= 0.0 { -norm } else { norm };
        // v = x - alpha e1, stored in-place; R[k,k] = alpha.
        let v0 = x0 - alpha;
        let vtv = sig - x0 * x0 + v0 * v0;
        let beta = if vtv == 0.0 { 0.0 } else { 2.0 / vtv };
        betas[k] = beta;
        w.set(k, k, alpha as f32);
        // Store v (rows k+1..m keep their x values; v0 kept separately via
        // implicit convention). To keep things simple we scale v so v0 = 1:
        // v_i <- x_i / v0 for i > k, and fold v0 into beta.
        if v0 != 0.0 {
            for i in k + 1..m {
                w.set(i, k, (w.at(i, k) as f64 / v0) as f32);
            }
            betas[k] = beta * v0 * v0;
        }

        // Apply H_k to the trailing columns.
        let bk = betas[k];
        if bk != 0.0 {
            for j in k + 1..n {
                // s = vᵀ col_j  (v0 = 1 implicit)
                let mut s = w.at(k, j) as f64;
                for i in k + 1..m {
                    s += (w.at(i, k) as f64) * (w.at(i, j) as f64);
                }
                let s = s * bk;
                w.set(k, j, (w.at(k, j) as f64 - s) as f32);
                for i in k + 1..m {
                    let wi = w.at(i, j) as f64 - s * (w.at(i, k) as f64);
                    w.set(i, j, wi as f32);
                }
            }
        }

        if pivot {
            // Downdate norms; recompute when cancellation is severe.
            for j in k + 1..n {
                let rij = (w.at(k, j) as f64).powi(2);
                norms[j] -= rij;
                if norms[j] < 1e-10 * norms_orig[j] || norms[j] < 0.0 {
                    norms[j] = (k + 1..m).map(|i| (w.at(i, j) as f64).powi(2)).sum();
                }
            }
        }
    }

    // Extract R (t×n upper triangular).
    let mut r = Tensor::zeros(&[t, n]);
    for i in 0..t {
        for j in i..n {
            r.set(i, j, w.at(i, j));
        }
    }

    // Accumulate thin Q (m×t): apply H_0 … H_{t-1} to the first t columns
    // of the identity, in reverse order.
    let mut q = Tensor::zeros(&[m, t]);
    for j in 0..t {
        q.set(j, j, 1.0);
    }
    for k in (0..t).rev() {
        let bk = betas[k];
        if bk == 0.0 {
            continue;
        }
        for j in 0..t {
            let mut s = q.at(k, j) as f64;
            for i in k + 1..m {
                s += (w.at(i, k) as f64) * (q.at(i, j) as f64);
            }
            let s = s * bk;
            q.set(k, j, (q.at(k, j) as f64 - s) as f32);
            for i in k + 1..m {
                let qi = q.at(i, j) as f64 - s * (w.at(i, k) as f64);
                q.set(i, j, qi as f32);
            }
        }
    }

    PivotedQr { q, r, perm }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::orthonormality_defect;
    use crate::util::rng::Rng;

    fn reconstruction_error(a: &Tensor, f: &PivotedQr) -> f32 {
        f.reconstruct().max_abs_diff(a)
    }

    #[test]
    fn square_random_reconstructs() {
        let mut rng = Rng::new(10);
        for n in [1usize, 2, 5, 16, 48] {
            let a = Tensor::randn(&[n, n], &mut rng, 1.0);
            let f = pivoted_qr(&a);
            assert!(
                reconstruction_error(&a, &f) < 2e-4,
                "n={n} err={}",
                reconstruction_error(&a, &f)
            );
            assert!(orthonormality_defect(&f.q) < 1e-4, "n={n}");
        }
    }

    #[test]
    fn rectangular_shapes() {
        let mut rng = Rng::new(11);
        for (m, n) in [(8usize, 3usize), (3, 8), (16, 5), (5, 16), (1, 4), (4, 1)] {
            let a = Tensor::randn(&[m, n], &mut rng, 1.0);
            let f = pivoted_qr(&a);
            let t = m.min(n);
            assert_eq!(f.q.shape, vec![m, t]);
            assert_eq!(f.r.shape, vec![t, n]);
            assert!(reconstruction_error(&a, &f) < 2e-4, "{m}x{n}");
            assert!(orthonormality_defect(&f.q) < 1e-4, "{m}x{n}");
        }
    }

    #[test]
    fn diag_nonincreasing() {
        let mut rng = Rng::new(12);
        for _ in 0..10 {
            let a = Tensor::randn(&[24, 24], &mut rng, 1.0);
            let d = pivoted_qr(&a).diag();
            for i in 1..d.len() {
                assert!(
                    d[i].abs() <= d[i - 1].abs() * (1.0 + 1e-4),
                    "diag not ordered at {i}: {} > {}",
                    d[i].abs(),
                    d[i - 1].abs()
                );
            }
        }
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Rng::new(13);
        let a = Tensor::randn(&[10, 10], &mut rng, 1.0);
        let f = pivoted_qr(&a);
        for i in 0..10 {
            for j in 0..i {
                assert_eq!(f.r.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn rank_deficient_exposes_zero_tail() {
        // Build a rank-3 10×10 matrix; pivoted diag should collapse after 3.
        let mut rng = Rng::new(14);
        let u = Tensor::randn(&[10, 3], &mut rng, 1.0);
        let v = Tensor::randn(&[3, 10], &mut rng, 1.0);
        let a = u.matmul(&v);
        let d = pivoted_qr(&a).diag();
        assert!(d[2].abs() > 1e-2);
        for &x in &d[3..] {
            assert!(x.abs() < 1e-3, "tail diag {x}");
        }
    }

    #[test]
    fn pivoting_beats_no_pivoting_on_graded_matrix() {
        // Columns with wildly different scales: pivoted diag must be ordered,
        // unpivoted generally is not.
        let mut rng = Rng::new(15);
        let n = 12;
        let mut a = Tensor::randn(&[n, n], &mut rng, 1.0);
        for j in 0..n {
            let s = 10f32.powi(-(((j * 7) % n) as i32) / 2);
            for i in 0..n {
                a.set(i, j, a.at(i, j) * s);
            }
        }
        let dp = pivoted_qr(&a).diag();
        for i in 1..dp.len() {
            assert!(dp[i].abs() <= dp[i - 1].abs() * (1.0 + 1e-4));
        }
        let f = householder_qr(&a);
        assert!(reconstruction_error(&a, &f) < 2e-4);
    }

    #[test]
    fn truncate_full_rank_reconstructs() {
        let mut rng = Rng::new(16);
        let a = Tensor::randn(&[8, 8], &mut rng, 1.0);
        let f = pivoted_qr(&a);
        let (q, r) = f.truncate(8);
        assert!(q.matmul(&r).max_abs_diff(&a) < 2e-4);
    }

    #[test]
    fn truncation_error_decreases_with_rank() {
        let mut rng = Rng::new(17);
        let a = Tensor::randn(&[16, 16], &mut rng, 1.0);
        let f = pivoted_qr(&a);
        let mut last = f64::INFINITY;
        for r in [2usize, 4, 8, 12, 16] {
            let (q, rr) = f.truncate(r);
            let mut diff = a.clone();
            let approx = q.matmul(&rr);
            for (d, ap) in diff.data.iter_mut().zip(&approx.data) {
                *d -= ap;
            }
            let err = diff.fro_norm();
            assert!(err <= last + 1e-4, "r={r}: {err} > {last}");
            last = err;
        }
        assert!(last < 2e-3);
    }

    #[test]
    fn identity_matrix() {
        let a = Tensor::eye(6);
        let f = pivoted_qr(&a);
        assert!(reconstruction_error(&a, &f) < 1e-6);
        for d in f.diag() {
            assert!((d.abs() - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn zero_matrix_is_handled() {
        let a = Tensor::zeros(&[5, 5]);
        let f = pivoted_qr(&a);
        assert!(f.reconstruct().max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn property_random_sizes() {
        // Generative sweep: arbitrary shapes and scales reconstruct and
        // stay orthonormal.
        let mut rng = Rng::new(18);
        for trial in 0..25 {
            let m = rng.range(1, 30);
            let n = rng.range(1, 30);
            let scale = 10f32.powi(rng.range(0, 5) as i32 - 2);
            let a = Tensor::randn(&[m, n], &mut rng, scale);
            let f = pivoted_qr(&a);
            let err = reconstruction_error(&a, &f);
            let tol = 2e-4 * scale.max(1.0);
            assert!(err < tol, "trial {trial} ({m}x{n}, scale {scale}): err {err}");
            assert!(orthonormality_defect(&f.q) < 1e-4, "trial {trial}");
            let d = f.diag();
            for i in 1..d.len() {
                assert!(d[i].abs() <= d[i - 1].abs() * (1.0 + 1e-3));
            }
        }
    }
}
