//! Numerical linear algebra substrate: pivoted QR (the heart of QR-LoRA),
//! one-sided Jacobi SVD (for the SVD-LoRA baseline), and rank-selection
//! rules.
//!
//! The paper extracts an orthonormal basis from each frozen weight matrix
//! with QR decomposition **with column pivoting** (Businger–Golub), so the
//! diagonal of R ranks basis directions by energy: |R₁₁| ≥ |R₂₂| ≥ ….
//! The coordinator performs this extraction host-side once per adapted
//! matrix; the resulting (Q_r, R_r) factors are then fed to the XLA graph
//! as frozen inputs.

mod qr;
mod svd;

pub use qr::{householder_qr, pivoted_qr, PivotedQr};
pub use svd::{jacobi_svd, Svd};

use crate::tensor::Tensor;

/// How to choose the retained rank r from the pivoted-R diagonal.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RankRule {
    /// §4.1 of the paper: r = #{ i : |R_ii| > τ·|R₁₁| }.
    DiagRatio,
    /// Eq. (4): smallest r with Σ_{i≤r} R_ii² / Σ_i R_ii² ≥ τ.
    EnergyCumulative,
}

/// Select the retained rank from the diagonal of a pivoted R factor.
/// Always returns at least 1 (an adapter with zero directions is useless
/// and would break downstream shape plumbing).
pub fn select_rank(diag: &[f32], tau: f64, rule: RankRule) -> usize {
    assert!(!diag.is_empty());
    assert!((0.0..=1.0).contains(&tau), "tau must be in [0,1], got {tau}");
    let r = match rule {
        RankRule::DiagRatio => {
            let head = diag[0].abs() as f64;
            if head == 0.0 {
                1
            } else {
                diag.iter().filter(|d| d.abs() as f64 > tau * head).count()
            }
        }
        RankRule::EnergyCumulative => {
            let total: f64 = diag.iter().map(|&d| (d as f64) * (d as f64)).sum();
            if total == 0.0 {
                1
            } else {
                let mut acc = 0.0;
                let mut r = diag.len();
                for (i, &d) in diag.iter().enumerate() {
                    acc += (d as f64) * (d as f64);
                    if acc / total >= tau {
                        r = i + 1;
                        break;
                    }
                }
                r
            }
        }
    };
    r.max(1)
}

/// Max |QᵀQ - I| — orthonormality defect of the columns of `q`.
pub fn orthonormality_defect(q: &Tensor) -> f32 {
    let qtq = q.t().matmul(q);
    let n = qtq.rows();
    let mut worst = 0.0f32;
    for i in 0..n {
        for j in 0..n {
            let want = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((qtq.at(i, j) - want).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diag_ratio_rule() {
        let diag = [10.0, 6.0, 5.0, 0.5, 0.1];
        // Strict inequality: |R_ii| > τ·|R₁₁|.
        assert_eq!(select_rank(&diag, 0.49, RankRule::DiagRatio), 3);
        assert_eq!(select_rank(&diag, 0.5, RankRule::DiagRatio), 2);
        assert_eq!(select_rank(&diag, 0.04, RankRule::DiagRatio), 4);
        assert_eq!(select_rank(&diag, 0.99, RankRule::DiagRatio), 1);
    }

    #[test]
    fn energy_rule() {
        let diag = [3.0, 4.0, 0.0]; // energies 9, 16 — unordered on purpose
        // cumulative: 9/25 = 0.36, 25/25 = 1.0
        assert_eq!(select_rank(&diag, 0.3, RankRule::EnergyCumulative), 1);
        assert_eq!(select_rank(&diag, 0.5, RankRule::EnergyCumulative), 2);
        assert_eq!(select_rank(&diag, 1.0, RankRule::EnergyCumulative), 2);
    }

    #[test]
    fn energy_rule_monotone_in_tau() {
        let diag: Vec<f32> = (1..=20).rev().map(|x| x as f32).collect();
        let mut last = 0;
        for t in [0.1, 0.3, 0.5, 0.7, 0.9, 0.99] {
            let r = select_rank(&diag, t, RankRule::EnergyCumulative);
            assert!(r >= last, "rank not monotone at tau={t}");
            last = r;
        }
    }

    #[test]
    fn rank_at_least_one() {
        assert_eq!(select_rank(&[0.0, 0.0], 0.9, RankRule::DiagRatio), 1);
        assert_eq!(select_rank(&[0.0, 0.0], 0.9, RankRule::EnergyCumulative), 1);
    }

    #[test]
    fn diag_ratio_extremes() {
        let diag: Vec<f32> = (1..=10).rev().map(|x| x as f32).collect(); // 10..1
        // τ = 0: every direction with |R_ii| > 0 is retained.
        assert_eq!(select_rank(&diag, 0.0, RankRule::DiagRatio), 10);
        // τ = 1: strict inequality |R_ii| > |R₀₀| retains none → clamped to 1.
        assert_eq!(select_rank(&diag, 1.0, RankRule::DiagRatio), 1);
        // τ = 0 with a zero tail only keeps the nonzero prefix.
        let with_tail = [4.0f32, 2.0, 0.0, 0.0];
        assert_eq!(select_rank(&with_tail, 0.0, RankRule::DiagRatio), 2);
    }

    #[test]
    fn energy_extremes() {
        let diag: Vec<f32> = vec![2.0; 8]; // equal energies
        // τ = 0: first direction already reaches the (trivial) target.
        assert_eq!(select_rank(&diag, 0.0, RankRule::EnergyCumulative), 1);
        // τ = 1: all directions needed to reach full energy.
        assert_eq!(select_rank(&diag, 1.0, RankRule::EnergyCumulative), 8);
        // zero tail: full energy reached before the tail.
        let with_tail = [3.0f32, 4.0, 0.0, 0.0];
        assert_eq!(select_rank(&with_tail, 1.0, RankRule::EnergyCumulative), 2);
    }

    #[test]
    #[should_panic(expected = "tau must be in [0,1]")]
    fn tau_out_of_range_panics() {
        select_rank(&[1.0], 1.5, RankRule::DiagRatio);
    }

    #[test]
    fn single_direction_always_retained() {
        assert_eq!(select_rank(&[5.0], 0.0, RankRule::DiagRatio), 1);
        assert_eq!(select_rank(&[5.0], 1.0, RankRule::DiagRatio), 1);
        assert_eq!(select_rank(&[5.0], 1.0, RankRule::EnergyCumulative), 1);
    }
}
