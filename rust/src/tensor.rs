//! Host-side dense f32 matrix/tensor type.
//!
//! The coordinator needs real numerics of its own — pivoted QR / SVD basis
//! extraction, adapter merging, metric math — independent of the XLA device
//! graph. This module provides a row-major f32 `Tensor` with the operations
//! those paths need, plus `.npy` I/O for interop with the python build-time
//! tests.

use std::io::{Read, Write};
use std::path::Path;

use crate::kernels;
use crate::util::pool;

/// Dense row-major f32 tensor. Rank ≤ 4 in practice; most linalg paths use
/// rank-2 views via `rows()`/`cols()`.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    /// Dimension sizes, outermost first.
    pub shape: Vec<usize>,
    /// Row-major elements (`shape.iter().product()` of them).
    pub data: Vec<f32>,
}

impl Tensor {
    /// All-zero tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    /// Constant-filled tensor of the given shape.
    pub fn filled(shape: &[usize], v: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; n],
        }
    }

    /// Wrap an owned vector as a tensor (panics on shape/length mismatch).
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Tensor {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    /// Random normal entries scaled by `std` (for init and tests).
    pub fn randn(shape: &[usize], rng: &mut crate::util::rng::Rng, std: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: (0..n).map(|_| rng.normal() * std).collect(),
        }
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Number of rows (rank-2 only).
    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "rows() on rank-{} tensor", self.shape.len());
        self.shape[0]
    }

    /// Number of columns (rank-2 only).
    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "cols() on rank-{} tensor", self.shape.len());
        self.shape[1]
    }

    /// Element `(i, j)` of a rank-2 tensor.
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.shape[1] + j]
    }

    /// Set element `(i, j)` of a rank-2 tensor.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.shape[1] + j] = v;
    }

    /// Immutable row slice (rank-2).
    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.shape[1];
        &self.data[i * c..(i + 1) * c]
    }

    /// Mutable row slice (rank-2).
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.shape[1];
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Copy of column `j` (rank-2).
    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows()).map(|i| self.at(i, j)).collect()
    }

    /// Matrix transpose (rank-2). Row-parallel over output rows for large
    /// matrices (every `matmul` transposes its RHS, so this is on the hot
    /// path); each output row is one strided column gather.
    pub fn t(&self) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&[n, m]);
        if m == 0 || n == 0 {
            return out;
        }
        pool::par_rows(&mut out.data, n, m.saturating_mul(n), |j0, chunk| {
            for (jj, orow) in chunk.chunks_mut(m).enumerate() {
                let j = j0 + jj;
                for (i, o) in orow.iter_mut().enumerate() {
                    *o = self.data[i * n + j];
                }
            }
        });
        out
    }

    /// Matrix multiply `self (m×k) @ other (k×n)`.
    ///
    /// Transposes `other` once so every output element is a dot product of
    /// two contiguous slices — the `kernels` dot microkernel then runs on
    /// contiguous data, which is 2–4× faster than the previous i-k-j saxpy
    /// loop at the hot shapes (see the `matmul` entries in
    /// `benches/bench_main.rs`).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        let k = self.cols();
        let k2 = other.rows();
        assert_eq!(k, k2, "matmul shape mismatch: {:?} @ {:?}", self.shape, other.shape);
        self.matmul_t(&other.t())
    }

    /// `self (m×k) @ otherᵀ` where `other` is (n×k) — no transpose needed,
    /// both operands stream contiguously.
    ///
    /// Row-parallel: output rows are partitioned into one contiguous span
    /// per pool lane (`util::pool`), each span handed to
    /// [`kernels::Kernels::matmul_xw_t`] (which keeps the serial kernel's
    /// column blocking). Every output element is still one dot of the same
    /// two slices, so results are bit-identical for any thread count;
    /// shapes below the pool's work cutoff stay on the serial path.
    pub fn matmul_t(&self, other: &Tensor) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (n, k2) = (other.rows(), other.cols());
        assert_eq!(
            k, k2,
            "matmul_t shape mismatch: {:?} @ t{:?}",
            self.shape, other.shape
        );
        let mut out = Tensor::zeros(&[m, n]);
        if m == 0 || n == 0 {
            return out;
        }
        // Resolve the kernel selection on this thread: pool workers do not
        // see the caller's `kernels::with_kernels` override.
        let kern = kernels::active();
        let work = m.saturating_mul(n).saturating_mul(k.max(1));
        pool::par_rows(&mut out.data, m, work, |row0, chunk| {
            let rows = chunk.len() / n;
            let a_rows = &self.data[row0 * k..(row0 + rows) * k];
            kern.matmul_xw_t(a_rows, &other.data, k, n, chunk);
        });
        out
    }

    /// `selfᵀ (k×m) @ other (m×n)` — the gradient contraction `xᵀ·dy`,
    /// computed as a sum of row outer products (both reads contiguous).
    ///
    /// Row-parallel over *output* rows (columns of `self`): each span
    /// accumulates over `m` in the serial order, so results are
    /// bit-identical for any thread count.
    ///
    /// The `a == 0.0` skip inside [`kernels::Kernels::matmul_xt_y`] keeps
    /// its place on purpose: its cost is one compare amortized over an
    /// `n`-wide axpy (<1% on dense inputs — see the paired
    /// `t_matmul … dense/sparse-rows` entries in `benches/bench_main.rs`),
    /// while the MLM gradient contraction `dlogitsᵀ·h` hits it on every
    /// masked-out position (typically ~85% of rows are exactly zero),
    /// skipping the whole axpy there.
    pub fn t_matmul(&self, other: &Tensor) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (m2, n) = (other.rows(), other.cols());
        assert_eq!(
            m, m2,
            "t_matmul shape mismatch: t{:?} @ {:?}",
            self.shape, other.shape
        );
        let mut out = Tensor::zeros(&[k, n]);
        if k == 0 || n == 0 {
            return out;
        }
        let kern = kernels::active();
        let work = m.saturating_mul(n).saturating_mul(k.max(1));
        pool::par_rows(&mut out.data, k, work, |i0, chunk| {
            kern.matmul_xt_y(&self.data, &other.data, m, k, n, i0, chunk);
        });
        out
    }

    /// Elementwise in-place add.
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Elementwise in-place scale.
    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Max absolute entrywise difference.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Rows `[lo, hi)` as a new tensor (rank-2).
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Tensor {
        let c = self.cols();
        Tensor::from_vec(&[hi - lo, c], self.data[lo * c..hi * c].to_vec())
    }

    /// Columns `[lo, hi)` as a new tensor (rank-2).
    pub fn slice_cols(&self, lo: usize, hi: usize) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let w = hi - lo;
        let mut out = Tensor::zeros(&[m, w]);
        for i in 0..m {
            out.data[i * w..(i + 1) * w].copy_from_slice(&self.data[i * n + lo..i * n + hi]);
        }
        out
    }

    /// Reorder columns by `perm`: `out[:, j] = self[:, perm[j]]`.
    pub fn permute_cols(&self, perm: &[usize]) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        assert_eq!(perm.len(), n);
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for (j, &p) in perm.iter().enumerate() {
                out.data[i * n + j] = self.data[i * n + p];
            }
        }
        out
    }

    /// Write in NumPy `.npy` v1.0 format (f32 little-endian, C order).
    pub fn save_npy(&self, path: &Path) -> anyhow::Result<()> {
        let shape_str = match self.shape.len() {
            1 => format!("({},)", self.shape[0]),
            _ => format!(
                "({})",
                self.shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
            ),
        };
        let mut header = format!(
            "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}"
        );
        // Pad so that magic(6)+ver(2)+hlen(2)+header is a multiple of 64.
        let unpadded = 10 + header.len() + 1;
        let pad = (64 - unpadded % 64) % 64;
        header.push_str(&" ".repeat(pad));
        header.push('\n');

        let mut f = std::fs::File::create(path)?;
        f.write_all(b"\x93NUMPY\x01\x00")?;
        f.write_all(&(header.len() as u16).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        let mut bytes = Vec::with_capacity(self.data.len() * 4);
        for v in &self.data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        f.write_all(&bytes)?;
        Ok(())
    }

    /// Read a `.npy` file (f32 or f64 little-endian, C order).
    pub fn load_npy(path: &Path) -> anyhow::Result<Tensor> {
        let mut f = std::fs::File::open(path)?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        if buf.len() < 10 || &buf[..6] != b"\x93NUMPY" {
            anyhow::bail!("{path:?}: not an npy file");
        }
        let (hlen, hstart) = if buf[6] == 1 {
            (u16::from_le_bytes([buf[8], buf[9]]) as usize, 10)
        } else {
            (
                u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]) as usize,
                12,
            )
        };
        let header = std::str::from_utf8(&buf[hstart..hstart + hlen])?;
        let fortran = header.contains("'fortran_order': True");
        if fortran {
            anyhow::bail!("{path:?}: fortran order unsupported");
        }
        let descr_f32 = header.contains("'<f4'");
        let descr_f64 = header.contains("'<f8'");
        if !descr_f32 && !descr_f64 {
            anyhow::bail!("{path:?}: unsupported dtype in {header}");
        }
        let shape_txt = header
            .split("'shape':")
            .nth(1)
            .and_then(|s| s.split('(').nth(1))
            .and_then(|s| s.split(')').next())
            .ok_or_else(|| anyhow::anyhow!("bad npy header: {header}"))?;
        let shape: Vec<usize> = shape_txt
            .split(',')
            .filter_map(|p| {
                let p = p.trim();
                if p.is_empty() {
                    None
                } else {
                    Some(p.parse::<usize>())
                }
            })
            .collect::<Result<_, _>>()?;
        let n: usize = shape.iter().product();
        let body = &buf[hstart + hlen..];
        let data: Vec<f32> = if descr_f32 {
            if body.len() < n * 4 {
                anyhow::bail!("{path:?}: truncated body");
            }
            body.chunks_exact(4)
                .take(n)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()
        } else {
            if body.len() < n * 8 {
                anyhow::bail!("{path:?}: truncated body");
            }
            body.chunks_exact(8)
                .take(n)
                .map(|c| {
                    f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]) as f32
                })
                .collect()
        };
        let shape = if shape.is_empty() { vec![1] } else { shape };
        Ok(Tensor::from_vec(&shape, data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut r = Rng::new(1);
        let a = Tensor::randn(&[5, 5], &mut r, 1.0);
        let i = Tensor::eye(5);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-6);
        assert!(i.matmul(&a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn matmul_rectangular() {
        let mut r = Rng::new(2);
        let a = Tensor::randn(&[3, 7], &mut r, 1.0);
        let b = Tensor::randn(&[7, 4], &mut r, 1.0);
        let c = a.matmul(&b);
        assert_eq!(c.shape, vec![3, 4]);
        // Spot-check one entry.
        let mut want = 0.0f32;
        for k in 0..7 {
            want += a.at(1, k) * b.at(k, 2);
        }
        assert!((c.at(1, 2) - want).abs() < 1e-4);
    }

    #[test]
    fn matmul_t_matches_explicit_transpose() {
        let mut r = Rng::new(21);
        let a = Tensor::randn(&[5, 9], &mut r, 1.0);
        let b = Tensor::randn(&[7, 9], &mut r, 1.0);
        let got = a.matmul_t(&b);
        let want = a.matmul(&b.t());
        assert_eq!(got.shape, vec![5, 7]);
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let mut r = Rng::new(22);
        let a = Tensor::randn(&[6, 4], &mut r, 1.0);
        let b = Tensor::randn(&[6, 5], &mut r, 1.0);
        let got = a.t_matmul(&b);
        let want = a.t().matmul(&b);
        assert_eq!(got.shape, vec![4, 5]);
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn matmul_blocked_sizes() {
        // Exercise the BLOCK_N path (n > 64) and ragged tails.
        let mut r = Rng::new(23);
        let a = Tensor::randn(&[3, 130], &mut r, 1.0);
        let b = Tensor::randn(&[130, 67], &mut r, 1.0);
        let c = a.matmul(&b);
        for (i, j) in [(0usize, 0usize), (2, 66), (1, 64)] {
            let mut want = 0f64;
            for k in 0..130 {
                want += a.at(i, k) as f64 * b.at(k, j) as f64;
            }
            assert!((c.at(i, j) as f64 - want).abs() < 1e-3, "({i},{j})");
        }
    }

    // Serial-vs-parallel bit-identity for these kernels is covered by the
    // broader property tests in rust/tests/pool_determinism.rs.

    #[test]
    fn transpose_involution() {
        let mut r = Rng::new(3);
        let a = Tensor::randn(&[4, 6], &mut r, 1.0);
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn transpose_matmul_identity() {
        // (AB)^T = B^T A^T
        let mut r = Rng::new(4);
        let a = Tensor::randn(&[3, 5], &mut r, 1.0);
        let b = Tensor::randn(&[5, 2], &mut r, 1.0);
        let lhs = a.matmul(&b).t();
        let rhs = b.t().matmul(&a.t());
        assert!(lhs.max_abs_diff(&rhs) < 1e-5);
    }

    #[test]
    fn slices() {
        let a = Tensor::from_vec(&[3, 3], (0..9).map(|x| x as f32).collect());
        assert_eq!(a.slice_rows(1, 3).data, vec![3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        assert_eq!(a.slice_cols(0, 2).data, vec![0.0, 1.0, 3.0, 4.0, 6.0, 7.0]);
        assert_eq!(a.col(2), vec![2.0, 5.0, 8.0]);
    }

    #[test]
    fn permute_cols_roundtrip() {
        let mut r = Rng::new(5);
        let a = Tensor::randn(&[4, 6], &mut r, 1.0);
        let perm = vec![3, 1, 5, 0, 2, 4];
        let mut inv = vec![0; 6];
        for (j, &p) in perm.iter().enumerate() {
            inv[p] = j;
        }
        assert!(a.permute_cols(&perm).permute_cols(&inv).max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn npy_roundtrip() {
        let dir = std::env::temp_dir().join("qrlora_test_npy");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.npy");
        let mut r = Rng::new(6);
        for shape in [vec![7usize], vec![3, 4], vec![2, 3, 4]] {
            let a = Tensor::randn(&shape, &mut r, 2.0);
            a.save_npy(&path).unwrap();
            let b = Tensor::load_npy(&path).unwrap();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn fro_norm() {
        let a = Tensor::from_vec(&[2, 2], vec![3.0, 0.0, 0.0, 4.0]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-12);
    }
}
