//! AArch64 NEON kernels.
//!
//! Mirrors `kernels::x86` at 128 bits: strict-mode functions reproduce the
//! scalar reference loops bit for bit (the four `float32x4` lanes carry
//! exactly the four accumulator chains of `scalar::dot`; `vaddq`/`vmulq`
//! stay separate instructions — `vfmaq` fuses and is only reachable in the
//! opt-in relaxed mode), and the horizontal reduction keeps the
//! `(l0+l1)+(l2+l3)` parenthesization. `dot_i8i8` accumulates i8×i8
//! products exactly in i32 lanes via `vmull_s8` + pairwise-add.
//!
//! NEON is mandatory on AArch64, so these functions are always safe to
//! call on this architecture; they stay `unsafe fn` for pointer-based
//! loads and API symmetry with the x86 module.

#![cfg(target_arch = "aarch64")]

use std::arch::aarch64::*;

#[inline]
unsafe fn hsum4(acc: float32x4_t) -> f32 {
    (vgetq_lane_f32::<0>(acc) + vgetq_lane_f32::<1>(acc))
        + (vgetq_lane_f32::<2>(acc) + vgetq_lane_f32::<3>(acc))
}

/// Strict dot product — bit-matches `scalar::dot`.
///
/// # Safety
/// NEON is baseline on aarch64; callers only need valid slices of equal
/// length (checked by debug assertion).
pub(crate) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let mut acc = vdupq_n_f32(0.0);
    for c in 0..chunks {
        let i = c * 4;
        let va = vld1q_f32(a.as_ptr().add(i));
        let vb = vld1q_f32(b.as_ptr().add(i));
        acc = vaddq_f32(acc, vmulq_f32(va, vb));
    }
    let mut s = hsum4(acc);
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// Four strict dots sharing the `a` loads; each output bit-matches
/// `scalar::dot(a, b_j)`.
///
/// # Safety
/// As [`dot`].
pub(crate) unsafe fn dot4(a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
    let n = a.len();
    let chunks = n / 4;
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut acc2 = vdupq_n_f32(0.0);
    let mut acc3 = vdupq_n_f32(0.0);
    for c in 0..chunks {
        let i = c * 4;
        let va = vld1q_f32(a.as_ptr().add(i));
        acc0 = vaddq_f32(acc0, vmulq_f32(va, vld1q_f32(b0.as_ptr().add(i))));
        acc1 = vaddq_f32(acc1, vmulq_f32(va, vld1q_f32(b1.as_ptr().add(i))));
        acc2 = vaddq_f32(acc2, vmulq_f32(va, vld1q_f32(b2.as_ptr().add(i))));
        acc3 = vaddq_f32(acc3, vmulq_f32(va, vld1q_f32(b3.as_ptr().add(i))));
    }
    let mut out = [hsum4(acc0), hsum4(acc1), hsum4(acc2), hsum4(acc3)];
    for i in chunks * 4..n {
        out[0] += a[i] * b0[i];
        out[1] += a[i] * b1[i];
        out[2] += a[i] * b2[i];
        out[3] += a[i] * b3[i];
    }
    out
}

/// Relaxed dot product: four fused-multiply-add accumulators (16 lanes in
/// flight). Re-associated and fused — only reachable through the opt-in
/// relaxed mode (≤1e-5 relative-error contract).
///
/// # Safety
/// As [`dot`].
pub(crate) unsafe fn dot_relaxed(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut acc2 = vdupq_n_f32(0.0);
    let mut acc3 = vdupq_n_f32(0.0);
    let mut i = 0usize;
    while i + 16 <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(a.as_ptr().add(i)), vld1q_f32(b.as_ptr().add(i)));
        acc1 = vfmaq_f32(acc1, vld1q_f32(a.as_ptr().add(i + 4)), vld1q_f32(b.as_ptr().add(i + 4)));
        acc2 = vfmaq_f32(acc2, vld1q_f32(a.as_ptr().add(i + 8)), vld1q_f32(b.as_ptr().add(i + 8)));
        acc3 =
            vfmaq_f32(acc3, vld1q_f32(a.as_ptr().add(i + 12)), vld1q_f32(b.as_ptr().add(i + 12)));
        i += 16;
    }
    let mut acc = vaddq_f32(vaddq_f32(acc0, acc1), vaddq_f32(acc2, acc3));
    while i + 4 <= n {
        acc = vfmaq_f32(acc, vld1q_f32(a.as_ptr().add(i)), vld1q_f32(b.as_ptr().add(i)));
        i += 4;
    }
    let mut s = hsum4(acc);
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// Integer i8×i8 dot product: 8 products per step via `vmull_s8`
/// (i8×i8→i16) + `vpadalq_s16` pairwise accumulate into i32 lanes
/// (exact — integer addition is associative).
///
/// # Safety
/// As [`dot`].
pub(crate) unsafe fn dot_i8i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc = vdupq_n_s32(0);
    let mut i = 0usize;
    while i + 8 <= n {
        let va = vld1_s8(a.as_ptr().add(i));
        let vb = vld1_s8(b.as_ptr().add(i));
        acc = vpadalq_s16(acc, vmull_s8(va, vb));
        i += 8;
    }
    let mut s = vaddvq_s32(acc);
    while i < n {
        s += (a[i] as i32) * (b[i] as i32);
        i += 1;
    }
    s
}

/// `y += alpha · x` (exact — independent lanes, separate mul/add).
///
/// # Safety
/// As [`dot`].
pub(crate) unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = y.len();
    let va = vdupq_n_f32(alpha);
    let mut i = 0usize;
    while i + 4 <= n {
        let vy = vld1q_f32(y.as_ptr().add(i));
        let vx = vld1q_f32(x.as_ptr().add(i));
        vst1q_f32(y.as_mut_ptr().add(i), vaddq_f32(vy, vmulq_f32(va, vx)));
        i += 4;
    }
    while i < n {
        y[i] += alpha * x[i];
        i += 1;
    }
}

#[inline]
unsafe fn cvt_i8x8_to_f32(q: *const i8) -> (float32x4_t, float32x4_t) {
    let q16 = vmovl_s8(vld1_s8(q));
    (
        vcvtq_f32_s32(vmovl_s16(vget_low_s16(q16))),
        vcvtq_f32_s32(vmovl_s16(vget_high_s16(q16))),
    )
}

/// `y += c · q` (int8 operand, exact i8→i32→f32 convert per lane).
///
/// # Safety
/// As [`dot`].
pub(crate) unsafe fn axpy_i8(c: f32, q: &[i8], y: &mut [f32]) {
    debug_assert_eq!(q.len(), y.len());
    let n = y.len();
    let vc = vdupq_n_f32(c);
    let mut i = 0usize;
    while i + 8 <= n {
        let (lo, hi) = cvt_i8x8_to_f32(q.as_ptr().add(i));
        let y0 = vld1q_f32(y.as_ptr().add(i));
        let y1 = vld1q_f32(y.as_ptr().add(i + 4));
        vst1q_f32(y.as_mut_ptr().add(i), vaddq_f32(y0, vmulq_f32(vc, lo)));
        vst1q_f32(y.as_mut_ptr().add(i + 4), vaddq_f32(y1, vmulq_f32(vc, hi)));
        i += 8;
    }
    while i < n {
        y[i] += c * q[i] as f32;
        i += 1;
    }
}

/// `y = s · q` (int8 row dequantize, exact per lane).
///
/// # Safety
/// As [`dot`].
pub(crate) unsafe fn scale_i8(s: f32, q: &[i8], y: &mut [f32]) {
    debug_assert_eq!(q.len(), y.len());
    let n = y.len();
    let vs = vdupq_n_f32(s);
    let mut i = 0usize;
    while i + 8 <= n {
        let (lo, hi) = cvt_i8x8_to_f32(q.as_ptr().add(i));
        vst1q_f32(y.as_mut_ptr().add(i), vmulq_f32(vs, lo));
        vst1q_f32(y.as_mut_ptr().add(i + 4), vmulq_f32(vs, hi));
        i += 8;
    }
    while i < n {
        y[i] = s * q[i] as f32;
        i += 1;
    }
}

/// `y += x` (exact).
///
/// # Safety
/// As [`dot`].
pub(crate) unsafe fn vadd(x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = y.len();
    let mut i = 0usize;
    while i + 4 <= n {
        let vy = vld1q_f32(y.as_ptr().add(i));
        let vx = vld1q_f32(x.as_ptr().add(i));
        vst1q_f32(y.as_mut_ptr().add(i), vaddq_f32(vy, vx));
        i += 4;
    }
    while i < n {
        y[i] += x[i];
        i += 1;
    }
}

/// `y *= x` (exact).
///
/// # Safety
/// As [`dot`].
pub(crate) unsafe fn vmul(x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = y.len();
    let mut i = 0usize;
    while i + 4 <= n {
        let vy = vld1q_f32(y.as_ptr().add(i));
        let vx = vld1q_f32(x.as_ptr().add(i));
        vst1q_f32(y.as_mut_ptr().add(i), vmulq_f32(vy, vx));
        i += 4;
    }
    while i < n {
        y[i] *= x[i];
        i += 1;
    }
}

/// `acc += a ⊙ b` (exact — per-column accumulators are independent).
///
/// # Safety
/// As [`dot`].
pub(crate) unsafe fn vmuladd(a: &[f32], b: &[f32], acc: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), acc.len());
    let n = acc.len();
    let mut i = 0usize;
    while i + 4 <= n {
        let vo = vld1q_f32(acc.as_ptr().add(i));
        let va = vld1q_f32(a.as_ptr().add(i));
        let vb = vld1q_f32(b.as_ptr().add(i));
        vst1q_f32(acc.as_mut_ptr().add(i), vaddq_f32(vo, vmulq_f32(va, vb)));
        i += 4;
    }
    while i < n {
        acc[i] += a[i] * b[i];
        i += 1;
    }
}

/// LayerNorm forward normalize/affine for one row (exact).
///
/// # Safety
/// As [`dot`]. All slices share one length.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn ln_norm_row(
    xi: &[f32],
    mu: f32,
    rs: f32,
    g: &[f32],
    b: &[f32],
    y: &mut [f32],
    xhat: &mut [f32],
) {
    let d = xi.len();
    let vmu = vdupq_n_f32(mu);
    let vrs = vdupq_n_f32(rs);
    let mut j = 0usize;
    while j + 4 <= d {
        let vx = vld1q_f32(xi.as_ptr().add(j));
        let vh = vmulq_f32(vsubq_f32(vx, vmu), vrs);
        vst1q_f32(xhat.as_mut_ptr().add(j), vh);
        let vg = vld1q_f32(g.as_ptr().add(j));
        let vb = vld1q_f32(b.as_ptr().add(j));
        vst1q_f32(y.as_mut_ptr().add(j), vaddq_f32(vmulq_f32(vh, vg), vb));
        j += 4;
    }
    while j < d {
        let h = (xi[j] - mu) * rs;
        xhat[j] = h;
        y[j] = h * g[j] + b[j];
        j += 1;
    }
}

/// LayerNorm backward dx for one row (exact).
///
/// # Safety
/// As [`dot`]. All slices share one length.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn ln_dx_row(
    dyr: &[f32],
    xh: &[f32],
    g: &[f32],
    m1: f32,
    m2: f32,
    rstd: f32,
    dx: &mut [f32],
) {
    let d = dx.len();
    let vm1 = vdupq_n_f32(m1);
    let vm2 = vdupq_n_f32(m2);
    let vrs = vdupq_n_f32(rstd);
    let mut j = 0usize;
    while j + 4 <= d {
        let vdy = vld1q_f32(dyr.as_ptr().add(j));
        let vg = vld1q_f32(g.as_ptr().add(j));
        let vxh = vld1q_f32(xh.as_ptr().add(j));
        let vdxh = vmulq_f32(vdy, vg);
        let vt = vsubq_f32(vsubq_f32(vdxh, vm1), vmulq_f32(vxh, vm2));
        vst1q_f32(dx.as_mut_ptr().add(j), vmulq_f32(vrs, vt));
        j += 4;
    }
    while j < d {
        let dxh = dyr[j] * g[j];
        dx[j] = rstd * (dxh - m1 - xh[j] * m2);
        j += 1;
    }
}
