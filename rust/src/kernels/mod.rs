//! Unified CPU microkernel dispatch: one [`Kernels`] surface over scalar,
//! AVX2, and NEON implementations of every hot inner loop (dense/int8
//! matmul products, LayerNorm, GELU, softmax, axpy).
//!
//! # Layering
//!
//! This module is a **leaf**: it sees only raw slices and row counts,
//! never `Tensor`/`QuantTensor` or the worker pool. The callers
//! (`tensor.rs`, `quant.rs`, `model/host.rs`) keep the pool orchestration
//! and hand each worker's contiguous row chunk to one `Kernels` method.
//! Callers must resolve [`active`] **once, outside the pool closure**, and
//! let the closure capture the `Copy` handle — pool workers do not inherit
//! the caller's thread-local [`with_kernels`] override.
//!
//! # Backend selection
//!
//! `--simd auto|avx2|neon|scalar` (CLI) or `QRLORA_SIMD` (env) pick the
//! backend; `auto` (the default) uses runtime feature detection, cached
//! once per process ([`detect`]). Forcing a backend the CPU lacks warns
//! and falls back to scalar — it never executes an illegal instruction.
//! Tests and benches can override per thread with [`with_kernels`].
//!
//! # Determinism contract
//!
//! In the default (strict) mode every method is **bit-identical** across
//! backends *and* thread counts: SIMD lanes reproduce the scalar
//! reference's accumulator chains exactly (no FMA, no re-association, no
//! lane-count change; see `kernels::scalar` for the reference loops), and
//! transcendentals (`tanh`, `exp`, `sqrt`) always run as scalar libm
//! calls. One documented exception: [`Kernels::matmul_xw_q`] on a SIMD
//! backend quantizes the activation row once and accumulates i8×i8
//! products in i32 lanes — exact integer arithmetic (identical across
//! AVX2 and NEON, and per-thread deterministic) but a different
//! *quantization* of the product than the scalar fused-dequant reference,
//! so its f32 results differ from `QRLORA_SIMD=scalar` within the
//! documented activation-quantization bound (see the method docs).
//!
//! The opt-in **relaxed** mode (`--simd-relaxed` / `QRLORA_SIMD_RELAXED`)
//! lets dot-product reductions use wide multi-accumulator FMA chains:
//! ≤1e-5 relative error against strict mode (property-tested in
//! `rust/tests/kernels.rs`), still per-thread deterministic, but
//! backend-specific bits.

mod scalar;

#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(target_arch = "x86_64")]
mod x86;

use std::cell::Cell;
use std::sync::OnceLock;

/// `sqrt(2/π)` — the tanh-GELU inner coefficient (moved from
/// `model/host.rs`; the kernels own the GELU loops now).
const SQRT_2_OVER_PI: f32 = 0.797_884_6;

/// Matrix shapes `(m, k, n)` shared by the kernel parity suite
/// (`rust/tests/kernels.rs`) and the pool determinism suite
/// (`rust/tests/pool_determinism.rs`), so the thread-count and simd-mode
/// matrices compose over the same tall/wide/square/ragged cases. Sizes
/// straddle the pool's serial cutoff.
pub const PARITY_SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (3, 257, 5),
    (64, 64, 64),
    (130, 67, 33),
    (5, 8, 512),
    (256, 31, 7),
    (97, 128, 130),
];

/// A concrete SIMD instruction set a [`Kernels`] handle dispatches to.
///
/// All variants exist on every architecture (so CLI parsing and tests
/// compile everywhere); [`backend_available`] says which ones this CPU can
/// actually run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdBackend {
    /// The portable reference loops (`kernels::scalar`) — always available
    /// and the bit-level ground truth for strict mode.
    Scalar,
    /// x86-64 AVX2 (+FMA for relaxed mode).
    Avx2,
    /// AArch64 NEON.
    Neon,
}

impl SimdBackend {
    /// Lowercase name, matching the `--simd` spelling.
    pub fn name(&self) -> &'static str {
        match self {
            SimdBackend::Scalar => "scalar",
            SimdBackend::Avx2 => "avx2",
            SimdBackend::Neon => "neon",
        }
    }
}

/// A parsed `--simd` / `QRLORA_SIMD` request (before availability
/// resolution).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdRequest {
    /// Use the best backend the CPU supports (the default).
    Auto,
    /// Force the scalar reference loops.
    Scalar,
    /// Request AVX2 (falls back to scalar, with a warning, if absent).
    Avx2,
    /// Request NEON (falls back to scalar, with a warning, if absent).
    Neon,
}

impl SimdRequest {
    /// Parse a `--simd` / `QRLORA_SIMD` value. The CLI calls this eagerly
    /// so a typo fails fast instead of silently serving on the wrong
    /// kernels.
    pub fn parse(s: &str) -> anyhow::Result<SimdRequest> {
        match s.trim().to_ascii_lowercase().as_str() {
            "" | "auto" => Ok(SimdRequest::Auto),
            "scalar" => Ok(SimdRequest::Scalar),
            "avx2" => Ok(SimdRequest::Avx2),
            "neon" => Ok(SimdRequest::Neon),
            other => {
                anyhow::bail!("unknown simd backend {other:?} (expected auto|avx2|neon|scalar)")
            }
        }
    }
}

/// Best SIMD backend this CPU supports, detected once per process and
/// cached. AVX2 additionally requires FMA (relaxed mode uses it, and
/// every AVX2-era core has both); NEON is mandatory on aarch64.
pub fn detect() -> SimdBackend {
    static DETECTED: OnceLock<SimdBackend> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
                return SimdBackend::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return SimdBackend::Neon;
            }
        }
        SimdBackend::Scalar
    })
}

/// Whether this CPU can run `b` (scalar always can; at most one SIMD
/// backend exists per architecture, so this is `detect() == b` otherwise).
pub fn backend_available(b: SimdBackend) -> bool {
    b == SimdBackend::Scalar || detect() == b
}

fn resolve(req: SimdRequest) -> SimdBackend {
    let want = match req {
        SimdRequest::Auto => return detect(),
        SimdRequest::Scalar => return SimdBackend::Scalar,
        SimdRequest::Avx2 => SimdBackend::Avx2,
        SimdRequest::Neon => SimdBackend::Neon,
    };
    if backend_available(want) {
        want
    } else {
        crate::warnln!(
            "kernels: {} not available on this cpu; falling back to scalar",
            want.name()
        );
        SimdBackend::Scalar
    }
}

fn from_env() -> Kernels {
    let req = match std::env::var("QRLORA_SIMD") {
        Ok(v) => match SimdRequest::parse(&v) {
            Ok(r) => r,
            Err(e) => {
                crate::warnln!("kernels: ignoring QRLORA_SIMD: {e}");
                SimdRequest::Auto
            }
        },
        Err(_) => SimdRequest::Auto,
    };
    // Same truthiness convention as QRLORA_QUANT (`quant_backbone_from_env`).
    let relaxed = match std::env::var("QRLORA_SIMD_RELAXED") {
        Ok(v) => {
            let v = v.trim().to_ascii_lowercase();
            !matches!(v.as_str(), "" | "0" | "false" | "off" | "no")
        }
        Err(_) => false,
    };
    Kernels { backend: resolve(req), relaxed }
}

thread_local! {
    static OVERRIDE: Cell<Option<Kernels>> = const { Cell::new(None) };
}

/// The process-wide kernel selection (`QRLORA_SIMD` / `--simd`, resolved
/// and cached on first use), unless the current thread is inside a
/// [`with_kernels`] override. Callers on the pool's hot paths resolve
/// this once per operation, before dispatching work to pool threads.
pub fn active() -> Kernels {
    OVERRIDE.with(|o| o.get()).unwrap_or_else(|| {
        static ENV: OnceLock<Kernels> = OnceLock::new();
        *ENV.get_or_init(from_env)
    })
}

/// Run `f` with [`active`] forced to `k` on this thread (tests/benches).
/// Mirrors `pool::with_threads`: the override nests and restores on exit.
/// It is thread-local on purpose — operations capture the handle before
/// fanning out to pool workers, so the override still governs them.
pub fn with_kernels<T>(k: Kernels, f: impl FnOnce() -> T) -> T {
    let prev = OVERRIDE.with(|o| o.replace(Some(k)));
    let out = f();
    OVERRIDE.with(|o| o.set(prev));
    out
}

/// Dispatch an expression to the active backend. The cfg-gated arms are
/// stripped on foreign architectures, where the catch-all routes any
/// (unreachable) SIMD variant to the scalar reference.
macro_rules! dispatch {
    ($self:ident, $scalar:expr, $x86:expr, $neon:expr) => {
        match $self.backend {
            #[cfg(target_arch = "x86_64")]
            SimdBackend::Avx2 => unsafe { $x86 },
            #[cfg(target_arch = "aarch64")]
            SimdBackend::Neon => unsafe { $neon },
            _ => $scalar,
        }
    };
}

/// A resolved kernel selection: one backend plus the strict/relaxed mode
/// bit. `Copy`, so pool closures capture it by value.
///
/// Construct via [`active`] (the process selection), [`Kernels::scalar`]
/// (the reference), [`Kernels::detected`] (best available), or
/// [`Kernels::new`]. See the module docs for the determinism contract
/// every method follows; per-method docs state shapes and layouts.
///
/// All matrix arguments are dense row-major slices. "Row chunk" methods
/// take the caller's contiguous span of output rows (`out.len()` must be
/// a multiple of the row width) plus the matching span of input rows —
/// exactly how `util::pool::par_rows` partitions work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Kernels {
    backend: SimdBackend,
    relaxed: bool,
}

impl Kernels {
    /// The scalar reference in strict mode — bit-level ground truth.
    pub fn scalar() -> Kernels {
        Kernels { backend: SimdBackend::Scalar, relaxed: false }
    }

    /// The best backend this CPU supports, in the given mode.
    pub fn detected(relaxed: bool) -> Kernels {
        Kernels { backend: detect(), relaxed }
    }

    /// A specific backend/mode; falls back to scalar (like the env path,
    /// but silently — callers wanting the warning go through the env) if
    /// the CPU cannot run `backend`.
    pub fn new(backend: SimdBackend, relaxed: bool) -> Kernels {
        let backend = if backend_available(backend) { backend } else { SimdBackend::Scalar };
        Kernels { backend, relaxed }
    }

    /// The backend this handle dispatches to.
    pub fn backend(&self) -> SimdBackend {
        self.backend
    }

    /// Whether the relaxed (re-associated FMA) mode is on. A no-op on the
    /// scalar backend.
    pub fn relaxed(&self) -> bool {
        self.relaxed
    }

    /// Human-readable selection for startup banners (`bench`, `serve`,
    /// `info`).
    pub fn describe(&self) -> &'static str {
        match (self.backend, self.relaxed) {
            (SimdBackend::Scalar, false) => "scalar",
            (SimdBackend::Scalar, true) => "scalar (relaxed is a no-op)",
            (SimdBackend::Avx2, false) => "avx2",
            (SimdBackend::Avx2, true) => "avx2+relaxed",
            (SimdBackend::Neon, false) => "neon",
            (SimdBackend::Neon, true) => "neon+relaxed",
        }
    }

    // ---- dot-product primitives ---------------------------------------

    /// Dot product of two equal-length slices in the reference
    /// 4-accumulator order. Strict mode: bit-identical across backends.
    /// Relaxed mode: wide FMA accumulators, ≤1e-5 relative error.
    #[inline]
    pub fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        if self.relaxed {
            return dispatch!(
                self,
                scalar::dot(a, b),
                x86::dot_relaxed(a, b),
                neon::dot_relaxed(a, b)
            );
        }
        dispatch!(self, scalar::dot(a, b), x86::dot(a, b), neon::dot(a, b))
    }

    /// Four dot products sharing the left operand:
    /// `[dot(a,b0), …, dot(a,b3)]`, each bit-identical to [`Kernels::dot`]
    /// in strict mode (the column-blocked matmul building block).
    #[inline]
    pub fn dot4(&self, a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
        if self.relaxed {
            return [self.dot(a, b0), self.dot(a, b1), self.dot(a, b2), self.dot(a, b3)];
        }
        dispatch!(
            self,
            scalar::dot4(a, b0, b1, b2, b3),
            x86::dot4(a, b0, b1, b2, b3),
            neon::dot4(a, b0, b1, b2, b3)
        )
    }

    /// Dot product in plain sequential single-accumulator order — the
    /// attention score/probability contraction. Strict mode runs the
    /// scalar loop on every backend (a vector reduction cannot reproduce a
    /// sequential chain); relaxed mode uses the wide FMA reduction.
    #[inline]
    pub fn dot_seq(&self, a: &[f32], b: &[f32]) -> f32 {
        if self.relaxed {
            return dispatch!(
                self,
                scalar::dot_seq(a, b),
                x86::dot_relaxed(a, b),
                neon::dot_relaxed(a, b)
            );
        }
        scalar::dot_seq(a, b)
    }

    // ---- elementwise primitives (exact in every mode) -----------------

    /// `y += alpha · x`. Exact in every mode (independent lanes, separate
    /// mul/add) — gradient/context row accumulation.
    #[inline]
    pub fn axpy(&self, alpha: f32, x: &[f32], y: &mut [f32]) {
        dispatch!(self, scalar::axpy(alpha, x, y), x86::axpy(alpha, x, y), neon::axpy(alpha, x, y))
    }

    /// `y += c · q` with an exact in-register i8→f32 convert — the int8
    /// backward axpy and embedding-row accumulate. Exact in every mode.
    #[inline]
    pub fn axpy_i8(&self, c: f32, q: &[i8], y: &mut [f32]) {
        dispatch!(self, scalar::axpy_i8(c, q, y), x86::axpy_i8(c, q, y), neon::axpy_i8(c, q, y))
    }

    /// `y = s · q` (dequantize one int8 row into f32). Exact in every mode.
    #[inline]
    pub fn scale_i8(&self, s: f32, q: &[i8], y: &mut [f32]) {
        dispatch!(self, scalar::scale_i8(s, q, y), x86::scale_i8(s, q, y), neon::scale_i8(s, q, y))
    }

    /// `y += x` elementwise (bias rows, residual adds, column sums). Exact
    /// in every mode.
    #[inline]
    pub fn vadd(&self, x: &[f32], y: &mut [f32]) {
        dispatch!(self, scalar::vadd(x, y), x86::vadd(x, y), neon::vadd(x, y))
    }

    /// `y *= x` elementwise (column scaling). Exact in every mode.
    #[inline]
    pub fn vmul(&self, x: &[f32], y: &mut [f32]) {
        dispatch!(self, scalar::vmul(x, y), x86::vmul(x, y), neon::vmul(x, y))
    }

    /// `acc += a ⊙ b` elementwise — per-column independent accumulators
    /// (LayerNorm dγ, λ gradients). Exact in every mode.
    #[inline]
    pub fn vmuladd(&self, a: &[f32], b: &[f32], acc: &mut [f32]) {
        dispatch!(
            self,
            scalar::vmuladd(a, b, acc),
            x86::vmuladd(a, b, acc),
            neon::vmuladd(a, b, acc)
        )
    }

    // ---- f32 matmul row drivers ---------------------------------------

    /// One row chunk of `A (m×k) @ Bᵀ` with `B` stored `(n×k)`:
    /// `out[r,j] = dot(a_rows[r,:], b[j,:])`. `a_rows` holds the chunk's
    /// rows of `A` (`out.len()/n` of them, row-major, width `k`); `b` is
    /// the full `(n×k)` operand. Keeps the reference kernel's column
    /// blocking; every output element is one [`Kernels::dot`] /
    /// [`Kernels::dot4`] of the same two slices regardless of chunking, so
    /// strict mode is bit-identical across backends and partitions.
    pub fn matmul_xw_t(&self, a_rows: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32]) {
        if n == 0 {
            return;
        }
        let rows = out.len() / n;
        debug_assert_eq!(rows * n, out.len());
        debug_assert_eq!(rows * k, a_rows.len());
        const BLOCK_N: usize = 64;
        for j0 in (0..n).step_by(BLOCK_N) {
            let j1 = (j0 + BLOCK_N).min(n);
            for r in 0..rows {
                let arow = &a_rows[r * k..(r + 1) * k];
                let orow = &mut out[r * n..(r + 1) * n];
                let mut j = j0;
                while j + 4 <= j1 {
                    let d4 = self.dot4(
                        arow,
                        &b[j * k..(j + 1) * k],
                        &b[(j + 1) * k..(j + 2) * k],
                        &b[(j + 2) * k..(j + 3) * k],
                        &b[(j + 3) * k..(j + 4) * k],
                    );
                    orow[j..j + 4].copy_from_slice(&d4);
                    j += 4;
                }
                while j < j1 {
                    orow[j] = self.dot(arow, &b[j * k..(j + 1) * k]);
                    j += 1;
                }
            }
        }
    }

    /// One row chunk of `Aᵀ (k×m) @ B (m×n)` (the gradient contraction
    /// `xᵀ·dy`) as a sum of scaled row axpys. `a`/`b` are the full `(m×k)`
    /// / `(m×n)` operands; the chunk covers output rows
    /// `[i0, i0 + out.len()/n)`. Accumulation over `m` runs in the serial
    /// order with the reference's `a == 0.0` skip (zeroed gradient rows
    /// skip the whole axpy), and the axpy itself is exact in every mode —
    /// so this method is bit-identical across backends in *both* modes.
    #[allow(clippy::too_many_arguments)]
    pub fn matmul_xt_y(
        &self,
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
        i0: usize,
        out: &mut [f32],
    ) {
        if n == 0 {
            return;
        }
        debug_assert_eq!(out.len() % n, 0);
        for mm in 0..m {
            let arow = &a[mm * k..(mm + 1) * k];
            let brow = &b[mm * n..(mm + 1) * n];
            for (ii, orow) in out.chunks_mut(n).enumerate() {
                let alpha = arow[i0 + ii];
                if alpha == 0.0 {
                    continue;
                }
                self.axpy(alpha, brow, orow);
            }
        }
    }

    // ---- int8 matmul row drivers --------------------------------------

    /// One row chunk of the forward int8 product `x (m×k) @ W` with the
    /// weight stored transposed int8 `(n×k)` (`wq` values, `scales` one
    /// f32 per `group_rows` rows): `out[r,j] ≈ Σ_e x[r,e]·scale(j)·q[j,e]`.
    ///
    /// Backend contract — **the one strict-mode exception**:
    /// * scalar: the fused-dequant reference (`Σ x·(q as f32)`, scaled
    ///   once after the 4-accumulator reduction) — bit-identical to the
    ///   pre-kernels implementation;
    /// * AVX2/NEON (strict *and* relaxed): quantizes each activation row
    ///   once (symmetric absmax, the same rounding as
    ///   `QuantTensor::quantize`), then accumulates i8×i8 products in i32
    ///   lanes and applies `sx·scale(j)` once per output. Integer
    ///   accumulation is exact, so the result is identical across AVX2 and
    ///   NEON and bit-stable for any thread count/partition — but it
    ///   differs from the scalar reference by the activation-quantization
    ///   error, bounded per element by `0.5·sx·scale(j)·Σ_e|q[j,e]|` plus
    ///   f32 rounding (property-tested in `rust/tests/kernels.rs`).
    #[allow(clippy::too_many_arguments)]
    pub fn matmul_xw_q(
        &self,
        x_rows: &[f32],
        k: usize,
        wq: &[i8],
        scales: &[f32],
        group_rows: usize,
        n: usize,
        out: &mut [f32],
    ) {
        if n == 0 {
            return;
        }
        let rows = out.len() / n;
        debug_assert_eq!(rows * n, out.len());
        debug_assert_eq!(rows * k, x_rows.len());
        let g = group_rows.max(1);
        const BLOCK_N: usize = 64;
        if self.backend == SimdBackend::Scalar {
            // Fused-dequant reference (pre-kernels bits).
            for j0 in (0..n).step_by(BLOCK_N) {
                let j1 = (j0 + BLOCK_N).min(n);
                for r in 0..rows {
                    let xrow = &x_rows[r * k..(r + 1) * k];
                    let orow = &mut out[r * n..(r + 1) * n];
                    for j in j0..j1 {
                        orow[j] = scales[j / g] * scalar::dot_i8(xrow, &wq[j * k..(j + 1) * k]);
                    }
                }
            }
            return;
        }
        // Integer path: quantize each activation row once, then i8×i8→i32.
        let mut qx = vec![0i8; rows * k];
        let mut sx = vec![0f32; rows];
        for r in 0..rows {
            sx[r] = scalar::quantize_row(&x_rows[r * k..(r + 1) * k], &mut qx[r * k..(r + 1) * k]);
        }
        for j0 in (0..n).step_by(BLOCK_N) {
            let j1 = (j0 + BLOCK_N).min(n);
            for r in 0..rows {
                let qxr = &qx[r * k..(r + 1) * k];
                let orow = &mut out[r * n..(r + 1) * n];
                for j in j0..j1 {
                    let isum = self.dot_i8i8(qxr, &wq[j * k..(j + 1) * k]);
                    orow[j] = (sx[r] * scales[j / g]) * isum as f32;
                }
            }
        }
    }

    /// One row chunk of the backward int8 product `dy (m×n) @ W-stored`
    /// with the weight stored transposed int8 `(n×k)`, i.e. `dy·Wᵀ →
    /// (m×k)`, as scaled int8 row axpys:
    /// `out[r,:] += (dy[r,j]·scale(j)) · q[j,:]`. `dy_rows` holds the
    /// chunk's rows of `dy` (width `n`); `out` the matching rows (width
    /// `kk`). Keeps the reference's `c == 0.0` skip, and the int8 axpy is
    /// exact in every mode — bit-identical across backends in both modes
    /// (gradients stay f32-faithful; only the forward product quantizes
    /// activations).
    #[allow(clippy::too_many_arguments)]
    pub fn matmul_dyw_t_q(
        &self,
        dy_rows: &[f32],
        n: usize,
        wq: &[i8],
        scales: &[f32],
        group_rows: usize,
        kk: usize,
        out: &mut [f32],
    ) {
        if kk == 0 {
            return;
        }
        debug_assert_eq!(out.len() % kk, 0);
        let g = group_rows.max(1);
        for (r, orow) in out.chunks_mut(kk).enumerate() {
            let dyr = &dy_rows[r * n..(r + 1) * n];
            for j in 0..n {
                let c = dyr[j] * scales[j / g];
                if c == 0.0 {
                    continue;
                }
                self.axpy_i8(c, &wq[j * kk..(j + 1) * kk], orow);
            }
        }
    }

    #[inline]
    fn dot_i8i8(&self, a: &[i8], b: &[i8]) -> i32 {
        dispatch!(self, scalar::dot_i8i8(a, b), x86::dot_i8i8(a, b), neon::dot_i8i8(a, b))
    }

    // ---- LayerNorm row drivers ----------------------------------------

    /// LayerNorm forward for a chunk of rows of width `d`: per row,
    /// `xhat = (x-μ)·rstd`, `y = xhat·g + b`, writing `y`/`xhat` (both
    /// `rows·d`) and `rstd` (one per row). The μ/σ² reductions run as the
    /// reference's sequential scalar sums in **every** mode (they are
    /// O(d) and feed `sqrt`); only the normalize/affine writes vectorize,
    /// exactly — so this method is bit-identical across backends in both
    /// modes.
    #[allow(clippy::too_many_arguments)]
    pub fn ln_fwd_rows(
        &self,
        x_rows: &[f32],
        d: usize,
        g: &[f32],
        b: &[f32],
        y: &mut [f32],
        xhat: &mut [f32],
        rstd: &mut [f32],
    ) {
        for (ri, rs_out) in rstd.iter_mut().enumerate() {
            let xi = &x_rows[ri * d..(ri + 1) * d];
            let mu = xi.iter().sum::<f32>() / d as f32;
            let var = xi.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
            let rs = 1.0 / (var + 1e-5).sqrt();
            *rs_out = rs;
            let lo = ri * d;
            self.ln_norm_row(xi, mu, rs, g, b, &mut y[lo..lo + d], &mut xhat[lo..lo + d]);
        }
    }

    /// LayerNorm backward dx for a chunk of rows: per row, the two moment
    /// reductions (`m1 = mean(dy·g)`, `m2 = mean(dy·g·xhat)`) run as the
    /// reference's sequential scalar sums in every mode; the dx write
    /// `rstd·(dy·g − m1 − xhat·m2)` vectorizes exactly. Bit-identical
    /// across backends in both modes. (dγ/dβ accumulate separately via
    /// [`Kernels::vmuladd`]/[`Kernels::vadd`] under the pool's fixed-chunk
    /// reduction.)
    #[allow(clippy::too_many_arguments)]
    pub fn ln_bwd_dx_rows(
        &self,
        dy_rows: &[f32],
        xhat_rows: &[f32],
        rstd_rows: &[f32],
        g: &[f32],
        d: usize,
        dx: &mut [f32],
    ) {
        for (ri, dxrow) in dx.chunks_mut(d).enumerate() {
            let dyr = &dy_rows[ri * d..(ri + 1) * d];
            let xh = &xhat_rows[ri * d..(ri + 1) * d];
            let mut m1 = 0f32;
            let mut m2 = 0f32;
            for j in 0..d {
                let dxh = dyr[j] * g[j];
                m1 += dxh;
                m2 += dxh * xh[j];
            }
            m1 /= d as f32;
            m2 /= d as f32;
            self.ln_dx_row(dyr, xh, g, m1, m2, rstd_rows[ri], dxrow);
        }
    }

    #[inline]
    #[allow(clippy::too_many_arguments)]
    fn ln_norm_row(
        &self,
        xi: &[f32],
        mu: f32,
        rs: f32,
        g: &[f32],
        b: &[f32],
        y: &mut [f32],
        xhat: &mut [f32],
    ) {
        dispatch!(
            self,
            scalar::ln_norm_row(xi, mu, rs, g, b, y, xhat),
            x86::ln_norm_row(xi, mu, rs, g, b, y, xhat),
            neon::ln_norm_row(xi, mu, rs, g, b, y, xhat)
        )
    }

    #[inline]
    #[allow(clippy::too_many_arguments)]
    fn ln_dx_row(
        &self,
        dyr: &[f32],
        xh: &[f32],
        g: &[f32],
        m1: f32,
        m2: f32,
        rstd: f32,
        dx: &mut [f32],
    ) {
        dispatch!(
            self,
            scalar::ln_dx_row(dyr, xh, g, m1, m2, rstd, dx),
            x86::ln_dx_row(dyr, xh, g, m1, m2, rstd, dx),
            neon::ln_dx_row(dyr, xh, g, m1, m2, rstd, dx)
        )
    }

    // ---- GELU / softmax (shared transcendental loops) -----------------

    /// Tanh-GELU forward for a chunk of rows of width `cols`, writing the
    /// activation into `y` and the tanh cache into `t` (both pre-zeroed by
    /// the caller). `live`, when present, holds one mask value per chunk
    /// row: rows with mask `0.0` (padded positions) are **skipped** — their
    /// `y`/`t` stay exactly `0.0` and no `tanh` is spent on them. The
    /// `tanh` loop itself is the shared scalar reference on every backend
    /// and in both modes, so live rows are bit-identical everywhere.
    pub fn gelu_fwd_rows(
        &self,
        x_rows: &[f32],
        cols: usize,
        live: Option<&[f32]>,
        y: &mut [f32],
        t: &mut [f32],
    ) {
        if cols == 0 {
            return;
        }
        let rows = y.len() / cols;
        debug_assert_eq!(rows * cols, y.len());
        for r in 0..rows {
            if let Some(mask) = live {
                if mask[r] == 0.0 {
                    continue;
                }
            }
            for i in r * cols..(r + 1) * cols {
                let v = x_rows[i];
                let inner = SQRT_2_OVER_PI * (v + 0.044715 * v * v * v);
                let th = inner.tanh();
                t[i] = th;
                y[i] = 0.5 * v * (1.0 + th);
            }
        }
    }

    /// Tanh-GELU backward over a flat element span:
    /// `dx = dy·(½(1+t) + ½·x·(1−t²)·du)` with the cached tanh `t`. Shared
    /// scalar loop on every backend (bit-identical everywhere).
    pub fn gelu_bwd(&self, dy: &[f32], x_pre: &[f32], t: &[f32], dx: &mut [f32]) {
        for (i, o) in dx.iter_mut().enumerate() {
            let v = x_pre[i];
            let th = t[i];
            let du = SQRT_2_OVER_PI * (1.0 + 3.0 * 0.044715 * v * v);
            *o = dy[i] * (0.5 * (1.0 + th) + 0.5 * v * (1.0 - th * th) * du);
        }
    }

    /// Row-wise softmax in place over a chunk of rows of width `cols`,
    /// restricted to the first `valid` columns; columns `[valid, cols)`
    /// are written `0.0` without spending `exp` on them. Shared scalar
    /// loop on every backend (bit-identical everywhere).
    ///
    /// Bit-compatibility with a full-width softmax holds whenever the
    /// masked tail was pushed at least ~104 below the live maximum (the
    /// model adds `NEG_INF = -1e9` to masked logits): `exp` then
    /// underflows to exactly `+0.0`, contributing nothing to the
    /// denominator — precisely what the tail skip produces. Pass
    /// `valid = cols` for the unmasked case.
    pub fn softmax_rows(&self, data: &mut [f32], cols: usize, valid: usize) {
        if cols == 0 {
            return;
        }
        let valid = valid.clamp(1, cols);
        for row in data.chunks_mut(cols) {
            let (head, tail) = row.split_at_mut(valid);
            let mut maxv = f32::NEG_INFINITY;
            for &v in head.iter() {
                maxv = maxv.max(v);
            }
            let mut denom = 0f32;
            for v in head.iter_mut() {
                *v = (*v - maxv).exp();
                denom += *v;
            }
            for v in head.iter_mut() {
                *v /= denom;
            }
            for v in tail.iter_mut() {
                *v = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_documented_spellings() {
        assert_eq!(SimdRequest::parse("auto").unwrap(), SimdRequest::Auto);
        assert_eq!(SimdRequest::parse("").unwrap(), SimdRequest::Auto);
        assert_eq!(SimdRequest::parse(" Scalar ").unwrap(), SimdRequest::Scalar);
        assert_eq!(SimdRequest::parse("AVX2").unwrap(), SimdRequest::Avx2);
        assert_eq!(SimdRequest::parse("neon").unwrap(), SimdRequest::Neon);
        assert!(SimdRequest::parse("sse9").is_err());
    }

    #[test]
    fn scalar_is_always_available_and_detect_is_cached() {
        assert!(backend_available(SimdBackend::Scalar));
        assert_eq!(detect(), detect());
        assert!(backend_available(detect()));
    }

    #[test]
    fn new_falls_back_to_scalar_when_unavailable() {
        // At most one SIMD backend exists per arch, so the other one must
        // fall back (and on plain scalar hosts, both do).
        for b in [SimdBackend::Avx2, SimdBackend::Neon] {
            let k = Kernels::new(b, false);
            if backend_available(b) {
                assert_eq!(k.backend(), b);
            } else {
                assert_eq!(k.backend(), SimdBackend::Scalar);
            }
        }
    }

    #[test]
    fn with_kernels_overrides_and_restores() {
        let outer = active();
        let forced = Kernels::scalar();
        with_kernels(forced, || {
            assert_eq!(active(), forced);
            let nested = Kernels::detected(true);
            with_kernels(nested, || assert_eq!(active(), nested));
            assert_eq!(active(), forced);
        });
        assert_eq!(active(), outer);
    }

    #[test]
    fn describe_names_backend_and_mode() {
        assert_eq!(Kernels::scalar().describe(), "scalar");
        let k = Kernels::detected(false);
        assert_eq!(k.describe(), k.backend().name());
    }

    #[test]
    fn softmax_masked_tail_matches_neg_inf_full_width() {
        // A masked tail pushed NEG_INF below the live max must produce
        // exactly what the tail skip writes: +0.0 and an unchanged head.
        let k = Kernels::scalar();
        let head = [0.3f32, -1.2, 2.5, 0.0, 1.1];
        let cols = 8usize;
        let mut full: Vec<f32> = head.to_vec();
        full.extend([0.7 - 1e9, -0.2 - 1e9, 0.05 - 1e9]);
        let mut masked = full.clone();
        k.softmax_rows(&mut full, cols, cols);
        k.softmax_rows(&mut masked, cols, head.len());
        for (i, (a, b)) in full.iter().zip(&masked).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "col {i}: {a} vs {b}");
        }
    }

    #[test]
    fn gelu_mask_skips_rows_exactly() {
        let k = Kernels::scalar();
        let cols = 5usize;
        let x: Vec<f32> = (0..3 * cols).map(|i| (i as f32) * 0.3 - 2.0).collect();
        let live = [1.0f32, 0.0, 1.0];
        let mut y = vec![0f32; x.len()];
        let mut t = vec![0f32; x.len()];
        k.gelu_fwd_rows(&x, cols, Some(&live), &mut y, &mut t);
        let mut y_full = vec![0f32; x.len()];
        let mut t_full = vec![0f32; x.len()];
        k.gelu_fwd_rows(&x, cols, None, &mut y_full, &mut t_full);
        for i in 0..x.len() {
            if i / cols == 1 {
                assert_eq!(y[i], 0.0, "dead row must stay zero");
                assert_eq!(t[i], 0.0, "dead row cache must stay zero");
            } else {
                assert_eq!(y[i].to_bits(), y_full[i].to_bits(), "live row changed at {i}");
                assert_eq!(t[i].to_bits(), t_full[i].to_bits(), "live cache changed at {i}");
            }
        }
    }
}
