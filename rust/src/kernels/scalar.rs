//! Scalar reference kernels — the exact loops the pre-SIMD code ran.
//!
//! Every function here is the bit-level ground truth for the strict
//! (default) mode: the SIMD backends must reproduce these results bit for
//! bit (see the module docs in `kernels::` for the one documented
//! exception, the int8 integer-accumulate forward path), and
//! `QRLORA_SIMD=scalar` routes every kernel through this module
//! unchanged. The bodies are verbatim moves of the original inner loops
//! from `tensor.rs`, `quant.rs`, and `model/host.rs` — do not "clean up"
//! their accumulation order.

/// Unrolled dot product with four independent accumulators (keeps the FP
/// dependency chain short enough for the auto-vectorizer). Moved from
/// `tensor::dot`.
#[inline]
pub(crate) fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let mut acc = [0f32; 4];
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// Four dot products sharing one left operand: `[dot(a,b0), …, dot(a,b3)]`,
/// each bit-identical to [`dot`] on the same pair.
#[inline]
pub(crate) fn dot4(a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
    [dot(a, b0), dot(a, b1), dot(a, b2), dot(a, b3)]
}

/// Plain sequential single-accumulator dot product — the attention score /
/// probability contractions in `model/host.rs` accumulate in this order,
/// which is *not* the 4-accumulator order of [`dot`].
#[inline]
pub(crate) fn dot_seq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0f32;
    for (x, y) in a.iter().zip(b) {
        s += x * y;
    }
    s
}

/// Unrolled f32×i8 dot product (four independent accumulators, like
/// [`dot`]); the i8→f32 convert happens in-register, the scale is applied
/// once by the caller after the reduction. Moved from `quant::dot_i8`.
#[inline]
pub(crate) fn dot_i8(a: &[f32], b: &[i8]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let mut acc = [0f32; 4];
    for ci in 0..chunks {
        let i = ci * 4;
        acc[0] += a[i] * b[i] as f32;
        acc[1] += a[i + 1] * b[i + 1] as f32;
        acc[2] += a[i + 2] * b[i + 2] as f32;
        acc[3] += a[i + 3] * b[i + 3] as f32;
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for i in chunks * 4..n {
        s += a[i] * b[i] as f32;
    }
    s
}

/// Integer i8×i8 dot product accumulated in i32 (exact: `|q| ≤ 127`, so
/// the sum is exact for any `k` up to `2^31 / 127^2 ≈ 133k`).
#[inline]
pub(crate) fn dot_i8i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0i32;
    for (x, y) in a.iter().zip(b) {
        s += (*x as i32) * (*y as i32);
    }
    s
}

/// Symmetric absmax int8 quantization of one row — the same rounding as
/// `QuantTensor::quantize` applied to a single group. Returns the scale.
#[inline]
pub(crate) fn quantize_row(x: &[f32], q: &mut [i8]) -> f32 {
    let mut absmax = 0f32;
    for v in x {
        absmax = absmax.max(v.abs());
    }
    let scale = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
    let inv = 1.0 / scale;
    for (dst, &v) in q.iter_mut().zip(x) {
        *dst = (v * inv).round().clamp(-127.0, 127.0) as i8;
    }
    scale
}

/// `y += alpha · x`, elementwise in the serial order.
#[inline]
pub(crate) fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (o, &v) in y.iter_mut().zip(x) {
        *o += alpha * v;
    }
}

/// `y += c · q` with an in-register i8→f32 convert (exact — every i8
/// value is representable in f32). Moved from the `quant::matmul_q` inner
/// loop / `EmbRef::add_row`.
#[inline]
pub(crate) fn axpy_i8(c: f32, q: &[i8], y: &mut [f32]) {
    debug_assert_eq!(q.len(), y.len());
    for (o, &qv) in y.iter_mut().zip(q) {
        *o += c * qv as f32;
    }
}

/// `y = s · q` (int8 row dequantize into an f32 row; `EmbRef::write_row`).
#[inline]
pub(crate) fn scale_i8(s: f32, q: &[i8], y: &mut [f32]) {
    debug_assert_eq!(q.len(), y.len());
    for (o, &qv) in y.iter_mut().zip(q) {
        *o = s * qv as f32;
    }
}

/// `y += x` elementwise.
#[inline]
pub(crate) fn vadd(x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (o, &v) in y.iter_mut().zip(x) {
        *o += v;
    }
}

/// `y *= x` elementwise.
#[inline]
pub(crate) fn vmul(x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (o, &v) in y.iter_mut().zip(x) {
        *o *= v;
    }
}

/// `acc += a ⊙ b` elementwise (per-column independent accumulators — the
/// LayerNorm dγ and λ-gradient reductions).
#[inline]
pub(crate) fn vmuladd(a: &[f32], b: &[f32], acc: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), acc.len());
    for ((o, &x), &y) in acc.iter_mut().zip(a).zip(b) {
        *o += x * y;
    }
}

/// LayerNorm forward normalize/affine for one row:
/// `xhat[j] = (xi[j]-mu)·rs`, `y[j] = xhat[j]·g[j] + b[j]`.
#[inline]
pub(crate) fn ln_norm_row(
    xi: &[f32],
    mu: f32,
    rs: f32,
    g: &[f32],
    b: &[f32],
    y: &mut [f32],
    xhat: &mut [f32],
) {
    for j in 0..xi.len() {
        let h = (xi[j] - mu) * rs;
        xhat[j] = h;
        y[j] = h * g[j] + b[j];
    }
}

/// LayerNorm backward dx for one row:
/// `dx[j] = rstd · (dy[j]·g[j] − m1 − xhat[j]·m2)`.
#[inline]
pub(crate) fn ln_dx_row(
    dyr: &[f32],
    xh: &[f32],
    g: &[f32],
    m1: f32,
    m2: f32,
    rstd: f32,
    dx: &mut [f32],
) {
    for j in 0..dx.len() {
        let dxh = dyr[j] * g[j];
        dx[j] = rstd * (dxh - m1 - xh[j] * m2);
    }
}
