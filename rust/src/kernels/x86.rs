//! x86-64 SIMD kernels (SSE2 baseline + AVX2/FMA).
//!
//! Strict-mode functions reproduce the scalar reference loops bit for bit:
//! the 128-bit accumulator lanes carry exactly the four independent
//! accumulator chains of `scalar::dot`, multiplies and adds stay separate
//! instructions (never fused), and the horizontal reduction uses the same
//! `(l0+l1)+(l2+l3)` parenthesization. Elementwise kernels vectorize at
//! 256 bits — per-lane operation sequences are unchanged, so they are
//! exact in every mode. Only `dot_relaxed` (wide FMA accumulators, opt-in
//! `--simd-relaxed`) and `dot_i8i8` (integer accumulation, exact in i32
//! but a different *quantization* than the scalar fused-dequant path) may
//! differ from scalar bits.
//!
//! Everything here is `unsafe fn`: AVX2/FMA functions are
//! `#[target_feature]`-gated and must only be called after runtime
//! detection (`kernels::detect`), which `Kernels` guarantees by
//! construction.

#![cfg(target_arch = "x86_64")]

use std::arch::x86_64::*;

#[inline]
unsafe fn hsum4(acc: __m128) -> f32 {
    let mut lanes = [0f32; 4];
    _mm_storeu_ps(lanes.as_mut_ptr(), acc);
    (lanes[0] + lanes[1]) + (lanes[2] + lanes[3])
}

/// Strict dot product: lane `j` of the SSE accumulator runs exactly the
/// scalar chain `acc[j]`, so the result bit-matches `scalar::dot`.
///
/// # Safety
/// SSE2 is baseline on x86-64; callers only need valid slices of equal
/// length (checked by debug assertion).
pub(crate) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let mut acc = _mm_setzero_ps();
    for c in 0..chunks {
        let i = c * 4;
        let va = _mm_loadu_ps(a.as_ptr().add(i));
        let vb = _mm_loadu_ps(b.as_ptr().add(i));
        acc = _mm_add_ps(acc, _mm_mul_ps(va, vb));
    }
    let mut s = hsum4(acc);
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// Four strict dots sharing the `a` loads (the default-mode matmul
/// speedup: 4x fewer loads of the left row, four independent accumulator
/// registers in flight). Each output bit-matches `scalar::dot(a, b_j)`.
///
/// # Safety
/// As [`dot`]: baseline SSE2, equal-length slices.
pub(crate) unsafe fn dot4(a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
    let n = a.len();
    let chunks = n / 4;
    let mut acc0 = _mm_setzero_ps();
    let mut acc1 = _mm_setzero_ps();
    let mut acc2 = _mm_setzero_ps();
    let mut acc3 = _mm_setzero_ps();
    for c in 0..chunks {
        let i = c * 4;
        let va = _mm_loadu_ps(a.as_ptr().add(i));
        acc0 = _mm_add_ps(acc0, _mm_mul_ps(va, _mm_loadu_ps(b0.as_ptr().add(i))));
        acc1 = _mm_add_ps(acc1, _mm_mul_ps(va, _mm_loadu_ps(b1.as_ptr().add(i))));
        acc2 = _mm_add_ps(acc2, _mm_mul_ps(va, _mm_loadu_ps(b2.as_ptr().add(i))));
        acc3 = _mm_add_ps(acc3, _mm_mul_ps(va, _mm_loadu_ps(b3.as_ptr().add(i))));
    }
    let mut out = [hsum4(acc0), hsum4(acc1), hsum4(acc2), hsum4(acc3)];
    for i in chunks * 4..n {
        out[0] += a[i] * b0[i];
        out[1] += a[i] * b1[i];
        out[2] += a[i] * b2[i];
        out[3] += a[i] * b3[i];
    }
    out
}

/// Relaxed dot product: four 256-bit FMA accumulators (32 lanes in
/// flight). Faster but re-associated — only reachable through the opt-in
/// relaxed mode (`--simd-relaxed`, ≤1e-5 relative-error contract).
///
/// # Safety
/// Requires AVX2+FMA; `Kernels` only dispatches here after runtime
/// detection confirmed both.
#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn dot_relaxed(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut acc2 = _mm256_setzero_ps();
    let mut acc3 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 32 <= n {
        acc0 = _mm256_fmadd_ps(
            _mm256_loadu_ps(a.as_ptr().add(i)),
            _mm256_loadu_ps(b.as_ptr().add(i)),
            acc0,
        );
        acc1 = _mm256_fmadd_ps(
            _mm256_loadu_ps(a.as_ptr().add(i + 8)),
            _mm256_loadu_ps(b.as_ptr().add(i + 8)),
            acc1,
        );
        acc2 = _mm256_fmadd_ps(
            _mm256_loadu_ps(a.as_ptr().add(i + 16)),
            _mm256_loadu_ps(b.as_ptr().add(i + 16)),
            acc2,
        );
        acc3 = _mm256_fmadd_ps(
            _mm256_loadu_ps(a.as_ptr().add(i + 24)),
            _mm256_loadu_ps(b.as_ptr().add(i + 24)),
            acc3,
        );
        i += 32;
    }
    let mut acc = _mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3));
    while i + 8 <= n {
        acc = _mm256_fmadd_ps(
            _mm256_loadu_ps(a.as_ptr().add(i)),
            _mm256_loadu_ps(b.as_ptr().add(i)),
            acc,
        );
        i += 8;
    }
    let q = _mm_add_ps(_mm256_castps256_ps128(acc), _mm256_extractf128_ps::<1>(acc));
    let mut s = hsum4(q);
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// Integer i8×i8 dot product: 16 products per step via
/// sign-extend-to-i16 + `madd` pairs, accumulated in i32 lanes (exact —
/// integer addition is associative, so the lane split cannot change the
/// sum).
///
/// # Safety
/// Requires AVX2 (runtime-detected by `Kernels`).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn dot_i8i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 16 <= n {
        let va = _mm256_cvtepi8_epi16(_mm_loadu_si128(a.as_ptr().add(i).cast()));
        let vb = _mm256_cvtepi8_epi16(_mm_loadu_si128(b.as_ptr().add(i).cast()));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(va, vb));
        i += 16;
    }
    let q = _mm_add_epi32(_mm256_castsi256_si128(acc), _mm256_extracti128_si256::<1>(acc));
    let q = _mm_add_epi32(q, _mm_shuffle_epi32::<0x0E>(q)); // lanes [2,3] onto [0,1]
    let q = _mm_add_epi32(q, _mm_shuffle_epi32::<0x01>(q)); // lane 1 onto 0
    let mut s = _mm_cvtsi128_si32(q);
    while i < n {
        s += (a[i] as i32) * (b[i] as i32);
        i += 1;
    }
    s
}

/// `y += alpha · x` at 256 bits — exact in every mode (independent lanes,
/// separate mul/add, same per-element sequence as scalar).
///
/// # Safety
/// Requires AVX (implied by the AVX2 runtime detection `Kernels` does).
#[target_feature(enable = "avx")]
pub(crate) unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = y.len();
    let va = _mm256_set1_ps(alpha);
    let mut i = 0usize;
    while i + 8 <= n {
        let vy = _mm256_loadu_ps(y.as_ptr().add(i));
        let vx = _mm256_loadu_ps(x.as_ptr().add(i));
        _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(vy, _mm256_mul_ps(va, vx)));
        i += 8;
    }
    while i < n {
        y[i] += alpha * x[i];
        i += 1;
    }
}

/// `y += c · q` (int8 operand, exact i8→i32→f32 convert per lane).
///
/// # Safety
/// Requires AVX2 (runtime-detected by `Kernels`).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn axpy_i8(c: f32, q: &[i8], y: &mut [f32]) {
    debug_assert_eq!(q.len(), y.len());
    let n = y.len();
    let vc = _mm256_set1_ps(c);
    let mut i = 0usize;
    while i + 8 <= n {
        let vq =
            _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(_mm_loadl_epi64(q.as_ptr().add(i).cast())));
        let vy = _mm256_loadu_ps(y.as_ptr().add(i));
        _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(vy, _mm256_mul_ps(vc, vq)));
        i += 8;
    }
    while i < n {
        y[i] += c * q[i] as f32;
        i += 1;
    }
}

/// `y = s · q` (int8 row dequantize, exact per lane).
///
/// # Safety
/// Requires AVX2 (runtime-detected by `Kernels`).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn scale_i8(s: f32, q: &[i8], y: &mut [f32]) {
    debug_assert_eq!(q.len(), y.len());
    let n = y.len();
    let vs = _mm256_set1_ps(s);
    let mut i = 0usize;
    while i + 8 <= n {
        let vq =
            _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(_mm_loadl_epi64(q.as_ptr().add(i).cast())));
        _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_mul_ps(vs, vq));
        i += 8;
    }
    while i < n {
        y[i] = s * q[i] as f32;
        i += 1;
    }
}

/// `y += x` at 256 bits (exact).
///
/// # Safety
/// Requires AVX (runtime-detected by `Kernels`).
#[target_feature(enable = "avx")]
pub(crate) unsafe fn vadd(x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = y.len();
    let mut i = 0usize;
    while i + 8 <= n {
        let vy = _mm256_loadu_ps(y.as_ptr().add(i));
        let vx = _mm256_loadu_ps(x.as_ptr().add(i));
        _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(vy, vx));
        i += 8;
    }
    while i < n {
        y[i] += x[i];
        i += 1;
    }
}

/// `y *= x` at 256 bits (exact).
///
/// # Safety
/// Requires AVX (runtime-detected by `Kernels`).
#[target_feature(enable = "avx")]
pub(crate) unsafe fn vmul(x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = y.len();
    let mut i = 0usize;
    while i + 8 <= n {
        let vy = _mm256_loadu_ps(y.as_ptr().add(i));
        let vx = _mm256_loadu_ps(x.as_ptr().add(i));
        _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_mul_ps(vy, vx));
        i += 8;
    }
    while i < n {
        y[i] *= x[i];
        i += 1;
    }
}

/// `acc += a ⊙ b` at 256 bits (exact — per-column accumulators are
/// independent, mul and add stay separate).
///
/// # Safety
/// Requires AVX (runtime-detected by `Kernels`).
#[target_feature(enable = "avx")]
pub(crate) unsafe fn vmuladd(a: &[f32], b: &[f32], acc: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), acc.len());
    let n = acc.len();
    let mut i = 0usize;
    while i + 8 <= n {
        let vo = _mm256_loadu_ps(acc.as_ptr().add(i));
        let va = _mm256_loadu_ps(a.as_ptr().add(i));
        let vb = _mm256_loadu_ps(b.as_ptr().add(i));
        _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_add_ps(vo, _mm256_mul_ps(va, vb)));
        i += 8;
    }
    while i < n {
        acc[i] += a[i] * b[i];
        i += 1;
    }
}

/// LayerNorm forward normalize/affine for one row (exact — per-lane
/// `(x-mu)*rs` then `h*g+b`, same op sequence as scalar).
///
/// # Safety
/// Requires AVX (runtime-detected by `Kernels`). All slices share one
/// length.
#[target_feature(enable = "avx")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn ln_norm_row(
    xi: &[f32],
    mu: f32,
    rs: f32,
    g: &[f32],
    b: &[f32],
    y: &mut [f32],
    xhat: &mut [f32],
) {
    let d = xi.len();
    let vmu = _mm256_set1_ps(mu);
    let vrs = _mm256_set1_ps(rs);
    let mut j = 0usize;
    while j + 8 <= d {
        let vx = _mm256_loadu_ps(xi.as_ptr().add(j));
        let vh = _mm256_mul_ps(_mm256_sub_ps(vx, vmu), vrs);
        _mm256_storeu_ps(xhat.as_mut_ptr().add(j), vh);
        let vg = _mm256_loadu_ps(g.as_ptr().add(j));
        let vb = _mm256_loadu_ps(b.as_ptr().add(j));
        _mm256_storeu_ps(y.as_mut_ptr().add(j), _mm256_add_ps(_mm256_mul_ps(vh, vg), vb));
        j += 8;
    }
    while j < d {
        let h = (xi[j] - mu) * rs;
        xhat[j] = h;
        y[j] = h * g[j] + b[j];
        j += 1;
    }
}

/// LayerNorm backward dx for one row (exact — per-lane
/// `rstd·((dy·g − m1) − xhat·m2)`, same op sequence as scalar).
///
/// # Safety
/// Requires AVX (runtime-detected by `Kernels`). All slices share one
/// length.
#[target_feature(enable = "avx")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn ln_dx_row(
    dyr: &[f32],
    xh: &[f32],
    g: &[f32],
    m1: f32,
    m2: f32,
    rstd: f32,
    dx: &mut [f32],
) {
    let d = dx.len();
    let vm1 = _mm256_set1_ps(m1);
    let vm2 = _mm256_set1_ps(m2);
    let vrs = _mm256_set1_ps(rstd);
    let mut j = 0usize;
    while j + 8 <= d {
        let vdy = _mm256_loadu_ps(dyr.as_ptr().add(j));
        let vg = _mm256_loadu_ps(g.as_ptr().add(j));
        let vxh = _mm256_loadu_ps(xh.as_ptr().add(j));
        let vdxh = _mm256_mul_ps(vdy, vg);
        let vt = _mm256_sub_ps(_mm256_sub_ps(vdxh, vm1), _mm256_mul_ps(vxh, vm2));
        _mm256_storeu_ps(dx.as_mut_ptr().add(j), _mm256_mul_ps(vrs, vt));
        j += 8;
    }
    while j < d {
        let dxh = dyr[j] * g[j];
        dx[j] = rstd * (dxh - m1 - xh[j] * m2);
        j += 1;
    }
}
