//! Experiment harnesses regenerating every table and figure of the paper.
//!
//! | id | paper artifact | harness |
//! |----|----------------|---------|
//! | T1 | Table 1 (MNLI sweep)        | [`table1`] |
//! | T2 | Table 2 (MRPC sweep)        | [`table2`] |
//! | T3 | Table 3 (8-task comparison) | [`table3`] |
//! | T4 | Table 4 (data ablation)     | [`table4`] |
//! | F1 | Figure 1 (params vs perf)   | [`figure1`] |
//!
//! Rows print as GitHub-flavoured markdown on stdout (the same rows the
//! paper reports, with our measured numbers); EXPERIMENTS.md records a
//! captured run.

mod pipeline;

pub use pipeline::Pipeline;

use crate::adapters::{Proj, Scope};

use crate::linalg::RankRule;
use crate::training::{self, FinetuneJob, Method, Methods, RunResult, TrainConfig};

/// Shared experiment knobs (scaled-down budgets for the 1-core testbed).
#[derive(Clone, Debug)]
pub struct ExpConfig {
    pub preset: String,
    pub pretrain_steps: usize,
    pub warmup_steps: usize,
    pub steps: usize,
    pub train_examples: usize,
    pub seed: u64,
    pub lr_ft: f64,
    pub lr_adapter: f64,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            preset: "tiny".into(),
            pretrain_steps: 500,
            warmup_steps: 600,
            steps: 500,
            train_examples: 10_000,
            seed: 17,
            lr_ft: 5e-4,
            lr_adapter: 2e-3,
        }
    }
}

impl ExpConfig {
    fn train_cfg(&self, is_ft: bool) -> TrainConfig {
        TrainConfig {
            steps: self.steps,
            lr: if is_ft { self.lr_ft } else { self.lr_adapter },
            warmup_steps: (self.steps / 20).max(5),
            train_examples: self.train_examples,
            log_every: (self.steps / 5).max(1),
        }
    }
}

fn run(
    pipe: &mut Pipeline,
    cfg: &ExpConfig,
    task_name: &str,
    method: &Method,
    train_examples: usize,
) -> anyhow::Result<RunResult> {
    let mut tc = cfg.train_cfg(matches!(method, Method::FullFt));
    tc.train_examples = train_examples;
    let (warm_bb, warm_head) = pipe.warmed(task_name)?;
    let data = pipe.data(task_name)?;
    let job = FinetuneJob {
        rt: pipe.rt,
        preset: &cfg.preset,
        task: &data,
        lexicon: &pipe.lexicon,
        backbone: &warm_bb,
        head: Some(&warm_head),
        config: tc,
        seed: cfg.seed ^ 0x51ab,
    };
    training::run_finetune(&job, method)
}

/// A printed table row.
pub struct Row {
    pub category: String,
    pub config: String,
    pub params: usize,
    pub cells: Vec<(String, f64)>,
}

pub fn print_table(title: &str, header: &[&str], rows: &[Row]) {
    println!("\n### {title}\n");
    println!("| Category | Configuration | # Trainable | {} |", header.join(" | "));
    println!("|---|---|---:|{}", "---:|".repeat(header.len()));
    for r in rows {
        let cells: Vec<String> = r.cells.iter().map(|(_, v)| format!("{v:.2}")).collect();
        println!(
            "| {} | {} | {} | {} |",
            r.category,
            r.config,
            r.params,
            cells.join(" | ")
        );
    }
}

/// Tables 1 & 2: per-task sweep over method / τ / scope / projection set.
pub fn table_sweep(cfg: &ExpConfig, task_name: &str) -> anyhow::Result<Vec<Row>> {
    let mut pipe = Pipeline::new(cfg)?;
    let preset = pipe.preset.clone();
    let mut rows = Vec::new();
    let (warm_bb, _) = pipe.warmed(task_name)?;

    // Baselines.
    let baselines: Vec<(&str, &str, Method)> = vec![
        ("Fine-tuning", "warm + adapt epochs", Method::FullFt),
        ("Original LoRA", "ΔW = BA, r = 2", Methods::lora(&warm_bb, &preset, 2.0, cfg.seed)?),
        ("SVD-LoRA", "r=2, k=1, α=2", Methods::svd_lora(&warm_bb, &preset, 1, 2.0, cfg.seed)?),
    ];
    // QR-LoRA τ sweep (all layers, W_o) + scope/projection sweep (τ=0.5).
    let nl = preset.n_layers;
    let last_k = (nl / 3).max(1); // "last 4 of 12" → last third
    let qr_variants: Vec<(String, Scope, f64)> = vec![
        (format!("τ=0.5, all {nl} layers W_o"), Scope::all_layers(&[Proj::O]), 0.5),
        (format!("τ=0.7, all {nl} layers W_o"), Scope::all_layers(&[Proj::O]), 0.7),
        (format!("τ=0.8, all {nl} layers W_o"), Scope::all_layers(&[Proj::O]), 0.8),
        (format!("τ=0.5, last {last_k} layers W_o"), Scope::last_layers(last_k, &[Proj::O]), 0.5),
        (
            format!("τ=0.5, last {last_k} layers W_q,W_v"),
            Scope::last_layers(last_k, &[Proj::Q, Proj::V]),
            0.5,
        ),
    ];

    let header_vals = |r: &RunResult| -> Vec<(String, f64)> {
        let mut cells = vec![("Acc-1".to_string(), 100.0 * r.dev.accuracy)];
        if let Some(mm) = &r.dev_mm {
            cells.push(("Acc-2".to_string(), 100.0 * mm.accuracy));
        } else {
            cells.push(("F1".to_string(), 100.0 * r.dev.f1));
        }
        cells
    };

    for (cat, label, method) in baselines {
        let r = run(&mut pipe, cfg, task_name, &method, cfg.train_examples)?;
        crate::info!("{task_name} {cat}: {:?}", r.headline());
        rows.push(Row {
            category: cat.to_string(),
            config: label.to_string(),
            params: if matches!(method, Method::FullFt) {
                r.trainable_params
            } else {
                r.trainable_params
            },
            cells: header_vals(&r),
        });
    }
    for (label, scope, tau) in qr_variants {
        let method = Methods::qr_lora(&warm_bb, &preset, scope, tau, RankRule::DiagRatio)?;
        let r = run(&mut pipe, cfg, task_name, &method, cfg.train_examples)?;
        crate::info!("{task_name} QR-LoRA {label}: {:?}", r.headline());
        rows.push(Row {
            category: "QR-LoRA".to_string(),
            config: label,
            params: r.trainable_params,
            cells: header_vals(&r),
        });
    }
    Ok(rows)
}

pub fn table1(cfg: &ExpConfig) -> anyhow::Result<()> {
    let rows = table_sweep(cfg, "mnli")?;
    print_table(
        "Table 1 — MNLI (matched / mismatched accuracy)",
        &["Accuracy-1 (%)", "Accuracy-2 (%)"],
        &rows,
    );
    Ok(())
}

pub fn table2(cfg: &ExpConfig) -> anyhow::Result<()> {
    let rows = table_sweep(cfg, "mrpc")?;
    print_table("Table 2 — MRPC (accuracy / F1)", &["Accuracy (%)", "F1 (%)"], &rows);
    Ok(())
}

/// Table 3: QR-LoRA1/2 vs SVD-LoRA vs LoRA vs FT across all 8 tasks.
pub fn table3(cfg: &ExpConfig, tasks: &[&str]) -> anyhow::Result<()> {
    let mut pipe = Pipeline::new(cfg)?;
    let preset = pipe.preset.clone();
    let nl = preset.n_layers;
    let last_k = (nl / 3).max(1);

    let mut rows: Vec<Row> = Vec::new();
    let method_specs: Vec<(&str, &str)> = vec![
        ("QR-LoRA1", "Wq,Wv last-k τ=0.5"),
        ("QR-LoRA2", "Wq last-k τ=0.5"),
        ("SVD-LoRA", "r=2,k=1,α=2"),
        ("LoRA", "ΔW=BA, r=2"),
        ("FT", "full"),
    ];
    for (mname, label) in &method_specs {
        let mut cells = Vec::new();
        let mut params = 0usize;
        for task_name in tasks {
            let (warm_bb, _) = pipe.warmed(task_name)?;
            let method = match *mname {
                "QR-LoRA1" => Methods::qr_lora(
                    &warm_bb,
                    &preset,
                    Scope::last_layers(last_k, &[Proj::Q, Proj::V]),
                    0.5,
                    RankRule::DiagRatio,
                )?,
                "QR-LoRA2" => Methods::qr_lora(
                    &warm_bb,
                    &preset,
                    Scope::last_layers(last_k, &[Proj::Q]),
                    0.5,
                    RankRule::DiagRatio,
                )?,
                "SVD-LoRA" => Methods::svd_lora(&warm_bb, &preset, 1, 2.0, cfg.seed)?,
                "LoRA" => Methods::lora(&warm_bb, &preset, 2.0, cfg.seed)?,
                _ => Method::FullFt,
            };
            let r = run(&mut pipe, cfg, task_name, &method, cfg.train_examples)?;
            params = r.trainable_params;
            crate::info!("table3 {mname} {task_name}: {:.2}", r.headline());
            cells.push((task_name.to_string(), r.headline()));
        }
        rows.push(Row {
            category: mname.to_string(),
            config: label.to_string(),
            params,
            cells,
        });
    }
    let header: Vec<&str> = tasks.to_vec();
    print_table("Table 3 — method comparison across tasks (headline metric %)", &header, &rows);
    Ok(())
}

/// Table 4: MNLI training-set-size ablation {2k, 10k, 50k} × {LoRA, QR-LoRA, FT}.
pub fn table4(cfg: &ExpConfig, sizes: &[usize]) -> anyhow::Result<()> {
    let mut pipe = Pipeline::new(cfg)?;
    let preset = pipe.preset.clone();
    let nl = preset.n_layers;
    let last_k = (nl / 3).max(1);
    let mut rows = Vec::new();
    for &size in sizes {
        let (warm_bb, _) = pipe.warmed("mnli")?;
        let methods: Vec<(&str, Method)> = vec![
            ("LoRA", Methods::lora(&warm_bb, &preset, 2.0, cfg.seed)?),
            (
                "QR-LoRA",
                Methods::qr_lora(
                    &warm_bb,
                    &preset,
                    Scope::last_layers(last_k, &[Proj::Q, Proj::V]),
                    0.5,
                    RankRule::DiagRatio,
                )?,
            ),
            ("FT", Method::FullFt),
        ];
        for (name, method) in methods {
            let r = run(&mut pipe, cfg, "mnli", &method, size)?;
            crate::info!("table4 {name}@{size}: {:.2}/{:.2}", 100.0 * r.dev.accuracy,
                r.dev_mm.as_ref().map(|m| 100.0 * m.accuracy).unwrap_or(0.0));
            rows.push(Row {
                category: name.to_string(),
                config: format!("{size} examples"),
                params: r.trainable_params,
                cells: vec![
                    ("Acc-1".into(), 100.0 * r.dev.accuracy),
                    ("Acc-2".into(), 100.0 * r.dev_mm.map(|m| m.accuracy).unwrap_or(0.0)),
                ],
            });
        }
    }
    print_table(
        "Table 4 — MNLI data ablation (matched / mismatched accuracy)",
        &["Accuracy-1 (%)", "Accuracy-2 (%)"],
        &rows,
    );
    Ok(())
}

/// Figure 1: parameter-count vs performance scatter (MNLI + MRPC), emitted
/// as CSV plus an ASCII scatter.
pub fn figure1(cfg: &ExpConfig) -> anyhow::Result<()> {
    let mut points: Vec<(String, usize, f64)> = Vec::new();
    for task_name in ["mnli", "mrpc"] {
        let rows = table_sweep(cfg, task_name)?;
        for r in rows {
            points.push((
                format!("{task_name}/{}", r.category),
                r.params,
                r.cells[0].1,
            ));
        }
    }
    println!("\n### Figure 1 — parameter/performance trade-off (CSV)\n");
    println!("```csv\nseries,params,metric");
    for (name, params, metric) in &points {
        println!("{name},{params},{metric:.2}");
    }
    println!("```");
    // ASCII scatter: log10(params) on x, metric on y.
    println!("\n```text");
    let (w, h) = (64usize, 16usize);
    let xmax = points.iter().map(|p| (p.1.max(1) as f64).log10()).fold(1.0f64, f64::max);
    let ymin = points.iter().map(|p| p.2).fold(f64::INFINITY, f64::min) - 1.0;
    let ymax = points.iter().map(|p| p.2).fold(0.0f64, f64::max) + 1.0;
    let mut grid = vec![vec![' '; w]; h];
    for (name, params, metric) in &points {
        let x = (((params.max(&1).clone() as f64).log10() / xmax) * (w - 1) as f64) as usize;
        let y = (((metric - ymin) / (ymax - ymin)) * (h - 1) as f64) as usize;
        let c = name.split('/').nth(1).and_then(|s| s.chars().next()).unwrap_or('?');
        grid[h - 1 - y.min(h - 1)][x.min(w - 1)] = c;
    }
    for row in grid {
        println!("{}", row.into_iter().collect::<String>());
    }
    println!("x: log10(trainable params)  y: headline metric (%)");
    println!("F=Fine-tuning  O=Original LoRA  S=SVD-LoRA  Q=QR-LoRA");
    println!("```");
    Ok(())
}
