//! Pipeline caching: pretrained backbone and per-task warm-up results are
//! computed once per (preset, seed) and cached under `runs/` so table
//! harnesses that share a task don't redo the expensive phases.

use std::collections::BTreeMap;
use std::path::PathBuf;

use super::ExpConfig;
use crate::data::{task, Lexicon, TaskData};
use crate::model::checkpoint;
use crate::runtime::{create_backend, Backend, BackendChoice, Preset};
use crate::tensor::Tensor;
use crate::training::{self, TrainConfig};

type Params = BTreeMap<String, Tensor>;

pub struct Pipeline {
    pub rt: &'static dyn Backend,
    pub preset: Preset,
    pub lexicon: Lexicon,
    cfg: ExpConfig,
    runs_dir: PathBuf,
    backbone: Option<Params>,
    warmed: BTreeMap<String, (Params, Params)>,
    data: BTreeMap<String, TaskData>,
}

/// The backend is created once per thread and leaked — sessions borrow it
/// for the process lifetime. (Backends hold `Rc` executable caches, so they
/// are deliberately thread-local; experiment driving is single-threaded.)
///
/// Selection: `QRLORA_BACKEND` ∈ {auto, host, pjrt} (default auto: PJRT
/// when compiled with the `pjrt` feature and `$QRLORA_ARTIFACTS/manifest.json`
/// exists, else the hermetic host backend).
fn global_backend() -> anyhow::Result<&'static dyn Backend> {
    thread_local! {
        static RT: std::cell::OnceCell<&'static dyn Backend> = const { std::cell::OnceCell::new() };
    }
    RT.with(|cell| {
        if let Some(rt) = cell.get() {
            return Ok(*rt);
        }
        let dir = std::env::var("QRLORA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        let choice = BackendChoice::from_env()?;
        let bk = create_backend(choice, std::path::Path::new(&dir))?;
        crate::debugln!("using {} backend", bk.name());
        let bk: &'static dyn Backend = Box::leak(bk);
        let _ = cell.set(bk);
        Ok(bk)
    })
}

impl Pipeline {
    pub fn new(cfg: &ExpConfig) -> anyhow::Result<Pipeline> {
        let rt = global_backend()?;
        let preset = rt.manifest().preset(&cfg.preset)?.clone();
        let lexicon = Lexicon::new(preset.vocab);
        Ok(Pipeline {
            rt,
            preset,
            lexicon,
            cfg: cfg.clone(),
            runs_dir: PathBuf::from("runs"),
            backbone: None,
            warmed: BTreeMap::new(),
            data: BTreeMap::new(),
        })
    }

    /// Task data (cached).
    pub fn data(&mut self, name: &str) -> anyhow::Result<TaskData> {
        if !self.data.contains_key(name) {
            let spec = task(name)?;
            let d = TaskData::generate(spec, &self.lexicon, self.cfg.seed);
            self.data.insert(name.to_string(), d);
        }
        Ok(self.data[name].clone())
    }

    /// MLM-pretrained backbone (cached on disk per preset+seed).
    pub fn backbone(&mut self) -> anyhow::Result<Params> {
        if let Some(bb) = &self.backbone {
            return Ok(bb.clone());
        }
        let path = self.runs_dir.join(format!(
            "backbone_{}_{}_s{}_p{}.qck",
            self.rt.name(),
            self.cfg.preset,
            self.cfg.seed,
            self.cfg.pretrain_steps
        ));
        let bb = if path.exists() {
            crate::info!("loading cached backbone {path:?}");
            checkpoint::load_params(&path)?
        } else {
            crate::info!(
                "pretraining backbone ({} steps, preset {})",
                self.cfg.pretrain_steps,
                self.cfg.preset
            );
            let (bb, losses) = training::pretrain(
                self.rt,
                &self.cfg.preset,
                &self.lexicon,
                self.cfg.pretrain_steps,
                1e-3,
                self.cfg.seed,
            )?;
            crate::info!(
                "pretrain mlm loss {:.3} → {:.3}",
                losses.first().map(|x| x.1).unwrap_or(f32::NAN),
                losses.last().map(|x| x.1).unwrap_or(f32::NAN)
            );
            checkpoint::save_params(&path, &bb)?;
            bb
        };
        self.backbone = Some(bb.clone());
        Ok(bb)
    }

    /// Warm-up FT for a task (cached in memory and on disk).
    pub fn warmed(&mut self, name: &str) -> anyhow::Result<(Params, Params)> {
        if let Some(w) = self.warmed.get(name) {
            return Ok(w.clone());
        }
        let bb_path = self.runs_dir.join(format!(
            "warm_{}_{}_{}_s{}_w{}.qck",
            self.rt.name(),
            self.cfg.preset,
            name,
            self.cfg.seed,
            self.cfg.warmup_steps
        ));
        let head_path = bb_path.with_extension("head.qck");
        let result = if bb_path.exists() && head_path.exists() {
            crate::info!("loading cached warmup for {name}");
            (checkpoint::load_params(&bb_path)?, checkpoint::load_params(&head_path)?)
        } else {
            let backbone = self.backbone()?;
            let data = self.data(name)?;
            crate::info!("warm-up FT on {name} ({} steps)", self.cfg.warmup_steps);
            let wcfg = TrainConfig {
                steps: self.cfg.warmup_steps,
                lr: self.cfg.lr_ft.max(5e-4),
                warmup_steps: (self.cfg.warmup_steps / 10).max(5),
                train_examples: self.cfg.train_examples,
                log_every: (self.cfg.warmup_steps / 4).max(1),
            };
            let (bb, head) = training::warmup(
                self.rt,
                &self.cfg.preset,
                &data,
                &backbone,
                &wcfg,
                self.cfg.seed ^ 0x77,
            )?;
            checkpoint::save_params(&bb_path, &bb)?;
            checkpoint::save_params(&head_path, &head)?;
            (bb, head)
        };
        self.warmed.insert(name.to_string(), result.clone());
        Ok(result)
    }
}
