//! Masked-LM pretraining support: synthetic corpus + BERT-style masking +
//! the pretrain driver.

use std::collections::BTreeMap;

use crate::data::vocab::{CLS, MASK, N_RESERVED, PAD, SEP};
use crate::data::{gen_example, Lexicon, ALL_TASKS};
use crate::model;
use crate::runtime::{Backend, Buffer, Preset, Role};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Build a pretraining corpus by sampling surface sentences from every task
/// generator across all genres — the synthetic analogue of the heterogeneous
/// pretraining text that gives real checkpoints their structured spectra.
pub fn make_corpus(lex: &Lexicon, n_sentences: usize, seed: u64) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(n_sentences);
    for i in 0..n_sentences {
        let spec = &ALL_TASKS[i % ALL_TASKS.len()];
        let genre = rng.below(crate::data::N_GENRES);
        let ex = gen_example(spec, lex, &mut rng, genre, i);
        let mut sent = ex.a;
        if !ex.b.is_empty() {
            sent.push(SEP);
            sent.extend(ex.b);
        }
        out.push(sent);
    }
    out
}

/// Assembles MLM batches with BERT-style 80/10/10 masking.
pub struct MlmBatcher {
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    pub mask_prob: f64,
}

impl MlmBatcher {
    pub fn new(preset: &Preset) -> MlmBatcher {
        MlmBatcher {
            batch: preset.batch,
            seq: preset.max_seq,
            vocab: preset.vocab,
            mask_prob: 0.15,
        }
    }

    /// Build one MLM batch: (input_ids, type_ids, attn_mask, labels).
    /// Labels are -100 everywhere except masked positions.
    pub fn assemble(
        &self,
        sentences: &[&Vec<u32>],
        rng: &mut Rng,
    ) -> (Vec<i32>, Vec<i32>, Vec<f32>, Vec<i32>) {
        let mut ids = Vec::with_capacity(self.batch * self.seq);
        let mut types = vec![0i32; self.batch * self.seq];
        let mut mask = Vec::with_capacity(self.batch * self.seq);
        let mut labels = Vec::with_capacity(self.batch * self.seq);
        for i in 0..self.batch {
            let sent = sentences[i % sentences.len()];
            let mut row = vec![CLS as i32];
            row.extend(sent.iter().map(|&t| t as i32));
            row.push(SEP as i32);
            row.truncate(self.seq);
            let used = row.len();
            row.resize(self.seq, PAD as i32);
            for (s, tok) in row.iter_mut().enumerate() {
                let maskable = s < used && *tok >= N_RESERVED as i32;
                if maskable && rng.chance(self.mask_prob) {
                    labels.push(*tok);
                    let roll = rng.f64();
                    if roll < 0.8 {
                        *tok = MASK as i32;
                    } else if roll < 0.9 {
                        *tok = (N_RESERVED as usize + rng.below(self.vocab - N_RESERVED as usize))
                            as i32;
                    } // else keep original
                } else {
                    labels.push(-100);
                }
                mask.push(if s < used { 1.0 } else { 0.0 });
            }
            ids.extend(row);
        }
        // types already zeroed
        let _ = &mut types;
        (ids, types, mask, labels)
    }
}

/// Run MLM pretraining and return the backbone parameter map.
pub fn pretrain(
    rt: &dyn Backend,
    preset_name: &str,
    lex: &Lexicon,
    steps: usize,
    lr: f64,
    seed: u64,
) -> anyhow::Result<(BTreeMap<String, Tensor>, Vec<(usize, f32)>)> {
    let preset = rt.manifest().preset(preset_name)?.clone();
    let exe = rt.load(&format!("{preset_name}/pretrain_step"))?;
    let exe_metrics = rt.load(&format!("{preset_name}/pretrain_metrics"))?;
    let layout = exe.spec.layout()?.clone();

    let corpus = make_corpus(lex, 4096, seed ^ 0xC0FFEE);
    let batcher = MlmBatcher::new(&preset);
    let mut rng = Rng::new(seed);

    let state = model::init_state(&layout, seed);
    let mut state_buf = rt.upload_f32(&state, &[layout.total])?;
    let mut losses = Vec::new();

    for step in 1..=steps {
        let sents: Vec<&Vec<u32>> = (0..preset.batch)
            .map(|_| &corpus[rng.below(corpus.len())])
            .collect();
        let (ids, types, mask, labels) = batcher.assemble(&sents, &mut rng);
        let lr_now = if step <= 20 {
            lr * step as f64 / 20.0
        } else {
            lr
        } as f32;
        let spec = exe.spec.clone();
        let b = preset.batch;
        let s = preset.max_seq;
        let ids_b = rt.upload_i32(&ids, &[b, s])?;
        let types_b = rt.upload_i32(&types, &[b, s])?;
        let mask_b = rt.upload_f32(&mask, &[b, s])?;
        let labels_b = rt.upload_i32(&labels, &[b, s])?;
        let lr_b = rt.upload_scalar(lr_now)?;
        let t_b = rt.upload_scalar(step as f32)?;

        let mut args: Vec<&Buffer> = Vec::new();
        for t in &spec.inputs {
            match (t.role, t.name.as_str()) {
                (Role::State, _) => args.push(&state_buf),
                (Role::Batch, "batch/input_ids") => args.push(&ids_b),
                (Role::Batch, "batch/type_ids") => args.push(&types_b),
                (Role::Batch, "batch/attn_mask") => args.push(&mask_b),
                (Role::Batch, "batch/mlm_labels") => args.push(&labels_b),
                (Role::Scalar, "lr") => args.push(&lr_b),
                (Role::Scalar, _) => args.push(&t_b),
                (role, name) => anyhow::bail!("unexpected pretrain input {name:?} ({role:?})"),
            }
        }
        let mut outs = rt.execute(&exe, &args)?;
        drop(args);
        state_buf = outs.swap_remove(0);
        if step % 20 == 0 || step == steps || step == 1 {
            let head = rt.read_metrics(&exe_metrics, &state_buf)?;
            losses.push((step, head[0]));
            crate::debugln!("pretrain step {step}: mlm loss {:.4}", head[0]);
        }
    }

    let state = rt.download_f32(&state_buf)?;
    Ok((model::extract_all(&state, &layout), losses))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_sentences_nonempty() {
        let lex = Lexicon::new(512);
        let c = make_corpus(&lex, 64, 1);
        assert_eq!(c.len(), 64);
        assert!(c.iter().all(|s| !s.is_empty()));
    }

    #[test]
    fn mlm_masking_rates() {
        let lex = Lexicon::new(512);
        let c = make_corpus(&lex, 32, 2);
        let b = MlmBatcher {
            batch: 16,
            seq: 32,
            vocab: 512,
            mask_prob: 0.15,
        };
        let refs: Vec<&Vec<u32>> = c.iter().take(16).collect();
        let mut rng = Rng::new(3);
        let mut masked = 0usize;
        let mut maskable = 0usize;
        for _ in 0..50 {
            let (ids, _, mask, labels) = b.assemble(&refs, &mut rng);
            assert_eq!(ids.len(), 16 * 32);
            for (i, &l) in labels.iter().enumerate() {
                if mask[i] > 0.0 && ids[i] != CLS as i32 && ids[i] != SEP as i32 {
                    maskable += 1;
                }
                if l >= 0 {
                    masked += 1;
                    assert!(mask[i] > 0.0, "masked a padding position");
                }
            }
        }
        let rate = masked as f64 / maskable as f64;
        assert!((0.10..0.22).contains(&rate), "mask rate {rate}");
    }

    #[test]
    fn labels_match_original_tokens() {
        let lex = Lexicon::new(512);
        let c = make_corpus(&lex, 8, 4);
        let b = MlmBatcher {
            batch: 4,
            seq: 32,
            vocab: 512,
            mask_prob: 0.5,
        };
        let refs: Vec<&Vec<u32>> = c.iter().take(4).collect();
        let mut rng = Rng::new(5);
        let (ids, _, _, labels) = b.assemble(&refs, &mut rng);
        for (i, &l) in labels.iter().enumerate() {
            if l >= 0 {
                // label is a real vocab id; if the input kept the token it
                // must equal the label
                assert!(l >= N_RESERVED as i32 && (l as usize) < 512);
                if ids[i] != MASK as i32 && ids[i] >= N_RESERVED as i32 {
                    // either "keep" (10%) or "random" (10%) case — can't
                    // distinguish, but both are legal
                }
            }
        }
    }
}
