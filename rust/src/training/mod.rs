//! Training + evaluation sessions over the step programs of any
//! [`Backend`] (pure-Rust host interpreter or PJRT AOT graphs).
//!
//! A `Session` owns the backend-resident training state and drives it with
//! batches: one `Backend::execute` per step, state never leaving the
//! backend. Higher-level drivers implement the paper's pipeline:
//!
//!   pretrain (MLM) → warm-up FT on the task → freeze → adapter training
//!
//! and the evaluation protocol (dev / dev-mismatched with per-task metrics).

mod mlm;
mod session;

pub use mlm::{make_corpus, pretrain, MlmBatcher};
pub use session::{EvalOutput, Method, Session, TrainConfig};

use std::collections::BTreeMap;

use crate::adapters::{LoraAdapterSet, QrAdapterSet};
use crate::data::{metric_kind, Batcher, HeadKind, Lexicon, Split, TaskData};
use crate::metrics::EvalResult;
use crate::runtime::Backend;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Everything needed to fine-tune one (task, method) pair.
pub struct FinetuneJob<'a> {
    pub rt: &'a dyn Backend,
    pub preset: &'a str,
    pub task: &'a TaskData,
    pub lexicon: &'a Lexicon,
    pub backbone: &'a BTreeMap<String, Tensor>,
    /// Warmed task head (from the warm-up phase), if any.
    pub head: Option<&'a BTreeMap<String, Tensor>>,
    pub config: TrainConfig,
    pub seed: u64,
}

/// Result of a fine-tune run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub task: String,
    pub method_label: String,
    pub trainable_params: usize,
    pub dev: EvalResult,
    pub dev_mm: Option<EvalResult>,
    pub final_loss: f32,
    pub steps: usize,
    pub losses: Vec<(usize, f32)>,
}

impl RunResult {
    /// Headline metric (task convention) in percent.
    pub fn headline(&self) -> f64 {
        self.dev.headline(metric_kind(&self.task))
    }
}

/// Run one fine-tuning job with a given method.
pub fn run_finetune(job: &FinetuneJob, method: &Method) -> anyhow::Result<RunResult> {
    let preset = job.rt.manifest().preset(job.preset)?.clone();
    let head_kind = job.task.spec.head;
    let mut session = Session::finetune(
        job.rt,
        &preset,
        method,
        head_kind,
        job.backbone,
        job.head,
        job.seed,
    )?;

    let batcher = Batcher::new(&preset, head_kind == HeadKind::Reg);
    let mut rng = Rng::new(job.seed ^ 0xFEED);
    let cfg = &job.config;

    let train = &job.task.train[..cfg.train_examples.min(job.task.train.len())];
    let mut losses = Vec::new();
    let mut step = 0usize;
    'outer: loop {
        for chunk in batcher.epoch(train, &mut rng) {
            if step >= cfg.steps {
                break 'outer;
            }
            let batch = batcher.assemble(&chunk);
            let lr = cfg.lr_at(step);
            session.step(&batch, job.task.spec.n_classes, lr)?;
            if step % cfg.log_every == 0 || step + 1 == cfg.steps {
                let loss = session.last_loss()?;
                losses.push((step, loss));
                crate::debugln!(
                    "{}/{} step {step}: loss {loss:.4} lr {lr:.2e}",
                    job.task.spec.name,
                    session.method_label()
                );
            }
            step += 1;
        }
        if train.is_empty() {
            anyhow::bail!("empty training set");
        }
    }

    let dev = session.evaluate(&batcher, job.task, Split::Dev)?;
    let dev_mm = if job.task.spec.mm_genres.is_some() {
        Some(session.evaluate(&batcher, job.task, Split::DevMismatched)?)
    } else {
        None
    };

    Ok(RunResult {
        task: job.task.spec.name.to_string(),
        method_label: session.method_label().to_string(),
        trainable_params: session.trainable_params(),
        dev: dev.result,
        dev_mm: dev_mm.map(|e| e.result),
        final_loss: losses.last().map(|(_, l)| *l).unwrap_or(f32::NAN),
        steps: step,
        losses,
    })
}

/// Warm-up: full fine-tune on the task for `steps`, returning the updated
/// backbone and the trained task head (the paper warm-up fine-tunes for
/// three epochs before attaching adapters).
pub fn warmup(
    rt: &dyn Backend,
    preset_name: &str,
    task: &TaskData,
    backbone: &BTreeMap<String, Tensor>,
    cfg: &TrainConfig,
    seed: u64,
) -> anyhow::Result<(BTreeMap<String, Tensor>, BTreeMap<String, Tensor>)> {
    let preset = rt.manifest().preset(preset_name)?.clone();
    let head_kind = task.spec.head;
    let method = Method::FullFt;
    let mut session =
        Session::finetune(rt, &preset, &method, head_kind, backbone, None, seed)?;
    let batcher = Batcher::new(&preset, head_kind == HeadKind::Reg);
    let mut rng = Rng::new(seed ^ 0xBEEF);

    let mut step = 0usize;
    'outer: loop {
        for chunk in batcher.epoch(&task.train, &mut rng) {
            if step >= cfg.steps {
                break 'outer;
            }
            let batch = batcher.assemble(&chunk);
            session.step(&batch, task.spec.n_classes, cfg.lr_at(step))?;
            step += 1;
        }
    }
    let params = session.download_params()?;
    let mut bb = BTreeMap::new();
    let mut head = BTreeMap::new();
    for (name, t) in params {
        if name.starts_with("head/") {
            head.insert(name, t);
        } else {
            bb.insert(name, t);
        }
    }
    Ok((bb, head))
}

/// Build the method descriptor objects from backbone + preset (adapter
/// factorization happens here).
pub struct Methods;

impl Methods {
    pub fn qr_lora(
        backbone: &BTreeMap<String, Tensor>,
        preset: &crate::runtime::Preset,
        scope: crate::adapters::Scope,
        tau: f64,
        rule: crate::linalg::RankRule,
    ) -> anyhow::Result<Method> {
        let set = QrAdapterSet::build(backbone, preset, scope, tau, rule)?;
        Ok(Method::QrLora(set))
    }

    pub fn lora(
        backbone: &BTreeMap<String, Tensor>,
        preset: &crate::runtime::Preset,
        alpha: f32,
        seed: u64,
    ) -> anyhow::Result<Method> {
        let set = LoraAdapterSet::build(
            backbone,
            preset,
            crate::adapters::LoraInit::Standard,
            alpha,
            seed,
        )?;
        Ok(Method::Lora { set, label: "LoRA".into() })
    }

    pub fn svd_lora(
        backbone: &BTreeMap<String, Tensor>,
        preset: &crate::runtime::Preset,
        k: usize,
        alpha: f32,
        seed: u64,
    ) -> anyhow::Result<Method> {
        let set = LoraAdapterSet::build(
            backbone,
            preset,
            crate::adapters::LoraInit::Svd { k },
            alpha,
            seed,
        )?;
        Ok(Method::Lora { set, label: "SVD-LoRA".into() })
    }
}
