//! A training/eval session: backend-resident state + frozen inputs + the
//! step/eval executables for one (preset, method, head) triple.
//!
//! Generic over [`Backend`]: on PJRT the state buffer is device-resident
//! and steps are single `execute` calls; on the host backend the same
//! protocol runs through the pure-Rust interpreter.

use std::collections::BTreeMap;
use std::rc::Rc;

use crate::adapters::{LoraAdapterSet, QrAdapterSet};
use crate::data::{Batch, Batcher, HeadKind, Split, TaskData};
use crate::metrics::{argmax, EvalResult};
use crate::model;
use crate::runtime::{
    Backend, BatchedAdapters, Buffer, DType, Executable, Preset, Role, StateLayout,
};
use crate::tensor::Tensor;

/// Fine-tuning method descriptor (adapter state included).
pub enum Method {
    FullFt,
    QrLora(QrAdapterSet),
    Lora { set: LoraAdapterSet, label: String },
}

impl Method {
    pub fn artifact_name(&self) -> &'static str {
        match self {
            Method::FullFt => "ft",
            Method::QrLora(_) => "qrlora",
            Method::Lora { .. } => "lora",
        }
    }

    pub fn label(&self) -> String {
        match self {
            Method::FullFt => "FT".to_string(),
            Method::QrLora(_) => "QR-LoRA".to_string(),
            Method::Lora { label, .. } => label.clone(),
        }
    }

    /// The method-derived frozen inputs (QR factors/masks for QR-LoRA,
    /// A/B/scales for LoRA; empty for full FT). These ride beside the
    /// backbone as frozen session inputs, and the adapter store folds
    /// them into its backbone fingerprint
    /// (`store::format::fingerprint_extend`) so a record trained under a
    /// different τ/scope/α is rejected at warm start.
    pub fn frozen_inputs(&self) -> Vec<(String, Vec<f32>)> {
        match self {
            Method::FullFt => Vec::new(),
            Method::QrLora(set) => set.frozen_inputs(),
            Method::Lora { set, .. } => set.frozen_inputs(),
        }
    }
}

/// Training hyperparameters + budget.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f64,
    pub warmup_steps: usize,
    pub train_examples: usize,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 300,
            lr: 1e-3,
            warmup_steps: 20,
            train_examples: 10_000,
            log_every: 25,
        }
    }
}

impl TrainConfig {
    /// Linear warmup then constant.
    pub fn lr_at(&self, step: usize) -> f32 {
        if step < self.warmup_steps {
            (self.lr * (step + 1) as f64 / self.warmup_steps as f64) as f32
        } else {
            self.lr as f32
        }
    }
}

/// Evaluation output: aggregated metrics + raw predictions.
pub struct EvalOutput {
    pub result: EvalResult,
    pub preds_cls: Vec<usize>,
    pub preds_reg: Vec<f64>,
}

/// One live training session.
pub struct Session<'a> {
    bk: &'a dyn Backend,
    preset: Preset,
    exe_train: Rc<Executable>,
    exe_metrics: Rc<Executable>,
    exe_eval: Rc<Executable>,
    layout: StateLayout,
    state_buf: Buffer,
    /// Frozen inputs in artifact order (train program).
    frozen: Vec<(String, Buffer)>,
    head_kind: HeadKind,
    method_label: String,
    trainable: usize,
    t: usize,
}

impl<'a> Session<'a> {
    /// Assemble a fine-tune session: state init (+ adapter/backbone
    /// placement), frozen uploads, executable loading.
    pub fn finetune(
        bk: &'a dyn Backend,
        preset: &Preset,
        method: &Method,
        head_kind: HeadKind,
        backbone: &BTreeMap<String, Tensor>,
        head: Option<&BTreeMap<String, Tensor>>,
        seed: u64,
    ) -> anyhow::Result<Session<'a>> {
        let suffix = match head_kind {
            HeadKind::Cls => "cls",
            HeadKind::Reg => "reg",
        };
        let mname = method.artifact_name();
        let key_train = format!("{}/train_step_{}_{}", preset.name, mname, suffix);
        let key_metrics = format!("{}/metrics_{}_{}", preset.name, mname, suffix);
        let key_eval = format!("{}/eval_fwd_{}_{}", preset.name, mname, suffix);
        let exe_train = bk.load(&key_train)?;
        let exe_metrics = bk.load(&key_metrics)?;
        let exe_eval = bk.load(&key_eval)?;
        let layout = exe_train.spec.layout()?.clone();

        // --- state vector -------------------------------------------------
        let mut state = model::init_state(&layout, seed);
        match method {
            Method::FullFt => {
                // Backbone (+ optionally head) are trainable: copy them in.
                for (name, t) in backbone {
                    if layout.param(name).is_ok() {
                        model::write_param(&mut state, &layout, name, t)?;
                    }
                }
            }
            Method::Lora { set, .. } => {
                for (name, t) in set.state_writes() {
                    model::write_param(&mut state, &layout, &name, &t)?;
                }
            }
            Method::QrLora(_) => {} // λ starts at zero (init default)
        }
        if let Some(head_params) = head {
            for (name, t) in head_params {
                if layout.param(name).is_ok() {
                    model::write_param(&mut state, &layout, name, t)?;
                }
            }
        }
        let state_buf = bk.upload_f32(&state, &[layout.total])?;

        // --- frozen inputs (adapter methods: factors/masks + backbone) ----
        let mut frozen_values: BTreeMap<String, Vec<f32>> = BTreeMap::new();
        if !matches!(method, Method::FullFt) {
            for (name, v) in method.frozen_inputs() {
                frozen_values.insert(name, v);
            }
            for (name, t) in backbone {
                frozen_values.insert(name.clone(), t.data.clone());
            }
        }
        let mut frozen = Vec::new();
        for t in exe_train.spec.inputs_with_role(Role::Frozen).map(|(_, t)| t.clone()) {
            let v = frozen_values.remove(&t.name).ok_or_else(|| {
                anyhow::anyhow!("{}: no value for frozen input {:?}", key_train, t.name)
            })?;
            anyhow::ensure!(
                v.len() == t.numel(),
                "{}: frozen {:?} has {} elems, want {}",
                key_train,
                t.name,
                v.len(),
                t.numel()
            );
            frozen.push((t.name.clone(), bk.upload_f32(&v, &t.shape)?));
        }

        let trainable = match method {
            Method::FullFt => layout.n_params,
            Method::QrLora(set) => set.trainable_params(),
            Method::Lora { set, .. } => set.trainable_params(),
        };

        Ok(Session {
            bk,
            preset: preset.clone(),
            exe_train,
            exe_metrics,
            exe_eval,
            layout,
            state_buf,
            frozen,
            head_kind,
            method_label: method.label(),
            trainable,
            t: 0,
        })
    }

    pub fn method_label(&self) -> &str {
        &self.method_label
    }

    /// The backend this session runs on.
    pub fn backend(&self) -> &'a dyn Backend {
        self.bk
    }

    /// Adapter (or full) trainable parameter count, paper convention
    /// (task head excluded for adapter methods).
    pub fn trainable_params(&self) -> usize {
        self.trainable
    }

    pub fn steps_taken(&self) -> usize {
        self.t
    }

    pub fn layout(&self) -> &StateLayout {
        &self.layout
    }

    /// Upload the batch tensors for a program, in artifact order.
    fn batch_buffers(
        &self,
        spec: &crate::runtime::ArtifactSpec,
        batch: &Batch,
        n_classes: usize,
    ) -> anyhow::Result<Vec<(String, Buffer)>> {
        let k = if self.head_kind == HeadKind::Cls {
            self.preset.n_classes
        } else {
            1
        };
        let mut out = Vec::new();
        for (_, t) in spec.inputs_with_role(Role::Batch) {
            let buf = match t.name.as_str() {
                "batch/input_ids" => self.bk.upload_i32(&batch.input_ids, &t.shape)?,
                "batch/type_ids" => self.bk.upload_i32(&batch.type_ids, &t.shape)?,
                "batch/attn_mask" => self.bk.upload_f32(&batch.attn_mask, &t.shape)?,
                "batch/labels" => match t.dtype {
                    DType::I32 => self.bk.upload_i32(&batch.labels_i32, &t.shape)?,
                    DType::F32 => self.bk.upload_f32(&batch.labels_f32, &t.shape)?,
                },
                "batch/class_mask" => {
                    self.bk.upload_f32(&Batcher::class_mask(n_classes, k), &t.shape)?
                }
                "batch/example_w" => self.bk.upload_f32(&batch.example_w, &t.shape)?,
                other => anyhow::bail!("unexpected batch input {other:?}"),
            };
            out.push((t.name.clone(), buf));
        }
        Ok(out)
    }

    /// One training step (single backend call; state stays resident).
    pub fn step(&mut self, batch: &Batch, n_classes: usize, lr: f32) -> anyhow::Result<()> {
        self.t += 1;
        let spec = self.exe_train.spec.clone();
        let batch_bufs = self.batch_buffers(&spec, batch, n_classes)?;
        let lr_buf = self.bk.upload_scalar(lr)?;
        let t_buf = self.bk.upload_scalar(self.t as f32)?;

        let mut args: Vec<&Buffer> = Vec::with_capacity(spec.inputs.len());
        for t in &spec.inputs {
            match t.role {
                Role::State => args.push(&self.state_buf),
                Role::Frozen => {
                    args.push(
                        &self
                            .frozen
                            .iter()
                            .find(|(n, _)| n == &t.name)
                            .ok_or_else(|| anyhow::anyhow!("missing frozen {:?}", t.name))?
                            .1,
                    );
                }
                Role::Batch => {
                    args.push(
                        &batch_bufs
                            .iter()
                            .find(|(n, _)| n == &t.name)
                            .ok_or_else(|| anyhow::anyhow!("missing batch {:?}", t.name))?
                            .1,
                    );
                }
                Role::Scalar => {
                    args.push(if t.name == "lr" { &lr_buf } else { &t_buf });
                }
                other => anyhow::bail!("unexpected input role {other:?}"),
            }
        }
        let mut outs = self.bk.execute(&self.exe_train, &args)?;
        drop(args);
        self.state_buf = outs.swap_remove(0);
        Ok(())
    }

    /// Loss recorded by the most recent step.
    pub fn last_loss(&self) -> anyhow::Result<f32> {
        let head = self.bk.read_metrics(&self.exe_metrics, &self.state_buf)?;
        let f = self.layout.metric("loss")?;
        Ok(head[f.offset])
    }

    /// Logits recorded by the most recent step (B×K row-major).
    pub fn last_logits(&self) -> anyhow::Result<Vec<f32>> {
        let head = self.bk.read_metrics(&self.exe_metrics, &self.state_buf)?;
        let f = self.layout.metric("logits")?;
        Ok(head[f.offset..f.offset + f.numel()].to_vec())
    }

    /// Forward pass on an eval batch → logits (host).
    pub fn forward(&self, batch: &Batch, n_classes: usize) -> anyhow::Result<Vec<f32>> {
        let spec = self.exe_eval.spec.clone();
        let batch_bufs = self.batch_buffers(&spec, batch, n_classes)?;
        let mut args: Vec<&Buffer> = Vec::with_capacity(spec.inputs.len());
        for t in &spec.inputs {
            match t.role {
                Role::State => args.push(&self.state_buf),
                Role::Frozen => {
                    args.push(&self.frozen.iter().find(|(n, _)| n == &t.name).unwrap().1)
                }
                Role::Batch => {
                    args.push(&batch_bufs.iter().find(|(n, _)| n == &t.name).unwrap().1)
                }
                other => anyhow::bail!("unexpected eval input role {other:?}"),
            }
        }
        let outs = self.bk.execute(&self.exe_eval, &args)?;
        drop(args);
        self.bk.download_f32(&outs[0])
    }

    /// Forward pass on a mixed-task batch: per-row adapter selection out
    /// of a resident bank, no state swaps.
    ///
    /// `states[t]` / `class_masks[t]` are the bank's backend-resident
    /// buffers and `row_slots[b]` picks the adapter serving batch row `b`.
    /// The session's own state buffer is not consulted. Per-request logits
    /// are bit-identical to [`Session::forward`] after `upload_state` of
    /// the same adapter (property-tested in `rust/tests/serve_batched.rs`).
    pub fn forward_multi(
        &self,
        batch: &Batch,
        states: &[&Buffer],
        class_masks: &[&Buffer],
        row_slots: &[usize],
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(!states.is_empty(), "forward_multi: empty adapter bank");
        let spec = self.exe_eval.spec.clone();
        // Placeholder class mask (all classes live); execute_batched
        // substitutes each adapter's own mask.
        let k = if self.head_kind == HeadKind::Cls {
            self.preset.n_classes
        } else {
            1
        };
        let batch_bufs = self.batch_buffers(&spec, batch, k)?;
        let mut args: Vec<&Buffer> = Vec::with_capacity(spec.inputs.len());
        for t in &spec.inputs {
            match t.role {
                // Placeholder — execute_batched selects per-row states.
                Role::State => args.push(states[0]),
                Role::Frozen => {
                    args.push(&self.frozen.iter().find(|(n, _)| n == &t.name).unwrap().1)
                }
                Role::Batch => {
                    args.push(&batch_bufs.iter().find(|(n, _)| n == &t.name).unwrap().1)
                }
                other => anyhow::bail!("unexpected eval input role {other:?}"),
            }
        }
        let adapters = BatchedAdapters { states, class_masks, row_slots };
        let outs = self.bk.execute_batched(&self.exe_eval, &args, &adapters)?;
        drop(args);
        self.bk.download_f32(&outs[0])
    }

    /// Evaluate a dataset split with the task's metrics.
    pub fn evaluate(
        &self,
        batcher: &Batcher,
        task: &TaskData,
        split: Split,
    ) -> anyhow::Result<EvalOutput> {
        let data = task.split(split);
        anyhow::ensure!(!data.is_empty(), "empty split {split:?} for {}", task.spec.name);
        let k = if self.head_kind == HeadKind::Cls {
            self.preset.n_classes
        } else {
            1
        };
        let mut preds_cls = Vec::new();
        let mut preds_reg = Vec::new();
        let mut labels_cls = Vec::new();
        let mut labels_reg = Vec::new();

        let refs: Vec<&crate::data::Example> = data.iter().collect();
        for chunk in refs.chunks(batcher.batch) {
            let batch = batcher.assemble(chunk);
            let logits = self.forward(&batch, task.spec.n_classes)?;
            for (i, ex) in chunk.iter().enumerate() {
                let row = &logits[i * k..(i + 1) * k];
                match ex.label {
                    crate::data::Label::Class(c) => {
                        preds_cls.push(argmax(row));
                        labels_cls.push(c);
                    }
                    crate::data::Label::Score(s) => {
                        preds_reg.push(row[0] as f64);
                        labels_reg.push(s as f64);
                    }
                }
            }
        }
        let result = if self.head_kind == HeadKind::Cls {
            EvalResult::classification(&preds_cls, &labels_cls)
        } else {
            EvalResult::regression(&preds_reg, &labels_reg)
        };
        Ok(EvalOutput { result, preds_cls, preds_reg })
    }

    /// Download the trainable parameter region as named tensors.
    pub fn download_params(&self) -> anyhow::Result<BTreeMap<String, Tensor>> {
        let state = self.bk.download_f32(&self.state_buf)?;
        Ok(model::extract_all(&state, &self.layout))
    }

    /// Download the raw state vector (checkpointing).
    pub fn download_state(&self) -> anyhow::Result<Vec<f32>> {
        self.bk.download_f32(&self.state_buf)
    }

    /// Download the Adam moment vectors `(m, v)` from the state tail —
    /// the optional optimizer-state section of a durable adapter record
    /// (`store::format::AdapterRecord`), letting a later session resume
    /// fine-tuning instead of only serving.
    pub fn download_moments(&self) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let state = self.bk.download_f32(&self.state_buf)?;
        let n = self.layout.n_params;
        let base = self.layout.total - 3 * n;
        Ok((state[base + n..base + 2 * n].to_vec(), state[base + 2 * n..base + 3 * n].to_vec()))
    }

    /// Restore a previously saved state vector.
    pub fn upload_state(&mut self, state: &[f32]) -> anyhow::Result<()> {
        anyhow::ensure!(state.len() == self.layout.total, "state length mismatch");
        self.state_buf = self.bk.upload_f32(state, &[self.layout.total])?;
        Ok(())
    }
}
