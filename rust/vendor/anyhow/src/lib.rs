//! Minimal vendored stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so the subset of `anyhow`
//! this workspace relies on is vendored here: the boxed [`Error`] type, the
//! [`Result`] alias, the `anyhow!` / `bail!` / `ensure!` macros, and a
//! [`Context`] extension trait. Error chains print like upstream anyhow:
//! `{}` shows the top message, `{:#}` joins the chain with `: `, and `{:?}`
//! adds a `Caused by:` block.

use std::error::Error as StdError;
use std::fmt;

/// A boxed dynamic error with an optional cause chain.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a printable message.
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error {
            msg: msg.to_string(),
            source: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context(self, msg: impl fmt::Display) -> Error {
        Error {
            msg: msg.to_string(),
            source: Some(Box::new(self)),
        }
    }

}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = &self.source;
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = &e.source;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            let mut cur = &self.source;
            while let Some(e) = cur {
                write!(f, "\n    {}", e.msg)?;
                cur = &e.source;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = Vec::new();
        msgs.push(e.to_string());
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(Error {
                msg: m,
                source: err.map(Box::new),
            });
        }
        err.expect("at least one message")
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to results.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| e.into().context(msg))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42)
    }

    #[test]
    fn display_and_alternate() {
        let e = fails().unwrap_err().context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn from_std_error() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(format!("{e}").contains("gone"));
    }

    #[test]
    fn ensure_passes_and_fails() {
        let ok: Result<()> = (|| {
            ensure!(1 + 1 == 2, "math broke");
            Ok(())
        })();
        assert!(ok.is_ok());
        let err: Result<()> = (|| {
            ensure!(1 + 1 == 3, "bad {}", "sum");
            Ok(())
        })();
        assert_eq!(format!("{}", err.unwrap_err()), "bad sum");
    }

    #[test]
    fn question_mark_converts() {
        fn parse() -> Result<i32> {
            let v: i32 = "12x".parse()?;
            Ok(v)
        }
        assert!(parse().is_err());
    }
}
