//! Stub of the `xla` PJRT binding surface used by `qrlora::runtime`.
//!
//! The real crate links the PJRT C API and cannot be vendored here; this
//! stub carries the exact type/method surface the `pjrt` feature compiles
//! against, and every entry point returns [`XlaError::Unavailable`] at
//! runtime. Swap the `xla` path dependency in the workspace `Cargo.toml`
//! for the real bindings to execute actual HLO artifacts; no source change
//! in `qrlora` is needed.

use std::fmt;

/// Error type mirroring the real crate's (everything here returns
/// `Unavailable`).
#[derive(Debug)]
pub enum XlaError {
    Unavailable(&'static str),
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            XlaError::Unavailable(what) => write!(
                f,
                "{what}: built against the xla stub — swap rust/vendor/xla-stub \
                 for the real xla crate (see README \"Execution backends\"), \
                 or run with the host backend (QRLORA_BACKEND=host)"
            ),
        }
    }
}

impl std::error::Error for XlaError {}

type Result<T> = std::result::Result<T, XlaError>;

const ERR: XlaError = XlaError::Unavailable("PJRT unavailable");

/// Host-side literal value.
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(ERR)
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(ERR)
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(ERR)
    }

    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(ERR)
    }
}

/// PJRT client handle.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(ERR)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(ERR)
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(ERR)
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(ERR)
    }
}

/// XLA computation wrapper.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}
