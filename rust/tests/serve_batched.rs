//! Bit-identity property tests for batched multi-adapter serving.
//!
//! The contract under test: a mixed-task batch served through the resident
//! `AdapterBank` path (`Session::forward_multi` / `execute_batched`) must
//! reproduce the sequential swap-per-request path (`upload_state` +
//! `forward`) **bit for bit**, per request, for both adapter methods and
//! for multiple pool thread counts. The grouped fallback (what a backend
//! without a single-pass fast path runs, e.g. PJRT) must agree too.

use std::collections::{BTreeMap, VecDeque};

use qrlora::adapters::{Proj, Scope};
use qrlora::data::{task, Batcher, Example, HeadKind, Lexicon, TaskData};
use qrlora::linalg::RankRule;
use qrlora::runtime::{execute_batched_grouped, Backend, BatchedAdapters, HostBackend};
use qrlora::server::{serve_swap, Request, Router, RouterStats};
use qrlora::tensor::Tensor;
use qrlora::training::{Method, Methods, Session};
use qrlora::util::pool;
use qrlora::util::rng::Rng;

/// Random backbone with the ft layout's parameter names/shapes (values are
/// irrelevant to the identity property).
fn synthetic_backbone(bk: &dyn Backend) -> BTreeMap<String, Tensor> {
    let exe = bk.load("tiny/train_step_ft_cls").unwrap();
    let mut rng = Rng::new(7);
    let mut backbone = BTreeMap::new();
    for f in &exe.spec.layout().unwrap().params {
        if !f.name.starts_with("head/") {
            backbone.insert(f.name.clone(), Tensor::randn(&f.shape, &mut rng, 0.05));
        }
    }
    backbone
}

/// `n` distinct adapter states: the session's initial state with the
/// trainable region deterministically perturbed per slot.
fn perturbed_states(session: &Session, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let layout = session.layout().clone();
    let base = session.download_state().unwrap();
    (0..n)
        .map(|i| {
            let mut st = base.clone();
            let mut rng = Rng::new(seed + i as u64);
            for f in &layout.params {
                for j in 0..f.numel() {
                    st[f.offset + j] += rng.normal() * 0.02;
                }
            }
            st
        })
        .collect()
}

fn build_method(bk: &dyn Backend, name: &str, backbone: &BTreeMap<String, Tensor>) -> Method {
    let preset = bk.manifest().preset("tiny").unwrap().clone();
    match name {
        "qrlora" => Methods::qr_lora(
            backbone,
            &preset,
            Scope::all_layers(&[Proj::Q, Proj::V]),
            0.5,
            RankRule::DiagRatio,
        )
        .unwrap(),
        "lora" => Methods::lora(backbone, &preset, 2.0, 1).unwrap(),
        other => panic!("unknown method {other}"),
    }
}

/// Mixed batch through the bank vs per-request swaps, bit-compared at
/// several thread counts. `quantize` runs the whole comparison on a
/// backend holding the frozen backbone int8: the serving bit-identity
/// contract (and its thread-count independence) must hold on the
/// quantized path too.
fn check_bit_identity_quant(method_name: &str, quantize: bool) {
    let bk = HostBackend::with_quant(quantize);
    let preset = bk.manifest().preset("tiny").unwrap().clone();
    let backbone = synthetic_backbone(&bk);
    let method = build_method(&bk, method_name, &backbone);
    let mut session =
        Session::finetune(&bk, &preset, &method, HeadKind::Cls, &backbone, None, 3).unwrap();
    let states = perturbed_states(&session, 3, 17);

    let lex = Lexicon::new(preset.vocab);
    let data = TaskData::generate(task("mnli").unwrap(), &lex, 5);
    let batcher = Batcher::new(&preset, false);
    let refs: Vec<&Example> = data.train[..preset.batch].iter().collect();
    let mixed = batcher.assemble(&refs);
    let row_slots: Vec<usize> =
        (0..preset.batch).map(|i| [0, 1, 2, 0, 2, 1, 0, 1][i % 8]).collect();

    let n_classes = 3usize;
    let k = session.layout().param("head/wc").unwrap().shape[1];
    let cmask = Batcher::class_mask(n_classes, k);

    // Swap-per-request reference (serial pool).
    let want_rows: Vec<Vec<f32>> = pool::with_threads(1, || {
        refs.iter()
            .enumerate()
            .map(|(i, ex)| {
                session.upload_state(&states[row_slots[i]]).unwrap();
                let single = batcher.assemble(&[*ex]);
                session.forward(&single, n_classes).unwrap()[..k].to_vec()
            })
            .collect()
    });

    // Resident bank, one mixed pass, at ≥2 thread counts.
    let state_bufs: Vec<_> = states.iter().map(|s| bk.upload_f32(s, &[s.len()]).unwrap()).collect();
    let mask_bufs: Vec<_> =
        (0..states.len()).map(|_| bk.upload_f32(&cmask, &[k]).unwrap()).collect();
    let state_refs: Vec<_> = state_bufs.iter().collect();
    let mask_refs: Vec<_> = mask_bufs.iter().collect();
    for threads in [1usize, 3] {
        let got = pool::with_threads(threads, || {
            session
                .forward_multi(&mixed, &state_refs, &mask_refs, &row_slots)
                .unwrap()
        });
        for (i, want) in want_rows.iter().enumerate() {
            for j in 0..k {
                assert_eq!(
                    got[i * k + j].to_bits(),
                    want[j].to_bits(),
                    "{method_name} t={threads}: row {i} col {j}: {} vs {}",
                    got[i * k + j],
                    want[j]
                );
            }
        }
    }
}

#[test]
fn mixed_batch_bit_identical_to_swap_qrlora() {
    check_bit_identity_quant("qrlora", false);
}

#[test]
fn mixed_batch_bit_identical_to_swap_lora() {
    check_bit_identity_quant("lora", false);
}

#[test]
fn mixed_batch_bit_identical_to_swap_qrlora_int8_backbone() {
    check_bit_identity_quant("qrlora", true);
}

#[test]
fn mixed_batch_bit_identical_to_swap_lora_int8_backbone() {
    check_bit_identity_quant("lora", true);
}

/// The grouped fallback (PJRT's path) must agree with the host fast path
/// bit for bit on the same mixed batch.
#[test]
fn grouped_fallback_matches_fast_path() {
    let bk = HostBackend::new();
    let preset = bk.manifest().preset("tiny").unwrap().clone();
    let backbone = synthetic_backbone(&bk);
    let method = build_method(&bk, "qrlora", &backbone);
    let session =
        Session::finetune(&bk, &preset, &method, HeadKind::Cls, &backbone, None, 3).unwrap();
    let states = perturbed_states(&session, 3, 29);

    let lex = Lexicon::new(preset.vocab);
    let data = TaskData::generate(task("sst2").unwrap(), &lex, 9);
    let batcher = Batcher::new(&preset, false);
    let refs: Vec<&Example> = data.train[..preset.batch].iter().collect();
    let mixed = batcher.assemble(&refs);
    let row_slots: Vec<usize> = (0..preset.batch).map(|i| i % states.len()).collect();

    let k = session.layout().param("head/wc").unwrap().shape[1];
    let cmask = Batcher::class_mask(2, k);
    let state_bufs: Vec<_> = states.iter().map(|s| bk.upload_f32(s, &[s.len()]).unwrap()).collect();
    let mask_bufs: Vec<_> =
        (0..states.len()).map(|_| bk.upload_f32(&cmask, &[k]).unwrap()).collect();
    let state_refs: Vec<_> = state_bufs.iter().collect();
    let mask_refs: Vec<_> = mask_bufs.iter().collect();

    // Fast path via the session.
    let fast = session
        .forward_multi(&mixed, &state_refs, &mask_refs, &row_slots)
        .unwrap();

    // Grouped fallback straight through the free function: rebuild the
    // spec-ordered argument list from fresh uploads (the session's own
    // buffers are private) and hand it the same adapter bank.
    let exe = bk.load("tiny/eval_fwd_qrlora_cls").unwrap();
    let adapters = BatchedAdapters {
        states: &state_refs,
        class_masks: &mask_refs,
        row_slots: &row_slots,
    };
    let mut owned: Vec<qrlora::runtime::Buffer> = Vec::new();
    let mut frozen_values: BTreeMap<String, Vec<f32>> = BTreeMap::new();
    if let Method::QrLora(set) = &method {
        for (name, v) in set.frozen_inputs() {
            frozen_values.insert(name, v);
        }
    }
    for (name, t) in &backbone {
        frozen_values.insert(name.clone(), t.data.clone());
    }
    for t in &exe.spec.inputs {
        use qrlora::runtime::{DType, Role};
        let buf = match t.role {
            Role::State => bk.upload_f32(&states[0], &[states[0].len()]).unwrap(),
            Role::Frozen => {
                let v = frozen_values
                    .get(&t.name)
                    .unwrap_or_else(|| panic!("missing frozen {}", t.name));
                bk.upload_f32(v, &t.shape).unwrap()
            }
            Role::Batch => match t.name.as_str() {
                "batch/input_ids" => bk.upload_i32(&mixed.input_ids, &t.shape).unwrap(),
                "batch/type_ids" => bk.upload_i32(&mixed.type_ids, &t.shape).unwrap(),
                "batch/attn_mask" => bk.upload_f32(&mixed.attn_mask, &t.shape).unwrap(),
                "batch/labels" => match t.dtype {
                    DType::I32 => bk.upload_i32(&mixed.labels_i32, &t.shape).unwrap(),
                    DType::F32 => bk.upload_f32(&mixed.labels_f32, &t.shape).unwrap(),
                },
                "batch/class_mask" => bk.upload_f32(&cmask, &t.shape).unwrap(),
                "batch/example_w" => bk.upload_f32(&mixed.example_w, &t.shape).unwrap(),
                other => panic!("unexpected batch input {other}"),
            },
            other => panic!("unexpected eval input role {other:?}"),
        };
        owned.push(buf);
    }
    let args: Vec<&qrlora::runtime::Buffer> = owned.iter().collect();
    let outs = execute_batched_grouped(&bk, &exe, &args, &adapters).unwrap();
    let grouped = bk.download_f32(&outs[0]).unwrap();

    assert_eq!(fast.len(), grouped.len());
    for (i, (a, b)) in fast.iter().zip(&grouped).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "elem {i}: fast {a} vs grouped {b}");
    }
}

/// End-to-end router vs swap loop on a mixed stream, with a bank smaller
/// than the task count so admissions/evictions happen mid-stream; results
/// must still match the swap path bit for bit and the stats must add up.
#[test]
fn router_with_evictions_matches_swap_path() {
    let bk = HostBackend::new();
    let preset = bk.manifest().preset("tiny").unwrap().clone();
    let backbone = synthetic_backbone(&bk);
    let method = build_method(&bk, "qrlora", &backbone);
    let mut session =
        Session::finetune(&bk, &preset, &method, HeadKind::Cls, &backbone, None, 3).unwrap();
    let tasks = ["sst2", "mrpc", "qnli"];
    let states = perturbed_states(&session, tasks.len(), 41);

    let lex = Lexicon::new(preset.vocab);
    let batcher = Batcher::new(&preset, false);
    let per_task: Vec<TaskData> = tasks
        .iter()
        .enumerate()
        .map(|(ti, name)| TaskData::generate(task(name).unwrap(), &lex, 11 + ti as u64))
        .collect();
    let mut rng = Rng::new(77);
    let mut queue: VecDeque<Request> = VecDeque::new();
    for id in 0..40 {
        let ti = rng.below(tasks.len());
        let ex = per_task[ti].train[rng.below(64)].clone();
        queue.push_back(Request { id, task: tasks[ti].to_string(), example: ex });
    }

    // Batched path: bank capacity 2 < 3 tasks forces evictions.
    let (batched, stats) = {
        let mut router = Router::new(&session, batcher.clone(), 0, 2).unwrap();
        for (i, name) in tasks.iter().enumerate() {
            let n_classes = task(name).unwrap().n_classes;
            router.register(name, states[i].clone(), n_classes).unwrap();
        }
        let mut q = queue.clone();
        let out = router.serve(&mut q).unwrap();
        (out, router.stats)
    };
    assert_eq!(stats.requests, 40);
    assert_eq!(stats.batched_requests, 40);
    assert_eq!(stats.swap_requests, 0);
    assert!(stats.evictions > 0, "capacity 2 with 3 tasks must evict: {stats:?}");
    assert!(stats.swaps >= stats.evictions);
    assert!(stats.batches < 40, "requests must be batched, got {} batches", stats.batches);

    // Swap reference on the identical stream.
    let mut library: BTreeMap<String, Vec<f32>> = BTreeMap::new();
    for (i, name) in tasks.iter().enumerate() {
        library.insert(name.to_string(), states[i].clone());
    }
    let mut swap_stats = RouterStats::default();
    let mut q = queue.clone();
    let swapped = serve_swap(&mut session, &batcher, &library, &mut q, &mut swap_stats).unwrap();
    assert_eq!(swap_stats.swap_requests, 40);
    assert!(swap_stats.swaps > 0);

    let mut by_id: BTreeMap<usize, Vec<f32>> = BTreeMap::new();
    for (r, l) in swapped {
        by_id.insert(r.id, l);
    }
    assert_eq!(batched.len(), 40);
    for (r, logits) in &batched {
        let want = &by_id[&r.id];
        assert_eq!(logits.len(), want.len());
        for (j, (a, b)) in logits.iter().zip(want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "req {} col {j}: {a} vs {b}", r.id);
        }
    }
}
