//! Integration tests for the durable adapter store: record round-trips
//! (f32 and int8-backbone-trained adapters), corruption detection,
//! registry crash recovery, concurrent-publish index merging (the
//! last-writer-wins race the store lock exists for), and the warm-start
//! bit-identity contract — logits served from a store-restored state
//! must equal the freshly trained session's logits bit for bit, for both
//! adapter methods.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Duration;

use qrlora::adapters::{Proj, Scope};
use qrlora::data::{task, Batch, Batcher, HeadKind, Lexicon, TaskData};
use qrlora::linalg::RankRule;
use qrlora::runtime::{Backend, HostBackend};
use qrlora::store::{
    fingerprint_layout, fingerprint_params, AdapterKey, AdapterRecord, GcPolicy, RecordMeta,
    Registry, Source, StoreLock, TieredAdapters, LOCK_FILE,
};
use qrlora::tensor::Tensor;
use qrlora::training::{Method, Methods, Session};
use qrlora::util::rng::Rng;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("qrlora_store_tests").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn synthetic_backbone(bk: &dyn Backend) -> BTreeMap<String, Tensor> {
    let exe = bk.load("tiny/train_step_ft_cls").unwrap();
    let mut rng = Rng::new(7);
    let mut backbone = BTreeMap::new();
    for f in &exe.spec.layout().unwrap().params {
        if !f.name.starts_with("head/") {
            backbone.insert(f.name.clone(), Tensor::randn(&f.shape, &mut rng, 0.05));
        }
    }
    backbone
}

fn build_method(bk: &dyn Backend, name: &str, backbone: &BTreeMap<String, Tensor>) -> Method {
    let preset = bk.manifest().preset("tiny").unwrap().clone();
    match name {
        "qrlora" => Methods::qr_lora(
            backbone,
            &preset,
            Scope::all_layers(&[Proj::Q, Proj::V]),
            0.5,
            RankRule::DiagRatio,
        )
        .unwrap(),
        "lora" => Methods::lora(backbone, &preset, 2.0, 1).unwrap(),
        other => panic!("unknown method {other}"),
    }
}

/// Train a few real steps so λ/A/B/head and the Adam moments are all
/// non-trivial, and return the batch used (for forward comparisons).
fn trained_session<'a>(
    bk: &'a dyn Backend,
    method: &Method,
    backbone: &BTreeMap<String, Tensor>,
    steps: usize,
) -> (Session<'a>, Batch) {
    let preset = bk.manifest().preset("tiny").unwrap().clone();
    let mut session =
        Session::finetune(bk, &preset, method, HeadKind::Cls, backbone, None, 3).unwrap();
    let lex = Lexicon::new(preset.vocab);
    let data = TaskData::generate(task("sst2").unwrap(), &lex, 5);
    let batcher = Batcher::new(&preset, false);
    let refs: Vec<&qrlora::data::Example> = data.train[..preset.batch].iter().collect();
    let batch = batcher.assemble(&refs);
    for _ in 0..steps {
        session.step(&batch, 2, 1e-3).unwrap();
    }
    (session, batch)
}

fn capture(
    session: &Session,
    backbone: &BTreeMap<String, Tensor>,
    method_name: &str,
    with_adam: bool,
) -> AdapterRecord {
    AdapterRecord::from_session(
        session,
        AdapterKey::new("tiny", method_name, "sst2", 3),
        fingerprint_params(backbone),
        2,
        87.5,
        123.0,
        with_adam,
    )
    .unwrap()
}

#[test]
fn record_roundtrip_f32_and_int8_backbone() {
    // The record must round-trip bit-exactly whether the adapter was
    // trained against the f32 or the int8-quantized frozen backbone —
    // what's stored (λ/A/B/head + moments) is f32 either way.
    for quantize in [false, true] {
        let bk = HostBackend::with_quant(quantize);
        let backbone = synthetic_backbone(&bk);
        let method = build_method(&bk, "qrlora", &backbone);
        let (session, batch) = trained_session(&bk, &method, &backbone, 3);
        let record = capture(&session, &backbone, "qrlora", true);

        let dir = tmp_dir(&format!("roundtrip_q{quantize}"));
        let path = dir.join("rec.qad");
        record.save(&path).unwrap();
        let loaded = AdapterRecord::load(&path).unwrap();

        assert_eq!(loaded.meta.key, record.meta.key);
        assert_eq!(loaded.meta.manifest_fp, fingerprint_layout(session.layout()));
        assert_eq!(loaded.meta.backbone_fp, fingerprint_params(&backbone));
        assert_eq!(loaded.meta.steps, 3);
        // The record carries the backbone representation it trained
        // against and refuses the other one: an f32-trained adapter must
        // never warm-start an int8 backend (or vice versa).
        assert_eq!(loaded.meta.backbone_repr, if quantize { "int8" } else { "f32" });
        let fps = (fingerprint_layout(session.layout()), fingerprint_params(&backbone));
        assert!(loaded.check_compat(fps.0, fps.1, bk.backbone_repr()).is_ok());
        let other = if quantize { "f32" } else { "int8" };
        let err = loaded.check_compat(fps.0, fps.1, other).unwrap_err().to_string();
        assert!(err.contains("backbone"), "{err}");
        assert_eq!(loaded.params, record.params, "params must round-trip bit-exactly");
        let (m, v) = session.download_moments().unwrap();
        let adam = loaded.adam.as_ref().expect("adam section saved");
        assert_eq!(adam.m, m);
        assert_eq!(adam.v, v);
        assert_eq!(adam.t, 3);

        // A restored state must serve the same logits, bit for bit.
        let want = session.forward(&batch, 2).unwrap();
        let preset = bk.manifest().preset("tiny").unwrap().clone();
        let mut restored =
            Session::finetune(&bk, &preset, &method, HeadKind::Cls, &backbone, None, 99)
                .unwrap();
        restored.upload_state(&loaded.state_vector(session.layout()).unwrap()).unwrap();
        let got = restored.forward(&batch, 2).unwrap();
        assert_eq!(want.len(), got.len());
        for (i, (a, b)) in want.iter().zip(&got).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "quant={quantize} logit {i}: {a} vs {b}");
        }
    }
}

#[test]
fn corrupted_record_is_a_checksum_error_not_garbage_weights() {
    let bk = HostBackend::new();
    let backbone = synthetic_backbone(&bk);
    let method = build_method(&bk, "qrlora", &backbone);
    let (session, _) = trained_session(&bk, &method, &backbone, 2);
    let record = capture(&session, &backbone, "qrlora", false);

    let dir = tmp_dir("corrupt");
    let path = dir.join("rec.qad");
    record.save(&path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    // Flip one byte deep in the tensors payload.
    let pos = bytes.len() - 11;
    bytes[pos] ^= 0x20;
    std::fs::write(&path, &bytes).unwrap();
    let err = AdapterRecord::load(&path).unwrap_err().to_string();
    assert!(err.contains("checksum"), "want a checksum error, got: {err}");
}

#[test]
fn registry_atomicity_under_simulated_crashed_write() {
    let bk = HostBackend::new();
    let backbone = synthetic_backbone(&bk);
    let method = build_method(&bk, "qrlora", &backbone);
    let (session, _) = trained_session(&bk, &method, &backbone, 2);
    let record = capture(&session, &backbone, "qrlora", false);

    let dir = tmp_dir("crashed_write");
    let mut reg = Registry::open(&dir).unwrap();
    reg.publish(&record).unwrap();
    assert_eq!(reg.len(), 1);
    drop(reg);

    // Simulate a crash mid-publish of a SECOND record: a partial record
    // temp file and a partial index temp file, never renamed. Fresh temp
    // debris is left on disk (it could be a live sibling process
    // mid-publish; only stale temps are swept) but must be completely
    // inert: not adopted, not parsed, not corrupting anything.
    std::fs::write(dir.join("next.tmp4242"), b"half a record........").unwrap();
    std::fs::write(dir.join("index.tmp4242"), b"{\"version\": 1, \"entr").unwrap();
    let reg = Registry::open(&dir).unwrap();
    assert_eq!(reg.len(), 1, "the published record survives, the crash debris is inert");
    let key = AdapterKey::new("tiny", "qrlora", "sst2", 3);
    assert!(reg.lookup(&key).is_some());
    assert!(reg.load(&key).is_ok(), "debris must not affect record loads");
    drop(reg);
    let _ = std::fs::remove_file(dir.join("next.tmp4242"));
    let _ = std::fs::remove_file(dir.join("index.tmp4242"));

    // Corrupt the index itself: open() rebuilds it from the record files.
    std::fs::write(dir.join("index.json"), b"NOT JSON AT ALL").unwrap();
    let reg = Registry::open(&dir).unwrap();
    assert_eq!(reg.len(), 1, "index rebuilt by scanning self-describing records");
    let loaded = reg.load(&key).unwrap();
    assert_eq!(loaded.params, record.params);

    // Stale entry recovery: delete the record file behind the index.
    std::fs::remove_file(reg.record_path(reg.lookup(&key).unwrap())).unwrap();
    let reg = Registry::open(&dir).unwrap();
    assert!(reg.is_empty(), "dangling index entries are dropped on open");
}

#[test]
fn warm_start_logits_bit_identical_for_qrlora_and_lora() {
    for method_name in ["qrlora", "lora"] {
        let bk = HostBackend::new();
        let backbone = synthetic_backbone(&bk);
        let method = build_method(&bk, method_name, &backbone);
        let (session, batch) = trained_session(&bk, &method, &backbone, 4);
        let want = session.forward(&batch, 2).unwrap();

        // Publish, then resolve through the tiered store exactly like a
        // restarted server would (prefetch on the pool + resolve).
        let dir = tmp_dir(&format!("warm_{method_name}"));
        let record = capture(&session, &backbone, method_name, false);
        Registry::open(&dir).unwrap().publish(&record).unwrap();

        let mut tiers = TieredAdapters::new(
            Some(Registry::open(&dir).unwrap()),
            fingerprint_layout(session.layout()),
            fingerprint_params(&backbone),
            bk.backbone_repr(),
            "tiny",
            method_name,
            3,
        );
        let layout = session.layout().clone();
        tiers.prefetch(&layout, &["sst2"]);
        let resolved = tiers
            .resolve(&layout, "sst2", |_| panic!("warm start must not train"))
            .unwrap();
        assert_eq!(resolved.source, Source::Disk);
        assert_eq!(resolved.n_classes, 2);
        let state = resolved.state.clone();
        assert_eq!(tiers.stats.disk_hits, 1);
        assert_eq!(tiers.stats.trained, 0);

        let preset = bk.manifest().preset("tiny").unwrap().clone();
        let mut restored =
            Session::finetune(&bk, &preset, &method, HeadKind::Cls, &backbone, None, 42)
                .unwrap();
        restored.upload_state(&state).unwrap();
        let got = restored.forward(&batch, 2).unwrap();
        for (i, (a, b)) in want.iter().zip(&got).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{method_name} warm-start logit {i}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn mismatched_or_corrupt_record_falls_back_to_training() {
    let bk = HostBackend::new();
    let backbone = synthetic_backbone(&bk);
    let method = build_method(&bk, "qrlora", &backbone);
    let (session, _) = trained_session(&bk, &method, &backbone, 2);
    let layout = session.layout().clone();
    let good_fp = fingerprint_params(&backbone);

    // Publish a record that claims a DIFFERENT backbone.
    let dir = tmp_dir("mismatch");
    let mut bad = capture(&session, &backbone, "qrlora", false);
    bad.meta.backbone_fp = good_fp ^ 0xFF;
    Registry::open(&dir).unwrap().publish(&bad).unwrap();

    let mut tiers = TieredAdapters::new(
        Some(Registry::open(&dir).unwrap()),
        fingerprint_layout(&layout),
        good_fp,
        bk.backbone_repr(),
        "tiny",
        "qrlora",
        3,
    );
    let mut trained = false;
    let resolved = tiers
        .resolve(&layout, "sst2", |key| {
            trained = true;
            let mut rec = capture(&session, &backbone, "qrlora", false);
            rec.meta.key = key.clone();
            Ok(rec)
        })
        .unwrap();
    assert!(trained, "a mismatched record must fall back to the trainer");
    assert_eq!(resolved.source, Source::Trained);
    assert_eq!(tiers.stats.rejected, 1);

    // The fallback republished a good record: a fresh resolver warm
    // starts from it.
    let mut tiers2 = TieredAdapters::new(
        Some(Registry::open(&dir).unwrap()),
        fingerprint_layout(&layout),
        good_fp,
        bk.backbone_repr(),
        "tiny",
        "qrlora",
        3,
    );
    let r2 = tiers2.resolve(&layout, "sst2", |_| panic!("must warm start now")).unwrap();
    assert_eq!(r2.source, Source::Disk);
}

#[test]
fn gc_prunes_and_store_stays_consistent() {
    let bk = HostBackend::new();
    let backbone = synthetic_backbone(&bk);
    let method = build_method(&bk, "qrlora", &backbone);
    let (session, _) = trained_session(&bk, &method, &backbone, 1);

    let dir = tmp_dir("gc_consistency");
    let mut reg = Registry::open(&dir).unwrap();
    for (task_name, age) in [("sst2", 100u64), ("mrpc", 200), ("qnli", 300)] {
        let mut rec = capture(&session, &backbone, "qrlora", false);
        rec.meta.key = AdapterKey::new("tiny", "qrlora", task_name, 3);
        rec.meta.created_unix = age;
        reg.publish(&rec).unwrap();
    }
    let report = qrlora::store::gc::gc(
        &mut reg,
        &GcPolicy { max_count: Some(2), ..Default::default() },
        1000,
        false,
    )
    .unwrap();
    assert_eq!(report.removed.len(), 1);
    assert_eq!(report.removed[0].task, "sst2", "oldest record pruned first");
    assert!(report.freed_bytes > 0);
    // Survivors still verify; the pruned file is gone from disk.
    drop(reg);
    let reg = Registry::open(&dir).unwrap();
    assert_eq!(reg.len(), 2);
    assert!(reg.verify().iter().all(|r| r.result.is_ok()));
}

/// A tiny record with a distinct (task, seed) key — the publish-race
/// tests need key volume, not real weights.
fn synthetic_record(task_name: &str, seed: u64) -> AdapterRecord {
    let mut params = BTreeMap::new();
    params.insert("head/wc".to_string(), Tensor::zeros(&[2, 2]));
    AdapterRecord {
        meta: RecordMeta {
            key: AdapterKey::new("tiny", "stress", task_name, seed),
            manifest_fp: 1,
            backbone_fp: 2,
            backbone_repr: "f32".to_string(),
            n_classes: 2,
            eval_metric: 0.0,
            steps: 0,
            train_ms: 0.0,
            created_unix: 1,
        },
        params,
        adam: None,
    }
}

#[test]
fn concurrent_publishes_from_many_threads_all_land() {
    // The race the store lock exists for: N writers, each holding its own
    // Registry snapshot of one directory, publish concurrently. Before
    // the locked read-merge-rewrite, every writer rewrote the index from
    // its stale snapshot and the last one silently dropped the others'
    // rows. All N×M keys must survive.
    let dir = tmp_dir("concurrent_publish");
    drop(Registry::open(&dir).unwrap()); // materialize the store once
    let writers = 4usize;
    let per_writer = 6usize;
    let handles: Vec<_> = (0..writers)
        .map(|w| {
            let dir = dir.clone();
            std::thread::spawn(move || {
                let mut reg = Registry::open(&dir).unwrap();
                for j in 0..per_writer {
                    reg.publish_merged(&synthetic_record(&format!("t{j}"), w as u64)).unwrap();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let reg = Registry::open(&dir).unwrap();
    assert_eq!(reg.len(), writers * per_writer, "a concurrent publish lost index entries");
    for w in 0..writers {
        for j in 0..per_writer {
            let key = AdapterKey::new("tiny", "stress", &format!("t{j}"), w as u64);
            assert!(reg.lookup(&key).is_some(), "lost {key:?}");
        }
    }
    assert!(reg.verify().iter().all(|r| r.result.is_ok()));
}

#[test]
fn publish_takes_over_a_crashed_holders_lock() {
    if !std::path::Path::new("/proc/self").exists() {
        return; // pid liveness is /proc-gated
    }
    let dir = tmp_dir("crashed_holder");
    let mut reg = Registry::open(&dir).unwrap();
    reg.publish_merged(&synthetic_record("t0", 0)).unwrap();
    // Forge a lock whose holder pid cannot exist (> PID_MAX): publish
    // must take it over via the dead-pid rule instead of timing out.
    let body = r#"{"pid": 999999999, "acquired_unix": 0, "token": "crashed"}"#;
    std::fs::write(dir.join(LOCK_FILE), body).unwrap();
    reg.publish_merged(&synthetic_record("t1", 0)).unwrap();
    assert_eq!(reg.len(), 2);
    assert!(!dir.join(LOCK_FILE).exists(), "publish must release the taken-over lock");
}

#[test]
fn gc_blocks_on_a_held_lock_then_proceeds() {
    let dir = tmp_dir("gc_under_lock");
    let mut reg = Registry::open(&dir).unwrap();
    for j in 0..3 {
        reg.publish_merged(&synthetic_record(&format!("t{j}"), 0)).unwrap();
    }
    drop(reg);

    let lock = StoreLock::acquire(&dir).unwrap();
    let (tx, rx) = std::sync::mpsc::channel();
    let gc_dir = dir.clone();
    let gc_thread = std::thread::spawn(move || {
        let mut reg = Registry::open(&gc_dir).unwrap();
        let report = qrlora::store::gc::gc(
            &mut reg,
            &GcPolicy { max_count: Some(1), ..Default::default() },
            100,
            false,
        )
        .unwrap();
        tx.send(report.removed.len()).unwrap();
    });
    // While the lock is held, gc's index rewrite must wait on it.
    assert!(
        rx.recv_timeout(Duration::from_millis(300)).is_err(),
        "gc must block on the held store lock"
    );
    drop(lock);
    let removed = rx.recv_timeout(Duration::from_secs(10)).expect("gc must finish post-release");
    gc_thread.join().unwrap();
    assert_eq!(removed, 2);
    assert_eq!(Registry::open(&dir).unwrap().len(), 1);
}

#[test]
fn index_generation_bumps_on_every_locked_rewrite() {
    // The fleet's store-watch polls this counter to notice sibling
    // publishes without re-reading the whole index.
    let dir = tmp_dir("generation");
    let mut reg = Registry::open(&dir).unwrap();
    let g0 = Registry::read_generation(&dir).unwrap();
    reg.publish_merged(&synthetic_record("t0", 0)).unwrap();
    let g1 = Registry::read_generation(&dir).unwrap();
    assert!(g1 > g0, "publish must bump the generation ({g0} -> {g1})");
    reg.publish_merged(&synthetic_record("t1", 0)).unwrap();
    let g2 = Registry::read_generation(&dir).unwrap();
    assert!(g2 > g1);
    let (_, removed) =
        reg.remove(&[AdapterKey::new("tiny", "stress", "t0", 0)]).unwrap();
    assert_eq!(removed.len(), 1);
    let g3 = Registry::read_generation(&dir).unwrap();
    assert!(g3 > g2, "remove must bump the generation too ({g2} -> {g3})");
}

#[test]
fn load_rejects_a_record_swapped_behind_the_index() {
    // `load` must enforce the index row's fingerprints the way `verify`
    // does: a record file replaced on disk under the same name (checksums
    // fine, fingerprints different) is an error, not a silent load.
    let dir = tmp_dir("load_fp_drift");
    let mut reg = Registry::open(&dir).unwrap();
    reg.publish_merged(&synthetic_record("t0", 0)).unwrap();
    let key = AdapterKey::new("tiny", "stress", "t0", 0);
    let mut drifted = synthetic_record("t0", 0);
    drifted.meta.backbone_fp = 999;
    drifted.save(&reg.record_path(reg.lookup(&key).unwrap())).unwrap();
    let err = reg.load(&key).unwrap_err().to_string();
    assert!(err.contains("drifted"), "want a fingerprint-drift error, got: {err}");
}
