//! End-to-end integration on the tiny preset: pretrain → warmup → adapter
//! fine-tune → eval, across all three methods — hermetically on the
//! pure-Rust `HostBackend` (no `make artifacts` needed).

use qrlora::adapters::{Proj, Scope};
use qrlora::data::{task, Lexicon, TaskData};
use qrlora::linalg::RankRule;
use qrlora::runtime::{Backend, HostBackend};
use qrlora::training::{self, FinetuneJob, Method, Methods, TrainConfig};

fn backend() -> HostBackend {
    HostBackend::new()
}

fn tiny_cfg(steps: usize) -> TrainConfig {
    TrainConfig {
        steps,
        lr: 2e-3,
        warmup_steps: 5,
        train_examples: 512,
        log_every: 10,
    }
}

#[test]
fn pretrain_reduces_mlm_loss() {
    let rt = backend();
    let lex = Lexicon::new(512);
    let (backbone, losses) = training::pretrain(&rt, "tiny", &lex, 30, 2e-3, 42).unwrap();
    assert!(backbone.contains_key("emb/tok"));
    assert!(backbone.contains_key("layer1/attn/wo"));
    let first = losses.first().unwrap().1;
    let last = losses.last().unwrap().1;
    assert!(last < first, "mlm loss did not fall: {first} -> {last}");
}

#[test]
fn full_pipeline_qrlora_beats_chance() {
    let rt = backend();
    let lex = Lexicon::new(512);
    let spec = task("sst2").unwrap();
    let mut data = TaskData::generate(spec, &lex, 7);
    data.train.truncate(512);
    data.dev.truncate(256);

    // 1. pretrain backbone (reduces MLM loss — asserted in its own test)
    let (backbone, _) = training::pretrain(&rt, "tiny", &lex, 300, 1e-3, 1).unwrap();

    // 2. warm-up full fine-tune (the paper warm-up FTs before adapters)
    let mut wcfg = tiny_cfg(300);
    wcfg.lr = 1e-3;
    let (warm_bb, warm_head) = training::warmup(&rt, "tiny", &data, &backbone, &wcfg, 2).unwrap();

    // 3. QR-LoRA on the frozen warmed backbone
    let preset = rt.manifest().preset("tiny").unwrap().clone();
    let method = Methods::qr_lora(
        &warm_bb,
        &preset,
        Scope::all_layers(&[Proj::Q, Proj::V]),
        0.5,
        RankRule::DiagRatio,
    )
    .unwrap();
    if let Method::QrLora(ref set) = method {
        assert!(set.trainable_params() > 0);
        assert!(set.trainable_params() < 8 * 32 + 1); // ≤ slots × r_max
    }
    let job = FinetuneJob {
        rt: &rt,
        preset: "tiny",
        task: &data,
        lexicon: &lex,
        backbone: &warm_bb,
        head: Some(&warm_head),
        config: tiny_cfg(150),
        seed: 3,
    };
    let result = training::run_finetune(&job, &method).unwrap();
    assert!(result.final_loss.is_finite());
    // Majority class of the truncated dev split never exceeds ~0.55 on this
    // balanced synthetic task; 0.62 demonstrates real learning.
    assert!(
        result.dev.accuracy > 0.62,
        "qr-lora sst2 accuracy {:.3} not above chance",
        result.dev.accuracy
    );
}

#[test]
fn all_methods_run_on_mnli_with_mismatched_eval() {
    let rt = backend();
    let lex = Lexicon::new(512);
    let spec = task("mnli").unwrap();
    let mut data = TaskData::generate(spec, &lex, 11);
    data.train.truncate(256);
    data.dev.truncate(128);
    data.dev_mm.truncate(128);

    let (backbone, _) = training::pretrain(&rt, "tiny", &lex, 20, 2e-3, 4).unwrap();
    let preset = rt.manifest().preset("tiny").unwrap().clone();

    let methods = vec![
        Method::FullFt,
        Methods::lora(&backbone, &preset, 2.0, 5).unwrap(),
        Methods::svd_lora(&backbone, &preset, 1, 2.0, 6).unwrap(),
        Methods::qr_lora(
            &backbone,
            &preset,
            Scope::last_layers(1, &[Proj::O]),
            0.5,
            RankRule::DiagRatio,
        )
        .unwrap(),
    ];
    let mut param_counts = Vec::new();
    for method in &methods {
        let job = FinetuneJob {
            rt: &rt,
            preset: "tiny",
            task: &data,
            lexicon: &lex,
            backbone: &backbone,
            head: None,
            config: tiny_cfg(25),
            seed: 8,
        };
        let result = training::run_finetune(&job, method).unwrap();
        assert!(result.final_loss.is_finite(), "{}", result.method_label);
        assert!(result.dev_mm.is_some(), "{}: no mismatched eval", result.method_label);
        param_counts.push((result.method_label.clone(), result.trainable_params));
    }
    // Parameter ordering: QR-LoRA << LoRA/SVD-LoRA << FT (paper's headline).
    let get = |label: &str| {
        param_counts
            .iter()
            .find(|(l, _)| l == label)
            .map(|(_, c)| *c)
            .unwrap()
    };
    assert!(get("QR-LoRA") < get("LoRA") / 2, "{param_counts:?}");
    assert_eq!(get("LoRA"), get("SVD-LoRA"));
    assert!(get("LoRA") < get("FT") / 10, "{param_counts:?}");
}

#[test]
fn regression_task_trains_and_correlates() {
    let rt = backend();
    let lex = Lexicon::new(512);
    let spec = task("stsb").unwrap();
    let mut data = TaskData::generate(spec, &lex, 13);
    data.train.truncate(512);
    data.dev.truncate(200);

    let (backbone, _) = training::pretrain(&rt, "tiny", &lex, 200, 1e-3, 9).unwrap();
    // Warm-up first (paper protocol), then adapter training.
    let mut wcfg = tiny_cfg(250);
    wcfg.lr = 1e-3;
    let (warm_bb, warm_head) = training::warmup(&rt, "tiny", &data, &backbone, &wcfg, 12).unwrap();
    let preset = rt.manifest().preset("tiny").unwrap().clone();
    let method = Methods::qr_lora(
        &warm_bb,
        &preset,
        Scope::all_layers(&[Proj::Q, Proj::V]),
        0.5,
        RankRule::DiagRatio,
    )
    .unwrap();
    let job = FinetuneJob {
        rt: &rt,
        preset: "tiny",
        task: &data,
        lexicon: &lex,
        backbone: &warm_bb,
        head: Some(&warm_head),
        config: tiny_cfg(100),
        seed: 10,
    };
    let result = training::run_finetune(&job, &method).unwrap();
    assert!(result.final_loss.is_finite());
    assert!(
        result.dev.pearson > 0.2,
        "stsb pearson {:.3} shows no learning",
        result.dev.pearson
    );
}

#[test]
fn checkpoint_roundtrip_through_session() {
    use qrlora::model::checkpoint;
    let rt = backend();
    let lex = Lexicon::new(512);
    let (backbone, _) = training::pretrain(&rt, "tiny", &lex, 5, 1e-3, 20).unwrap();
    let dir = std::env::temp_dir().join("qrlora_e2e_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bb.qck");
    checkpoint::save_params(&path, &backbone).unwrap();
    let loaded = checkpoint::load_params(&path).unwrap();
    assert_eq!(loaded.len(), backbone.len());
    for (k, v) in &backbone {
        assert_eq!(&loaded[k], v, "{k}");
    }
}

#[test]
fn session_state_roundtrip_and_hot_swap() {
    // The serving path's core op: download a trained state vector, swap a
    // different one in, swap back, and get identical logits.
    use qrlora::data::{Batcher, HeadKind};
    use qrlora::training::Session;

    let rt = backend();
    let lex = Lexicon::new(512);
    let spec = task("sst2").unwrap();
    let mut data = TaskData::generate(spec, &lex, 31);
    data.train.truncate(64);
    let (backbone, _) = training::pretrain(&rt, "tiny", &lex, 5, 1e-3, 30).unwrap();
    let preset = rt.manifest().preset("tiny").unwrap().clone();
    let method = Methods::qr_lora(
        &backbone,
        &preset,
        Scope::last_layers(1, &[Proj::Q]),
        0.5,
        RankRule::DiagRatio,
    )
    .unwrap();
    let mut session =
        Session::finetune(&rt, &preset, &method, HeadKind::Cls, &backbone, None, 33).unwrap();
    let batcher = Batcher::new(&preset, false);
    let refs: Vec<&qrlora::data::Example> = data.train[..preset.batch].iter().collect();
    let batch = batcher.assemble(&refs);

    let state_a = session.download_state().unwrap();
    let logits_a = session.forward(&batch, spec.n_classes).unwrap();
    // train a few steps → different state/logits
    for _ in 0..3 {
        session.step(&batch, spec.n_classes, 5e-2).unwrap();
    }
    let logits_b = session.forward(&batch, spec.n_classes).unwrap();
    assert!(
        logits_a
            .iter()
            .zip(&logits_b)
            .any(|(a, b)| (a - b).abs() > 1e-6),
        "training did not change logits"
    );
    // swap the original adapter back in → identical logits again
    session.upload_state(&state_a).unwrap();
    let logits_c = session.forward(&batch, spec.n_classes).unwrap();
    for (a, c) in logits_a.iter().zip(&logits_c) {
        assert_eq!(a, c, "hot-swap did not restore state exactly");
    }
}
