//! Property tests for `linalg::qr` — the numerical backbone of QR-LoRA.
//!
//! Properties pinned here (over random tall/wide/square/rank-deficient/zero
//! matrices):
//!  1. Q orthonormality: ‖QᵀQ − I‖∞ small.
//!  2. Pivoting orders the diagonal: |R₀₀| ≥ |R₁₁| ≥ … (monotone
//!     non-increasing).
//!  3. Exact reconstruction: Q·R̃ ≈ A in the original column order.
//!  4. Truncation quality: the rank-r residual ‖A − Q_r R̃_r‖_F is within a
//!     modest factor of the SVD rank-r residual (the optimal one) — the
//!     quasi-optimality that justifies using pivoted QR instead of SVD for
//!     basis extraction.

use qrlora::linalg::{jacobi_svd, orthonormality_defect, pivoted_qr};
use qrlora::tensor::Tensor;
use qrlora::util::rng::Rng;

fn fro_residual(a: &Tensor, approx: &Tensor) -> f64 {
    let mut diff = a.clone();
    for (d, ap) in diff.data.iter_mut().zip(&approx.data) {
        *d -= ap;
    }
    diff.fro_norm()
}

fn case_matrices(rng: &mut Rng) -> Vec<(String, Tensor)> {
    let mut out = Vec::new();
    // tall, wide, square
    for (m, n) in [(24usize, 8usize), (8, 24), (16, 16)] {
        out.push((format!("dense {m}x{n}"), Tensor::randn(&[m, n], rng, 1.0)));
    }
    // rank-deficient: 20×20 of rank 4
    let u = Tensor::randn(&[20, 4], rng, 1.0);
    let v = Tensor::randn(&[4, 20], rng, 1.0);
    out.push(("rank-4 20x20".to_string(), u.matmul(&v)));
    // graded column scales (pivoting stress)
    let mut g = Tensor::randn(&[12, 12], rng, 1.0);
    for j in 0..12 {
        let s = 10f32.powi(-((j % 6) as i32));
        for i in 0..12 {
            g.set(i, j, g.at(i, j) * s);
        }
    }
    out.push(("graded 12x12".to_string(), g));
    // zero matrix
    out.push(("zero 6x6".to_string(), Tensor::zeros(&[6, 6])));
    out
}

#[test]
fn q_columns_are_orthonormal() {
    let mut rng = Rng::new(100);
    for (name, a) in case_matrices(&mut rng) {
        let f = pivoted_qr(&a);
        // Zero (or rank-deficient) columns yield zero Q columns; check the
        // defect only over the numerically nonzero prefix.
        let diag = f.diag();
        let r_nonzero = diag.iter().take_while(|d| d.abs() > 1e-5).count();
        if r_nonzero == 0 {
            continue; // zero matrix: nothing to be orthonormal
        }
        let q = f.q.slice_cols(0, r_nonzero);
        let defect = orthonormality_defect(&q);
        assert!(defect < 1e-3, "{name}: orthonormality defect {defect}");
    }
}

#[test]
fn pivoted_diag_is_monotone_nonincreasing() {
    let mut rng = Rng::new(101);
    for (name, a) in case_matrices(&mut rng) {
        let d = pivoted_qr(&a).diag();
        for i in 1..d.len() {
            assert!(
                d[i].abs() <= d[i - 1].abs() * (1.0 + 1e-3) + 1e-6,
                "{name}: |diag| not monotone at {i}: {} > {}",
                d[i].abs(),
                d[i - 1].abs()
            );
        }
    }
}

#[test]
fn reconstruction_is_exact_at_full_rank() {
    let mut rng = Rng::new(102);
    for (name, a) in case_matrices(&mut rng) {
        let f = pivoted_qr(&a);
        let err = f.reconstruct().max_abs_diff(&a);
        let scale = a.data.iter().fold(0f32, |acc, v| acc.max(v.abs())).max(1.0);
        assert!(err < 5e-4 * scale, "{name}: reconstruction error {err}");
    }
}

#[test]
fn truncation_residual_is_quasi_optimal_vs_svd() {
    // SVD truncation is the Frobenius-optimal rank-r approximation; pivoted
    // QR must stay within a modest factor of it (strong RRQR theory gives
    // sqrt(1 + r(n−r)) worst case; random matrices behave far better).
    let mut rng = Rng::new(103);
    for trial in 0..3 {
        let n = 16usize;
        let a = Tensor::randn(&[n, n], &mut rng, 1.0);
        let f = pivoted_qr(&a);
        let svd = jacobi_svd(&a);
        for r in [2usize, 4, 8, 12] {
            let (q_r, r_r) = f.truncate(r);
            let qr_res = fro_residual(&a, &q_r.matmul(&r_r));
            // optimal residual = sqrt(Σ_{i>r} σ_i²)
            let svd_res: f64 = svd.s[r..]
                .iter()
                .map(|&s| (s as f64) * (s as f64))
                .sum::<f64>()
                .sqrt();
            let factor = (1.0 + (r * (n - r)) as f64).sqrt();
            assert!(
                qr_res <= svd_res * factor + 1e-3,
                "trial {trial} r={r}: QR residual {qr_res:.4} vs SVD {svd_res:.4} \
                 (allowed factor {factor:.2})"
            );
        }
        // and rank-deficient input: truncating at the true rank is exact
        let u = Tensor::randn(&[n, 3], &mut rng, 1.0);
        let v = Tensor::randn(&[3, n], &mut rng, 1.0);
        let low = u.matmul(&v);
        let lf = pivoted_qr(&low);
        let (q3, r3) = lf.truncate(3);
        let res = fro_residual(&low, &q3.matmul(&r3));
        assert!(res < 1e-2, "trial {trial}: rank-3 truncation residual {res}");
    }
}

#[test]
fn truncation_residual_monotone_in_rank() {
    let mut rng = Rng::new(104);
    let a = Tensor::randn(&[20, 20], &mut rng, 1.0);
    let f = pivoted_qr(&a);
    let mut last = f64::INFINITY;
    for r in 1..=20 {
        let (q_r, r_r) = f.truncate(r);
        let res = fro_residual(&a, &q_r.matmul(&r_r));
        assert!(res <= last + 1e-3, "residual rose at r={r}: {res} > {last}");
        last = res;
    }
    assert!(last < 1e-2, "full-rank residual {last}");
}
