//! Chaos suite: deterministic fault injection (`QRLORA_FAULTS`, see
//! `qrlora::util::faults`) drives the *real binary* through the failure
//! modes the supervision / retry / degraded-serving layers exist for:
//!
//! * a worker killed mid-publish → the fleet restarts it and still
//!   completes with a store that passes `adapters verify`,
//! * a hung worker → heartbeat liveness kills and restarts it,
//! * transient store reads → absorbed by bounded retry, warm start intact,
//! * an unreachable store → loud degraded serving, train-on-miss,
//! * a crash between a checkpoint's temp write and its rename → the torn
//!   temp never poisons the next run,
//! * a lock holder dying without release → the next process takes over,
//! * a socket connection wedged by the `net.conn` hang → later
//!   connections are still served and the budget completes,
//! * a server killed mid-connection → the store verifies clean and a
//!   warm respawn serves straight from it,
//! * an engine wedged with a request in flight → the flight recorder
//!   dumps that request's spans to stderr before the process dies.
//!
//! Every scenario is seeded and env-driven — no `rand`, no timing
//! dependence beyond generous supervision deadlines.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Output, Stdio};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use qrlora::store::Registry;

/// Serialize the scenarios: they spawn multi-process fleets running real
/// training loops, and running two at once would oversubscribe the box
/// and turn the hang-detection deadlines flaky.
static SERIAL: Mutex<()> = Mutex::new(());

const EXE: &str = env!("CARGO_BIN_EXE_qrlora");

/// One tiny training budget for every scenario, so the serve-based tests
/// sharing a working directory reuse each other's `runs/` caches instead
/// of each paying a cold pretrain.
const BUDGET: &[&str] =
    &["--pretrain-steps", "20", "--warmup-steps", "10", "--steps", "10", "--requests", "6"];

/// Working directory shared by the serve scenarios (never wiped: the
/// whole point is cache reuse; correctness never depends on its state
/// because each scenario gets its own adapter-store directory).
fn shared_cwd() -> PathBuf {
    let dir = std::env::temp_dir().join("qrlora_chaos_tests").join("shared");
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A scenario-private directory, wiped on entry.
fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("qrlora_chaos_tests").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Run the binary in `cwd` with an optional fault spec, capturing output.
/// The fault-plan env vars are scrubbed first so scenarios can't leak
/// into each other (or inherit anything from the test runner).
fn run(cwd: &Path, faults: Option<&str>, args: &[&str]) -> Output {
    let mut cmd = Command::new(EXE);
    cmd.current_dir(cwd)
        .args(args)
        .env_remove("QRLORA_FAULTS")
        .env_remove("QRLORA_FAULTS_SEED")
        .env_remove("QRLORA_FAULTS_RESTART")
        .env_remove("QRLORA_WORKER_ID");
    if let Some(spec) = faults {
        cmd.env("QRLORA_FAULTS", spec);
    }
    cmd.output().expect("spawn qrlora")
}

fn out_str(out: &Output) -> (String, String) {
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

#[track_caller]
fn assert_success(out: &Output, what: &str) {
    let (stdout, stderr) = out_str(out);
    assert!(
        out.status.success(),
        "{what} failed ({}):\n--- stdout ---\n{stdout}\n--- stderr ---\n{stderr}",
        out.status
    );
}

#[track_caller]
fn assert_has(haystack: &str, needle: &str, what: &str) {
    assert!(haystack.contains(needle), "{what}: missing {needle:?} in:\n{haystack}");
}

fn serve_args(store: &str, extra: &[&str]) -> Vec<String> {
    let mut args: Vec<String> = vec!["serve".into()];
    args.extend(extra.iter().map(|s| s.to_string()));
    args.extend(BUDGET.iter().map(|s| s.to_string()));
    args.push("--adapter-store".into());
    args.push(store.into());
    args
}

fn refs(args: &[String]) -> Vec<&str> {
    args.iter().map(|s| s.as_str()).collect()
}

/// Drain one output pipe into the shared line channel on a relay thread,
/// so a filling pipe can never wedge the child while the test is busy.
fn relay(src: impl std::io::Read + Send + 'static, tx: Sender<String>) {
    std::thread::spawn(move || {
        let mut reader = BufReader::new(src);
        let mut line = String::new();
        loop {
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) | Err(_) => return,
                Ok(_) => {
                    let _ = tx.send(line.trim_end().to_string());
                }
            }
        }
    });
}

/// A `serve --listen` child under test. `spawn` blocks until the
/// listener announces its bound address on stdout (`NET_LISTEN`).
struct NetServer {
    child: Child,
    addr: String,
    lines: Receiver<String>,
    seen: Vec<String>,
}

impl NetServer {
    fn spawn(cwd: &Path, faults: Option<&str>, store: &str, requests: usize) -> NetServer {
        let req = requests.to_string();
        let mut args: Vec<String> = vec!["serve".into(), "--listen".into(), "127.0.0.1:0".into()];
        args.extend(BUDGET[..6].iter().map(|s| s.to_string())); // training knobs
        args.extend(["--requests".into(), req]);
        args.extend(["--adapter-store".into(), store.into()]);
        let mut cmd = Command::new(EXE);
        cmd.current_dir(cwd)
            .args(refs(&args))
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .env_remove("QRLORA_FAULTS")
            .env_remove("QRLORA_FAULTS_SEED")
            .env_remove("QRLORA_FAULTS_RESTART")
            .env_remove("QRLORA_WORKER_ID");
        if let Some(spec) = faults {
            cmd.env("QRLORA_FAULTS", spec);
        }
        let mut child = cmd.spawn().expect("spawn qrlora serve --listen");
        let (tx, lines) = channel::<String>();
        relay(child.stdout.take().expect("stdout piped"), tx.clone());
        relay(child.stderr.take().expect("stderr piped"), tx);
        let mut seen = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(600);
        let addr = loop {
            match lines.recv_timeout(Duration::from_secs(1)) {
                Ok(line) => {
                    let found = line.strip_prefix("NET_LISTEN ").map(|rest| {
                        rest.split_whitespace().next().unwrap_or("").to_string()
                    });
                    seen.push(line);
                    if let Some(addr) = found {
                        break addr;
                    }
                }
                Err(_) => {
                    let log = seen.join("\n");
                    assert!(Instant::now() < deadline, "no NET_LISTEN within 600 s:\n{log}");
                }
            }
        };
        NetServer { child, addr, lines, seen }
    }

    /// Wait for a clean exit; returns everything the child printed.
    fn finish(mut self) -> String {
        let deadline = Instant::now() + Duration::from_secs(120);
        let status = loop {
            if let Some(s) = self.child.try_wait().expect("wait qrlora") {
                break s;
            }
            if Instant::now() >= deadline {
                let _ = self.child.kill();
                panic!("server did not exit within 120 s:\n{}", self.seen.join("\n"));
            }
            std::thread::sleep(Duration::from_millis(50));
        };
        while let Ok(line) = self.lines.recv_timeout(Duration::from_millis(500)) {
            self.seen.push(line);
        }
        let all = self.seen.join("\n");
        assert!(status.success(), "serve --listen failed ({status}):\n{all}");
        all
    }

    /// Kill mid-run; returns everything printed up to the kill.
    fn kill(mut self) -> String {
        let _ = self.child.kill();
        let _ = self.child.wait();
        while let Ok(line) = self.lines.recv_timeout(Duration::from_millis(500)) {
            self.seen.push(line);
        }
        self.seen.join("\n")
    }
}

/// A minimal valid native-protocol request (token ids far inside any
/// preset's vocabulary).
fn req_line(id: usize, task: &str) -> String {
    format!("{{\"id\": {id}, \"task\": {task:?}, \"a\": [1, 2, 3], \"b\": [4, 5]}}")
}

/// Connect, send one request line, read one reply line.
fn one_shot(addr: &str, line: &str) -> String {
    let mut stream = TcpStream::connect(addr).expect("connect to serve --listen");
    stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let mut reply = String::new();
    reader.read_line(&mut reply).expect("read reply");
    reply
}

/// Tentpole acceptance: a worker dying mid-publish (abort *between* the
/// record's temp write and its rename) is restarted under the budget, the
/// fleet completes and aggregates, and the store the crash landed in
/// passes `adapters verify` with zero failures.
#[test]
fn chaos_worker_crash_mid_publish_fleet_completes_and_store_verifies() {
    let _g = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
    let cwd = shared_cwd();
    let store = fresh_dir("store_kill");
    let store_s = store.display().to_string();

    let args = serve_args(&store_s, &["--fleet", "2", "--heartbeat-secs", "1"]);
    let out = run(&cwd, Some("publish=crash_after_temp"), &refs(&args));
    assert_success(&out, "fleet with mid-publish crash");
    let (stdout, stderr) = out_str(&out);
    assert_has(&stdout, "FLEET_AGGREGATE", "fleet must aggregate after restarts");
    assert_has(&stderr, "FAULT: injected crash at publish", "the fault must actually fire");
    assert_has(&stderr, "restarting worker", "the crashed worker must be restarted");

    let verify = run(&cwd, None, &["adapters", "verify", "--adapter-store", &store_s]);
    assert_success(&verify, "adapters verify after a mid-publish crash");
    let (stdout, _) = out_str(&verify);
    assert_has(&stdout, "verified 3 record(s), 0 failure(s)", "store must be intact");
}

/// A worker that hangs before producing any output is detected by the
/// heartbeat deadline, killed, restarted, and the fleet completes with
/// both workers reporting.
#[test]
fn chaos_hung_worker_is_killed_restarted_and_fleet_completes() {
    let _g = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
    let cwd = shared_cwd();
    let store = fresh_dir("store_hang");
    let store_s = store.display().to_string();

    let args = serve_args(&store_s, &["--fleet", "2", "--heartbeat-secs", "1"]);
    let out = run(&cwd, Some("serve=hang@w0"), &refs(&args));
    assert_success(&out, "fleet with a hung worker");
    let (stdout, stderr) = out_str(&out);
    assert_has(&stderr, "killing as hung", "the silent worker must be killed");
    assert_has(&stderr, "restarting worker 0", "the hung worker must be restarted");
    assert_has(&stdout, "aggregate: 2 worker(s), 6 requests", "both workers must report");
}

/// Transient store-read errors (first two reads fail) are absorbed by the
/// bounded retry without falling back to index rebuild or retraining: the
/// warm start still resolves everything from the store.
#[test]
fn chaos_transient_store_read_errors_are_absorbed_by_retry() {
    let _g = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
    let cwd = shared_cwd();
    let store = fresh_dir("store_read");
    let store_s = store.display().to_string();

    let cold = run(&cwd, None, &refs(&serve_args(&store_s, &[])));
    assert_success(&cold, "cold serve populating the store");
    let (stdout, _) = out_str(&cold);
    assert_has(&stdout, "0/3 from store, 3 trained", "cold run must train everything");

    let warm = run(&cwd, Some("store.read=err#2"), &refs(&serve_args(&store_s, &[])));
    assert_success(&warm, "warm serve through transient read errors");
    let (stdout, stderr) = out_str(&warm);
    assert_has(&stderr, "transient failure", "the retries must be loud");
    assert_has(&stdout, "3/3 from store", "retry must preserve the full warm start");
    assert_has(&stdout, "warm-up training steps: 0", "no retraining through a transient blip");
}

/// With the store unreachable, serving degrades loudly — RAM tier +
/// train-on-miss — instead of failing, and the queued publishes are
/// reported at shutdown.
#[test]
fn chaos_unavailable_store_serves_degraded_with_train_on_miss() {
    let _g = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
    let cwd = shared_cwd();
    let store = fresh_dir("store_offline");
    let store_s = store.display().to_string();

    let out = run(&cwd, Some("store.open=err"), &refs(&serve_args(&store_s, &[])));
    assert_success(&out, "degraded serve with the store offline");
    let (stdout, stderr) = out_str(&out);
    assert_has(&stderr, "DEGRADED", "degraded mode must be loud");
    assert_has(&stdout, "0/3 from store, 3 trained", "misses must train in RAM");
    assert!(
        !stdout.contains("warm-up training steps: 0"),
        "train-on-miss must run real warm-up steps:\n{stdout}"
    );
    assert_has(&stderr, "still queued at shutdown", "unflushed publishes must be reported");
}

/// A crash between a checkpoint's temp write and its rename leaves only
/// temp debris: the published name never exists torn, so a clean rerun
/// succeeds instead of choking on a half-written cache.
#[test]
fn chaos_torn_checkpoint_crash_recovers_on_rerun() {
    let _g = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
    let cwd = fresh_dir("torn_ckpt");
    let mut args = vec!["pretrain"];
    args.extend(&BUDGET[..6]); // training knobs only; pretrain takes no --requests

    let crash = run(&cwd, Some("checkpoint=crash_after_temp"), &args);
    assert!(!crash.status.success(), "the injected checkpoint crash must kill the run");
    let (_, stderr) = out_str(&crash);
    assert_has(&stderr, "FAULT: injected crash at checkpoint", "the fault must actually fire");

    let rerun = run(&cwd, None, &args);
    assert_success(&rerun, "pretrain rerun after a torn checkpoint");
    let (stdout, _) = out_str(&rerun);
    assert_has(&stdout, "backbone ready", "the rerun must complete from a clean slate");
}

/// A lock holder that dies without releasing (injected leak on drop)
/// leaves `index.lock` behind; the next publisher takes it over through
/// the dead-pid rule and the index keeps every record.
#[test]
fn chaos_leaked_lock_is_taken_over_by_the_next_process() {
    if !Path::new("/proc/self").exists() {
        return; // dead-pid takeover is /proc-gated; aging alone needs 60 s
    }
    let _g = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
    let cwd = fresh_dir("leaked_lock");
    let store = cwd.join("store");
    let store_s = store.display().to_string();
    let publish = |faults: Option<&str>, writer: &str| {
        run(
            &cwd,
            faults,
            &[
                "adapters",
                "stress-publish",
                "--adapter-store",
                &store_s,
                "--records",
                "1",
                "--writer-id",
                writer,
            ],
        )
    };

    let leak = publish(Some("lock=hold_past_stale"), "0");
    assert_success(&leak, "publish with an injected lock leak");
    assert!(store.join("index.lock").exists(), "the leaked lock must still be on disk");

    let next = publish(None, "1");
    assert_success(&next, "publish against a leaked lock");
    let (_, stderr) = out_str(&next);
    assert_has(&stderr, "took over stale lock", "takeover must go through the dead-pid rule");

    let reg = Registry::open(&store).unwrap();
    assert_eq!(reg.len(), 2, "both writers' records must survive the takeover");
    assert!(reg.verify().iter().all(|r| r.result.is_ok()));
}

/// A connection wedged by the `net.conn` hang (fires on the first
/// connection only) must not stall anyone else: later connections get
/// real replies and the request budget completes, exiting the server
/// cleanly — the wedged reader is detached, not joined.
#[test]
fn chaos_socket_hang_on_one_connection_does_not_stall_others() {
    let _g = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
    let cwd = shared_cwd();
    let store = fresh_dir("store_net_hang");
    let store_s = store.display().to_string();

    let server = NetServer::spawn(&cwd, Some("net.conn=hang"), &store_s, 2);

    // Connection 0: its reader thread hangs before the first read, so
    // this request can never be answered. Keep the socket open for the
    // whole scenario — the point is a *live* wedged connection.
    let mut wedged = TcpStream::connect(&server.addr).expect("conn 0");
    wedged.write_all(req_line(0, "sst2").as_bytes()).unwrap();
    wedged.write_all(b"\n").unwrap();
    wedged.flush().unwrap();

    // Later connections must be served normally and drain the budget.
    let b = one_shot(&server.addr, &req_line(1, "mrpc"));
    let c = one_shot(&server.addr, &req_line(2, "qnli"));
    assert_has(&b, "\"logits\"", "conn 1 must be served while conn 0 is wedged");
    assert_has(&c, "\"logits\"", "conn 2 must be served while conn 0 is wedged");
    assert!(!b.contains("\"error\"") && !c.contains("\"error\""), "no error replies:\n{b}\n{c}");

    let all = server.finish();
    assert_has(&all, "FAULT: injected hang at net.conn", "the fault must actually fire");
    drop(wedged);
}

/// Killing the server mid-connection must leave the fleet restartable:
/// the adapter store still passes `adapters verify` with zero failures,
/// and a warm respawn serves straight from the surviving records.
#[test]
fn chaos_socket_kill_mid_connection_leaves_store_clean_and_restartable() {
    let _g = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
    let cwd = shared_cwd();
    let store = fresh_dir("store_net_kill");
    let store_s = store.display().to_string();

    // Populate the store first so the kill lands on real records.
    let cold = run(&cwd, None, &refs(&serve_args(&store_s, &[])));
    assert_success(&cold, "cold serve populating the store");

    // Serve one request to prove the connection is live, then kill the
    // server with the second request still in flight.
    let server = NetServer::spawn(&cwd, None, &store_s, 6);
    let mut stream = TcpStream::connect(&server.addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    stream.write_all(req_line(0, "sst2").as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut reply = String::new();
    reader.read_line(&mut reply).expect("read first reply");
    assert_has(&reply, "\"logits\"", "the first request must be served before the kill");
    stream.write_all(req_line(1, "mrpc").as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.flush().unwrap();
    let _ = server.kill();

    let verify = run(&cwd, None, &["adapters", "verify", "--adapter-store", &store_s]);
    assert_success(&verify, "adapters verify after killing the server mid-connection");
    let (stdout, _) = out_str(&verify);
    assert_has(&stdout, "verified 3 record(s), 0 failure(s)", "store must survive the kill");

    // Warm respawn: the fleet is restartable from the surviving store.
    let warm = NetServer::spawn(&cwd, None, &store_s, 1);
    let reply = one_shot(&warm.addr, &req_line(9, "qnli"));
    assert_has(&reply, "\"logits\"", "the respawned server must serve from the store");
    let all = warm.finish();
    assert_has(&all, "3/3 from store", "the respawn must warm-start, not retrain");
}

/// A worker whose engine wedges with a request in flight must leave a
/// post-mortem: the `net.engine` hang fires only once work is queued, and
/// the flight recorder dumps the in-flight request's spans (at least its
/// `admit`) to stderr before the supervisor would SIGKILL it — the black
/// box that says what the server was doing when it died.
#[test]
fn chaos_flight_recorder_dumps_in_flight_spans_on_engine_hang() {
    let _g = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
    let cwd = shared_cwd();
    let store = fresh_dir("store_flight");
    let store_s = store.display().to_string();

    let server = NetServer::spawn(&cwd, Some("net.engine=hang"), &store_s, 2);

    // One admitted request: it parks behind the engine (which hangs the
    // moment the queue is non-empty), so no reply ever comes.
    let mut stream = TcpStream::connect(&server.addr).expect("connect");
    stream.write_all(req_line(0, "sst2").as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.flush().unwrap();

    // Give the engine loop a beat to see the queued work, fire the hang,
    // and flush the dump, then play the supervisor and kill it.
    std::thread::sleep(Duration::from_secs(1));
    let all = server.kill();
    assert_has(&all, "FAULT: injected hang at net.engine", "the fault must actually fire");
    assert_has(&all, "FLIGHT_BEGIN reason=net.engine", "the dump must open with its reason");
    assert_has(&all, "FLIGHT_END reason=net.engine", "the dump must close");
    assert!(
        all.lines().any(|l| l.starts_with("FLIGHT {") && l.contains("\"stage\":\"admit\"")),
        "the dump must carry the in-flight request's admit span:\n{all}"
    );
    drop(stream);
}
