//! Property tests for the worker-pool parallelization: every parallel path
//! (tensor kernels, full train/eval/pretrain steps) must produce
//! **bit-identical** output for threads=1 vs threads=N. The pool partitions
//! work into contiguous row spans without changing per-element accumulation
//! order, so these are exact (`to_bits`) comparisons, not tolerances.

use std::collections::BTreeMap;

use qrlora::data::HeadKind;
use qrlora::kernels::{self, Kernels};
use qrlora::model::host::{
    eval_forward, pretrain_step, train_step, FrozenMap, FrozenValue, MethodKind, MlmBatchRef,
    TaskBatchRef,
};
use qrlora::runtime::{Manifest, Preset, Role, StateLayout};
use qrlora::tensor::Tensor;
use qrlora::util::pool;
use qrlora::util::rng::Rng;

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: bit mismatch at {i}: {x} vs {y}"
        );
    }
}

#[test]
fn matmul_kernels_bit_identical_across_thread_counts() {
    // Tall, wide, square, and ragged shapes; sizes straddle the serial
    // cutoff so both paths are exercised. The shapes are shared with the
    // SIMD parity suite (`rust/tests/kernels.rs`) via
    // `kernels::PARITY_SHAPES`, and per-thread bit-identity must hold for
    // every kernel backend — the SIMD lanes carry the same accumulation
    // chains the scalar reference does, and the pool partitions rows the
    // same way regardless of the backend.
    for &(m, k, n) in kernels::PARITY_SHAPES {
        let mut rng = Rng::new((m * 1_000_003 + k * 1009 + n) as u64);
        let a = Tensor::randn(&[m, k], &mut rng, 1.0);
        let bt = Tensor::randn(&[n, k], &mut rng, 1.0); // matmul_t RHS
        let b = Tensor::randn(&[k, n], &mut rng, 1.0); // matmul RHS
        let c = Tensor::randn(&[m, n], &mut rng, 1.0); // t_matmul RHS
        for kern in [Kernels::scalar(), Kernels::detected(false)] {
            let tag = kern.describe();
            kernels::with_kernels(kern, || {
                let s_mt = pool::with_threads(1, || a.matmul_t(&bt));
                let s_mm = pool::with_threads(1, || a.matmul(&b));
                let s_tm = pool::with_threads(1, || a.t_matmul(&c));
                for t in [2usize, 4, 7] {
                    let p_mt = pool::with_threads(t, || a.matmul_t(&bt));
                    let p_mm = pool::with_threads(t, || a.matmul(&b));
                    let p_tm = pool::with_threads(t, || a.t_matmul(&c));
                    let what = format!("matmul_t {m}x{k}x{n} t={t} [{tag}]");
                    assert_bits_eq(&s_mt.data, &p_mt.data, &what);
                    let what = format!("matmul {m}x{k}x{n} t={t} [{tag}]");
                    assert_bits_eq(&s_mm.data, &p_mm.data, &what);
                    let what = format!("t_matmul {m}x{k}x{n} t={t} [{tag}]");
                    assert_bits_eq(&s_tm.data, &p_tm.data, &what);
                }
            });
        }
    }
}

#[test]
fn t_matmul_zero_skip_rows_bit_identical() {
    // The zero-skip branch must not interact with the row partition: zero
    // rows land inside and across span boundaries.
    let mut rng = Rng::new(4242);
    let mut a = Tensor::randn(&[96, 64], &mut rng, 1.0);
    for i in 0..96 {
        if i % 3 != 0 {
            for v in a.row_mut(i) {
                *v = 0.0;
            }
        }
    }
    let c = Tensor::randn(&[96, 80], &mut rng, 1.0);
    let serial = pool::with_threads(1, || a.t_matmul(&c));
    for t in [2usize, 4] {
        let par = pool::with_threads(t, || a.t_matmul(&c));
        assert_bits_eq(&serial.data, &par.data, &format!("sparse t_matmul t={t}"));
    }
}

#[test]
fn fixed_chunk_row_reductions_bit_identical_across_thread_counts() {
    // The PR-2 carve-out ("row reductions stay serial") is closed: column
    // sums, LayerNorm dγ/dβ, and the global gradient norm now run as
    // fixed-chunk partial sums (`pool::par_reduce_rows`). This mirrors the
    // col_sum shape (the model-internal reductions are private; the
    // train/pretrain step tests below cover them end to end) on sizes that
    // straddle both the chunk size and the serial cutoff.
    for &(rows, cols) in &[(8usize, 16usize), (64, 32), (65, 7), (300, 48), (1030, 5)] {
        let mut rng = Rng::new((rows * 31 + cols) as u64);
        let t = Tensor::randn(&[rows, cols], &mut rng, 1.0);
        let colsum = || {
            pool::par_reduce_rows::<f32, _>(rows, cols, 1 << 20, |row0, n, acc| {
                for i in row0..row0 + n {
                    for (a, &v) in acc.iter_mut().zip(t.row(i)) {
                        *a += v;
                    }
                }
            })
        };
        let serial = pool::with_threads(1, colsum);
        for th in [2usize, 4, 7] {
            let par = pool::with_threads(th, colsum);
            assert_bits_eq(&serial, &par, &format!("col_sum {rows}x{cols} t={th}"));
        }
        // Grad-norm shape: one f64 accumulator over a flat buffer.
        let sumsq = || {
            pool::par_reduce_rows::<f64, _>(t.data.len(), 1, 1 << 20, |lo, len, acc| {
                for &v in &t.data[lo..lo + len] {
                    acc[0] += (v as f64) * (v as f64);
                }
            })[0]
        };
        let s = pool::with_threads(1, sumsq);
        for th in [2usize, 4, 7] {
            let p = pool::with_threads(th, sumsq);
            assert_eq!(s.to_bits(), p.to_bits(), "sumsq {rows}x{cols} t={th}: {s} vs {p}");
        }
    }
}

fn setup(key: &str) -> (Preset, StateLayout, Vec<f32>, FrozenMap) {
    let m = Manifest::builtin();
    let a = m.artifact(key).unwrap();
    let p = m.preset(&a.preset).unwrap().clone();
    let layout = a.layout().unwrap().clone();
    let mut rng = Rng::new(31);
    let mut state = vec![0f32; layout.total];
    for f in &layout.params {
        for i in 0..f.numel() {
            state[f.offset + i] = rng.normal() * 0.05;
        }
    }
    let mut frozen: FrozenMap = BTreeMap::new();
    for (_, t) in a.inputs_with_role(Role::Frozen) {
        let data: Vec<f32> = if t.name.ends_with("/mask") {
            vec![1.0; t.numel()]
        } else {
            (0..t.numel()).map(|_| rng.normal() * 0.1).collect()
        };
        frozen.insert(t.name.clone(), FrozenValue::dense(Tensor::from_vec(&t.shape, data)));
    }
    (p, layout, state, frozen)
}

#[test]
fn train_and_eval_steps_bit_identical_across_thread_counts() {
    for (key, method) in [
        ("tiny/train_step_qrlora_cls", MethodKind::QrLora),
        ("tiny/train_step_lora_cls", MethodKind::Lora),
    ] {
        let (p, layout, state, frozen) = setup(key);
        let bs = p.batch * p.max_seq;
        let ids: Vec<i32> = (0..bs).map(|i| ((i * 7 + 2) % p.vocab) as i32).collect();
        let type_ids = vec![0i32; bs];
        let attn_mask: Vec<f32> =
            (0..bs).map(|i| if i % p.max_seq < p.max_seq - 3 { 1.0 } else { 0.0 }).collect();
        let labels: Vec<i32> = (0..p.batch).map(|i| (i % 2) as i32).collect();
        let class_mask = vec![1.0f32; p.n_classes];
        let example_w = vec![1.0f32; p.batch];
        let batch = TaskBatchRef {
            input_ids: &ids,
            type_ids: &type_ids,
            attn_mask: &attn_mask,
            labels_i32: &labels,
            labels_f32: &[],
            class_mask: &class_mask,
            example_w: &example_w,
        };
        let serial = pool::with_threads(1, || {
            train_step(&p, method, HeadKind::Cls, &layout, &state, &frozen, &batch, 1e-3, 1.0)
        });
        let serial_eval = pool::with_threads(1, || {
            eval_forward(&p, method, HeadKind::Cls, &layout, &state, &frozen, &batch)
        });
        for t in [2usize, 4] {
            let par = pool::with_threads(t, || {
                train_step(&p, method, HeadKind::Cls, &layout, &state, &frozen, &batch, 1e-3, 1.0)
            });
            assert_bits_eq(&serial, &par, &format!("{key} train_step t={t}"));
            let par_eval = pool::with_threads(t, || {
                eval_forward(&p, method, HeadKind::Cls, &layout, &state, &frozen, &batch)
            });
            assert_bits_eq(&serial_eval, &par_eval, &format!("{key} eval_fwd t={t}"));
        }
    }
}

#[test]
fn pretrain_step_bit_identical_across_thread_counts() {
    let (p, layout, state, _) = setup("tiny/pretrain_step");
    let bs = p.batch * p.max_seq;
    let ids: Vec<i32> = (0..bs).map(|i| ((i * 17 + 3) % p.vocab) as i32).collect();
    let type_ids = vec![0i32; bs];
    let attn_mask = vec![1.0f32; bs];
    let mut labels = vec![-100i32; bs];
    for i in (0..bs).step_by(7) {
        labels[i] = ((i * 31) % p.vocab) as i32;
    }
    let batch = MlmBatchRef {
        input_ids: &ids,
        type_ids: &type_ids,
        attn_mask: &attn_mask,
        mlm_labels: &labels,
    };
    let serial = pool::with_threads(1, || pretrain_step(&p, &layout, &state, &batch, 2e-3, 1.0));
    for t in [2usize, 4] {
        let par = pool::with_threads(t, || pretrain_step(&p, &layout, &state, &batch, 2e-3, 1.0));
        assert_bits_eq(&serial, &par, &format!("pretrain_step t={t}"));
    }
}
