//! Int8 frozen-backbone quantization: error bounds, kernel correctness,
//! thread-count bit-identity, residency, and the documented end-to-end
//! accuracy contract (`quant::METRIC_DELTA_BOUND`).

use std::collections::BTreeMap;

use qrlora::adapters::{Proj, Scope};
use qrlora::data::{task, HeadKind, Lexicon, TaskData};
use qrlora::kernels::{self, Kernels};
use qrlora::linalg::RankRule;
use qrlora::quant::{self, QuantTensor, QUANT_GROUP_ROWS};
use qrlora::runtime::{Backend, HostBackend};
use qrlora::tensor::Tensor;
use qrlora::training::{self, FinetuneJob, Methods, Session, TrainConfig};
use qrlora::util::pool;
use qrlora::util::rng::Rng;

/// Random backbone with the ft layout's parameter names/shapes.
fn synthetic_backbone(bk: &dyn Backend) -> BTreeMap<String, Tensor> {
    let exe = bk.load("tiny/train_step_ft_cls").unwrap();
    let mut rng = Rng::new(7);
    let mut backbone = BTreeMap::new();
    for f in &exe.spec.layout().unwrap().params {
        if !f.name.starts_with("head/") {
            backbone.insert(f.name.clone(), Tensor::randn(&f.shape, &mut rng, 0.05));
        }
    }
    backbone
}

fn qr_session<'a>(
    bk: &'a HostBackend,
    backbone: &BTreeMap<String, Tensor>,
    seed: u64,
) -> Session<'a> {
    let preset = bk.manifest().preset("tiny").unwrap().clone();
    let method = Methods::qr_lora(
        backbone,
        &preset,
        Scope::all_layers(&[Proj::Q, Proj::V]),
        0.5,
        RankRule::DiagRatio,
    )
    .unwrap();
    Session::finetune(bk, &preset, &method, HeadKind::Cls, backbone, None, seed).unwrap()
}

fn tiny_batch(bk: &dyn Backend) -> qrlora::data::Batch {
    let preset = bk.manifest().preset("tiny").unwrap().clone();
    let lex = Lexicon::new(preset.vocab);
    let data = TaskData::generate(task("sst2").unwrap(), &lex, 13);
    let batcher = qrlora::data::Batcher::new(&preset, false);
    let refs: Vec<&qrlora::data::Example> = data.train[..preset.batch].iter().collect();
    batcher.assemble(&refs)
}

/// An outlier row must only perturb its own scale group: rows outside the
/// group keep the tight per-group absmax/254 error bound (a single global
/// absmax scale would smear a ~1000x outlier into every row's error).
#[test]
fn outlier_rows_do_not_poison_other_groups() {
    let mut rng = Rng::new(3);
    let mut t = Tensor::randn(&[16, 32], &mut rng, 0.5);
    for v in t.row_mut(9) {
        *v *= 1000.0;
    }
    let q = QuantTensor::quantize(&t, 4);
    let back = q.dequantize();
    for i in 0..16 {
        let bound = q.scale_of_row(i) * 0.500001 + 1e-7;
        for j in 0..32 {
            let err = (t.at(i, j) - back.at(i, j)).abs();
            assert!(err <= bound, "({i},{j}): err {err} > bound {bound}");
        }
        if !(8..12).contains(&i) {
            // Outside the outlier's group the scale is the row's own
            // small absmax, so the bound stays tiny.
            assert!(q.scale_of_row(i) < 0.05, "row {i} scale {} polluted", q.scale_of_row(i));
        }
    }
    assert!(q.scale_of_row(9) > 1.0, "outlier group must carry a large scale");
}

/// The fused scalar kernels must agree with dequantize-then-matmul (the
/// only difference is where the scale multiply lands, so tolerance is fp32
/// rounding, not quantization error). Pinned to the scalar backend: on
/// SIMD backends `matmul_xw_q` takes the integer-accumulate path, whose
/// additional (bounded, documented) activation-quantization error is
/// covered by `rust/tests/kernels.rs` instead.
#[test]
fn fused_kernels_match_dequantized_reference() {
    let mut rng = Rng::new(5);
    let x = Tensor::randn(&[8, 48], &mut rng, 1.0);
    let w = Tensor::randn(&[48, 24], &mut rng, 0.8);
    let wq = QuantTensor::quantize(&w.t(), QUANT_GROUP_ROWS); // stored (24, 48)
    let dy = Tensor::randn(&[8, 24], &mut rng, 1.0);

    kernels::with_kernels(Kernels::scalar(), || {
        let fwd = quant::matmul_xw_q(&x, &wq); // x·W via int8
        let fwd_ref = x.matmul(&wq.dequantize().t());
        assert_eq!(fwd.shape, vec![8, 24]);
        assert!(fwd.max_abs_diff(&fwd_ref) < 1e-3, "fwd diff {}", fwd.max_abs_diff(&fwd_ref));

        let bwd = quant::matmul_dyw_t_q(&dy, &wq); // dy·Wᵀ via int8
        let bwd_ref = dy.matmul(&wq.dequantize());
        assert_eq!(bwd.shape, vec![8, 48]);
        assert!(bwd.max_abs_diff(&bwd_ref) < 1e-3, "bwd diff {}", bwd.max_abs_diff(&bwd_ref));
    });
}

/// Kernel-level thread-count bit-identity (shapes big enough to clear the
/// pool's serial cutoff).
#[test]
fn fused_kernels_bit_identical_across_threads() {
    let mut rng = Rng::new(8);
    let x = Tensor::randn(&[64, 128], &mut rng, 1.0);
    let w = Tensor::randn(&[128, 96], &mut rng, 1.0);
    let wq = QuantTensor::quantize(&w.t(), QUANT_GROUP_ROWS);
    let dy = Tensor::randn(&[64, 96], &mut rng, 1.0);
    let fwd1 = pool::with_threads(1, || quant::matmul_xw_q(&x, &wq));
    let bwd1 = pool::with_threads(1, || quant::matmul_dyw_t_q(&dy, &wq));
    for t in [2usize, 3, 5] {
        let fwd = pool::with_threads(t, || quant::matmul_xw_q(&x, &wq));
        let bwd = pool::with_threads(t, || quant::matmul_dyw_t_q(&dy, &wq));
        assert_eq!(fwd, fwd1, "matmul_xw_q t={t}");
        assert_eq!(bwd, bwd1, "matmul_dyw_t_q t={t}");
    }
}

/// The deprecated `matmul_qt`/`matmul_q` names must stay exact aliases of
/// `matmul_xw_q`/`matmul_dyw_t_q` for their one-PR deprecation window.
#[test]
#[allow(deprecated)]
fn deprecated_shims_alias_renamed_kernels() {
    let mut rng = Rng::new(11);
    let x = Tensor::randn(&[6, 40], &mut rng, 1.0);
    let w = Tensor::randn(&[40, 16], &mut rng, 0.7);
    let wq = QuantTensor::quantize(&w.t(), QUANT_GROUP_ROWS);
    let dy = Tensor::randn(&[6, 16], &mut rng, 1.0);
    assert_eq!(quant::matmul_qt(&x, &wq), quant::matmul_xw_q(&x, &wq));
    assert_eq!(quant::matmul_q(&dy, &wq), quant::matmul_dyw_t_q(&dy, &wq));
}

/// Full quantized train/eval steps through the backend must be
/// bit-identical for any thread count (the serving-path twin lives in
/// `serve_batched.rs::*_int8_backbone`).
#[test]
fn quantized_session_bit_identical_across_threads() {
    let run = |threads: usize| {
        pool::with_threads(threads, || {
            let bk = HostBackend::new_quantized();
            let backbone = synthetic_backbone(&bk);
            let mut session = qr_session(&bk, &backbone, 3);
            let batch = tiny_batch(&bk);
            session.step(&batch, 2, 1e-3).unwrap();
            let logits = session.forward(&batch, 2).unwrap();
            (session.download_state().unwrap(), logits)
        })
    };
    let (state1, logits1) = run(1);
    let (state3, logits3) = run(3);
    assert_eq!(state1.len(), state3.len());
    for (i, (a, b)) in state1.iter().zip(&state3).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "state[{i}]: {a} vs {b}");
    }
    for (i, (a, b)) in logits1.iter().zip(&logits3).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "logits[{i}]: {a} vs {b}");
    }
}

/// The acceptance gate on resident memory: backbone weights (embeddings +
/// attention/FFN projections) must shrink ≥3.5x vs f32, and the f32
/// backend must report no reduction.
#[test]
fn frozen_backbone_residency_reduced_at_least_3_5x() {
    let bk = HostBackend::new_quantized();
    let backbone = synthetic_backbone(&bk);
    let session = qr_session(&bk, &backbone, 4);
    let batch = tiny_batch(&bk);
    session.forward(&batch, 2).unwrap();
    let r = bk.frozen_residency().unwrap();
    assert!(r.backbone_f32_bytes > 0, "cache must hold backbone weights");
    assert!(r.other_bytes > 0, "QR factors/masks must stay f32");
    assert!(
        r.reduction() >= 3.5,
        "resident reduction {:.2}x below 3.5x ({} -> {} bytes)",
        r.reduction(),
        r.backbone_f32_bytes,
        r.backbone_resident_bytes
    );
    // Steady state: a second forward re-serves the cache, no growth.
    session.forward(&batch, 2).unwrap();
    assert_eq!(bk.frozen_residency().unwrap(), r);

    let bk32 = HostBackend::new();
    let backbone32 = synthetic_backbone(&bk32);
    let session32 = qr_session(&bk32, &backbone32, 4);
    let batch32 = tiny_batch(&bk32);
    session32.forward(&batch32, 2).unwrap();
    let r32 = bk32.frozen_residency().unwrap();
    assert_eq!(r32.backbone_f32_bytes, r32.backbone_resident_bytes);
    assert!((r32.reduction() - 1.0).abs() < 1e-9);
}

/// The documented end-to-end accuracy contract: an adapter trained and
/// evaluated against the int8 backbone must land within
/// `quant::METRIC_DELTA_BOUND` of the f32 path's eval metric, for both
/// adapter methods.
#[test]
fn eval_metric_parity_quant_vs_f32() {
    let lex = Lexicon::new(512);
    let spec = task("sst2").unwrap();
    let mut data = TaskData::generate(spec, &lex, 7);
    data.train.truncate(256);
    data.dev.truncate(128);

    // One pretrained backbone for every run: pretraining is full FT (no
    // frozen inputs), so it is identical on both backends.
    let bk32 = HostBackend::new();
    let (backbone, _) = training::pretrain(&bk32, "tiny", &lex, 60, 1e-3, 1).unwrap();
    let preset = bk32.manifest().preset("tiny").unwrap().clone();

    let accuracy_on = |bk: &HostBackend, method_name: &str| -> f64 {
        let method = match method_name {
            "qrlora" => Methods::qr_lora(
                &backbone,
                &preset,
                Scope::all_layers(&[Proj::Q, Proj::V]),
                0.5,
                RankRule::DiagRatio,
            )
            .unwrap(),
            "lora" => Methods::lora(&backbone, &preset, 2.0, 2).unwrap(),
            other => panic!("unknown method {other}"),
        };
        let job = FinetuneJob {
            rt: bk,
            preset: "tiny",
            task: &data,
            lexicon: &lex,
            backbone: &backbone,
            head: None,
            config: TrainConfig {
                steps: 60,
                lr: 2e-3,
                warmup_steps: 5,
                train_examples: 256,
                log_every: 100,
            },
            seed: 3,
        };
        let result = training::run_finetune(&job, &method).unwrap();
        assert!(result.final_loss.is_finite(), "{method_name}: non-finite loss");
        result.dev.accuracy
    };

    let bk8 = HostBackend::new_quantized();
    for method_name in ["qrlora", "lora"] {
        let acc32 = accuracy_on(&bk32, method_name);
        let acc8 = accuracy_on(&bk8, method_name);
        let delta = (acc32 - acc8).abs();
        assert!(
            delta <= quant::METRIC_DELTA_BOUND,
            "{method_name}: |f32 {acc32:.3} - int8 {acc8:.3}| = {delta:.3} exceeds the \
             documented bound {}",
            quant::METRIC_DELTA_BOUND
        );
    }
}
